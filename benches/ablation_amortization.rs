//! **Amortization ablation** (DESIGN.md E5) — the paper's §4 claim: "As the
//! number of output channels increases, the speed-up will asymptotically
//! approach the maximum achievable", because the input/output transform
//! costs are amortized over channel-deep GEMMs.
//!
//! Sweep M (output channels) for a fixed 3×3 layer and report the
//! im2row-vs-Winograd speedup curve; it must rise with M and flatten.
//! Also sweeps C (input channels) to show the same effect on the GEMM's
//! inner dimension, and prints the im2row crossover region (small C·M where
//! transforms dominate — the `MIN_CHANNEL_PRODUCT` selector threshold).
//!
//! E5c sweeps the **region-block size** (the L2 workspace budget) on a
//! VGG-ish layer: per-block workspace bytes must stay under each budget
//! while wall time stays flat-to-better vs the unblocked configuration —
//! the amortisation argument applied to the memory axis.

use winoconv::bench::{measure, BenchConfig, Table};
use winoconv::im2row::Im2RowConvolution;
use winoconv::parallel::ThreadPool;
use winoconv::tensor::Tensor;
use winoconv::util::cli::Args;
use winoconv::winograd::{WinogradConvolution, WinogradVariant};

fn main() -> winoconv::Result<()> {
    let args = Args::from_env(&["quick", "bench"])?;
    let threads: usize = args.get_parse_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    )?;
    let pool = ThreadPool::new(threads);
    let cfg = if args.flag("quick") { BenchConfig::quick() } else { BenchConfig::from_env() };

    let (h, w, c_fixed) = (28usize, 28usize, 64usize);
    let input = Tensor::randn(&[1, h, w, c_fixed], 1);

    let mut table = Table::new(
        &format!("E5a: speedup vs output channels M (28x28x{c_fixed}, 3x3, F(4x4,3x3))"),
        &["M", "im2row ms", "ours ms", "speedup"],
    );
    for m in [4usize, 8, 16, 32, 64, 128, 256, 512] {
        let weights = Tensor::randn(&[m, 3, 3, c_fixed], m as u64);
        let base_conv = Im2RowConvolution::new(&weights, (1, 1), (1, 1))?;
        let wino = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1))?;
        let base = measure(&cfg, || {
            let _ = base_conv.run(&input, Some(&pool)).unwrap();
        });
        let ours = measure(&cfg, || {
            let _ = wino.run(&input, Some(&pool)).unwrap();
        });
        table.row(&[
            m.to_string(),
            format!("{:.2}", base.median / 1e6),
            format!("{:.2}", ours.median / 1e6),
            format!("{:.2}x", base.median / ours.median),
        ]);
    }
    table.print();

    let mut table = Table::new(
        "E5b: speedup vs input channels C (28x28, 3x3 -> 64 filters)",
        &["C", "im2row ms", "ours ms", "speedup"],
    );
    for c in [1usize, 2, 4, 8, 16, 64, 128, 256] {
        let x = Tensor::randn(&[1, h, w, c], c as u64);
        let weights = Tensor::randn(&[64, 3, 3, c], 7);
        let base_conv = Im2RowConvolution::new(&weights, (1, 1), (1, 1))?;
        let wino = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1))?;
        let base = measure(&cfg, || {
            let _ = base_conv.run(&x, Some(&pool)).unwrap();
        });
        let ours = measure(&cfg, || {
            let _ = wino.run(&x, Some(&pool)).unwrap();
        });
        table.row(&[
            c.to_string(),
            format!("{:.2}", base.median / 1e6),
            format!("{:.2}", ours.median / 1e6),
            format!("{:.2}x", base.median / ours.median),
        ]);
    }
    table.print();

    // ---- E5c: region-block size sweep (the tentpole's memory knob) ----
    let (h, c, m) = (56usize, 128usize, 128usize);
    let input = Tensor::randn(&[1, h, h, c], 2);
    let weights = Tensor::randn(&[m, 3, 3, c], 3);
    let mut table = Table::new(
        &format!("E5c: block-size sweep (56x56x{c} 3x3 -> {m}, F(4x4,3x3))"),
        &["L2 budget", "regions/block", "block ws KiB", "ms", "vs unblocked"],
    );
    let unblocked = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1))?
        .with_block_budget(usize::MAX);
    let base = measure(&cfg, || {
        let _ = unblocked.run(&input, Some(&pool)).unwrap();
    });
    let budgets: [(usize, &str); 6] = [
        (64 * 1024, "64 KiB"),
        (128 * 1024, "128 KiB"),
        (256 * 1024, "256 KiB"),
        (512 * 1024, "512 KiB"),
        (1024 * 1024, "1 MiB"),
        (usize::MAX, "unbounded"),
    ];
    for (budget, label) in budgets {
        let wino = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1))?
            .with_block_budget(budget);
        let ours = measure(&cfg, || {
            let _ = wino.run(&input, Some(&pool)).unwrap();
        });
        let block_ws = wino.block_workspace_bytes(1, h, h)?;
        if budget != usize::MAX {
            assert!(
                block_ws <= budget,
                "per-block workspace {block_ws} B exceeds the {label} budget"
            );
        }
        table.row(&[
            label.to_string(),
            wino.regions_per_block(1, h, h)?.to_string(),
            format!("{}", block_ws / 1024),
            format!("{:.2}", ours.median / 1e6),
            format!("{:.2}x", base.median / ours.median),
        ]);
    }
    table.print();

    println!(
        "shape check (paper §4): speedup rises with M and C and saturates;\n\
         at tiny C·M the transforms dominate — that region is why the selector\n\
         (conv::select) keeps shallow layers on im2row. E5c: per-block workspace\n\
         tracks the budget while runtime stays flat — blocking buys the memory\n\
         cap for free."
    );
    Ok(())
}
