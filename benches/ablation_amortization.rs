//! **Amortization ablation** (DESIGN.md E5) — the paper's §4 claim: "As the
//! number of output channels increases, the speed-up will asymptotically
//! approach the maximum achievable", because the input/output transform
//! costs are amortized over channel-deep GEMMs.
//!
//! Sweep M (output channels) for a fixed 3×3 layer and report the
//! im2row-vs-Winograd speedup curve; it must rise with M and flatten.
//! Also sweeps C (input channels) to show the same effect on the GEMM's
//! inner dimension, and prints the im2row crossover region (small C·M where
//! transforms dominate — the `MIN_CHANNEL_PRODUCT` selector threshold).

use winoconv::bench::{measure, BenchConfig, Table};
use winoconv::im2row::Im2RowConvolution;
use winoconv::parallel::ThreadPool;
use winoconv::tensor::Tensor;
use winoconv::util::cli::Args;
use winoconv::winograd::{WinogradConvolution, WinogradVariant};

fn main() -> winoconv::Result<()> {
    let args = Args::from_env(&["quick", "bench"])?;
    let threads: usize = args.get_parse_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    )?;
    let pool = ThreadPool::new(threads);
    let cfg = if args.flag("quick") { BenchConfig::quick() } else { BenchConfig::from_env() };

    let (h, w, c_fixed) = (28usize, 28usize, 64usize);
    let input = Tensor::randn(&[1, h, w, c_fixed], 1);

    let mut table = Table::new(
        &format!("E5a: speedup vs output channels M (28x28x{c_fixed}, 3x3, F(4x4,3x3))"),
        &["M", "im2row ms", "ours ms", "speedup"],
    );
    for m in [4usize, 8, 16, 32, 64, 128, 256, 512] {
        let weights = Tensor::randn(&[m, 3, 3, c_fixed], m as u64);
        let base_conv = Im2RowConvolution::new(&weights, (1, 1), (1, 1))?;
        let wino = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1))?;
        let base = measure(&cfg, || {
            let _ = base_conv.run(&input, Some(&pool)).unwrap();
        });
        let ours = measure(&cfg, || {
            let _ = wino.run(&input, Some(&pool)).unwrap();
        });
        table.row(&[
            m.to_string(),
            format!("{:.2}", base.median / 1e6),
            format!("{:.2}", ours.median / 1e6),
            format!("{:.2}x", base.median / ours.median),
        ]);
    }
    table.print();

    let mut table = Table::new(
        "E5b: speedup vs input channels C (28x28, 3x3 -> 64 filters)",
        &["C", "im2row ms", "ours ms", "speedup"],
    );
    for c in [1usize, 2, 4, 8, 16, 64, 128, 256] {
        let x = Tensor::randn(&[1, h, w, c], c as u64);
        let weights = Tensor::randn(&[64, 3, 3, c], 7);
        let base_conv = Im2RowConvolution::new(&weights, (1, 1), (1, 1))?;
        let wino = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1))?;
        let base = measure(&cfg, || {
            let _ = base_conv.run(&x, Some(&pool)).unwrap();
        });
        let ours = measure(&cfg, || {
            let _ = wino.run(&x, Some(&pool)).unwrap();
        });
        table.row(&[
            c.to_string(),
            format!("{:.2}", base.median / 1e6),
            format!("{:.2}", ours.median / 1e6),
            format!("{:.2}x", base.median / ours.median),
        ]);
    }
    table.print();
    println!(
        "shape check (paper §4): speedup rises with M and C and saturates;\n\
         at tiny C·M the transforms dominate — that region is why the selector\n\
         (conv::select) keeps shallow layers on im2row."
    );
    Ok(())
}
