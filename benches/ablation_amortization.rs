//! **Amortization ablation** (DESIGN.md E5) — the paper's §4 claim: "As the
//! number of output channels increases, the speed-up will asymptotically
//! approach the maximum achievable", because the input/output transform
//! costs are amortized over channel-deep GEMMs.
//!
//! Sweep M (output channels) for a fixed 3×3 layer and report the
//! im2row-vs-Winograd speedup curve; it must rise with M and flatten.
//! Also sweeps C (input channels) to show the same effect on the GEMM's
//! inner dimension, and prints the im2row crossover region (small C·M where
//! transforms dominate — the `MIN_CHANNEL_PRODUCT` selector threshold).
//!
//! E5c sweeps the **region-block size** (the L2 workspace budget) on a
//! VGG-ish layer: per-block workspace bytes must stay under each budget
//! while wall time stays flat-to-better vs the unblocked configuration —
//! the amortisation argument applied to the memory axis.
//!
//! **E6** is the fusion ablation: the fused pipeline (transform-as-pack +
//! gather-as-epilogue, C never materialised) vs the staged three-pass
//! pipeline (`run_staged_with`) on the Table-1 flagship's (VGG-16) fast
//! layers — the wall-clock value of moving Winograd-domain data through
//! the cache hierarchy once.
//!
//! `--smoke` runs a tiny-shape E6 only (with an equality assert) — the CI
//! bench bit-rot gate wired into `ci.sh`.

use winoconv::bench::workloads::unique_fast_layers;
use winoconv::bench::{measure, ms, BenchConfig, Table};
use winoconv::conv::Activation;
use winoconv::im2row::Im2RowConvolution;
use winoconv::parallel::ThreadPool;
use winoconv::tensor::Tensor;
use winoconv::util::cli::Args;
use winoconv::util::stats::ns_to_ms;
use winoconv::winograd::{WinogradConvolution, WinogradVariant};
use winoconv::workspace::Workspace;
use winoconv::zoo::ModelKind;

/// E6: fused vs staged on one layer; returns (staged ms, fused ms).
#[allow(clippy::too_many_arguments)]
fn e6_layer(
    pool: &ThreadPool,
    cfg: &BenchConfig,
    wino: &WinogradConvolution,
    input: &Tensor,
    bias: &[f32],
    n: usize,
    h: usize,
    w: usize,
    check_equal: bool,
) -> winoconv::Result<(f64, f64, usize, usize)> {
    let staged_elems = wino.staged_workspace_elems_for(n, h, w)?;
    let fused_elems = wino.workspace_elems_for(n, h, w)?;
    let mut ws_s = Workspace::with_capacity(staged_elems);
    let mut ws_f = Workspace::with_capacity(fused_elems);
    if check_equal {
        let a = wino.run_staged_with(input, Some(pool), Some(bias), Activation::Relu, &mut ws_s)?;
        let b = wino.run_fused_with(input, Some(pool), Some(bias), Activation::Relu, &mut ws_f)?;
        assert!(a.allclose(&b, 1e-4), "E6: fused != staged");
    }
    let staged = measure(cfg, || {
        let _ = wino
            .run_staged_with(input, Some(pool), Some(bias), Activation::Relu, &mut ws_s)
            .unwrap();
    });
    let fused = measure(cfg, || {
        let _ = wino
            .run_fused_with(input, Some(pool), Some(bias), Activation::Relu, &mut ws_f)
            .unwrap();
    });
    Ok((ns_to_ms(staged.median), ns_to_ms(fused.median), staged_elems, fused_elems))
}

fn main() -> winoconv::Result<()> {
    let args = Args::from_env(&["quick", "bench", "smoke"])?;
    let threads: usize = args.get_parse_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    )?;
    let pool = ThreadPool::new(threads);
    let cfg = if args.flag("quick") { BenchConfig::quick() } else { BenchConfig::from_env() };

    if args.flag("smoke") {
        // CI bit-rot gate: one tiny shape through both pipelines, asserted
        // equal, under the quick measurement profile.
        let cfg = BenchConfig::quick();
        let weights = Tensor::randn(&[32, 3, 3, 32], 2);
        let wino = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1))?;
        let input = Tensor::randn(&[1, 14, 14, 32], 3);
        let bias: Vec<f32> = (0..32).map(|i| i as f32 * 1e-3).collect();
        let (s_ms, f_ms, _, _) = e6_layer(&pool, &cfg, &wino, &input, &bias, 1, 14, 14, true)?;
        println!(
            "E6 smoke (14x14x32 -> 32, F(4x4,3x3)): staged {s_ms:.2} ms, fused {f_ms:.2} ms"
        );
        println!("smoke ok: benches run and fused == staged");
        return Ok(());
    }

    let (h, w, c_fixed) = (28usize, 28usize, 64usize);
    let input = Tensor::randn(&[1, h, w, c_fixed], 1);

    let mut table = Table::new(
        &format!("E5a: speedup vs output channels M (28x28x{c_fixed}, 3x3, F(4x4,3x3))"),
        &["M", "im2row ms", "ours ms", "speedup"],
    );
    for m in [4usize, 8, 16, 32, 64, 128, 256, 512] {
        let weights = Tensor::randn(&[m, 3, 3, c_fixed], m as u64);
        let base_conv = Im2RowConvolution::new(&weights, (1, 1), (1, 1))?;
        let wino = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1))?;
        let base = measure(&cfg, || {
            let _ = base_conv.run(&input, Some(&pool)).unwrap();
        });
        let ours = measure(&cfg, || {
            let _ = wino.run(&input, Some(&pool)).unwrap();
        });
        table.row(&[
            m.to_string(),
            ms(base.median),
            ms(ours.median),
            format!("{:.2}x", base.median / ours.median),
        ]);
    }
    table.print();

    let mut table = Table::new(
        "E5b: speedup vs input channels C (28x28, 3x3 -> 64 filters)",
        &["C", "im2row ms", "ours ms", "speedup"],
    );
    for c in [1usize, 2, 4, 8, 16, 64, 128, 256] {
        let x = Tensor::randn(&[1, h, w, c], c as u64);
        let weights = Tensor::randn(&[64, 3, 3, c], 7);
        let base_conv = Im2RowConvolution::new(&weights, (1, 1), (1, 1))?;
        let wino = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1))?;
        let base = measure(&cfg, || {
            let _ = base_conv.run(&x, Some(&pool)).unwrap();
        });
        let ours = measure(&cfg, || {
            let _ = wino.run(&x, Some(&pool)).unwrap();
        });
        table.row(&[
            c.to_string(),
            ms(base.median),
            ms(ours.median),
            format!("{:.2}x", base.median / ours.median),
        ]);
    }
    table.print();

    // ---- E5c: region-block size sweep (the tentpole's memory knob) ----
    let (h, c, m) = (56usize, 128usize, 128usize);
    let input = Tensor::randn(&[1, h, h, c], 2);
    let weights = Tensor::randn(&[m, 3, 3, c], 3);
    let mut table = Table::new(
        &format!("E5c: block-size sweep (56x56x{c} 3x3 -> {m}, F(4x4,3x3))"),
        &["L2 budget", "regions/block", "block ws KiB", "ms", "vs unblocked"],
    );
    let unblocked = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1))?
        .with_block_budget(usize::MAX);
    let base = measure(&cfg, || {
        let _ = unblocked.run(&input, Some(&pool)).unwrap();
    });
    let budgets: [(usize, &str); 6] = [
        (64 * 1024, "64 KiB"),
        (128 * 1024, "128 KiB"),
        (256 * 1024, "256 KiB"),
        (512 * 1024, "512 KiB"),
        (1024 * 1024, "1 MiB"),
        (usize::MAX, "unbounded"),
    ];
    for (budget, label) in budgets {
        let wino = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1))?
            .with_block_budget(budget);
        let ours = measure(&cfg, || {
            let _ = wino.run(&input, Some(&pool)).unwrap();
        });
        let block_ws = wino.block_workspace_bytes(1, h, h)?;
        if budget != usize::MAX {
            // The packed-A block is padded to whole MR row panels; a budget
            // below one panel's footprint degenerates to the 1-region
            // minimum, which may exceed it (same floor the unit tests pin).
            assert!(
                block_ws <= budget || wino.regions_per_block(1, h, h)? == 1,
                "per-block workspace {block_ws} B exceeds the {label} budget"
            );
        }
        table.row(&[
            label.to_string(),
            wino.regions_per_block(1, h, h)?.to_string(),
            format!("{}", block_ws / 1024),
            ms(ours.median),
            format!("{:.2}x", base.median / ours.median),
        ]);
    }
    table.print();

    // ---- E6: fused (transform-as-pack + gather-as-epilogue) vs staged ----
    let mut table = Table::new(
        "E6: fused vs staged pipeline (VGG-16 fast layers, F(4x4,3x3), bias+ReLU)",
        &["layer", "staged ms", "fused ms", "speedup", "staged ws KiB", "fused ws KiB"],
    );
    for (spec, _count) in unique_fast_layers(ModelKind::Vgg16, 1)? {
        let input = spec.input(11);
        let weights = spec.weights(12);
        let wino = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, spec.pad)?;
        let bias: Vec<f32> = (0..spec.cout).map(|i| i as f32 * 1e-3).collect();
        let (n, hh, ww) = (spec.input_shape[0], spec.input_shape[1], spec.input_shape[2]);
        let (s_ms, f_ms, s_elems, f_elems) =
            e6_layer(&pool, &cfg, &wino, &input, &bias, n, hh, ww, false)?;
        table.row(&[
            spec.name.clone(),
            format!("{s_ms:.2}"),
            format!("{f_ms:.2}"),
            format!("{:.2}x", s_ms / f_ms),
            format!("{}", s_elems * 4 / 1024),
            format!("{}", f_elems * 4 / 1024),
        ]);
    }
    table.print();

    println!(
        "shape check (paper §4): speedup rises with M and C and saturates;\n\
         at tiny C·M the transforms dominate — that region is why the selector\n\
         (conv::select) keeps shallow layers on im2row. E5c: per-block workspace\n\
         tracks the budget while runtime stays flat — blocking buys the memory\n\
         cap for free. E6: the fused pipeline deletes the pack_a pass and the\n\
         Winograd-domain C block entirely (fused ws column), so fused <= staged\n\
         wall-clock is the expected shape on every layer — the paper's\n\
         'interleave the stages' claim in one table."
    );
    Ok(())
}
