//! **Batch ablation**: one batched GEMM sweep over `[N, H, W, C]` vs `N`
//! back-to-back batch-1 walks of the same engine on the same frames.
//!
//! The claim under test is the tentpole amortization model: with the
//! frames gathered contiguously, every layer's packed weight panel (the
//! GEMM B side) streams through cache **once for all N frames** instead of
//! once per frame, while the packed-A side (patch rows, Winograd regions,
//! NHWC rows) simply carries N× the rows. The math per output row is
//! unchanged, so the two paths must agree **bit for bit** — the batched
//! sweep is pure bandwidth/overhead savings, never a numerics trade.
//!
//! Workload: the unique Winograd-suitable ("fast") layers plus the unique
//! 1×1 and depthwise layers of a model (default VGG-16, another via
//! `--model`), at `--batch N` (default 4).
//!
//! `--smoke` runs shrunk VGG-16-shaped fast layers and a shrunk
//! MobileNetV2-shaped bottleneck (expand 1×1 → depthwise 3×3 → project
//! 1×1) at N ∈ {2, 4, 8} with correctness asserts (batched == N × batch-1
//! **bit-for-bit**, pre-sized arenas never grow) and **fails unless** the
//! batched sweep strictly beats the N sequential walks on every
//! weight-panel-bound layer (the winograd/pointwise GEMMs; the depthwise
//! layer has no shared B panel to amortise, so it is reported, not gated)
//! — the CI gate wired into `ci.sh`.

use winoconv::bench::workloads::{
    unique_depthwise_layers, unique_fast_layers, unique_pointwise_layers, LayerSpec,
};
use winoconv::bench::{measure, ms, BenchConfig, Table};
use winoconv::conv::depthwise::DepthwiseConvolution;
use winoconv::conv::pointwise::PointwiseConvolution;
use winoconv::conv::Activation;
use winoconv::im2row::Im2RowConvolution;
use winoconv::parallel::ThreadPool;
use winoconv::tensor::{Tensor, TensorView};
use winoconv::util::cli::Args;
use winoconv::winograd::{WinogradConvolution, WinogradVariant};
use winoconv::workspace::Workspace;
use winoconv::zoo::ModelKind;

/// The engine a layer spec binds for this ablation — mirrors the prepared
/// model's selector: depthwise → direct depthwise, dense 1×1 → zero-copy
/// pointwise, fast 3×3 → Winograd F(4×4, 3×3), everything else → im2row.
enum Engine {
    Wino(WinogradConvolution),
    Pw(PointwiseConvolution),
    Dw(DepthwiseConvolution),
    Im2Row(Im2RowConvolution),
}

impl Engine {
    fn bind(spec: &LayerSpec) -> winoconv::Result<Engine> {
        let weights = spec.weights(42);
        Ok(if spec.depthwise() {
            Engine::Dw(DepthwiseConvolution::new(&weights, spec.stride, spec.pad)?)
        } else if spec.pointwise() {
            Engine::Pw(PointwiseConvolution::new(&weights, spec.stride, spec.pad)?)
        } else if spec.fast() && spec.kernel == (3, 3) {
            Engine::Wino(WinogradConvolution::new(
                WinogradVariant::F4x4_3x3,
                &weights,
                spec.pad,
            )?)
        } else {
            Engine::Im2Row(Im2RowConvolution::new(&weights, spec.stride, spec.pad)?)
        })
    }

    fn label(&self) -> &'static str {
        match self {
            Engine::Wino(_) => "winograd",
            Engine::Pw(_) => "pointwise",
            Engine::Dw(_) => "depthwise",
            Engine::Im2Row(_) => "im2row",
        }
    }

    fn output_hw(&self, h: usize, w: usize) -> winoconv::Result<(usize, usize)> {
        match self {
            Engine::Wino(c) => c.output_hw(h, w),
            Engine::Pw(c) => c.output_hw(h, w),
            Engine::Dw(c) => c.output_hw(h, w),
            Engine::Im2Row(c) => c.output_hw(h, w),
        }
    }

    fn workspace_elems_for(&self, n: usize, h: usize, w: usize) -> winoconv::Result<usize> {
        match self {
            Engine::Wino(c) => c.workspace_elems_for(n, h, w),
            Engine::Pw(c) => c.workspace_elems_for(n, h, w),
            Engine::Dw(c) => c.workspace_elems_for(n, h, w),
            Engine::Im2Row(c) => c.workspace_elems_for(n, h, w),
        }
    }

    fn run_into(
        &self,
        input: &TensorView,
        pool: &ThreadPool,
        bias: &[f32],
        act: Activation,
        ws: &mut Workspace,
        out: &mut [f32],
    ) -> winoconv::Result<()> {
        match self {
            Engine::Wino(c) => c.run_fused_into(input, Some(pool), Some(bias), act, ws, out),
            Engine::Pw(c) => c.run_fused_into(input, Some(pool), Some(bias), act, ws, out),
            Engine::Dw(c) => c.run_fused_into(input, Some(pool), Some(bias), act, ws, out),
            Engine::Im2Row(c) => c.run_fused_into(input, Some(pool), Some(bias), act, ws, out),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn run_batched_into(
        &self,
        batch: &TensorView,
        nb: usize,
        pool: &ThreadPool,
        bias: &[f32],
        act: Activation,
        ws: &mut Workspace,
        out: &mut [f32],
    ) -> winoconv::Result<()> {
        match self {
            Engine::Wino(c) => {
                c.run_fused_batched_into(batch, nb, Some(pool), Some(bias), act, ws, out)
            }
            Engine::Pw(c) => {
                c.run_fused_batched_into(batch, nb, Some(pool), Some(bias), act, ws, out)
            }
            Engine::Dw(c) => {
                c.run_fused_batched_into(batch, nb, Some(pool), Some(bias), act, ws, out)
            }
            Engine::Im2Row(c) => {
                c.run_fused_batched_into(batch, nb, Some(pool), Some(bias), act, ws, out)
            }
        }
    }
}

/// One batched sweep vs `nb` back-to-back batch-1 walks on one layer.
/// Returns `(sequential, batched)` median seconds; with `check` set,
/// asserts the two paths agree bit-for-bit and neither pre-sized arena
/// grows.
fn bench_batched_layer(
    spec: &LayerSpec,
    nb: usize,
    cfg: &BenchConfig,
    pool: &ThreadPool,
    check: bool,
) -> winoconv::Result<(f64, f64, &'static str)> {
    let (h, w, c) = (spec.input_shape[1], spec.input_shape[2], spec.cin);
    let engine = Engine::bind(spec)?;
    let (oh, ow) = engine.output_hw(h, w)?;
    let act = Activation::Relu;
    let bias: Vec<f32> = Tensor::randn(&[spec.cout], 43).into_vec();
    let batch = Tensor::randn(&[nb, h, w, c], 44);
    let frame_in = h * w * c;
    let frame_out = oh * ow * spec.cout;
    let frame_shape = [1usize, h, w, c];
    let mut out_seq = vec![f32::NAN; nb * frame_out];
    let mut out_bat = vec![f32::NAN; nb * frame_out];
    let mut ws_seq = Workspace::with_capacity(engine.workspace_elems_for(1, h, w)?);
    let mut ws_bat = Workspace::with_capacity(engine.workspace_elems_for(nb, h, w)?);

    // The N back-to-back batch-1 walks the engine used to serve: each
    // frame re-streams every packed weight panel through cache.
    let sequential = |ws: &mut Workspace, out: &mut [f32]| -> winoconv::Result<()> {
        for f in 0..nb {
            let fv = TensorView::new(
                &frame_shape,
                &batch.data()[f * frame_in..(f + 1) * frame_in],
            )?;
            engine.run_into(&fv, pool, &bias, act, ws, &mut out[f * frame_out..(f + 1) * frame_out])?;
        }
        Ok(())
    };

    if check {
        sequential(&mut ws_seq, &mut out_seq)?;
        engine.run_batched_into(&batch.view(), nb, pool, &bias, act, &mut ws_bat, &mut out_bat)?;
        assert_eq!(
            out_bat, out_seq,
            "{} N={nb}: batched sweep and sequential walks must agree bit-for-bit",
            spec.name
        );
        assert_eq!(ws_seq.grow_count(), 0, "{}: pre-sized batch-1 arena grew", spec.name);
        assert_eq!(ws_bat.grow_count(), 0, "{}: pre-sized batched arena grew", spec.name);
    }

    let bat = measure(cfg, || {
        engine
            .run_batched_into(&batch.view(), nb, pool, &bias, act, &mut ws_bat, &mut out_bat)
            .unwrap();
    });
    let seq = measure(cfg, || {
        sequential(&mut ws_seq, &mut out_seq).unwrap();
    });
    Ok((seq.median, bat.median, engine.label()))
}

fn vgg_shaped(name: &str, hw: usize, cin: usize, cout: usize) -> LayerSpec {
    LayerSpec {
        model: ModelKind::Vgg16,
        name: name.to_string(),
        input_shape: vec![1, hw, hw, cin],
        cin,
        cout,
        kernel: (3, 3),
        stride: (1, 1),
        pad: (1, 1),
        groups: 1,
    }
}

fn mb2_pw(name: &str, hw: usize, cin: usize, cout: usize) -> LayerSpec {
    LayerSpec {
        model: ModelKind::MobileNetV2,
        name: name.to_string(),
        input_shape: vec![1, hw, hw, cin],
        cin,
        cout,
        kernel: (1, 1),
        stride: (1, 1),
        pad: (0, 0),
        groups: 1,
    }
}

fn mb2_dw(name: &str, hw: usize, c: usize) -> LayerSpec {
    LayerSpec {
        model: ModelKind::MobileNetV2,
        name: name.to_string(),
        input_shape: vec![1, hw, hw, c],
        cin: c,
        cout: c,
        kernel: (3, 3),
        stride: (1, 1),
        pad: (1, 1),
        groups: c,
    }
}

/// `--smoke`: the CI gate. Shrunk VGG-16-shaped fast layers and a shrunk
/// MobileNetV2-shaped bottleneck at N ∈ {2, 4, 8}: bitwise-identity and
/// arena asserts always, strictly-faster asserts on every
/// weight-panel-bound layer.
fn smoke(pool: &ThreadPool) -> winoconv::Result<()> {
    let cfg = BenchConfig::quick();
    let layers = [
        vgg_shaped("vgg_conv3_2", 28, 128, 128),
        vgg_shaped("vgg_conv4_2", 14, 256, 256),
        mb2_pw("mb2_expand", 14, 32, 192),
        mb2_dw("mb2_dw3x3", 14, 192),
        mb2_pw("mb2_project", 14, 192, 32),
    ];
    for nb in [2usize, 4, 8] {
        for spec in &layers {
            let (seq, bat, engine) = bench_batched_layer(spec, nb, &cfg, pool, true)?;
            let gated = engine != "depthwise";
            println!(
                "smoke {} [{engine}] N={nb}: {}x batch-1 {} ms -> batched {} ms ({:.2}x{})",
                spec.name,
                nb,
                ms(seq),
                ms(bat),
                seq / bat,
                if gated { "" } else { ", not gated" },
            );
            if gated {
                assert!(
                    bat < seq,
                    "smoke {} N={nb}: batched sweep ({} ms) must strictly beat {} back-to-back \
                     batch-1 walks ({} ms)",
                    spec.name,
                    ms(bat),
                    nb,
                    ms(seq)
                );
            }
        }
    }
    println!(
        "smoke ok: batched GEMM sweep strictly beats N back-to-back batch-1 walks \
         (bitwise-identical) on VGG-16 fast layers and the MobileNetV2 bottleneck at N in {{2,4,8}}"
    );
    Ok(())
}

fn main() -> winoconv::Result<()> {
    let args = Args::from_env(&["quick", "bench", "smoke"])?;
    let threads: usize = args.get_parse_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    )?;
    let pool = ThreadPool::new(threads);
    if args.flag("smoke") {
        return smoke(&pool);
    }
    let cfg = if args.flag("quick") { BenchConfig::quick() } else { BenchConfig::from_env() };
    let nb: usize = args.get_parse_or("batch", 4)?;
    if nb < 2 {
        return Err(winoconv::Error::Config("--batch must be at least 2".into()));
    }

    let model = match args.get("model") {
        Some(name) => ModelKind::parse(name)
            .ok_or_else(|| winoconv::Error::Config(format!("unknown model {name:?}")))?,
        None => ModelKind::Vgg16,
    };

    let mut layers: Vec<(LayerSpec, usize)> = unique_fast_layers(model, 1)?;
    layers.extend(unique_pointwise_layers(model, 1)?);
    layers.extend(unique_depthwise_layers(model, 1)?);
    if layers.is_empty() {
        println!("{model} has no conv layers this ablation covers; try --model vgg16");
        return Ok(());
    }
    let mut table = Table::new(
        &format!("{model}: batched sweep vs {nb}x batch-1 walks ({threads} thread(s))"),
        &["layer", "engine", "shape", "N", "seq ms", "batched ms", "speedup", "count"],
    );
    for (spec, count) in &layers {
        let (seq, bat, engine) = bench_batched_layer(spec, nb, &cfg, &pool, true)?;
        eprintln!(
            "  {:<24} {:<9} {:>3}x{:<3} {:>4}->{:<4} N={nb} {:>8} -> {:>8} ms  {:.2}x",
            spec.name,
            engine,
            spec.input_shape[1],
            spec.input_shape[2],
            spec.cin,
            spec.cout,
            ms(seq),
            ms(bat),
            seq / bat
        );
        table.row(&[
            spec.name.clone(),
            engine.to_string(),
            format!("{}x{}x{}", spec.input_shape[1], spec.input_shape[2], spec.cin),
            format!("{nb}"),
            ms(seq),
            ms(bat),
            format!("{:.2}x", seq / bat),
            format!("{count}"),
        ]);
    }
    table.print();
    println!(
        "expectation: every weight-panel-bound engine (winograd / im2row /\n\
         pointwise) wins — the batched sweep streams each packed B panel\n\
         through cache once for all N frames — while depthwise only saves\n\
         per-call overhead (no shared panel to amortise)."
    );
    Ok(())
}
