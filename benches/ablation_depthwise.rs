//! **Depthwise ablation**: the direct register-tiled depthwise engine
//! (`conv::depthwise`) vs the degenerate **im2row-as-grouped** baseline —
//! what running a depthwise layer through the paper's im2row machinery
//! actually costs (per channel: a strided plane extract, a 9-wide patch
//! matrix, and a `[R×9]·[9×1]` GEMM — exactly the memory-bound shape the
//! depthwise literature warns about).
//!
//! Workload: the unique 3×3 depthwise layers of MobileNetV1 (optionally
//! another model via `--model`), batch 1, both strides.
//!
//! `--smoke` runs two small layers with correctness asserts (engine ==
//! baseline numerically, arena grow-count 0) and **fails unless the direct
//! engine beats the im2row-as-grouped baseline** — the CI gate wired into
//! `ci.sh` that keeps the depthwise path measurably worth having.

use winoconv::bench::workloads::{unique_depthwise_layers, LayerSpec};
use winoconv::bench::{measure, ms, BenchConfig, Table};
use winoconv::conv::depthwise::DepthwiseConvolution;
use winoconv::im2row::Im2RowConvolution;
use winoconv::parallel::ThreadPool;
use winoconv::tensor::Tensor;
use winoconv::util::cli::Args;
use winoconv::workspace::Workspace;
use winoconv::zoo::ModelKind;

/// The im2row-as-grouped baseline: one single-channel `Im2RowConvolution`
/// per channel (weights pre-packed once, as the dense baseline gets), with
/// plane extract/scatter staged through reusable buffers. This is the
/// fairest expression of "just use the existing machinery" — it pays the
/// copies and degenerate GEMMs the direct engine exists to avoid.
struct GroupedIm2Row {
    convs: Vec<Im2RowConvolution>,
}

impl GroupedIm2Row {
    fn new(weights: &Tensor, stride: (usize, usize), pad: (usize, usize)) -> winoconv::Result<Self> {
        let c = weights.shape()[0];
        let mut convs = Vec::with_capacity(c);
        for ch in 0..c {
            let mut w1 = Tensor::zeros(&[1, 3, 3, 1]);
            for a in 0..3 {
                for b in 0..3 {
                    *w1.at4_mut(0, a, b, 0) = weights.at4(ch, a, b, 0);
                }
            }
            convs.push(Im2RowConvolution::new(&w1, stride, pad)?);
        }
        Ok(GroupedIm2Row { convs })
    }

    /// One inference: per channel, extract the plane, convolve, scatter.
    /// `plane_in`/`plane_out` are caller-owned reusable staging buffers
    /// (`[N, H, W, 1]` / `N·OH·OW` elements) so the measured loop pays the
    /// copies and degenerate GEMMs, not allocator traffic.
    fn run(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
        ws: &mut Workspace,
        plane_in: &mut Tensor,
        plane_out: &mut [f32],
        out: &mut [f32],
    ) -> winoconv::Result<()> {
        let c = input.shape()[3];
        let src = input.data();
        for (ch, conv) in self.convs.iter().enumerate() {
            for (p, v) in plane_in.data_mut().iter_mut().enumerate() {
                *v = src[p * c + ch];
            }
            conv.run_fused_into(
                &plane_in.view(),
                pool,
                None,
                winoconv::conv::Activation::None,
                ws,
                plane_out,
            )?;
            for (p, &v) in plane_out.iter().enumerate() {
                out[p * c + ch] = v;
            }
        }
        Ok(())
    }
}

fn bench_layer(
    spec: &LayerSpec,
    cfg: &BenchConfig,
    pool: &ThreadPool,
    check: bool,
) -> winoconv::Result<(f64, f64)> {
    let input = spec.input(41);
    let weights = spec.weights(42);
    let (n, h, w) = (spec.input_shape[0], spec.input_shape[1], spec.input_shape[2]);
    let dw = DepthwiseConvolution::new(&weights, spec.stride, spec.pad)?;
    let baseline = GroupedIm2Row::new(&weights, spec.stride, spec.pad)?;
    let (oh, ow) = dw.output_hw(h, w)?;
    let mut out_dw = vec![0.0f32; n * oh * ow * spec.cin];
    let mut out_base = vec![f32::NAN; out_dw.len()];
    let mut ws_dw = Workspace::with_capacity(dw.workspace_elems_for(n, h, w)?);
    let mut ws_base = Workspace::new();
    // Baseline staging allocated once, outside the measured loop.
    let mut plane_in = Tensor::zeros(&[n, h, w, 1]);
    let mut plane_out = vec![0.0f32; n * oh * ow];

    if check {
        dw.run_fused_into(
            &input.view(),
            Some(pool),
            None,
            winoconv::conv::Activation::None,
            &mut ws_dw,
            &mut out_dw,
        )?;
        baseline.run(&input, Some(pool), &mut ws_base, &mut plane_in, &mut plane_out, &mut out_base)?;
        let err = winoconv::util::rel_error(&out_dw, &out_base);
        assert!(err < 1e-4, "{}: depthwise != im2row-as-grouped, rel err {err}", spec.name);
        assert_eq!(
            ws_dw.grow_count(),
            0,
            "{}: pre-sized depthwise arena grew",
            spec.name
        );
    }

    let direct = measure(cfg, || {
        dw.run_fused_into(
            &input.view(),
            Some(pool),
            None,
            winoconv::conv::Activation::None,
            &mut ws_dw,
            &mut out_dw,
        )
        .unwrap();
    });
    let grouped = measure(cfg, || {
        baseline
            .run(&input, Some(pool), &mut ws_base, &mut plane_in, &mut plane_out, &mut out_base)
            .unwrap();
    });
    Ok((grouped.median, direct.median))
}

/// `--smoke`: the CI gate. Two MobileNetV1-shaped layers (one per stride),
/// shrunk spatially so the whole gate runs in seconds, with correctness
/// asserts and a hard direct-beats-baseline assert.
fn smoke(pool: &ThreadPool) -> winoconv::Result<()> {
    let cfg = BenchConfig::quick();
    for (c, hw, stride) in [(64usize, 28usize, (1, 1)), (128, 28, (2, 2))] {
        let spec = LayerSpec {
            model: ModelKind::MobileNetV1,
            name: format!("dw{c}s{}", stride.0),
            input_shape: vec![1, hw, hw, c],
            cin: c,
            cout: c,
            kernel: (3, 3),
            stride,
            pad: (1, 1),
            groups: c,
        };
        let (base, ours) = bench_layer(&spec, &cfg, pool, true)?;
        println!(
            "smoke {}: im2row-as-grouped {} ms -> depthwise {} ms ({:.1}x)",
            spec.name,
            ms(base),
            ms(ours),
            base / ours
        );
        assert!(
            ours < base,
            "smoke {}: direct depthwise ({} ms) must beat im2row-as-grouped ({} ms)",
            spec.name,
            ms(ours),
            ms(base)
        );
    }
    println!("smoke ok: direct depthwise beats im2row-as-grouped on both strides");
    Ok(())
}

fn main() -> winoconv::Result<()> {
    let args = Args::from_env(&["quick", "bench", "smoke"])?;
    let threads: usize = args.get_parse_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    )?;
    let pool = ThreadPool::new(threads);
    if args.flag("smoke") {
        return smoke(&pool);
    }
    let cfg = if args.flag("quick") { BenchConfig::quick() } else { BenchConfig::from_env() };

    let model = match args.get("model") {
        Some(name) => ModelKind::parse(name)
            .ok_or_else(|| winoconv::Error::Config(format!("unknown model {name:?}")))?,
        None => ModelKind::MobileNetV1,
    };

    let layers = unique_depthwise_layers(model, 1)?;
    if layers.is_empty() {
        println!("{model} has no depthwise layers; try --model mobilenet-v1");
        return Ok(());
    }
    let mut table = Table::new(
        &format!("{model}: direct depthwise vs im2row-as-grouped ({threads} thread(s))"),
        &["layer", "shape", "stride", "grouped ms", "depthwise ms", "speedup", "count"],
    );
    for (spec, count) in layers {
        let (base, ours) = bench_layer(&spec, &cfg, &pool, true)?;
        eprintln!(
            "  {:<12} {:>3}x{:<3} C={:<5} s{} {:>8} -> {:>8} ms  {:.1}x",
            spec.name,
            spec.input_shape[1],
            spec.input_shape[2],
            spec.cin,
            spec.stride.0,
            ms(base),
            ms(ours),
            base / ours
        );
        table.row(&[
            spec.name.clone(),
            format!("{}x{}x{}", spec.input_shape[1], spec.input_shape[2], spec.cin),
            format!("{}", spec.stride.0),
            ms(base),
            ms(ours),
            format!("{:.1}x", base / ours),
            format!("{count}"),
        ]);
    }
    table.print();
    println!(
        "expectation: the direct engine wins on every row (the baseline pays\n\
         per-channel plane copies + 9-wide patch matrices + degenerate GEMMs)."
    );
    Ok(())
}
