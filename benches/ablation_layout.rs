//! **Layout ablation** (DESIGN.md E6) — the paper's §2.1.2 design choice:
//! NHWC over NCHW for the SIMD transforms.
//!
//! Under NHWC a vector load yields four channels of one pixel, so the
//! transform kernels stream whole channel groups; under NCHW the same
//! transform must either work single-channel (wasting lanes whenever the
//! spatial tile isn't a lane multiple) or transpose on the fly. We measure
//! the end-to-end Winograd convolution with (a) native NHWC input vs
//! (b) NCHW input converted at the layer boundary — the realistic cost a
//! framework pays for the wrong layout — plus the raw conversion overhead.

use winoconv::bench::{measure, ms, BenchConfig, Table};
use winoconv::parallel::ThreadPool;
use winoconv::tensor::{nchw_to_nhwc, nhwc_to_nchw, Tensor};
use winoconv::util::cli::Args;
use winoconv::winograd::{WinogradConvolution, WinogradVariant};

fn main() -> winoconv::Result<()> {
    let args = Args::from_env(&["quick", "bench"])?;
    let threads: usize = args.get_parse_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    )?;
    let pool = ThreadPool::new(threads);
    let cfg = if args.flag("quick") { BenchConfig::quick() } else { BenchConfig::from_env() };

    let mut table = Table::new(
        "E6: NHWC vs NCHW-at-the-boundary, F(4x4,3x3) end-to-end",
        &["layer", "NHWC ms", "NCHW+convert ms", "convert-only ms", "penalty"],
    );
    for (h, c, m) in [(56usize, 64usize, 64usize), (28, 128, 128), (14, 256, 256)] {
        let input = Tensor::randn(&[1, h, h, c], 1);
        let input_nchw = nhwc_to_nchw(&input)?;
        let weights = Tensor::randn(&[m, 3, 3, c], 2);
        let wino = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1))?;

        let nhwc = measure(&cfg, || {
            let _ = wino.run(&input, Some(&pool)).unwrap();
        });
        let nchw = measure(&cfg, || {
            // A NCHW-resident framework must convert in and out.
            let x = nchw_to_nhwc(&input_nchw).unwrap();
            let y = wino.run(&x, Some(&pool)).unwrap();
            let _ = nhwc_to_nchw(&y).unwrap();
        });
        let conv_only = measure(&cfg, || {
            let x = nchw_to_nhwc(&input_nchw).unwrap();
            std::hint::black_box(&x);
        });
        table.row(&[
            format!("{h}x{h}x{c} -> {m}"),
            ms(nhwc.median),
            ms(nchw.median),
            ms(conv_only.median),
            format!("{:.1}%", (nchw.median / nhwc.median - 1.0) * 100.0),
        ]);
    }
    table.print();
    println!(
        "shape check (paper §2.1.2): NHWC wins — channel-innermost data feeds the\n\
         4-lane transforms directly; NCHW pays conversion on every layer boundary."
    );
    Ok(())
}
