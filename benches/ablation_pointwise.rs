//! **Pointwise ablation**: the zero-copy direct 1×1 engine
//! (`conv::pointwise`) vs the im2row baseline, plus the fused-residual
//! epilogue vs the unfused conv → add → act chain.
//!
//! Two claims are measured, matching the engine's two design points:
//!
//! 1. **Zero staging copy.** For a 1×1 stride-1 layer, im2row's patch
//!    matrix `[N·OH·OW, C]` is literally a copy of the input; the direct
//!    engine hands the NHWC activations to the GEMM in place. Same GEMM,
//!    minus one full pass over the input. (At stride 2 both paths gather
//!    the sampled rows, so the engines converge — reported, not gated.)
//! 2. **Fused residual.** `out = act(conv(x) + bias + r)` in one GEMM
//!    epilogue, reading `r` while the micro-tile is cache-hot, vs the
//!    unfused conv → `add_into` → `relu_into` walk that re-traverses the
//!    output twice. Bit-identical results by construction.
//!
//! Workload: the unique dense 1×1 layers of ResNet-50 (another model via
//! `--model`), batch 1.
//!
//! `--smoke` runs shrunk ResNet-50-shaped layers with correctness asserts
//! (pointwise == im2row **bit-for-bit**, fused == separate **bit-for-bit**,
//! arena grow-count 0) and **fails unless** the direct engine beats im2row
//! at stride 1 and the fused epilogue is no slower than the separate-add
//! chain — the CI gate wired into `ci.sh`.

use winoconv::bench::workloads::{unique_pointwise_layers, LayerSpec};
use winoconv::bench::{measure, ms, BenchConfig, Table};
use winoconv::conv::pointwise::PointwiseConvolution;
use winoconv::conv::Activation;
use winoconv::im2row::Im2RowConvolution;
use winoconv::nn::ops;
use winoconv::parallel::ThreadPool;
use winoconv::tensor::Tensor;
use winoconv::util::cli::Args;
use winoconv::workspace::Workspace;
use winoconv::zoo::ModelKind;

/// Direct-pointwise vs im2row on one layer. Returns `(im2row, ours)`
/// median seconds; with `check` set, asserts the outputs agree
/// bit-for-bit and that neither pre-sized arena grew.
fn bench_layer(
    spec: &LayerSpec,
    cfg: &BenchConfig,
    pool: &ThreadPool,
    check: bool,
) -> winoconv::Result<(f64, f64)> {
    let input = spec.input(41);
    let weights = spec.weights(42);
    let (n, h, w) = (spec.input_shape[0], spec.input_shape[1], spec.input_shape[2]);
    let pw = PointwiseConvolution::new(&weights, spec.stride, spec.pad)?;
    let baseline = Im2RowConvolution::new(&weights, spec.stride, spec.pad)?;
    let (oh, ow) = pw.output_hw(h, w)?;
    let mut out_pw = vec![0.0f32; n * oh * ow * spec.cout];
    let mut out_base = vec![f32::NAN; out_pw.len()];
    let mut ws_pw = Workspace::with_capacity(pw.workspace_elems_for(n, h, w)?);
    let mut ws_base = Workspace::with_capacity(baseline.workspace_elems_for(n, h, w)?);

    if check {
        pw.run_fused_into(&input.view(), Some(pool), None, Activation::None, &mut ws_pw, &mut out_pw)?;
        baseline.run_fused_into(
            &input.view(),
            Some(pool),
            None,
            Activation::None,
            &mut ws_base,
            &mut out_base,
        )?;
        assert_eq!(
            out_pw, out_base,
            "{}: pointwise and im2row must agree bit-for-bit",
            spec.name
        );
        assert_eq!(ws_pw.grow_count(), 0, "{}: pre-sized pointwise arena grew", spec.name);
    }

    let ours = measure(cfg, || {
        pw.run_fused_into(&input.view(), Some(pool), None, Activation::None, &mut ws_pw, &mut out_pw)
            .unwrap();
    });
    let base = measure(cfg, || {
        baseline
            .run_fused_into(
                &input.view(),
                Some(pool),
                None,
                Activation::None,
                &mut ws_base,
                &mut out_base,
            )
            .unwrap();
    });
    Ok((base.median, ours.median))
}

/// Fused-residual epilogue vs the unfused conv → add → relu walk on one
/// stride-1 layer. Returns `(separate, fused)` median seconds; with
/// `check` set, asserts bit-identity first.
fn bench_residual(
    spec: &LayerSpec,
    cfg: &BenchConfig,
    pool: &ThreadPool,
    check: bool,
) -> winoconv::Result<(f64, f64)> {
    let input = spec.input(43);
    let weights = spec.weights(44);
    let (n, h, w) = (spec.input_shape[0], spec.input_shape[1], spec.input_shape[2]);
    let pw = PointwiseConvolution::new(&weights, spec.stride, spec.pad)?;
    let (oh, ow) = pw.output_hw(h, w)?;
    let elems = n * oh * ow * spec.cout;
    let res = Tensor::randn(&[n, oh, ow, spec.cout], 45);
    let bias: Vec<f32> = Tensor::randn(&[spec.cout], 46).into_vec();
    let mut out_fused = vec![0.0f32; elems];
    let mut conv_tmp = vec![0.0f32; elems];
    let mut sum_tmp = vec![0.0f32; elems];
    let mut out_sep = vec![f32::NAN; elems];
    let mut ws = Workspace::with_capacity(pw.workspace_elems_for(n, h, w)?);

    // The unfused walk the prepared model would otherwise execute:
    // conv (bias, linear) → elementwise add → standalone ReLU, each a
    // full pass over the output.
    let mut separate = |ws: &mut Workspace, out: &mut [f32]| -> winoconv::Result<()> {
        pw.run_fused_into(
            &input.view(),
            Some(pool),
            Some(&bias),
            Activation::None,
            ws,
            &mut conv_tmp,
        )?;
        ops::add_into(&conv_tmp, res.data(), &mut sum_tmp)?;
        ops::relu_into(&sum_tmp, out)
    };

    if check {
        pw.run_residual_fused_into(
            &input.view(),
            Some(pool),
            Some(&bias),
            Activation::Relu,
            res.data(),
            &mut ws,
            &mut out_fused,
        )?;
        separate(&mut ws, &mut out_sep)?;
        assert_eq!(
            out_fused, out_sep,
            "{}: fused residual and separate-add must agree bit-for-bit",
            spec.name
        );
        assert_eq!(ws.grow_count(), 0, "{}: pre-sized arena grew", spec.name);
    }

    let fused = measure(cfg, || {
        pw.run_residual_fused_into(
            &input.view(),
            Some(pool),
            Some(&bias),
            Activation::Relu,
            res.data(),
            &mut ws,
            &mut out_fused,
        )
        .unwrap();
    });
    let sep = measure(cfg, || {
        separate(&mut ws, &mut out_sep).unwrap();
    });
    Ok((sep.median, fused.median))
}

fn resnet50_shaped(name: &str, hw: usize, cin: usize, cout: usize, stride: usize) -> LayerSpec {
    LayerSpec {
        model: ModelKind::ResNet50,
        name: name.to_string(),
        input_shape: vec![1, hw, hw, cin],
        cin,
        cout,
        kernel: (1, 1),
        stride: (stride, stride),
        pad: (0, 0),
        groups: 1,
    }
}

/// `--smoke`: the CI gate. ResNet-50-shaped 1×1 layers with correctness
/// asserts, a hard zero-copy-beats-im2row assert at stride 1, and a hard
/// fused-no-slower-than-separate assert for the residual epilogue.
fn smoke(pool: &ThreadPool) -> winoconv::Result<()> {
    let cfg = BenchConfig::quick();
    // Stride 1: the zero-copy claim. Reduce- and expand-shaped layers —
    // the patch copy im2row pays scales with C, so both directions gate.
    for spec in [
        resnet50_shaped("pw_reduce", 28, 256, 64, 1),
        resnet50_shaped("pw_expand", 28, 64, 256, 1),
    ] {
        let (base, ours) = bench_layer(&spec, &cfg, pool, true)?;
        println!(
            "smoke {}: im2row {} ms -> pointwise {} ms ({:.2}x)",
            spec.name,
            ms(base),
            ms(ours),
            base / ours
        );
        assert!(
            ours < base,
            "smoke {}: zero-copy pointwise ({} ms) must beat im2row ({} ms)",
            spec.name,
            ms(ours),
            ms(base)
        );
    }
    // Stride 2 (projection shape): both engines gather, outputs must still
    // match bit-for-bit; timing reported but not gated.
    let spec = resnet50_shaped("pw_proj_s2", 28, 256, 128, 2);
    let (base, ours) = bench_layer(&spec, &cfg, pool, true)?;
    println!(
        "smoke {}: im2row {} ms -> pointwise {} ms ({:.2}x, not gated)",
        spec.name,
        ms(base),
        ms(ours),
        base / ours
    );
    // The fused-residual claim, on a bottleneck-tail-shaped layer.
    let spec = resnet50_shaped("pw_residual", 28, 64, 256, 1);
    let (sep, fused) = bench_residual(&spec, &cfg, pool, true)?;
    println!(
        "smoke {}: separate-add {} ms -> fused {} ms ({:.2}x)",
        spec.name,
        ms(sep),
        ms(fused),
        sep / fused
    );
    assert!(
        fused <= sep,
        "smoke {}: fused residual ({} ms) must be no slower than separate add ({} ms)",
        spec.name,
        ms(fused),
        ms(sep)
    );
    println!("smoke ok: zero-copy beats im2row at stride 1; fused residual no slower than separate add");
    Ok(())
}

fn main() -> winoconv::Result<()> {
    let args = Args::from_env(&["quick", "bench", "smoke"])?;
    let threads: usize = args.get_parse_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    )?;
    let pool = ThreadPool::new(threads);
    if args.flag("smoke") {
        return smoke(&pool);
    }
    let cfg = if args.flag("quick") { BenchConfig::quick() } else { BenchConfig::from_env() };

    let model = match args.get("model") {
        Some(name) => ModelKind::parse(name)
            .ok_or_else(|| winoconv::Error::Config(format!("unknown model {name:?}")))?,
        None => ModelKind::ResNet50,
    };

    let layers = unique_pointwise_layers(model, 1)?;
    if layers.is_empty() {
        println!("{model} has no dense 1x1 layers; try --model resnet-50");
        return Ok(());
    }
    let mut table = Table::new(
        &format!("{model}: zero-copy pointwise vs im2row ({threads} thread(s))"),
        &["layer", "shape", "stride", "im2row ms", "pointwise ms", "speedup", "count"],
    );
    for (spec, count) in &layers {
        let (base, ours) = bench_layer(spec, &cfg, &pool, true)?;
        eprintln!(
            "  {:<24} {:>3}x{:<3} {:>4}->{:<4} s{} {:>8} -> {:>8} ms  {:.2}x",
            spec.name,
            spec.input_shape[1],
            spec.input_shape[2],
            spec.cin,
            spec.cout,
            spec.stride.0,
            ms(base),
            ms(ours),
            base / ours
        );
        table.row(&[
            spec.name.clone(),
            format!("{}x{}x{}", spec.input_shape[1], spec.input_shape[2], spec.cin),
            format!("{}", spec.stride.0),
            ms(base),
            ms(ours),
            format!("{:.2}x", base / ours),
            format!("{count}"),
        ]);
    }
    table.print();

    let mut rtable = Table::new(
        &format!("{model}: fused residual epilogue vs conv + add + relu"),
        &["layer", "shape", "separate ms", "fused ms", "speedup"],
    );
    for (spec, _) in layers.iter().filter(|(s, _)| s.stride == (1, 1)) {
        let (sep, fused) = bench_residual(spec, &cfg, &pool, true)?;
        rtable.row(&[
            spec.name.clone(),
            format!("{}x{}x{}", spec.input_shape[1], spec.input_shape[2], spec.cout),
            ms(sep),
            ms(fused),
            format!("{:.2}x", sep / fused),
        ]);
    }
    rtable.print();
    println!(
        "expectation: the zero-copy engine wins every stride-1 row (im2row's\n\
         patch matrix is a full input copy there) and converges with im2row\n\
         at stride 2 (both gather); the fused epilogue wins by skipping two\n\
         extra passes over the output."
    );
    Ok(())
}
