//! **Quantization ablation**: the int8 engines (`quant`) vs their f32
//! twins on identical layer shapes.
//!
//! The claim (paper §2.3's arithmetic-intensity argument, applied to
//! dtype): a u8×i8→i32 GEMM moves a quarter of the bytes of the f32 GEMM
//! — the patch matrix, the packed weight panel and the staging buffer are
//! all one byte per element — so on the memory-bound mobile shapes the
//! quantized im2row path beats the f32 one *even paying* the per-layer
//! dynamic activation-quantize pass. Dequantization happens once per
//! output element in the GEMM epilogue while the accumulator tile is
//! cache-hot; f32 activations flow between layers, so accuracy drift
//! stays layer-local.
//!
//! `--smoke` (the CI gate wired into `ci.sh`) runs two MobileNet/ResNet
//! interior dense 3×3 shapes with correctness asserts (int8 tracks the
//! f32 oracle within the subsystem's rel-error budget, pre-sized arenas
//! never grow) and **fails unless** the int8 im2row GEMM is strictly
//! faster than the f32 im2row GEMM on the same shape. The depthwise and
//! pointwise int8 engines are reported (correctness-checked, not
//! perf-gated: their f32 twins are already direct, copy-free kernels, so
//! the byte-traffic argument is weaker there).

use winoconv::bench::{measure, ms, BenchConfig, Table};
use winoconv::conv::depthwise::DepthwiseConvolution;
use winoconv::conv::pointwise::PointwiseConvolution;
use winoconv::conv::Activation;
use winoconv::im2row::Im2RowConvolution;
use winoconv::parallel::ThreadPool;
use winoconv::quant::{
    QuantDepthwiseConvolution, QuantIm2RowConvolution, QuantPointwiseConvolution,
};
use winoconv::tensor::Tensor;
use winoconv::util::cli::Args;
use winoconv::workspace::Workspace;

/// Max |int8 − f32| over the layer output, relative to the f32 peak —
/// the per-layer drift the quantization scheme promises (per-tensor u8
/// activations × per-channel i8 weights keeps this well under 5%).
const REL_TOL: f32 = 0.05;

struct DenseSpec {
    name: &'static str,
    hw: usize,
    cin: usize,
    cout: usize,
}

fn rel_drift(q: &[f32], f: &[f32]) -> f32 {
    let peak = f.iter().fold(0f32, |a, &v| a.max(v.abs())).max(1e-12);
    q.iter().zip(f).fold(0f32, |a, (&x, &y)| a.max((x - y).abs())) / peak
}

/// Int8 im2row GEMM vs f32 im2row GEMM on one dense 3×3 pad-1 layer.
/// Returns `(f32, int8)` median seconds; with `check` set, asserts the
/// int8 output tracks the f32 oracle and that neither pre-sized arena
/// grew.
fn bench_dense(
    spec: &DenseSpec,
    cfg: &BenchConfig,
    pool: &ThreadPool,
    check: bool,
) -> winoconv::Result<(f64, f64)> {
    let (n, h, w) = (1usize, spec.hw, spec.hw);
    let input = Tensor::randn(&[n, h, w, spec.cin], 51);
    let weights = Tensor::randn(&[spec.cout, 3, 3, spec.cin], 52);
    let bias: Vec<f32> = Tensor::randn(&[spec.cout], 53).into_vec();
    let qc = QuantIm2RowConvolution::new(&weights, (1, 1), (1, 1))?;
    let base = Im2RowConvolution::new(&weights, (1, 1), (1, 1))?;
    let mut out_q = vec![0.0f32; n * h * w * spec.cout];
    let mut out_f = vec![f32::NAN; out_q.len()];
    let mut ws_q = Workspace::with_capacity(qc.workspace_elems_for(n, h, w)?);
    let mut ws_f = Workspace::with_capacity(base.workspace_elems_for(n, h, w)?);

    if check {
        qc.run_fused_i8_into(
            &input.view(),
            Some(pool),
            Some(&bias),
            Activation::Relu,
            &mut ws_q,
            &mut out_q,
        )?;
        base.run_fused_into(
            &input.view(),
            Some(pool),
            Some(&bias),
            Activation::Relu,
            &mut ws_f,
            &mut out_f,
        )?;
        let drift = rel_drift(&out_q, &out_f);
        assert!(
            drift < REL_TOL,
            "{}: int8 drift {drift} exceeds rel tolerance {REL_TOL}",
            spec.name
        );
        assert_eq!(ws_q.grow_count(), 0, "{}: pre-sized int8 arena grew", spec.name);
        assert_eq!(ws_f.grow_count(), 0, "{}: pre-sized f32 arena grew", spec.name);
    }

    let int8 = measure(cfg, || {
        qc.run_fused_i8_into(
            &input.view(),
            Some(pool),
            Some(&bias),
            Activation::Relu,
            &mut ws_q,
            &mut out_q,
        )
        .unwrap();
    });
    let f32t = measure(cfg, || {
        base.run_fused_into(
            &input.view(),
            Some(pool),
            Some(&bias),
            Activation::Relu,
            &mut ws_f,
            &mut out_f,
        )
        .unwrap();
    });
    Ok((f32t.median, int8.median))
}

/// Int8 vs f32 direct depthwise 3×3 on one `C`-channel layer. Reported,
/// not perf-gated; correctness + grow pins still assert with `check`.
fn bench_depthwise(
    hw: usize,
    c: usize,
    cfg: &BenchConfig,
    pool: &ThreadPool,
    check: bool,
) -> winoconv::Result<(f64, f64)> {
    let input = Tensor::randn(&[1, hw, hw, c], 61);
    let weights = Tensor::randn(&[c, 3, 3, 1], 62);
    let bias: Vec<f32> = Tensor::randn(&[c], 63).into_vec();
    let qc = QuantDepthwiseConvolution::new(&weights, (1, 1), (1, 1))?;
    let base = DepthwiseConvolution::new(&weights, (1, 1), (1, 1))?;
    let mut out_q = vec![0.0f32; hw * hw * c];
    let mut out_f = vec![f32::NAN; out_q.len()];
    let mut ws_q = Workspace::with_capacity(qc.workspace_elems_for(1, hw, hw)?);
    let mut ws_f = Workspace::with_capacity(base.workspace_elems_for(1, hw, hw)?);

    if check {
        qc.run_fused_i8_into(
            &input.view(),
            Some(pool),
            Some(&bias),
            Activation::Relu6,
            &mut ws_q,
            &mut out_q,
        )?;
        base.run_fused_into(
            &input.view(),
            Some(pool),
            Some(&bias),
            Activation::Relu6,
            &mut ws_f,
            &mut out_f,
        )?;
        let drift = rel_drift(&out_q, &out_f);
        assert!(drift < REL_TOL, "depthwise c{c}: int8 drift {drift} exceeds {REL_TOL}");
        assert_eq!(ws_q.grow_count(), 0, "depthwise c{c}: pre-sized int8 arena grew");
    }

    let int8 = measure(cfg, || {
        qc.run_fused_i8_into(
            &input.view(),
            Some(pool),
            Some(&bias),
            Activation::Relu6,
            &mut ws_q,
            &mut out_q,
        )
        .unwrap();
    });
    let f32t = measure(cfg, || {
        base.run_fused_into(
            &input.view(),
            Some(pool),
            Some(&bias),
            Activation::Relu6,
            &mut ws_f,
            &mut out_f,
        )
        .unwrap();
    });
    Ok((f32t.median, int8.median))
}

/// Int8 vs f32 direct pointwise (1×1) on one layer. Reported, not
/// perf-gated (the f32 engine is zero-copy; int8 pays a quantize pass).
fn bench_pointwise(
    hw: usize,
    cin: usize,
    cout: usize,
    cfg: &BenchConfig,
    pool: &ThreadPool,
    check: bool,
) -> winoconv::Result<(f64, f64)> {
    let input = Tensor::randn(&[1, hw, hw, cin], 71);
    let weights = Tensor::randn(&[cout, 1, 1, cin], 72);
    let bias: Vec<f32> = Tensor::randn(&[cout], 73).into_vec();
    let qc = QuantPointwiseConvolution::new(&weights, (1, 1), (0, 0))?;
    let base = PointwiseConvolution::new(&weights, (1, 1), (0, 0))?;
    let mut out_q = vec![0.0f32; hw * hw * cout];
    let mut out_f = vec![f32::NAN; out_q.len()];
    let mut ws_q = Workspace::with_capacity(qc.workspace_elems_for(1, hw, hw)?);
    let mut ws_f = Workspace::with_capacity(base.workspace_elems_for(1, hw, hw)?);

    if check {
        qc.run_fused_i8_into(
            &input.view(),
            Some(pool),
            Some(&bias),
            Activation::Relu,
            &mut ws_q,
            &mut out_q,
        )?;
        base.run_fused_into(
            &input.view(),
            Some(pool),
            Some(&bias),
            Activation::Relu,
            &mut ws_f,
            &mut out_f,
        )?;
        let drift = rel_drift(&out_q, &out_f);
        assert!(
            drift < REL_TOL,
            "pointwise {cin}->{cout}: int8 drift {drift} exceeds {REL_TOL}"
        );
        assert_eq!(ws_q.grow_count(), 0, "pointwise {cin}->{cout}: pre-sized int8 arena grew");
    }

    let int8 = measure(cfg, || {
        qc.run_fused_i8_into(
            &input.view(),
            Some(pool),
            Some(&bias),
            Activation::Relu,
            &mut ws_q,
            &mut out_q,
        )
        .unwrap();
    });
    let f32t = measure(cfg, || {
        base.run_fused_into(
            &input.view(),
            Some(pool),
            Some(&bias),
            Activation::Relu,
            &mut ws_f,
            &mut out_f,
        )
        .unwrap();
    });
    Ok((f32t.median, int8.median))
}

/// The two gated dense shapes: interior MobileNet/ResNet-scale 3×3 pad-1
/// layers (GEMM K = 576 and 1152) where the byte-traffic argument bites.
const DENSE: [DenseSpec; 2] = [
    DenseSpec { name: "conv3x3_56x56_64", hw: 56, cin: 64, cout: 64 },
    DenseSpec { name: "conv3x3_28x28_128", hw: 28, cin: 128, cout: 128 },
];

/// `--smoke`: the CI gate. Dense int8 im2row GEMM must strictly beat the
/// f32 GEMM on both shapes; depthwise/pointwise correctness-checked and
/// reported.
fn smoke(pool: &ThreadPool) -> winoconv::Result<()> {
    let cfg = BenchConfig::quick();
    for spec in &DENSE {
        let (f32t, int8) = bench_dense(spec, &cfg, pool, true)?;
        println!(
            "smoke {}: f32 {} ms -> int8 {} ms ({:.2}x)",
            spec.name,
            ms(f32t),
            ms(int8),
            f32t / int8
        );
        assert!(
            int8 < f32t,
            "smoke {}: int8 im2row GEMM ({} ms) must beat the f32 GEMM ({} ms)",
            spec.name,
            ms(int8),
            ms(f32t)
        );
    }
    let (f32t, int8) = bench_depthwise(56, 128, &cfg, pool, true)?;
    println!(
        "smoke dw3x3_56x56_128: f32 {} ms -> int8 {} ms ({:.2}x, not gated)",
        ms(f32t),
        ms(int8),
        f32t / int8
    );
    let (f32t, int8) = bench_pointwise(28, 128, 256, &cfg, pool, true)?;
    println!(
        "smoke pw_28x28_128->256: f32 {} ms -> int8 {} ms ({:.2}x, not gated)",
        ms(f32t),
        ms(int8),
        f32t / int8
    );
    println!("smoke ok: int8 im2row GEMM beats f32 on both dense shapes; drift within {REL_TOL}");
    Ok(())
}

fn main() -> winoconv::Result<()> {
    let args = Args::from_env(&["quick", "bench", "smoke"])?;
    let threads: usize = args.get_parse_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    )?;
    let pool = ThreadPool::new(threads);
    if args.flag("smoke") {
        return smoke(&pool);
    }
    let cfg = if args.flag("quick") { BenchConfig::quick() } else { BenchConfig::from_env() };

    let mut table = Table::new(
        &format!("int8 engines vs f32 twins, batch 1 ({threads} thread(s))"),
        &["layer", "shape", "f32 ms", "int8 ms", "speedup"],
    );
    for spec in &DENSE {
        let (f32t, int8) = bench_dense(spec, &cfg, &pool, true)?;
        table.row(&[
            spec.name.to_string(),
            format!("{}x{}x{}->{}", spec.hw, spec.hw, spec.cin, spec.cout),
            ms(f32t),
            ms(int8),
            format!("{:.2}x", f32t / int8),
        ]);
    }
    for (hw, c) in [(112usize, 64usize), (56, 128), (28, 256)] {
        let (f32t, int8) = bench_depthwise(hw, c, &cfg, &pool, true)?;
        table.row(&[
            format!("dw3x3_{hw}x{hw}_{c}"),
            format!("{hw}x{hw}x{c}"),
            ms(f32t),
            ms(int8),
            format!("{:.2}x", f32t / int8),
        ]);
    }
    for (hw, cin, cout) in [(56usize, 64usize, 128usize), (28, 128, 256), (14, 256, 512)] {
        let (f32t, int8) = bench_pointwise(hw, cin, cout, &cfg, &pool, true)?;
        table.row(&[
            format!("pw_{hw}x{hw}_{cin}to{cout}"),
            format!("{hw}x{hw}x{cin}"),
            ms(f32t),
            ms(int8),
            format!("{:.2}x", f32t / int8),
        ]);
    }
    table.print();
    println!(
        "expectation: int8 wins the dense im2row rows (quarter the byte\n\
         traffic through the patch matrix and weight panel); the direct\n\
         depthwise/pointwise engines converge — their f32 twins are already\n\
         copy-free, so int8 only trades a quantize pass for narrower loads."
    );
    Ok(())
}
