//! **Tracing-overhead ablation**: whole-network planned walks with the
//! span sink enabled vs disabled, interleaved sample-for-sample so thermal
//! and scheduler drift hits both sides equally.
//!
//! The claim under test is that observability is free enough to leave on:
//! the trace ring is pre-reserved (`trace::reserve`), recording a span is
//! five relaxed atomic stores behind one `fetch_add`, and a traced walk
//! stays **bit-for-bit identical** and **allocation-free** (grow = 0,
//! fallback = 0) — so enabling per-layer + per-stage tracing on a full
//! SqueezeNet walk must cost at most 3% (the CI gate in `ci.sh`).
//!
//! `--smoke` additionally pins the exact span census: this process runs
//! nothing else, so `trace::len()` after W traced walks must equal
//! `W × trace_spans_per_walk()` with zero drops, and the conv layer spans
//! must match the model's dispatch census walk-for-walk.
//!
//! Full mode (`--model <name>`) prints the traced/untraced medians and the
//! span census for one model without gating.

use std::time::Instant;
use winoconv::bench::ms;
use winoconv::nn::{PreparedModel, Scheme};
use winoconv::parallel::ThreadPool;
use winoconv::tensor::Tensor;
use winoconv::trace::{self, AlgoCode, SpanKind};
use winoconv::util::cli::Args;
use winoconv::util::stats::percentile_sorted;
use winoconv::workspace::Workspace;
use winoconv::zoo::ModelKind;

/// Maximum traced/untraced median ratio the smoke gate accepts.
const MAX_OVERHEAD: f64 = 1.03;
/// Interleaved (untraced, traced) sample pairs per gate attempt.
const GATE_REPS: usize = 30;
/// Independent gate attempts before the smoke run fails: the ring cost is
/// deterministic but a 3% bar on a millisecond-scale walk is within OS
/// noise, so one noisy attempt gets retried rather than failing CI.
const GATE_ATTEMPTS: usize = 3;

struct Harness {
    prepared: PreparedModel,
    pool: ThreadPool,
    input: Tensor,
    ws: Workspace,
    acts: Workspace,
    out: Vec<f32>,
}

impl Harness {
    fn new(model: ModelKind, threads: usize) -> winoconv::Result<Harness> {
        let graph = model.build(1)?;
        let shape = model.input_shape(1);
        let prepared =
            PreparedModel::prepare(model.name(), &graph, &shape, Scheme::WinogradWhereSuitable)?;
        let out = vec![f32::NAN; prepared.output_shape().iter().product()];
        Ok(Harness {
            ws: Workspace::with_capacity(prepared.workspace_elems()),
            acts: Workspace::with_capacity(prepared.activation_plan().peak_elems()),
            input: Tensor::randn(&shape, 5),
            pool: ThreadPool::new(threads),
            prepared,
            out,
        })
    }

    fn walk(&mut self) -> winoconv::Result<()> {
        self.prepared.run_planned_into(
            &self.input,
            Some(&self.pool),
            &mut self.ws,
            &mut self.acts,
            &mut self.out,
        )
    }

    /// One interleaved overhead measurement: `reps` (untraced, traced)
    /// walk pairs, median nanoseconds each. Tracing state is restored to
    /// disabled; the caller owns ring sizing.
    fn overhead(&mut self, reps: usize) -> winoconv::Result<(f64, f64)> {
        let mut plain = Vec::with_capacity(reps);
        let mut traced = Vec::with_capacity(reps);
        for _ in 0..reps {
            trace::set_enabled(false);
            let t0 = Instant::now();
            self.walk()?;
            plain.push(t0.elapsed().as_nanos() as f64);
            trace::set_enabled(true);
            let t0 = Instant::now();
            self.walk()?;
            traced.push(t0.elapsed().as_nanos() as f64);
        }
        trace::set_enabled(false);
        plain.sort_by(|a, b| a.partial_cmp(b).unwrap());
        traced.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Ok((percentile_sorted(&plain, 50.0), percentile_sorted(&traced, 50.0)))
    }
}

/// `--smoke`: the CI gate — exact census, bitwise identity, zero
/// allocation and the ≤3% overhead bar on SqueezeNet.
fn smoke(threads: usize) -> winoconv::Result<()> {
    let model = ModelKind::SqueezeNet;
    let mut h = Harness::new(model, threads)?;
    let per_walk = h.prepared.trace_spans_per_walk();
    assert!(per_walk > 0, "traced model records no spans");

    // 1) Bitwise identity: a traced walk lands the same bits as an
    //    untraced one on the same arenas.
    trace::reserve(per_walk + 8);
    h.walk()?; // warm-up, untraced
    let want = h.out.clone();
    h.out.fill(f32::NAN);
    trace::set_enabled(true);
    h.walk()?;
    trace::set_enabled(false);
    assert_eq!(h.out, want, "traced walk must be bit-identical to untraced");

    // 2) Exact span census over W traced walks: this bench is the only
    //    thing running in this process, so the pinned counts are exact —
    //    the in-crate integration tests can only assert lower bounds.
    let walks = 4usize;
    trace::reserve(walks * per_walk + 8);
    trace::set_enabled(true);
    for _ in 0..walks {
        h.walk()?;
    }
    trace::set_enabled(false);
    assert_eq!(trace::dropped(), 0, "sized-to-fit ring must not drop spans");
    assert_eq!(
        trace::len(),
        walks * per_walk,
        "span census must equal walks x trace_spans_per_walk()"
    );
    assert_eq!(h.ws.grow_count(), 0, "tracing must not grow the conv scratch arena");
    assert_eq!(h.acts.grow_count(), 0, "tracing must not grow the activation arena");
    assert_eq!(h.prepared.fallback_count(), 0, "tracing must not force arena fallbacks");
    let spans = trace::take();
    let census = h.prepared.dispatch_census();
    let conv_layer_spans = spans
        .iter()
        .filter(|s| s.kind == SpanKind::Layer && s.algo != AlgoCode::None)
        .count();
    assert_eq!(
        conv_layer_spans as u64,
        census.total() * walks as u64,
        "conv layer spans must match the dispatch census walk-for-walk"
    );
    println!(
        "smoke census: {} spans / {walks} walks ({per_walk} per walk, {} conv layers), \
         grow 0, fallback 0, bitwise identical",
        spans.len(),
        census.total(),
    );

    // 3) Overhead gate: enabled tracing costs <= 3% on the whole-network
    //    walk, interleaved medians, best of GATE_ATTEMPTS.
    trace::reserve(GATE_REPS * per_walk + 8);
    let mut best = f64::INFINITY;
    for attempt in 1..=GATE_ATTEMPTS {
        trace::reset();
        let (plain, traced) = h.overhead(GATE_REPS)?;
        let ratio = traced / plain;
        best = best.min(ratio);
        println!(
            "smoke overhead attempt {attempt}: untraced {} ms -> traced {} ms ({:.4}x)",
            ms(plain),
            ms(traced),
            ratio
        );
        if best <= MAX_OVERHEAD {
            break;
        }
    }
    assert!(
        best <= MAX_OVERHEAD,
        "traced walk must cost at most {MAX_OVERHEAD}x the untraced walk, got {best:.4}x"
    );
    println!(
        "smoke ok: tracing ON costs {:.2}% on a {model} walk (gate {:.0}%), census exact, \
         outputs bitwise identical, zero allocation",
        (best - 1.0) * 100.0,
        (MAX_OVERHEAD - 1.0) * 100.0,
    );
    Ok(())
}

fn main() -> winoconv::Result<()> {
    let args = Args::from_env(&["quick", "bench", "smoke"])?;
    let threads: usize = args.get_parse_or("threads", 4)?;
    if args.flag("smoke") {
        return smoke(threads);
    }
    let model = match args.get("model") {
        Some(name) => ModelKind::parse(name)
            .ok_or_else(|| winoconv::Error::Config(format!("unknown model {name:?}")))?,
        None => ModelKind::SqueezeNet,
    };
    let reps: usize = args.get_parse_or("reps", if args.flag("quick") { 10 } else { GATE_REPS })?;
    let mut h = Harness::new(model, threads)?;
    let per_walk = h.prepared.trace_spans_per_walk();
    h.walk()?; // warm-up
    trace::reserve(reps * per_walk + 8);
    let (plain, traced) = h.overhead(reps)?;
    println!(
        "{model}: untraced {} ms -> traced {} ms ({:.4}x, {per_walk} spans/walk, \
         median of {reps} interleaved pairs, {threads} threads)",
        ms(plain),
        ms(traced),
        traced / plain,
    );
    let _ = trace::take();
    Ok(())
}
