//! **Variant ablation** (DESIGN.md E7): all applicable Winograd variants on
//! representative layers, against the im2row baseline — the data behind the
//! per-shape variant choice in `conv::select` (F(4×4) vs F(2×2) on 3×3
//! layers, tile-size effects on small feature maps, the extension variants
//! F(6×6,3×3)/F(4×4,5×5) the paper leaves as future work).

use winoconv::bench::{measure, ms, BenchConfig, Table};
use winoconv::im2row::Im2RowConvolution;
use winoconv::parallel::ThreadPool;
use winoconv::tensor::Tensor;
use winoconv::util::cli::Args;
use winoconv::winograd::{WinogradConvolution, WinogradVariant};

fn main() -> winoconv::Result<()> {
    let args = Args::from_env(&["quick", "bench"])?;
    let threads: usize = args.get_parse_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    )?;
    let pool = ThreadPool::new(threads);
    let cfg = if args.flag("quick") { BenchConfig::quick() } else { BenchConfig::from_env() };

    // (label, h, w, c, m, kernel, pad, candidate variants)
    let cases: Vec<(&str, usize, usize, usize, usize, (usize, usize), (usize, usize), Vec<WinogradVariant>)> = vec![
        (
            "VGG mid: 56x56x128 3x3",
            56, 56, 128, 128, (3, 3), (1, 1),
            vec![WinogradVariant::F2x2_3x3, WinogradVariant::F4x4_3x3, WinogradVariant::F6x6_3x3],
        ),
        (
            "small map: 7x7x512 3x3",
            7, 7, 512, 512, (3, 3), (1, 1),
            vec![WinogradVariant::F2x2_3x3, WinogradVariant::F4x4_3x3],
        ),
        (
            "GoogleNet: 14x14x32 5x5 -> 64",
            14, 14, 32, 64, (5, 5), (2, 2),
            vec![WinogradVariant::F2x2_5x5, WinogradVariant::F4x4_5x5],
        ),
        (
            "Inception-B: 17x17x128 1x7",
            17, 17, 128, 128, (1, 7), (0, 3),
            vec![WinogradVariant::F2_1x7, WinogradVariant::F4_1x7],
        ),
        (
            "Inception-B: 17x17x128 7x1",
            17, 17, 128, 128, (7, 1), (3, 0),
            vec![WinogradVariant::F2_7x1, WinogradVariant::F4_7x1],
        ),
    ];

    for (label, h, w, c, m, kernel, pad, variants) in cases {
        let input = Tensor::randn(&[1, h, w, c], 1);
        let weights = Tensor::randn(&[m, kernel.0, kernel.1, c], 2);
        let im2row = Im2RowConvolution::new(&weights, (1, 1), pad)?;
        let base = measure(&cfg, || {
            let _ = im2row.run(&input, Some(&pool)).unwrap();
        });
        let mut table = Table::new(
            &format!("E7: {label} ({threads} thread(s))"),
            &["algorithm", "ms", "speedup vs im2row", "theoretical"],
        );
        table.row(&[
            "im2row".into(),
            ms(base.median),
            "1.00x".into(),
            "1.00x".into(),
        ]);
        for v in variants {
            let wino = WinogradConvolution::new(v, &weights, pad)?;
            let ours = measure(&cfg, || {
                let _ = wino.run(&input, Some(&pool)).unwrap();
            });
            table.row(&[
                v.name().into(),
                ms(ours.median),
                format!("{:.2}x", base.median / ours.median),
                format!("{:.2}x", v.theoretical_speedup()),
            ]);
        }
        table.print();
    }
    println!(
        "shape check: bigger tiles win on large maps (more saving per GEMM);\n\
         on small maps partial tiles erode F(4x4)/F(6x6) and F(2x2) closes in —\n\
         the selector's spatial heuristic encodes exactly this."
    );
    Ok(())
}
