//! **Figure 3 reproduction** (DESIGN.md E3): normalized whole-network
//! runtime for all five CNNs, both schemes, with the Winograd-suitable
//! ("fast") fraction split out — rendered as a table plus an ASCII bar
//! chart, batch size 1 as in the paper.
//!
//! Every bar is normalized to that model's im2row total (= 1.00), so the
//! figure shows (a) how much of each network is accelerable and (b) how far
//! the fast fraction shrinks under the region-wise scheme.

use winoconv::bench::Table;
use winoconv::nn::{PreparedModel, Scheme};
use winoconv::parallel::ThreadPool;
use winoconv::tensor::Tensor;
use winoconv::util::cli::Args;
use winoconv::zoo::ModelKind;

fn main() -> winoconv::Result<()> {
    let args = Args::from_env(&["quick", "bench"])?;
    let threads: usize = args.get_parse_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    )?;
    let quick = args.flag("quick")
        || std::env::var("WINOCONV_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let reps: usize = args.get_parse_or("reps", if quick { 1 } else { 3 })?;
    let pool = ThreadPool::new(threads);

    let models: Vec<ModelKind> = match args.get("model") {
        Some(name) => vec![ModelKind::parse(name)
            .ok_or_else(|| winoconv::Error::Config(format!("unknown model {name:?}")))?],
        // Figure 3 reproduces the paper's five networks; the MobileNets
        // (no Winograd-suitable layers) are opt-in via --model.
        None => vec![
            ModelKind::Vgg16,
            ModelKind::Vgg19,
            ModelKind::GoogleNet,
            ModelKind::InceptionV3,
            ModelKind::SqueezeNet,
        ],
    };

    let mut table = Table::new(
        &format!("Figure 3: normalized runtime (im2row total = 1.00), batch 1, {threads} thread(s)"),
        &["Model", "scheme", "fast fraction", "other fraction", "total"],
    );
    let mut bars: Vec<(String, f64, f64, f64, f64)> = Vec::new();

    for model in models {
        eprintln!("benching {model} ...");
        let graph = model.build(1)?;
        let shape = model.input_shape(1);
        let input = Tensor::randn(&shape, 7);
        let mut full = [0.0f64; 2];
        let mut fast = [0.0f64; 2];
        for (i, scheme) in [Scheme::Im2RowOnly, Scheme::WinogradWhereSuitable]
            .into_iter()
            .enumerate()
        {
            let prepared = PreparedModel::prepare(model.name(), &graph, &shape, scheme)?;
            let _ = prepared.run(&input, Some(&pool))?;
            for _ in 0..reps {
                let t0 = std::time::Instant::now();
                let (_, timings) = prepared.run(&input, Some(&pool))?;
                full[i] += t0.elapsed().as_nanos() as f64;
                fast[i] += timings
                    .iter()
                    .filter(|t| t.fast_layer)
                    .map(|t| t.ns as f64)
                    .sum::<f64>();
            }
            full[i] /= reps as f64;
            fast[i] /= reps as f64;
        }
        let norm = full[0];
        for (i, scheme) in ["im2row", "ours"].into_iter().enumerate() {
            table.row(&[
                model.display().to_string(),
                scheme.into(),
                format!("{:.3}", fast[i] / norm),
                format!("{:.3}", (full[i] - fast[i]) / norm),
                format!("{:.3}", full[i] / norm),
            ]);
        }
        bars.push((
            model.display().to_string(),
            fast[0] / norm,
            (full[0] - fast[0]) / norm,
            fast[1] / norm,
            (full[1] - fast[1]) / norm,
        ));
    }
    table.print();

    // ASCII rendition of the paper's stacked-bar figure.
    println!("\nFigure 3 (ASCII): '#' = fast-layer time, '.' = other, 50 cols = im2row total\n");
    for (name, bf, bo, of_, oo) in bars {
        let render = |fast: f64, other: f64| {
            let f = (fast * 50.0).round() as usize;
            let o = (other * 50.0).round() as usize;
            format!("{}{}", "#".repeat(f), ".".repeat(o))
        };
        println!("{name:>13} im2row |{}", render(bf, bo));
        println!("{:>13} ours   |{}", "", render(of_, oo));
    }
    println!("\nshape check: the '#' segment shrinks 2-3x under ours; '.' stays put.");
    Ok(())
}
