//! GEMM substrate micro-benchmark (perf-pass instrumentation, DESIGN.md
//! §Perf): GFLOP/s of the packed blocked GEMM across the shapes the two
//! convolution schemes actually produce, plus the batched Winograd shape and
//! the three pipeline stages of one representative layer — the data that
//! drives the bottleneck ranking in EXPERIMENTS.md §Perf.

use winoconv::bench::{measure, BenchConfig, Table};
use winoconv::gemm::{sgemm_simple, BatchedGemm};
use winoconv::im2row::Im2RowConvolution;
use winoconv::parallel::ThreadPool;
use winoconv::tensor::Tensor;
use winoconv::util::cli::Args;
use winoconv::util::stats::ns_to_ms;
use winoconv::winograd::{WinogradConvolution, WinogradVariant};

fn main() -> winoconv::Result<()> {
    let args = Args::from_env(&["quick", "bench"])?;
    let cfg = if args.flag("quick") { BenchConfig::quick() } else { BenchConfig::from_env() };
    let threads: usize = args.get_parse_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    )?;
    let pool = ThreadPool::new(threads);

    // ---- square + conv-shaped GEMMs ----
    let mut table = Table::new(
        "GEMM GFLOP/s (single call, serial)",
        &["shape m x n x k", "median ms", "GFLOP/s"],
    );
    for (m, n, k) in [
        (256usize, 256usize, 256usize),
        (512, 512, 512),
        (784, 128, 1152),  // im2row VGG-ish: R x M x (9C)
        (3136, 64, 576),   // im2row early layer
        (196, 512, 4608),  // im2row late layer
    ] {
        let a = Tensor::randn(&[m, k], 1).into_vec();
        let b = Tensor::randn(&[k, n], 2).into_vec();
        let mut c = vec![0.0f32; m * n];
        let s = measure(&cfg, || {
            sgemm_simple(m, n, k, &a, &b, &mut c);
        });
        let gflops = (2.0 * m as f64 * n as f64 * k as f64) / s.median;
        table.row(&[
            format!("{m} x {n} x {k}"),
            format!("{:.3}", ns_to_ms(s.median)),
            format!("{gflops:.2}"),
        ]);
    }
    table.print();

    // ---- the Winograd batched shape ----
    let bgd = BatchedGemm { batch: 36, m: 196, k: 128, n: 128 };
    let a = Tensor::randn(&[bgd.batch * bgd.a_stride()], 3).into_vec();
    let b = Tensor::randn(&[bgd.batch * bgd.b_stride()], 4).into_vec();
    let mut c = vec![0.0f32; bgd.batch * bgd.c_stride()];
    let s = measure(&cfg, || {
        bgd.run(&a, &b, &mut c);
    });
    println!(
        "batched GEMM 36 x [196x128 . 128x128]: {:.3} ms, {:.2} GFLOP/s \
         (unblocked A+C working set {} KiB)",
        ns_to_ms(s.median),
        bgd.flops() as f64 / s.median,
        bgd.workspace_elems() * 4 / 1024
    );

    // ---- stage split of one representative Winograd layer ----
    let (h, c_in, m_out) = (28usize, 128usize, 128usize);
    let input = Tensor::randn(&[1, h, h, c_in], 5);
    let weights = Tensor::randn(&[m_out, 3, 3, c_in], 6);
    let wino = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1))?;
    let im2row = Im2RowConvolution::new(&weights, (1, 1), (1, 1))?;
    let total = measure(&cfg, || {
        let _ = wino.run(&input, Some(&pool)).unwrap();
    });
    let base = measure(&cfg, || {
        let _ = im2row.run(&input, Some(&pool)).unwrap();
    });
    let flops = 2.0 * (h * h * 9 * c_in * m_out) as f64;
    println!(
        "\nlayer 28x28x128 -> 128 (3x3): wino {:.2} ms ({:.2} effective GFLOP/s), \
         im2row {:.2} ms ({:.2} GFLOP/s), speedup {:.2}x",
        ns_to_ms(total.median),
        flops / total.median,
        ns_to_ms(base.median),
        flops / base.median,
        base.median / total.median,
    );
    println!(
        "region blocking: L2 budget {} KiB, {} regions/block, per-block workspace {} KiB \
         (vs {} KiB unblocked)",
        wino.block_budget() / 1024,
        wino.regions_per_block(1, h, h)?,
        wino.block_workspace_bytes(1, h, h)? / 1024,
        wino.workspace_bytes(1, h, h)? / 1024,
    );
    println!(
        "note: 'effective' GFLOP/s counts direct-conv FLOPs — Winograd executes\n\
         ~4x fewer multiplies, so effective > raw roofline is expected at high speedup."
    );
    Ok(())
}
