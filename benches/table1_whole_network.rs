//! **Table 1 reproduction** (DESIGN.md E2): whole-network mean absolute
//! runtime (ms) at batch 1, for both schemes, split into Full Network and
//! Fast Layers — plus the derived speedup rows, exactly like the paper's
//! Table 1 (VGG-16, GoogleNet, Inception-v3, SqueezeNet; VGG-19 appears in
//! Figure 3 only, so `--model vgg19` is opt-in here too).
//!
//! Paper reference (4× A73): speedups 60.7% / 41.6% / 40.9% / 29.6% —
//! ordered by the fraction of runtime spent in Winograd-suitable layers.

use winoconv::bench::{ms, Table};
use winoconv::nn::{ActivationPlan, PreparedModel, Scheme};
use winoconv::parallel::ThreadPool;
use winoconv::quant::Dtype;
use winoconv::tensor::{Tensor, TensorView};
use winoconv::util::cli::Args;
use winoconv::workspace::Workspace;
use winoconv::zoo::ModelKind;

/// `--smoke`: the CI peak-memory gate. Prints the planner's peak activation
/// bytes (vs the naive sum-of-all-intermediates) for every zoo model —
/// MobileNets and ResNets included — then runs SqueezeNet, both MobileNets
/// and both ResNets end-to-end over pre-sized arenas asserting grow-count
/// and fallback-count both stay 0 — peak-memory drift or a
/// steady-state-allocation regression fails CI the same way bench bit-rot
/// does. For the MobileNets this also pins the depthwise engine's planned
/// write-into path (every dw layer dispatches to it); for MobileNetV2 and
/// the ResNets it pins the pointwise engine's dispatch census and the
/// residual-fusion savings in the activation plan. A final int8 pass runs
/// the quantizable models (MobileNetV1/V2, ResNet-18) end-to-end at
/// `Dtype::Int8`, pinning the int8 dispatch census and the accuracy drift
/// vs the f32 oracle. A batched pass then runs SqueezeNet and MobileNetV2
/// through `prepare_batched(4)` / `run_planned_batched_into`, pinning the
/// census x N dispatch accounting, grow-count 0 / fallback-count 0 on the
/// N-scaled arenas, and bitwise equality of every batch row against the
/// batch-1 planned walk on the same frame.
fn smoke(pool: &ThreadPool) -> winoconv::Result<()> {
    let mut table = Table::new(
        "activation memory plan per zoo model (batch 1)",
        &["Model", "planned peak KiB", "naive sum KiB", "saving"],
    );
    for model in ModelKind::ALL {
        let graph = model.build(1)?;
        let shape = model.input_shape(1);
        let shapes = graph.infer_shapes(&shape)?;
        let plan = ActivationPlan::for_graph(&graph.nodes, &shapes);
        assert!(
            plan.peak_bytes() < plan.naive_bytes(),
            "{model}: planner found no sharing (peak {} >= naive {})",
            plan.peak_bytes(),
            plan.naive_bytes()
        );
        table.row(&[
            model.display().to_string(),
            format!("{}", plan.peak_bytes() / 1024),
            format!("{}", plan.naive_bytes() / 1024),
            format!("{:.1}x", plan.naive_bytes() as f64 / plan.peak_bytes() as f64),
        ]);
    }
    table.print();

    for model in [
        ModelKind::SqueezeNet,
        ModelKind::MobileNetV1,
        ModelKind::MobileNetV2,
        ModelKind::ResNet18,
        ModelKind::ResNet50,
    ] {
        let graph = model.build(1)?;
        let shape = model.input_shape(1);
        let prepared =
            PreparedModel::prepare(model.name(), &graph, &shape, Scheme::WinogradWhereSuitable)?;
        let mut ws = Workspace::with_capacity(prepared.workspace_elems());
        let mut acts = Workspace::with_capacity(prepared.activation_plan().peak_elems());
        let mut out = vec![f32::NAN; prepared.output_shape().iter().product()];
        for seed in 0..2 {
            let input = Tensor::randn(&shape, seed);
            prepared.run_planned_into(&input, Some(pool), &mut ws, &mut acts, &mut out)?;
        }
        assert_eq!(ws.grow_count(), 0, "smoke {model}: scratch arena grew after pre-sizing");
        assert_eq!(acts.grow_count(), 0, "smoke {model}: activation arena grew after pre-sizing");
        assert_eq!(prepared.fallback_count(), 0, "smoke {model}: run() fallback taken");
        let counts = prepared.dispatch_counts();
        let census = prepared.dispatch_census();
        assert_eq!(counts.total(), 2 * census.total(), "smoke {model}: dispatch accounting");
        if matches!(model, ModelKind::MobileNetV1 | ModelKind::MobileNetV2) {
            assert!(
                census.depthwise > 0 && counts.depthwise == 2 * census.depthwise,
                "smoke {model}: depthwise layers must dispatch to the direct engine"
            );
        }
        if matches!(model, ModelKind::MobileNetV2 | ModelKind::ResNet18 | ModelKind::ResNet50) {
            assert!(
                census.pointwise > 0 && counts.pointwise == 2 * census.pointwise,
                "smoke {model}: dense 1x1 layers must dispatch to the pointwise engine"
            );
        }
        // Residual fusion must pay off in the activation plan: fused
        // conv/add intermediates get zero-size slots, so the naive
        // sum-of-all-intermediates strictly drops vs the unfused baseline
        // binding, and the planned peak can only shrink. (MobileNetV2's
        // global peak sits in the non-residual 112x112 expand region, so
        // only ResNet-50 — whose peak was the unfused bottleneck add at
        // 56x56x256 — must show a strict peak drop.)
        if matches!(model, ModelKind::MobileNetV2 | ModelKind::ResNet50) {
            let baseline =
                PreparedModel::prepare(model.name(), &graph, &shape, Scheme::Im2RowOnly)?;
            let (bp, op) = (baseline.activation_plan(), prepared.activation_plan());
            assert!(
                op.naive_bytes() < bp.naive_bytes(),
                "smoke {model}: residual fusion must remove planner intermediates \
                 (fused naive {} >= unfused naive {})",
                op.naive_bytes(),
                bp.naive_bytes()
            );
            assert!(
                op.peak_bytes() <= bp.peak_bytes(),
                "smoke {model}: fusion must never grow the planned peak"
            );
            if model == ModelKind::ResNet50 {
                assert!(
                    op.peak_bytes() < bp.peak_bytes(),
                    "smoke {model}: bottleneck fusion must shrink the planned peak \
                     (fused {} KiB vs unfused {} KiB)",
                    op.peak_bytes() / 1024,
                    bp.peak_bytes() / 1024
                );
            }
        }
        println!(
            "smoke ok: {} planned activation peak {} KiB (naive {} KiB), grow-count 0, \
             fallback-count 0, dispatch {}",
            model.display(),
            prepared.activation_plan().peak_bytes() / 1024,
            prepared.activation_plan().naive_bytes() / 1024,
            counts,
        );
    }

    // Quantized gate: the quantizable zoo models (MobileNetV1/V2 +
    // ResNet-18) prepared at int8 run end-to-end over pre-sized arenas at
    // grow-count 0 / fallback-count 0, every conv dispatches through an
    // int8 lane (Winograd and the f32 engines see zero traffic), the
    // dispatch accounting stays exact, and the whole-network output tracks
    // the f32 oracle within the calibrated drift budget.
    for model in [ModelKind::MobileNetV1, ModelKind::MobileNetV2, ModelKind::ResNet18] {
        assert!(model.quantizable(), "smoke {model}: quantized gate covers this model");
        let graph = model.build(1)?;
        let shape = model.input_shape(1);
        let input = Tensor::randn(&shape, 7);
        let oracle_m = PreparedModel::prepare(model.name(), &graph, &shape, Scheme::Im2RowOnly)?;
        let (oracle, _) = oracle_m.run(&input, Some(pool))?;
        let prepared = PreparedModel::prepare_with_dtype(
            model.name(),
            &graph,
            &shape,
            Scheme::WinogradWhereSuitable,
            Dtype::Int8,
        )?;
        let mut ws = Workspace::with_capacity(prepared.workspace_elems());
        let mut acts = Workspace::with_capacity(prepared.activation_plan().peak_elems());
        let mut out = vec![f32::NAN; prepared.output_shape().iter().product()];
        for _ in 0..2 {
            prepared.run_planned_into(&input, Some(pool), &mut ws, &mut acts, &mut out)?;
        }
        assert_eq!(ws.grow_count(), 0, "smoke {model} int8: scratch arena grew after pre-sizing");
        assert_eq!(acts.grow_count(), 0, "smoke {model} int8: activation arena grew");
        assert_eq!(prepared.fallback_count(), 0, "smoke {model} int8: run() fallback taken");
        let census = prepared.dispatch_census();
        let counts = prepared.dispatch_counts();
        assert_eq!(counts.total(), 2 * census.total(), "smoke {model} int8: dispatch accounting");
        assert_eq!(
            census.winograd + census.im2row + census.depthwise + census.pointwise + census.direct,
            0,
            "smoke {model} int8: f32 lanes must see zero traffic"
        );
        match model {
            // MobileNetV1: the stem 3x3/s2 is the only dense spatial conv;
            // every separable block is one depthwise + one pointwise.
            ModelKind::MobileNetV1 => {
                assert_eq!(census.depthwise_i8, 13, "smoke {model} int8: dw census");
                assert_eq!(census.pointwise_i8, 13, "smoke {model} int8: pw census");
                assert_eq!(census.im2row_i8, 1, "smoke {model} int8: stem census");
            }
            // MobileNetV2: 17 inverted-residual depthwise layers; the
            // expand/project 1x1s all land on the int8 pointwise engine.
            ModelKind::MobileNetV2 => {
                assert_eq!(census.depthwise_i8, 17, "smoke {model} int8: dw census");
                assert!(census.pointwise_i8 > 0, "smoke {model} int8: pw census");
            }
            // ResNet-18: 3x3 basic blocks on int8 im2row, 1x1 downsample
            // projections on the int8 pointwise engine.
            _ => {
                assert!(
                    census.im2row_i8 > 0 && census.pointwise_i8 > 0,
                    "smoke {model} int8: both dense int8 lanes must bind"
                );
            }
        }
        // Accuracy drift vs the f32 oracle: a layer-wise error-propagation
        // simulation of the scheme (per-tensor u8 activations x per-channel
        // i8 weights, f32 activations between layers) puts the worst-case
        // relative drift of these three networks at 0.116; 0.25 leaves 2x
        // headroom while still catching a broken requantize path outright.
        assert!(out.iter().all(|v| v.is_finite()), "smoke {model} int8: non-finite output");
        let peak = oracle.data().iter().fold(0f32, |a, &v| a.max(v.abs())).max(1e-12);
        let drift = out
            .iter()
            .zip(oracle.data())
            .fold(0f32, |a, (&x, &y)| a.max((x - y).abs()));
        assert!(
            drift <= 0.25 * peak,
            "smoke {model} int8: drift {drift} exceeds 0.25 x f32 peak {peak}"
        );
        println!(
            "smoke ok: {} int8 end-to-end, grow-count 0, fallback-count 0, \
             drift {:.4} of f32 peak, dispatch {}",
            model.display(),
            drift / peak,
            counts,
        );
    }

    // Batched gate: a prepared model scaled to N frames must run the whole
    // network in one planned walk per batch — every dispatch advances the
    // counters by census x N, the N-scaled arenas never grow, the run()
    // fallback is never taken, and each batch row is bit-identical to the
    // batch-1 planned walk over the same frame (batching reorders nothing;
    // it only widens the GEMM sweeps over a shared weight panel).
    let nb = 4usize;
    for model in [ModelKind::SqueezeNet, ModelKind::MobileNetV2] {
        let graph = model.build(1)?;
        let shape = model.input_shape(1);
        let prepared =
            PreparedModel::prepare(model.name(), &graph, &shape, Scheme::WinogradWhereSuitable)?;
        let batch = prepared.prepare_batched(nb)?;
        let frame_in: usize = shape.iter().product();
        let frame_out: usize = prepared.output_shape().iter().product();
        assert_eq!(
            batch.peak_elems(),
            prepared.activation_plan().peak_elems() * nb,
            "smoke {model} batched: plan slots must scale linearly with N"
        );

        // Reference: each frame through the batch-1 planned path.
        let mut ws1 = Workspace::with_capacity(prepared.workspace_elems());
        let mut acts1 = Workspace::with_capacity(prepared.activation_plan().peak_elems());
        let mut input = Tensor::zeros(batch.input_shape());
        let mut want = vec![f32::NAN; nb * frame_out];
        for f in 0..nb {
            let frame = Tensor::randn(&shape, 100 + f as u64);
            input.data_mut()[f * frame_in..(f + 1) * frame_in].copy_from_slice(frame.data());
            prepared.run_planned_into(
                &frame,
                Some(pool),
                &mut ws1,
                &mut acts1,
                &mut want[f * frame_out..(f + 1) * frame_out],
            )?;
        }

        let before = prepared.dispatch_counts().total();
        let mut ws = Workspace::with_capacity(batch.workspace_elems());
        let mut acts = Workspace::with_capacity(batch.peak_elems());
        let mut got = vec![f32::NAN; nb * frame_out];
        for _ in 0..2 {
            let view = TensorView::new(batch.input_shape(), input.data())?;
            prepared.run_planned_batched_into(
                &batch,
                &view,
                Some(pool),
                &mut ws,
                &mut acts,
                &mut got,
            )?;
        }
        assert_eq!(ws.grow_count(), 0, "smoke {model} batched: scratch arena grew");
        assert_eq!(acts.grow_count(), 0, "smoke {model} batched: activation arena grew");
        assert_eq!(prepared.fallback_count(), 0, "smoke {model} batched: run() fallback taken");
        let census = prepared.dispatch_census();
        assert_eq!(
            prepared.dispatch_counts().total() - before,
            2 * nb as u64 * census.total(),
            "smoke {model} batched: dispatch accounting must advance by census x N"
        );
        assert!(
            got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
            "smoke {model} batched: batch rows must be bit-identical to batch-1 walks"
        );
        println!(
            "smoke ok: {} batched N={nb}, one planned walk per batch, census x N dispatch, \
             grow-count 0, fallback-count 0, rows bitwise == batch-1",
            model.display(),
        );
    }
    Ok(())
}

struct Row {
    model: ModelKind,
    base_full: f64,
    base_fast: f64,
    ours_full: f64,
    ours_fast: f64,
}

fn main() -> winoconv::Result<()> {
    let args = Args::from_env(&["quick", "bench", "smoke"])?;
    let threads: usize = args.get_parse_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    )?;
    let quick = args.flag("quick")
        || std::env::var("WINOCONV_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let reps: usize = args.get_parse_or("reps", if quick { 1 } else { 3 })?;
    let pool = ThreadPool::new(threads);

    if args.flag("smoke") {
        return smoke(&pool);
    }

    let models: Vec<ModelKind> = match args.get("model") {
        Some(name) => vec![ModelKind::parse(name)
            .ok_or_else(|| winoconv::Error::Config(format!("unknown model {name:?}")))?],
        None => vec![
            ModelKind::Vgg16,
            ModelKind::GoogleNet,
            ModelKind::InceptionV3,
            ModelKind::SqueezeNet,
            ModelKind::MobileNetV1,
            ModelKind::MobileNetV2,
            ModelKind::ResNet18,
            ModelKind::ResNet50,
        ],
    };

    let mut rows = Vec::new();
    for model in models {
        eprintln!("benching {model} (both schemes, {reps} rep(s)) ...");
        let graph = model.build(1)?;
        let shape = model.input_shape(1);
        let input = Tensor::randn(&shape, 99);
        let mut full = [0.0f64; 2];
        let mut fast = [0.0f64; 2];
        for (i, scheme) in [Scheme::Im2RowOnly, Scheme::WinogradWhereSuitable]
            .into_iter()
            .enumerate()
        {
            let prepared = PreparedModel::prepare(model.name(), &graph, &shape, scheme)?;
            if i == 0 {
                let plan = prepared.activation_plan();
                eprintln!(
                    "  activation plan: peak {} KiB vs naive {} KiB ({:.1}x saving)",
                    plan.peak_bytes() / 1024,
                    plan.naive_bytes() / 1024,
                    plan.naive_bytes() as f64 / plan.peak_bytes().max(1) as f64,
                );
            }
            let _ = prepared.run(&input, Some(&pool))?; // warm-up
            for _ in 0..reps {
                let t0 = std::time::Instant::now();
                let (_, timings) = prepared.run(&input, Some(&pool))?;
                full[i] += t0.elapsed().as_nanos() as f64;
                fast[i] += timings
                    .iter()
                    .filter(|t| t.fast_layer)
                    .map(|t| t.ns as f64)
                    .sum::<f64>();
            }
            full[i] /= reps as f64;
            fast[i] /= reps as f64;
            eprintln!("  {scheme}: full {} ms, fast-layers {} ms", ms(full[i]), ms(fast[i]));
        }
        rows.push(Row {
            model,
            base_full: full[0],
            base_fast: fast[0],
            ours_full: full[1],
            ours_fast: fast[1],
        });
    }

    let mut table = Table::new(
        &format!(
            "Table 1: whole-network mean absolute runtime (ms), batch 1, {threads} thread(s)"
        ),
        &["Model", "scheme", "Full Network", "Fast Layers"],
    );
    for r in &rows {
        table.row(&[
            r.model.display().to_string(),
            "Im2Row".into(),
            ms(r.base_full),
            ms(r.base_fast),
        ]);
        table.row(&[
            r.model.display().to_string(),
            "Ours".into(),
            ms(r.ours_full),
            ms(r.ours_fast),
        ]);
    }
    table.print();

    let mut table = Table::new(
        "Table 1 (derived): speedup",
        &["Model", "full ms saved", "full %", "fast ms saved", "fast %", "paper full %"],
    );
    // MobileNets are not in the paper's Table 1 — and have no
    // Winograd-suitable layers, so their scheme delta is expected ≈ 0 (the
    // depthwise engine binds on both schemes; see ablation_depthwise).
    let paper = [
        (ModelKind::Vgg16, "60.7%"),
        (ModelKind::GoogleNet, "41.6%"),
        (ModelKind::InceptionV3, "40.9%"),
        (ModelKind::SqueezeNet, "29.6%"),
        (ModelKind::Vgg19, "-"),
        (ModelKind::MobileNetV1, "-"),
        (ModelKind::MobileNetV2, "-"),
        (ModelKind::ResNet18, "-"),
        (ModelKind::ResNet50, "-"),
    ];
    for r in &rows {
        let paper_pct = paper
            .iter()
            .find(|(m, _)| *m == r.model)
            .map(|(_, p)| *p)
            .unwrap_or("-");
        // MobileNets have no fast layers: guard the 0/0 fast-speedup cell.
        let fast_pct = if r.base_fast > 0.0 {
            format!("{:.1}%", (1.0 - r.ours_fast / r.base_fast) * 100.0)
        } else {
            "-".to_string()
        };
        table.row(&[
            r.model.display().to_string(),
            ms(r.base_full - r.ours_full),
            format!("{:.1}%", (1.0 - r.ours_full / r.base_full) * 100.0),
            ms(r.base_fast - r.ours_fast),
            fast_pct,
            paper_pct.to_string(),
        ]);
    }
    table.print();
    println!(
        "shape check: gains should be bounded by the fast-layer fraction\n\
         (VGG >> GoogleNet ≈ Inception-v3 > SqueezeNet, as in the paper)."
    );
    Ok(())
}
