//! **Table 2 reproduction** (DESIGN.md E1): per-layer speedup of the
//! region-wise multi-channel Winograd scheme over im2row+GEMM, aggregated
//! per (model, layer-type) with average and peak — the same rows the paper
//! reports.
//!
//! Paper reference bands (4× Cortex-A73): 3×3 avg 2.2–3.1× / peak up to
//! 4.1×; 5×5 avg 2.3–2.7×; 1×7 & 7×1 avg ~2.0×. The *shape* to reproduce:
//! every fast layer wins, 3×3 wins most, 1-D variants least.
//!
//! `WINOCONV_BENCH_QUICK=1` or `--quick` shrinks sample counts;
//! `--model <name>` restricts to one model; `--profile` appends a traced
//! whole-network roofline table per model (FLOPs, GFLOP/s, intensity).

use std::collections::BTreeMap;
use winoconv::bench::workloads::unique_fast_layers;
use winoconv::bench::{measure, BenchConfig, Table};
use winoconv::conv::select::select_variant_spatial;
use winoconv::im2row::Im2RowConvolution;
use winoconv::nn::{PreparedModel, Scheme};
use winoconv::parallel::ThreadPool;
use winoconv::tensor::Tensor;
use winoconv::util::cli::Args;
use winoconv::util::stats::ns_to_ms;
use winoconv::winograd::WinogradConvolution;
use winoconv::workspace::Workspace;
use winoconv::zoo::ModelKind;

fn main() -> winoconv::Result<()> {
    let args = Args::from_env(&["quick", "bench", "profile"])?;
    let threads: usize = args.get_parse_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    )?;
    let pool = ThreadPool::new(threads);
    let cfg = if args.flag("quick") { BenchConfig::quick() } else { BenchConfig::from_env() };

    let models: Vec<ModelKind> = match args.get("model") {
        Some(name) => vec![ModelKind::parse(name)
            .ok_or_else(|| winoconv::Error::Config(format!("unknown model {name:?}")))?],
        None => ModelKind::ALL.to_vec(),
    };

    // (model, layer-type) → list of (speedup, weight = occurrence count).
    let mut agg: BTreeMap<(String, String), Vec<(f64, usize)>> = BTreeMap::new();

    for model in &models {
        eprintln!("benching {model} fast layers ...");
        for (spec, count) in unique_fast_layers(*model, 1)? {
            let input = spec.input(11);
            let weights = spec.weights(12);
            let oh = spec.input_shape[1] + 2 * spec.pad.0 - spec.kernel.0 + 1;
            let ow = spec.input_shape[2] + 2 * spec.pad.1 - spec.kernel.1 + 1;
            let variant = match select_variant_spatial(spec.kernel, oh, ow) {
                Some(v) => v,
                None => continue,
            };
            let im2row = Im2RowConvolution::new(&weights, spec.stride, spec.pad)?;
            let wino = WinogradConvolution::new(variant, &weights, spec.pad)?;
            let base = measure(&cfg, || {
                let _ = im2row.run(&input, Some(&pool)).unwrap();
            });
            let ours = measure(&cfg, || {
                let _ = wino.run(&input, Some(&pool)).unwrap();
            });
            let s = base.median / ours.median;
            eprintln!(
                "  {:<28} {:<4} {:>7.2} ms -> {:>7.2} ms  {s:.2}x",
                spec.name,
                spec.layer_type(),
                ns_to_ms(base.median),
                ns_to_ms(ours.median)
            );
            agg.entry((model.display().to_string(), spec.layer_type()))
                .or_default()
                .push((s, count));
        }
    }

    let mut table = Table::new(
        &format!("Table 2: per-layer speedup, im2row vs ours ({threads} thread(s))"),
        &["Model", "Layer-type", "Average Speedup", "Peak Speedup", "paper avg", "paper peak"],
    );
    let paper: BTreeMap<(&str, &str), (&str, &str)> = BTreeMap::from([
        (("VGG-16", "3x3"), ("2.7x", "3.5x")),
        (("VGG-19", "3x3"), ("2.8x", "3.5x")),
        (("GoogleNet", "3x3"), ("2.6x", "4.1x")),
        (("GoogleNet", "5x5"), ("2.3x", "3.2x")),
        (("Inception-v3", "1x7"), ("2.0x", "2.1x")),
        (("Inception-v3", "7x1"), ("2.0x", "2.1x")),
        (("Inception-v3", "3x3"), ("3.1x", "3.8x")),
        (("Inception-v3", "5x5"), ("2.7x", "2.8x")),
        (("SqueezeNet", "3x3"), ("2.2x", "2.6x")),
    ]);
    for ((model, ltype), speedups) in &agg {
        let total_w: usize = speedups.iter().map(|(_, w)| w).sum();
        let avg: f64 =
            speedups.iter().map(|(s, w)| s * *w as f64).sum::<f64>() / total_w as f64;
        let peak = speedups.iter().map(|(s, _)| *s).fold(0.0, f64::max);
        let (pa, pp) = paper
            .get(&(model.as_str(), ltype.as_str()))
            .copied()
            .unwrap_or(("-", "-"));
        table.row(&[
            model.clone(),
            ltype.clone(),
            format!("{avg:.1}x"),
            format!("{peak:.1}x"),
            pa.to_string(),
            pp.to_string(),
        ]);
    }
    table.print();
    println!(
        "note: paper numbers are 4x Cortex-A73 + NEON; this testbed is {threads} x86 thread(s).\n\
         The reproduction target is the *shape*: all fast layers > 1x, 3x3 strongest, 1-D weakest."
    );

    // `--profile`: whole-network traced walks per model, reduced to the
    // roofline view — shows *why* the per-layer speedups above land where
    // they do (high-intensity 3x3 layers vs bandwidth-bound 1x1/pool).
    if args.flag("profile") {
        for model in &models {
            let graph = model.build(1)?;
            let shape = model.input_shape(1);
            let prepared = PreparedModel::prepare(
                model.name(),
                &graph,
                &shape,
                Scheme::WinogradWhereSuitable,
            )?;
            let input = Tensor::randn(&shape, 7);
            let mut ws = Workspace::with_capacity(prepared.workspace_elems());
            let mut acts =
                Workspace::with_capacity(prepared.activation_plan().peak_elems());
            let mut out = vec![f32::NAN; prepared.output_shape().iter().product()];
            prepared.run_planned_into(&input, Some(&pool), &mut ws, &mut acts, &mut out)?; // warm-up
            let walks = if args.flag("quick") { 2usize } else { 4 };
            winoconv::trace::reserve(walks * prepared.trace_spans_per_walk() + 64);
            winoconv::trace::set_enabled(true);
            for _ in 0..walks {
                prepared.run_planned_into(&input, Some(&pool), &mut ws, &mut acts, &mut out)?;
            }
            winoconv::trace::set_enabled(false);
            let profiles = winoconv::trace::roofline::build_profiles(
                &prepared.layer_infos(),
                &winoconv::trace::take(),
            );
            print!(
                "{}",
                winoconv::trace::roofline::render(
                    &format!("{model}: per-layer roofline ({walks} walks, {threads} threads)"),
                    &profiles,
                )
            );
        }
    }
    Ok(())
}
