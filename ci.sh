#!/usr/bin/env bash
# CI gate for winoconv. The missing-manifest class of regression (a repo
# that cannot even `cargo build`) can never land silently again: every step
# here is fatal.
#
# Usage: ./ci.sh [--no-lint]
#   --no-lint   skip the fmt/clippy steps (e.g. on toolchains without them)
set -euo pipefail
cd "$(dirname "$0")"

run() {
    echo "==> $*"
    "$@"
}

# Static-invariant gate, first and fatal: documented unsafe, allocation-free
# hot paths, SIMD backend + entry-point parity, registered targets. Fails
# with file:line findings and prints the files-scanned / unsafe-sites /
# waivers summary on every run so the counts show up in every CI log.
run cargo run --release --bin statcheck

run cargo build --release
run cargo test -q

# Bench bit-rot gate: the bench binaries must keep building, and one
# tiny-shape run of the fusion ablation must keep passing its fused==staged
# assert — bench drift fails CI instead of rotting silently.
run cargo build --release --benches
run cargo bench --bench ablation_amortization -- --smoke

# Peak-memory gate: the activation planner must keep beating the naive
# sum-of-all-intermediates on every zoo model (MobileNets included), and
# SqueezeNet + MobileNetV1/V2 runs over pre-sized arenas must stay at
# grow-count 0 / fallback-count 0 — a steady-state-allocation or
# peak-memory regression fails CI too. Also pins the int8 end-to-end pass
# and the batched (N=4) planned pass: census x N dispatch accounting,
# grow-count 0 on the N-scaled arenas, batch rows bitwise == batch-1.
run cargo bench --bench table1_whole_network -- --smoke

# Depthwise gate: the direct register-tiled depthwise engine must keep
# beating the im2row-as-grouped degenerate baseline on MobileNetV1-shaped
# 3x3 depthwise layers (both strides), and must keep matching it
# numerically over a grow-count-0 arena.
run cargo bench --bench ablation_depthwise -- --smoke

# Pointwise gate: the zero-copy direct 1x1 engine must keep beating im2row
# at stride 1 (where the patch matrix is a full input copy) and keep
# matching it bit-for-bit at both strides; the fused residual epilogue
# must stay no slower than the separate conv + add + relu walk, also
# bit-identically, over grow-count-0 arenas.
run cargo bench --bench ablation_pointwise -- --smoke

# Quantization gate: the int8 im2row GEMM (u8xi8->i32 micro-kernel +
# dequantizing epilogue) must keep strictly beating the f32 im2row GEMM on
# identical dense 3x3 shapes, with int8 outputs tracking the f32 oracle
# within the subsystem's rel-error budget over grow-count-0 arenas.
run cargo bench --bench ablation_quant -- --smoke

# Batching gate: one batched GEMM sweep over [N, H, W, C] must keep
# strictly beating N back-to-back batch-1 walks, bit-identically, on
# VGG-16-shaped fast layers and a MobileNetV2-shaped bottleneck at
# N in {2, 4, 8}, over grow-count-0 arenas (the depthwise layer has no
# shared weight panel to amortise and is reported, not gated).
run cargo bench --bench ablation_batch -- --smoke

# Tracing gate: per-layer + per-stage span recording must stay cheap
# enough to leave on — a traced whole-network SqueezeNet walk at most
# 1.03x the untraced walk (interleaved medians), bit-for-bit identical
# output, zero arena growth/fallback with the sink enabled, and an exact
# span census (walks x trace_spans_per_walk, conv layer spans matching
# the dispatch census, zero drops on a sized-to-fit ring).
run cargo bench --bench ablation_trace -- --smoke

if [[ "${1:-}" != "--no-lint" ]]; then
    if cargo fmt --version >/dev/null 2>&1; then
        run cargo fmt --check
    else
        echo "==> cargo fmt unavailable; skipping (install rustfmt or pass --no-lint)"
    fi
    if cargo clippy --version >/dev/null 2>&1; then
        run cargo clippy --all-targets -- -D warnings
    else
        echo "==> cargo clippy unavailable; skipping (install clippy or pass --no-lint)"
    fi
fi

echo "==> ci green"
