//! Walk the model zoo: build each of the nine CNNs (the paper's five plus
//! MobileNetV1/V2 and ResNet-18/50), run one inference under both schemes,
//! and print the per-model layer census plus the slowest layers — a quick
//! structural sanity check of the whole stack.
//!
//! ```sh
//! cargo run --release --example model_zoo -- [--model resnet-50] [--threads 4] [--dtype int8] [--batch 4] [--profile]
//! ```
//! Without `--model`, only the small models run (VGG/Inception take
//! minutes in a debug-ish environment; use the benches for full tables).
//! Note the MobileNets show ≈ 0 scheme delta by design: they have no
//! Winograd-suitable layers, and their depthwise convs bind the direct
//! depthwise engine under *both* schemes (see `ablation_depthwise`); the
//! 1×1-heavy MobileNetV2/ResNet bottlenecks split on the zero-copy
//! pointwise engine instead (see `ablation_pointwise`).

use winoconv::bench::{ms, Table};
use winoconv::nn::{PreparedModel, Scheme};
use winoconv::parallel::ThreadPool;
use winoconv::quant::Dtype;
use winoconv::tensor::{Tensor, TensorView};
use winoconv::util::cli::Args;
use winoconv::workspace::Workspace;
use winoconv::zoo::ModelKind;

fn main() -> winoconv::Result<()> {
    let args = Args::from_env(&["profile"])?;
    let threads: usize = args.get_parse_or("threads", 4)?;
    let dtype: Dtype = args.get_parse_or("dtype", Dtype::F32)?;
    let batch: usize = args.get_parse_or("batch", 1)?;
    if batch == 0 {
        return Err(winoconv::Error::Config("--batch must be at least 1".into()));
    }
    let pool = ThreadPool::new(threads);

    let models: Vec<ModelKind> = match args.get("model") {
        Some(name) => vec![ModelKind::parse(name)
            .ok_or_else(|| winoconv::Error::Config(format!("unknown model {name:?}")))?],
        None => vec![
            ModelKind::SqueezeNet,
            ModelKind::GoogleNet,
            ModelKind::MobileNetV1,
            ModelKind::MobileNetV2,
            ModelKind::ResNet18,
        ],
    };

    for model in models {
        let graph = model.build(1)?;
        let shape = model.input_shape(1);
        let shapes = graph.infer_shapes(&shape)?;
        println!(
            "\n=== {model}: {} nodes, {} convs, input {:?} ===",
            graph.nodes.len(),
            graph.conv_count(),
            shape
        );

        let input = Tensor::randn(&shape, 3);
        let mut rows: Vec<(String, f64, f64, bool)> = Vec::new();
        let mut totals = (0.0f64, 0.0f64);
        for (si, scheme) in [Scheme::Im2RowOnly, Scheme::WinogradWhereSuitable]
            .into_iter()
            .enumerate()
        {
            let prepared =
                PreparedModel::prepare_with_dtype(model.name(), &graph, &shape, scheme, dtype)?;
            if si == 0 {
                println!("dtype {dtype}: dispatch census {}", prepared.dispatch_census());
            }
            if si == 0 {
                let plan = prepared.activation_plan();
                println!(
                    "activation plan: peak {} KiB, naive sum-of-intermediates {} KiB ({:.1}x saving); \
                     conv scratch {} KiB",
                    plan.peak_bytes() / 1024,
                    plan.naive_bytes() / 1024,
                    plan.naive_bytes() as f64 / plan.peak_bytes().max(1) as f64,
                    prepared.workspace_elems() * 4 / 1024,
                );
            }
            let _ = prepared.run(&input, Some(&pool))?; // warm-up
            let t0 = std::time::Instant::now();
            let (out, timings) = prepared.run(&input, Some(&pool))?;
            let total = t0.elapsed().as_nanos() as f64;
            assert_eq!(out.shape().last(), Some(&1000));
            if si == 0 {
                totals.0 = total;
                for t in &timings {
                    rows.push((t.name.clone(), t.ns as f64, 0.0, t.fast_layer));
                }
            } else {
                totals.1 = total;
                for (row, t) in rows.iter_mut().zip(&timings) {
                    row.2 = t.ns as f64;
                }
            }
        }

        // Top-5 slowest layers under the baseline.
        let mut by_cost = rows.clone();
        by_cost.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut table = Table::new(
            &format!("{model}: 5 costliest layers (im2row baseline vs ours)"),
            &["layer", "im2row ms", "ours ms", "fast layer"],
        );
        for (name, base, ours, fast) in by_cost.into_iter().take(5) {
            table.row(&[name, ms(base), ms(ours), fast.to_string()]);
        }
        table.print();
        println!(
            "whole network: im2row {} ms -> ours {} ms ({:.1}% faster)",
            ms(totals.0),
            ms(totals.1),
            (1.0 - totals.1 / totals.0) * 100.0
        );

        // `--batch N`: one batched planned walk sweeps all N frames through
        // each layer's shared weight panel — compare the amortised per-frame
        // time against the batch-1 walk above.
        if batch > 1 {
            let prepared = PreparedModel::prepare_with_dtype(
                model.name(),
                &graph,
                &shape,
                Scheme::WinogradWhereSuitable,
                dtype,
            )?;
            let plan = prepared.prepare_batched(batch)?;
            let batched_in = Tensor::randn(plan.input_shape(), 3);
            let mut ws = Workspace::with_capacity(plan.workspace_elems());
            let mut acts = Workspace::with_capacity(plan.peak_elems());
            let mut out = vec![f32::NAN; plan.output_shape().iter().product()];
            let view = TensorView::new(plan.input_shape(), batched_in.data())?;
            prepared
                .run_planned_batched_into(&plan, &view, Some(&pool), &mut ws, &mut acts, &mut out)?; // warm-up
            let t0 = std::time::Instant::now();
            prepared
                .run_planned_batched_into(&plan, &view, Some(&pool), &mut ws, &mut acts, &mut out)?;
            let per_batch = t0.elapsed().as_nanos() as f64;
            println!(
                "batched N={batch}: {} ms/batch, {} ms/frame (batch-1 walk: {} ms)",
                ms(per_batch),
                ms(per_batch / batch as f64),
                ms(totals.1),
            );
        }

        // `--profile`: traced planned walks reduced to the per-layer
        // roofline table (same view as `winoconv profile`).
        if args.flag("profile") {
            let prepared = PreparedModel::prepare_with_dtype(
                model.name(),
                &graph,
                &shape,
                Scheme::WinogradWhereSuitable,
                dtype,
            )?;
            let mut ws = Workspace::with_capacity(prepared.workspace_elems());
            let mut acts =
                Workspace::with_capacity(prepared.activation_plan().peak_elems());
            let mut out = vec![f32::NAN; prepared.output_shape().iter().product()];
            prepared.run_planned_into(&input, Some(&pool), &mut ws, &mut acts, &mut out)?; // warm-up
            let walks = 4usize;
            winoconv::trace::reserve(walks * prepared.trace_spans_per_walk() + 64);
            winoconv::trace::set_enabled(true);
            for _ in 0..walks {
                prepared.run_planned_into(&input, Some(&pool), &mut ws, &mut acts, &mut out)?;
            }
            winoconv::trace::set_enabled(false);
            let profiles = winoconv::trace::roofline::build_profiles(
                &prepared.layer_infos(),
                &winoconv::trace::take(),
            );
            print!(
                "{}",
                winoconv::trace::roofline::render(
                    &format!("{model}: per-layer roofline ({walks} walks, {dtype})"),
                    &profiles,
                )
            );
        }

        // Output-shape audit for the curious.
        let final_shape = shapes.last().unwrap();
        println!("output shape: {final_shape:?}");
    }
    Ok(())
}
