//! Cross-validate the three-layer stack (DESIGN.md E9): every AOT artifact
//! (L2 JAX graph calling L1 Pallas kernels, lowered to HLO text) is executed
//! through the PJRT runtime and compared against the native Rust (L3)
//! implementation of the same function on identical inputs.
//!
//! ```sh
//! make artifacts && cargo run --release --example pjrt_verify
//! ```

use winoconv::util::cli::Args;

fn main() -> winoconv::Result<()> {
    let args = Args::from_env(&[])?;
    let dir = args.get_or("artifacts", "artifacts");
    winoconv::runtime::verify::verify_all(std::path::Path::new(&dir), true)?;
    println!("\nrust engine == JAX/Pallas artifacts — three-layer stack verified");
    Ok(())
}
