//! Quickstart: one convolution, three algorithms, identical numbers.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use winoconv::conv::{Conv2d, ConvAlgorithm};
use winoconv::parallel::ThreadPool;
use winoconv::tensor::Tensor;
use winoconv::winograd::WinogradVariant;

fn main() -> winoconv::Result<()> {
    // A VGG-ish layer: 3×3 stride-1 convolution, 64 → 64 channels, 56×56.
    let conv = Conv2d::new(64, 64, (3, 3)).with_padding((1, 1));
    let input = Tensor::randn(&[1, 56, 56, 64], 42);
    let weights = conv.random_weights(7);
    let pool = ThreadPool::new(4);

    println!("layer: 56x56x64 -> 64, 3x3 stride 1 pad 1");
    println!("auto-selected algorithm: {}\n", conv.resolved_algorithm());

    let mut reference: Option<Tensor> = None;
    for alg in [
        ConvAlgorithm::Im2Row,
        ConvAlgorithm::Winograd(WinogradVariant::F2x2_3x3),
        ConvAlgorithm::Winograd(WinogradVariant::F4x4_3x3),
    ] {
        let conv = conv.clone().with_algorithm(alg);
        let t0 = std::time::Instant::now();
        let out = conv.run_with(&input, &weights, Some(&pool))?;
        let dt = t0.elapsed();
        match &reference {
            None => {
                reference = Some(out);
                println!("{alg:<28} {dt:>10.2?}   (reference)");
            }
            Some(r) => {
                let ok = out.allclose(r, 1e-3);
                println!("{alg:<28} {dt:>10.2?}   matches reference: {ok}");
                assert!(ok, "algorithms disagree!");
            }
        }
    }

    println!("\nall algorithms agree — see `winoconv layers --model vgg16` for the full table");
    Ok(())
}
