//! End-to-end driver (DESIGN.md E4): load SqueezeNet, serve a stream of
//! batched requests through the coordinator, and report latency/throughput —
//! the reproduction of the paper's deployment claim (§1: "an average
//! inference rate of 47 frames/sec" on 4× Cortex-A73).
//!
//! Two phases:
//! 1. *closed-loop latency*: one in-flight request at a time (batch size 1,
//!    the paper's setting) — reports per-frame latency and fps.
//! 2. *open-loop throughput*: several client threads keep the queue full —
//!    the latency-budgeted batcher forms real multi-frame batches (watch
//!    the `batches: ... (mean ... frames, max ...)` stats and the p50/p99
//!    queue-wait percentiles move in the phase-2 report).
//!
//! ```sh
//! cargo run --release --example serve_squeezenet -- [--seconds 20] [--threads 4] [--clients 3]
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};
use winoconv::coordinator::{EngineConfig, InferenceEngine};
use winoconv::nn::{PreparedModel, Scheme};
use winoconv::tensor::Tensor;
use winoconv::util::cli::Args;
use winoconv::zoo::ModelKind;

fn main() -> winoconv::Result<()> {
    let args = Args::from_env(&[])?;
    let seconds: u64 = args.get_parse_or("seconds", 20)?;
    let threads: usize = args.get_parse_or("threads", 4)?;
    let clients: usize = args.get_parse_or("clients", 3)?;

    let model = ModelKind::SqueezeNet;
    let shape = model.input_shape(1);
    println!("building {model} ({:?} input) ...", shape);
    let graph = model.build(1)?;
    println!(
        "prepared: {} conv layers, scheme = region-wise Winograd where suitable",
        graph.conv_count()
    );
    let prepared = PreparedModel::prepare(model.name(), &graph, &shape, Scheme::WinogradWhereSuitable)?;

    // Tracing stays ON for the whole serve: the dispatcher records its
    // queue-wait/gather/compute/scatter phases and every walk its layer +
    // engine-stage spans into this pre-reserved ring (overflow drops, never
    // allocates) — and steady-state serving must *still* never allocate,
    // which the arena assert at shutdown pins.
    winoconv::trace::reserve(1 << 16);
    winoconv::trace::set_enabled(true);

    // ---- Phase 1: closed-loop, batch 1 (the paper's measurement) ----
    let engine = InferenceEngine::start(
        prepared,
        EngineConfig {
            threads,
            ..EngineConfig::default()
        },
    );
    println!("\n[phase 1] closed-loop single-stream for {}s on {threads} threads", seconds / 2);
    let deadline = Instant::now() + Duration::from_secs(seconds / 2);
    let mut frames = 0u64;
    while Instant::now() < deadline {
        let input = Tensor::randn(&shape, frames);
        let resp = engine.infer(input)?;
        assert_eq!(resp.output.shape(), &[1, 1000]);
        frames += 1;
    }
    let snap = engine.metrics();
    println!("  {}", snap.report());
    println!(
        "  single-stream rate: {:.1} frames/sec (paper: 47 fps on 4x Cortex-A73)",
        snap.throughput_fps
    );

    // ---- Phase 2: open-loop with several clients ----
    println!("\n[phase 2] open-loop, {clients} clients for {}s", seconds - seconds / 2);
    let stop = Arc::new(AtomicBool::new(false));
    let engine = Arc::new(engine);
    let handles: Vec<_> = (0..clients)
        .map(|cid| {
            let engine = Arc::clone(&engine);
            let stop = Arc::clone(&stop);
            let shape = shape.clone();
            std::thread::spawn(move || {
                let mut sent = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let input = Tensor::randn(&shape, (cid as u64) << 32 | sent);
                    match engine.infer(input) {
                        Ok(_) => sent += 1,
                        Err(_) => std::thread::sleep(Duration::from_millis(2)),
                    }
                }
                sent
            })
        })
        .collect();
    std::thread::sleep(Duration::from_secs(seconds - seconds / 2));
    stop.store(true, Ordering::Relaxed);
    let per_client: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let snap = engine.metrics();
    println!("  per-client frames: {per_client:?}");
    println!("  {}", snap.report());

    let engine = Arc::try_unwrap(engine).map_err(|_| {
        winoconv::Error::Runtime("engine still referenced".into())
    })?;
    engine.shutdown();

    // Observability wrap-up: the whole serve ran with the span sink
    // enabled, so zero arena growth here proves tracing kept the
    // steady-state no-allocation invariant under real concurrent load.
    winoconv::trace::set_enabled(false);
    let spans = winoconv::trace::take();
    let serve_spans = spans
        .iter()
        .filter(|s| s.kind == winoconv::trace::SpanKind::Serve)
        .count();
    println!(
        "\ntrace: {} spans captured ({serve_spans} dispatcher-phase, {} dropped on ring overflow)",
        spans.len(),
        winoconv::trace::dropped(),
    );
    assert_eq!(
        snap.arena_grows, 0,
        "steady-state serving must not allocate with tracing enabled"
    );
    assert_eq!(
        snap.arena_fallbacks, 0,
        "the dispatcher's dedicated arenas must never hit the fallback path"
    );
    println!("\n# Prometheus exposition (scrape target output)");
    print!("{}", snap.prometheus());
    println!("\ndone — record these numbers in EXPERIMENTS.md E4");
    Ok(())
}
