"""AOT lowering: JAX/Pallas computations → HLO *text* artifacts for the Rust
PJRT runtime.

HLO text, NOT serialized HloModuleProto: jax ≥ 0.5 emits protos with 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md). Every entry is
lowered with ``return_tuple=True`` so the Rust side unpacks one tuple.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, example_args):
    """Lower a jitted function to HLO text via StableHLO.

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big constant arrays as ``constant({...})`` and xla_extension
    0.5.1's text parser silently reads those as zeros — the transform
    matrices would vanish from the artifact.
    """
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions.short_parsable()
    opts.print_large_constants = True
    return comp.as_hlo_module().to_string(opts)


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def conv_entry(variant_name, n, h, w, c, m, kh, kw, ph, pw):
    """A single Winograd conv layer artifact: inputs (x, weights)."""

    def fn(x, wt):
        return (model.winograd_conv2d(x, wt, variant_name, (ph, pw)),)

    return fn, (spec(n, h, w, c), spec(m, kh, kw, c))


#: name → (fn, example_args). Shapes are small on purpose: these artifacts
#: exist for cross-validation (examples/pjrt_verify.rs), not throughput.
ENTRIES = {
    "conv_f2x2_3x3": conv_entry("f2x2_3x3", 1, 16, 16, 8, 16, 3, 3, 1, 1),
    "conv_f4x4_3x3": conv_entry("f4x4_3x3", 1, 24, 24, 16, 32, 3, 3, 1, 1),
    "conv_f2x2_5x5": conv_entry("f2x2_5x5", 1, 12, 12, 8, 8, 5, 5, 2, 2),
    "conv_f2_1x7": conv_entry("f2_1x7", 1, 8, 32, 8, 16, 1, 7, 0, 3),
    "mini_cnn": (
        model.mini_cnn,
        (spec(1, 16, 16, 4), spec(8, 3, 3, 4), spec(8, 3, 3, 8), spec(8, 10)),
    ),
}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", help="build a single entry by name")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    names = [args.only] if args.only else list(ENTRIES)
    for name in names:
        fn, example_args = ENTRIES[name]
        text = to_hlo_text(fn, example_args)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
