"""Pure-jnp correctness oracles for the L1 kernels and the L2 model.

No Pallas anywhere in this file — every result here is computed by plain
XLA ops (``lax.conv_general_dilated`` or explicit einsums) and is the ground
truth the pytest/hypothesis suite holds the kernels to.
"""

import jax
import jax.numpy as jnp
from jax import lax


def direct_conv2d(x, w, stride=(1, 1), pad=(0, 0)):
    """Direct NHWC convolution oracle.

    Args:
      x: ``[N, H, W, C]`` input.
      w: ``[M, KH, KW, C]`` filters (the engine's canonical layout).
      stride: ``(sh, sw)``.
      pad: symmetric ``(ph, pw)`` zero padding.

    Returns:
      ``[N, OH, OW, M]``.
    """
    # lax expects HWIO filter layout for NHWC.
    w_hwio = jnp.transpose(w, (1, 2, 3, 0))
    return lax.conv_general_dilated(
        x,
        w_hwio,
        window_strides=stride,
        padding=((pad[0], pad[0]), (pad[1], pad[1])),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def winograd_stage_reference(tiles, kb, u, ka):
    """Pure-jnp reference of the three Winograd stages over flattened tiles.

    Args:
      tiles: ``[R, t², C]``.
      kb: ``[t², t²]`` input transform.
      u: ``[t², C, M]`` transformed weights.
      ka: ``[m², t²]`` output transform.

    Returns:
      ``[R, m², M]`` output tiles — what the three Pallas kernels chained
      together must reproduce.
    """
    v = jnp.einsum("ts,rsc->trc", kb, tiles)  # input transform + scatter
    y = jnp.einsum("trc,tcm->trm", v, u)  # batched GEMM
    return jnp.einsum("pt,trm->rpm", ka, y)  # gather + output transform


def extract_tiles(x_padded, th, tw, mh, mw, tiles_h, tiles_w):
    """Slice overlapping ``th×tw`` regions on the ``mh×mw`` output grid.

    Returns ``[N·tiles_h·tiles_w, th·tw, C]`` flattened tiles. Shared by the
    reference and the real model (tile extraction is data movement, not the
    compute hot-spot the Pallas kernels own).
    """
    n, _, _, c = x_padded.shape

    def one(r):
        b = r // (tiles_h * tiles_w)
        rem = r % (tiles_h * tiles_w)
        ty, tx = rem // tiles_w, rem % tiles_w
        tile = lax.dynamic_slice(x_padded, (b, ty * mh, tx * mw, 0), (1, th, tw, c))
        return tile.reshape(th * tw, c)

    r_total = n * tiles_h * tiles_w
    return jax.vmap(one)(jnp.arange(r_total))
