"""L1 Pallas kernels for the region-wise multi-channel Winograd pipeline.

Three kernels, one per pipeline stage (the paper's §2 steps):

1. :func:`input_transform`  — scatter: tiles ``[R, t², C]`` → Winograd-domain
   A-matrices ``[t², R, C]`` (``V = KB @ d`` per region).
2. :func:`batched_gemm`     — the ``t²`` GEMMs ``[R×C]·[C×M]``.
3. :func:`output_transform` — gather: ``[t², R, M]`` → spatial output tiles
   ``[R, m², M]`` (``y = KA @ prod`` per region).

TPU adaptation (DESIGN.md §Hardware-Adaptation): tiles are flattened so each
stage is a *single matmul per grid step* — the transform matrices hit the
MXU instead of being scalar add/sub chains, channels stay innermost (lane
dimension), and the region axis is the grid. ``interpret=True`` everywhere:
the CPU PJRT plugin cannot execute Mosaic custom-calls, and correctness (not
wallclock) is what the L1 layer asserts; VMEM/MXU characteristics are
estimated statically in DESIGN.md.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def input_transform(tiles, kb, *, block_r=64):
    """``V[t, r, c] = Σ_s KB[t, s] · tiles[r, s, c]`` via Pallas.

    Args:
      tiles: ``[R, t², C]`` flattened input regions.
      kb: ``[t², t²]`` Kronecker input-transform matrix (constant).
      block_r: regions per grid step.

    Returns:
      ``[t², R, C]`` — the stacked GEMM A-matrices (scatter layout: writing
      the transposed layout here is exactly the paper's scatter step).
    """
    r_total, t2, c = tiles.shape
    kb = jnp.asarray(kb, dtype=tiles.dtype)
    assert kb.shape == (t2, t2), f"KB {kb.shape} vs t²={t2}"
    block_r = min(block_r, r_total)

    def kernel(kb_ref, t_ref, o_ref):
        d = t_ref[...]  # [block_r, t2, C]
        # One MXU-shaped contraction per grid step.
        v = jnp.einsum("ts,rsc->trc", kb_ref[...], d, preferred_element_type=jnp.float32)
        o_ref[...] = v.astype(o_ref.dtype)

    grid = (pl.cdiv(r_total, block_r),)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t2, t2), lambda r: (0, 0)),
            pl.BlockSpec((block_r, t2, c), lambda r: (r, 0, 0)),
        ],
        out_specs=pl.BlockSpec((t2, block_r, c), lambda r: (0, r, 0)),
        out_shape=jax.ShapeDtypeStruct((t2, r_total, c), tiles.dtype),
        interpret=True,
    )(kb, tiles)


def batched_gemm(v, u, *, block_r=128):
    """``Y[t] = V[t] @ U[t]`` for every tile position ``t`` via Pallas.

    Args:
      v: ``[t², R, C]`` transformed input matrices.
      u: ``[t², C, M]`` transformed weight matrices.
      block_r: rows of V per grid step.

    Returns:
      ``[t², R, M]``.
    """
    t2, r_total, c = v.shape
    t2u, cu, m = u.shape
    assert (t2u, cu) == (t2, c), f"V {v.shape} vs U {u.shape}"
    block_r = min(block_r, r_total)

    def kernel(v_ref, u_ref, o_ref):
        o_ref[...] = jnp.einsum(
            "trc,tcm->trm",
            v_ref[...],
            u_ref[...],
            preferred_element_type=jnp.float32,
        ).astype(o_ref.dtype)

    grid = (t2, pl.cdiv(r_total, block_r))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_r, c), lambda t, r: (t, r, 0)),
            pl.BlockSpec((1, c, m), lambda t, r: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_r, m), lambda t, r: (t, r, 0)),
        out_shape=jax.ShapeDtypeStruct((t2, r_total, m), v.dtype),
        interpret=True,
    )(v, u)


def output_transform(y, ka, *, block_r=64):
    """``out[r] = KA @ Y[:, r, :]`` via Pallas (the gather step).

    Args:
      y: ``[t², R, M]`` GEMM outputs in the Winograd domain.
      ka: ``[m², t²]`` Kronecker output-transform matrix.
      block_r: regions per grid step.

    Returns:
      ``[R, m², M]`` spatial output tiles.
    """
    t2, r_total, m = y.shape
    ka = jnp.asarray(ka, dtype=y.dtype)
    assert ka.shape[1] == t2, f"KA {ka.shape} vs t²={t2}"
    m2 = ka.shape[0]
    block_r = min(block_r, r_total)

    def kernel(ka_ref, y_ref, o_ref):
        t = y_ref[...]  # [t2, block_r, M]
        out = jnp.einsum("pt,trm->rpm", ka_ref[...], t, preferred_element_type=jnp.float32)
        o_ref[...] = out.astype(o_ref.dtype)

    grid = (pl.cdiv(r_total, block_r),)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m2, t2), lambda r: (0, 0)),
            pl.BlockSpec((t2, block_r, m), lambda r: (0, r, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, m2, m), lambda r: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((r_total, m2, m), y.dtype),
        interpret=True,
    )(ka, y)


def weight_transform(w_flat, kg):
    """``U[t², C, M] = KG @ g`` — once per layer, plain XLA (off the request
    path, like the Rust engine's prepare step).

    Args:
      w_flat: ``[r², C, M]`` filter taps (flattened spatially, row-major).
      kg: ``[t², r²]`` Kronecker filter-transform matrix.
    """
    r2, c, m = w_flat.shape
    kg = jnp.asarray(kg, dtype=w_flat.dtype)
    assert kg.shape[1] == r2, f"KG {kg.shape} vs r²={r2}"
    return jnp.einsum("ts,scm->tcm", kg, w_flat)
