"""L2: the JAX compute graph — region-wise Winograd convolution built from
the L1 Pallas kernels, plus a small CNN used by the end-to-end artifact.

Everything here is build-time: ``aot.py`` lowers these functions to HLO text
once, and the Rust engine executes the artifacts via PJRT with Python out of
the loop.
"""

import jax
import jax.numpy as jnp

from .kernels import ref, winograd as wk
from .transforms import VARIANTS


def winograd_conv2d(x, w, variant_name, pad=(0, 0)):
    """Region-wise multi-channel Winograd convolution (stride 1).

    Args:
      x: ``[N, H, W, C]`` NHWC input.
      w: ``[M, KH, KW, C]`` filters.
      variant_name: key into :data:`~compile.transforms.VARIANTS`.
      pad: symmetric ``(ph, pw)`` padding.

    Returns:
      ``[N, OH, OW, M]``.
    """
    v = VARIANTS[variant_name]
    kb, kg, ka = v.kron_matrices()
    (mh, mw), (rh, rw) = v.out_tile, v.kernel
    th, tw = v.in_tile

    n, h, w_in, c = x.shape
    m, kh, kw, wc = w.shape
    assert (kh, kw) == (rh, rw), f"filter {kh}x{kw} vs variant {v.name}"
    assert wc == c, f"channels {wc} vs {c}"
    ph, pw = pad
    oh, ow = h + 2 * ph - rh + 1, w_in + 2 * pw - rw + 1
    tiles_h, tiles_w = -(-oh // mh), -(-ow // mw)

    # Pad so the tile grid is fully in-bounds.
    need_h = tiles_h * mh + th - mh
    need_w = tiles_w * mw + tw - mw
    x_p = jnp.pad(
        x, ((0, 0), (ph, need_h - h - ph), (pw, need_w - w_in - pw), (0, 0))
    )

    # Stage 0 (data movement): overlapping tiles, flattened row-major.
    tiles = ref.extract_tiles(x_p, th, tw, mh, mw, tiles_h, tiles_w)

    # Filter transform (prepare step): [M,KH,KW,C] → [r², C, M] → U [t²,C,M].
    w_flat = jnp.transpose(w.reshape(m, rh * rw, c), (1, 2, 0))
    u = wk.weight_transform(w_flat, kg)

    # Stages 1–3 (the Pallas hot path).
    v_mat = wk.input_transform(tiles, kb)
    y_mat = wk.batched_gemm(v_mat, u)
    out_tiles = wk.output_transform(y_mat, ka)  # [R, m², M]

    # Reassemble and clip ragged edges.
    out = out_tiles.reshape(n, tiles_h, tiles_w, mh, mw, m)
    out = jnp.transpose(out, (0, 1, 3, 2, 4, 5)).reshape(
        n, tiles_h * mh, tiles_w * mw, m
    )
    return out[:, :oh, :ow, :]


def conv_layer(x, w, pad=(0, 0), variant_name=None):
    """A conv layer that routes through Winograd when a variant is given,
    else through the XLA direct conv (the selector lives in Rust; here the
    caller picks explicitly at build time)."""
    if variant_name is None:
        return ref.direct_conv2d(x, w, (1, 1), pad)
    return winograd_conv2d(x, w, variant_name, pad)


def mini_cnn(x, w1, w2, wfc):
    """The end-to-end artifact model: two Winograd 3×3 conv layers + ReLU,
    global average pool, and a linear classifier.

    Args:
      x: ``[N, 16, 16, C1]`` input.
      w1: ``[C2, 3, 3, C1]`` first conv filters.
      w2: ``[C3, 3, 3, C2]`` second conv filters.
      wfc: ``[C3, K]`` classifier weights.

    Returns:
      ``(logits [N, K],)``.
    """
    h = conv_layer(x, w1, pad=(1, 1), variant_name="f4x4_3x3")
    h = jax.nn.relu(h)
    h = conv_layer(h, w2, pad=(1, 1), variant_name="f2x2_3x3")
    h = jax.nn.relu(h)
    h = jnp.mean(h, axis=(1, 2))  # global average pool
    return (h @ wfc,)
