"""Cook-Toom / Winograd transform-matrix construction (exact rationals).

Mirror of ``rust/src/winograd/cook_toom.rs`` — same interpolation points,
same construction, so the L1 Pallas kernels and the L3 Rust engine compute
with *identical* matrices. Derivation and the correctness identity are
documented in the Rust module; here we keep the construction and the exact
identity check used by the pytest suite.
"""

from dataclasses import dataclass
from fractions import Fraction

import numpy as np

#: Canonical interpolation points (small values + reciprocal pairs keep both
#: matrix magnitudes and fp error growth low). Must match the Rust sequence.
DEFAULT_POINTS = [
    Fraction(0), Fraction(1), Fraction(-1), Fraction(2), Fraction(-2),
    Fraction(1, 2), Fraction(-1, 2), Fraction(3), Fraction(-3),
    Fraction(1, 3), Fraction(-1, 3), Fraction(4), Fraction(-4),
]


def _poly_from_roots(roots):
    """Coefficients (ascending powers) of prod(x - r) over exact Fractions."""
    coeffs = [Fraction(1)]
    for root in roots:
        nxt = [Fraction(0)] * (len(coeffs) + 1)
        for p, c in enumerate(coeffs):
            nxt[p + 1] += c
            nxt[p] -= c * root
        coeffs = nxt
    return coeffs


def cook_toom_exact(m, r, points=None):
    """Exact (Fraction) matrices ``(BT (n,n), G (n,r), AT (m,n))`` for F(m,r).

    ``n = m + r - 1`` multiplications; ``points`` are the n-1 finite
    interpolation points (∞ is implicit).
    """
    n = m + r - 1
    pts = list(DEFAULT_POINTS[: n - 1] if points is None else points)
    assert len(pts) == n - 1, f"need {n - 1} points"
    assert len(set(pts)) == len(pts), "points must be distinct"

    # AT (m×n): Vandermonde columns + ∞ column e_{m-1}.
    at = [[Fraction(0)] * n for _ in range(m)]
    for k, a in enumerate(pts):
        p = Fraction(1)
        for i in range(m):
            at[i][k] = p
            p *= a
    at[m - 1][n - 1] = Fraction(1)

    # G (n×r): scaled Vandermonde rows + ∞ row e_{r-1}.
    g = [[Fraction(0)] * r for _ in range(n)]
    for i, a in enumerate(pts):
        norm = Fraction(1)
        for k, b in enumerate(pts):
            if k != i:
                norm *= a - b
        p = Fraction(1)
        for j in range(r):
            g[i][j] = p / norm
            p *= a
    g[n - 1][r - 1] = Fraction(1)

    # BT (n×n): rows are coefficients of N_i(x) = prod_{k≠i}(x − α_k);
    # last row: coefficients of M(x) = prod_k (x − α_k).
    bt = [[Fraction(0)] * n for _ in range(n)]
    for i in range(n - 1):
        omit = [a for k, a in enumerate(pts) if k != i]
        for l, c in enumerate(_poly_from_roots(omit)):
            bt[i][l] = c
    for l, c in enumerate(_poly_from_roots(pts)):
        bt[n - 1][l] = c

    return bt, g, at


def verify_identity_exact(bt, g, at):
    """Exactly check Σ_k AT[i][k]·G[k][j]·BT[k][l] == δ(l == i+j)."""
    m, n = len(at), len(at[0])
    r = len(g[0])
    for i in range(m):
        for j in range(r):
            for l in range(n):
                s = sum(at[i][k] * g[k][j] * bt[k][l] for k in range(n))
                if s != (1 if l == i + j else 0):
                    return False
    return True


def cook_toom(m, r, points=None, dtype=np.float32):
    """float matrices ``(BT, G, AT)`` for F(m, r)."""
    bt, g, at = cook_toom_exact(m, r, points)
    to_np = lambda rows: np.array([[float(v) for v in row] for row in rows], dtype=dtype)
    return to_np(bt), to_np(g), to_np(at)


@dataclass(frozen=True)
class Variant:
    """A 2-D (or 1-D via identity axis) Winograd variant, mirroring
    ``rust/src/winograd/mod.rs::WinogradVariant``."""

    name: str
    out_tile: tuple  # (mh, mw)
    kernel: tuple  # (rh, rw)

    @property
    def in_tile(self):
        return (
            self.out_tile[0] + self.kernel[0] - 1,
            self.out_tile[1] + self.kernel[1] - 1,
        )

    def axis_matrices(self, axis):
        """(BT, G, AT) for one axis; identity when the filter is flat there."""
        m = self.out_tile[axis]
        r = self.kernel[axis]
        if r == 1:
            eye = np.ones((1, 1), dtype=np.float32)
            return eye, eye, eye
        return cook_toom(m, r)

    def kron_matrices(self):
        """2-D transforms as Kronecker products, flattening tiles row-major:

        * ``KB (t²×t²)``  — input transform:  ``V = KB @ d_flat``
        * ``KG (t²×r²)``  — filter transform: ``U = KG @ g_flat``
        * ``KA (m²×t²)``  — output transform: ``y = KA @ prod_flat``

        (kron because ``vec(L·X·Rᵀ) = (L ⊗ R)·vec(X)`` for row-major vec.)
        """
        bt_h, g_h, at_h = self.axis_matrices(0)
        bt_w, g_w, at_w = self.axis_matrices(1)
        return (
            np.kron(bt_h, bt_w).astype(np.float32),
            np.kron(g_h, g_w).astype(np.float32),
            np.kron(at_h, at_w).astype(np.float32),
        )


#: The shipped variants (same registry as the Rust engine).
VARIANTS = {
    "f2x2_3x3": Variant("f2x2_3x3", (2, 2), (3, 3)),
    "f4x4_3x3": Variant("f4x4_3x3", (4, 4), (3, 3)),
    "f6x6_3x3": Variant("f6x6_3x3", (6, 6), (3, 3)),
    "f2x2_5x5": Variant("f2x2_5x5", (2, 2), (5, 5)),
    "f4x4_5x5": Variant("f4x4_5x5", (4, 4), (5, 5)),
    "f2_1x7": Variant("f2_1x7", (1, 2), (1, 7)),
    "f2_7x1": Variant("f2_7x1", (2, 1), (7, 1)),
    "f4_1x3": Variant("f4_1x3", (1, 4), (1, 3)),
    "f4_3x1": Variant("f4_3x1", (4, 1), (3, 1)),
}
