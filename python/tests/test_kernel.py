"""L1 Pallas kernels vs the pure-jnp oracle — the core correctness signal.

Hypothesis sweeps randomise shapes, channel counts and variants so edge
cases (ragged tiles, C=1, single-region inputs) are exercised, not just the
happy path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref, winograd as wk
from compile.transforms import VARIANTS


def rand(shape, seed):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape), dtype=jnp.float32)


# ---------------------------------------------------------------- stage level


@pytest.mark.parametrize("name", ["f2x2_3x3", "f4x4_3x3", "f2x2_5x5", "f2_1x7"])
def test_stages_match_reference(name):
    v = VARIANTS[name]
    kb, kg, ka = v.kron_matrices()
    t2 = v.in_tile[0] * v.in_tile[1]
    r_total, c, m = 10, 6, 9
    tiles = rand((r_total, t2, c), 1)
    u = rand((t2, c, m), 2)

    want = ref.winograd_stage_reference(tiles, kb, u, ka)
    v_mat = wk.input_transform(tiles, kb)
    y_mat = wk.batched_gemm(v_mat, u)
    got = wk.output_transform(y_mat, ka)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_input_transform_scatter_layout():
    """The kernel's output layout IS the scatter: [t², R, C]."""
    v = VARIANTS["f2x2_3x3"]
    kb, _, _ = v.kron_matrices()
    tiles = rand((5, 16, 3), 3)
    out = wk.input_transform(tiles, kb)
    assert out.shape == (16, 5, 3)
    want = jnp.einsum("ts,rsc->trc", jnp.asarray(kb), tiles)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5)


def test_batched_gemm_blocks_partition_r():
    """block_r smaller than R still covers every region exactly once."""
    v_mat = rand((4, 37, 5), 4)
    u = rand((4, 5, 6), 5)
    got = wk.batched_gemm(v_mat, u, block_r=8)
    want = jnp.einsum("trc,tcm->trm", v_mat, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


# ----------------------------------------------------------------- conv level


@pytest.mark.parametrize(
    "name,h,w,c,m,pad",
    [
        ("f2x2_3x3", 8, 8, 4, 8, (1, 1)),
        ("f2x2_3x3", 7, 9, 3, 5, (0, 0)),
        ("f4x4_3x3", 12, 12, 8, 16, (1, 1)),
        ("f4x4_3x3", 9, 10, 3, 5, (1, 1)),  # ragged tiles
        ("f6x6_3x3", 14, 14, 4, 4, (1, 1)),
        ("f2x2_5x5", 12, 12, 4, 6, (2, 2)),
        ("f4x4_5x5", 13, 13, 3, 4, (2, 2)),
        ("f2_1x7", 6, 17, 4, 6, (0, 3)),
        ("f2_7x1", 17, 6, 4, 6, (3, 0)),
        ("f4_1x3", 5, 15, 3, 4, (0, 1)),
        ("f4_3x1", 15, 5, 3, 4, (1, 0)),
    ],
)
def test_winograd_conv_matches_direct(name, h, w, c, m, pad):
    v = VARIANTS[name]
    x = rand((1, h, w, c), h * w)
    wt = rand((m, v.kernel[0], v.kernel[1], c), c * m)
    got = model.winograd_conv2d(x, wt, name, pad)
    want = ref.direct_conv2d(x, wt, (1, 1), pad)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


@settings(max_examples=25, deadline=None)
@given(
    name=st.sampled_from(["f2x2_3x3", "f4x4_3x3", "f2x2_5x5"]),
    h=st.integers(min_value=5, max_value=20),
    w=st.integers(min_value=5, max_value=20),
    c=st.integers(min_value=1, max_value=9),
    m=st.integers(min_value=1, max_value=9),
    padded=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_winograd_conv_hypothesis_2d(name, h, w, c, m, padded, seed):
    v = VARIANTS[name]
    rh, rw = v.kernel
    pad = (rh // 2, rw // 2) if padded else (0, 0)
    if h + 2 * pad[0] < rh or w + 2 * pad[1] < rw:
        return  # invalid geometry, skip
    x = rand((1, h, w, c), seed % 100000)
    wt = rand((m, rh, rw, c), (seed + 1) % 100000)
    got = model.winograd_conv2d(x, wt, name, pad)
    want = ref.direct_conv2d(x, wt, (1, 1), pad)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-3, atol=5e-3)


@settings(max_examples=15, deadline=None)
@given(
    name=st.sampled_from(["f2_1x7", "f2_7x1", "f4_1x3", "f4_3x1"]),
    span=st.integers(min_value=8, max_value=24),
    other=st.integers(min_value=1, max_value=6),
    c=st.integers(min_value=1, max_value=6),
    m=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_winograd_conv_hypothesis_1d(name, span, other, c, m, seed):
    v = VARIANTS[name]
    rh, rw = v.kernel
    h, w = (other, span) if rh == 1 else (span, other)
    x = rand((1, h, w, c), seed % 100000)
    wt = rand((m, rh, rw, c), (seed + 7) % 100000)
    got = model.winograd_conv2d(x, wt, name, (0, 0))
    want = ref.direct_conv2d(x, wt, (1, 1), (0, 0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-3, atol=5e-3)


def test_batch_dimension():
    x = rand((3, 8, 8, 4), 11)
    wt = rand((6, 3, 3, 4), 12)
    got = model.winograd_conv2d(x, wt, "f2x2_3x3", (1, 1))
    want = ref.direct_conv2d(x, wt, (1, 1), (1, 1))
    assert got.shape == (3, 8, 8, 6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_wrong_kernel_shape_asserts():
    x = rand((1, 8, 8, 4), 1)
    wt = rand((4, 5, 5, 4), 2)
    with pytest.raises(AssertionError):
        model.winograd_conv2d(x, wt, "f2x2_3x3", (0, 0))


# ----------------------------------------------------------------- model level


def test_mini_cnn_shapes_and_gradability():
    x = rand((2, 16, 16, 4), 1)
    w1 = rand((8, 3, 3, 4), 2) * 0.2
    w2 = rand((8, 3, 3, 8), 3) * 0.2
    wfc = rand((8, 10), 4) * 0.2
    (logits,) = model.mini_cnn(x, w1, w2, wfc)
    assert logits.shape == (2, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_mini_cnn_matches_direct_composition():
    x = rand((1, 16, 16, 4), 5)
    w1 = rand((8, 3, 3, 4), 6) * 0.2
    w2 = rand((8, 3, 3, 8), 7) * 0.2
    wfc = rand((8, 10), 8) * 0.2
    (got,) = model.mini_cnn(x, w1, w2, wfc)
    h = jax.nn.relu(ref.direct_conv2d(x, w1, (1, 1), (1, 1)))
    h = jax.nn.relu(ref.direct_conv2d(h, w2, (1, 1), (1, 1)))
    want = jnp.mean(h, axis=(1, 2)) @ wfc
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)
