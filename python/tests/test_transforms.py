"""Exact and numerical checks of the Cook-Toom matrix construction."""

import numpy as np
import pytest

from compile import transforms as T


ALL_FMR = [(2, 3), (4, 3), (6, 3), (2, 5), (4, 5), (2, 7), (4, 7)]


@pytest.mark.parametrize("m,r", ALL_FMR)
def test_identity_exact(m, r):
    """The minimal-filtering identity holds exactly over rationals."""
    bt, g, at = T.cook_toom_exact(m, r)
    assert T.verify_identity_exact(bt, g, at)


def test_identity_detects_corruption():
    bt, g, at = T.cook_toom_exact(2, 3)
    bt[0][0] += 1
    assert not T.verify_identity_exact(bt, g, at)


@pytest.mark.parametrize("m,r", ALL_FMR)
def test_1d_correlation_matches_direct(m, r):
    """y = AT[(G·g) ⊙ (BT·d)] equals the direct valid correlation."""
    rng = np.random.RandomState(m * 100 + r)
    bt, g_m, at = T.cook_toom(m, r, dtype=np.float64)
    n = m + r - 1
    g = rng.randn(r)
    d = rng.randn(n)
    y = at @ ((g_m @ g) * (bt @ d))
    want = np.array([np.dot(g, d[i : i + r]) for i in range(m)])
    np.testing.assert_allclose(y, want, rtol=1e-9, atol=1e-9)


def test_f4_3_matches_lavin_published_matrices():
    """With points (0, 1, −1, 2, −2) the construction reproduces Lavin's
    F(4,3) matrices exactly — pinning us to the literature."""
    bt, g, at = T.cook_toom(4, 3, dtype=np.float64)
    bt_lavin = np.array([
        [4, 0, -5, 0, 1, 0],
        [0, -4, -4, 1, 1, 0],
        [0, 4, -4, -1, 1, 0],
        [0, -2, -1, 2, 1, 0],
        [0, 2, -1, -2, 1, 0],
        [0, 4, 0, -5, 0, 1],
    ], dtype=np.float64)
    at_lavin = np.array([
        [1, 1, 1, 1, 1, 0],
        [0, 1, -1, 2, -2, 0],
        [0, 1, 1, 4, 4, 0],
        [0, 1, -1, 8, -8, 1],
    ], dtype=np.float64)
    np.testing.assert_allclose(bt, bt_lavin)
    np.testing.assert_allclose(at, at_lavin)
    np.testing.assert_allclose(g[0], [0.25, 0, 0])
    np.testing.assert_allclose(g[-1], [0, 0, 1])


@pytest.mark.parametrize("name", list(T.VARIANTS))
def test_variant_geometry(name):
    v = T.VARIANTS[name]
    th, tw = v.in_tile
    assert th == v.out_tile[0] + v.kernel[0] - 1
    assert tw == v.out_tile[1] + v.kernel[1] - 1
    kb, kg, ka = v.kron_matrices()
    assert kb.shape == (th * tw, th * tw)
    assert kg.shape == (th * tw, v.kernel[0] * v.kernel[1])
    assert ka.shape == (v.out_tile[0] * v.out_tile[1], th * tw)


def test_kron_equals_two_pass_transform():
    """(L ⊗ R) vec(X) == vec(L X Rᵀ) for the row-major flattening."""
    v = T.VARIANTS["f2x2_3x3"]
    bt_h, _, _ = v.axis_matrices(0)
    bt_w, _, _ = v.axis_matrices(1)
    kb, _, _ = v.kron_matrices()
    rng = np.random.RandomState(0)
    x = rng.randn(4, 4).astype(np.float32)
    two_pass = bt_h @ x @ bt_w.T
    np.testing.assert_allclose(kb @ x.reshape(-1), two_pass.reshape(-1), rtol=1e-5)


def test_duplicate_points_rejected():
    with pytest.raises(AssertionError):
        T.cook_toom_exact(2, 3, points=[0, 1, 1])
