//! A small hand-rolled Rust lexer for the `statcheck` passes.
//!
//! The offline build forbids `syn`, so the static-analysis passes work on a
//! flat token stream instead of a syntax tree. The lexer understands exactly
//! the constructs that would otherwise produce false positives on a text
//! search: line and (nested) block comments, string/raw-string/byte-string
//! and char literals, lifetimes vs chars (`'a` vs `'a'`), identifiers,
//! numbers, and single-character punctuation. Multi-character operators
//! (`::`, `->`, `..`) are emitted as runs of single `Punct` tokens; the
//! passes match on those runs.
//!
//! Every token carries the 1-based line it starts on, so findings can point
//! at `file:line`.

/// What a token is. Comments are kept in the stream — the unsafe-audit pass
/// reads them — and filtered out by [`super::parse::Parsed`] for the passes
/// that only want code.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `unsafe`, `Vec`, …).
    Ident,
    /// A single punctuation character.
    Punct,
    /// String literal, including raw and byte strings.
    Str,
    /// Char literal, e.g. `'x'` or `'\n'`.
    Char,
    /// Lifetime, e.g. `'a` or `'static`.
    Lifetime,
    /// Numeric literal (loosely lexed; good enough for pattern matching).
    Num,
    /// `// …` comment (doc comments included).
    LineComment,
    /// `/* … */` comment, possibly nested and multi-line.
    BlockComment,
}

/// One lexed token: kind, exact text, and the 1-based line it starts on.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

fn collect(cs: &[char]) -> String {
    cs.iter().collect()
}

fn count_newlines(cs: &[char]) -> usize {
    cs.iter().filter(|&&c| c == '\n').count()
}

/// Lex `src` into a token stream. Never fails: malformed input (unterminated
/// strings or comments) is absorbed into the current token to end-of-file.
pub fn lex(src: &str) -> Vec<Tok> {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // Line comment.
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let start = i;
            while i < n && cs[i] != '\n' {
                i += 1;
            }
            toks.push(Tok {
                kind: TokKind::LineComment,
                text: collect(&cs[start..i]),
                line,
            });
            continue;
        }
        // Block comment (nested).
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if cs[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            toks.push(Tok {
                kind: TokKind::BlockComment,
                text: collect(&cs[start..i]),
                line: start_line,
            });
            continue;
        }
        // Raw strings (`r"…"`, `r#"…"#`) and byte strings (`b"…"`, `br"…"`),
        // tried before identifier lexing; plain `r`/`b` idents fall through.
        if c == 'r' || c == 'b' {
            let mut j = i;
            if cs[j] == 'b' {
                j += 1;
            }
            if j < n && cs[j] == 'r' {
                let mut k = j + 1;
                let mut hashes = 0usize;
                while k < n && cs[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && cs[k] == '"' {
                    // Scan for `"` followed by `hashes` hash marks.
                    k += 1;
                    loop {
                        if k >= n {
                            break;
                        }
                        if cs[k] == '"' {
                            let mut h = 0usize;
                            while k + 1 + h < n && h < hashes && cs[k + 1 + h] == '#' {
                                h += 1;
                            }
                            if h == hashes {
                                k += 1 + hashes;
                                break;
                            }
                        }
                        k += 1;
                    }
                    let start_line = line;
                    line += count_newlines(&cs[i..k]);
                    toks.push(Tok {
                        kind: TokKind::Str,
                        text: collect(&cs[i..k]),
                        line: start_line,
                    });
                    i = k;
                    continue;
                }
            } else if c == 'b' && j < n && cs[j] == '"' {
                let mut k = j + 1;
                while k < n && cs[k] != '"' {
                    if cs[k] == '\\' {
                        k += 1;
                    }
                    k += 1;
                }
                if k < n {
                    k += 1;
                }
                let start_line = line;
                line += count_newlines(&cs[i..k]);
                toks.push(Tok {
                    kind: TokKind::Str,
                    text: collect(&cs[i..k]),
                    line: start_line,
                });
                i = k;
                continue;
            }
            // Not a string prefix: fall through to identifier lexing.
        }
        // String literal.
        if c == '"' {
            let mut j = i + 1;
            while j < n && cs[j] != '"' {
                if cs[j] == '\\' {
                    j += 1;
                }
                j += 1;
            }
            if j < n {
                j += 1;
            }
            let start_line = line;
            line += count_newlines(&cs[i..j]);
            toks.push(Tok {
                kind: TokKind::Str,
                text: collect(&cs[i..j]),
                line: start_line,
            });
            i = j;
            continue;
        }
        // Lifetime or char literal.
        if c == '\'' {
            if i + 1 < n && (cs[i + 1].is_alphabetic() || cs[i + 1] == '_') {
                let mut j = i + 1;
                while j < n && (cs[j].is_alphanumeric() || cs[j] == '_') {
                    j += 1;
                }
                if !(j < n && cs[j] == '\'') {
                    toks.push(Tok {
                        kind: TokKind::Lifetime,
                        text: collect(&cs[i..j]),
                        line,
                    });
                    i = j;
                    continue;
                }
            }
            let mut j = i + 1;
            if j < n && cs[j] == '\\' {
                j += 2;
                while j < n && cs[j] != '\'' {
                    j += 1;
                }
            } else if j < n {
                j += 1;
            }
            if j < n {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Char,
                text: collect(&cs[i..j]),
                line,
            });
            i = j;
            continue;
        }
        // Identifier / keyword.
        if c.is_alphabetic() || c == '_' {
            let mut j = i;
            while j < n && (cs[j].is_alphanumeric() || cs[j] == '_') {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text: collect(&cs[i..j]),
                line,
            });
            i = j;
            continue;
        }
        // Number (loose: `1_000`, `0.5f32`, `1e9`; a `.` followed by an
        // alphabetic char ends the token so `4.min(x)` lexes as a call).
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n {
                let d = cs[j];
                if d.is_alphanumeric() || d == '_' {
                    j += 1;
                    continue;
                }
                if d == '.' {
                    if j + 1 < n && (cs[j + 1].is_alphabetic() || cs[j + 1] == '_') {
                        break;
                    }
                    j += 1;
                    continue;
                }
                break;
            }
            toks.push(Tok {
                kind: TokKind::Num,
                text: collect(&cs[i..j]),
                line,
            });
            i = j;
            continue;
        }
        toks.push(Tok {
            kind: TokKind::Punct,
            text: c.to_string(),
            line,
        });
        i += 1;
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn comments_strings_and_chars_are_single_tokens() {
        let toks = kinds("let s = \"a // not a comment\"; // real\n/* block */ 'x' 'a");
        assert!(toks.contains(&(TokKind::Str, "\"a // not a comment\"".to_string())));
        assert!(toks.contains(&(TokKind::LineComment, "// real".to_string())));
        assert!(toks.contains(&(TokKind::BlockComment, "/* block */".to_string())));
        assert!(toks.contains(&(TokKind::Char, "'x'".to_string())));
        assert!(toks.contains(&(TokKind::Lifetime, "'a".to_string())));
    }

    #[test]
    fn nested_block_comments_and_escapes() {
        let toks = kinds("/* outer /* inner */ still */ x \"esc \\\" quote\" '\\n'");
        assert_eq!(toks[0].0, TokKind::BlockComment);
        assert_eq!(toks[1], (TokKind::Ident, "x".to_string()));
        assert_eq!(toks[2].0, TokKind::Str);
        assert_eq!(toks[3], (TokKind::Char, "'\\n'".to_string()));
    }

    #[test]
    fn raw_strings_swallow_their_contents() {
        let toks = kinds("r#\"unsafe { vec![] }\"# after");
        assert_eq!(toks[0].0, TokKind::Str);
        assert_eq!(toks[1], (TokKind::Ident, "after".to_string()));
        // `r`-named identifiers are not mistaken for raw strings.
        let toks = kinds("rows b r");
        assert!(toks.iter().all(|t| t.0 == TokKind::Ident));
    }

    #[test]
    fn line_numbers_track_multiline_tokens() {
        let toks = lex("a\n/* two\nlines */\nb");
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[1].line, 2); // comment starts on line 2
        assert_eq!(toks[2].line, 4); // `b` lands after the comment's newline
    }

    #[test]
    fn numbers_do_not_eat_method_calls() {
        let toks = kinds("4.min(x) 0.5 1_000");
        assert_eq!(toks[0], (TokKind::Num, "4".to_string()));
        assert_eq!(toks[1], (TokKind::Punct, ".".to_string()));
        assert_eq!(toks[2], (TokKind::Ident, "min".to_string()));
        assert!(toks.contains(&(TokKind::Num, "0.5".to_string())));
        assert!(toks.contains(&(TokKind::Num, "1_000".to_string())));
    }
}
