//! # `analysis` — the in-repo static-analysis passes behind `statcheck`
//!
//! The paper's latency wins live in exactly the code Rust cannot check for
//! us: hand-written NEON intrinsics, a transmute-based fork-join pool, and
//! arena-backed write-into kernels whose zero-steady-state-allocation claim
//! was previously enforced only dynamically (grow-count pins in `ci.sh`).
//! This module turns those structural invariants into a static CI gate.
//!
//! Five passes run over the whole tree (`rust/src`, `benches`, `examples`,
//! `tests`) and fail with `file:line` diagnostics:
//!
//! 1. [`unsafe_audit`] — every `unsafe` site carries a `// SAFETY:` comment.
//! 2. [`no_alloc`] — no allocation tokens in the registered hot paths.
//! 3. `simd-parity` ([`parity`]) — the portable and NEON backends export
//!    identical `pub fn` signature sets.
//! 4. `entry-parity` ([`parity`]) — every `*_into` op keeps its allocating
//!    twin and vice versa.
//! 5. [`targets`] — every bench/example is in `Cargo.toml`; every `--smoke`
//!    bench is exercised by `ci.sh`; `ci.sh` runs `statcheck`.
//!
//! The offline build forbids `syn`, so everything sits on the hand-rolled
//! [`lexer`] + [`parse`] layer: a flat token stream that understands
//! strings, comments, attributes and brace nesting — exactly enough syntax
//! to avoid false positives, no more.
//!
//! A finding is silenced by an inline waiver comment on the same line or
//! the line above: `// statcheck: allow(<pass>): why`. Waivers are counted
//! and printed by the binary so they cannot accumulate silently.

pub mod lexer;
pub mod no_alloc;
pub mod parity;
pub mod parse;
pub mod targets;
pub mod unsafe_audit;

use parse::{Parsed, SourceFile};
use std::fmt;
use std::fs;
use std::path::Path;

/// One diagnostic from one pass.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Which pass produced it (e.g. `unsafe-audit`).
    pub pass: &'static str,
    /// Repo-relative file path.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// Human-readable description of the violation.
    pub message: String,
}

impl Finding {
    /// Build a finding.
    pub fn new(pass: &'static str, file: &str, line: usize, message: impl Into<String>) -> Finding {
        Finding {
            pass,
            file: file.to_string(),
            line,
            message: message.into(),
        }
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.pass, self.message)
    }
}

/// Result of running all passes: real findings (CI-fatal), waived findings
/// (counted and printed), and the summary counters.
#[derive(Debug)]
pub struct Report {
    /// Unwaived findings, sorted by file then line. Nonempty fails CI.
    pub findings: Vec<Finding>,
    /// Findings silenced by an inline `statcheck: allow(...)` comment.
    pub waivers: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// Number of non-test `unsafe` tokens across the tree.
    pub unsafe_sites: usize,
}

/// Match `s` against a pattern containing at most one `*` wildcard.
pub fn glob_match(pat: &str, s: &str) -> bool {
    match pat.split_once('*') {
        None => pat == s,
        Some((pre, suf)) => {
            s.len() >= pre.len() + suf.len() && s.starts_with(pre) && s.ends_with(suf)
        }
    }
}

/// Whether `f` carries an inline waiver: `statcheck: allow(<pass>)` on the
/// finding's line or the line above.
fn waived(files: &[Parsed], f: &Finding) -> bool {
    let p = match files.iter().find(|p| p.file.path == f.file) {
        Some(p) => p,
        None => return false,
    };
    let tag = format!("statcheck: allow({})", f.pass);
    p.file.line_text(f.line).contains(&tag)
        || (f.line > 1 && p.file.line_text(f.line - 1).contains(&tag))
}

/// Run every pass over already-loaded sources plus the manifest and CI
/// script contents. Pure: the unit of testing for the whole gate.
pub fn run_passes(files: &[Parsed], cargo_toml: &str, ci_sh: &str) -> Report {
    let mut all: Vec<Finding> = Vec::new();
    for p in files {
        all.extend(unsafe_audit::run(p));
        all.extend(no_alloc::run(p));
    }
    all.extend(parity::run_simd(files));
    all.extend(parity::run_entry(files));
    all.extend(targets::run(files, cargo_toml, ci_sh));

    let mut findings = Vec::new();
    let mut waivers = Vec::new();
    for f in all {
        if waived(files, &f) {
            waivers.push(f);
        } else {
            findings.push(f);
        }
    }
    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    waivers.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Report {
        findings,
        waivers,
        files_scanned: files.len(),
        unsafe_sites: files.iter().map(unsafe_audit::unsafe_sites).sum(),
    }
}

/// Load every `.rs` file under the scanned roots, paths repo-relative with
/// forward slashes, sorted for deterministic output.
pub fn load_tree(root: &Path) -> std::io::Result<Vec<Parsed>> {
    let mut paths: Vec<String> = Vec::new();
    for dir in ["rust/src", "benches", "examples", "tests"] {
        collect_rs(root, &root.join(dir), &mut paths)?;
    }
    paths.sort();
    let mut out = Vec::new();
    for rel in paths {
        let text = fs::read_to_string(root.join(&rel))?;
        out.push(Parsed::new(SourceFile::new(&rel, &text)));
    }
    Ok(out)
}

fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<String>) -> std::io::Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path.strip_prefix(root).unwrap_or(&path);
            let rel: Vec<String> = rel
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect();
            out.push(rel.join("/"));
        }
    }
    Ok(())
}

/// Load the tree rooted at `root` and run every pass: what the `statcheck`
/// binary and the tree-wide integration test call.
pub fn run_all(root: &Path) -> std::io::Result<Report> {
    let files = load_tree(root)?;
    let cargo_toml = fs::read_to_string(root.join("Cargo.toml")).unwrap_or_default();
    let ci_sh = fs::read_to_string(root.join("ci.sh")).unwrap_or_default();
    Ok(run_passes(&files, &cargo_toml, &ci_sh))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glob_matches_exact_prefix_and_suffix() {
        assert!(glob_match("conv_rows", "conv_rows"));
        assert!(glob_match("*_fused_into", "run_fused_into"));
        assert!(glob_match("rust/src/*", "rust/src/simd/neon.rs"));
        assert!(glob_match("*", "anything"));
        assert!(!glob_match("*_fused_into", "run_fused"));
        assert!(!glob_match("conv_rows", "conv_rows2"));
    }

    #[test]
    fn findings_render_as_file_line_pass_message() {
        let f = Finding::new("no-alloc", "rust/src/x.rs", 7, "boom");
        assert_eq!(f.to_string(), "rust/src/x.rs:7: [no-alloc] boom");
    }

    #[test]
    fn waivers_are_separated_from_findings() {
        let src = "fn f(p: *const f32) -> f32 {\n    // statcheck: allow(unsafe-audit): fixture.\n    unsafe { *p }\n}\nfn g(p: *const f32) -> f32 {\n    unsafe { *p }\n}\n";
        let files = [Parsed::new(SourceFile::new("rust/src/fixture.rs", src))];
        let r = run_passes(&files, "", "statcheck");
        assert_eq!(r.waivers.len(), 1);
        assert_eq!(r.waivers[0].line, 3);
        assert_eq!(r.findings.len(), 1);
        assert_eq!(r.findings[0].line, 6);
        assert_eq!(r.unsafe_sites, 2);
    }

    #[test]
    fn report_counts_files_and_sites() {
        let files = [
            Parsed::new(SourceFile::new("rust/src/a.rs", "fn a() {}\n")),
            Parsed::new(SourceFile::new("rust/src/b.rs", "fn b() {}\n")),
        ];
        let r = run_passes(&files, "", "statcheck");
        assert_eq!(r.files_scanned, 2);
        assert_eq!(r.unsafe_sites, 0);
        assert!(r.findings.is_empty() && r.waivers.is_empty());
    }
}
