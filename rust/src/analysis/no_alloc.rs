//! Pass 2 — **hot-path no-alloc**: allocation tokens are forbidden inside
//! the registered hot modules and functions (outside `#[cfg(test)]`).
//!
//! This is the static dual of the dynamic grow-count-0 / fallback-count-0
//! pins in `ci.sh`: the arena pins prove a *particular benchmark run* did
//! not allocate; this pass proves the hot code *cannot* allocate, whatever
//! shapes it is fed. New hot paths opt in by adding themselves to
//! [`HOT_FILES`] or [`HOT_FNS`]; a deliberate allocation (e.g. the arena's
//! own counted grow path) carries an inline `statcheck: allow(no-alloc)`
//! waiver, which the binary counts and prints.

use super::lexer::TokKind;
use super::parse::Parsed;
use super::{glob_match, Finding};

/// Pass name, as used in diagnostics and `statcheck: allow(...)` waivers.
pub const PASS: &str = "no-alloc";

/// Files that are hot end to end: every non-test line is scanned.
const HOT_FILES: &[&str] = &[
    "rust/src/simd/portable.rs",
    "rust/src/simd/neon.rs",
    "rust/src/gemm/microkernel.rs",
    "rust/src/gemm/pack.rs",
    "rust/src/gemm/epilogue.rs",
];

/// `(file glob, fn glob)` pairs naming hot functions in otherwise-cold
/// files. Globs support a single `*`.
const HOT_FNS: &[(&str, &str)] = &[
    ("*", "*_fused_into"),
    ("*", "*_i8_into"),
    ("*", "*_batched_into"),
    ("*", "run_planned_into"),
    ("rust/src/conv/depthwise/mod.rs", "conv_rows"),
    ("rust/src/conv/pointwise/mod.rs", "gemm_rows"),
    ("rust/src/workspace.rs", "take"),
    ("rust/src/workspace.rs", "split2"),
    ("rust/src/workspace.rs", "ensure"),
    // The span sink's steady-state recording path: everything a traced
    // walk executes per span (the ring itself is pre-reserved).
    ("rust/src/trace/mod.rs", "now_ns"),
    ("rust/src/trace/mod.rs", "enabled"),
    ("rust/src/trace/mod.rs", "begin"),
    ("rust/src/trace/mod.rs", "end_stage"),
    ("rust/src/trace/mod.rs", "record"),
    ("rust/src/trace/mod.rs", "record_*"),
    ("rust/src/trace/mod.rs", "set_current_layer"),
    ("rust/src/trace/mod.rs", "pack_w0"),
];

/// `Type::method` allocating constructors.
const PATHS: &[(&str, &str)] = &[
    ("Vec", "new"),
    ("Vec", "with_capacity"),
    ("Box", "new"),
    ("String", "new"),
    ("String", "from"),
    ("Tensor", "zeros"),
];

/// Allocating (or arena-growing) method calls.
const METHODS: &[&str] = &[
    "to_vec",
    "collect",
    "clone",
    "to_string",
    "to_owned",
    "resize",
    "push",
    "reserve",
    "extend",
];

/// Allocating macros.
const MACROS: &[&str] = &["vec", "format"];

/// Findings for allocation tokens inside the file's hot spans.
pub fn run(p: &Parsed) -> Vec<Finding> {
    let spans = hot_spans(p);
    if spans.is_empty() {
        return Vec::new();
    }
    let mut out = Vec::new();
    for k in 0..p.code.len() {
        let line = p.ctok(k).line;
        if p.in_tests(line) {
            continue;
        }
        let ctx = match spans.iter().find(|s| line >= s.0 && line <= s.1) {
            Some(s) => s.2,
            None => continue,
        };
        if let Some(pat) = alloc_at(p, k) {
            out.push(Finding::new(
                PASS,
                &p.file.path,
                line,
                format!("allocation `{pat}` in hot path `{ctx}`"),
            ));
        }
    }
    out
}

/// The hot `(start line, end line, context name)` spans of this file.
fn hot_spans(p: &Parsed) -> Vec<(usize, usize, &str)> {
    let mut spans: Vec<(usize, usize, &str)> = Vec::new();
    if HOT_FILES.contains(&p.file.path.as_str()) {
        spans.push((1, usize::MAX, p.file.path.as_str()));
        return spans;
    }
    for f in &p.fns {
        if p.in_tests(f.line) {
            continue;
        }
        let hot = HOT_FNS
            .iter()
            .any(|(fg, ng)| glob_match(fg, &p.file.path) && glob_match(ng, &f.name));
        if hot {
            spans.push((f.line, f.end_line, f.name.as_str()));
        }
    }
    spans
}

/// Text of the code token at `j`, or `""` past the end.
fn txt(p: &Parsed, j: usize) -> &str {
    if j < p.code.len() {
        &p.ctok(j).text
    } else {
        ""
    }
}

/// If an allocation pattern starts at code-index `k`, its display name.
fn alloc_at(p: &Parsed, k: usize) -> Option<String> {
    let t = p.ctok(k);
    if t.kind == TokKind::Ident {
        if MACROS.contains(&t.text.as_str()) && txt(p, k + 1) == "!" {
            return Some(format!("{}!", t.text));
        }
        for (ty, m) in PATHS {
            if t.text == *ty
                && txt(p, k + 1) == ":"
                && txt(p, k + 2) == ":"
                && txt(p, k + 3) == *m
            {
                return Some(format!("{ty}::{m}"));
            }
        }
    }
    if t.kind == TokKind::Punct && t.text == "." {
        // `x..extend` puts an ident right after the range's second dot;
        // a method match needs this `.` to be alone on both sides.
        if k > 0 && p.ctok(k - 1).text == "." {
            return None;
        }
        let name = txt(p, k + 1);
        if METHODS.contains(&name) && txt(p, k + 2) != "." {
            return Some(format!(".{name}"));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::parse::SourceFile;

    fn findings(path: &str, src: &str) -> Vec<Finding> {
        run(&Parsed::new(SourceFile::new(path, src)))
    }

    #[test]
    fn cold_files_are_not_scanned() {
        let src = "pub fn f() -> Vec<f32> {\n    vec![0.0; 4]\n}\n";
        assert!(findings("rust/src/zoo/mod.rs", src).is_empty());
    }

    #[test]
    fn alloc_in_a_hot_file_is_flagged() {
        let src = "pub fn splat(x: f32) -> Vec<f32> {\n    let v = Vec::new();\n    v\n}\n";
        let f = findings("rust/src/simd/portable.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 2);
        assert!(f[0].message.contains("Vec::new"));
    }

    #[test]
    fn alloc_in_a_registered_hot_fn_is_flagged() {
        let src = "fn cold() -> String {\n    format!(\"ok\")\n}\npub fn run_fused_into(out: &mut [f32]) {\n    let label = format!(\"x\");\n    let _ = label;\n}\n";
        let f = findings("rust/src/some/file.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 5);
        assert!(f[0].message.contains("format!"));
        assert!(f[0].message.contains("run_fused_into"));
    }

    #[test]
    fn method_and_macro_tokens_are_caught() {
        let src = "pub fn pack(a: &[f32]) {\n    let v = a.to_vec();\n    let w = vec![0.0f32; 8];\n    let c = v.clone();\n    let _ = (w, c);\n}\n";
        let f = findings("rust/src/gemm/pack.rs", src);
        let pats: Vec<&str> = f.iter().map(|x| x.message.as_str()).collect();
        assert_eq!(f.len(), 3);
        assert!(pats[0].contains(".to_vec"));
        assert!(pats[1].contains("vec!"));
        assert!(pats[2].contains(".clone"));
    }

    #[test]
    fn test_modules_inside_hot_files_are_exempt() {
        let src = "pub fn id(x: f32) -> f32 {\n    x\n}\n#[cfg(test)]\nmod tests {\n    fn h() -> Vec<f32> {\n        vec![1.0]\n    }\n}\n";
        assert!(findings("rust/src/simd/portable.rs", src).is_empty());
    }

    #[test]
    fn range_syntax_is_not_an_alloc_method() {
        // `x..extend` puts the ident `extend` right after a dot; the
        // adjacent-dot guards keep ranges from matching as method calls.
        let src = "pub fn f(x: usize, extend: usize) -> usize {\n    (x..extend).len()\n}\n";
        assert!(findings("rust/src/gemm/pack.rs", src).is_empty());
    }
}
