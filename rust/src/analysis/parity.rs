//! Passes 3 and 4 — the parity invariants.
//!
//! **simd-parity**: `simd/portable.rs` and `simd/neon.rs` must export
//! identical sets of `pub fn` signatures (compared token-for-token with
//! `const` stripped, since the portable backend can be `const fn` where the
//! intrinsic one cannot). A backend gaining an op without its twin fails CI.
//!
//! **entry-parity**: every public `*_into` op must keep an allocating twin
//! (`X`, `X_with`, or a registered alias) in the same file, and — the other
//! direction — the registered write-into entry points must keep existing,
//! so an op cannot quietly lose its arena-backed variant.

use super::parse::Parsed;
use super::Finding;
use std::collections::HashSet;

/// SIMD backend-parity pass name.
pub const SIMD_PASS: &str = "simd-parity";

/// Entry-point parity pass name.
pub const ENTRY_PASS: &str = "entry-parity";

const PORTABLE: &str = "rust/src/simd/portable.rs";
const NEON: &str = "rust/src/simd/neon.rs";

/// `(file, into fn, allocating twin)` pairs for ops whose twin does not
/// follow the `X`/`X_with` naming rule.
const ALIASES: &[(&str, &str, &str)] = &[
    ("rust/src/nn/ops.rs", "add_into", "add_elementwise"),
    ("rust/src/nn/graph.rs", "run_planned_into", "run_with_workspace"),
];

/// The write-into entry points the engine guarantees: if the file exists,
/// the fn must too. This is the "vice versa" direction — deleting an
/// `*_into` variant (falling back to allocate-per-call) fails CI.
const REQUIRED_INTO: &[(&str, &str)] = &[
    ("rust/src/winograd/convolve.rs", "run_fused_into"),
    ("rust/src/winograd/convolve.rs", "run_fused_batched_into"),
    ("rust/src/im2row/mod.rs", "run_fused_into"),
    ("rust/src/im2row/mod.rs", "run_fused_batched_into"),
    ("rust/src/conv/depthwise/mod.rs", "run_fused_into"),
    ("rust/src/conv/depthwise/mod.rs", "run_fused_batched_into"),
    ("rust/src/conv/pointwise/mod.rs", "run_fused_into"),
    ("rust/src/conv/pointwise/mod.rs", "run_fused_batched_into"),
    ("rust/src/conv/pointwise/mod.rs", "run_residual_fused_into"),
    ("rust/src/conv/direct.rs", "direct_conv2d_into"),
    ("rust/src/conv/direct.rs", "direct_conv2d_grouped_into"),
    ("rust/src/conv/direct.rs", "direct_conv2d_grouped_batched_into"),
    ("rust/src/quant/im2row.rs", "run_fused_i8_into"),
    ("rust/src/quant/depthwise.rs", "run_fused_i8_into"),
    ("rust/src/quant/pointwise.rs", "run_fused_i8_into"),
    ("rust/src/nn/graph.rs", "run_planned_into"),
    ("rust/src/nn/graph.rs", "run_planned_batched_into"),
    ("rust/src/nn/ops.rs", "max_pool2d_into"),
    ("rust/src/nn/ops.rs", "avg_pool2d_into"),
    ("rust/src/nn/ops.rs", "global_avg_pool_into"),
    ("rust/src/nn/ops.rs", "relu6_into"),
    ("rust/src/nn/ops.rs", "add_into"),
    ("rust/src/nn/ops.rs", "fully_connected_into"),
    ("rust/src/nn/ops.rs", "softmax_into"),
    ("rust/src/nn/ops.rs", "lrn_across_channels_into"),
];

/// Findings for `pub fn` signature drift between the two SIMD backends.
pub fn run_simd(files: &[Parsed]) -> Vec<Finding> {
    let a = files.iter().find(|p| p.file.path == PORTABLE);
    let b = files.iter().find(|p| p.file.path == NEON);
    let (a, b) = match (a, b) {
        (Some(a), Some(b)) => (a, b),
        _ => return Vec::new(),
    };
    let mut out = Vec::new();
    missing_twins(a, b, &mut out);
    missing_twins(b, a, &mut out);
    out
}

fn missing_twins(from: &Parsed, to: &Parsed, out: &mut Vec<Finding>) {
    let there: HashSet<&str> = to
        .fns
        .iter()
        .filter(|f| f.is_pub && !to.in_tests(f.line))
        .map(|f| f.sig.as_str())
        .collect();
    for f in &from.fns {
        if !f.is_pub || from.in_tests(f.line) || there.contains(f.sig.as_str()) {
            continue;
        }
        out.push(Finding::new(
            SIMD_PASS,
            &from.file.path,
            f.line,
            format!("pub fn `{}` has no identical twin in `{}`", f.name, to.file.path),
        ));
    }
}

/// Findings for `*_into` ops missing allocating twins and for deleted
/// registered entry points.
pub fn run_entry(files: &[Parsed]) -> Vec<Finding> {
    let mut out = Vec::new();
    for p in files {
        if !p.file.path.starts_with("rust/src/") {
            continue;
        }
        let names: HashSet<&str> = p
            .fns
            .iter()
            .filter(|f| !p.in_tests(f.line))
            .map(|f| f.name.as_str())
            .collect();
        for f in &p.fns {
            if !f.is_pub || p.in_tests(f.line) {
                continue;
            }
            let base = match f.name.strip_suffix("_into") {
                Some(b) if !b.is_empty() => b,
                _ => continue,
            };
            let with = format!("{base}_with");
            let alias_ok = ALIASES.iter().any(|(file, into, twin)| {
                *file == p.file.path && *into == f.name && names.contains(twin)
            });
            if !names.contains(base) && !names.contains(with.as_str()) && !alias_ok {
                out.push(Finding::new(
                    ENTRY_PASS,
                    &p.file.path,
                    f.line,
                    format!(
                        "`{}` has no allocating twin (`{base}` / `{with}`) in this file",
                        f.name
                    ),
                ));
            }
        }
        for (file, into) in REQUIRED_INTO {
            if *file == p.file.path && !names.contains(into) {
                out.push(Finding::new(
                    ENTRY_PASS,
                    &p.file.path,
                    1,
                    format!("registered write-into entry point `{into}` no longer exists"),
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::parse::SourceFile;

    fn parsed(path: &str, src: &str) -> Parsed {
        Parsed::new(SourceFile::new(path, src))
    }

    #[test]
    fn identical_backends_pass() {
        let src = "pub fn add(a: f32, b: f32) -> f32 {\n    a + b\n}\n";
        let files = [parsed(PORTABLE, src), parsed(NEON, src)];
        assert!(run_simd(&files).is_empty());
    }

    #[test]
    fn one_sided_simd_fn_is_flagged_on_the_side_that_has_it() {
        let a = "pub fn add(a: f32, b: f32) -> f32 {\n    a + b\n}\npub fn min(a: f32, b: f32) -> f32 {\n    a.min(b)\n}\n";
        let b = "pub fn add(a: f32, b: f32) -> f32 {\n    a + b\n}\n";
        let files = [parsed(PORTABLE, a), parsed(NEON, b)];
        let f = run_simd(&files);
        assert_eq!(f.len(), 1);
        assert_eq!((f[0].file.as_str(), f[0].line), (PORTABLE, 4));
        assert!(f[0].message.contains("min"));
    }

    #[test]
    fn const_fn_matches_its_non_const_twin() {
        let a = "pub const fn zero() -> f32 {\n    0.0\n}\n";
        let b = "pub fn zero() -> f32 {\n    0.0\n}\n";
        let files = [parsed(PORTABLE, a), parsed(NEON, b)];
        assert!(run_simd(&files).is_empty());
    }

    #[test]
    fn orphaned_into_is_flagged() {
        let src = "pub fn relu_into(out: &mut [f32]) {\n    out[0] = 0.0;\n}\n";
        let files = [parsed("rust/src/nn/extra.rs", src)];
        let f = run_entry(&files);
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("relu_into"));
    }

    #[test]
    fn base_with_and_alias_twins_all_satisfy_parity() {
        let src = "pub fn relu(x: &[f32]) -> f32 {\n    x[0]\n}\npub fn relu_into(out: &mut [f32]) {\n    out[0] = 0.0;\n}\npub fn run_fused_with(w: usize) -> usize {\n    w\n}\npub fn run_fused_into(out: &mut [f32], w: usize) {\n    out[0] = w as f32;\n}\n";
        let files = [parsed("rust/src/nn/extra.rs", src)];
        assert!(run_entry(&files).is_empty());
    }

    #[test]
    fn deleting_a_registered_entry_point_is_flagged() {
        let src = "pub fn run_fused_with(w: usize) -> usize {\n    w\n}\n";
        let files = [parsed("rust/src/im2row/mod.rs", src)];
        let f = run_entry(&files);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 1);
        assert!(f[0].message.contains("run_fused_into"));
    }
}
