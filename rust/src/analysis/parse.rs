//! Structural view of one source file for the `statcheck` passes: the token
//! stream, the code-only token index, `#[cfg(test)]` line spans, and `fn`
//! item spans recovered by brace matching — the pieces of syntax the passes
//! need without a real parser.

use super::lexer::{lex, Tok, TokKind};
use std::collections::HashSet;

/// One source file: repo-relative path (forward slashes) plus contents.
#[derive(Debug, Clone)]
pub struct SourceFile {
    /// Repo-relative path, e.g. `rust/src/simd/neon.rs`.
    pub path: String,
    /// Full file text.
    pub text: String,
}

impl SourceFile {
    /// Build from a path and contents (used by tests to feed fixtures).
    pub fn new(path: &str, text: &str) -> SourceFile {
        SourceFile {
            path: path.to_string(),
            text: text.to_string(),
        }
    }

    /// Text of the 1-based line `ln` (empty for out-of-range lines).
    pub fn line_text(&self, ln: usize) -> &str {
        if ln == 0 {
            return "";
        }
        self.text.lines().nth(ln - 1).unwrap_or("")
    }
}

/// Span of one `fn` item.
#[derive(Debug, Clone)]
pub struct FnSpan {
    /// The function's name.
    pub name: String,
    /// 1-based line of the `fn` keyword.
    pub line: usize,
    /// 1-based line of the body's closing brace.
    pub end_line: usize,
    /// Whether a `pub` qualifier precedes it (`pub(crate)` counts).
    pub is_pub: bool,
    /// Normalized signature: the code tokens from `fn` to the body's `{`,
    /// joined with single spaces (visibility and `const` excluded) — the
    /// string the SIMD backend-parity pass compares.
    pub sig: String,
}

/// A lexed-and-scanned source file.
#[derive(Debug)]
pub struct Parsed {
    /// The underlying file.
    pub file: SourceFile,
    /// Full token stream, comments included.
    pub toks: Vec<Tok>,
    /// Indices into `toks` of the non-comment tokens.
    pub code: Vec<usize>,
    /// Lines covered by items annotated `#[cfg(test)]`.
    pub test_lines: HashSet<usize>,
    /// Every `fn` item found (test modules included; callers filter via
    /// [`Parsed::in_tests`]).
    pub fns: Vec<FnSpan>,
}

fn ct<'a>(toks: &'a [Tok], code: &[usize], k: usize) -> &'a Tok {
    &toks[code[k]]
}

impl Parsed {
    /// Lex and scan one file.
    pub fn new(file: SourceFile) -> Parsed {
        let toks = lex(&file.text);
        let code: Vec<usize> = (0..toks.len())
            .filter(|&i| {
                !matches!(toks[i].kind, TokKind::LineComment | TokKind::BlockComment)
            })
            .collect();
        let test_lines = cfg_test_lines(&toks, &code);
        let fns = fn_spans(&toks, &code);
        Parsed {
            file,
            toks,
            code,
            test_lines,
            fns,
        }
    }

    /// Whether the 1-based line falls inside a `#[cfg(test)]` item.
    pub fn in_tests(&self, line: usize) -> bool {
        self.test_lines.contains(&line)
    }

    /// The code token at code-index `k`.
    pub fn ctok(&self, k: usize) -> &Tok {
        ct(&self.toks, &self.code, k)
    }
}

/// Lines covered by `#[cfg(test)]`-annotated items, found by matching the
/// attribute's token run and then brace-matching the item that follows.
fn cfg_test_lines(toks: &[Tok], code: &[usize]) -> HashSet<usize> {
    let mut out = HashSet::new();
    let m = code.len();
    let mut i = 0usize;
    while i < m {
        if ct(toks, code, i).text == "#" && i + 1 < m && ct(toks, code, i + 1).text == "[" {
            // Collect the attribute's tokens up to the matching `]`.
            let mut depth = 0i32;
            let mut j = i + 1;
            let mut attr = String::new();
            while j < m {
                let t = &ct(toks, code, j).text;
                if t == "[" {
                    depth += 1;
                } else if t == "]" {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else {
                    attr.push_str(t);
                }
                j += 1;
            }
            if attr == "cfg(test)" {
                // Skip any further attributes, then brace-match the item.
                let mut k = j + 1;
                while k + 1 < m
                    && ct(toks, code, k).text == "#"
                    && ct(toks, code, k + 1).text == "["
                {
                    let mut d2 = 0i32;
                    k += 1;
                    while k < m {
                        let t = &ct(toks, code, k).text;
                        if t == "[" {
                            d2 += 1;
                        } else if t == "]" {
                            d2 -= 1;
                            if d2 == 0 {
                                break;
                            }
                        }
                        k += 1;
                    }
                    k += 1;
                }
                while k < m {
                    let t = &ct(toks, code, k).text;
                    if t == "{" || t == ";" {
                        break;
                    }
                    k += 1;
                }
                if k < m && ct(toks, code, k).text == "{" {
                    let mut d2 = 0i32;
                    while k < m {
                        let t = &ct(toks, code, k).text;
                        if t == "{" {
                            d2 += 1;
                        } else if t == "}" {
                            d2 -= 1;
                            if d2 == 0 {
                                break;
                            }
                        }
                        k += 1;
                    }
                    let end_line = ct(toks, code, k.min(m - 1)).line;
                    for ln in ct(toks, code, i).line..=end_line {
                        out.insert(ln);
                    }
                    i = k;
                }
            } else if j > i {
                i = j;
            }
        }
        i += 1;
    }
    out
}

/// All `fn` items, via brace matching. Semicolon-terminated declarations
/// (trait methods without bodies) are skipped.
fn fn_spans(toks: &[Tok], code: &[usize]) -> Vec<FnSpan> {
    let m = code.len();
    let mut out = Vec::new();
    for i in 0..m {
        let t = ct(toks, code, i);
        if t.kind != TokKind::Ident || t.text != "fn" {
            continue;
        }
        if i + 1 >= m || ct(toks, code, i + 1).kind != TokKind::Ident {
            continue;
        }
        let name = ct(toks, code, i + 1).text.clone();
        // Visibility: walk back over the item's qualifiers/attributes to
        // the previous item boundary.
        let mut is_pub = false;
        let mut b = i;
        let mut steps = 0usize;
        while b > 0 && steps < 16 {
            b -= 1;
            steps += 1;
            let t = &ct(toks, code, b).text;
            if t == ";" || t == "{" || t == "}" {
                break;
            }
            if t == "pub" {
                is_pub = true;
                break;
            }
        }
        // Find the body's opening brace; a `;` outside parens/brackets
        // means this is a bodyless declaration.
        let mut j = i;
        let mut paren = 0i32;
        let mut bracket = 0i32;
        let mut body = None;
        let mut sig = String::new();
        while j < m {
            let t = &ct(toks, code, j).text;
            if t == "(" {
                paren += 1;
            } else if t == ")" {
                paren -= 1;
            } else if t == "[" {
                bracket += 1;
            } else if t == "]" {
                bracket -= 1;
            } else if paren == 0 && bracket == 0 && t == ";" {
                break;
            } else if paren == 0 && bracket == 0 && t == "{" {
                body = Some(j);
                break;
            }
            if t != "const" {
                if !sig.is_empty() {
                    sig.push(' ');
                }
                sig.push_str(t);
            }
            j += 1;
        }
        let body = match body {
            Some(b) => b,
            None => continue,
        };
        let mut depth = 0i32;
        let mut k = body;
        while k < m {
            let t = &ct(toks, code, k).text;
            if t == "{" {
                depth += 1;
            } else if t == "}" {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            k += 1;
        }
        let end_line = ct(toks, code, k.min(m - 1)).line;
        out.push(FnSpan {
            name,
            line: t.line,
            end_line,
            is_pub,
            sig,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parsed(src: &str) -> Parsed {
        Parsed::new(SourceFile::new("fixture.rs", src))
    }

    #[test]
    fn fn_spans_cover_bodies_and_visibility() {
        let p = parsed(
            "pub fn a(x: usize) -> usize {\n    x\n}\nfn b() {\n    a(1);\n}\n\
             pub(crate) fn c() {}\n",
        );
        let names: Vec<_> = p.fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, ["a", "b", "c"]);
        assert!(p.fns[0].is_pub && !p.fns[1].is_pub && p.fns[2].is_pub);
        assert_eq!((p.fns[0].line, p.fns[0].end_line), (1, 3));
        assert_eq!((p.fns[1].line, p.fns[1].end_line), (4, 6));
    }

    #[test]
    fn array_return_types_do_not_end_the_signature() {
        let p = parsed("pub fn t(rows: [f32; 4]) -> [f32; 4] {\n    rows\n}\n");
        assert_eq!(p.fns.len(), 1);
        assert_eq!(p.fns[0].sig, "fn t ( rows : [ f32 ; 4 ] ) - > [ f32 ; 4 ]");
    }

    #[test]
    fn const_is_stripped_from_signatures() {
        let a = parsed("pub const fn zero() -> f32 {\n    0.0\n}\n");
        let b = parsed("pub fn zero() -> f32 {\n    1.0\n}\n");
        assert_eq!(a.fns[0].sig, b.fns[0].sig);
    }

    #[test]
    fn cfg_test_items_are_marked() {
        let p = parsed(
            "fn real() {}\n#[cfg(test)]\nmod tests {\n    fn helper() {}\n}\nfn after() {}\n",
        );
        assert!(!p.in_tests(1));
        assert!(p.in_tests(2) && p.in_tests(3) && p.in_tests(4) && p.in_tests(5));
        assert!(!p.in_tests(6));
        // The helper fn is found but sits on a test line.
        let helper = p.fns.iter().find(|f| f.name == "helper").unwrap();
        assert!(p.in_tests(helper.line));
    }

    #[test]
    fn bodyless_declarations_are_skipped() {
        let p = parsed("trait T {\n    fn decl(&self);\n    fn with_default(&self) {}\n}\n");
        let names: Vec<_> = p.fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, ["with_default"]);
    }
}
