//! Pass 5 — **target-registration**: the build graph must stay in sync with
//! the tree. Every `benches/*.rs` / `examples/*.rs` file must be declared in
//! `Cargo.toml` (PR 1's missing-manifest incident can never land again),
//! every bench that implements a `--smoke` mode must actually be invoked in
//! `ci.sh` with `--smoke`, and `ci.sh` must keep running `statcheck` itself.

use super::parse::Parsed;
use super::Finding;

/// Pass name, as used in diagnostics and `statcheck: allow(...)` waivers.
pub const PASS: &str = "targets";

/// A target declared in `Cargo.toml`.
#[derive(Debug, Clone)]
struct Target {
    kind: String,
    name: String,
    path: String,
}

/// Minimal line-oriented scan of the manifest: enough TOML to recover
/// `[[bench]]`/`[[example]]`/`[[test]]`/`[[bin]]` sections with their
/// `name`/`path` keys.
fn targets(cargo_toml: &str) -> Vec<Target> {
    let mut out: Vec<Target> = Vec::new();
    let mut current: Option<Target> = None;
    for raw in cargo_toml.lines() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.starts_with('[') {
            if let Some(t) = current.take() {
                out.push(t);
            }
            if let Some(kind) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
                current = Some(Target {
                    kind: kind.to_string(),
                    name: String::new(),
                    path: String::new(),
                });
            }
            continue;
        }
        if let (Some(t), Some((k, v))) = (current.as_mut(), line.split_once('=')) {
            let v = v.trim().trim_matches('"').to_string();
            match k.trim() {
                "name" => t.name = v,
                "path" => t.path = v,
                _ => {}
            }
        }
    }
    if let Some(t) = current.take() {
        out.push(t);
    }
    out
}

/// Findings for unregistered target files, un-exercised `--smoke` benches,
/// and a `ci.sh` that no longer runs `statcheck`.
pub fn run(files: &[Parsed], cargo_toml: &str, ci_sh: &str) -> Vec<Finding> {
    let decls = targets(cargo_toml);
    let mut out = Vec::new();
    for p in files {
        let kind = if p.file.path.starts_with("benches/") {
            "bench"
        } else if p.file.path.starts_with("examples/") {
            "example"
        } else {
            continue;
        };
        let decl = decls
            .iter()
            .find(|t| t.kind == kind && t.path == p.file.path);
        let decl = match decl {
            Some(d) => d,
            None => {
                out.push(Finding::new(
                    PASS,
                    &p.file.path,
                    1,
                    format!("{kind} file is not declared in Cargo.toml (missing [[{kind}]] entry)"),
                ));
                continue;
            }
        };
        if kind == "bench" && has_smoke_mode(p) && !ci_runs_smoke(ci_sh, &decl.name) {
            out.push(Finding::new(
                PASS,
                &p.file.path,
                1,
                format!(
                    "bench `{}` implements --smoke but ci.sh never runs `--bench {} -- --smoke`",
                    decl.name, decl.name
                ),
            ));
        }
    }
    if !ci_sh.contains("statcheck") {
        out.push(Finding::new(
            PASS,
            "ci.sh",
            1,
            "ci.sh no longer runs the statcheck gate",
        ));
    }
    out
}

/// A bench advertises a smoke mode by mentioning `"smoke"` in a string
/// literal (flag registration or `args.flag("smoke")`).
fn has_smoke_mode(p: &Parsed) -> bool {
    use super::lexer::TokKind;
    p.toks
        .iter()
        .any(|t| t.kind == TokKind::Str && t.text.contains("smoke"))
}

fn ci_runs_smoke(ci_sh: &str, bench: &str) -> bool {
    let flag = format!("--bench {bench}");
    ci_sh
        .lines()
        .any(|l| l.contains(&flag) && l.contains("--smoke"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::parse::SourceFile;

    const MANIFEST: &str = "[package]\nname = \"x\"\n\n[[bench]]\nname = \"fast\"\npath = \"benches/fast.rs\"\nharness = false\n\n[[example]]\nname = \"demo\"\npath = \"examples/demo.rs\"\n";
    const CI: &str = "cargo run --release --bin statcheck\ncargo bench --bench fast -- --smoke\n";

    fn parsed(path: &str, src: &str) -> Parsed {
        Parsed::new(SourceFile::new(path, src))
    }

    #[test]
    fn registered_targets_pass() {
        let files = [
            parsed("benches/fast.rs", "fn main() {\n    let _ = \"smoke\";\n}\n"),
            parsed("examples/demo.rs", "fn main() {}\n"),
        ];
        assert!(run(&files, MANIFEST, CI).is_empty());
    }

    #[test]
    fn unregistered_bench_is_flagged() {
        let files = [parsed("benches/rogue.rs", "fn main() {}\n")];
        let f = run(&files, MANIFEST, CI);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].file, "benches/rogue.rs");
        assert!(f[0].message.contains("[[bench]]"));
    }

    #[test]
    fn smoke_bench_missing_from_ci_is_flagged() {
        let files = [parsed("benches/fast.rs", "fn main() {\n    let _ = \"smoke\";\n}\n")];
        let f = run(&files, MANIFEST, "cargo run --release --bin statcheck\n");
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("--smoke"));
    }

    #[test]
    fn benches_without_smoke_modes_are_not_required_in_ci() {
        let files = [parsed("benches/fast.rs", "fn main() {}\n")];
        assert!(run(&files, MANIFEST, CI).is_empty());
    }

    #[test]
    fn ci_without_statcheck_is_flagged() {
        let f = run(&[], MANIFEST, "cargo test\n");
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].file, "ci.sh");
    }
}
