//! Pass 1 — **unsafe-audit**: every `unsafe` site (block, fn, `unsafe impl`)
//! must be immediately preceded by a `// SAFETY:` comment stating the
//! precondition it relies on.
//!
//! "Immediately preceded" means: walking the raw token stream backwards from
//! the `unsafe` keyword, a comment containing `SAFETY:` appears before the
//! previous statement boundary (`;`, `{`, or `}`). That window covers both
//! the plain form (comment directly above the keyword) and mid-statement
//! blocks like `let x: &[f32] = unsafe { … };` where the comment sits above
//! the whole `let`. The boundary rule also means two consecutive
//! `unsafe impl` items each need their own comment — one argument cannot
//! silently cover its neighbour.

use super::lexer::TokKind;
use super::parse::Parsed;
use super::Finding;

/// Pass name, as used in diagnostics and `statcheck: allow(...)` waivers.
pub const PASS: &str = "unsafe-audit";

/// Number of non-test `unsafe` tokens in the file (the count `statcheck`
/// prints in its summary line).
pub fn unsafe_sites(p: &Parsed) -> usize {
    (0..p.code.len()).filter(|&k| is_site(p, k)).count()
}

/// Findings for `unsafe` sites that lack a `// SAFETY:` comment.
pub fn run(p: &Parsed) -> Vec<Finding> {
    let mut out = Vec::new();
    for k in 0..p.code.len() {
        if !is_site(p, k) || documented(p, k) {
            continue;
        }
        out.push(Finding::new(
            PASS,
            &p.file.path,
            p.ctok(k).line,
            "`unsafe` without a preceding `// SAFETY:` comment stating its precondition",
        ));
    }
    out
}

fn is_site(p: &Parsed, k: usize) -> bool {
    let t = p.ctok(k);
    t.kind == TokKind::Ident && t.text == "unsafe" && !p.in_tests(t.line)
}

/// Walk the raw stream backwards from the `unsafe` token to the previous
/// statement boundary; any comment mentioning `SAFETY:` in that window
/// documents the site.
fn documented(p: &Parsed, k: usize) -> bool {
    let mut i = p.code[k];
    while i > 0 {
        i -= 1;
        let t = &p.toks[i];
        match t.kind {
            TokKind::LineComment | TokKind::BlockComment => {
                if t.text.contains("SAFETY:") {
                    return true;
                }
            }
            TokKind::Punct => {
                if t.text == ";" || t.text == "{" || t.text == "}" {
                    return false;
                }
            }
            _ => {}
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::parse::SourceFile;

    fn findings(src: &str) -> Vec<Finding> {
        run(&Parsed::new(SourceFile::new("fixture.rs", src)))
    }

    #[test]
    fn documented_block_passes() {
        let src = "fn f(p: *const f32) -> f32 {\n    // SAFETY: caller guarantees p is valid.\n    unsafe { *p }\n}\n";
        assert!(findings(src).is_empty());
    }

    #[test]
    fn undocumented_block_is_flagged_with_its_line() {
        let src = "fn f(p: *const f32) -> f32 {\n    unsafe { *p }\n}\n";
        let f = findings(src);
        assert_eq!(f.len(), 1);
        assert_eq!((f[0].file.as_str(), f[0].line), ("fixture.rs", 2));
        assert_eq!(f[0].pass, PASS);
    }

    #[test]
    fn comment_above_a_let_statement_covers_its_unsafe_block() {
        let src = "fn f(p: *const f32) -> f32 {\n    // SAFETY: p valid per the fn contract.\n    let v: f32 = unsafe { *p };\n    v\n}\n";
        assert!(findings(src).is_empty());
    }

    #[test]
    fn consecutive_unsafe_impls_each_need_a_comment() {
        let src = "struct X;\n// SAFETY: X owns no thread-affine state.\nunsafe impl Send for X {}\nunsafe impl Sync for X {}\n";
        let f = findings(src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn test_code_is_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(p: *const f32) -> f32 {\n        unsafe { *p }\n    }\n}\n";
        assert!(findings(src).is_empty());
    }

    #[test]
    fn the_word_unsafe_in_strings_and_comments_is_not_a_site() {
        let src = "// unsafe is discussed here\nfn f() -> &'static str {\n    \"unsafe\"\n}\n";
        assert!(findings(src).is_empty());
        assert_eq!(unsafe_sites(&Parsed::new(SourceFile::new("fixture.rs", src))), 0);
    }
}
