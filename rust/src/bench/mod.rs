//! Statistical benchmarking harness + the table printers that regenerate the
//! paper's artifacts (offline build: no `criterion`).
//!
//! Methodology: the paper measures PMU cycles on an isolated big cluster; on
//! a noisy host we (1) warm up until the code path is steady, (2) take many
//! wall-clock samples, (3) report the median / 5%-trimmed mean
//! ([`crate::util::stats::Summary`]), which are robust to scheduler spikes.

pub mod workloads;

use crate::util::stats::Summary;
use std::time::Instant;

/// Configuration of a measurement run.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Warm-up iterations (not recorded).
    pub warmup_iters: usize,
    /// Recorded samples.
    pub samples: usize,
    /// Lower bound on total measured time; samples are added until both
    /// `samples` and this budget are satisfied (cheap benchmarks take more
    /// samples, expensive ones stop at `samples`).
    pub min_time_ns: u64,
    /// Hard cap on samples regardless of the time budget.
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            samples: 10,
            min_time_ns: 200_000_000, // 0.2 s
            max_samples: 200,
        }
    }
}

impl BenchConfig {
    /// A faster profile for CI/tests.
    pub fn quick() -> Self {
        BenchConfig {
            warmup_iters: 1,
            samples: 3,
            min_time_ns: 10_000_000,
            max_samples: 20,
        }
    }

    /// Scale sample counts from the environment (`WINOCONV_BENCH_QUICK=1`).
    pub fn from_env() -> Self {
        if std::env::var("WINOCONV_BENCH_QUICK").map(|v| v == "1").unwrap_or(false) {
            BenchConfig::quick()
        } else {
            BenchConfig::default()
        }
    }
}

/// Measure a closure under `cfg`, returning robust summary statistics.
pub fn measure<F: FnMut()>(cfg: &BenchConfig, mut f: F) -> Summary {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.samples);
    let start = Instant::now();
    loop {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
        let enough_samples = samples.len() >= cfg.samples;
        let enough_time = start.elapsed().as_nanos() as u64 >= cfg.min_time_ns;
        if (enough_samples && enough_time) || samples.len() >= cfg.max_samples {
            break;
        }
    }
    Summary::from_samples(&samples)
}

/// A named measurement, for table assembly.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Row label.
    pub name: String,
    /// Timing summary.
    pub summary: Summary,
}

/// Simple fixed-width ASCII table printer used by every bench target so the
/// regenerated tables read like the paper's.
#[derive(Debug)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table row arity");
        self.rows.push(cells.to_vec());
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("{c:<w$} | "));
            }
            s.trim_end().to_string()
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a throughput in GFLOP/s given work and a summary.
pub fn gflops(flops: usize, s: &Summary) -> f64 {
    flops as f64 / s.median
}

/// One-line bench report helper.
pub fn report(name: &str, s: &Summary) {
    println!("{name:<48} {}", s.display_line());
}

/// Pretty milliseconds for table cells.
pub fn ms(ns: f64) -> String {
    format!("{:.2}", crate::util::stats::ns_to_ms(ns))
}

/// Pretty speedup factor.
pub fn speedup(baseline_ns: f64, ours_ns: f64) -> String {
    format!("{:.2}x", baseline_ns / ours_ns)
}

/// Re-export for bench binaries.
pub use crate::util::stats::fmt_ns as format_ns;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_samples() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            samples: 5,
            min_time_ns: 0,
            max_samples: 10,
        };
        let mut calls = 0usize;
        let s = measure(&cfg, || {
            calls += 1;
        });
        assert_eq!(s.n, 5);
        assert_eq!(calls, 6); // warmup + samples
    }

    #[test]
    fn max_samples_caps_cheap_benchmarks() {
        let cfg = BenchConfig {
            warmup_iters: 0,
            samples: 5,
            min_time_ns: u64::MAX,
            max_samples: 12,
        };
        let s = measure(&cfg, || {});
        assert_eq!(s.n, 12);
    }

    #[test]
    fn table_renders_all_rows() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["1".into(), "hello".into()]);
        t.row(&["22".into(), "x".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("hello"));
        assert!(s.matches('\n').count() >= 5);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn helpers_format() {
        assert_eq!(ms(1_500_000.0), "1.50");
        assert_eq!(speedup(200.0, 100.0), "2.00x");
        assert!(format_ns(1.0).contains("ns"));
    }
}
