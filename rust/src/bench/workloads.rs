//! Benchmark workloads: the conv layers of the five evaluated CNNs as
//! standalone specs (for the per-layer Table 2 benches) plus helpers shared
//! by the whole-network benches.

use crate::conv::select::is_winograd_suitable;
use crate::nn::{Graph, Op};
use crate::tensor::Tensor;
use crate::zoo::ModelKind;
use crate::Result;

/// One conv layer lifted out of a model, with its concrete input shape.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    /// Owning model.
    pub model: ModelKind,
    /// Layer name inside the model.
    pub name: String,
    /// NHWC input shape at batch 1.
    pub input_shape: Vec<usize>,
    /// Input channels.
    pub cin: usize,
    /// Output channels.
    pub cout: usize,
    /// Filter `(kh, kw)`.
    pub kernel: (usize, usize),
    /// Stride.
    pub stride: (usize, usize),
    /// Padding.
    pub pad: (usize, usize),
    /// Channel groups (1 = dense; `groups == cin == cout` = depthwise).
    pub groups: usize,
}

impl LayerSpec {
    /// The paper's layer-type label (`"3x3"`, `"5x5"`, `"1x7"`, `"7x1"`, …).
    pub fn layer_type(&self) -> String {
        format!("{}x{}", self.kernel.0, self.kernel.1)
    }

    /// Is the layer Winograd-suitable (a "fast layer")? Grouped layers
    /// never are — C_group is too shallow to amortise the transforms.
    pub fn fast(&self) -> bool {
        is_winograd_suitable(self.kernel, self.stride, self.groups)
    }

    /// Is the layer depthwise (`groups == cin == cout`)?
    pub fn depthwise(&self) -> bool {
        self.groups > 1 && self.groups == self.cin && self.groups == self.cout
    }

    /// Is the layer a dense unpadded 1×1 the zero-copy pointwise engine
    /// accepts (stride 1 or 2)?
    pub fn pointwise(&self) -> bool {
        self.groups == 1
            && self.kernel == (1, 1)
            && self.pad == (0, 0)
            && (self.stride == (1, 1) || self.stride == (2, 2))
    }

    /// Deterministic input tensor for benching.
    pub fn input(&self, seed: u64) -> Tensor {
        Tensor::randn(&self.input_shape, seed)
    }

    /// Deterministic weights `[M, KH, KW, C/groups]`.
    pub fn weights(&self, seed: u64) -> Tensor {
        crate::conv::Conv2d::new(self.cin, self.cout, self.kernel)
            .with_groups(self.groups)
            .random_weights(seed)
    }

    /// FLOPs of this layer (direct-conv count).
    pub fn flops(&self) -> usize {
        let oh = (self.input_shape[1] + 2 * self.pad.0 - self.kernel.0) / self.stride.0 + 1;
        let ow = (self.input_shape[2] + 2 * self.pad.1 - self.kernel.1) / self.stride.1 + 1;
        crate::conv::direct::conv_flops(
            self.input_shape[0],
            oh,
            ow,
            self.kernel.0,
            self.kernel.1,
            self.cin / self.groups,
            self.cout,
        )
    }
}

/// Extract every conv layer of `model` (batch 1) with resolved input shapes.
pub fn conv_layers(model: ModelKind, seed: u64) -> Result<Vec<LayerSpec>> {
    let graph: Graph = model.build(seed)?;
    let shapes = graph.infer_shapes(&model.input_shape(1))?;
    let mut out = Vec::new();
    for node in graph.nodes.iter() {
        if let Op::Conv { desc, .. } = &node.op {
            let in_shape = shapes[node.inputs[0]].clone();
            out.push(LayerSpec {
                model,
                name: node.name.clone(),
                input_shape: in_shape,
                cin: desc.cin,
                cout: desc.cout,
                kernel: desc.kernel,
                stride: desc.stride,
                pad: desc.padding,
                groups: desc.groups,
            });
        }
    }
    Ok(out)
}

/// The depthwise conv layers of a model, deduplicated by shape signature
/// with occurrence counts — the workload of the `ablation_depthwise`
/// bench.
pub fn unique_depthwise_layers(model: ModelKind, seed: u64) -> Result<Vec<(LayerSpec, usize)>> {
    let mut seen: Vec<(LayerSpec, usize)> = Vec::new();
    for spec in conv_layers(model, seed)?.into_iter().filter(LayerSpec::depthwise) {
        match seen.iter_mut().find(|(s, _)| {
            s.input_shape == spec.input_shape && s.cin == spec.cin && s.stride == spec.stride
        }) {
            Some((_, count)) => *count += 1,
            None => seen.push((spec, 1)),
        }
    }
    Ok(seen)
}

/// The dense 1×1 pointwise conv layers of a model, deduplicated by shape
/// signature with occurrence counts — the workload of the
/// `ablation_pointwise` bench.
pub fn unique_pointwise_layers(model: ModelKind, seed: u64) -> Result<Vec<(LayerSpec, usize)>> {
    let mut seen: Vec<(LayerSpec, usize)> = Vec::new();
    for spec in conv_layers(model, seed)?.into_iter().filter(LayerSpec::pointwise) {
        match seen.iter_mut().find(|(s, _)| {
            s.input_shape == spec.input_shape
                && s.cin == spec.cin
                && s.cout == spec.cout
                && s.stride == spec.stride
        }) {
            Some((_, count)) => *count += 1,
            None => seen.push((spec, 1)),
        }
    }
    Ok(seen)
}

/// The fast (Winograd-suitable) conv layers of a model, deduplicated by
/// shape signature so per-layer benches don't redundantly re-measure
/// identical layers (e.g. VGG's repeated blocks, Inception's twin modules).
pub fn unique_fast_layers(model: ModelKind, seed: u64) -> Result<Vec<(LayerSpec, usize)>> {
    let mut seen: Vec<(LayerSpec, usize)> = Vec::new();
    for spec in conv_layers(model, seed)?.into_iter().filter(LayerSpec::fast) {
        match seen.iter_mut().find(|(s, _)| {
            s.input_shape == spec.input_shape
                && s.cin == spec.cin
                && s.cout == spec.cout
                && s.kernel == spec.kernel
        }) {
            Some((_, count)) => *count += 1,
            None => seen.push((spec, 1)),
        }
    }
    Ok(seen)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_layers_extracted() {
        let layers = conv_layers(ModelKind::Vgg16, 1).unwrap();
        assert_eq!(layers.len(), 13);
        assert!(layers.iter().all(|l| l.layer_type() == "3x3" && l.fast()));
        // conv1_1 sees the raw image.
        assert_eq!(layers[0].input_shape, vec![1, 224, 224, 3]);
        // conv5_x sees 14×14×512.
        assert_eq!(layers[12].input_shape, vec![1, 14, 14, 512]);
    }

    #[test]
    fn inception_v3_has_1d_layers() {
        let layers = conv_layers(ModelKind::InceptionV3, 1).unwrap();
        let types: std::collections::HashSet<String> =
            layers.iter().filter(|l| l.fast()).map(|l| l.layer_type()).collect();
        for t in ["3x3", "5x5", "1x7", "7x1", "1x3", "3x1"] {
            assert!(types.contains(t), "missing {t}");
        }
    }

    #[test]
    fn dedup_compresses_vgg() {
        let unique = unique_fast_layers(ModelKind::Vgg16, 1).unwrap();
        let total: usize = unique.iter().map(|(_, c)| c).sum();
        assert_eq!(total, 13);
        assert!(unique.len() < 13, "VGG has repeated block shapes");
    }

    #[test]
    fn mobilenet_depthwise_layers_extracted() {
        let layers = conv_layers(ModelKind::MobileNetV1, 1).unwrap();
        assert_eq!(layers.len(), 27);
        // No MobileNetV1 layer is Winograd-suitable; 13 are depthwise.
        assert!(layers.iter().all(|l| !l.fast()));
        assert_eq!(layers.iter().filter(|l| l.depthwise()).count(), 13);
        let unique = unique_depthwise_layers(ModelKind::MobileNetV1, 1).unwrap();
        let total: usize = unique.iter().map(|(_, c)| c).sum();
        assert_eq!(total, 13);
        assert!(unique.len() < 13, "V1 repeats 512-channel s1 blocks");
        for (spec, _) in &unique {
            assert_eq!(spec.kernel, (3, 3));
            assert_eq!(spec.weights(1).shape(), &[spec.cin, 3, 3, 1]);
        }
    }

    #[test]
    fn resnet50_pointwise_layers_extracted() {
        let layers = conv_layers(ModelKind::ResNet50, 1).unwrap();
        assert_eq!(layers.len(), 53);
        assert_eq!(layers.iter().filter(|l| l.pointwise()).count(), 36);
        let unique = unique_pointwise_layers(ModelKind::ResNet50, 1).unwrap();
        let total: usize = unique.iter().map(|(_, c)| c).sum();
        assert_eq!(total, 36);
        assert!(unique.len() < 36, "bottleneck stages repeat 1x1 shapes");
        for (spec, _) in &unique {
            assert_eq!(spec.kernel, (1, 1));
            assert_eq!(spec.groups, 1);
        }
    }

    #[test]
    fn flops_positive_and_plausible() {
        for (spec, _) in unique_fast_layers(ModelKind::SqueezeNet, 1).unwrap() {
            assert!(spec.flops() > 0);
        }
        // VGG conv1_1: 2·224·224·9·3·64 ≈ 0.17 GFLOP.
        let l = &conv_layers(ModelKind::Vgg16, 1).unwrap()[0];
        assert_eq!(l.flops(), 2 * 224 * 224 * 9 * 3 * 64);
    }
}
