//! `statcheck` — run the repo's static-invariant passes and fail on findings.
//!
//! ```text
//! cargo run --release --bin statcheck [-- --root DIR --quiet]
//! ```
//!
//! Prints waived findings (unless `--quiet`), then real findings as
//! `file:line: [pass] message`, then a one-line summary. Exit codes:
//! 0 clean, 1 findings, 2 usage or I/O error.

use std::path::Path;
use std::process::ExitCode;
use winoconv::analysis;
use winoconv::util::cli::Args;

fn main() -> ExitCode {
    let args = match Args::from_env(&["quiet", "help"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("statcheck: {e}");
            return ExitCode::from(2);
        }
    };
    if args.flag("help") {
        println!("USAGE: statcheck [--root DIR] [--quiet]");
        return ExitCode::SUCCESS;
    }
    let root = args.get_or("root", ".");
    let report = match analysis::run_all(Path::new(&root)) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("statcheck: cannot scan {root:?}: {e}");
            return ExitCode::from(2);
        }
    };
    if !args.flag("quiet") {
        for w in &report.waivers {
            println!("waived: {w}");
        }
    }
    for f in &report.findings {
        println!("{f}");
    }
    println!(
        "statcheck: {} files scanned, {} unsafe sites, {} waivers, {} findings",
        report.files_scanned,
        report.unsafe_sites,
        report.waivers.len(),
        report.findings.len()
    );
    if report.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
