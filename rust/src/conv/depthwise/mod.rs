//! Direct depthwise convolution — the register-tiled SIMD engine for the
//! MobileNet workload class.
//!
//! ## Why not Winograd (or im2row)?
//!
//! The paper's region-wise Winograd argument (§4) amortises the input/output
//! transform cost over the `C·M` products of the channel-mixing GEMM. A
//! depthwise layer has **no channel mixing**: each channel convolves alone
//! (`C_group = 1`), so there is no GEMM to amortise against and the
//! transforms dominate outright. im2row fares no better — with `K = KH·KW·1`
//! the patch matrix is a 9-wide memory-bound copy feeding `C` degenerate
//! `[R×9]·[9×1]` GEMMs. Zhang et al. (*High Performance Depthwise and
//! Pointwise Convolutions on Mobile Devices*, 2020) and Hao et al.
//! (*Towards Effective Depthwise Convolutions on ARMv8*, 2022) both reach
//! the same conclusion the selector ([`crate::conv::select`]) encodes: the
//! right algorithm for this regime is a **direct** loop nest, vectorised
//! over channels, with enough register tiling that every input pixel is
//! loaded once per kernel row.
//!
//! ## The register-tiling scheme
//!
//! NHWC keeps channels innermost, so — exactly like the paper's Winograd
//! transforms — one 128-bit [`F32x4`] register holds **four channels of one
//! pixel**, and the per-channel depthwise products become lane-parallel
//! FMAs with no horizontal reduction:
//!
//! * **Channel groups** — the channel axis is walked in groups of 4 lanes
//!   (ragged tails via partial load/store). Per group, the nine 3×3 taps
//!   are preloaded into nine registers (`wv[9]`) that stay resident for the
//!   whole output row.
//! * **Output-row column tiles** — each output row is processed
//!   [`COL_TILE`] output pixels at a time: 4 accumulators live in registers
//!   across all nine taps, so the kernel runs 36 FMAs per tile against
//!   ≤ 18 input loads (at stride 1 adjacent taps/columns re-touch the same
//!   pixels, which stay L1-resident) with zero intermediate stores.
//! * **Fused epilogue** — accumulators are *seeded* with the bias vector
//!   and clamped (ReLU / ReLU6) in registers before the single store, so —
//!   like both GEMM-backed schemes — depthwise outputs are written exactly
//!   once, already biased and activated.
//!
//! Padding is staged: `run_fused_into` zero-pads the input into
//! workspace-owned memory ([`TensorView::pad_spatial_into`], no copy for
//! valid/unpadded layers), so the hot loops carry no bounds checks and the
//! zero-steady-state-allocation invariant of the planned executor holds —
//! with a warm arena this path performs **no heap allocation**.
//!
//! Scope: 3×3 kernels at stride 1 and stride 2 (the only depthwise shapes
//! the MobileNet family ships); anything else routes to the naive grouped
//! oracle ([`crate::conv::direct::direct_conv2d_grouped`]), which is also
//! this engine's property-test reference.

use crate::gemm::Activation;
use crate::parallel::ThreadPool;
use crate::simd::F32x4;
use crate::tensor::{Tensor, TensorView};
use crate::workspace::Workspace;
use crate::{bail_shape, bail_unsupported, Result};

/// Output pixels per register tile: 4 accumulators + 9 weight vectors + a
/// bias vector keeps the working set within even AArch32's 16 q-registers.
pub const COL_TILE: usize = 4;

/// A prepared direct depthwise convolution: 3×3 taps repacked tap-major so
/// each tap's channel run is contiguous (one [`F32x4`] load per tap and
/// 4-channel group), reusable across inputs — the same prepare-once
/// treatment [`crate::winograd::WinogradConvolution`] and
/// [`crate::im2row::Im2RowConvolution`] get.
#[derive(Debug, Clone)]
pub struct DepthwiseConvolution {
    channels: usize,
    stride: (usize, usize),
    pad: (usize, usize),
    /// Taps repacked to `[KH·KW][C]`: `w[(a·3 + b)·C + ch]` — for a fixed
    /// tap `(a, b)` the channel group `ch..ch+4` is one vector load.
    w: Vec<f32>,
}

impl DepthwiseConvolution {
    /// Prepare from `[C, 3, 3, 1]` weights (the `[M, KH, KW, C/groups]`
    /// convention at `groups == cin == cout`). Only 3×3 at stride (1,1) or
    /// (2,2) is supported — the selector never routes other shapes here.
    pub fn new(weights: &Tensor, stride: (usize, usize), pad: (usize, usize)) -> Result<Self> {
        if weights.rank() != 4 || weights.shape()[3] != 1 {
            bail_shape!(
                "depthwise weights must be [C, KH, KW, 1], got {:?}",
                weights.shape()
            );
        }
        let (c, kh, kw) = (weights.shape()[0], weights.shape()[1], weights.shape()[2]);
        if (kh, kw) != (3, 3) {
            bail_unsupported!("depthwise engine is 3x3-only, got {kh}x{kw}");
        }
        if stride != (1, 1) && stride != (2, 2) {
            bail_unsupported!("depthwise engine supports stride 1 or 2, got {stride:?}");
        }
        let mut w = vec![0.0f32; 9 * c];
        for ch in 0..c {
            for a in 0..3 {
                for b in 0..3 {
                    w[(a * 3 + b) * c + ch] = weights.at4(ch, a, b, 0);
                }
            }
        }
        Ok(DepthwiseConvolution {
            channels: c,
            stride,
            pad,
            w,
        })
    }

    /// Channel count (== groups == cin == cout).
    pub fn channels(&self) -> usize {
        self.channels
    }

    /// Output spatial size for an `h×w` input.
    pub fn output_hw(&self, h: usize, w: usize) -> Result<(usize, usize)> {
        let (ph, pw) = self.pad;
        if h + 2 * ph < 3 || w + 2 * pw < 3 {
            bail_shape!("input {h}x{w} (pad {ph},{pw}) smaller than filter 3x3");
        }
        Ok(((h + 2 * ph - 3) / self.stride.0 + 1, (w + 2 * pw - 3) / self.stride.1 + 1))
    }

    /// Elements of workspace-owned padded-input staging one inference over
    /// an `[n, h, w, C]` input borrows — 0 for valid (unpadded) layers,
    /// where the engine reads the caller's input directly.
    pub fn staging_elems_for(&self, n: usize, h: usize, w: usize) -> usize {
        let (ph, pw) = self.pad;
        if ph == 0 && pw == 0 {
            0
        } else {
            n * (h + 2 * ph) * (w + 2 * pw) * self.channels
        }
    }

    /// Workspace elements one inference borrows from the arena — staging is
    /// the engine's only scratch (no patch matrix, no packed blocks).
    pub fn workspace_elems_for(&self, n: usize, h: usize, w: usize) -> Result<usize> {
        let _ = self.output_hw(h, w)?; // geometry must be valid
        Ok(self.staging_elems_for(n, h, w))
    }

    /// Run with a throwaway arena (tests / one-shot use).
    pub fn run(&self, input: &Tensor, pool: Option<&ThreadPool>) -> Result<Tensor> {
        let mut ws = Workspace::new();
        self.run_with_workspace(input, pool, &mut ws)
    }

    /// [`run`](Self::run) drawing the padded-input staging from a
    /// caller-owned arena.
    pub fn run_with_workspace(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
        ws: &mut Workspace,
    ) -> Result<Tensor> {
        self.run_fused_with(input, pool, None, Activation::None, ws)
    }

    /// Allocating wrapper over [`run_fused_into`](Self::run_fused_into) —
    /// kept as the oracle the write-into path is property-tested against.
    pub fn run_fused_with(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
    ) -> Result<Tensor> {
        if input.rank() != 4 {
            bail_shape!("input must be [N, H, W, C], got {:?}", input.shape());
        }
        let (n, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
        let (oh, ow) = self.output_hw(h, w)?;
        let mut out = Tensor::zeros(&[n, oh, ow, self.channels]);
        self.run_fused_into(&input.view(), pool, bias, act, ws, out.data_mut())?;
        Ok(out)
    }

    /// The write-into pipeline: the padded input is staged into
    /// workspace-owned memory (no copy for valid layers), and the
    /// register-tiled kernel lands biased/activated outputs directly in
    /// the caller-provided `out` slice (`N·OH·OW·C` elements, fully
    /// overwritten — dirty arena memory is fine). With a warm arena this
    /// path performs **zero heap allocation** — the property the planned
    /// executor ([`crate::nn::PreparedModel`]) builds on.
    pub fn run_fused_into(
        &self,
        input: &TensorView,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
        out: &mut [f32],
    ) -> Result<()> {
        if input.rank() != 4 {
            bail_shape!("input must be [N, H, W, C], got {:?}", input.shape());
        }
        let (n, h, w, c) = (
            input.shape()[0],
            input.shape()[1],
            input.shape()[2],
            input.shape()[3],
        );
        if c != self.channels {
            bail_shape!("input has {c} channels, depthwise weights expect {}", self.channels);
        }
        if let Some(b) = bias {
            if b.len() != c {
                bail_shape!("bias length {} vs {c} channels", b.len());
            }
        }
        let (oh, ow) = self.output_hw(h, w)?;
        if out.len() != n * oh * ow * c {
            bail_shape!(
                "output slice has {} elems, layer writes {}",
                out.len(),
                n * oh * ow * c
            );
        }
        let out_addr = out.as_mut_ptr() as usize;
        let (ph, pw) = self.pad;
        if ph == 0 && pw == 0 {
            // No staging copy, but the Pack span is still recorded (~0 ns)
            // so the per-engine stage census stays fixed at two.
            let stage_t = crate::trace::begin();
            crate::trace::end_stage(
                stage_t,
                crate::trace::Stage::Pack,
                crate::trace::AlgoCode::Depthwise,
            );
            let stage_t = crate::trace::begin();
            self.conv_rows(input, n, oh, ow, bias, act, pool, out_addr);
            crate::trace::end_stage(
                stage_t,
                crate::trace::Stage::Compute,
                crate::trace::AlgoCode::Depthwise,
            );
        } else {
            let stage_t = crate::trace::begin();
            let staging = ws.take(self.staging_elems_for(n, h, w));
            input.pad_spatial_into(ph, ph, pw, pw, staging);
            let pshape = [n, h + 2 * ph, w + 2 * pw, c];
            let padded = TensorView::new(&pshape, staging)?;
            crate::trace::end_stage(
                stage_t,
                crate::trace::Stage::Pack,
                crate::trace::AlgoCode::Depthwise,
            );
            let stage_t = crate::trace::begin();
            self.conv_rows(&padded, n, oh, ow, bias, act, pool, out_addr);
            crate::trace::end_stage(
                stage_t,
                crate::trace::Stage::Compute,
                crate::trace::AlgoCode::Depthwise,
            );
        }
        Ok(())
    }

    /// Allocating twin of
    /// [`run_fused_batched_into`](Self::run_fused_batched_into) — the
    /// oracle its batched-vs-sequential property tests compare against.
    #[allow(clippy::too_many_arguments)]
    pub fn run_fused_batched_with(
        &self,
        batch: &Tensor,
        nb: usize,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
    ) -> Result<Tensor> {
        if batch.rank() != 4 {
            bail_shape!("batch must be [NB, H, W, C], got {:?}", batch.shape());
        }
        let (h, w) = (batch.shape()[1], batch.shape()[2]);
        let (oh, ow) = self.output_hw(h, w)?;
        let mut out = Tensor::zeros(&[batch.shape()[0], oh, ow, self.channels]);
        self.run_fused_batched_into(&batch.view(), nb, pool, bias, act, ws, out.data_mut())?;
        Ok(out)
    }

    /// Batched write-into entry point: `nb` frames gathered contiguously as
    /// one `[nb, H, W, C]` view run through one pass of the register-tiled
    /// kernel, which parallelises over the `nb·OH` independent output rows
    /// — a frame boundary is just another row boundary, so the result is
    /// **bit-identical** to running the frames one at a time.
    /// Allocation-free with a warm arena (statcheck-registered).
    #[allow(clippy::too_many_arguments)]
    pub fn run_fused_batched_into(
        &self,
        batch: &TensorView,
        nb: usize,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
        out: &mut [f32],
    ) -> Result<()> {
        super::check_batch_dim(batch, nb)?;
        self.run_fused_into(batch, pool, bias, act, ws, out)
    }

    /// The hot loop over an **already padded** source view. Parallelises
    /// over output rows (`N·OH` independent jobs, disjoint output rows).
    #[allow(clippy::too_many_arguments)]
    fn conv_rows(
        &self,
        src: &TensorView,
        n: usize,
        oh: usize,
        ow: usize,
        bias: Option<&[f32]>,
        act: Activation,
        pool: Option<&ThreadPool>,
        out_addr: usize,
    ) {
        let c = self.channels;
        let (sh, sw) = self.stride;
        let (hp, wp) = (src.shape()[1], src.shape()[2]);
        let data = src.data();
        let taps = &self.w;
        // What the row jobs' tap loads assume of the padded source: nine
        // taps per channel, and every 3x3 window of every output pixel
        // in-bounds of `data`.
        debug_assert_eq!(taps.len(), 9 * c);
        debug_assert!(data.len() >= n * hp * wp * c);
        debug_assert!(oh == 0 || (oh - 1) * sh + 3 <= hp);
        debug_assert!(ow == 0 || (ow - 1) * sw + 3 <= wp);
        let row_job = |r: usize| {
            let b = r / oh;
            let oy = r % oh;
            let iy0 = oy * sh;
            // SAFETY: each job writes only its own `(b, oy)` output row;
            // jobs are disjoint and `out` outlives the dispatch.
            let out_row: &mut [f32] = unsafe {
                std::slice::from_raw_parts_mut(
                    (out_addr as *mut f32).add((b * oh + oy) * ow * c),
                    ow * c,
                )
            };
            for cg in (0..c).step_by(4) {
                let lanes = (c - cg).min(4);
                let full = lanes == 4;
                // Preload the nine taps of this channel group — resident
                // in registers for the whole output row.
                let mut wv = [F32x4::zero(); 9];
                for (t, wvt) in wv.iter_mut().enumerate() {
                    let s = &taps[t * c + cg..];
                    *wvt = if full { F32x4::load(s) } else { F32x4::load_partial(&s[..lanes]) };
                }
                // Accumulators are *seeded* with the bias (zero when none):
                // the epilogue costs no extra pass over the output.
                let bv = match bias {
                    Some(bb) => F32x4::load_partial(&bb[cg..cg + lanes]),
                    None => F32x4::zero(),
                };
                // Flat index of padded pixel (b, iy0+a, ix, cg).
                let at = |a: usize, ix: usize| ((b * hp + iy0 + a) * wp + ix) * c + cg;
                let load = |idx: usize| {
                    let s = &data[idx..];
                    if full {
                        F32x4::load(s)
                    } else {
                        F32x4::load_partial(&s[..lanes])
                    }
                };
                let mut ox = 0usize;
                // Register tile: COL_TILE output pixels × 9 taps, all
                // accumulators live across the tap loop.
                while ox + COL_TILE <= ow {
                    let mut acc = [bv; COL_TILE];
                    for a in 0..3 {
                        for bx in 0..3 {
                            for (t, accx) in acc.iter_mut().enumerate() {
                                let pv = load(at(a, (ox + t) * sw + bx));
                                *accx = accx.fma(pv, wv[a * 3 + bx]);
                            }
                        }
                    }
                    for (t, accx) in acc.iter().enumerate() {
                        let v = act.apply_vec(*accx);
                        let dst = &mut out_row[(ox + t) * c + cg..];
                        if full {
                            v.store(dst);
                        } else {
                            v.store_partial(dst, lanes);
                        }
                    }
                    ox += COL_TILE;
                }
                // Ragged tail columns, one accumulator at a time.
                while ox < ow {
                    let mut accx = bv;
                    for a in 0..3 {
                        for bx in 0..3 {
                            let pv = load(at(a, ox * sw + bx));
                            accx = accx.fma(pv, wv[a * 3 + bx]);
                        }
                    }
                    let v = act.apply_vec(accx);
                    let dst = &mut out_row[ox * c + cg..];
                    if full {
                        v.store(dst);
                    } else {
                        v.store_partial(dst, lanes);
                    }
                    ox += 1;
                }
            }
        };
        match pool {
            Some(pool) => pool.parallel_for(n * oh, row_job),
            None => (0..n * oh).for_each(row_job),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::direct::direct_conv2d_grouped;
    use crate::testkit::{check, Gen};

    /// Scalar per-channel reference computing *exactly* the kernel's math:
    /// accumulator seeded with the bias, taps in `(a, b)` order via fused
    /// `mul_add`, activation last — so the SIMD engine must match it
    /// **bit for bit** (each F32x4 lane is an independent scalar chain).
    fn reference_depthwise(
        input: &Tensor,
        weights: &Tensor,
        stride: (usize, usize),
        pad: (usize, usize),
        bias: Option<&[f32]>,
        act: Activation,
    ) -> Tensor {
        let (n, h, w, c) = (
            input.shape()[0],
            input.shape()[1],
            input.shape()[2],
            input.shape()[3],
        );
        let (sh, sw) = stride;
        let (ph, pw) = pad;
        let (oh, ow) = ((h + 2 * ph - 3) / sh + 1, (w + 2 * pw - 3) / sw + 1);
        let mut out = Tensor::zeros(&[n, oh, ow, c]);
        for b in 0..n {
            for oy in 0..oh {
                for ox in 0..ow {
                    for ch in 0..c {
                        let mut acc = bias.map_or(0.0, |bb| bb[ch]);
                        for a in 0..3 {
                            for bx in 0..3 {
                                let iy = (oy * sh + a) as isize - ph as isize;
                                let ix = (ox * sw + bx) as isize - pw as isize;
                                // The engine convolves a zero-padded copy,
                                // so out-of-bounds taps contribute an
                                // explicit 0·w fma (not a skip).
                                let x = if iy >= 0 && iy < h as isize && ix >= 0 && ix < w as isize
                                {
                                    input.at4(b, iy as usize, ix as usize, ch)
                                } else {
                                    0.0
                                };
                                acc = x.mul_add(weights.at4(ch, a, bx, 0), acc);
                            }
                        }
                        *out.at4_mut(b, oy, ox, ch) = act.apply(acc);
                    }
                }
            }
        }
        out
    }

    /// The satellite property test: `run_fused_into` is bit-identical to
    /// the naive per-channel reference across strides {1,2} × paddings ×
    /// ragged channel counts (C % 4 ≠ 0) × {none, bias, bias+ReLU,
    /// bias+ReLU6}, writing into NaN-poisoned buffers.
    #[test]
    fn property_depthwise_matches_reference_bitwise() {
        check("depthwise == scalar fma reference", 48, |g: &mut Gen| {
            let c = g.usize_in(1, 11); // exercises C % 4 ∈ {0,1,2,3}
            let stride = if g.usize_in(0, 1) == 0 { (1, 1) } else { (2, 2) };
            let pad = match g.usize_in(0, 2) {
                0 => (0, 0),
                1 => (1, 1),
                _ => (1, 0),
            };
            let h = g.usize_in(3, 14);
            let w = g.usize_in(3, 14);
            let n = g.usize_in(1, 2);
            if h + 2 * pad.0 < 3 || w + 2 * pad.1 < 3 {
                return true;
            }
            let input = Tensor::from_vec(&[n, h, w, c], g.normal_vec(n * h * w * c)).unwrap();
            let weights = Tensor::from_vec(&[c, 3, 3, 1], g.normal_vec(9 * c)).unwrap();
            let bias: Vec<f32> = g.normal_vec(c);
            let (bias_opt, act) = match g.usize_in(0, 3) {
                0 => (None, Activation::None),
                1 => (Some(bias.as_slice()), Activation::None),
                2 => (Some(bias.as_slice()), Activation::Relu),
                _ => (Some(bias.as_slice()), Activation::Relu6),
            };
            let want = reference_depthwise(&input, &weights, stride, pad, bias_opt, act);
            let conv = DepthwiseConvolution::new(&weights, stride, pad).unwrap();
            let mut ws = Workspace::new();
            let mut got = vec![f32::NAN; want.len()];
            conv.run_fused_into(&input.view(), None, bias_opt, act, &mut ws, &mut got)
                .unwrap();
            got == want.data()
        });
    }

    /// The batched contract: one `[nb, H, W, C]` gathered walk through
    /// `run_fused_batched_into` is **bit-identical** to `nb` sequential
    /// batch-1 `run_fused_into` walks over the same frames — each output
    /// row's 9-tap fma chain is per-(frame, row, channel) — across strides
    /// × paddings × ragged channel counts × {none, bias, bias+ReLU6},
    /// written into NaN-poisoned buffers, and to its allocating twin.
    #[test]
    fn property_batched_matches_sequential_bitwise() {
        check("depthwise batched == nb × batch-1", 32, |g: &mut Gen| {
            let nb = g.usize_in(2, 5);
            let c = g.usize_in(1, 11);
            let stride = if g.usize_in(0, 1) == 0 { (1, 1) } else { (2, 2) };
            let pad = if g.usize_in(0, 1) == 0 { (0, 0) } else { (1, 1) };
            let h = g.usize_in(3, 11);
            let w = g.usize_in(3, 11);
            let input =
                Tensor::from_vec(&[nb, h, w, c], g.normal_vec(nb * h * w * c)).unwrap();
            let weights = Tensor::from_vec(&[c, 3, 3, 1], g.normal_vec(9 * c)).unwrap();
            let bias: Vec<f32> = g.normal_vec(c);
            let (bias_opt, act) = match g.usize_in(0, 2) {
                0 => (None, Activation::None),
                1 => (Some(bias.as_slice()), Activation::None),
                _ => (Some(bias.as_slice()), Activation::Relu6),
            };
            let conv = DepthwiseConvolution::new(&weights, stride, pad).unwrap();
            let mut ws = Workspace::new();
            let frame = h * w * c;
            let mut want: Vec<f32> = Vec::new();
            for f in 0..nb {
                let ft = Tensor::from_vec(
                    &[1, h, w, c],
                    input.data()[f * frame..(f + 1) * frame].to_vec(),
                )
                .unwrap();
                want.extend_from_slice(
                    conv.run_fused_with(&ft, None, bias_opt, act, &mut ws).unwrap().data(),
                );
            }
            let mut got = vec![f32::NAN; want.len()];
            conv.run_fused_batched_into(&input.view(), nb, None, bias_opt, act, &mut ws, &mut got)
                .unwrap();
            let twin =
                conv.run_fused_batched_with(&input, nb, None, bias_opt, act, &mut ws).unwrap();
            got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits())
                && got == *twin.data()
        });
    }

    /// Cross-oracle: the engine (bias-less) agrees with the grouped direct
    /// oracle at `groups == C` within float tolerance (different
    /// accumulation order, hence allclose rather than bit equality).
    #[test]
    fn matches_grouped_direct_oracle() {
        for (stride, pad) in [((1, 1), (1, 1)), ((2, 2), (1, 1)), ((1, 1), (0, 0)), ((2, 2), (0, 0))]
        {
            let c = 6;
            let input = Tensor::randn(&[2, 9, 11, c], 7);
            let weights = Tensor::randn(&[c, 3, 3, 1], 8);
            let conv = DepthwiseConvolution::new(&weights, stride, pad).unwrap();
            let got = conv.run(&input, None).unwrap();
            let want = direct_conv2d_grouped(&input, &weights, stride, pad, c).unwrap();
            assert_eq!(got.shape(), want.shape());
            assert!(
                got.allclose(&want, 1e-5),
                "stride {stride:?} pad {pad:?} diverges from grouped direct"
            );
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let pool = ThreadPool::new(4);
        let input = Tensor::randn(&[1, 20, 17, 13], 3);
        let weights = Tensor::randn(&[13, 3, 3, 1], 4);
        let bias: Vec<f32> = (0..13).map(|i| i as f32 * 0.1 - 0.6).collect();
        let conv = DepthwiseConvolution::new(&weights, (1, 1), (1, 1)).unwrap();
        let mut ws = Workspace::new();
        let a = conv
            .run_fused_with(&input, None, Some(&bias), Activation::Relu6, &mut ws)
            .unwrap();
        let b = conv
            .run_fused_with(&input, Some(&pool), Some(&bias), Activation::Relu6, &mut ws)
            .unwrap();
        assert_eq!(a.data(), b.data(), "pooled run must be bit-identical");
        // ReLU6 must actually clamp somewhere for this input to test it.
        assert!(a.data().iter().any(|&v| v == 0.0));
        assert!(a.data().iter().all(|&v| v <= 6.0));
    }

    /// Arena pin (PR 3 style): pre-sized from `workspace_elems_for`, the
    /// arena never grows across repeated inferences, and the sizing formula
    /// matches the actual borrow. Valid layers borrow nothing at all.
    #[test]
    fn arena_grow_count_stays_zero() {
        let weights = Tensor::randn(&[8, 3, 3, 1], 9);
        let conv = DepthwiseConvolution::new(&weights, (2, 2), (1, 1)).unwrap();
        let need = conv.workspace_elems_for(1, 12, 10).unwrap();
        assert_eq!(need, 14 * 12 * 8);
        let mut ws = Workspace::with_capacity(need);
        for seed in 0..3 {
            let input = Tensor::randn(&[1, 12, 10, 8], seed + 50);
            let _ = conv.run_with_workspace(&input, None, &mut ws).unwrap();
        }
        assert_eq!(ws.grow_count(), 0, "pre-sized arena must not grow");
        assert_eq!(ws.high_water_elems(), need, "sizing formula matches borrow");

        let valid = DepthwiseConvolution::new(&weights, (1, 1), (0, 0)).unwrap();
        let mut ws = Workspace::new();
        let input = Tensor::randn(&[1, 12, 10, 8], 60);
        let _ = valid.run_with_workspace(&input, None, &mut ws).unwrap();
        assert_eq!(ws.grow_count(), 0, "valid layers read the input in place");
        assert_eq!(valid.workspace_elems_for(1, 12, 10).unwrap(), 0);
    }

    #[test]
    fn rejects_bad_configs() {
        let w33 = Tensor::zeros(&[4, 3, 3, 1]);
        // Non-3×3 / non-depthwise weight shapes.
        assert!(DepthwiseConvolution::new(&Tensor::zeros(&[4, 5, 5, 1]), (1, 1), (2, 2)).is_err());
        assert!(DepthwiseConvolution::new(&Tensor::zeros(&[4, 3, 3, 2]), (1, 1), (1, 1)).is_err());
        // Unsupported strides.
        assert!(DepthwiseConvolution::new(&w33, (1, 2), (0, 0)).is_err());
        assert!(DepthwiseConvolution::new(&w33, (3, 3), (0, 0)).is_err());
        let conv = DepthwiseConvolution::new(&w33, (1, 1), (0, 0)).unwrap();
        let mut ws = Workspace::new();
        // Channel mismatch.
        let bad_c = Tensor::zeros(&[1, 8, 8, 5]);
        assert!(conv.run(&bad_c, None).is_err());
        // Too-small input.
        assert!(conv.run(&Tensor::zeros(&[1, 2, 2, 4]), None).is_err());
        // Wrong bias length and wrong output slice size.
        let input = Tensor::zeros(&[1, 8, 8, 4]);
        let mut out = vec![0.0; 6 * 6 * 4];
        assert!(conv
            .run_fused_into(&input.view(), None, Some(&[0.0; 3]), Activation::None, &mut ws, &mut out)
            .is_err());
        assert!(conv
            .run_fused_into(&input.view(), None, None, Activation::None, &mut ws, &mut out[1..])
            .is_err());
    }

    /// Hand-computed 3×3: all-ones input and taps, single channel.
    #[test]
    fn hand_computed_values() {
        let input = Tensor::full(&[1, 3, 3, 1], 1.0);
        let weights = Tensor::full(&[1, 3, 3, 1], 1.0);
        let conv = DepthwiseConvolution::new(&weights, (1, 1), (0, 0)).unwrap();
        let out = conv.run(&input, None).unwrap();
        assert_eq!(out.shape(), &[1, 1, 1, 1]);
        assert_eq!(out.data()[0], 9.0);
        // Same-padded: corners see 4 taps, edges 6, centre 9.
        let conv = DepthwiseConvolution::new(&weights, (1, 1), (1, 1)).unwrap();
        let out = conv.run(&input, None).unwrap();
        assert_eq!(out.shape(), &[1, 3, 3, 1]);
        assert_eq!(out.at4(0, 0, 0, 0), 4.0);
        assert_eq!(out.at4(0, 0, 1, 0), 6.0);
        assert_eq!(out.at4(0, 1, 1, 0), 9.0);
        // Stride 2 over 7×7 valid → 3×3 outputs.
        let input = Tensor::randn(&[1, 7, 7, 1], 1);
        let conv = DepthwiseConvolution::new(&weights, (2, 2), (0, 0)).unwrap();
        assert_eq!(conv.run(&input, None).unwrap().shape(), &[1, 3, 3, 1]);
    }
}
