//! Direct (naive) convolution — the correctness oracle every fast path is
//! validated against. Seven nested loops, no tricks; the innermost loop runs
//! over NHWC channels so it is at least cache-coherent, but this path is for
//! tests, tiny problems and the bench baselines, not production.

use crate::tensor::Tensor;
use crate::{bail_shape, Result};

/// `output[n, oy, ox, m] = Σ_{a,b,c} input[n, oy·sh+a−ph, ox·sw+b−pw, c] ·
/// weights[m, a, b, c]` with zero padding.
pub fn direct_conv2d(
    input: &Tensor,
    weights: &Tensor,
    stride: (usize, usize),
    pad: (usize, usize),
) -> Result<Tensor> {
    if input.rank() != 4 || weights.rank() != 4 {
        bail_shape!(
            "direct_conv2d expects rank-4 input/weights, got {:?} / {:?}",
            input.shape(),
            weights.shape()
        );
    }
    let (n, h, w, c) = (
        input.shape()[0],
        input.shape()[1],
        input.shape()[2],
        input.shape()[3],
    );
    let (m, kh, kw, wc) = (
        weights.shape()[0],
        weights.shape()[1],
        weights.shape()[2],
        weights.shape()[3],
    );
    if wc != c {
        bail_shape!("channel mismatch: input {c}, weights {wc}");
    }
    let (sh, sw) = stride;
    let (ph, pw) = pad;
    if sh == 0 || sw == 0 {
        bail_shape!("stride must be positive");
    }
    if h + 2 * ph < kh || w + 2 * pw < kw {
        bail_shape!("input {h}x{w} (pad {ph},{pw}) smaller than filter {kh}x{kw}");
    }
    let oh = (h + 2 * ph - kh) / sh + 1;
    let ow = (w + 2 * pw - kw) / sw + 1;

    let mut out = Tensor::zeros(&[n, oh, ow, m]);
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for mi in 0..m {
                    let mut acc = 0.0f32;
                    for a in 0..kh {
                        let iy = (oy * sh + a) as isize - ph as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for bx in 0..kw {
                            let ix = (ox * sw + bx) as isize - pw as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let px = input.pixel(b, iy as usize, ix as usize);
                            for ch in 0..c {
                                acc += px[ch] * weights.at4(mi, a, bx, ch);
                            }
                        }
                    }
                    *out.at4_mut(b, oy, ox, mi) = acc;
                }
            }
        }
    }
    Ok(out)
}

/// FLOP count of a direct convolution (the roofline denominator used in the
/// bench reports): 2·N·OH·OW·KH·KW·C·M.
pub fn conv_flops(
    n: usize,
    oh: usize,
    ow: usize,
    kh: usize,
    kw: usize,
    c: usize,
    m: usize,
) -> usize {
    2 * n * oh * ow * kh * kw * c * m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_kernel_passthrough() {
        // 1×1 kernel with identity channel-mixing copies the input.
        let input = Tensor::randn(&[1, 4, 4, 2], 1);
        let mut w = Tensor::zeros(&[2, 1, 1, 2]);
        *w.at4_mut(0, 0, 0, 0) = 1.0;
        *w.at4_mut(1, 0, 0, 1) = 1.0;
        let out = direct_conv2d(&input, &w, (1, 1), (0, 0)).unwrap();
        assert_eq!(out, input);
    }

    #[test]
    fn hand_computed_3x3() {
        // All-ones 3×3 input, all-ones 3×3 kernel, no pad: single output = 9.
        let input = Tensor::full(&[1, 3, 3, 1], 1.0);
        let w = Tensor::full(&[1, 3, 3, 1], 1.0);
        let out = direct_conv2d(&input, &w, (1, 1), (0, 0)).unwrap();
        assert_eq!(out.shape(), &[1, 1, 1, 1]);
        assert_eq!(out.data()[0], 9.0);
        // With pad 1 the corner output sees only 4 taps.
        let out = direct_conv2d(&input, &w, (1, 1), (1, 1)).unwrap();
        assert_eq!(out.shape(), &[1, 3, 3, 1]);
        assert_eq!(out.at4(0, 0, 0, 0), 4.0);
        assert_eq!(out.at4(0, 1, 1, 0), 9.0);
        assert_eq!(out.at4(0, 0, 1, 0), 6.0);
    }

    #[test]
    fn stride_two_downsamples() {
        let input = Tensor::randn(&[1, 7, 7, 1], 2);
        let w = Tensor::randn(&[1, 3, 3, 1], 3);
        let out = direct_conv2d(&input, &w, (2, 2), (0, 0)).unwrap();
        assert_eq!(out.shape(), &[1, 3, 3, 1]);
    }

    #[test]
    fn channel_summation() {
        // Two input channels with weights (1, 10): output = c0 + 10·c1.
        let mut input = Tensor::zeros(&[1, 1, 1, 2]);
        input.data_mut()[0] = 3.0;
        input.data_mut()[1] = 5.0;
        let mut w = Tensor::zeros(&[1, 1, 1, 2]);
        w.data_mut()[0] = 1.0;
        w.data_mut()[1] = 10.0;
        let out = direct_conv2d(&input, &w, (1, 1), (0, 0)).unwrap();
        assert_eq!(out.data()[0], 53.0);
    }

    #[test]
    fn errors_on_bad_config() {
        let input = Tensor::zeros(&[1, 4, 4, 2]);
        let w = Tensor::zeros(&[1, 3, 3, 3]);
        assert!(direct_conv2d(&input, &w, (1, 1), (0, 0)).is_err()); // channel mismatch
        let w = Tensor::zeros(&[1, 5, 5, 2]);
        assert!(direct_conv2d(&input, &w, (1, 1), (0, 0)).is_err()); // too small
        let w = Tensor::zeros(&[1, 3, 3, 2]);
        assert!(direct_conv2d(&input, &w, (0, 1), (0, 0)).is_err()); // zero stride
    }

    #[test]
    fn flops_formula() {
        assert_eq!(conv_flops(1, 2, 2, 3, 3, 4, 5), 2 * 2 * 2 * 9 * 4 * 5);
    }
}
