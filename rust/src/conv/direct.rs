//! Direct (naive) convolution — the correctness oracle every fast path is
//! validated against. Seven nested loops, no tricks; the innermost loop runs
//! over NHWC channels so it is at least cache-coherent, but this path is for
//! tests, tiny problems and the bench baselines, not production.
//!
//! The grouped entry points ([`direct_conv2d_grouped`],
//! [`direct_conv2d_grouped_into`]) generalise the same loops to grouped
//! convolution (`[M, KH, KW, C/groups]` weights): they are the oracle the
//! direct depthwise engine ([`crate::conv::depthwise`]) is property-tested
//! against, and the fallback the selector routes exotic grouped shapes to.

use crate::tensor::{Tensor, TensorView};
use crate::{bail_shape, Result};

/// Validate input/weight shapes, stride, padding and grouping, and derive
/// the output spatial extents — the single copy of the direct-conv geometry
/// every entry point shares. Grouped weights are `[M, KH, KW, C/groups]`.
fn checked_out_hw(
    input_shape: &[usize],
    weights: &Tensor,
    stride: (usize, usize),
    pad: (usize, usize),
    groups: usize,
) -> Result<(usize, usize)> {
    if input_shape.len() != 4 || weights.rank() != 4 {
        bail_shape!(
            "direct_conv2d expects rank-4 input/weights, got {:?} / {:?}",
            input_shape,
            weights.shape()
        );
    }
    let (h, w, c) = (input_shape[1], input_shape[2], input_shape[3]);
    let (m, kh, kw, wc) = (
        weights.shape()[0],
        weights.shape()[1],
        weights.shape()[2],
        weights.shape()[3],
    );
    if groups == 0 || c % groups != 0 || m % groups != 0 {
        bail_shape!("groups {groups} does not divide C={c} / M={m}");
    }
    if wc != c / groups {
        bail_shape!("channel mismatch: input C/groups {}, weights {wc}", c / groups);
    }
    let (sh, sw) = stride;
    let (ph, pw) = pad;
    if sh == 0 || sw == 0 {
        bail_shape!("stride must be positive");
    }
    if h + 2 * ph < kh || w + 2 * pw < kw {
        bail_shape!("input {h}x{w} (pad {ph},{pw}) smaller than filter {kh}x{kw}");
    }
    Ok(((h + 2 * ph - kh) / sh + 1, (w + 2 * pw - kw) / sw + 1))
}

/// `output[n, oy, ox, m] = Σ_{a,b,c} input[n, oy·sh+a−ph, ox·sw+b−pw, c] ·
/// weights[m, a, b, c]` with zero padding. Allocating wrapper over
/// [`direct_conv2d_into`].
pub fn direct_conv2d(
    input: &Tensor,
    weights: &Tensor,
    stride: (usize, usize),
    pad: (usize, usize),
) -> Result<Tensor> {
    direct_conv2d_grouped(input, weights, stride, pad, 1)
}

/// Grouped direct convolution: input channels are split into `groups`
/// equal slices, weights are `[M, KH, KW, C/groups]`, and output channel
/// `m` convolves input slice `m / (M/groups)`. `groups == 1` is the dense
/// case, `groups == C == M` the depthwise case.
pub fn direct_conv2d_grouped(
    input: &Tensor,
    weights: &Tensor,
    stride: (usize, usize),
    pad: (usize, usize),
    groups: usize,
) -> Result<Tensor> {
    let (oh, ow) = checked_out_hw(input.shape(), weights, stride, pad, groups)?;
    let mut out = Tensor::zeros(&[input.shape()[0], oh, ow, weights.shape()[0]]);
    direct_conv2d_grouped_into(&input.view(), weights, stride, pad, groups, out.data_mut())?;
    Ok(out)
}

/// [`direct_conv2d`] writing into a caller-provided `N·OH·OW·M` slice
/// (fully overwritten — dirty arena memory is fine). The write-into oracle
/// matching the conv stack's `run_*_into` entry points.
pub fn direct_conv2d_into(
    input: &TensorView,
    weights: &Tensor,
    stride: (usize, usize),
    pad: (usize, usize),
    out: &mut [f32],
) -> Result<()> {
    direct_conv2d_grouped_into(input, weights, stride, pad, 1, out)
}

/// [`direct_conv2d_grouped`] writing into a caller-provided `N·OH·OW·M`
/// slice (fully overwritten — dirty arena memory is fine).
pub fn direct_conv2d_grouped_into(
    input: &TensorView,
    weights: &Tensor,
    stride: (usize, usize),
    pad: (usize, usize),
    groups: usize,
    out: &mut [f32],
) -> Result<()> {
    let (oh, ow) = checked_out_hw(input.shape(), weights, stride, pad, groups)?;
    let (n, h, w, c) = (
        input.shape()[0],
        input.shape()[1],
        input.shape()[2],
        input.shape()[3],
    );
    let (m, kh, kw) = (weights.shape()[0], weights.shape()[1], weights.shape()[2]);
    let (sh, sw) = stride;
    let (ph, pw) = pad;
    if out.len() != n * oh * ow * m {
        bail_shape!("output slice has {} elems, conv writes {}", out.len(), n * oh * ow * m);
    }
    let cg = c / groups; // input channels per group
    let mg = m / groups; // output channels per group

    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for mi in 0..m {
                    let c0 = (mi / mg) * cg; // first input channel of mi's group
                    let mut acc = 0.0f32;
                    for a in 0..kh {
                        let iy = (oy * sh + a) as isize - ph as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for bx in 0..kw {
                            let ix = (ox * sw + bx) as isize - pw as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let px = input.pixel(b, iy as usize, ix as usize);
                            for ch in 0..cg {
                                acc += px[c0 + ch] * weights.at4(mi, a, bx, ch);
                            }
                        }
                    }
                    out[((b * oh + oy) * ow + ox) * m + mi] = acc;
                }
            }
        }
    }
    Ok(())
}

/// Allocating twin of [`direct_conv2d_grouped_batched_into`] — the oracle
/// its batched-vs-sequential tests compare against.
pub fn direct_conv2d_grouped_batched(
    batch: &Tensor,
    weights: &Tensor,
    stride: (usize, usize),
    pad: (usize, usize),
    groups: usize,
    nb: usize,
) -> Result<Tensor> {
    super::check_batch_dim(&batch.view(), nb)?;
    direct_conv2d_grouped(batch, weights, stride, pad, groups)
}

/// Batched write-into entry point for the grouped direct oracle: `nb`
/// frames gathered contiguously as one `[nb, H, W, C]` view execute in one
/// walk (the naive loops already iterate the leading dimension, so a frame
/// boundary is just another `n` index — **bit-identical** to running the
/// frames one at a time).
pub fn direct_conv2d_grouped_batched_into(
    batch: &TensorView,
    weights: &Tensor,
    stride: (usize, usize),
    pad: (usize, usize),
    groups: usize,
    nb: usize,
    out: &mut [f32],
) -> Result<()> {
    super::check_batch_dim(batch, nb)?;
    direct_conv2d_grouped_into(batch, weights, stride, pad, groups, out)
}

/// FLOP count of a direct convolution (the roofline denominator used in the
/// bench reports): 2·N·OH·OW·KH·KW·C·M.
pub fn conv_flops(
    n: usize,
    oh: usize,
    ow: usize,
    kh: usize,
    kw: usize,
    c: usize,
    m: usize,
) -> usize {
    2 * n * oh * ow * kh * kw * c * m
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Batched grouped direct == the same frames run one at a time,
    /// bit for bit, into a NaN-poisoned buffer — and the entry rejects a
    /// frame-count mismatch.
    #[test]
    fn grouped_batched_matches_sequential_bitwise() {
        let (nb, h, w, c, groups) = (3usize, 5usize, 6usize, 4usize, 2usize);
        let input = Tensor::randn(&[nb, h, w, c], 21);
        let weights = Tensor::randn(&[6, 3, 3, c / groups], 22);
        let frame = h * w * c;
        let mut want: Vec<f32> = Vec::new();
        for f in 0..nb {
            let ft = Tensor::from_vec(
                &[1, h, w, c],
                input.data()[f * frame..(f + 1) * frame].to_vec(),
            )
            .unwrap();
            let o = direct_conv2d_grouped(&ft, &weights, (1, 1), (1, 1), groups).unwrap();
            want.extend_from_slice(o.data());
        }
        let mut got = vec![f32::NAN; want.len()];
        direct_conv2d_grouped_batched_into(
            &input.view(),
            &weights,
            (1, 1),
            (1, 1),
            groups,
            nb,
            &mut got,
        )
        .unwrap();
        assert!(got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));
        let twin =
            direct_conv2d_grouped_batched(&input, &weights, (1, 1), (1, 1), groups, nb).unwrap();
        assert_eq!(got, *twin.data());
        assert!(
            direct_conv2d_grouped_batched(&input, &weights, (1, 1), (1, 1), groups, 2).is_err(),
            "nb = 2 must reject a 3-frame tensor"
        );
    }

    #[test]
    fn identity_kernel_passthrough() {
        // 1×1 kernel with identity channel-mixing copies the input.
        let input = Tensor::randn(&[1, 4, 4, 2], 1);
        let mut w = Tensor::zeros(&[2, 1, 1, 2]);
        *w.at4_mut(0, 0, 0, 0) = 1.0;
        *w.at4_mut(1, 0, 0, 1) = 1.0;
        let out = direct_conv2d(&input, &w, (1, 1), (0, 0)).unwrap();
        assert_eq!(out, input);
    }

    #[test]
    fn hand_computed_3x3() {
        // All-ones 3×3 input, all-ones 3×3 kernel, no pad: single output = 9.
        let input = Tensor::full(&[1, 3, 3, 1], 1.0);
        let w = Tensor::full(&[1, 3, 3, 1], 1.0);
        let out = direct_conv2d(&input, &w, (1, 1), (0, 0)).unwrap();
        assert_eq!(out.shape(), &[1, 1, 1, 1]);
        assert_eq!(out.data()[0], 9.0);
        // With pad 1 the corner output sees only 4 taps.
        let out = direct_conv2d(&input, &w, (1, 1), (1, 1)).unwrap();
        assert_eq!(out.shape(), &[1, 3, 3, 1]);
        assert_eq!(out.at4(0, 0, 0, 0), 4.0);
        assert_eq!(out.at4(0, 1, 1, 0), 9.0);
        assert_eq!(out.at4(0, 0, 1, 0), 6.0);
    }

    #[test]
    fn stride_two_downsamples() {
        let input = Tensor::randn(&[1, 7, 7, 1], 2);
        let w = Tensor::randn(&[1, 3, 3, 1], 3);
        let out = direct_conv2d(&input, &w, (2, 2), (0, 0)).unwrap();
        assert_eq!(out.shape(), &[1, 3, 3, 1]);
    }

    #[test]
    fn channel_summation() {
        // Two input channels with weights (1, 10): output = c0 + 10·c1.
        let mut input = Tensor::zeros(&[1, 1, 1, 2]);
        input.data_mut()[0] = 3.0;
        input.data_mut()[1] = 5.0;
        let mut w = Tensor::zeros(&[1, 1, 1, 2]);
        w.data_mut()[0] = 1.0;
        w.data_mut()[1] = 10.0;
        let out = direct_conv2d(&input, &w, (1, 1), (0, 0)).unwrap();
        assert_eq!(out.data()[0], 53.0);
    }

    #[test]
    fn errors_on_bad_config() {
        let input = Tensor::zeros(&[1, 4, 4, 2]);
        let w = Tensor::zeros(&[1, 3, 3, 3]);
        assert!(direct_conv2d(&input, &w, (1, 1), (0, 0)).is_err()); // channel mismatch
        let w = Tensor::zeros(&[1, 5, 5, 2]);
        assert!(direct_conv2d(&input, &w, (1, 1), (0, 0)).is_err()); // too small
        let w = Tensor::zeros(&[1, 3, 3, 2]);
        assert!(direct_conv2d(&input, &w, (0, 1), (0, 0)).is_err()); // zero stride
    }

    #[test]
    fn flops_formula() {
        assert_eq!(conv_flops(1, 2, 2, 3, 3, 4, 5), 2 * 2 * 2 * 9 * 4 * 5);
    }

    /// Grouped == dense when groups = 1; depthwise (groups = C = M) equals
    /// per-channel 2-D correlation computed by hand on a tiny case.
    #[test]
    fn grouped_matches_dense_and_hand_depthwise() {
        // groups = 1 reduces to the dense oracle.
        let input = Tensor::randn(&[1, 5, 6, 4], 31);
        let w = Tensor::randn(&[6, 3, 3, 4], 32);
        let dense = direct_conv2d(&input, &w, (1, 1), (1, 1)).unwrap();
        let grouped = direct_conv2d_grouped(&input, &w, (1, 1), (1, 1), 1).unwrap();
        assert_eq!(dense, grouped);

        // Depthwise: 2 channels, 1×1 taps scale each channel independently.
        let mut input = Tensor::zeros(&[1, 1, 1, 2]);
        input.data_mut().copy_from_slice(&[3.0, 5.0]);
        let mut w = Tensor::zeros(&[2, 1, 1, 1]);
        w.data_mut().copy_from_slice(&[2.0, 10.0]);
        let out = direct_conv2d_grouped(&input, &w, (1, 1), (0, 0), 2).unwrap();
        assert_eq!(out.data(), &[6.0, 50.0]);

        // Grouped with 2 groups of 2 channels: group sums stay separate.
        let input = Tensor::from_vec(&[1, 1, 1, 4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let w = Tensor::from_vec(&[2, 1, 1, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let out = direct_conv2d_grouped(&input, &w, (1, 1), (0, 0), 2).unwrap();
        assert_eq!(out.data(), &[3.0, 7.0]);

        // Bad group configs are rejected.
        let input = Tensor::zeros(&[1, 4, 4, 4]);
        let w = Tensor::zeros(&[4, 3, 3, 2]);
        assert!(direct_conv2d_grouped(&input, &w, (1, 1), (1, 1), 3).is_err());
        assert!(direct_conv2d_grouped(&input, &w, (1, 1), (1, 1), 4).is_err()); // wc != c/g
        assert!(direct_conv2d_grouped(&input, &w, (1, 1), (1, 1), 0).is_err());
    }

    /// The write-into oracle matches the allocating wrapper bit-for-bit on
    /// a dirty destination, and rejects a wrong-size slice.
    #[test]
    fn into_variant_matches_allocating() {
        let input = Tensor::randn(&[2, 6, 7, 3], 4);
        let w = Tensor::randn(&[5, 3, 3, 3], 5);
        let want = direct_conv2d(&input, &w, (2, 1), (1, 0)).unwrap();
        let mut out = vec![f32::NAN; want.len()];
        direct_conv2d_into(&input.view(), &w, (2, 1), (1, 0), &mut out).unwrap();
        assert_eq!(out, want.data());
        assert!(direct_conv2d_into(&input.view(), &w, (2, 1), (1, 0), &mut out[1..]).is_err());
    }
}
