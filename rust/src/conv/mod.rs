//! Public convolution API: one descriptor, pluggable algorithms, plus the
//! per-layer selection heuristic (§3.2 of the paper: "layers suitable for
//! Winograd-based acceleration use our scheme, the rest use im2row" —
//! extended with the direct depthwise engine for grouped layers, where the
//! paper's C·M amortization argument does not apply).

pub mod depthwise;
pub mod direct;
pub mod pointwise;
pub mod select;

pub use select::{select_algorithm, select_algorithm_spatial, select_algorithm_spatial_dtype};

/// Fused pointwise activation (none / ReLU / ReLU6) — defined next to the
/// GEMM epilogues that apply it, re-exported here for descriptor use.
pub use crate::gemm::Activation;

use crate::im2row::Im2RowConvolution;
use crate::parallel::ThreadPool;
use crate::quant::{
    Dtype, QuantDepthwiseConvolution, QuantIm2RowConvolution, QuantPointwiseConvolution,
};
use crate::tensor::Tensor;
use crate::winograd::{WinogradConvolution, WinogradVariant};
use crate::workspace::Workspace;
use crate::{bail_shape, bail_unsupported, Result};
use depthwise::DepthwiseConvolution;
use pointwise::PointwiseConvolution;

/// Which implementation executes a convolution layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ConvAlgorithm {
    /// Naive oracle (tests / tiny shapes / exotic grouped fallback).
    Direct,
    /// Direct register-tiled SIMD depthwise engine
    /// ([`depthwise::DepthwiseConvolution`]) — 3×3 layers with
    /// `groups == cin == cout` at stride 1 or 2.
    DirectDepthwise,
    /// Zero-copy direct pointwise engine
    /// ([`pointwise::PointwiseConvolution`]) — dense unpadded 1×1 layers
    /// at stride 1 (input read in place) or 2 (strided row gather).
    DirectPointwise,
    /// Classical im2row + single GEMM (the paper's baseline).
    Im2Row,
    /// Quantized im2row + int8 GEMM
    /// ([`crate::quant::QuantIm2RowConvolution`]) — dense spatial layers
    /// under [`Dtype::Int8`]. The int8 routing never picks Winograd.
    Im2RowI8,
    /// Quantized direct depthwise engine
    /// ([`crate::quant::QuantDepthwiseConvolution`]) — depthwise 3×3
    /// layers under [`Dtype::Int8`].
    DirectDepthwiseI8,
    /// Quantized direct pointwise engine
    /// ([`crate::quant::QuantPointwiseConvolution`]) — dense unpadded 1×1
    /// layers under [`Dtype::Int8`].
    DirectPointwiseI8,
    /// Region-wise multi-channel Winograd with an explicit variant.
    Winograd(WinogradVariant),
    /// Pick automatically per layer shape ([`select_algorithm_spatial`]).
    Auto,
}

impl std::fmt::Display for ConvAlgorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConvAlgorithm::Direct => write!(f, "direct"),
            ConvAlgorithm::DirectDepthwise => write!(f, "depthwise"),
            ConvAlgorithm::DirectPointwise => write!(f, "pointwise"),
            ConvAlgorithm::Im2Row => write!(f, "im2row"),
            ConvAlgorithm::Im2RowI8 => write!(f, "im2row-i8"),
            ConvAlgorithm::DirectDepthwiseI8 => write!(f, "depthwise-i8"),
            ConvAlgorithm::DirectPointwiseI8 => write!(f, "pointwise-i8"),
            ConvAlgorithm::Winograd(v) => write!(f, "winograd-{v}"),
            ConvAlgorithm::Auto => write!(f, "auto"),
        }
    }
}

/// Shorthand constructors used across benches/examples.
impl ConvAlgorithm {
    /// The paper's headline 3×3 configuration.
    pub const WINOGRAD_F4X4_3X3: ConvAlgorithm = ConvAlgorithm::Winograd(WinogradVariant::F4x4_3x3);
}

/// Fused per-output-channel bias + activation, applied inside the conv's
/// GEMM epilogue (never as a separate pass — conv outputs are written
/// exactly once, already biased/activated).
///
/// Consulted by the `Conv2d::run*` family only. Graph nodes
/// ([`crate::nn::Op::Conv`]) carry bias/activation directly on the op, and
/// `PreparedModel::prepare` rejects a non-noop descriptor epilogue to keep
/// a single source of truth.
#[derive(Debug, Clone, Default)]
pub struct ConvEpilogue {
    /// Per-output-channel bias (length `cout`), added in the epilogue.
    pub bias: Option<Vec<f32>>,
    /// Activation applied after the bias (ReLU, or MobileNet's ReLU6).
    pub act: Activation,
}

impl ConvEpilogue {
    /// Does this descriptor do anything at all?
    pub fn is_noop(&self) -> bool {
        self.bias.is_none() && self.act.is_none()
    }
}

/// A 2-D convolution layer descriptor with a chosen algorithm.
///
/// ```no_run
/// use winoconv::conv::{Conv2d, ConvAlgorithm};
/// use winoconv::tensor::Tensor;
/// let conv = Conv2d::new(32, 64, (3, 3)).with_padding((1, 1));
/// let x = Tensor::randn(&[1, 28, 28, 32], 1);
/// let w = conv.random_weights(2);
/// let y = conv.run(&x, &w).unwrap();
/// assert_eq!(y.shape(), &[1, 28, 28, 64]);
/// ```
#[derive(Debug, Clone)]
pub struct Conv2d {
    /// Input channels.
    pub cin: usize,
    /// Output channels.
    pub cout: usize,
    /// Filter extent `(kh, kw)`.
    pub kernel: (usize, usize),
    /// Stride `(sh, sw)`.
    pub stride: (usize, usize),
    /// Symmetric zero padding `(ph, pw)`.
    pub padding: (usize, usize),
    /// Channel groups (1 = dense; `groups == cin == cout` = depthwise).
    /// Weights carry `cin / groups` channels: `[M, KH, KW, C/groups]`.
    pub groups: usize,
    /// Algorithm choice (default [`ConvAlgorithm::Auto`]).
    pub algorithm: ConvAlgorithm,
    /// Element type the layer computes in (default [`Dtype::F32`]).
    /// [`Dtype::Int8`] makes `Auto` resolve through the int8 routing
    /// ([`select_algorithm_spatial_dtype`]) — never Winograd.
    pub dtype: Dtype,
    /// Fused bias/activation descriptor (default: none) — executed inside
    /// the GEMM epilogue on every algorithm path.
    pub epilogue: ConvEpilogue,
}

impl Conv2d {
    /// New stride-1, unpadded, dense, auto-algorithm layer with no fused
    /// epilogue.
    pub fn new(cin: usize, cout: usize, kernel: (usize, usize)) -> Conv2d {
        Conv2d {
            cin,
            cout,
            kernel,
            stride: (1, 1),
            padding: (0, 0),
            groups: 1,
            algorithm: ConvAlgorithm::Auto,
            dtype: Dtype::F32,
            epilogue: ConvEpilogue::default(),
        }
    }

    /// Builder: set the stride.
    pub fn with_stride(mut self, stride: (usize, usize)) -> Conv2d {
        self.stride = stride;
        self
    }

    /// Builder: set the padding.
    pub fn with_padding(mut self, padding: (usize, usize)) -> Conv2d {
        self.padding = padding;
        self
    }

    /// Builder: set the channel grouping (`groups == cin == cout` makes the
    /// layer depthwise; weights then carry `cin / groups` channels each).
    pub fn with_groups(mut self, groups: usize) -> Conv2d {
        self.groups = groups;
        self
    }

    /// Builder: force an algorithm.
    pub fn with_algorithm(mut self, algorithm: ConvAlgorithm) -> Conv2d {
        self.algorithm = algorithm;
        self
    }

    /// Builder: set the compute dtype ([`Dtype::Int8`] = dynamic-range
    /// quantized inference; see [`crate::quant`]).
    pub fn with_dtype(mut self, dtype: Dtype) -> Conv2d {
        self.dtype = dtype;
        self
    }

    /// Builder: fuse a per-output-channel bias (length `cout`) into the
    /// conv's epilogue.
    pub fn with_bias(mut self, bias: Vec<f32>) -> Conv2d {
        self.epilogue.bias = Some(bias);
        self
    }

    /// Builder: fuse a ReLU (after any bias) into the conv's epilogue.
    pub fn with_relu(mut self, relu: bool) -> Conv2d {
        self.epilogue.act = Activation::from_relu(relu);
        self
    }

    /// Builder: fuse an arbitrary activation (ReLU / ReLU6) into the
    /// conv's epilogue.
    pub fn with_activation(mut self, act: Activation) -> Conv2d {
        self.epilogue.act = act;
        self
    }

    /// Deterministic He-style random weights `[M, KH, KW, C/groups]`.
    pub fn random_weights(&self, seed: u64) -> Tensor {
        let cg = self.cin / self.groups.max(1);
        let fan_in = (self.kernel.0 * self.kernel.1 * cg) as f32;
        let mut w = Tensor::randn(&[self.cout, self.kernel.0, self.kernel.1, cg], seed);
        let scale = (2.0 / fan_in).sqrt();
        for v in w.data_mut() {
            *v *= scale;
        }
        w
    }

    /// Resolve [`ConvAlgorithm::Auto`] for this layer shape, without input
    /// shape information (channel/kernel/stride/group heuristics only, via
    /// the unified chooser). Prefer
    /// [`resolved_algorithm_for`](Self::resolved_algorithm_for) when the
    /// input shape is known — small feature maps then get the 2×2-tile
    /// variant instead of wasting partial 4×4 tiles.
    pub fn resolved_algorithm(&self) -> ConvAlgorithm {
        match self.algorithm {
            ConvAlgorithm::Auto => select_algorithm_spatial_dtype(
                self.dtype,
                self.kernel,
                self.stride,
                self.padding,
                self.groups,
                self.cin,
                self.cout,
                None,
            ),
            a => a,
        }
    }

    /// Resolve [`ConvAlgorithm::Auto`] with the input shape in hand: the
    /// single spatial-aware chooser ([`select_algorithm_spatial`]) sees the
    /// output extent, so small maps refine the Winograd variant by the
    /// paper's partial-tile argument. This is what [`run_with`](Self::run_with)
    /// and the prepared-model binder use — run path and zoo path can no
    /// longer disagree on the variant.
    pub fn resolved_algorithm_for(&self, input_shape: &[usize]) -> ConvAlgorithm {
        match self.algorithm {
            ConvAlgorithm::Auto => {
                let out_hw = match self.output_shape(input_shape) {
                    Ok(out) => Some((out[1], out[2])),
                    // Bad shapes fail properly at run time.
                    Err(_) => None,
                };
                select_algorithm_spatial_dtype(
                    self.dtype,
                    self.kernel,
                    self.stride,
                    self.padding,
                    self.groups,
                    self.cin,
                    self.cout,
                    out_hw,
                )
            }
            a => a,
        }
    }

    /// Execute serially.
    pub fn run(&self, input: &Tensor, weights: &Tensor) -> Result<Tensor> {
        self.run_with(input, weights, None)
    }

    /// Execute, optionally parallelised over `pool`.
    pub fn run_with(
        &self,
        input: &Tensor,
        weights: &Tensor,
        pool: Option<&ThreadPool>,
    ) -> Result<Tensor> {
        let mut ws = Workspace::new();
        self.run_with_workspace(input, weights, pool, &mut ws)
    }

    /// [`run_with`](Self::run_with) drawing all layer scratch from a
    /// caller-owned arena (see [`crate::workspace`]).
    ///
    /// The layer's [`ConvEpilogue`] (bias/activation) executes fused on
    /// every fast path: inside the GEMM epilogue for im2row, inside the
    /// gather epilogue for Winograd, in-register for the depthwise engine,
    /// and as a post pass only on the `Direct` oracle (which has no fused
    /// pipeline).
    pub fn run_with_workspace(
        &self,
        input: &Tensor,
        weights: &Tensor,
        pool: Option<&ThreadPool>,
        ws: &mut Workspace,
    ) -> Result<Tensor> {
        if self.groups == 0 || self.cin % self.groups != 0 || self.cout % self.groups != 0 {
            bail_shape!(
                "groups {} does not divide cin {} / cout {}",
                self.groups,
                self.cin,
                self.cout
            );
        }
        let bias = self.epilogue.bias.as_deref();
        if let Some(b) = bias {
            if b.len() != self.cout {
                crate::bail_shape!("bias length {} vs {} output channels", b.len(), self.cout);
            }
        }
        let act = self.epilogue.act;
        match self.resolved_algorithm_for(input.shape()) {
            ConvAlgorithm::Direct => {
                let mut y = direct::direct_conv2d_grouped(
                    input,
                    weights,
                    self.stride,
                    self.padding,
                    self.groups,
                )?;
                apply_bias_act(&mut y, bias, act)?;
                Ok(y)
            }
            ConvAlgorithm::DirectDepthwise => {
                if self.groups != self.cin || self.groups != self.cout {
                    bail_unsupported!(
                        "depthwise engine requires groups == cin == cout, layer has {}/{}/{}",
                        self.groups,
                        self.cin,
                        self.cout
                    );
                }
                DepthwiseConvolution::new(weights, self.stride, self.padding)?
                    .run_fused_with(input, pool, bias, act, ws)
            }
            ConvAlgorithm::DirectPointwise => {
                if self.groups != 1 {
                    bail_unsupported!(
                        "pointwise path is dense-only, layer has {} groups",
                        self.groups
                    );
                }
                PointwiseConvolution::new(weights, self.stride, self.padding)?
                    .run_fused_with(input, pool, bias, act, ws)
            }
            ConvAlgorithm::Im2Row => {
                if self.groups != 1 {
                    bail_unsupported!("im2row path is dense-only, layer has {} groups", self.groups);
                }
                Im2RowConvolution::new(weights, self.stride, self.padding)?
                    .run_fused_with(input, pool, bias, act, ws)
            }
            ConvAlgorithm::Im2RowI8 => {
                if self.groups != 1 {
                    bail_unsupported!(
                        "im2row-i8 path is dense-only, layer has {} groups",
                        self.groups
                    );
                }
                QuantIm2RowConvolution::new(weights, self.stride, self.padding)?
                    .run_fused_i8_with(input, pool, bias, act, ws)
            }
            ConvAlgorithm::DirectDepthwiseI8 => {
                if self.groups != self.cin || self.groups != self.cout {
                    bail_unsupported!(
                        "depthwise-i8 engine requires groups == cin == cout, layer has {}/{}/{}",
                        self.groups,
                        self.cin,
                        self.cout
                    );
                }
                QuantDepthwiseConvolution::new(weights, self.stride, self.padding)?
                    .run_fused_i8_with(input, pool, bias, act, ws)
            }
            ConvAlgorithm::DirectPointwiseI8 => {
                if self.groups != 1 {
                    bail_unsupported!(
                        "pointwise-i8 path is dense-only, layer has {} groups",
                        self.groups
                    );
                }
                QuantPointwiseConvolution::new(weights, self.stride, self.padding)?
                    .run_fused_i8_with(input, pool, bias, act, ws)
            }
            ConvAlgorithm::Winograd(v) => {
                if self.groups != 1 {
                    bail_unsupported!(
                        "Winograd path is dense-only, layer has {} groups",
                        self.groups
                    );
                }
                if self.stride != (1, 1) {
                    bail_unsupported!("Winograd requires stride 1, layer has {:?}", self.stride);
                }
                WinogradConvolution::new(v, weights, self.padding)?
                    .run_fused_with(input, pool, bias, act, ws)
            }
            ConvAlgorithm::Auto => unreachable!("resolved above"),
        }
    }

    /// Output shape for a given input shape.
    pub fn output_shape(&self, input: &[usize]) -> Result<Vec<usize>> {
        let (n, h, w) = (input[0], input[1], input[2]);
        let (kh, kw) = self.kernel;
        let (ph, pw) = self.padding;
        let (sh, sw) = self.stride;
        if h + 2 * ph < kh || w + 2 * pw < kw {
            crate::bail_shape!("input {h}x{w} too small for {kh}x{kw} (pad {ph},{pw})");
        }
        Ok(vec![
            n,
            (h + 2 * ph - kh) / sh + 1,
            (w + 2 * pw - kw) / sw + 1,
            self.cout,
        ])
    }

    /// FLOPs for one inference through this layer on `input` shape — each
    /// output channel convolves `cin / groups` input channels.
    pub fn flops(&self, input: &[usize]) -> Result<usize> {
        let out = self.output_shape(input)?;
        Ok(direct::conv_flops(
            out[0],
            out[1],
            out[2],
            self.kernel.0,
            self.kernel.1,
            self.cin / self.groups.max(1),
            self.cout,
        ))
    }
}

impl Conv2d {
    /// Batched descriptor execution: `nb` frames gathered contiguously as
    /// one `[nb, H, W, C]` input run as a **single** pass of the resolved
    /// engine — one packed-B weight-panel traversal, `nb`× the packed-A
    /// rows/regions — instead of `nb` back-to-back batch-1 walks. Validates
    /// that the input's leading dimension carries exactly the declared
    /// batch, then delegates to
    /// [`run_with_workspace`](Self::run_with_workspace) (every engine
    /// folds N into its GEMM/region row space natively). Bit-identical to
    /// the sequential walks it amortizes.
    pub fn run_batched_with_workspace(
        &self,
        batch: &Tensor,
        weights: &Tensor,
        nb: usize,
        pool: Option<&ThreadPool>,
        ws: &mut Workspace,
    ) -> Result<Tensor> {
        check_batch_dim(&batch.view(), nb)?;
        self.run_with_workspace(batch, weights, pool, ws)
    }
}

/// Shared guard for the conv stack's `*_batched_into` entry points: the
/// view must be rank 4 and its leading dimension must carry exactly the
/// declared batch. Kept allocation-free — it sits on every batched hot
/// path.
pub(crate) fn check_batch_dim(batch: &crate::tensor::TensorView, nb: usize) -> Result<()> {
    if batch.rank() != 4 {
        bail_shape!("batch must be [NB, H, W, C], got {:?}", batch.shape());
    }
    if nb == 0 || batch.shape()[0] != nb {
        bail_shape!(
            "batched entry declared nb = {nb}, view carries {} frames",
            batch.shape()[0]
        );
    }
    Ok(())
}

/// Post-pass bias/activation for the `Direct` oracle path. The fused paths
/// never call this — their epilogues apply it in-flight. Delegates to the
/// shared [`crate::nn::ops`] helpers so the oracle semantics have one
/// source of truth.
fn apply_bias_act(t: &mut Tensor, bias: Option<&[f32]>, act: Activation) -> Result<()> {
    match bias {
        Some(b) => crate::nn::ops::bias_act_inplace(t, b, act),
        None => {
            crate::nn::ops::act_inplace(t, act);
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_algorithms_agree() {
        let conv = Conv2d::new(4, 8, (3, 3)).with_padding((1, 1));
        let x = Tensor::randn(&[1, 10, 10, 4], 1);
        let w = conv.random_weights(2);
        let direct = conv
            .clone()
            .with_algorithm(ConvAlgorithm::Direct)
            .run(&x, &w)
            .unwrap();
        for alg in [
            ConvAlgorithm::Im2Row,
            ConvAlgorithm::Winograd(WinogradVariant::F2x2_3x3),
            ConvAlgorithm::Winograd(WinogradVariant::F4x4_3x3),
            ConvAlgorithm::Auto,
        ] {
            let got = conv.clone().with_algorithm(alg).run(&x, &w).unwrap();
            assert!(got.allclose(&direct, 5e-4), "algorithm {alg} disagrees");
        }
    }

    /// The fused bias/activation descriptor must produce identical results
    /// on every algorithm path (direct applies it as a post pass; im2row
    /// and Winograd fuse it into their GEMM epilogues) — for both ReLU and
    /// ReLU6.
    #[test]
    fn epilogue_descriptor_agrees_across_algorithms() {
        for act in [Activation::Relu, Activation::Relu6] {
            let bias: Vec<f32> = (0..8).map(|i| i as f32 * 1.2 - 1.2).collect();
            let conv = Conv2d::new(4, 8, (3, 3))
                .with_padding((1, 1))
                .with_bias(bias)
                .with_activation(act);
            let x = Tensor::randn(&[1, 10, 10, 4], 21);
            let w = conv.random_weights(22);
            let direct = conv
                .clone()
                .with_algorithm(ConvAlgorithm::Direct)
                .run(&x, &w)
                .unwrap();
            // Both clamps must actually fire somewhere for this to test
            // fusion (the large bias spread guarantees > 6 pre-activation
            // values for the ReLU6 case).
            assert!(direct.data().iter().any(|&v| v == 0.0));
            if act == Activation::Relu6 {
                assert!(direct.data().iter().any(|&v| v == 6.0));
                assert!(direct.data().iter().all(|&v| v <= 6.0));
            }
            for alg in [
                ConvAlgorithm::Im2Row,
                ConvAlgorithm::Winograd(WinogradVariant::F2x2_3x3),
                ConvAlgorithm::Winograd(WinogradVariant::F4x4_3x3),
                ConvAlgorithm::Auto,
            ] {
                let got = conv.clone().with_algorithm(alg).run(&x, &w).unwrap();
                assert!(got.allclose(&direct, 5e-4), "algorithm {alg} ({act}) disagrees");
            }
            // A wrong-length bias is rejected on every path.
            let bad = conv.clone().with_bias(vec![0.0; 3]);
            assert!(bad.run(&x, &w).is_err());
        }
    }

    /// A depthwise descriptor auto-routes to the depthwise engine and
    /// agrees with the grouped direct oracle, epilogue included.
    #[test]
    fn depthwise_descriptor_routes_and_agrees() {
        let c = 10;
        let bias: Vec<f32> = (0..c).map(|i| i as f32 * 0.3 - 1.0).collect();
        for stride in [(1, 1), (2, 2)] {
            let conv = Conv2d::new(c, c, (3, 3))
                .with_groups(c)
                .with_stride(stride)
                .with_padding((1, 1))
                .with_bias(bias.clone())
                .with_activation(Activation::Relu6);
            assert_eq!(
                conv.resolved_algorithm_for(&[1, 12, 12, c]),
                ConvAlgorithm::DirectDepthwise
            );
            let x = Tensor::randn(&[1, 12, 12, c], 5);
            let w = conv.random_weights(6);
            assert_eq!(w.shape(), &[c, 3, 3, 1]);
            let got = conv.run(&x, &w).unwrap();
            let want = conv
                .clone()
                .with_algorithm(ConvAlgorithm::Direct)
                .run(&x, &w)
                .unwrap();
            assert!(got.allclose(&want, 5e-4), "depthwise stride {stride:?} disagrees");
        }
        // A grouped-but-not-depthwise layer falls back to the grouped
        // direct oracle and still runs.
        let conv = Conv2d::new(8, 16, (3, 3)).with_groups(4).with_padding((1, 1));
        assert_eq!(conv.resolved_algorithm(), ConvAlgorithm::Direct);
        let x = Tensor::randn(&[1, 6, 6, 8], 7);
        let w = conv.random_weights(8);
        assert_eq!(w.shape(), &[16, 3, 3, 2]);
        assert_eq!(conv.run(&x, &w).unwrap().shape(), &[1, 6, 6, 16]);
        // Invalid grouping is rejected.
        let bad = Conv2d::new(8, 16, (3, 3)).with_groups(3);
        assert!(bad.run(&x, &w).is_err());
    }

    #[test]
    fn winograd_rejects_stride() {
        let conv = Conv2d::new(2, 2, (3, 3))
            .with_stride((2, 2))
            .with_algorithm(ConvAlgorithm::Winograd(WinogradVariant::F4x4_3x3));
        let x = Tensor::randn(&[1, 8, 8, 2], 1);
        let w = conv.random_weights(2);
        assert!(conv.run(&x, &w).is_err());
    }

    #[test]
    fn auto_resolves_per_shape() {
        // 3×3 s1 → Winograd; 3×3 s2 → im2row; 1×1 → the pointwise engine
        // (stride 1 and 2); padded 1×1 → im2row; depthwise → the depthwise
        // engine.
        let a = Conv2d::new(16, 16, (3, 3)).resolved_algorithm();
        assert!(matches!(a, ConvAlgorithm::Winograd(_)));
        let a = Conv2d::new(16, 16, (3, 3)).with_stride((2, 2)).resolved_algorithm();
        assert_eq!(a, ConvAlgorithm::Im2Row);
        let a = Conv2d::new(16, 16, (1, 1)).resolved_algorithm();
        assert_eq!(a, ConvAlgorithm::DirectPointwise);
        let a = Conv2d::new(16, 16, (1, 1)).with_stride((2, 2)).resolved_algorithm();
        assert_eq!(a, ConvAlgorithm::DirectPointwise);
        let a = Conv2d::new(16, 16, (1, 1)).with_padding((1, 1)).resolved_algorithm();
        assert_eq!(a, ConvAlgorithm::Im2Row);
        let a = Conv2d::new(16, 16, (3, 3)).with_groups(16).resolved_algorithm();
        assert_eq!(a, ConvAlgorithm::DirectDepthwise);
    }

    /// A 1×1 descriptor auto-routes to the pointwise engine and agrees with
    /// the direct oracle, epilogue included, at both supported strides.
    #[test]
    fn pointwise_descriptor_routes_and_agrees() {
        let bias: Vec<f32> = (0..24).map(|i| i as f32 * 0.3 - 2.0).collect();
        for stride in [(1, 1), (2, 2)] {
            let conv = Conv2d::new(16, 24, (1, 1))
                .with_stride(stride)
                .with_bias(bias.clone())
                .with_activation(Activation::Relu6);
            assert_eq!(
                conv.resolved_algorithm_for(&[1, 12, 12, 16]),
                ConvAlgorithm::DirectPointwise
            );
            let x = Tensor::randn(&[1, 12, 12, 16], 5);
            let w = conv.random_weights(6);
            assert_eq!(w.shape(), &[24, 1, 1, 16]);
            let got = conv.run(&x, &w).unwrap();
            let want = conv
                .clone()
                .with_algorithm(ConvAlgorithm::Direct)
                .run(&x, &w)
                .unwrap();
            assert!(got.allclose(&want, 5e-4), "pointwise stride {stride:?} disagrees");
            // And bit-identical to the forced im2row baseline it replaces.
            let base = conv
                .clone()
                .with_algorithm(ConvAlgorithm::Im2Row)
                .run(&x, &w)
                .unwrap();
            assert_eq!(got.data(), base.data(), "pointwise must match im2row bitwise");
        }
    }

    #[test]
    fn auto_refines_variant_by_input_shape() {
        let conv = Conv2d::new(16, 16, (3, 3)).with_padding((1, 1));
        // Large map: the 4×4 tile amortises best.
        assert_eq!(
            conv.resolved_algorithm_for(&[1, 56, 56, 16]),
            ConvAlgorithm::Winograd(WinogradVariant::F4x4_3x3)
        );
        // Small map: partial 4×4 tiles would dominate; refine to 2×2.
        assert_eq!(
            conv.resolved_algorithm_for(&[1, 4, 4, 16]),
            ConvAlgorithm::Winograd(WinogradVariant::F2x2_3x3)
        );
        // Non-Winograd resolutions pass through untouched.
        let strided = Conv2d::new(16, 16, (3, 3)).with_stride((2, 2));
        assert_eq!(
            strided.resolved_algorithm_for(&[1, 56, 56, 16]),
            ConvAlgorithm::Im2Row
        );
        // An explicitly forced variant is never second-guessed.
        let forced = Conv2d::new(16, 16, (3, 3))
            .with_padding((1, 1))
            .with_algorithm(ConvAlgorithm::Winograd(WinogradVariant::F4x4_3x3));
        assert_eq!(
            forced.resolved_algorithm_for(&[1, 4, 4, 16]),
            ConvAlgorithm::Winograd(WinogradVariant::F4x4_3x3)
        );
    }

    #[test]
    fn small_map_auto_matches_direct() {
        // The refined small-map path must stay numerically correct.
        let conv = Conv2d::new(8, 16, (3, 3)).with_padding((1, 1));
        let x = Tensor::randn(&[1, 4, 4, 8], 3);
        let w = conv.random_weights(4);
        let direct = conv
            .clone()
            .with_algorithm(ConvAlgorithm::Direct)
            .run(&x, &w)
            .unwrap();
        let auto = conv.run(&x, &w).unwrap();
        assert!(auto.allclose(&direct, 5e-4));
    }

    /// An Int8 descriptor auto-resolves onto the quantized engines (never
    /// Winograd) and tracks the f32 oracle within quantization tolerance on
    /// every routed shape.
    #[test]
    fn int8_descriptor_routes_and_tracks_f32() {
        use crate::util::rel_error;
        // Dense 3×3 s1 (f32 would take Winograd) → im2row-i8.
        let conv = Conv2d::new(8, 16, (3, 3))
            .with_padding((1, 1))
            .with_dtype(Dtype::Int8);
        assert_eq!(
            conv.resolved_algorithm_for(&[1, 12, 12, 8]),
            ConvAlgorithm::Im2RowI8
        );
        let x = Tensor::randn(&[1, 12, 12, 8], 201);
        let w = conv.random_weights(202);
        let got = conv.run(&x, &w).unwrap();
        let want = conv.clone().with_dtype(Dtype::F32).run(&x, &w).unwrap();
        assert_eq!(got.shape(), want.shape());
        assert!(rel_error(got.data(), want.data()) < 0.05);

        // Depthwise → depthwise-i8.
        let dw = Conv2d::new(8, 8, (3, 3))
            .with_groups(8)
            .with_padding((1, 1))
            .with_dtype(Dtype::Int8);
        assert_eq!(dw.resolved_algorithm(), ConvAlgorithm::DirectDepthwiseI8);
        let w = dw.random_weights(203);
        let got = dw.run(&x, &w).unwrap();
        let want = dw.clone().with_dtype(Dtype::F32).run(&x, &w).unwrap();
        assert!(rel_error(got.data(), want.data()) < 0.05);

        // Dense 1×1 → pointwise-i8.
        let pw = Conv2d::new(8, 12, (1, 1)).with_dtype(Dtype::Int8);
        assert_eq!(pw.resolved_algorithm(), ConvAlgorithm::DirectPointwiseI8);
        let w = pw.random_weights(204);
        let got = pw.run(&x, &w).unwrap();
        let want = pw.clone().with_dtype(Dtype::F32).run(&x, &w).unwrap();
        assert!(rel_error(got.data(), want.data()) < 0.05);

        // Forced quantized algorithms reject incompatible groupings.
        let bad = Conv2d::new(8, 16, (3, 3))
            .with_groups(4)
            .with_algorithm(ConvAlgorithm::Im2RowI8);
        assert!(bad.run(&x, &Tensor::zeros(&[16, 3, 3, 2])).is_err());
    }

    #[test]
    fn output_shape_and_flops() {
        let conv = Conv2d::new(3, 8, (3, 3)).with_padding((1, 1));
        assert_eq!(conv.output_shape(&[2, 8, 8, 3]).unwrap(), vec![2, 8, 8, 8]);
        assert_eq!(
            conv.flops(&[1, 8, 8, 3]).unwrap(),
            2 * 8 * 8 * 9 * 3 * 8
        );
        let unpadded = Conv2d::new(3, 8, (3, 3));
        assert!(unpadded.output_shape(&[1, 1, 1, 3]).is_err());
        // Depthwise FLOPs: one input channel per output channel.
        let dw = Conv2d::new(8, 8, (3, 3)).with_groups(8).with_padding((1, 1));
        assert_eq!(dw.flops(&[1, 8, 8, 8]).unwrap(), 2 * 8 * 8 * 9 * 8);
    }

    #[test]
    fn weights_scaled_by_fan_in() {
        let big = Conv2d::new(512, 4, (3, 3)).random_weights(1).max_abs();
        let small = Conv2d::new(2, 4, (3, 3)).random_weights(1).max_abs();
        assert!(big < small);
    }
}
