//! Direct pointwise (1×1) convolution — the zero-copy GEMM engine for the
//! bottleneck-heavy workload class (MobileNetV2 expansions/projections,
//! ResNet-50 reduce/expand pairs, ResNet downsample shortcuts).
//!
//! ## Why not im2row (or Winograd)?
//!
//! The paper's region-wise Winograd argument (§4) only applies to spatial
//! kernels — a 1×1 layer has no transform to amortise, so it always fell to
//! im2row. But im2row's patch matrix for a 1×1 stride-1 layer is a
//! **verbatim copy of the input**: under NHWC every output pixel's
//! receptive field is exactly its own `C`-run, so `[N·OH·OW, KH·KW·C]`
//! degenerates to `[N·H·W, C]` — the flattened activation tensor itself.
//! The copy is pure overhead. This engine drops it: the NHWC input *is* the
//! GEMM A operand (`lda = C`), fed straight to
//! [`sgemm_prepacked_fused`] against the layer's prepare-time-packed
//! weights. Zhang et al. 2020 (*High Performance Depthwise and Pointwise
//! Convolutions on Mobile Devices*) reach the same conclusion: direct
//! pointwise with fused elementwise ops is the decisive lever here.
//!
//! * **Stride 1** — zero staging: the GEMM reads the caller's input in
//!   place. `workspace_elems_for` is 0 and a warm run allocates nothing.
//! * **Stride 2** (ResNet downsample projections) — the output only samples
//!   every other pixel, so the engine gathers the sampled `C`-runs into a
//!   workspace-owned `[N·OH·OW, C]` staging buffer (one contiguous memcpy
//!   per output pixel — `KH·KW = 1` of im2row's copies, over ¼ the rows)
//!   and runs the same GEMM over it.
//!
//! Bias/activation ride the [`BiasAct`] epilogue exactly as on the im2row
//! path; the residual-fused entry points swap in [`BiasActAdd`], which also
//! reads the skip-connection operand while each micro-tile is cache-hot —
//! a `Conv(1×1) → Add → Act` residual chain becomes one GEMM with no
//! separate whole-tensor add pass (see [`crate::nn::PreparedModel`]'s
//! prepare-time fusion).

use crate::gemm::{sgemm_prepacked_fused, Activation, BiasAct, BiasActAdd, Epilogue, PackedB};
use crate::parallel::ThreadPool;
use crate::tensor::{Tensor, TensorView};
use crate::workspace::Workspace;
use crate::{bail_shape, bail_unsupported, Result};

/// A prepared direct pointwise convolution: `[M, 1, 1, C]` weights
/// transposed to `[C, M]` and pre-packed into GEMM panel layout once at
/// prepare time — the same treatment [`crate::im2row::Im2RowConvolution`]
/// gets, and (for 1×1) the **identical** packed matrix, so this engine's
/// outputs are bit-identical to the im2row path it replaces.
#[derive(Debug, Clone)]
pub struct PointwiseConvolution {
    cin: usize,
    cout: usize,
    stride: (usize, usize),
    /// Weights as `[C, M]` row-major, packed: `wt[ch·M + m] = w[m, 0, 0, ch]`.
    wt_packed: PackedB,
}

impl PointwiseConvolution {
    /// Prepare from `[M, 1, 1, C]` weights. Only unpadded layers at stride
    /// (1,1) or (2,2) are supported — every 1×1 conv the evaluated networks
    /// ship; the selector never routes other shapes here.
    pub fn new(weights: &Tensor, stride: (usize, usize), pad: (usize, usize)) -> Result<Self> {
        if weights.rank() != 4 || weights.shape()[1] != 1 || weights.shape()[2] != 1 {
            bail_shape!("pointwise weights must be [M, 1, 1, C], got {:?}", weights.shape());
        }
        if pad != (0, 0) {
            bail_unsupported!("pointwise engine is unpadded-only, got pad {pad:?}");
        }
        if stride != (1, 1) && stride != (2, 2) {
            bail_unsupported!("pointwise engine supports stride 1 or 2, got {stride:?}");
        }
        let (m, c) = (weights.shape()[0], weights.shape()[3]);
        // W[ch][m] — the k = ch patch-row order a 1×1 im2row layer would
        // use, so the packed panels match the baseline exactly.
        let mut wt = vec![0.0f32; c * m];
        for mi in 0..m {
            for ch in 0..c {
                wt[ch * m + mi] = weights.at4(mi, 0, 0, ch);
            }
        }
        Ok(PointwiseConvolution {
            cin: c,
            cout: m,
            stride,
            wt_packed: PackedB::pack(&wt, m, c, m),
        })
    }

    /// Input channels.
    pub fn cin(&self) -> usize {
        self.cin
    }

    /// Output channels.
    pub fn cout(&self) -> usize {
        self.cout
    }

    /// Output spatial size for an `h×w` input.
    pub fn output_hw(&self, h: usize, w: usize) -> Result<(usize, usize)> {
        if h == 0 || w == 0 {
            bail_shape!("input {h}x{w} smaller than filter 1x1");
        }
        Ok(((h - 1) / self.stride.0 + 1, (w - 1) / self.stride.1 + 1))
    }

    /// Elements of workspace-owned row-gather staging one inference over an
    /// `[n, h, w, C]` input borrows — **0 at stride 1**, where the GEMM
    /// reads the caller's NHWC input in place (the zero-copy property).
    pub fn staging_elems_for(&self, n: usize, h: usize, w: usize) -> usize {
        if self.stride == (1, 1) {
            0
        } else {
            let (oh, ow) = ((h - 1) / self.stride.0 + 1, (w - 1) / self.stride.1 + 1);
            n * oh * ow * self.cin
        }
    }

    /// Workspace elements one inference borrows from the arena — the
    /// strided row-gather staging is the engine's only scratch (GEMM pack
    /// panels come from per-thread scratch, as on every GEMM path).
    pub fn workspace_elems_for(&self, n: usize, h: usize, w: usize) -> Result<usize> {
        let _ = self.output_hw(h, w)?; // geometry must be valid
        Ok(self.staging_elems_for(n, h, w))
    }

    /// Run with a throwaway arena (tests / one-shot use).
    pub fn run(&self, input: &Tensor, pool: Option<&ThreadPool>) -> Result<Tensor> {
        let mut ws = Workspace::new();
        self.run_with_workspace(input, pool, &mut ws)
    }

    /// [`run`](Self::run) drawing any strided-gather staging from a
    /// caller-owned arena.
    pub fn run_with_workspace(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
        ws: &mut Workspace,
    ) -> Result<Tensor> {
        self.run_fused_with(input, pool, None, Activation::None, ws)
    }

    /// Allocating wrapper over [`run_fused_into`](Self::run_fused_into) —
    /// kept as the oracle the write-into path is property-tested against.
    pub fn run_fused_with(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
    ) -> Result<Tensor> {
        let mut out = self.alloc_output(input)?;
        self.run_fused_into(&input.view(), pool, bias, act, ws, out.data_mut())?;
        Ok(out)
    }

    /// The write-into pipeline: one fused GEMM straight over the caller's
    /// NHWC input (stride 1) or over the workspace-staged row gather
    /// (stride 2), bias/activation applied per cache-hot micro-tile by the
    /// [`BiasAct`] epilogue, output landed directly in the caller-provided
    /// `out` slice (`N·OH·OW·M` elements, fully overwritten — dirty arena
    /// memory is fine). With a warm arena this path performs **zero heap
    /// allocation** — at stride 1 it borrows nothing from the arena either.
    pub fn run_fused_into(
        &self,
        input: &TensorView,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
        out: &mut [f32],
    ) -> Result<()> {
        let (n, h, w) = self.check_fused_args(input, bias, out.len())?;
        // Zero-copy engine: no patch matrix, so the Pack span is ~0 ns —
        // recorded anyway to keep the per-engine stage census fixed at two
        // (stride-2 row gathers happen inside the GEMM sweep).
        let stage_t = crate::trace::begin();
        crate::trace::end_stage(
            stage_t,
            crate::trace::Stage::Pack,
            crate::trace::AlgoCode::Pointwise,
        );
        let stage_t = crate::trace::begin();
        let r = self.gemm_rows(input, n, h, w, pool, ws, out, &BiasAct { bias, act });
        crate::trace::end_stage(
            stage_t,
            crate::trace::Stage::Gemm,
            crate::trace::AlgoCode::Pointwise,
        );
        r
    }

    /// Allocating wrapper over
    /// [`run_residual_fused_into`](Self::run_residual_fused_into) — the
    /// oracle its property tests compare against.
    #[allow(clippy::too_many_arguments)]
    pub fn run_residual_fused_with(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        res: &[f32],
        ws: &mut Workspace,
    ) -> Result<Tensor> {
        let mut out = self.alloc_output(input)?;
        self.run_residual_fused_into(&input.view(), pool, bias, act, res, ws, out.data_mut())?;
        Ok(out)
    }

    /// [`run_fused_into`](Self::run_fused_into) with a fused residual
    /// accumulate: `out = act(conv(input) + bias + res)`, the residual read
    /// per element by the [`BiasActAdd`] epilogue while each micro-tile is
    /// cache-hot. `res` must have exactly the output's `N·OH·OW·M`
    /// elements (the same-shape skip connection of a residual block). The
    /// scalar chain associates exactly like the unfused conv → add → act
    /// walk, so fusion is **bit-identical** to the separate-pass reference.
    #[allow(clippy::too_many_arguments)]
    pub fn run_residual_fused_into(
        &self,
        input: &TensorView,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        res: &[f32],
        ws: &mut Workspace,
        out: &mut [f32],
    ) -> Result<()> {
        let (n, h, w) = self.check_fused_args(input, bias, out.len())?;
        if res.len() != out.len() {
            bail_shape!("residual has {} elems, output has {}", res.len(), out.len());
        }
        // Same fixed two-stage census as run_fused_into: a ~0 ns Pack span
        // (zero-copy A operand), then the GEMM + fused-residual epilogue.
        let stage_t = crate::trace::begin();
        crate::trace::end_stage(
            stage_t,
            crate::trace::Stage::Pack,
            crate::trace::AlgoCode::Pointwise,
        );
        let stage_t = crate::trace::begin();
        let r = self.gemm_rows(
            input,
            n,
            h,
            w,
            pool,
            ws,
            out,
            &BiasActAdd { bias, act, res, ldr: self.cout },
        );
        crate::trace::end_stage(
            stage_t,
            crate::trace::Stage::Gemm,
            crate::trace::AlgoCode::Pointwise,
        );
        r
    }

    /// Allocating twin of
    /// [`run_fused_batched_into`](Self::run_fused_batched_into) — the
    /// oracle its batched-vs-sequential property tests compare against.
    #[allow(clippy::too_many_arguments)]
    pub fn run_fused_batched_with(
        &self,
        batch: &Tensor,
        nb: usize,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
    ) -> Result<Tensor> {
        let mut out = self.alloc_output(batch)?;
        self.run_fused_batched_into(&batch.view(), nb, pool, bias, act, ws, out.data_mut())?;
        Ok(out)
    }

    /// Batched write-into entry point: `nb` frames gathered contiguously as
    /// one `[nb, H, W, C]` view execute as a **single** GEMM
    /// `[nb·OH·OW × C] · [C × M]` — one traversal of the prepare-time
    /// packed-B weight panels, `nb`× the A rows (still read zero-copy at
    /// stride 1). Each output row's k-accumulation is independent of how
    /// many rows share the sweep, so the result is **bit-identical** to
    /// running the frames one at a time. Allocation-free with a warm arena
    /// (statcheck-registered).
    #[allow(clippy::too_many_arguments)]
    pub fn run_fused_batched_into(
        &self,
        batch: &TensorView,
        nb: usize,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
        out: &mut [f32],
    ) -> Result<()> {
        super::check_batch_dim(batch, nb)?;
        self.run_fused_into(batch, pool, bias, act, ws, out)
    }

    /// Allocate the output tensor for the allocating (oracle) wrappers.
    fn alloc_output(&self, input: &Tensor) -> Result<Tensor> {
        if input.rank() != 4 {
            bail_shape!("input must be [N, H, W, C], got {:?}", input.shape());
        }
        let (n, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
        let (oh, ow) = self.output_hw(h, w)?;
        Ok(Tensor::zeros(&[n, oh, ow, self.cout]))
    }

    /// Shared argument validation for the write-into entry points.
    fn check_fused_args(
        &self,
        input: &TensorView,
        bias: Option<&[f32]>,
        out_len: usize,
    ) -> Result<(usize, usize, usize)> {
        if input.rank() != 4 {
            bail_shape!("input must be [N, H, W, C], got {:?}", input.shape());
        }
        let (n, h, w, c) = (
            input.shape()[0],
            input.shape()[1],
            input.shape()[2],
            input.shape()[3],
        );
        if c != self.cin {
            bail_shape!("input has {c} channels, pointwise weights expect {}", self.cin);
        }
        if let Some(b) = bias {
            if b.len() != self.cout {
                bail_shape!("bias length {} vs {} output channels", b.len(), self.cout);
            }
        }
        let (oh, ow) = self.output_hw(h, w)?;
        if out_len != n * oh * ow * self.cout {
            bail_shape!(
                "output slice has {out_len} elems, layer writes {}",
                n * oh * ow * self.cout
            );
        }
        Ok((n, h, w))
    }

    /// The hot core: resolve the GEMM A operand — the input itself at
    /// stride 1, the workspace-staged row gather otherwise — and run the
    /// single fused GEMM `[N·OH·OW × C] · [C × M]` with the caller's
    /// epilogue. Allocation-free (statcheck-registered).
    #[allow(clippy::too_many_arguments)]
    fn gemm_rows<E: Epilogue>(
        &self,
        input: &TensorView,
        n: usize,
        h: usize,
        w: usize,
        pool: Option<&ThreadPool>,
        ws: &mut Workspace,
        out: &mut [f32],
        epi: &E,
    ) -> Result<()> {
        let c = self.cin;
        if self.stride == (1, 1) {
            // Zero-copy: the flattened NHWC input is the A matrix, lda = C.
            sgemm_prepacked_fused(
                n * h * w,
                input.data(),
                c,
                &self.wt_packed,
                out,
                self.cout,
                false,
                pool,
                epi,
            );
            return Ok(());
        }
        let (sh, sw) = self.stride;
        let (oh, ow) = ((h - 1) / sh + 1, (w - 1) / sw + 1);
        let staging = ws.take(n * oh * ow * c);
        let data = input.data();
        let s_addr = staging.as_mut_ptr() as usize;
        let gather_row = |r: usize| {
            let b = r / oh;
            let oy = r % oh;
            // SAFETY: each job writes only its own `(b, oy)` staging row;
            // jobs are disjoint and `staging` outlives the dispatch.
            let dst: &mut [f32] = unsafe {
                std::slice::from_raw_parts_mut((s_addr as *mut f32).add((b * oh + oy) * ow * c), ow * c)
            };
            let src_row = ((b * h + oy * sh) * w) * c;
            for ox in 0..ow {
                let s0 = src_row + ox * sw * c;
                dst[ox * c..(ox + 1) * c].copy_from_slice(&data[s0..s0 + c]);
            }
        };
        match pool {
            Some(pool) => pool.parallel_for(n * oh, gather_row),
            None => (0..n * oh).for_each(gather_row),
        }
        sgemm_prepacked_fused(
            n * oh * ow,
            staging,
            c,
            &self.wt_packed,
            out,
            self.cout,
            false,
            pool,
            epi,
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::direct::direct_conv2d;
    use crate::im2row::Im2RowConvolution;
    use crate::testkit::{check, Gen};

    /// The tentpole property: for 1×1 layers the engine is **bit-identical**
    /// to the im2row baseline it replaces — the patch matrix im2row copies
    /// is exactly the operand this engine reads in place (stride 1) or
    /// gathers (stride 2), and the packed weights match — across strides ×
    /// ragged C/M × {none, bias, ReLU, ReLU6}, written into NaN-poisoned
    /// offset windows.
    #[test]
    fn property_pointwise_matches_im2row_bitwise() {
        check("pointwise == im2row bit-for-bit", 48, |g: &mut Gen| {
            let c = g.usize_in(1, 19); // ragged vs the 4-lane SIMD width
            let m = g.usize_in(1, 21); // ragged vs NR = 16
            let stride = if g.usize_in(0, 1) == 0 { (1, 1) } else { (2, 2) };
            let h = g.usize_in(1, 9);
            let w = g.usize_in(1, 9);
            let n = g.usize_in(1, 2);
            let input = Tensor::from_vec(&[n, h, w, c], g.normal_vec(n * h * w * c)).unwrap();
            let weights = Tensor::from_vec(&[m, 1, 1, c], g.normal_vec(m * c)).unwrap();
            let bias: Vec<f32> = g.normal_vec(m);
            let (bias_opt, act) = match g.usize_in(0, 3) {
                0 => (None, Activation::None),
                1 => (Some(bias.as_slice()), Activation::None),
                2 => (Some(bias.as_slice()), Activation::Relu),
                _ => (Some(bias.as_slice()), Activation::Relu6),
            };
            let mut ws = Workspace::new();
            let want = Im2RowConvolution::new(&weights, stride, (0, 0))
                .unwrap()
                .run_fused_with(&input, None, bias_opt, act, &mut ws)
                .unwrap();
            let conv = PointwiseConvolution::new(&weights, stride, (0, 0)).unwrap();
            let off = 3usize;
            let mut backing = vec![f32::NAN; want.len() + off];
            conv.run_fused_into(&input.view(), None, bias_opt, act, &mut ws, &mut backing[off..])
                .unwrap();
            backing[off..] == *want.data() && backing[..off].iter().all(|x| x.is_nan())
        });
    }

    /// The fused-residual property: `run_residual_fused_into` is
    /// bit-identical to the separate-pass reference (conv with bias, then
    /// an elementwise add, then the activation) — the association order the
    /// [`BiasActAdd`] epilogue guarantees — into NaN-poisoned buffers, and
    /// to its allocating twin.
    #[test]
    fn property_residual_fused_matches_separate_add_bitwise() {
        check("fused residual == conv,add,act", 40, |g: &mut Gen| {
            let c = g.usize_in(1, 14);
            let m = g.usize_in(1, 18);
            let stride = if g.usize_in(0, 1) == 0 { (1, 1) } else { (2, 2) };
            let h = g.usize_in(1, 8);
            let w = g.usize_in(1, 8);
            let input = Tensor::from_vec(&[1, h, w, c], g.normal_vec(h * w * c)).unwrap();
            let weights = Tensor::from_vec(&[m, 1, 1, c], g.normal_vec(m * c)).unwrap();
            let bias: Vec<f32> = g.normal_vec(m);
            let bias_opt = if g.usize_in(0, 1) == 0 { None } else { Some(bias.as_slice()) };
            let act = *g.choose(&[Activation::None, Activation::Relu, Activation::Relu6]);
            let conv = PointwiseConvolution::new(&weights, stride, (0, 0)).unwrap();
            let mut ws = Workspace::new();
            // Separate-pass reference over the engine's own (act-less) conv.
            let pre = conv.run_fused_with(&input, None, bias_opt, Activation::None, &mut ws).unwrap();
            let res: Vec<f32> = g.normal_vec(pre.len());
            let want: Vec<f32> =
                pre.data().iter().zip(&res).map(|(&v, &r)| act.apply(v + r)).collect();
            let mut got = vec![f32::NAN; want.len()];
            conv.run_residual_fused_into(&input.view(), None, bias_opt, act, &res, &mut ws, &mut got)
                .unwrap();
            let twin = conv
                .run_residual_fused_with(&input, None, bias_opt, act, &res, &mut ws)
                .unwrap();
            got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()) && got == *twin.data()
        });
    }

    /// The batched contract: one `[nb, H, W, C]` gathered walk through
    /// `run_fused_batched_into` is **bit-identical** to `nb` sequential
    /// batch-1 `run_fused_into` walks over the same frames — the zero-copy
    /// A operand just grows by whole frame-rows — across strides × ragged
    /// shapes × {none, bias, bias+ReLU6} epilogues, written into
    /// NaN-poisoned buffers, and to its allocating twin.
    #[test]
    fn property_batched_matches_sequential_bitwise() {
        check("pointwise batched == nb × batch-1", 32, |g: &mut Gen| {
            let nb = g.usize_in(2, 5);
            let c = g.usize_in(1, 14);
            let m = g.usize_in(1, 18);
            let stride = if g.usize_in(0, 1) == 0 { (1, 1) } else { (2, 2) };
            let h = g.usize_in(1, 8);
            let w = g.usize_in(1, 8);
            let input =
                Tensor::from_vec(&[nb, h, w, c], g.normal_vec(nb * h * w * c)).unwrap();
            let weights = Tensor::from_vec(&[m, 1, 1, c], g.normal_vec(m * c)).unwrap();
            let bias: Vec<f32> = g.normal_vec(m);
            let (bias_opt, act) = match g.usize_in(0, 2) {
                0 => (None, Activation::None),
                1 => (Some(bias.as_slice()), Activation::None),
                _ => (Some(bias.as_slice()), Activation::Relu6),
            };
            let conv = PointwiseConvolution::new(&weights, stride, (0, 0)).unwrap();
            let mut ws = Workspace::new();
            let frame = h * w * c;
            let mut want: Vec<f32> = Vec::new();
            for f in 0..nb {
                let ft = Tensor::from_vec(
                    &[1, h, w, c],
                    input.data()[f * frame..(f + 1) * frame].to_vec(),
                )
                .unwrap();
                want.extend_from_slice(
                    conv.run_fused_with(&ft, None, bias_opt, act, &mut ws).unwrap().data(),
                );
            }
            let mut got = vec![f32::NAN; want.len()];
            conv.run_fused_batched_into(&input.view(), nb, None, bias_opt, act, &mut ws, &mut got)
                .unwrap();
            let twin =
                conv.run_fused_batched_with(&input, nb, None, bias_opt, act, &mut ws).unwrap();
            got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits())
                && got == *twin.data()
        });
    }

    /// Cross-oracle: agrees with the naive direct conv within float
    /// tolerance (different accumulation order).
    #[test]
    fn matches_direct_oracle() {
        for stride in [(1, 1), (2, 2)] {
            let input = Tensor::randn(&[2, 9, 11, 13], 7);
            let weights = Tensor::randn(&[17, 1, 1, 13], 8);
            let conv = PointwiseConvolution::new(&weights, stride, (0, 0)).unwrap();
            let got = conv.run(&input, None).unwrap();
            let want = direct_conv2d(&input, &weights, stride, (0, 0)).unwrap();
            assert_eq!(got.shape(), want.shape());
            assert!(got.allclose(&want, 1e-4), "stride {stride:?} diverges from direct");
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let pool = ThreadPool::new(4);
        let input = Tensor::randn(&[1, 14, 15, 24], 3);
        let weights = Tensor::randn(&[32, 1, 1, 24], 4);
        let bias: Vec<f32> = (0..32).map(|i| i as f32 * 0.1 - 1.6).collect();
        for stride in [(1, 1), (2, 2)] {
            let conv = PointwiseConvolution::new(&weights, stride, (0, 0)).unwrap();
            let mut ws = Workspace::new();
            let a = conv
                .run_fused_with(&input, None, Some(&bias), Activation::Relu6, &mut ws)
                .unwrap();
            let b = conv
                .run_fused_with(&input, Some(&pool), Some(&bias), Activation::Relu6, &mut ws)
                .unwrap();
            assert_eq!(a.data(), b.data(), "pooled run must be bit-identical");
            assert!(a.data().iter().any(|&v| v == 0.0));
            assert!(a.data().iter().all(|&v| v <= 6.0));
        }
    }

    /// Arena pins: stride-1 layers borrow **nothing** (the zero-copy
    /// property), stride-2 layers borrow exactly the gather staging and a
    /// pre-sized arena never grows across repeated inferences.
    #[test]
    fn arena_grow_count_stays_zero() {
        let weights = Tensor::randn(&[12, 1, 1, 8], 9);
        let s1 = PointwiseConvolution::new(&weights, (1, 1), (0, 0)).unwrap();
        assert_eq!(s1.workspace_elems_for(1, 10, 10).unwrap(), 0);
        let mut ws = Workspace::new();
        for seed in 0..3 {
            let input = Tensor::randn(&[1, 10, 10, 8], seed + 40);
            let _ = s1.run_with_workspace(&input, None, &mut ws).unwrap();
        }
        assert_eq!(ws.grow_count(), 0, "stride-1 pointwise reads the input in place");
        assert_eq!(ws.high_water_elems(), 0);

        let s2 = PointwiseConvolution::new(&weights, (2, 2), (0, 0)).unwrap();
        let need = s2.workspace_elems_for(1, 11, 10).unwrap();
        assert_eq!(need, 6 * 5 * 8);
        let mut ws = Workspace::with_capacity(need);
        for seed in 0..3 {
            let input = Tensor::randn(&[1, 11, 10, 8], seed + 50);
            let _ = s2.run_with_workspace(&input, None, &mut ws).unwrap();
        }
        assert_eq!(ws.grow_count(), 0, "pre-sized arena must not grow");
        assert_eq!(ws.high_water_elems(), need, "sizing formula matches borrow");
    }

    #[test]
    fn rejects_bad_configs() {
        let w11 = Tensor::zeros(&[6, 1, 1, 4]);
        // Non-1×1 weights, padding, unsupported strides.
        assert!(PointwiseConvolution::new(&Tensor::zeros(&[6, 3, 3, 4]), (1, 1), (0, 0)).is_err());
        assert!(PointwiseConvolution::new(&w11, (1, 1), (1, 1)).is_err());
        assert!(PointwiseConvolution::new(&w11, (1, 2), (0, 0)).is_err());
        assert!(PointwiseConvolution::new(&w11, (3, 3), (0, 0)).is_err());
        let conv = PointwiseConvolution::new(&w11, (1, 1), (0, 0)).unwrap();
        let mut ws = Workspace::new();
        // Channel mismatch.
        assert!(conv.run(&Tensor::zeros(&[1, 8, 8, 5]), None).is_err());
        // Wrong bias length, wrong output slice, wrong residual length.
        let input = Tensor::zeros(&[1, 8, 8, 4]);
        let mut out = vec![0.0; 8 * 8 * 6];
        assert!(conv
            .run_fused_into(&input.view(), None, Some(&[0.0; 3]), Activation::None, &mut ws, &mut out)
            .is_err());
        assert!(conv
            .run_fused_into(&input.view(), None, None, Activation::None, &mut ws, &mut out[1..])
            .is_err());
        assert!(conv
            .run_residual_fused_into(
                &input.view(),
                None,
                None,
                Activation::None,
                &[0.0; 7],
                &mut ws,
                &mut out,
            )
            .is_err());
    }

    /// Hand-computed values: all-ones weights sum the input channels.
    #[test]
    fn hand_computed_values() {
        let input = Tensor::from_vec(&[1, 2, 2, 2], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0])
            .unwrap();
        let weights = Tensor::full(&[1, 1, 1, 2], 1.0);
        let conv = PointwiseConvolution::new(&weights, (1, 1), (0, 0)).unwrap();
        let out = conv.run(&input, None).unwrap();
        assert_eq!(out.shape(), &[1, 2, 2, 1]);
        assert_eq!(out.data(), &[3.0, 7.0, 11.0, 15.0]);
        // Stride 2 keeps only pixel (0,0) of each 2×2 block.
        let conv = PointwiseConvolution::new(&weights, (2, 2), (0, 0)).unwrap();
        let out = conv.run(&input, None).unwrap();
        assert_eq!(out.shape(), &[1, 1, 1, 1]);
        assert_eq!(out.data(), &[3.0]);
    }
}
