//! Per-layer algorithm selection — the policy behind the paper's
//! "Winograd-suitable layers" split (§3.2).
//!
//! Suitability rules distilled from the paper:
//! * Winograd/Cook-Toom requires **stride 1** (the tiling assumes dense
//!   output coverage).
//! * `3×3` layers get `F(4×4, 3×3)` — the biggest measured win (2.2–3.1×
//!   average in Table 2) — unless the spatial extent is too small for 4×4
//!   output tiles, where `F(2×2, 3×3)` wastes less on partial tiles.
//! * `5×5` layers get `F(2×2, 5×5)` (GoogleNet/Inception rows of Table 2).
//! * `1×7`/`7×1` layers get the 1-D Cook-Toom **`F(4, 7)`** variants. The
//!   paper ships `F(2, 7)` for its Inception-v3 rows (~2.0–2.1×), but the
//!   10-point `F(4, 7)` measured faster on this engine (EXPERIMENTS.md
//!   §Perf step 5), so [`WinogradVariant::for_kernel`] routes there;
//!   `F(2, 7)` stays available for the `ablation_variants` bench.
//! * `1×3`/`3×1` get 1-D `F(4, 3)`.
//! * Everything else — `1×1`, strided, `7×7` stem layers, exotic shapes —
//!   falls back to im2row (they are either GEMM-dominated already or not
//!   expressible in the shipped variants).
//! * Very shallow channel counts (C·M small) cannot amortise the transform
//!   cost (§4 of the paper) and also fall back to im2row.

use super::ConvAlgorithm;
use crate::winograd::WinogradVariant;

/// Minimum `C·M` product below which transform overhead dominates and
/// im2row wins (from the amortization argument in §4; validated by the
/// `ablation_amortization` bench).
pub const MIN_CHANNEL_PRODUCT: usize = 64;

/// Choose the algorithm for a layer shape.
pub fn select_algorithm(
    kernel: (usize, usize),
    stride: (usize, usize),
    cin: usize,
    cout: usize,
) -> ConvAlgorithm {
    if stride != (1, 1) {
        return ConvAlgorithm::Im2Row;
    }
    if cin * cout < MIN_CHANNEL_PRODUCT {
        return ConvAlgorithm::Im2Row;
    }
    match WinogradVariant::for_kernel(kernel.0, kernel.1) {
        Some(v) => ConvAlgorithm::Winograd(v),
        None => ConvAlgorithm::Im2Row,
    }
}

/// Variant choice refined by spatial extent: small outputs prefer the 2×2
/// tile (fewer wasted partial-tile lanes). Used by the model zoo where
/// layer spatial sizes are known statically.
pub fn select_variant_spatial(
    kernel: (usize, usize),
    out_h: usize,
    out_w: usize,
) -> Option<WinogradVariant> {
    match kernel {
        (3, 3) => {
            if out_h * out_w < 36 || out_h < 4 || out_w < 4 {
                Some(WinogradVariant::F2x2_3x3)
            } else {
                Some(WinogradVariant::F4x4_3x3)
            }
        }
        _ => WinogradVariant::for_kernel(kernel.0, kernel.1),
    }
}

/// True if the paper's scheme applies to the layer at all — the
/// "fast layer" predicate used to split Table 1 / Figure 3.
pub fn is_winograd_suitable(kernel: (usize, usize), stride: (usize, usize)) -> bool {
    stride == (1, 1) && WinogradVariant::for_kernel(kernel.0, kernel.1).is_some()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stride_forces_im2row() {
        assert_eq!(
            select_algorithm((3, 3), (2, 2), 64, 64),
            ConvAlgorithm::Im2Row
        );
    }

    #[test]
    fn shallow_channels_force_im2row() {
        assert_eq!(select_algorithm((3, 3), (1, 1), 3, 8), ConvAlgorithm::Im2Row);
        assert!(matches!(
            select_algorithm((3, 3), (1, 1), 64, 64),
            ConvAlgorithm::Winograd(_)
        ));
    }

    #[test]
    fn kernel_shapes_route_to_expected_variants() {
        assert_eq!(
            select_algorithm((5, 5), (1, 1), 32, 64),
            ConvAlgorithm::Winograd(WinogradVariant::F2x2_5x5)
        );
        // Policy (module doc + WinogradVariant::F4_1x7 doc): 1-D 7-tap
        // layers route to F(4, 7), not the paper's F(2, 7) — see
        // EXPERIMENTS.md §Perf step 5.
        assert_eq!(
            select_algorithm((1, 7), (1, 1), 32, 64),
            ConvAlgorithm::Winograd(WinogradVariant::F4_1x7)
        );
        assert_eq!(
            select_algorithm((7, 1), (1, 1), 32, 64),
            ConvAlgorithm::Winograd(WinogradVariant::F4_7x1)
        );
        assert_eq!(select_algorithm((1, 1), (1, 1), 64, 64), ConvAlgorithm::Im2Row);
        assert_eq!(select_algorithm((7, 7), (1, 1), 64, 64), ConvAlgorithm::Im2Row);
    }

    #[test]
    fn spatial_refinement_prefers_small_tiles_on_small_maps() {
        assert_eq!(
            select_variant_spatial((3, 3), 56, 56),
            Some(WinogradVariant::F4x4_3x3)
        );
        assert_eq!(
            select_variant_spatial((3, 3), 4, 4),
            Some(WinogradVariant::F2x2_3x3)
        );
        assert_eq!(
            select_variant_spatial((5, 5), 14, 14),
            Some(WinogradVariant::F2x2_5x5)
        );
    }

    #[test]
    fn suitability_predicate() {
        assert!(is_winograd_suitable((3, 3), (1, 1)));
        assert!(is_winograd_suitable((1, 7), (1, 1)));
        assert!(!is_winograd_suitable((3, 3), (2, 2)));
        assert!(!is_winograd_suitable((1, 1), (1, 1)));
        assert!(!is_winograd_suitable((7, 7), (2, 2)));
    }
}
