//! Per-layer algorithm selection — the policy behind the paper's
//! "Winograd-suitable layers" split (§3.2), extended with the direct
//! depthwise engine for the MobileNet workload class.
//!
//! **One chooser.** Every caller — `Conv2d::run*`, the prepared-model
//! binder, the zoo benches — resolves through [`select_algorithm_spatial`],
//! which sees the kernel, stride, grouping, channel counts **and** (when
//! known) the output spatial extent. The historical split where
//! [`select_algorithm`] ignored spatial extent while the zoo path refined
//! variants through [`select_variant_spatial`] meant the run path could
//! pick `F(4×4, 3×3)` on a map where the zoo path would pick
//! `F(2×2, 3×3)`; both now route through the same spatial-aware logic
//! ([`select_algorithm`] is the `out_hw = None` shorthand kept for
//! shape-only callers, and documents that it returns the *family default*
//! variant which the spatial pass may refine).
//!
//! Suitability rules distilled from the paper (and its depthwise follow-ups
//! — Zhang et al. 2020, Hao et al. 2022):
//! * **Grouped layers first**: Winograd's C·M amortization argument (§4)
//!   collapses for grouped convolution (each group convolves only
//!   `C/groups` input channels; for depthwise, exactly one), and im2row
//!   degenerates into a memory-bound copy. A depthwise 3×3 layer
//!   (`groups == cin == cout`) at stride 1 or 2 routes to the direct
//!   register-tiled SIMD engine ([`crate::conv::depthwise`]); any other
//!   grouped shape falls back to the naive grouped direct path (correct,
//!   never fast — no evaluated network ships one).
//! * Winograd/Cook-Toom requires **stride 1** (the tiling assumes dense
//!   output coverage).
//! * `3×3` layers get `F(4×4, 3×3)` — the biggest measured win (2.2–3.1×
//!   average in Table 2) — unless the output extent is too small for 4×4
//!   tiles, where `F(2×2, 3×3)` wastes less on partial tiles.
//! * `5×5` layers get `F(2×2, 5×5)` (GoogleNet/Inception rows of Table 2).
//! * `1×7`/`7×1` layers get the 1-D Cook-Toom **`F(4, 7)`** variants. The
//!   paper ships `F(2, 7)` for its Inception-v3 rows (~2.0–2.1×), but the
//!   10-point `F(4, 7)` measured faster on this engine (EXPERIMENTS.md
//!   §Perf step 5), so [`WinogradVariant::for_kernel`] routes there;
//!   `F(2, 7)` stays available for the `ablation_variants` bench.
//! * `1×3`/`3×1` get 1-D `F(4, 3)`.
//! * **Dense 1×1 layers route to the direct pointwise engine**
//!   ([`crate::conv::pointwise`]): their im2row patch matrix is a verbatim
//!   copy of the NHWC input, so the engine feeds the input to the GEMM in
//!   place instead (zero staging copy). The rule covers stride 1 *and* the
//!   stride-2 exception (ResNet downsample projections), where the engine
//!   gathers the sampled pixel rows first — still `KH·KW = 1` of im2row's
//!   copies over ¼ the rows. No channel-product gate applies: with no
//!   transform to amortise, skipping the copy wins at every depth. Padded
//!   1×1 layers (no evaluated network ships one) stay on im2row.
//! * Everything else — strided spatial kernels, `7×7` stem layers, exotic
//!   shapes — falls back to im2row (they are either GEMM-dominated already
//!   or not expressible in the shipped variants).
//! * Very shallow channel counts (C·M small) cannot amortise the transform
//!   cost (§4 of the paper) and also fall back to im2row.
//!
//! **Quantized layers** resolve through the thin
//! [`select_algorithm_spatial_dtype`] wrapper. Int8 routing mirrors the f32
//! shape rules but swaps each engine for its [`crate::quant`] twin —
//! depthwise 3×3 → [`ConvAlgorithm::DirectDepthwiseI8`], dense unpadded
//! 1×1 (stride 1/2) → [`ConvAlgorithm::DirectPointwiseI8`], every other
//! dense shape → [`ConvAlgorithm::Im2RowI8`] — and **never picks
//! Winograd**: the Cook-Toom transforms subtract near-equal terms, and int8
//! lacks the mantissa headroom to absorb that cancellation (the standard
//! reason deployed int8 runtimes keep Winograd off). Exotic grouped shapes
//! keep the f32 `Direct` oracle — correctness over an unshipped fast path.

use super::ConvAlgorithm;
use crate::quant::Dtype;
use crate::winograd::WinogradVariant;

/// Minimum `C·M` product below which transform overhead dominates and
/// im2row wins (from the amortization argument in §4; validated by the
/// `ablation_amortization` bench).
pub const MIN_CHANNEL_PRODUCT: usize = 64;

/// The single spatial-aware chooser every resolution path funnels through.
///
/// `padding` gates the pointwise rule (the zero-copy engine is
/// unpadded-only; a padded 1×1 keeps the im2row fallback). `out_hw` is the
/// layer's output spatial extent when the caller knows the input shape
/// (`Conv2d::resolved_algorithm_for`, the prepared-model binder); `None`
/// falls back to the channel/kernel/stride heuristics with the
/// family-default Winograd variant.
pub fn select_algorithm_spatial(
    kernel: (usize, usize),
    stride: (usize, usize),
    padding: (usize, usize),
    groups: usize,
    cin: usize,
    cout: usize,
    out_hw: Option<(usize, usize)>,
) -> ConvAlgorithm {
    if groups > 1 {
        // Depthwise 3×3 at stride 1/2 → the direct register-tiled engine;
        // exotic grouped shapes → the naive grouped oracle.
        if groups == cin
            && groups == cout
            && kernel == (3, 3)
            && (stride == (1, 1) || stride == (2, 2))
        {
            return ConvAlgorithm::DirectDepthwise;
        }
        return ConvAlgorithm::Direct;
    }
    // Dense 1×1 → the zero-copy pointwise engine, at stride 1 or the
    // ResNet-downsample stride-2 exception (strided row gather). The
    // engine is unpadded-only; a padded 1×1 falls through to im2row.
    if kernel == (1, 1) && padding == (0, 0) && (stride == (1, 1) || stride == (2, 2)) {
        return ConvAlgorithm::DirectPointwise;
    }
    if stride != (1, 1) {
        return ConvAlgorithm::Im2Row;
    }
    if cin * cout < MIN_CHANNEL_PRODUCT {
        return ConvAlgorithm::Im2Row;
    }
    let variant = match out_hw {
        Some((oh, ow)) => select_variant_spatial(kernel, oh, ow),
        None => WinogradVariant::for_kernel(kernel.0, kernel.1),
    };
    match variant {
        Some(v) => ConvAlgorithm::Winograd(v),
        None => ConvAlgorithm::Im2Row,
    }
}

/// Dtype-aware front of the chooser. `Dtype::F32` delegates to
/// [`select_algorithm_spatial`] unchanged; `Dtype::Int8` applies the same
/// shape split but lands on the quantized engines and **never** on
/// Winograd (see the module doc). Grouped-but-not-depthwise shapes keep
/// the f32 `Direct` oracle even at Int8 — no evaluated network ships one,
/// and a correct slow path beats a missing fast one.
#[allow(clippy::too_many_arguments)]
pub fn select_algorithm_spatial_dtype(
    dtype: Dtype,
    kernel: (usize, usize),
    stride: (usize, usize),
    padding: (usize, usize),
    groups: usize,
    cin: usize,
    cout: usize,
    out_hw: Option<(usize, usize)>,
) -> ConvAlgorithm {
    if dtype == Dtype::F32 {
        return select_algorithm_spatial(kernel, stride, padding, groups, cin, cout, out_hw);
    }
    if groups > 1 {
        if groups == cin
            && groups == cout
            && kernel == (3, 3)
            && (stride == (1, 1) || stride == (2, 2))
        {
            return ConvAlgorithm::DirectDepthwiseI8;
        }
        return ConvAlgorithm::Direct;
    }
    if kernel == (1, 1) && padding == (0, 0) && (stride == (1, 1) || stride == (2, 2)) {
        return ConvAlgorithm::DirectPointwiseI8;
    }
    // Every remaining dense shape — spatial kernels at any stride, padded
    // 1×1s, shallow channels — takes the int8 im2row GEMM. No Winograd
    // branch exists at Int8 by design.
    ConvAlgorithm::Im2RowI8
}

/// Shape-only shorthand for [`select_algorithm_spatial`] with
/// `padding = (0, 0)` and `out_hw = None`: picks the algorithm family and
/// the *default* variant for an unpadded layer. Callers that know the
/// input shape (or pad) should pass the output extent and padding (or use
/// [`Conv2d::resolved_algorithm_for`](super::Conv2d::resolved_algorithm_for))
/// so small maps refine to the 2×2 tile and padded 1×1s keep im2row.
pub fn select_algorithm(
    kernel: (usize, usize),
    stride: (usize, usize),
    groups: usize,
    cin: usize,
    cout: usize,
) -> ConvAlgorithm {
    select_algorithm_spatial(kernel, stride, (0, 0), groups, cin, cout, None)
}

/// Variant choice refined by spatial extent: small outputs prefer the 2×2
/// tile (fewer wasted partial-tile lanes). The refinement step of
/// [`select_algorithm_spatial`]; also used directly by the per-layer
/// benches where the variant (not the family) is the question.
pub fn select_variant_spatial(
    kernel: (usize, usize),
    out_h: usize,
    out_w: usize,
) -> Option<WinogradVariant> {
    match kernel {
        (3, 3) => {
            if out_h * out_w < 36 || out_h < 4 || out_w < 4 {
                Some(WinogradVariant::F2x2_3x3)
            } else {
                Some(WinogradVariant::F4x4_3x3)
            }
        }
        _ => WinogradVariant::for_kernel(kernel.0, kernel.1),
    }
}

/// True if the paper's scheme applies to the layer at all — the
/// "fast layer" predicate used to split Table 1 / Figure 3. Grouped layers
/// are never Winograd-suitable: with `C_group = C/groups` (1 for
/// depthwise) the transform cost cannot amortise (§4).
pub fn is_winograd_suitable(
    kernel: (usize, usize),
    stride: (usize, usize),
    groups: usize,
) -> bool {
    groups == 1 && stride == (1, 1) && WinogradVariant::for_kernel(kernel.0, kernel.1).is_some()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stride_forces_im2row() {
        assert_eq!(
            select_algorithm((3, 3), (2, 2), 1, 64, 64),
            ConvAlgorithm::Im2Row
        );
    }

    #[test]
    fn shallow_channels_force_im2row() {
        assert_eq!(select_algorithm((3, 3), (1, 1), 1, 3, 8), ConvAlgorithm::Im2Row);
        assert!(matches!(
            select_algorithm((3, 3), (1, 1), 1, 64, 64),
            ConvAlgorithm::Winograd(_)
        ));
    }

    #[test]
    fn depthwise_routes_to_direct_engine() {
        // groups == cin == cout, 3×3, stride 1 or 2 → the depthwise engine.
        assert_eq!(
            select_algorithm((3, 3), (1, 1), 64, 64, 64),
            ConvAlgorithm::DirectDepthwise
        );
        assert_eq!(
            select_algorithm((3, 3), (2, 2), 64, 64, 64),
            ConvAlgorithm::DirectDepthwise
        );
        // Channel count never disqualifies depthwise (no C·M argument).
        assert_eq!(
            select_algorithm((3, 3), (1, 1), 4, 4, 4),
            ConvAlgorithm::DirectDepthwise
        );
        // Non-3×3 or channel-multiplier/grouped shapes → naive grouped.
        assert_eq!(select_algorithm((5, 5), (1, 1), 8, 8, 8), ConvAlgorithm::Direct);
        assert_eq!(select_algorithm((3, 3), (1, 1), 8, 8, 16), ConvAlgorithm::Direct);
        assert_eq!(select_algorithm((3, 3), (1, 1), 4, 16, 16), ConvAlgorithm::Direct);
        // Odd strides fall back too.
        assert_eq!(select_algorithm((3, 3), (1, 2), 8, 8, 8), ConvAlgorithm::Direct);
    }

    #[test]
    fn kernel_shapes_route_to_expected_variants() {
        assert_eq!(
            select_algorithm((5, 5), (1, 1), 1, 32, 64),
            ConvAlgorithm::Winograd(WinogradVariant::F2x2_5x5)
        );
        // Policy (module doc + WinogradVariant::F4_1x7 doc): 1-D 7-tap
        // layers route to F(4, 7), not the paper's F(2, 7) — see
        // EXPERIMENTS.md §Perf step 5.
        assert_eq!(
            select_algorithm((1, 7), (1, 1), 1, 32, 64),
            ConvAlgorithm::Winograd(WinogradVariant::F4_1x7)
        );
        assert_eq!(
            select_algorithm((7, 1), (1, 1), 1, 32, 64),
            ConvAlgorithm::Winograd(WinogradVariant::F4_7x1)
        );
        assert_eq!(
            select_algorithm((1, 1), (1, 1), 1, 64, 64),
            ConvAlgorithm::DirectPointwise
        );
        assert_eq!(select_algorithm((7, 7), (1, 1), 1, 64, 64), ConvAlgorithm::Im2Row);
    }

    /// The pointwise rule: dense unpadded 1×1 at stride 1 or 2 routes to
    /// the zero-copy engine regardless of channel depth; padded, oddly
    /// strided or grouped 1×1s keep their old fallbacks.
    #[test]
    fn pointwise_routing_rules() {
        assert_eq!(
            select_algorithm((1, 1), (1, 1), 1, 64, 128),
            ConvAlgorithm::DirectPointwise
        );
        // Stride-2 exception: ResNet downsample projections.
        assert_eq!(
            select_algorithm((1, 1), (2, 2), 1, 256, 512),
            ConvAlgorithm::DirectPointwise
        );
        // No C·M gate — skipping the copy wins at every depth.
        assert_eq!(
            select_algorithm((1, 1), (1, 1), 1, 3, 8),
            ConvAlgorithm::DirectPointwise
        );
        // Padded 1×1 (no evaluated network ships one) stays on im2row.
        assert_eq!(
            select_algorithm_spatial((1, 1), (1, 1), (1, 1), 1, 64, 64, None),
            ConvAlgorithm::Im2Row
        );
        // Unsupported strides stay on im2row; grouped 1×1 stays direct.
        assert_eq!(select_algorithm((1, 1), (3, 3), 1, 64, 64), ConvAlgorithm::Im2Row);
        assert_eq!(select_algorithm((1, 1), (1, 1), 4, 64, 64), ConvAlgorithm::Direct);
    }

    #[test]
    fn spatial_refinement_prefers_small_tiles_on_small_maps() {
        assert_eq!(
            select_variant_spatial((3, 3), 56, 56),
            Some(WinogradVariant::F4x4_3x3)
        );
        assert_eq!(
            select_variant_spatial((3, 3), 4, 4),
            Some(WinogradVariant::F2x2_3x3)
        );
        assert_eq!(
            select_variant_spatial((5, 5), 14, 14),
            Some(WinogradVariant::F2x2_5x5)
        );
    }

    /// The unified chooser applies the same spatial refinement the zoo path
    /// historically applied — no more policy split with the run path.
    #[test]
    fn spatial_chooser_refines_where_shape_only_defaults() {
        assert_eq!(
            select_algorithm_spatial((3, 3), (1, 1), (1, 1), 1, 16, 16, Some((56, 56))),
            ConvAlgorithm::Winograd(WinogradVariant::F4x4_3x3)
        );
        assert_eq!(
            select_algorithm_spatial((3, 3), (1, 1), (1, 1), 1, 16, 16, Some((4, 4))),
            ConvAlgorithm::Winograd(WinogradVariant::F2x2_3x3)
        );
        // Shape-only defaults to the 4×4 family variant.
        assert_eq!(
            select_algorithm((3, 3), (1, 1), 1, 16, 16),
            ConvAlgorithm::Winograd(WinogradVariant::F4x4_3x3)
        );
        // Spatial info never overrides the grouped or strided rules.
        assert_eq!(
            select_algorithm_spatial((3, 3), (2, 2), (1, 1), 1, 64, 64, Some((56, 56))),
            ConvAlgorithm::Im2Row
        );
        assert_eq!(
            select_algorithm_spatial((3, 3), (1, 1), (1, 1), 64, 64, 64, Some((4, 4))),
            ConvAlgorithm::DirectDepthwise
        );
    }

    /// Int8 routing: same shape split as f32 but onto the quantized
    /// engines, with Winograd categorically excluded.
    #[test]
    fn int8_routing_never_picks_winograd() {
        let d = Dtype::Int8;
        // Depthwise 3×3 s1/s2 → the quantized depthwise engine.
        assert_eq!(
            select_algorithm_spatial_dtype(d, (3, 3), (1, 1), (1, 1), 64, 64, 64, None),
            ConvAlgorithm::DirectDepthwiseI8
        );
        assert_eq!(
            select_algorithm_spatial_dtype(d, (3, 3), (2, 2), (1, 1), 64, 64, 64, None),
            ConvAlgorithm::DirectDepthwiseI8
        );
        // Dense unpadded 1×1 s1/s2 → the quantized pointwise engine.
        assert_eq!(
            select_algorithm_spatial_dtype(d, (1, 1), (1, 1), (0, 0), 1, 64, 128, None),
            ConvAlgorithm::DirectPointwiseI8
        );
        assert_eq!(
            select_algorithm_spatial_dtype(d, (1, 1), (2, 2), (0, 0), 1, 256, 512, None),
            ConvAlgorithm::DirectPointwiseI8
        );
        // Where f32 would pick Winograd (3×3 s1, deep channels, big map),
        // int8 takes the im2row GEMM instead.
        assert!(matches!(
            select_algorithm_spatial(
                (3, 3),
                (1, 1),
                (1, 1),
                1,
                64,
                64,
                Some((56, 56))
            ),
            ConvAlgorithm::Winograd(_)
        ));
        assert_eq!(
            select_algorithm_spatial_dtype(d, (3, 3), (1, 1), (1, 1), 1, 64, 64, Some((56, 56))),
            ConvAlgorithm::Im2RowI8
        );
        // Strided spatial, 7×7 stems, padded 1×1, shallow channels — all
        // int8 im2row.
        assert_eq!(
            select_algorithm_spatial_dtype(d, (3, 3), (2, 2), (1, 1), 1, 64, 64, None),
            ConvAlgorithm::Im2RowI8
        );
        assert_eq!(
            select_algorithm_spatial_dtype(d, (7, 7), (2, 2), (3, 3), 1, 3, 64, None),
            ConvAlgorithm::Im2RowI8
        );
        assert_eq!(
            select_algorithm_spatial_dtype(d, (1, 1), (1, 1), (1, 1), 1, 64, 64, None),
            ConvAlgorithm::Im2RowI8
        );
        // Exotic grouped shapes keep the f32 oracle.
        assert_eq!(
            select_algorithm_spatial_dtype(d, (3, 3), (1, 1), (1, 1), 4, 16, 16, None),
            ConvAlgorithm::Direct
        );
        // F32 delegates to the base chooser verbatim.
        assert_eq!(
            select_algorithm_spatial_dtype(
                Dtype::F32,
                (1, 1),
                (1, 1),
                (0, 0),
                1,
                64,
                64,
                None
            ),
            ConvAlgorithm::DirectPointwise
        );
    }

    #[test]
    fn suitability_predicate() {
        assert!(is_winograd_suitable((3, 3), (1, 1), 1));
        assert!(is_winograd_suitable((1, 7), (1, 1), 1));
        assert!(!is_winograd_suitable((3, 3), (2, 2), 1));
        assert!(!is_winograd_suitable((1, 1), (1, 1), 1));
        assert!(!is_winograd_suitable((7, 7), (2, 2), 1));
        // Depthwise/grouped 3×3 s1 is *not* a fast layer: C_group = 1.
        assert!(!is_winograd_suitable((3, 3), (1, 1), 64));
        assert!(!is_winograd_suitable((3, 3), (1, 1), 4));
    }
}
