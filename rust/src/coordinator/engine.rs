//! The inference engine: dispatcher thread pulling batches off the queue,
//! executing them on the prepared model over the compute threadpool, and
//! delivering responses to per-request channels.

use super::metrics::ServerMetrics;
use super::queue::{Request, RequestQueue, Response};
use crate::nn::PreparedModel;
use crate::parallel::ThreadPool;
use crate::tensor::Tensor;
use crate::workspace::Workspace;
use crate::{Error, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Engine tuning knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Compute threads (the paper's big cluster = 4).
    pub threads: usize,
    /// Queue capacity before backpressure.
    pub queue_capacity: usize,
    /// Max requests drained per dispatch round.
    pub max_batch: usize,
    /// How long the dispatcher waits for work per round.
    pub poll: Duration,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            threads: 4,
            queue_capacity: 64,
            max_batch: 8,
            poll: Duration::from_millis(5),
        }
    }
}

/// Response mailbox shared between dispatcher and waiting clients.
#[derive(Default)]
struct Mailbox {
    slots: Mutex<HashMap<u64, Result<Response>>>,
    ready: Condvar,
}

/// A running inference engine over one prepared model.
///
/// ```no_run
/// use winoconv::coordinator::{EngineConfig, InferenceEngine};
/// use winoconv::nn::{PreparedModel, Scheme};
/// use winoconv::tensor::Tensor;
/// use winoconv::zoo::ModelKind;
///
/// let graph = ModelKind::SqueezeNet.build(1).unwrap();
/// let model = PreparedModel::prepare(
///     "squeezenet", &graph, &[1, 224, 224, 3], Scheme::WinogradWhereSuitable).unwrap();
/// let engine = InferenceEngine::start(model, EngineConfig::default());
/// let out = engine.infer(Tensor::randn(&[1, 224, 224, 3], 1)).unwrap();
/// println!("{}", engine.metrics().report());
/// engine.shutdown();
/// ```
pub struct InferenceEngine {
    queue: RequestQueue,
    mailbox: Arc<Mailbox>,
    metrics: Arc<ServerMetrics>,
    next_id: AtomicU64,
    dispatcher: Option<thread::JoinHandle<()>>,
}

impl std::fmt::Debug for InferenceEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("InferenceEngine").field("queue", &self.queue).finish_non_exhaustive()
    }
}

impl InferenceEngine {
    /// Spawn the dispatcher and its compute pool.
    pub fn start(model: PreparedModel, cfg: EngineConfig) -> InferenceEngine {
        let queue = RequestQueue::new(cfg.queue_capacity);
        let mailbox = Arc::new(Mailbox::default());
        let metrics = Arc::new(ServerMetrics::new());

        let dispatcher = {
            let queue = queue.clone();
            let mailbox = Arc::clone(&mailbox);
            let metrics = Arc::clone(&metrics);
            thread::Builder::new()
                .name("winoconv-dispatcher".into())
                .spawn(move || {
                    let pool = ThreadPool::new(cfg.threads);
                    // The dispatcher (this engine's worker loop) owns one
                    // arena pair pre-sized at prepare time: conv scratch to
                    // the model's largest layer, activations to the
                    // planner's peak — steady-state serving performs zero
                    // heap allocation per request inside inference (the
                    // only per-request allocation left is the response
                    // tensor handed across the channel).
                    let mut ws = Workspace::with_capacity(model.workspace_elems());
                    let mut acts =
                        Workspace::with_capacity(model.activation_plan().peak_elems());
                    let out_shape: Vec<usize> = model.output_shape().to_vec();
                    loop {
                        match queue.pop_batch(cfg.max_batch, cfg.poll) {
                            None => break, // closed and drained
                            Some(batch) if batch.is_empty() => continue,
                            Some(batch) => {
                                for req in batch {
                                    let queued = req.submitted.elapsed();
                                    let t0 = Instant::now();
                                    let mut output = Tensor::zeros(&out_shape);
                                    let result = model.run_planned_into(
                                        &req.input,
                                        Some(&pool),
                                        &mut ws,
                                        &mut acts,
                                        output.data_mut(),
                                    );
                                    let compute = t0.elapsed();
                                    let resp = result.map(|()| Response {
                                        id: req.id,
                                        output,
                                        queue_ns: queued.as_nanos() as u64,
                                        compute_ns: compute.as_nanos() as u64,
                                    });
                                    if resp.is_ok() {
                                        metrics.record(
                                            queued.as_nanos() as u64,
                                            compute.as_nanos() as u64,
                                            req.submitted.elapsed().as_nanos() as u64,
                                        );
                                    }
                                    let mut slots = mailbox.slots.lock().unwrap();
                                    slots.insert(req.id, resp);
                                    mailbox.ready.notify_all();
                                }
                                // Surface arena health once per batch: a
                                // regression that starts allocating in
                                // steady state shows up in serving stats,
                                // not just in tests — without a second
                                // metrics lock on every request.
                                metrics.record_arena_health(
                                    model.fallback_count() as u64,
                                    (ws.grow_count() + acts.grow_count()) as u64,
                                );
                                // ... and which algorithm paths the batch's
                                // conv layers actually dispatched to.
                                metrics.record_dispatch_counts(model.dispatch_counts());
                            }
                        }
                    }
                })
                .expect("spawn dispatcher")
        };

        InferenceEngine {
            queue,
            mailbox,
            metrics,
            next_id: AtomicU64::new(1),
            dispatcher: Some(dispatcher),
        }
    }

    /// Submit a request without waiting; returns its id, or an error when
    /// the queue is saturated (backpressure).
    pub fn submit(&self, input: Tensor) -> Result<u64> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id,
            input,
            submitted: Instant::now(),
        };
        match self.queue.try_push(req) {
            Ok(()) => Ok(id),
            Err(_) => {
                self.metrics.record_rejected();
                Err(Error::Runtime("queue full (backpressure)".into()))
            }
        }
    }

    /// Block until request `id` completes.
    pub fn wait(&self, id: u64) -> Result<Response> {
        let mut slots = self.mailbox.slots.lock().unwrap();
        loop {
            if let Some(resp) = slots.remove(&id) {
                return resp;
            }
            slots = self.mailbox.ready.wait(slots).unwrap();
        }
    }

    /// Synchronous convenience: submit (blocking on backpressure) + wait.
    pub fn infer(&self, input: Tensor) -> Result<Response> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id,
            input,
            submitted: Instant::now(),
        };
        if !self.queue.push(req) {
            return Err(Error::Runtime("engine is shut down".into()));
        }
        self.wait(id)
    }

    /// Current metrics.
    pub fn metrics(&self) -> super::metrics::MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Pending queue depth.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Drain and stop. Safe to call once; drop also triggers it.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.queue.close();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for InferenceEngine {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{Activation, Conv2d};
    use crate::nn::{Graph, Op, Scheme};

    /// A tiny but real model for engine tests.
    fn tiny_model() -> PreparedModel {
        let mut g = Graph::new();
        let input = g.input();
        let desc = Conv2d::new(4, 16, (3, 3)).with_padding((1, 1));
        let w = desc.random_weights(1);
        let c = g.add(
            "conv",
            Op::Conv { desc, weights: w, bias: vec![0.0; 16], act: Activation::Relu },
            &[input],
        );
        let gap = g.add("gap", Op::GlobalAvgPool, &[c]);
        let fcw = crate::tensor::Tensor::randn(&[16, 10], 2);
        let fc = g.add("fc", Op::Fc { weights: fcw, bias: vec![0.0; 10], relu: false }, &[gap]);
        g.add("softmax", Op::Softmax, &[fc]);
        PreparedModel::prepare("tiny", &g, &[1, 16, 16, 4], Scheme::WinogradWhereSuitable).unwrap()
    }

    #[test]
    fn sync_inference_roundtrip() {
        let engine = InferenceEngine::start(tiny_model(), EngineConfig {
            threads: 2,
            ..EngineConfig::default()
        });
        let resp = engine.infer(Tensor::randn(&[1, 16, 16, 4], 3)).unwrap();
        assert_eq!(resp.output.shape(), &[1, 10]);
        let sum: f32 = resp.output.data().iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "softmax output sums to 1");
        assert_eq!(engine.metrics().completed, 1);
        engine.shutdown();
    }

    #[test]
    fn async_submit_wait_many() {
        let engine = InferenceEngine::start(tiny_model(), EngineConfig::default());
        let ids: Vec<u64> = (0..20)
            .map(|i| loop {
                match engine.submit(Tensor::randn(&[1, 16, 16, 4], i)) {
                    Ok(id) => break id,
                    Err(_) => std::thread::sleep(Duration::from_millis(1)),
                }
            })
            .collect();
        for id in ids {
            let resp = engine.wait(id).unwrap();
            assert_eq!(resp.id, id);
        }
        assert_eq!(engine.metrics().completed, 20);
        engine.shutdown();
    }

    #[test]
    fn wrong_shape_is_error_not_hang() {
        let engine = InferenceEngine::start(tiny_model(), EngineConfig::default());
        let r = engine.infer(Tensor::zeros(&[1, 8, 8, 4]));
        assert!(r.is_err());
        engine.shutdown();
    }

    /// The engine's per-worker-arena path never takes `PreparedModel::run`'s
    /// allocating mutex fallback and never grows its pre-sized arenas —
    /// steady-state serving performs zero heap allocation inside inference,
    /// and the serving metrics prove it.
    #[test]
    fn engine_arena_health_stays_clean() {
        let engine = InferenceEngine::start(tiny_model(), EngineConfig {
            threads: 2,
            ..EngineConfig::default()
        });
        for i in 0..8 {
            engine.infer(Tensor::randn(&[1, 16, 16, 4], i + 40)).unwrap();
        }
        let m = engine.metrics();
        assert_eq!(m.completed, 8);
        assert_eq!(m.arena_fallbacks, 0, "engine must never hit the run() fallback");
        assert_eq!(m.arena_grows, 0, "pre-sized worker arenas must never grow");
        // Dispatch gauge: the tiny model's one conv is Winograd-bound, so
        // 8 requests ⇒ 8 winograd dispatches and nothing else.
        assert_eq!(m.dispatch.winograd, 8);
        assert_eq!(m.dispatch.total(), 8);
        engine.shutdown();
    }

    #[test]
    fn metrics_track_throughput() {
        let engine = InferenceEngine::start(tiny_model(), EngineConfig::default());
        for i in 0..5 {
            engine.infer(Tensor::randn(&[1, 16, 16, 4], i)).unwrap();
        }
        let m = engine.metrics();
        assert_eq!(m.completed, 5);
        assert!(m.throughput_fps > 0.0);
        assert!(m.compute_ms.0 > 0.0);
        engine.shutdown();
    }

    #[test]
    fn shutdown_via_drop_does_not_hang() {
        let engine = InferenceEngine::start(tiny_model(), EngineConfig::default());
        engine.infer(Tensor::randn(&[1, 16, 16, 4], 1)).unwrap();
        drop(engine);
    }
}
