//! The inference engine: dispatcher thread gathering real batches off the
//! queue under a latency budget, executing each batch as **one** batched
//! planned walk (shared weight-panel traversal across frames) over the
//! compute threadpool, and delivering per-request responses.

use super::metrics::ServerMetrics;
use super::queue::{Request, RequestQueue, Response};
use crate::nn::{PreparedBatch, PreparedModel};
use crate::parallel::ThreadPool;
use crate::tensor::{Tensor, TensorView};
use crate::trace;
use crate::workspace::Workspace;
use crate::{Error, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Engine tuning knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Compute threads (the paper's big cluster = 4).
    pub threads: usize,
    /// Queue capacity before backpressure.
    pub queue_capacity: usize,
    /// Max frames gathered into one batched execution.
    pub max_batch: usize,
    /// How long the dispatcher waits for the *first* request per round.
    pub poll: Duration,
    /// Latency budget for filling a batch: once the first request of a
    /// round is seen, the batch stays open until it reaches `max_batch`
    /// frames or this window elapses — whichever comes first. Zero
    /// degenerates to drain-whatever-is-pending (no added latency, but
    /// batches only form under sustained concurrent load).
    pub batch_window: Duration,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            threads: 4,
            queue_capacity: 64,
            max_batch: 8,
            poll: Duration::from_millis(5),
            batch_window: Duration::from_millis(2),
        }
    }
}

/// Response mailbox shared between dispatcher and waiting clients.
#[derive(Default)]
struct Mailbox {
    slots: Mutex<HashMap<u64, Result<Response>>>,
    ready: Condvar,
}

/// A running inference engine over one prepared model.
///
/// ```no_run
/// use winoconv::coordinator::{EngineConfig, InferenceEngine};
/// use winoconv::nn::{PreparedModel, Scheme};
/// use winoconv::tensor::Tensor;
/// use winoconv::zoo::ModelKind;
///
/// let graph = ModelKind::SqueezeNet.build(1).unwrap();
/// let model = PreparedModel::prepare(
///     "squeezenet", &graph, &[1, 224, 224, 3], Scheme::WinogradWhereSuitable).unwrap();
/// let engine = InferenceEngine::start(model, EngineConfig::default());
/// let out = engine.infer(Tensor::randn(&[1, 224, 224, 3], 1)).unwrap();
/// println!("{}", engine.metrics().report());
/// engine.shutdown();
/// ```
pub struct InferenceEngine {
    queue: RequestQueue,
    mailbox: Arc<Mailbox>,
    metrics: Arc<ServerMetrics>,
    next_id: AtomicU64,
    dispatcher: Option<thread::JoinHandle<()>>,
}

impl std::fmt::Debug for InferenceEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("InferenceEngine").field("queue", &self.queue).finish_non_exhaustive()
    }
}

impl InferenceEngine {
    /// Spawn the dispatcher and its compute pool.
    pub fn start(model: PreparedModel, cfg: EngineConfig) -> InferenceEngine {
        let queue = RequestQueue::new(cfg.queue_capacity);
        let mailbox = Arc::new(Mailbox::default());
        let metrics = Arc::new(ServerMetrics::new());

        let dispatcher = {
            let queue = queue.clone();
            let mailbox = Arc::clone(&mailbox);
            let metrics = Arc::clone(&metrics);
            thread::Builder::new()
                .name("winoconv-dispatcher".into())
                .spawn(move || {
                    let pool = ThreadPool::new(cfg.threads);
                    let max_batch = cfg.max_batch.max(1);
                    // One batched plan per batch size the budgeted pop can
                    // return. Slot sizes scale by k, lifetimes don't, so
                    // the plans stay valid for the whole engine lifetime.
                    let plans: Vec<PreparedBatch> = (1..=max_batch)
                        .map(|k| model.prepare_batched(k))
                        .collect::<Result<Vec<_>>>()
                        .expect("batched plans for a prepared model");
                    // The dispatcher (this engine's worker loop) owns one
                    // arena pair pre-sized for the *largest* batch: conv
                    // scratch to the biggest layer at max_batch frames,
                    // activations to the planner's peak × max_batch —
                    // steady-state serving performs zero heap allocation
                    // inside inference at every batch size (the per-request
                    // allocations left are the response tensors handed
                    // across the channel).
                    let ws_elems =
                        plans.iter().map(|p| p.workspace_elems()).max().unwrap_or(0);
                    let mut ws = Workspace::with_capacity(ws_elems);
                    let mut acts = Workspace::with_capacity(
                        plans.last().map(|p| p.peak_elems()).unwrap_or(0),
                    );
                    let frame_in_shape: Vec<usize> = plans[0].input_shape().to_vec();
                    let frame_out_shape: Vec<usize> = plans[0].output_shape().to_vec();
                    let frame_in: usize = frame_in_shape.iter().product();
                    let frame_out: usize = frame_out_shape.iter().product();
                    // Staging buffers for the gather/scatter around the one
                    // batched walk: frames copy in as the leading rows of a
                    // [k, H, W, C] input, and the [k, ...] output splits
                    // back into per-request responses.
                    let mut staging_in = Tensor::zeros(plans[max_batch - 1].input_shape());
                    let mut staging_out =
                        Tensor::zeros(plans[max_batch - 1].output_shape());
                    loop {
                        match queue.pop_batch_budgeted(max_batch, cfg.poll, cfg.batch_window)
                        {
                            None => break, // closed and drained
                            Some(batch) if batch.is_empty() => continue,
                            Some(batch) => {
                                // Mis-shaped frames fail fast with an error
                                // response instead of poisoning the batch.
                                let mut run: Vec<Request> =
                                    Vec::with_capacity(batch.len());
                                for req in batch {
                                    if req.input.shape() == frame_in_shape.as_slice() {
                                        run.push(req);
                                    } else {
                                        let err = Err(Error::Shape(format!(
                                            "engine expects input {:?}, got {:?}",
                                            frame_in_shape,
                                            req.input.shape()
                                        )));
                                        let mut slots = mailbox.slots.lock().unwrap();
                                        slots.insert(req.id, err);
                                        mailbox.ready.notify_all();
                                    }
                                }
                                if run.is_empty() {
                                    continue;
                                }
                                let k = run.len();
                                let plan = &plans[k - 1];
                                let tr = trace::enabled();
                                let t0 = Instant::now();
                                let batch_t0 = if tr { trace::now_ns() } else { 0 };
                                for (i, req) in run.iter().enumerate() {
                                    staging_in.data_mut()
                                        [i * frame_in..(i + 1) * frame_in]
                                        .copy_from_slice(req.input.data());
                                }
                                if tr {
                                    trace::record_serve(
                                        trace::Stage::Gather,
                                        batch_t0,
                                        trace::now_ns().saturating_sub(batch_t0),
                                    );
                                }
                                // One batched planned walk for the whole
                                // batch: every weight panel streams through
                                // cache once for all k frames.
                                let result = TensorView::new(
                                    plan.input_shape(),
                                    &staging_in.data()[..k * frame_in],
                                )
                                .and_then(|view| {
                                    model.run_planned_batched_into(
                                        plan,
                                        &view,
                                        Some(&pool),
                                        &mut ws,
                                        &mut acts,
                                        &mut staging_out.data_mut()[..k * frame_out],
                                    )
                                });
                                let compute = t0.elapsed();
                                metrics.record_batch(k);
                                if tr {
                                    trace::record_serve(
                                        trace::Stage::Compute,
                                        batch_t0,
                                        compute.as_nanos() as u64,
                                    );
                                }
                                let scatter_t0 = if tr { trace::now_ns() } else { 0 };
                                for (i, req) in run.into_iter().enumerate() {
                                    let queued =
                                        t0.saturating_duration_since(req.submitted);
                                    if tr {
                                        // Synthetic interval ending at batch
                                        // start: how long this request sat in
                                        // the queue before the walk began.
                                        let q = queued.as_nanos() as u64;
                                        trace::record_serve(
                                            trace::Stage::QueueWait,
                                            batch_t0.saturating_sub(q),
                                            q,
                                        );
                                    }
                                    let resp = match &result {
                                        Ok(()) => {
                                            let mut output =
                                                Tensor::zeros(&frame_out_shape);
                                            output.data_mut().copy_from_slice(
                                                &staging_out.data()
                                                    [i * frame_out..(i + 1) * frame_out],
                                            );
                                            metrics.record(
                                                queued.as_nanos() as u64,
                                                compute.as_nanos() as u64,
                                                req.submitted.elapsed().as_nanos() as u64,
                                            );
                                            Ok(Response {
                                                id: req.id,
                                                output,
                                                queue_ns: queued.as_nanos() as u64,
                                                compute_ns: compute.as_nanos() as u64,
                                            })
                                        }
                                        Err(e) => Err(Error::Runtime(format!(
                                            "batched execution failed: {e}"
                                        ))),
                                    };
                                    let mut slots = mailbox.slots.lock().unwrap();
                                    slots.insert(req.id, resp);
                                    mailbox.ready.notify_all();
                                }
                                if tr {
                                    trace::record_serve(
                                        trace::Stage::Scatter,
                                        scatter_t0,
                                        trace::now_ns().saturating_sub(scatter_t0),
                                    );
                                }
                                // Surface arena health once per batch: a
                                // regression that starts allocating in
                                // steady state shows up in serving stats,
                                // not just in tests — without a second
                                // metrics lock on every request.
                                metrics.record_arena_health(
                                    model.fallback_count() as u64,
                                    (ws.grow_count() + acts.grow_count()) as u64,
                                );
                                // ... and which algorithm paths the batch's
                                // conv layers actually dispatched to.
                                metrics.record_dispatch_counts(model.dispatch_counts());
                            }
                        }
                    }
                })
                .expect("spawn dispatcher")
        };

        InferenceEngine {
            queue,
            mailbox,
            metrics,
            next_id: AtomicU64::new(1),
            dispatcher: Some(dispatcher),
        }
    }

    /// Submit a request without waiting; returns its id, or an error when
    /// the queue is saturated (backpressure).
    pub fn submit(&self, input: Tensor) -> Result<u64> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id,
            input,
            submitted: Instant::now(),
        };
        match self.queue.try_push(req) {
            Ok(()) => Ok(id),
            Err(_) => {
                self.metrics.record_rejected();
                Err(Error::Runtime("queue full (backpressure)".into()))
            }
        }
    }

    /// Block until request `id` completes.
    pub fn wait(&self, id: u64) -> Result<Response> {
        let mut slots = self.mailbox.slots.lock().unwrap();
        loop {
            if let Some(resp) = slots.remove(&id) {
                return resp;
            }
            slots = self.mailbox.ready.wait(slots).unwrap();
        }
    }

    /// Synchronous convenience: submit (blocking on backpressure) + wait.
    pub fn infer(&self, input: Tensor) -> Result<Response> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id,
            input,
            submitted: Instant::now(),
        };
        if !self.queue.push(req) {
            return Err(Error::Runtime("engine is shut down".into()));
        }
        self.wait(id)
    }

    /// Current metrics.
    pub fn metrics(&self) -> super::metrics::MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Pending queue depth.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Drain and stop. Safe to call once; drop also triggers it.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.queue.close();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for InferenceEngine {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{Activation, Conv2d};
    use crate::nn::{Graph, Op, Scheme};

    /// A tiny but real model for engine tests.
    fn tiny_model() -> PreparedModel {
        let mut g = Graph::new();
        let input = g.input();
        let desc = Conv2d::new(4, 16, (3, 3)).with_padding((1, 1));
        let w = desc.random_weights(1);
        let c = g.add(
            "conv",
            Op::Conv { desc, weights: w, bias: vec![0.0; 16], act: Activation::Relu },
            &[input],
        );
        let gap = g.add("gap", Op::GlobalAvgPool, &[c]);
        let fcw = crate::tensor::Tensor::randn(&[16, 10], 2);
        let fc = g.add("fc", Op::Fc { weights: fcw, bias: vec![0.0; 10], relu: false }, &[gap]);
        g.add("softmax", Op::Softmax, &[fc]);
        PreparedModel::prepare("tiny", &g, &[1, 16, 16, 4], Scheme::WinogradWhereSuitable).unwrap()
    }

    #[test]
    fn sync_inference_roundtrip() {
        let engine = InferenceEngine::start(tiny_model(), EngineConfig {
            threads: 2,
            ..EngineConfig::default()
        });
        let resp = engine.infer(Tensor::randn(&[1, 16, 16, 4], 3)).unwrap();
        assert_eq!(resp.output.shape(), &[1, 10]);
        let sum: f32 = resp.output.data().iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "softmax output sums to 1");
        assert_eq!(engine.metrics().completed, 1);
        engine.shutdown();
    }

    #[test]
    fn async_submit_wait_many() {
        let engine = InferenceEngine::start(tiny_model(), EngineConfig::default());
        let ids: Vec<u64> = (0..20)
            .map(|i| loop {
                match engine.submit(Tensor::randn(&[1, 16, 16, 4], i)) {
                    Ok(id) => break id,
                    Err(_) => std::thread::sleep(Duration::from_millis(1)),
                }
            })
            .collect();
        for id in ids {
            let resp = engine.wait(id).unwrap();
            assert_eq!(resp.id, id);
        }
        assert_eq!(engine.metrics().completed, 20);
        engine.shutdown();
    }

    #[test]
    fn wrong_shape_is_error_not_hang() {
        let engine = InferenceEngine::start(tiny_model(), EngineConfig::default());
        let r = engine.infer(Tensor::zeros(&[1, 8, 8, 4]));
        assert!(r.is_err());
        engine.shutdown();
    }

    /// The engine's per-worker-arena path never takes `PreparedModel::run`'s
    /// allocating mutex fallback and never grows its pre-sized arenas —
    /// steady-state serving performs zero heap allocation inside inference,
    /// and the serving metrics prove it.
    #[test]
    fn engine_arena_health_stays_clean() {
        let engine = InferenceEngine::start(tiny_model(), EngineConfig {
            threads: 2,
            ..EngineConfig::default()
        });
        for i in 0..8 {
            engine.infer(Tensor::randn(&[1, 16, 16, 4], i + 40)).unwrap();
        }
        let m = engine.metrics();
        assert_eq!(m.completed, 8);
        assert_eq!(m.arena_fallbacks, 0, "engine must never hit the run() fallback");
        assert_eq!(m.arena_grows, 0, "pre-sized worker arenas must never grow");
        // Dispatch gauge: the tiny model's one conv is Winograd-bound, so
        // 8 requests ⇒ 8 winograd dispatches and nothing else.
        assert_eq!(m.dispatch.winograd, 8);
        assert_eq!(m.dispatch.total(), 8);
        engine.shutdown();
    }

    /// Concurrent submits inside one generous batch window coalesce into a
    /// real multi-frame batch: fewer dispatched batches than completed
    /// requests, a max batch > 1, per-frame dispatch accounting intact
    /// (census × frames), and the max-batch-sized arenas never grow.
    #[test]
    fn concurrent_submits_form_real_batches() {
        let engine = InferenceEngine::start(tiny_model(), EngineConfig {
            threads: 2,
            max_batch: 8,
            batch_window: Duration::from_millis(100),
            ..EngineConfig::default()
        });
        let ids: Vec<u64> = (0..8)
            .map(|i| loop {
                match engine.submit(Tensor::randn(&[1, 16, 16, 4], i + 7)) {
                    Ok(id) => break id,
                    Err(_) => std::thread::sleep(Duration::from_millis(1)),
                }
            })
            .collect();
        for id in ids {
            let resp = engine.wait(id).unwrap();
            assert_eq!(resp.output.shape(), &[1, 10]);
            let sum: f32 = resp.output.data().iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "softmax output sums to 1");
        }
        let m = engine.metrics();
        assert_eq!(m.completed, 8);
        assert!(m.batches < 8, "8 near-simultaneous submits must coalesce");
        assert!(m.max_batch_seen > 1, "a real multi-frame batch formed");
        assert!(m.queue_ms.2 >= m.queue_ms.0, "queue percentiles are ordered");
        // Per-frame dispatch accounting: one winograd count per frame
        // regardless of how the frames were batched.
        assert_eq!(m.dispatch.winograd, 8);
        assert_eq!(m.dispatch.total(), 8);
        assert_eq!(m.arena_fallbacks, 0, "batched path never hits run() fallback");
        assert_eq!(m.arena_grows, 0, "max-batch-sized arenas never grow");
        engine.shutdown();
    }

    /// A mis-shaped request inside a batch errors alone — the other frames
    /// of the same dispatch round still complete.
    #[test]
    fn bad_frame_does_not_poison_batch() {
        let engine = InferenceEngine::start(tiny_model(), EngineConfig {
            threads: 2,
            max_batch: 4,
            batch_window: Duration::from_millis(100),
            ..EngineConfig::default()
        });
        let good = engine.submit(Tensor::randn(&[1, 16, 16, 4], 1)).unwrap();
        let bad = engine.submit(Tensor::zeros(&[1, 8, 8, 4])).unwrap();
        let good2 = engine.submit(Tensor::randn(&[1, 16, 16, 4], 2)).unwrap();
        assert!(engine.wait(bad).is_err());
        assert!(engine.wait(good).is_ok());
        assert!(engine.wait(good2).is_ok());
        assert_eq!(engine.metrics().completed, 2);
        engine.shutdown();
    }

    #[test]
    fn metrics_track_throughput() {
        let engine = InferenceEngine::start(tiny_model(), EngineConfig::default());
        for i in 0..5 {
            engine.infer(Tensor::randn(&[1, 16, 16, 4], i)).unwrap();
        }
        let m = engine.metrics();
        assert_eq!(m.completed, 5);
        assert!(m.throughput_fps > 0.0);
        assert!(m.compute_ms.0 > 0.0);
        engine.shutdown();
    }

    #[test]
    fn shutdown_via_drop_does_not_hang() {
        let engine = InferenceEngine::start(tiny_model(), EngineConfig::default());
        engine.infer(Tensor::randn(&[1, 16, 16, 4], 1)).unwrap();
        drop(engine);
    }
}
