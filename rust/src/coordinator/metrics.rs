//! Serving metrics: counters and latency reservoirs with percentile
//! snapshots (the numbers the paper's deployment claim — frames/sec on the
//! big cluster — is made of).
//!
//! Latency/batch-size reservoirs are **bounded**: a fixed-capacity
//! deterministic [`Reservoir`] sampler per stream, so memory stays constant
//! under sustained load while percentiles stay statistically sound (exact
//! below the cap, uniform samples above it; means and maxima are tracked
//! exactly either way). [`MetricsSnapshot::prometheus`] renders the whole
//! snapshot in Prometheus text exposition format for scraping.

use crate::nn::DispatchCounts;
use crate::util::stats::{ns_to_ms, percentile_sorted, Reservoir};
use std::sync::Mutex;
use std::time::Instant;

/// Samples kept per latency/batch stream. Below this count snapshots are
/// exact, so short runs (and the unit tests) see unchanged numbers.
const RESERVOIR_CAP: usize = 4096;

/// Thread-safe metrics registry for one engine.
pub struct ServerMetrics {
    inner: Mutex<Inner>,
    started: Instant,
}

impl std::fmt::Debug for ServerMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServerMetrics").field("started", &self.started).finish_non_exhaustive()
    }
}

struct Inner {
    completed: u64,
    rejected: u64,
    queue_ns: Reservoir,
    compute_ns: Reservoir,
    e2e_ns: Reservoir,
    batch_sizes: Reservoir,
    arena_fallbacks: u64,
    arena_grows: u64,
    dispatch: DispatchCounts,
}

/// Point-in-time view of the metrics.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// Completed requests.
    pub completed: u64,
    /// Requests rejected by backpressure.
    pub rejected: u64,
    /// Wall-clock seconds since engine start.
    pub uptime_s: f64,
    /// Completed / uptime.
    pub throughput_fps: f64,
    /// End-to-end latency percentiles in ms: (p50, p90, p99).
    pub e2e_ms: (f64, f64, f64),
    /// Compute-only latency percentiles in ms: (p50, p90, p99).
    pub compute_ms: (f64, f64, f64),
    /// Queue-wait percentiles in ms: (p50, p90, p99). Under the latency-
    /// budgeted batcher, p99 queue wait ≈ batch window + service time of
    /// the batch ahead — the knob the window trades against throughput.
    pub queue_ms: (f64, f64, f64),
    /// Mean queue wait in ms.
    pub mean_queue_ms: f64,
    /// Dispatched batches (one batched execution each).
    pub batches: u64,
    /// Mean frames per dispatched batch (completed / batches); 0 when no
    /// batch has run. The amortization the batched GEMM sweep buys scales
    /// with this number.
    pub mean_batch: f64,
    /// Largest batch dispatched so far.
    pub max_batch_seen: u64,
    /// Arena health: `PreparedModel::run` mutex-contention fallbacks
    /// observed (each one allocated throwaway arenas). The engine's
    /// per-worker-arena path must keep this at 0.
    pub arena_fallbacks: u64,
    /// Arena health: grow events across the worker's scratch + activation
    /// arenas. Non-zero after warm-up means a steady-state-allocation
    /// regression.
    pub arena_grows: u64,
    /// Per-algorithm conv dispatch totals (winograd / im2row / depthwise /
    /// pointwise / direct, plus the int8 lanes im2row_i8 / depthwise_i8 /
    /// pointwise_i8 when the served model was prepared quantized) — which
    /// execution paths the served traffic actually exercised.
    pub dispatch: DispatchCounts,
}

impl Default for ServerMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerMetrics {
    /// Fresh registry; the throughput clock starts now.
    pub fn new() -> ServerMetrics {
        ServerMetrics {
            inner: Mutex::new(Inner {
                completed: 0,
                rejected: 0,
                // Distinct seeds so the four streams decorrelate.
                queue_ns: Reservoir::new(RESERVOIR_CAP, 0x71),
                compute_ns: Reservoir::new(RESERVOIR_CAP, 0x72),
                e2e_ns: Reservoir::new(RESERVOIR_CAP, 0x73),
                batch_sizes: Reservoir::new(RESERVOIR_CAP, 0x74),
                arena_fallbacks: 0,
                arena_grows: 0,
                dispatch: DispatchCounts::default(),
            }),
            started: Instant::now(),
        }
    }

    /// Record one completed request.
    pub fn record(&self, queue_ns: u64, compute_ns: u64, e2e_ns: u64) {
        let mut m = self.inner.lock().unwrap();
        m.completed += 1;
        m.queue_ns.record(queue_ns as f64);
        m.compute_ns.record(compute_ns as f64);
        m.e2e_ns.record(e2e_ns as f64);
    }

    /// Record a backpressure rejection.
    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// Record one dispatched batch of `n` frames.
    pub fn record_batch(&self, n: usize) {
        self.inner.lock().unwrap().batch_sizes.record(n as f64);
    }

    /// Update the arena-health gauges (current fallback and grow counts —
    /// the dispatcher reports its model/arena state after each batch, so
    /// a steady-state-allocation regression shows up in serving stats).
    pub fn record_arena_health(&self, fallbacks: u64, grows: u64) {
        let mut m = self.inner.lock().unwrap();
        m.arena_fallbacks = fallbacks;
        m.arena_grows = grows;
    }

    /// Update the per-algorithm dispatch gauge (the model's running
    /// [`DispatchCounts`] totals, reported once per batch like the arena
    /// gauges).
    pub fn record_dispatch_counts(&self, counts: DispatchCounts) {
        self.inner.lock().unwrap().dispatch = counts;
    }

    /// Take a snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let uptime = self.started.elapsed().as_secs_f64().max(1e-9);
        let pct = |r: &Reservoir| -> (f64, f64, f64) {
            if r.is_empty() {
                return (0.0, 0.0, 0.0);
            }
            let v = r.sorted();
            let p = |q: f64| ns_to_ms(percentile_sorted(&v, q));
            (p(50.0), p(90.0), p(99.0))
        };
        MetricsSnapshot {
            completed: m.completed,
            rejected: m.rejected,
            uptime_s: uptime,
            throughput_fps: m.completed as f64 / uptime,
            e2e_ms: pct(&m.e2e_ns),
            compute_ms: pct(&m.compute_ns),
            queue_ms: pct(&m.queue_ns),
            mean_queue_ms: ns_to_ms(m.queue_ns.mean()),
            batches: m.batch_sizes.seen(),
            mean_batch: m.batch_sizes.mean(),
            max_batch_seen: m.batch_sizes.max() as u64,
            arena_fallbacks: m.arena_fallbacks,
            arena_grows: m.arena_grows,
            dispatch: m.dispatch,
        }
    }
}

impl MetricsSnapshot {
    /// One-paragraph human-readable report.
    pub fn report(&self) -> String {
        format!(
            "requests: {} completed, {} rejected | throughput: {:.1} fps | \
             e2e ms p50/p90/p99: {:.2}/{:.2}/{:.2} | \
             compute ms p50/p90/p99: {:.2}/{:.2}/{:.2} | \
             queue ms p50/p90/p99: {:.2}/{:.2}/{:.2} (mean {:.2}) | \
             batches: {} (mean {:.2} frames, max {}) | \
             arena fallbacks/grows: {}/{} | dispatch: {}",
            self.completed,
            self.rejected,
            self.throughput_fps,
            self.e2e_ms.0,
            self.e2e_ms.1,
            self.e2e_ms.2,
            self.compute_ms.0,
            self.compute_ms.1,
            self.compute_ms.2,
            self.queue_ms.0,
            self.queue_ms.1,
            self.queue_ms.2,
            self.mean_queue_ms,
            self.batches,
            self.mean_batch,
            self.max_batch_seen,
            self.arena_fallbacks,
            self.arena_grows,
            self.dispatch,
        )
    }

    /// Prometheus text-format exposition of the full snapshot: counters for
    /// request/batch totals and per-algorithm dispatch lanes, gauges for
    /// uptime/throughput/arena health, and `quantile`-labelled summaries
    /// for the three latency streams — the scrape-able serving surface.
    pub fn prometheus(&self) -> String {
        use std::fmt::Write as _;
        fn scalar(out: &mut String, name: &str, help: &str, ty: &str, v: f64) {
            let _ = writeln!(out, "# HELP winoconv_{name} {help}");
            let _ = writeln!(out, "# TYPE winoconv_{name} {ty}");
            let _ = writeln!(out, "winoconv_{name} {v}");
        }
        fn summary_ms(
            out: &mut String,
            name: &str,
            help: &str,
            q: (f64, f64, f64),
            count: u64,
        ) {
            let _ = writeln!(out, "# HELP winoconv_{name} {help}");
            let _ = writeln!(out, "# TYPE winoconv_{name} summary");
            let _ = writeln!(out, "winoconv_{name}{{quantile=\"0.5\"}} {}", q.0);
            let _ = writeln!(out, "winoconv_{name}{{quantile=\"0.9\"}} {}", q.1);
            let _ = writeln!(out, "winoconv_{name}{{quantile=\"0.99\"}} {}", q.2);
            let _ = writeln!(out, "winoconv_{name}_count {count}");
        }
        let mut s = String::new();
        scalar(
            &mut s,
            "requests_completed_total",
            "Completed requests.",
            "counter",
            self.completed as f64,
        );
        scalar(
            &mut s,
            "requests_rejected_total",
            "Requests rejected by backpressure.",
            "counter",
            self.rejected as f64,
        );
        scalar(&mut s, "uptime_seconds", "Seconds since engine start.", "gauge", self.uptime_s);
        scalar(
            &mut s,
            "throughput_fps",
            "Completed requests per second.",
            "gauge",
            self.throughput_fps,
        );
        summary_ms(
            &mut s,
            "e2e_latency_ms",
            "End-to-end request latency in milliseconds.",
            self.e2e_ms,
            self.completed,
        );
        summary_ms(
            &mut s,
            "compute_latency_ms",
            "Batched-compute latency in milliseconds.",
            self.compute_ms,
            self.completed,
        );
        summary_ms(
            &mut s,
            "queue_wait_ms",
            "Queue-wait latency in milliseconds.",
            self.queue_ms,
            self.completed,
        );
        scalar(
            &mut s,
            "queue_wait_mean_ms",
            "Exact mean queue wait in milliseconds.",
            "gauge",
            self.mean_queue_ms,
        );
        scalar(&mut s, "batches_total", "Dispatched batches.", "counter", self.batches as f64);
        scalar(
            &mut s,
            "batch_size_mean",
            "Exact mean frames per dispatched batch.",
            "gauge",
            self.mean_batch,
        );
        scalar(
            &mut s,
            "batch_size_max",
            "Largest batch dispatched so far.",
            "gauge",
            self.max_batch_seen as f64,
        );
        scalar(
            &mut s,
            "arena_fallbacks",
            "Mutex-contention arena fallbacks (must stay 0).",
            "gauge",
            self.arena_fallbacks as f64,
        );
        scalar(
            &mut s,
            "arena_grows",
            "Arena grow events (non-zero after warm-up is a regression).",
            "gauge",
            self.arena_grows as f64,
        );
        let _ = writeln!(s, "# HELP winoconv_dispatch_total Conv dispatches by algorithm lane.");
        let _ = writeln!(s, "# TYPE winoconv_dispatch_total counter");
        let d = &self.dispatch;
        for (lane, v) in [
            ("winograd", d.winograd),
            ("im2row", d.im2row),
            ("depthwise", d.depthwise),
            ("pointwise", d.pointwise),
            ("direct", d.direct),
            ("im2row_i8", d.im2row_i8),
            ("depthwise_i8", d.depthwise_i8),
            ("pointwise_i8", d.pointwise_i8),
        ] {
            let _ = writeln!(s, "winoconv_dispatch_total{{algo=\"{lane}\"}} {v}");
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let m = ServerMetrics::new();
        for i in 1..=100u64 {
            m.record(i * 1000, i * 2000, i * 3000);
        }
        m.record_rejected();
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        assert_eq!(s.rejected, 1);
        assert!(s.throughput_fps > 0.0);
        // p50 of 1..=100 µs-scale e2e values ≈ 0.1515 ms.
        assert!((s.e2e_ms.0 - 0.1515).abs() < 0.01, "{:?}", s.e2e_ms);
        assert!(s.e2e_ms.2 > s.e2e_ms.0);
        // Queue-wait reservoir gets the same percentile treatment.
        assert!(s.queue_ms.0 > 0.0, "{:?}", s.queue_ms);
        assert!(s.queue_ms.2 > s.queue_ms.0);
    }

    #[test]
    fn batch_size_stats_track_dispatches() {
        let m = ServerMetrics::new();
        for &n in &[1usize, 4, 8, 3] {
            m.record_batch(n);
        }
        let s = m.snapshot();
        assert_eq!(s.batches, 4);
        assert!((s.mean_batch - 4.0).abs() < 1e-9);
        assert_eq!(s.max_batch_seen, 8);
        assert!(s.report().contains("batches: 4 (mean 4.00 frames, max 8)"));
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = ServerMetrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.e2e_ms, (0.0, 0.0, 0.0));
        assert_eq!((s.arena_fallbacks, s.arena_grows), (0, 0));
        assert_eq!(s.dispatch.total(), 0);
        assert!(s.report().contains("0 completed"));
    }

    #[test]
    fn arena_health_gauges_track_latest() {
        let m = ServerMetrics::new();
        m.record_arena_health(0, 0);
        m.record_arena_health(2, 3);
        let s = m.snapshot();
        assert_eq!(s.arena_fallbacks, 2);
        assert_eq!(s.arena_grows, 3);
        assert!(s.report().contains("arena fallbacks/grows: 2/3"));
    }

    /// Minimal Prometheus text-format checker: every non-comment,
    /// non-blank line must be `name 〈float〉` or `name{k="v",...} 〈float〉`
    /// with a legal metric name, and every `# TYPE` must name a known type.
    fn assert_valid_prometheus(text: &str) {
        fn valid_name(n: &str) -> bool {
            !n.is_empty()
                && n.chars().next().map(|c| c.is_ascii_alphabetic() || c == '_').unwrap()
                && n.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
        }
        let mut samples = 0usize;
        for line in text.lines() {
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let ty = rest.split_whitespace().nth(1).expect("TYPE line has a type");
                assert!(
                    ["counter", "gauge", "summary", "histogram", "untyped"].contains(&ty),
                    "bad TYPE: {line}"
                );
                continue;
            }
            if line.starts_with('#') {
                continue;
            }
            let (series, value) = line.rsplit_once(' ').expect("sample line has a value");
            assert!(value.parse::<f64>().is_ok(), "unparsable value in: {line}");
            let name = match series.split_once('{') {
                None => series,
                Some((name, labels)) => {
                    let body = labels.strip_suffix('}').expect("balanced label braces");
                    for pair in body.split(',') {
                        let (k, v) = pair.split_once('=').expect("label k=v");
                        assert!(valid_name(k), "bad label name in: {line}");
                        assert!(
                            v.starts_with('"') && v.ends_with('"') && v.len() >= 2,
                            "unquoted label value in: {line}"
                        );
                    }
                    name
                }
            };
            assert!(valid_name(name), "bad metric name in: {line}");
            samples += 1;
        }
        assert!(samples > 0, "no samples in exposition");
    }

    #[test]
    fn prometheus_exposition_is_valid_and_complete() {
        let m = ServerMetrics::new();
        for i in 1..=50u64 {
            m.record(i * 1000, i * 2000, i * 3000);
        }
        m.record_rejected();
        m.record_batch(4);
        m.record_arena_health(0, 0);
        m.record_dispatch_counts(DispatchCounts {
            winograd: 9,
            im2row: 2,
            depthwise: 0,
            pointwise: 5,
            direct: 0,
            im2row_i8: 0,
            depthwise_i8: 0,
            pointwise_i8: 0,
        });
        let text = m.snapshot().prometheus();
        assert_valid_prometheus(&text);
        // Every snapshot field surfaces as a series.
        for needle in [
            "winoconv_requests_completed_total 50",
            "winoconv_requests_rejected_total 1",
            "winoconv_uptime_seconds",
            "winoconv_throughput_fps",
            "winoconv_e2e_latency_ms{quantile=\"0.5\"}",
            "winoconv_e2e_latency_ms{quantile=\"0.99\"}",
            "winoconv_compute_latency_ms{quantile=\"0.9\"}",
            "winoconv_queue_wait_ms{quantile=\"0.5\"}",
            "winoconv_queue_wait_ms_count 50",
            "winoconv_queue_wait_mean_ms",
            "winoconv_batches_total 1",
            "winoconv_batch_size_mean 4",
            "winoconv_batch_size_max 4",
            "winoconv_arena_fallbacks 0",
            "winoconv_arena_grows 0",
            "winoconv_dispatch_total{algo=\"winograd\"} 9",
            "winoconv_dispatch_total{algo=\"pointwise\"} 5",
            "winoconv_dispatch_total{algo=\"im2row_i8\"} 0",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }

    #[test]
    fn empty_prometheus_exposition_is_valid_too() {
        assert_valid_prometheus(&ServerMetrics::new().snapshot().prometheus());
    }

    /// The satellite fix this PR makes: a million records must not grow the
    /// registry without bound — the reservoirs cap, while counters, means
    /// and maxima stay exact and percentiles stay plausible.
    #[test]
    fn sustained_load_stays_bounded_and_sound() {
        let m = ServerMetrics::new();
        for i in 0..1_000_000u64 {
            // Uniform ramp 0..1ms so true percentiles are known.
            m.record(i % 1_000_000, 1, 1);
            if i % 8 == 0 {
                m.record_batch((i % 7 + 1) as usize);
            }
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 1_000_000);
        assert_eq!(s.batches, 125_000);
        assert_eq!(s.max_batch_seen, 7);
        // True p50 of the 0..1e6 ns ramp is 0.5 ms; the sampled estimate
        // must land well within the sampling error of a 4096-slot uniform
        // reservoir (±~5%).
        assert!((s.queue_ms.0 - 0.5).abs() < 0.05, "p50={}", s.queue_ms.0);
        assert!(s.queue_ms.2 > s.queue_ms.0);
        assert!((s.mean_queue_ms - 0.5).abs() < 1e-3, "exact mean {}", s.mean_queue_ms);
    }

    #[test]
    fn dispatch_gauge_tracks_latest() {
        let m = ServerMetrics::new();
        m.record_dispatch_counts(DispatchCounts {
            winograd: 4,
            im2row: 7,
            depthwise: 13,
            pointwise: 11,
            direct: 0,
            im2row_i8: 2,
            depthwise_i8: 5,
            pointwise_i8: 3,
        });
        let s = m.snapshot();
        assert_eq!(s.dispatch.winograd, 4);
        assert_eq!(s.dispatch.depthwise, 13);
        assert_eq!(s.dispatch.pointwise, 11);
        assert_eq!(s.dispatch.im2row_i8, 2);
        assert_eq!(s.dispatch.depthwise_i8, 5);
        assert_eq!(s.dispatch.pointwise_i8, 3);
        assert_eq!(s.dispatch.total(), 45);
        assert!(s.report().contains(
            "dispatch: winograd 4 / im2row 7 / depthwise 13 / pointwise 11 / direct 0 \
             / im2row_i8 2 / depthwise_i8 5 / pointwise_i8 3"
        ));
    }
}
