//! Serving metrics: counters and latency reservoirs with percentile
//! snapshots (the numbers the paper's deployment claim — frames/sec on the
//! big cluster — is made of).

use crate::nn::DispatchCounts;
use std::sync::Mutex;
use std::time::Instant;

/// Thread-safe metrics registry for one engine.
pub struct ServerMetrics {
    inner: Mutex<Inner>,
    started: Instant,
}

impl std::fmt::Debug for ServerMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServerMetrics").field("started", &self.started).finish_non_exhaustive()
    }
}

struct Inner {
    completed: u64,
    rejected: u64,
    queue_ns: Vec<u64>,
    compute_ns: Vec<u64>,
    e2e_ns: Vec<u64>,
    batch_sizes: Vec<u64>,
    arena_fallbacks: u64,
    arena_grows: u64,
    dispatch: DispatchCounts,
}

/// Point-in-time view of the metrics.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// Completed requests.
    pub completed: u64,
    /// Requests rejected by backpressure.
    pub rejected: u64,
    /// Wall-clock seconds since engine start.
    pub uptime_s: f64,
    /// Completed / uptime.
    pub throughput_fps: f64,
    /// End-to-end latency percentiles in ms: (p50, p90, p99).
    pub e2e_ms: (f64, f64, f64),
    /// Compute-only latency percentiles in ms: (p50, p90, p99).
    pub compute_ms: (f64, f64, f64),
    /// Queue-wait percentiles in ms: (p50, p90, p99). Under the latency-
    /// budgeted batcher, p99 queue wait ≈ batch window + service time of
    /// the batch ahead — the knob the window trades against throughput.
    pub queue_ms: (f64, f64, f64),
    /// Mean queue wait in ms.
    pub mean_queue_ms: f64,
    /// Dispatched batches (one batched execution each).
    pub batches: u64,
    /// Mean frames per dispatched batch (completed / batches); 0 when no
    /// batch has run. The amortization the batched GEMM sweep buys scales
    /// with this number.
    pub mean_batch: f64,
    /// Largest batch dispatched so far.
    pub max_batch_seen: u64,
    /// Arena health: `PreparedModel::run` mutex-contention fallbacks
    /// observed (each one allocated throwaway arenas). The engine's
    /// per-worker-arena path must keep this at 0.
    pub arena_fallbacks: u64,
    /// Arena health: grow events across the worker's scratch + activation
    /// arenas. Non-zero after warm-up means a steady-state-allocation
    /// regression.
    pub arena_grows: u64,
    /// Per-algorithm conv dispatch totals (winograd / im2row / depthwise /
    /// pointwise / direct, plus the int8 lanes im2row_i8 / depthwise_i8 /
    /// pointwise_i8 when the served model was prepared quantized) — which
    /// execution paths the served traffic actually exercised.
    pub dispatch: DispatchCounts,
}

impl Default for ServerMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerMetrics {
    /// Fresh registry; the throughput clock starts now.
    pub fn new() -> ServerMetrics {
        ServerMetrics {
            inner: Mutex::new(Inner {
                completed: 0,
                rejected: 0,
                queue_ns: Vec::new(),
                compute_ns: Vec::new(),
                e2e_ns: Vec::new(),
                batch_sizes: Vec::new(),
                arena_fallbacks: 0,
                arena_grows: 0,
                dispatch: DispatchCounts::default(),
            }),
            started: Instant::now(),
        }
    }

    /// Record one completed request.
    pub fn record(&self, queue_ns: u64, compute_ns: u64, e2e_ns: u64) {
        let mut m = self.inner.lock().unwrap();
        m.completed += 1;
        m.queue_ns.push(queue_ns);
        m.compute_ns.push(compute_ns);
        m.e2e_ns.push(e2e_ns);
    }

    /// Record a backpressure rejection.
    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// Record one dispatched batch of `n` frames.
    pub fn record_batch(&self, n: usize) {
        self.inner.lock().unwrap().batch_sizes.push(n as u64);
    }

    /// Update the arena-health gauges (current fallback and grow counts —
    /// the dispatcher reports its model/arena state after each batch, so
    /// a steady-state-allocation regression shows up in serving stats).
    pub fn record_arena_health(&self, fallbacks: u64, grows: u64) {
        let mut m = self.inner.lock().unwrap();
        m.arena_fallbacks = fallbacks;
        m.arena_grows = grows;
    }

    /// Update the per-algorithm dispatch gauge (the model's running
    /// [`DispatchCounts`] totals, reported once per batch like the arena
    /// gauges).
    pub fn record_dispatch_counts(&self, counts: DispatchCounts) {
        self.inner.lock().unwrap().dispatch = counts;
    }

    /// Take a snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let uptime = self.started.elapsed().as_secs_f64().max(1e-9);
        let pct = |xs: &[u64]| -> (f64, f64, f64) {
            if xs.is_empty() {
                return (0.0, 0.0, 0.0);
            }
            let mut v: Vec<f64> = xs.iter().map(|&x| x as f64).collect();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let p = |q: f64| crate::util::stats::percentile_sorted(&v, q) / 1e6;
            (p(50.0), p(90.0), p(99.0))
        };
        let mean_queue_ms = if m.queue_ns.is_empty() {
            0.0
        } else {
            m.queue_ns.iter().sum::<u64>() as f64 / m.queue_ns.len() as f64 / 1e6
        };
        let batches = m.batch_sizes.len() as u64;
        let mean_batch = if m.batch_sizes.is_empty() {
            0.0
        } else {
            m.batch_sizes.iter().sum::<u64>() as f64 / m.batch_sizes.len() as f64
        };
        MetricsSnapshot {
            completed: m.completed,
            rejected: m.rejected,
            uptime_s: uptime,
            throughput_fps: m.completed as f64 / uptime,
            e2e_ms: pct(&m.e2e_ns),
            compute_ms: pct(&m.compute_ns),
            queue_ms: pct(&m.queue_ns),
            mean_queue_ms,
            batches,
            mean_batch,
            max_batch_seen: m.batch_sizes.iter().copied().max().unwrap_or(0),
            arena_fallbacks: m.arena_fallbacks,
            arena_grows: m.arena_grows,
            dispatch: m.dispatch,
        }
    }
}

impl MetricsSnapshot {
    /// One-paragraph human-readable report.
    pub fn report(&self) -> String {
        format!(
            "requests: {} completed, {} rejected | throughput: {:.1} fps | \
             e2e ms p50/p90/p99: {:.2}/{:.2}/{:.2} | \
             compute ms p50/p90/p99: {:.2}/{:.2}/{:.2} | \
             queue ms p50/p90/p99: {:.2}/{:.2}/{:.2} (mean {:.2}) | \
             batches: {} (mean {:.2} frames, max {}) | \
             arena fallbacks/grows: {}/{} | dispatch: {}",
            self.completed,
            self.rejected,
            self.throughput_fps,
            self.e2e_ms.0,
            self.e2e_ms.1,
            self.e2e_ms.2,
            self.compute_ms.0,
            self.compute_ms.1,
            self.compute_ms.2,
            self.queue_ms.0,
            self.queue_ms.1,
            self.queue_ms.2,
            self.mean_queue_ms,
            self.batches,
            self.mean_batch,
            self.max_batch_seen,
            self.arena_fallbacks,
            self.arena_grows,
            self.dispatch,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let m = ServerMetrics::new();
        for i in 1..=100u64 {
            m.record(i * 1000, i * 2000, i * 3000);
        }
        m.record_rejected();
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        assert_eq!(s.rejected, 1);
        assert!(s.throughput_fps > 0.0);
        // p50 of 1..=100 µs-scale e2e values ≈ 0.1515 ms.
        assert!((s.e2e_ms.0 - 0.1515).abs() < 0.01, "{:?}", s.e2e_ms);
        assert!(s.e2e_ms.2 > s.e2e_ms.0);
        // Queue-wait reservoir gets the same percentile treatment.
        assert!(s.queue_ms.0 > 0.0, "{:?}", s.queue_ms);
        assert!(s.queue_ms.2 > s.queue_ms.0);
    }

    #[test]
    fn batch_size_stats_track_dispatches() {
        let m = ServerMetrics::new();
        for &n in &[1usize, 4, 8, 3] {
            m.record_batch(n);
        }
        let s = m.snapshot();
        assert_eq!(s.batches, 4);
        assert!((s.mean_batch - 4.0).abs() < 1e-9);
        assert_eq!(s.max_batch_seen, 8);
        assert!(s.report().contains("batches: 4 (mean 4.00 frames, max 8)"));
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = ServerMetrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.e2e_ms, (0.0, 0.0, 0.0));
        assert_eq!((s.arena_fallbacks, s.arena_grows), (0, 0));
        assert_eq!(s.dispatch.total(), 0);
        assert!(s.report().contains("0 completed"));
    }

    #[test]
    fn arena_health_gauges_track_latest() {
        let m = ServerMetrics::new();
        m.record_arena_health(0, 0);
        m.record_arena_health(2, 3);
        let s = m.snapshot();
        assert_eq!(s.arena_fallbacks, 2);
        assert_eq!(s.arena_grows, 3);
        assert!(s.report().contains("arena fallbacks/grows: 2/3"));
    }

    #[test]
    fn dispatch_gauge_tracks_latest() {
        let m = ServerMetrics::new();
        m.record_dispatch_counts(DispatchCounts {
            winograd: 4,
            im2row: 7,
            depthwise: 13,
            pointwise: 11,
            direct: 0,
            im2row_i8: 2,
            depthwise_i8: 5,
            pointwise_i8: 3,
        });
        let s = m.snapshot();
        assert_eq!(s.dispatch.winograd, 4);
        assert_eq!(s.dispatch.depthwise, 13);
        assert_eq!(s.dispatch.pointwise, 11);
        assert_eq!(s.dispatch.im2row_i8, 2);
        assert_eq!(s.dispatch.depthwise_i8, 5);
        assert_eq!(s.dispatch.pointwise_i8, 3);
        assert_eq!(s.dispatch.total(), 45);
        assert!(s.report().contains(
            "dispatch: winograd 4 / im2row 7 / depthwise 13 / pointwise 11 / direct 0 \
             / im2row_i8 2 / depthwise_i8 5 / pointwise_i8 3"
        ));
    }
}
