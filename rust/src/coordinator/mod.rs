//! L3 serving coordinator: request queue → dynamic batcher → worker pool →
//! per-request latency metrics.
//!
//! The paper's headline deployment claim is real-time single-stream
//! inference ("47 frames/sec SqueezeNet on 4× Cortex-A73", §1); this module
//! is the engine a downstream user would wrap around the kernels to get
//! there: clients submit NHWC frames, the dispatcher coalesces them into
//! batches (the prepared models are shape-specialised, so batching here
//! means queueing batch-1 executions back-to-back — exactly the paper's
//! batch-size-1 setting — while keeping the worker pipeline full), and a
//! metrics registry tracks latency percentiles and throughput. Each worker
//! loop owns a pre-sized [`crate::workspace::Workspace`] arena **pair** —
//! conv scratch sized to the model's largest layer, activations sized to
//! the prepare-time plan's peak (`PreparedModel::activation_plan()`) — and
//! executes via the planned write-into path, so steady-state serving
//! performs zero heap allocation inside inference. Arena health (run()
//! fallbacks, grow events) is exported with every metrics snapshot.

pub mod metrics;
pub mod queue;
pub mod engine;

pub use engine::{EngineConfig, InferenceEngine};
pub use metrics::{MetricsSnapshot, ServerMetrics};
pub use queue::{Request, RequestQueue, Response};
