//! L3 serving coordinator: request queue → dynamic batcher → worker pool →
//! per-request latency metrics.
//!
//! The paper's headline deployment claim is real-time single-stream
//! inference ("47 frames/sec SqueezeNet on 4× Cortex-A73", §1); this module
//! is the engine a downstream user would wrap around the kernels to get
//! there: clients submit NHWC frames, the dispatcher coalesces them into
//! batches (the prepared models are shape-specialised, so batching here
//! means queueing batch-1 executions back-to-back — exactly the paper's
//! batch-size-1 setting — while keeping the worker pipeline full), and a
//! metrics registry tracks latency percentiles and throughput. Each worker
//! loop owns one [`crate::workspace::Workspace`] arena pre-sized to the
//! model's largest layer, so steady-state serving allocates no per-request
//! scratch.

pub mod metrics;
pub mod queue;
pub mod engine;

pub use engine::{EngineConfig, InferenceEngine};
pub use metrics::{MetricsSnapshot, ServerMetrics};
pub use queue::{Request, RequestQueue, Response};
