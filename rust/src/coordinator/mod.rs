//! L3 serving coordinator: request queue → dynamic batcher → worker pool →
//! per-request latency metrics.
//!
//! The paper's headline deployment claim is real-time single-stream
//! inference ("47 frames/sec SqueezeNet on 4× Cortex-A73", §1); this module
//! is the engine a downstream user would wrap around the kernels to get
//! there — and past it, to N > 1: clients submit NHWC frames, and the
//! dispatcher gathers them into **real batches** under a configurable
//! latency budget ([`EngineConfig::batch_window`] — a batch closes when it
//! reaches `max_batch` frames or the window elapses, whichever first). Each
//! batch executes as *one* batched planned walk
//! (`PreparedModel::run_planned_batched_into`): the k frames ride as extra
//! rows of every layer's GEMM, so each packed weight panel streams through
//! cache once for all k frames instead of once per frame. A metrics
//! registry tracks p50/p99 queue-wait, compute, and end-to-end latency
//! percentiles plus batch-size stats and throughput. The dispatcher owns a
//! pre-sized [`crate::workspace::Workspace`] arena **pair** sized for
//! `max_batch` — conv scratch to the model's largest layer at full batch,
//! activations to the plan's peak × `max_batch` — and executes via the
//! batched write-into path, so steady-state serving performs zero heap
//! allocation inside inference at every batch size. Arena health (run()
//! fallbacks, grow events) is exported with every metrics snapshot.

pub mod metrics;
pub mod queue;
pub mod engine;

pub use engine::{EngineConfig, InferenceEngine};
pub use metrics::{MetricsSnapshot, ServerMetrics};
pub use queue::{Request, RequestQueue, Response};
