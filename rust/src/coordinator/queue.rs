//! Bounded MPMC request queue with blocking pop and backpressure
//! (offline build: no crossbeam/tokio — Mutex + Condvar).

use crate::tensor::Tensor;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One inference request.
#[derive(Debug)]
pub struct Request {
    /// Client-assigned id, echoed in the [`Response`].
    pub id: u64,
    /// One NHWC input frame (`[1, H, W, C]`); the dispatcher gathers up to
    /// `max_batch` of these into a single batched execution.
    pub input: Tensor,
    /// Submission timestamp (for end-to-end latency).
    pub submitted: Instant,
}

/// One inference response.
#[derive(Debug)]
pub struct Response {
    /// Request id.
    pub id: u64,
    /// Output tensor (class scores).
    pub output: Tensor,
    /// Queue wait time.
    pub queue_ns: u64,
    /// Pure compute time.
    pub compute_ns: u64,
}

struct Inner {
    queue: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct QueueState {
    items: VecDeque<Request>,
    closed: bool,
}

/// A bounded blocking queue of [`Request`]s shared between clients and the
/// engine's dispatcher.
#[derive(Clone)]
pub struct RequestQueue {
    inner: Arc<Inner>,
}

impl std::fmt::Debug for RequestQueue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RequestQueue").field("len", &self.len()).finish_non_exhaustive()
    }
}

impl RequestQueue {
    /// New queue holding at most `capacity` pending requests.
    pub fn new(capacity: usize) -> RequestQueue {
        RequestQueue {
            inner: Arc::new(Inner {
                queue: Mutex::new(QueueState {
                    items: VecDeque::new(),
                    closed: false,
                }),
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
                capacity: capacity.max(1),
            }),
        }
    }

    /// Blocking push; applies backpressure when full. Returns `false` if the
    /// queue has been closed.
    pub fn push(&self, req: Request) -> bool {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if st.closed {
                return false;
            }
            if st.items.len() < self.inner.capacity {
                st.items.push_back(req);
                self.inner.not_empty.notify_one();
                return true;
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking push. `Err(req)` when full or closed.
    pub fn try_push(&self, req: Request) -> Result<(), Request> {
        let mut st = self.inner.queue.lock().unwrap();
        if st.closed || st.items.len() >= self.inner.capacity {
            return Err(req);
        }
        st.items.push_back(req);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Pop up to `max` requests, waiting up to `wait` for the first one.
    /// Returns an empty vec on timeout; `None` when closed and drained.
    /// Drains whatever is pending as soon as anything arrives — a zero
    /// latency budget (see [`RequestQueue::pop_batch_budgeted`]).
    pub fn pop_batch(&self, max: usize, wait: Duration) -> Option<Vec<Request>> {
        self.pop_batch_budgeted(max, wait, Duration::ZERO)
    }

    /// Pop up to `max` requests under a latency budget: wait up to `wait`
    /// for the first request, then hold the batch open until it either
    /// fills to `max` or `budget` elapses — whichever comes first. The
    /// budget clock starts when the first request is seen, so an idle
    /// queue costs `wait`, not `wait + budget`. Returns an empty vec when
    /// no request arrived within `wait`; `None` when closed and drained.
    pub fn pop_batch_budgeted(
        &self,
        max: usize,
        wait: Duration,
        budget: Duration,
    ) -> Option<Vec<Request>> {
        let wait_deadline = Instant::now() + wait;
        let mut st = self.inner.queue.lock().unwrap();
        while st.items.is_empty() {
            if st.closed {
                return None;
            }
            let now = Instant::now();
            if now >= wait_deadline {
                return Some(Vec::new());
            }
            let (guard, _timeout) = self
                .inner
                .not_empty
                .wait_timeout(st, wait_deadline - now)
                .unwrap();
            st = guard;
        }
        let max = max.max(1);
        let close = Instant::now() + budget;
        while st.items.len() < max && !st.closed {
            let now = Instant::now();
            if now >= close {
                break;
            }
            let (guard, _timeout) = self
                .inner
                .not_empty
                .wait_timeout(st, close - now)
                .unwrap();
            st = guard;
        }
        let take = st.items.len().min(max);
        let batch: Vec<Request> = st.items.drain(..take).collect();
        self.inner.not_full.notify_all();
        Some(batch)
    }

    /// Pending request count.
    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().items.len()
    }

    /// True when no requests are pending.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: pushes fail, pops drain then return `None`.
    pub fn close(&self) {
        let mut st = self.inner.queue.lock().unwrap();
        st.closed = true;
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn req(id: u64) -> Request {
        Request {
            id,
            input: Tensor::zeros(&[1, 1, 1, 1]),
            submitted: Instant::now(),
        }
    }

    #[test]
    fn fifo_order_and_batching() {
        let q = RequestQueue::new(8);
        for i in 0..5 {
            assert!(q.push(req(i)));
        }
        let batch = q.pop_batch(3, Duration::from_millis(10)).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        let batch = q.pop_batch(10, Duration::from_millis(10)).unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn timeout_returns_empty() {
        let q = RequestQueue::new(2);
        let batch = q.pop_batch(4, Duration::from_millis(5)).unwrap();
        assert!(batch.is_empty());
    }

    /// A budgeted pop with fewer than `max` requests pending closes the
    /// batch at the deadline and returns the partial batch, rather than
    /// stalling until it fills.
    #[test]
    fn budgeted_pop_closes_partial_batch_at_deadline() {
        let q = RequestQueue::new(8);
        for i in 0..3 {
            assert!(q.push(req(i)));
        }
        let t0 = Instant::now();
        let batch = q
            .pop_batch_budgeted(8, Duration::from_millis(100), Duration::from_millis(20))
            .unwrap();
        let elapsed = t0.elapsed();
        assert_eq!(batch.len(), 3, "deadline-closed batch carries what arrived");
        assert!(elapsed >= Duration::from_millis(20), "held open for the budget");
        assert!(elapsed < Duration::from_millis(100), "did not wait the full poll");
    }

    /// A batch that fills to `max` closes immediately, without burning the
    /// rest of its latency budget.
    #[test]
    fn budgeted_pop_closes_full_batch_early() {
        let q = RequestQueue::new(8);
        for i in 0..4 {
            assert!(q.push(req(i)));
        }
        let t0 = Instant::now();
        let batch = q
            .pop_batch_budgeted(4, Duration::from_millis(100), Duration::from_secs(5))
            .unwrap();
        assert_eq!(batch.len(), 4);
        assert!(t0.elapsed() < Duration::from_secs(1), "full batch closes early");
    }

    /// Requests arriving while the batch is held open join it.
    #[test]
    fn budgeted_pop_gathers_late_arrivals() {
        let q = RequestQueue::new(8);
        q.push(req(0));
        let q2 = q.clone();
        let h = thread::spawn(move || {
            thread::sleep(Duration::from_millis(10));
            q2.push(req(1));
            q2.push(req(2));
        });
        let batch = q
            .pop_batch_budgeted(3, Duration::from_millis(100), Duration::from_millis(200))
            .unwrap();
        h.join().unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn try_push_respects_capacity() {
        let q = RequestQueue::new(2);
        assert!(q.try_push(req(0)).is_ok());
        assert!(q.try_push(req(1)).is_ok());
        assert!(q.try_push(req(2)).is_err());
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_drains_then_none() {
        let q = RequestQueue::new(4);
        q.push(req(1));
        q.close();
        assert!(!q.push(req(2)));
        let batch = q.pop_batch(4, Duration::from_millis(5)).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(q.pop_batch(4, Duration::from_millis(5)).is_none());
    }

    #[test]
    fn blocking_push_wakes_on_pop() {
        let q = RequestQueue::new(1);
        q.push(req(0));
        let q2 = q.clone();
        let h = thread::spawn(move || q2.push(req(1)));
        thread::sleep(Duration::from_millis(20));
        let b = q.pop_batch(1, Duration::from_millis(100)).unwrap();
        assert_eq!(b[0].id, 0);
        assert!(h.join().unwrap());
        let b = q.pop_batch(1, Duration::from_millis(100)).unwrap();
        assert_eq!(b[0].id, 1);
    }

    #[test]
    fn cross_thread_producer_consumer() {
        let q = RequestQueue::new(16);
        let producer = {
            let q = q.clone();
            thread::spawn(move || {
                for i in 0..100 {
                    q.push(req(i));
                }
                q.close();
            })
        };
        let mut seen = Vec::new();
        loop {
            match q.pop_batch(7, Duration::from_millis(50)) {
                None => break,
                Some(batch) => seen.extend(batch.iter().map(|r| r.id)),
            }
        }
        producer.join().unwrap();
        assert_eq!(seen.len(), 100);
        let mut sorted = seen.clone();
        sorted.sort_unstable();
        assert_eq!(seen, sorted, "FIFO per producer");
    }
}
