//! Batched GEMM: the `x²` independent `[R×C]·[C×M]` products at the heart of
//! the region-wise Winograd scheme (Figure 2(d) of the paper).
//!
//! All `x²` A-matrices live in one contiguous buffer (`[tile][R][C]`), as do
//! the B-matrices (`[tile][C][M]`) and outputs (`[tile][R][M]`) — exactly the
//! buffers the scatter (input transform) writes and the gather (output
//! transform) reads. Parallelism goes across (tile, M-block) pairs.
//!
//! With region blocking (convolve.rs), `R` is a *block* of regions rather
//! than the whole feature map, and the A/C buffers are arena slices from
//! [`crate::workspace::Workspace`]; together with the per-thread pack
//! scratch in [`super`], a steady-state batched GEMM performs no heap
//! allocation.

use super::{sgemm_blocked, sgemm_prepacked, Blocking, PackedB};
use crate::parallel::ThreadPool;

/// Descriptor for a uniform batch of GEMMs.
#[derive(Debug, Clone, Copy)]
pub struct BatchedGemm {
    /// Number of independent GEMMs (`x²` for an `x×x` Winograd tile).
    pub batch: usize,
    /// Rows per GEMM — the number of output regions R.
    pub m: usize,
    /// Inner dimension — input channels C.
    pub k: usize,
    /// Columns per GEMM — output channels M.
    pub n: usize,
}

impl BatchedGemm {
    /// Elements in each A matrix.
    pub fn a_stride(&self) -> usize {
        self.m * self.k
    }

    /// Elements in each B matrix.
    pub fn b_stride(&self) -> usize {
        self.k * self.n
    }

    /// Elements in each C matrix.
    pub fn c_stride(&self) -> usize {
        self.m * self.n
    }

    /// Total FLOPs for the whole batch (2·M·N·K each).
    pub fn flops(&self) -> usize {
        2 * self.batch * self.m * self.n * self.k
    }

    /// Workspace elements the batch's A + C buffers occupy — what one
    /// Winograd region block borrows from the arena for this GEMM shape.
    pub fn workspace_elems(&self) -> usize {
        self.batch * (self.a_stride() + self.c_stride())
    }

    /// Execute serially: `C[t] = A[t]·B[t]` for every tile `t`.
    pub fn run(&self, a: &[f32], b: &[f32], c: &mut [f32]) {
        self.validate(a, b, c);
        for t in 0..self.batch {
            sgemm_blocked(
                self.m,
                self.n,
                self.k,
                &a[t * self.a_stride()..],
                self.k,
                &b[t * self.b_stride()..],
                self.n,
                &mut c[t * self.c_stride()..],
                self.n,
                false,
                Blocking::default(),
                None,
            );
        }
    }

    /// Execute with tiles distributed across the threadpool.
    ///
    /// Each tile's GEMM is independent, so tiles are the natural parallel
    /// axis (the paper runs them across the A73 big cluster). Tiles are
    /// chunked one-at-a-time: with x²∈{16,36,64} tiles and ≤16 threads every
    /// worker gets ≥1 whole GEMM.
    pub fn run_with_pool(&self, pool: &ThreadPool, a: &[f32], b: &[f32], c: &mut [f32]) {
        self.validate(a, b, c);
        let c_addr = c.as_mut_ptr() as usize;
        let (bgd, a_ref, b_ref) = (*self, a, b);
        pool.parallel_for(self.batch, move |t| {
            // SAFETY: tile t writes only its own c_stride window; tiles are
            // disjoint.
            let ct: &mut [f32] = unsafe {
                std::slice::from_raw_parts_mut(
                    (c_addr as *mut f32).add(t * bgd.c_stride()),
                    bgd.c_stride(),
                )
            };
            sgemm_blocked(
                bgd.m,
                bgd.n,
                bgd.k,
                &a_ref[t * bgd.a_stride()..],
                bgd.k,
                &b_ref[t * bgd.b_stride()..],
                bgd.n,
                ct,
                bgd.n,
                false,
                Blocking::default(),
                None,
            );
        });
    }

    /// Pre-pack the B operand of every tile (done once per layer at prepare
    /// time; see EXPERIMENTS.md §Perf).
    pub fn prepack_b(&self, b: &[f32]) -> Vec<PackedB> {
        assert!(b.len() >= self.batch * self.b_stride(), "batched B too small");
        (0..self.batch)
            .map(|t| PackedB::pack(&b[t * self.b_stride()..], self.n, self.k, self.n))
            .collect()
    }

    /// Execute against pre-packed B matrices, tiles across the pool.
    pub fn run_prepacked(
        &self,
        pool: Option<&ThreadPool>,
        a: &[f32],
        b: &[PackedB],
        c: &mut [f32],
    ) {
        assert_eq!(b.len(), self.batch, "prepacked batch size mismatch");
        assert!(a.len() >= self.batch * self.a_stride(), "batched A too small");
        assert!(c.len() >= self.batch * self.c_stride(), "batched C too small");
        let c_addr = c.as_mut_ptr() as usize;
        let (bgd, a_ref) = (*self, a);
        let run_tile = |t: usize| {
            // SAFETY: tile t writes only its own c window; tiles disjoint.
            let ct: &mut [f32] = unsafe {
                std::slice::from_raw_parts_mut(
                    (c_addr as *mut f32).add(t * bgd.c_stride()),
                    bgd.c_stride(),
                )
            };
            sgemm_prepacked(
                bgd.m,
                &a_ref[t * bgd.a_stride()..],
                bgd.k,
                &b[t],
                ct,
                bgd.n,
                false,
                None,
            );
        };
        match pool {
            Some(pool) => pool.parallel_for(self.batch, run_tile),
            None => (0..self.batch).for_each(run_tile),
        }
    }

    fn validate(&self, a: &[f32], b: &[f32], c: &[f32]) {
        assert!(a.len() >= self.batch * self.a_stride(), "batched A too small");
        assert!(b.len() >= self.batch * self.b_stride(), "batched B too small");
        assert!(c.len() >= self.batch * self.c_stride(), "batched C too small");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::sgemm_ref;
    use crate::util::{rel_error, XorShiftRng};

    fn reference(bgd: &BatchedGemm, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0; bgd.batch * bgd.c_stride()];
        for t in 0..bgd.batch {
            let mut ct = vec![0.0; bgd.c_stride()];
            sgemm_ref(
                bgd.m,
                bgd.n,
                bgd.k,
                &a[t * bgd.a_stride()..(t + 1) * bgd.a_stride()],
                &b[t * bgd.b_stride()..(t + 1) * bgd.b_stride()],
                &mut ct,
            );
            c[t * bgd.c_stride()..(t + 1) * bgd.c_stride()].copy_from_slice(&ct);
        }
        c
    }

    fn random(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = XorShiftRng::new(seed);
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v);
        v
    }

    #[test]
    fn serial_matches_reference() {
        let bgd = BatchedGemm { batch: 16, m: 9, k: 7, n: 11 };
        let a = random(bgd.batch * bgd.a_stride(), 1);
        let b = random(bgd.batch * bgd.b_stride(), 2);
        let mut c = vec![0.0; bgd.batch * bgd.c_stride()];
        bgd.run(&a, &b, &mut c);
        assert!(rel_error(&c, &reference(&bgd, &a, &b)) < 1e-4);
    }

    #[test]
    fn parallel_matches_serial() {
        let pool = ThreadPool::new(4);
        let bgd = BatchedGemm { batch: 36, m: 25, k: 16, n: 32 };
        let a = random(bgd.batch * bgd.a_stride(), 3);
        let b = random(bgd.batch * bgd.b_stride(), 4);
        let mut c1 = vec![0.0; bgd.batch * bgd.c_stride()];
        let mut c2 = vec![0.0; bgd.batch * bgd.c_stride()];
        bgd.run(&a, &b, &mut c1);
        bgd.run_with_pool(&pool, &a, &b, &mut c2);
        assert!(rel_error(&c2, &c1) < 1e-6);
    }

    #[test]
    fn prepacked_matches_plain() {
        let bgd = BatchedGemm { batch: 8, m: 5, k: 37, n: 19 };
        let a = random(bgd.batch * bgd.a_stride(), 7);
        let b = random(bgd.batch * bgd.b_stride(), 8);
        let packed = bgd.prepack_b(&b);
        let mut c1 = vec![0.0; bgd.batch * bgd.c_stride()];
        let mut c2 = vec![0.0; bgd.batch * bgd.c_stride()];
        bgd.run(&a, &b, &mut c1);
        bgd.run_prepacked(None, &a, &packed, &mut c2);
        assert!(rel_error(&c2, &c1) < 1e-6);
        let pool = ThreadPool::new(3);
        let mut c3 = vec![0.0; bgd.batch * bgd.c_stride()];
        bgd.run_prepacked(Some(&pool), &a, &packed, &mut c3);
        assert!(rel_error(&c3, &c1) < 1e-6);
    }

    #[test]
    fn flops_formula() {
        let bgd = BatchedGemm { batch: 16, m: 10, k: 3, n: 4 };
        assert_eq!(bgd.flops(), 2 * 16 * 10 * 3 * 4);
        assert_eq!(bgd.workspace_elems(), 16 * (10 * 3 + 10 * 4));
    }

    #[test]
    fn single_tile_batch() {
        let bgd = BatchedGemm { batch: 1, m: 8, k: 8, n: 8 };
        let a = random(64, 5);
        let b = random(64, 6);
        let mut c = vec![0.0; 64];
        bgd.run(&a, &b, &mut c);
        assert!(rel_error(&c, &reference(&bgd, &a, &b)) < 1e-4);
    }
}
