//! Batched GEMM: the `x²` independent `[R×C]·[C×M]` products at the heart of
//! the region-wise Winograd scheme (Figure 2(d) of the paper).
//!
//! Two execution styles coexist:
//!
//! * **Staged** ([`BatchedGemm::run`] / [`BatchedGemm::run_prepacked`]) —
//!   all `x²` A-matrices live in one contiguous buffer (`[tile][R][C]`),
//!   outputs in `[tile][R][M]`; the scatter writes A, the GEMMs run, the
//!   gather reads C. Parallelism goes across tiles.
//! * **Fused** ([`BatchedGemm::run_packed_fused`]) — A arrives already in
//!   packed `MR`-panel layout (`[tile][`[`packed_a_elems`]`(R, C)]`,
//!   written by the transform via [`super::pack::packed_a_index`]), and C
//!   is **never materialised**: for each `MR`-region row panel and each
//!   `NR`-channel column panel, the `x²` per-tile micro-tiles are computed
//!   into one `[tiles]×MR×NR` per-thread hot cube and immediately handed
//!   to the [`Epilogue`] (the inverse-transform gather) while L1-hot.
//!   That is the paper's §2.2 interleaving: Winograd-domain data flows
//!   registers → epilogue without a round-trip through memory.
//!
//! With region blocking (convolve.rs), `R` is a *block* of regions rather
//! than the whole feature map, and the A (and, staged-only, C) buffers are
//! arena slices from [`crate::workspace::Workspace`]; together with the
//! per-thread pack scratch in [`super`], a steady-state batched GEMM
//! performs no heap allocation.

use super::microkernel::kernel_mr_nr;
use super::pack::packed_a_elems;
use super::{sgemm_blocked, sgemm_prepacked, with_scratch, Blocking, Epilogue, PackedB, MR, NR};
use crate::parallel::ThreadPool;
use std::cell::RefCell;

thread_local! {
    // Per-thread hot cube for the fused driver: `tiles × MR × NR` floats
    // (≤ 64·6·16 = 24 KiB — L1/L2 resident), reused across row panels and
    // calls so the fused path allocates nothing in steady state.
    static HOT_C_SCRATCH: RefCell<Vec<f32>> = RefCell::new(Vec::new());
}

/// Descriptor for a uniform batch of GEMMs.
#[derive(Debug, Clone, Copy)]
pub struct BatchedGemm {
    /// Number of independent GEMMs (`x²` for an `x×x` Winograd tile).
    pub batch: usize,
    /// Rows per GEMM — the number of output regions R.
    pub m: usize,
    /// Inner dimension — input channels C.
    pub k: usize,
    /// Columns per GEMM — output channels M.
    pub n: usize,
}

impl BatchedGemm {
    /// Elements in each A matrix.
    pub fn a_stride(&self) -> usize {
        self.m * self.k
    }

    /// Elements in each B matrix.
    pub fn b_stride(&self) -> usize {
        self.k * self.n
    }

    /// Elements in each C matrix.
    pub fn c_stride(&self) -> usize {
        self.m * self.n
    }

    /// Total FLOPs for the whole batch (2·M·N·K each).
    pub fn flops(&self) -> usize {
        2 * self.batch * self.m * self.n * self.k
    }

    /// Workspace elements the batch's A + C buffers occupy — what one
    /// Winograd region block borrows from the arena for the **staged**
    /// pipeline at this GEMM shape.
    pub fn workspace_elems(&self) -> usize {
        self.batch * (self.a_stride() + self.c_stride())
    }

    /// Elements of one tile's packed-A image (`MR`-panel layout over
    /// `m × k`) — the per-tile stride inside the fused driver's A buffer.
    pub fn packed_a_stride(&self) -> usize {
        packed_a_elems(self.m, self.k)
    }

    /// Elements of the whole batch's packed-A buffer — what one Winograd
    /// region block borrows from the arena for the **fused** pipeline
    /// (there is no C buffer at all).
    pub fn packed_a_elems_total(&self) -> usize {
        self.batch * self.packed_a_stride()
    }

    /// Execute serially: `C[t] = A[t]·B[t]` for every tile `t`.
    pub fn run(&self, a: &[f32], b: &[f32], c: &mut [f32]) {
        self.validate(a, b, c);
        for t in 0..self.batch {
            sgemm_blocked(
                self.m,
                self.n,
                self.k,
                &a[t * self.a_stride()..],
                self.k,
                &b[t * self.b_stride()..],
                self.n,
                &mut c[t * self.c_stride()..],
                self.n,
                false,
                Blocking::default(),
                None,
            );
        }
    }

    /// Execute with tiles distributed across the threadpool.
    ///
    /// Each tile's GEMM is independent, so tiles are the natural parallel
    /// axis (the paper runs them across the A73 big cluster). Tiles are
    /// chunked one-at-a-time: with x²∈{16,36,64} tiles and ≤16 threads every
    /// worker gets ≥1 whole GEMM.
    pub fn run_with_pool(&self, pool: &ThreadPool, a: &[f32], b: &[f32], c: &mut [f32]) {
        self.validate(a, b, c);
        let c_addr = c.as_mut_ptr() as usize;
        let (bgd, a_ref, b_ref) = (*self, a, b);
        pool.parallel_for(self.batch, move |t| {
            // SAFETY: tile t writes only its own c_stride window; tiles are
            // disjoint.
            let ct: &mut [f32] = unsafe {
                std::slice::from_raw_parts_mut(
                    (c_addr as *mut f32).add(t * bgd.c_stride()),
                    bgd.c_stride(),
                )
            };
            sgemm_blocked(
                bgd.m,
                bgd.n,
                bgd.k,
                &a_ref[t * bgd.a_stride()..],
                bgd.k,
                &b_ref[t * bgd.b_stride()..],
                bgd.n,
                ct,
                bgd.n,
                false,
                Blocking::default(),
                None,
            );
        });
    }

    /// Pre-pack the B operand of every tile (done once per layer at prepare
    /// time; see EXPERIMENTS.md §Perf).
    pub fn prepack_b(&self, b: &[f32]) -> Vec<PackedB> {
        assert!(b.len() >= self.batch * self.b_stride(), "batched B too small");
        (0..self.batch)
            .map(|t| PackedB::pack(&b[t * self.b_stride()..], self.n, self.k, self.n))
            .collect()
    }

    /// Execute against pre-packed B matrices, tiles across the pool.
    pub fn run_prepacked(
        &self,
        pool: Option<&ThreadPool>,
        a: &[f32],
        b: &[PackedB],
        c: &mut [f32],
    ) {
        assert_eq!(b.len(), self.batch, "prepacked batch size mismatch");
        assert!(a.len() >= self.batch * self.a_stride(), "batched A too small");
        assert!(c.len() >= self.batch * self.c_stride(), "batched C too small");
        let c_addr = c.as_mut_ptr() as usize;
        let (bgd, a_ref) = (*self, a);
        let run_tile = |t: usize| {
            // SAFETY: tile t writes only its own c window; tiles disjoint.
            let ct: &mut [f32] = unsafe {
                std::slice::from_raw_parts_mut(
                    (c_addr as *mut f32).add(t * bgd.c_stride()),
                    bgd.c_stride(),
                )
            };
            sgemm_prepacked(
                bgd.m,
                &a_ref[t * bgd.a_stride()..],
                bgd.k,
                &b[t],
                ct,
                bgd.n,
                false,
                None,
            );
        };
        match pool {
            Some(pool) => pool.parallel_for(self.batch, run_tile),
            None => (0..self.batch).for_each(run_tile),
        }
    }

    /// The fused driver: per-tile **packed** A panels in, [`Epilogue`]
    /// invocations out — no C matrices exist.
    ///
    /// `a_packed` holds `batch` per-tile packed-A images of
    /// [`packed_a_stride`](Self::packed_a_stride) elements each (produced
    /// by transform-as-pack via [`super::pack::packed_a_index`], dead rows
    /// of a short last panel zeroed). `b` holds one [`PackedB`] per tile
    /// (`k×n` each).
    ///
    /// For every `MR`-row panel `ip` (the parallel axis) and every
    /// `NR`-column panel `jp`, the driver accumulates all `batch` per-tile
    /// `MR×NR` micro-tiles — full depth `k`, KC blocks in registers — into
    /// a per-thread `[batch]×MR×NR` hot cube, then fires
    /// `epi.micro_tile(cube, NR, ip·MR, jp·NR, rows, cols)` **once** with
    /// the whole cube while it is L1-hot. `rows`/`cols` are the valid
    /// extents (`min(MR, m − ip·MR)`, `min(NR, n − jp·NR)`); tile `t`'s
    /// micro-tile sits at `cube[t·MR·NR ..]`. This cube convention is the
    /// one deliberate widening of the [`Epilogue`] contract: the Winograd
    /// gather needs all `x²` tile values of a region at once.
    pub fn run_packed_fused<E: Epilogue>(
        &self,
        pool: Option<&ThreadPool>,
        a_packed: &[f32],
        b: &[PackedB],
        epi: &E,
    ) {
        assert_eq!(b.len(), self.batch, "prepacked batch size mismatch");
        assert!(
            a_packed.len() >= self.packed_a_elems_total(),
            "batched packed A too small"
        );
        if self.m == 0 || self.n == 0 || self.batch == 0 {
            return;
        }
        if self.k == 0 {
            // Degenerate zero-depth batch: C is all zeros, but the epilogue
            // still fires once per (row panel, col panel) with zeroed cubes
            // — fused post-processing (bias/ReLU in the gather) must be
            // applied regardless of the inner dimension, and a stale hot
            // cube must never reach the epilogue.
            with_scratch(&HOT_C_SCRATCH, self.batch * MR * NR, |hot| {
                hot.fill(0.0);
                for ip in 0..self.m.div_ceil(MR) {
                    let rows = (self.m - ip * MR).min(MR);
                    for jp in 0..self.n.div_ceil(NR) {
                        let cols = (self.n - jp * NR).min(NR);
                        epi.micro_tile(hot, NR, ip * MR, jp * NR, rows, cols);
                    }
                }
            });
            return;
        }
        debug_assert!(b.iter().all(|pb| pb.k == self.k && pb.n == self.n));
        let a_stride = self.packed_a_stride();
        let row_panels = self.m.div_ceil(MR);
        let col_panels = self.n.div_ceil(NR);
        let bgd = *self;

        let run_row_panel = |ip: usize| {
            let row0 = ip * MR;
            let rows = (bgd.m - row0).min(MR);
            with_scratch(&HOT_C_SCRATCH, bgd.batch * MR * NR, |hot| {
                for jp in 0..col_panels {
                    let col0 = jp * NR;
                    let cols = (bgd.n - col0).min(NR);
                    for t in 0..bgd.batch {
                        let ct = &mut hot[t * MR * NR..(t + 1) * MR * NR];
                        // Panel `ip` of tile t's packed A: columns advance
                        // MR apart, so KC slice [pc, pc+kc) is contiguous.
                        let a_base = t * a_stride + ip * MR * bgd.k;
                        b[t].for_each_kc_panel(jp, |pc, kc, bpanel| {
                            let apanel = &a_packed[a_base + pc * MR..a_base + (pc + kc) * MR];
                            kernel_mr_nr(kc, apanel, bpanel, ct, NR, pc > 0);
                        });
                    }
                    epi.micro_tile(hot, NR, row0, col0, rows, cols);
                }
            });
        };
        match pool {
            Some(pool) if row_panels > 1 => pool.parallel_for(row_panels, run_row_panel),
            _ => (0..row_panels).for_each(run_row_panel),
        }
    }

    fn validate(&self, a: &[f32], b: &[f32], c: &[f32]) {
        assert!(a.len() >= self.batch * self.a_stride(), "batched A too small");
        assert!(b.len() >= self.batch * self.b_stride(), "batched B too small");
        assert!(c.len() >= self.batch * self.c_stride(), "batched C too small");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::sgemm_ref;
    use crate::util::{rel_error, XorShiftRng};

    fn reference(bgd: &BatchedGemm, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0; bgd.batch * bgd.c_stride()];
        for t in 0..bgd.batch {
            let mut ct = vec![0.0; bgd.c_stride()];
            sgemm_ref(
                bgd.m,
                bgd.n,
                bgd.k,
                &a[t * bgd.a_stride()..(t + 1) * bgd.a_stride()],
                &b[t * bgd.b_stride()..(t + 1) * bgd.b_stride()],
                &mut ct,
            );
            c[t * bgd.c_stride()..(t + 1) * bgd.c_stride()].copy_from_slice(&ct);
        }
        c
    }

    fn random(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = XorShiftRng::new(seed);
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v);
        v
    }

    #[test]
    fn serial_matches_reference() {
        let bgd = BatchedGemm { batch: 16, m: 9, k: 7, n: 11 };
        let a = random(bgd.batch * bgd.a_stride(), 1);
        let b = random(bgd.batch * bgd.b_stride(), 2);
        let mut c = vec![0.0; bgd.batch * bgd.c_stride()];
        bgd.run(&a, &b, &mut c);
        assert!(rel_error(&c, &reference(&bgd, &a, &b)) < 1e-4);
    }

    #[test]
    fn parallel_matches_serial() {
        let pool = ThreadPool::new(4);
        let bgd = BatchedGemm { batch: 36, m: 25, k: 16, n: 32 };
        let a = random(bgd.batch * bgd.a_stride(), 3);
        let b = random(bgd.batch * bgd.b_stride(), 4);
        let mut c1 = vec![0.0; bgd.batch * bgd.c_stride()];
        let mut c2 = vec![0.0; bgd.batch * bgd.c_stride()];
        bgd.run(&a, &b, &mut c1);
        bgd.run_with_pool(&pool, &a, &b, &mut c2);
        assert!(rel_error(&c2, &c1) < 1e-6);
    }

    #[test]
    fn prepacked_matches_plain() {
        let bgd = BatchedGemm { batch: 8, m: 5, k: 37, n: 19 };
        let a = random(bgd.batch * bgd.a_stride(), 7);
        let b = random(bgd.batch * bgd.b_stride(), 8);
        let packed = bgd.prepack_b(&b);
        let mut c1 = vec![0.0; bgd.batch * bgd.c_stride()];
        let mut c2 = vec![0.0; bgd.batch * bgd.c_stride()];
        bgd.run(&a, &b, &mut c1);
        bgd.run_prepacked(None, &a, &packed, &mut c2);
        assert!(rel_error(&c2, &c1) < 1e-6);
        let pool = ThreadPool::new(3);
        let mut c3 = vec![0.0; bgd.batch * bgd.c_stride()];
        bgd.run_prepacked(Some(&pool), &a, &packed, &mut c3);
        assert!(rel_error(&c3, &c1) < 1e-6);
    }

    #[test]
    fn flops_formula() {
        let bgd = BatchedGemm { batch: 16, m: 10, k: 3, n: 4 };
        assert_eq!(bgd.flops(), 2 * 16 * 10 * 3 * 4);
        assert_eq!(bgd.workspace_elems(), 16 * (10 * 3 + 10 * 4));
        assert_eq!(bgd.packed_a_stride(), 10usize.div_ceil(MR) * MR * 3);
        assert_eq!(bgd.packed_a_elems_total(), 16 * bgd.packed_a_stride());
    }

    /// Test epilogue: scatter each hot cube into per-tile C matrices so the
    /// fused driver's output can be compared against the staged reference.
    struct CubeScatter {
        c_addr: usize,
        m: usize,
        n: usize,
        batch: usize,
    }

    impl Epilogue for CubeScatter {
        fn micro_tile(
            &self,
            c: &mut [f32],
            ldc: usize,
            row0: usize,
            col0: usize,
            rows: usize,
            cols: usize,
        ) {
            for t in 0..self.batch {
                for r in 0..rows {
                    for j in 0..cols {
                        let v = c[t * MR * ldc + r * ldc + j];
                        let off = t * self.m * self.n + (row0 + r) * self.n + col0 + j;
                        // SAFETY: (row panel, col panel) regions are disjoint
                        // across epilogue invocations.
                        unsafe { *(self.c_addr as *mut f32).add(off) = v };
                    }
                }
            }
        }
    }

    /// The fused packed-A driver must match the staged reference on ragged
    /// shapes (m % MR ≠ 0, n % NR ≠ 0) and across KC block boundaries,
    /// serial and pooled.
    #[test]
    fn packed_fused_matches_reference() {
        use crate::gemm::pack::PackedAWriter;
        let pool = ThreadPool::new(3);
        for bgd in [
            BatchedGemm { batch: 4, m: 13, k: 37, n: 19 },
            BatchedGemm { batch: 3, m: 7, k: 300, n: 33 },
            BatchedGemm { batch: 16, m: MR, k: 5, n: NR },
            BatchedGemm { batch: 1, m: 1, k: 1, n: 1 },
        ] {
            let a = random(bgd.batch * bgd.a_stride(), bgd.m as u64);
            let b = random(bgd.batch * bgd.b_stride(), bgd.n as u64);
            let packed_b = bgd.prepack_b(&b);
            // Pack A per tile via the writer (the transform-as-pack layout).
            let mut a_packed = vec![f32::NAN; bgd.packed_a_elems_total()];
            for t in 0..bgd.batch {
                let mut w = PackedAWriter::new(
                    &mut a_packed[t * bgd.packed_a_stride()..(t + 1) * bgd.packed_a_stride()],
                    bgd.m,
                    bgd.k,
                );
                w.zero_pad_rows();
                for r in 0..bgd.m {
                    for p in 0..bgd.k {
                        w.write(r, p, a[t * bgd.a_stride() + r * bgd.k + p]);
                    }
                }
            }
            let want = reference(&bgd, &a, &b);
            for use_pool in [false, true] {
                let mut got = vec![0.0; bgd.batch * bgd.c_stride()];
                let epi = CubeScatter {
                    c_addr: got.as_mut_ptr() as usize,
                    m: bgd.m,
                    n: bgd.n,
                    batch: bgd.batch,
                };
                let p = if use_pool { Some(&pool) } else { None };
                bgd.run_packed_fused(p, &a_packed, &packed_b, &epi);
                assert!(
                    rel_error(&got, &want) < 1e-4,
                    "batch={} m={} k={} n={} pool={use_pool}: err={}",
                    bgd.batch,
                    bgd.m,
                    bgd.k,
                    bgd.n,
                    rel_error(&got, &want)
                );
            }
        }
    }

    #[test]
    fn single_tile_batch() {
        let bgd = BatchedGemm { batch: 1, m: 8, k: 8, n: 8 };
        let a = random(64, 5);
        let b = random(64, 6);
        let mut c = vec![0.0; 64];
        bgd.run(&a, &b, &mut c);
        assert!(rel_error(&c, &reference(&bgd, &a, &b)) < 1e-4);
    }
}
