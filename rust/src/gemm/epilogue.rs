//! GEMM epilogues: post-processing applied to each finished micro-tile of C
//! **while it is still cache-hot**, instead of a separate whole-tensor pass
//! after the GEMM returns.
//!
//! The drivers in [`super`] fire [`Epilogue::micro_tile`] exactly once per
//! output element — on the KC iteration that completes the tile's inner-
//! product, right after the micro-kernel's write-back, before the tile can
//! be evicted. Whether that write-back *stores* or *accumulates* stays a
//! micro-kernel concern (the `accumulate` flag); the epilogue owns what
//! happens next:
//!
//! * [`Store`] — nothing: the plain GEMM.
//! * [`BiasRelu`] — per-column bias add + optional ReLU. Both convolution
//!   schemes put output channels in C's columns, so this one epilogue fuses
//!   the conv bias/activation for im2row (C rows = output pixels) *and* any
//!   plain prepacked GEMM.
//! * the Winograd inverse-transform gather — implemented in
//!   `winograd::convolve` against the batched driver
//!   ([`super::BatchedGemm::run_packed_fused`]), which hands the epilogue a
//!   whole `[tiles]×MR×NR` hot cube per region panel (the inverse transform
//!   needs all `x²` tile values of a region at once).
//!
//! This is the output-side half of the paper's §2.2 argument: BLASFEO-class
//! kernels win on mobile CPUs because data crosses the cache hierarchy
//! once — outputs are written exactly once, already biased/activated/
//! inverse-transformed.

/// Post-processing for finished micro-tiles of C.
///
/// `Sync` because drivers invoke it from pool workers in parallel over
/// disjoint tiles.
pub trait Epilogue: Sync {
    /// Post-process the valid `rows×cols` region of a finished micro-tile.
    ///
    /// * `c` — slice starting at the tile's top-left element, row-major
    ///   with leading dimension `ldc` (so element `(r, j)` is
    ///   `c[r * ldc + j]`).
    /// * `row0`, `col0` — the tile's origin in the full C matrix (what a
    ///   per-column bias indexes with).
    /// * `rows`, `cols` — valid extent (≤ `MR`/`NR`; edge tiles are
    ///   smaller).
    fn micro_tile(
        &self,
        c: &mut [f32],
        ldc: usize,
        row0: usize,
        col0: usize,
        rows: usize,
        cols: usize,
    );
}

/// The no-op epilogue: leave C exactly as the GEMM wrote it.
#[derive(Debug, Clone, Copy, Default)]
pub struct Store;

impl Epilogue for Store {
    #[inline(always)]
    fn micro_tile(&self, _c: &mut [f32], _ldc: usize, _r0: usize, _c0: usize, _rows: usize, _cols: usize) {
    }
}

/// Per-column bias add and optional ReLU — the conv epilogue (C columns are
/// output channels in both convolution schemes).
#[derive(Debug, Clone, Copy)]
pub struct BiasRelu<'a> {
    /// Bias indexed by absolute C column; `None` ⇒ no add.
    pub bias: Option<&'a [f32]>,
    /// Clamp at zero after the bias.
    pub relu: bool,
}

impl Epilogue for BiasRelu<'_> {
    #[inline]
    fn micro_tile(
        &self,
        c: &mut [f32],
        ldc: usize,
        _row0: usize,
        col0: usize,
        rows: usize,
        cols: usize,
    ) {
        for r in 0..rows {
            let row = &mut c[r * ldc..r * ldc + cols];
            if let Some(bias) = self.bias {
                let b = &bias[col0..col0 + cols];
                for (v, &bv) in row.iter_mut().zip(b) {
                    let t = *v + bv;
                    *v = if self.relu { t.max(0.0) } else { t };
                }
            } else if self.relu {
                for v in row.iter_mut() {
                    *v = v.max(0.0);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_is_identity() {
        let mut c = vec![1.0, -2.0, 3.0, -4.0];
        Store.micro_tile(&mut c, 2, 5, 7, 2, 2);
        assert_eq!(c, vec![1.0, -2.0, 3.0, -4.0]);
    }

    #[test]
    fn bias_relu_respects_origin_and_extent() {
        // 2×2 valid region of a tile at col0 = 1, inside a 3-wide buffer.
        let mut c = vec![1.0, -2.0, 99.0, -3.0, 4.0, 99.0];
        let bias = [100.0, 10.0, 20.0];
        let epi = BiasRelu { bias: Some(&bias), relu: true };
        epi.micro_tile(&mut c, 3, 0, 1, 2, 2);
        // col0=1 ⇒ bias[1], bias[2] apply; ReLU clamps; ldc padding untouched.
        assert_eq!(c, vec![11.0, 18.0, 99.0, 7.0, 24.0, 99.0]);
    }

    #[test]
    fn relu_without_bias() {
        let mut c = vec![-1.0, 2.0];
        BiasRelu { bias: None, relu: true }.micro_tile(&mut c, 2, 0, 0, 1, 2);
        assert_eq!(c, vec![0.0, 2.0]);
    }

    #[test]
    fn no_bias_no_relu_is_identity() {
        let mut c = vec![-1.0, 2.0];
        BiasRelu { bias: None, relu: false }.micro_tile(&mut c, 2, 0, 0, 1, 2);
        assert_eq!(c, vec![-1.0, 2.0]);
    }
}
