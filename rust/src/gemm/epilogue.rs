//! GEMM epilogues: post-processing applied to each finished micro-tile of C
//! **while it is still cache-hot**, instead of a separate whole-tensor pass
//! after the GEMM returns.
//!
//! The drivers in [`super`] fire [`Epilogue::micro_tile`] exactly once per
//! output element — on the KC iteration that completes the tile's inner-
//! product, right after the micro-kernel's write-back, before the tile can
//! be evicted. Whether that write-back *stores* or *accumulates* stays a
//! micro-kernel concern (the `accumulate` flag); the epilogue owns what
//! happens next:
//!
//! * [`Store`] — nothing: the plain GEMM.
//! * [`BiasAct`] — per-column bias add + optional fused [`Activation`]
//!   (ReLU or MobileNet's ReLU6). Both convolution schemes put output
//!   channels in C's columns, so this one epilogue fuses the conv
//!   bias/activation for im2row (C rows = output pixels) *and* any plain
//!   prepacked GEMM.
//! * the Winograd inverse-transform gather — implemented in
//!   `winograd::convolve` against the batched driver
//!   ([`super::BatchedGemm::run_packed_fused`]), which hands the epilogue a
//!   whole `[tiles]×MR×NR` hot cube per region panel (the inverse transform
//!   needs all `x²` tile values of a region at once).
//!
//! This is the output-side half of the paper's §2.2 argument: BLASFEO-class
//! kernels win on mobile CPUs because data crosses the cache hierarchy
//! once — outputs are written exactly once, already biased/activated/
//! inverse-transformed.

use crate::simd::F32x4;

/// Fused pointwise activation applied by the conv epilogues (and the
/// direct-path post passes) after the optional bias add.
///
/// Lives here — the lowest layer that applies it on the hot path — and is
/// re-exported as `conv::Activation` for descriptor-level use. `Relu6` is
/// the clamp MobileNet-family networks train with (`min(max(x, 0), 6)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Activation {
    /// Identity.
    #[default]
    None,
    /// `max(x, 0)`.
    Relu,
    /// `min(max(x, 0), 6)` — MobileNet's clipped ReLU.
    Relu6,
}

impl Activation {
    /// Backwards-compatible constructor from the old `relu: bool` flags.
    pub fn from_relu(relu: bool) -> Activation {
        if relu {
            Activation::Relu
        } else {
            Activation::None
        }
    }

    /// Apply to one scalar.
    #[inline(always)]
    pub fn apply(self, v: f32) -> f32 {
        match self {
            Activation::None => v,
            Activation::Relu => v.max(0.0),
            Activation::Relu6 => v.clamp(0.0, 6.0),
        }
    }

    /// Apply to one 4-lane vector — the in-register form the Winograd
    /// gather and depthwise epilogues clamp with (same semantics as
    /// [`apply`](Self::apply), lane for lane, on finite values).
    #[inline(always)]
    pub fn apply_vec(self, v: F32x4) -> F32x4 {
        match self {
            Activation::None => v,
            Activation::Relu => v.max(F32x4::zero()),
            Activation::Relu6 => v.max(F32x4::zero()).min(F32x4::splat(6.0)),
        }
    }

    /// Is this the identity?
    #[inline(always)]
    pub fn is_none(self) -> bool {
        matches!(self, Activation::None)
    }
}

impl std::fmt::Display for Activation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Activation::None => write!(f, "none"),
            Activation::Relu => write!(f, "relu"),
            Activation::Relu6 => write!(f, "relu6"),
        }
    }
}

/// Post-processing for finished micro-tiles of C.
///
/// `Sync` because drivers invoke it from pool workers in parallel over
/// disjoint tiles.
pub trait Epilogue: Sync {
    /// Post-process the valid `rows×cols` region of a finished micro-tile.
    ///
    /// * `c` — slice starting at the tile's top-left element, row-major
    ///   with leading dimension `ldc` (so element `(r, j)` is
    ///   `c[r * ldc + j]`).
    /// * `row0`, `col0` — the tile's origin in the full C matrix (what a
    ///   per-column bias indexes with).
    /// * `rows`, `cols` — valid extent (≤ `MR`/`NR`; edge tiles are
    ///   smaller).
    fn micro_tile(
        &self,
        c: &mut [f32],
        ldc: usize,
        row0: usize,
        col0: usize,
        rows: usize,
        cols: usize,
    );
}

/// The no-op epilogue: leave C exactly as the GEMM wrote it.
#[derive(Debug, Clone, Copy, Default)]
pub struct Store;

impl Epilogue for Store {
    #[inline(always)]
    fn micro_tile(&self, _c: &mut [f32], _ldc: usize, _r0: usize, _c0: usize, _rows: usize, _cols: usize) {
    }
}

/// Per-column bias add and optional fused activation (ReLU / ReLU6) — the
/// conv epilogue (C columns are output channels in both convolution
/// schemes).
#[derive(Debug, Clone, Copy)]
pub struct BiasAct<'a> {
    /// Bias indexed by absolute C column; `None` ⇒ no add.
    pub bias: Option<&'a [f32]>,
    /// Activation applied after the bias.
    pub act: Activation,
}

impl Epilogue for BiasAct<'_> {
    #[inline]
    fn micro_tile(
        &self,
        c: &mut [f32],
        ldc: usize,
        _row0: usize,
        col0: usize,
        rows: usize,
        cols: usize,
    ) {
        for r in 0..rows {
            let row = &mut c[r * ldc..r * ldc + cols];
            if let Some(bias) = self.bias {
                let b = &bias[col0..col0 + cols];
                for (v, &bv) in row.iter_mut().zip(b) {
                    *v = self.act.apply(*v + bv);
                }
            } else if !self.act.is_none() {
                for v in row.iter_mut() {
                    *v = self.act.apply(*v);
                }
            }
        }
    }
}

/// Fused residual accumulate: `C = act(A·B + bias + R)`, with the residual
/// operand `R` read per element **while the micro-tile is cache-hot** — the
/// `Conv(1×1) → Add → Act` chain of a residual block collapses into one
/// GEMM instead of a conv followed by a whole-tensor add pass.
///
/// `R` is a full `M×N` matrix in the same row/column coordinates as C
/// (output pixels × output channels for the pointwise engine), addressed
/// with the absolute tile origin: element `(row0 + r, col0 + j)` is
/// `res[(row0 + r) * ldr + col0 + j]`.
///
/// The scalar chain is `act((acc + bias) + r)` — the exact association
/// order of the unfused `BiasAct` conv → `add_into` → activation walk, so
/// fused and unfused residual blocks stay **bit-identical**.
#[derive(Debug, Clone, Copy)]
pub struct BiasActAdd<'a> {
    /// Bias indexed by absolute C column; `None` ⇒ no add.
    pub bias: Option<&'a [f32]>,
    /// Activation applied after bias and residual.
    pub act: Activation,
    /// Residual matrix, same logical shape as C.
    pub res: &'a [f32],
    /// Leading dimension (row stride) of `res`.
    pub ldr: usize,
}

impl Epilogue for BiasActAdd<'_> {
    #[inline]
    fn micro_tile(
        &self,
        c: &mut [f32],
        ldc: usize,
        row0: usize,
        col0: usize,
        rows: usize,
        cols: usize,
    ) {
        for r in 0..rows {
            let row = &mut c[r * ldc..r * ldc + cols];
            let res = &self.res[(row0 + r) * self.ldr + col0..(row0 + r) * self.ldr + col0 + cols];
            if let Some(bias) = self.bias {
                let b = &bias[col0..col0 + cols];
                for ((v, &bv), &rv) in row.iter_mut().zip(b).zip(res) {
                    *v = self.act.apply((*v + bv) + rv);
                }
            } else {
                for (v, &rv) in row.iter_mut().zip(res) {
                    *v = self.act.apply(*v + rv);
                }
            }
        }
    }
}

/// Post-processing for finished **i32** micro-tiles — the int8 GEMM's
/// epilogue family.
///
/// The int8 driver ([`crate::quant::gemm`]) accumulates each `MR×NR` tile
/// in registers/stack (`[[i32; 16]; 4]`) over the **full** k extent and
/// never materialises an i32 C matrix; the epilogue consumes the finished
/// tile and writes the final output (f32 dequantized, or requantized i8)
/// exactly once, while the accumulators are still hot. `Sync` because the
/// driver fires it from pool workers over disjoint row blocks.
pub trait EpilogueI32: Sync {
    /// Consume the valid `rows×cols` region of a finished accumulator tile
    /// whose origin in the full C matrix is `(row0, col0)`.
    fn micro_tile_i32(
        &self,
        acc: &[[i32; 16]; 4],
        row0: usize,
        col0: usize,
        rows: usize,
        cols: usize,
    );
}

/// Dequantize-to-f32 epilogue: the dynamic-range int8 conv's output stage.
///
/// The raw accumulator holds `Σ qa·qw` with `qa = zp + round(x / s_in)`
/// (u8 affine activations) and `qw = round(w / s_w[c])` (per-channel
/// symmetric i8 weights). Subtracting the prepare-time folded correction
/// `a_zp · wsum[c]` (`wsum[c] = Σ_k qw`) leaves `Σ (qa−zp)·qw`, which a
/// single multiply by `s_in · s_w[c]` maps back to f32 — then the usual
/// bias add and activation clamp, fused like the f32 [`BiasAct`].
#[derive(Debug, Clone, Copy)]
pub struct QDequantBiasAct<'a> {
    /// Output matrix base address (`*mut f32` erased to `usize` so the
    /// epilogue is `Sync`); row-major with leading dimension `ldc`.
    pub out_addr: usize,
    /// Leading dimension (row stride, elements) of the output matrix.
    pub ldc: usize,
    /// Input (activation) scale `s_in`.
    pub a_scale: f32,
    /// Input zero point (u8 affine).
    pub a_zp: i32,
    /// Per-output-channel weight scales `s_w[c]`, indexed by C column.
    pub w_scales: &'a [f32],
    /// Per-output-channel weight sums `Σ_k qw`, indexed by C column.
    pub wsum: &'a [i32],
    /// Bias indexed by absolute C column; `None` ⇒ no add.
    pub bias: Option<&'a [f32]>,
    /// Activation applied after the bias.
    pub act: Activation,
}

impl EpilogueI32 for QDequantBiasAct<'_> {
    #[inline]
    fn micro_tile_i32(
        &self,
        acc: &[[i32; 16]; 4],
        row0: usize,
        col0: usize,
        rows: usize,
        cols: usize,
    ) {
        let out = self.out_addr as *mut f32;
        for (r, acc_row) in acc.iter().enumerate().take(rows) {
            // SAFETY: the driver assigns each worker disjoint 4-row blocks
            // of C and each (row0, col0, rows, cols) tile lies inside the
            // caller-sized m×ldc output buffer, so this mutable row slice
            // aliases nothing live.
            let dst = unsafe {
                std::slice::from_raw_parts_mut(out.add((row0 + r) * self.ldc + col0), cols)
            };
            for (j, d) in dst.iter_mut().enumerate() {
                let c = col0 + j;
                let centered = acc_row[j] - self.a_zp * self.wsum[c];
                let mut v = centered as f32 * (self.a_scale * self.w_scales[c]);
                if let Some(b) = self.bias {
                    v += b[c];
                }
                *d = self.act.apply(v);
            }
        }
    }
}

/// Requantize-to-i8 epilogue: bias add in i32, per-channel scale to the
/// output quantization grid, round-to-nearest-even, zero-point shift and
/// saturation to `[qmin, qmax]` — with the activation clamp **folded into
/// the saturation bounds** (ReLU ⇒ `qmin = zero_point`, ReLU6 ⇒ `qmax =
/// zero_point + round(6/s_out)`), so activation costs nothing here.
///
/// `q = clamp(rhe((acc + bias[c]) · scale[c]) + zero_point, qmin, qmax)`.
///
/// Rounding uses [`crate::util::fast_round_half_even`]; outside its 2²²
/// validity range the clamp saturates to the same bound the exact
/// reference would, which the `quant` property tests pin.
#[derive(Debug, Clone, Copy)]
pub struct Requantize<'a> {
    /// Output matrix base address (`*mut i8` erased to `usize`); row-major
    /// with leading dimension `ldc`.
    pub out_addr: usize,
    /// Leading dimension (row stride, elements) of the output matrix.
    pub ldc: usize,
    /// Bias in i32 (already on the accumulator grid), indexed by absolute
    /// C column; `None` ⇒ no add.
    pub bias: Option<&'a [i32]>,
    /// Per-output-channel requantize scale (acc grid → output grid).
    pub scale: &'a [f32],
    /// Output zero point.
    pub zero_point: i32,
    /// Lower saturation bound (activation clamp folded in).
    pub qmin: i32,
    /// Upper saturation bound (activation clamp folded in).
    pub qmax: i32,
}

impl EpilogueI32 for Requantize<'_> {
    #[inline]
    fn micro_tile_i32(
        &self,
        acc: &[[i32; 16]; 4],
        row0: usize,
        col0: usize,
        rows: usize,
        cols: usize,
    ) {
        let out = self.out_addr as *mut i8;
        for (r, acc_row) in acc.iter().enumerate().take(rows) {
            // SAFETY: same disjointness argument as `QDequantBiasAct` — one
            // worker per 4-row block, tile inside the m×ldc i8 output.
            let dst = unsafe {
                std::slice::from_raw_parts_mut(out.add((row0 + r) * self.ldc + col0), cols)
            };
            for (j, d) in dst.iter_mut().enumerate() {
                let c = col0 + j;
                let mut a = acc_row[j];
                if let Some(b) = self.bias {
                    a = a.wrapping_add(b[c]);
                }
                let q = crate::util::fast_round_half_even(a as f32 * self.scale[c]) as i32;
                *d = q.saturating_add(self.zero_point).clamp(self.qmin, self.qmax) as i8;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_is_identity() {
        let mut c = vec![1.0, -2.0, 3.0, -4.0];
        Store.micro_tile(&mut c, 2, 5, 7, 2, 2);
        assert_eq!(c, vec![1.0, -2.0, 3.0, -4.0]);
    }

    #[test]
    fn bias_relu_respects_origin_and_extent() {
        // 2×2 valid region of a tile at col0 = 1, inside a 3-wide buffer.
        let mut c = vec![1.0, -2.0, 99.0, -3.0, 4.0, 99.0];
        let bias = [100.0, 10.0, 20.0];
        let epi = BiasAct { bias: Some(&bias), act: Activation::Relu };
        epi.micro_tile(&mut c, 3, 0, 1, 2, 2);
        // col0=1 ⇒ bias[1], bias[2] apply; ReLU clamps; ldc padding untouched.
        assert_eq!(c, vec![11.0, 18.0, 99.0, 7.0, 24.0, 99.0]);
    }

    #[test]
    fn relu_without_bias() {
        let mut c = vec![-1.0, 2.0];
        BiasAct { bias: None, act: Activation::Relu }.micro_tile(&mut c, 2, 0, 0, 1, 2);
        assert_eq!(c, vec![0.0, 2.0]);
    }

    #[test]
    fn no_bias_no_act_is_identity() {
        let mut c = vec![-1.0, 2.0];
        BiasAct { bias: None, act: Activation::None }.micro_tile(&mut c, 2, 0, 0, 1, 2);
        assert_eq!(c, vec![-1.0, 2.0]);
    }

    #[test]
    fn residual_epilogue_adds_r_with_absolute_origin() {
        // 2×2 valid region of a tile at (row0=1, col0=1) inside a 3-wide C
        // buffer; R is the full 3×3 matrix (ldr = 3).
        let mut c = vec![1.0, -2.0, 99.0, -3.0, 4.0, 99.0];
        let res: Vec<f32> = (0..9).map(|i| i as f32 * 10.0).collect();
        let bias = [100.0, 10.0, 20.0];
        let epi = BiasActAdd { bias: Some(&bias), act: Activation::None, res: &res, ldr: 3 };
        epi.micro_tile(&mut c, 3, 1, 1, 2, 2);
        // (1,1): 1 + 10 + 40; (1,2): -2 + 20 + 50; (2,1): -3 + 10 + 70;
        // (2,2): 4 + 20 + 80. ldc padding untouched.
        assert_eq!(c, vec![51.0, 68.0, 99.0, 77.0, 104.0, 99.0]);
    }

    #[test]
    fn residual_epilogue_matches_unfused_chain_bitwise() {
        // act((acc + bias) + r) must associate exactly like the unfused
        // BiasAct → add → act walk, including under ReLU6 and no-bias.
        let accs = [0.1f32, -7.3, 5.9, 2.0e-8];
        let biases = [0.7f32, -0.2, 3.3, 1.0e-8];
        let resids = [1.3f32, 6.8, -2.1, 3.0e-8];
        for act in [Activation::None, Activation::Relu, Activation::Relu6] {
            let mut fused = accs;
            let epi = BiasActAdd { bias: Some(&biases), act, res: &resids, ldr: 4 };
            epi.micro_tile(&mut fused, 4, 0, 0, 1, 4);
            let mut nobias = accs;
            BiasActAdd { bias: None, act, res: &resids, ldr: 4 }.micro_tile(&mut nobias, 4, 0, 0, 1, 4);
            for j in 0..4 {
                let mut v = accs[j];
                BiasAct { bias: Some(&biases), act: Activation::None }
                    .micro_tile(std::slice::from_mut(&mut v), 1, 0, j, 1, 1);
                let unfused = act.apply(v + resids[j]);
                assert_eq!(fused[j].to_bits(), unfused.to_bits(), "act {act} col {j}");
                assert_eq!(nobias[j].to_bits(), act.apply(accs[j] + resids[j]).to_bits());
            }
        }
    }

    /// Scalar model of `Requantize` used by the tile tests below (the
    /// exhaustive property suite lives in `crate::quant`).
    fn requant_ref(acc: i32, bias: i32, scale: f32, zp: i32, qmin: i32, qmax: i32) -> i8 {
        let v = crate::util::round_half_even(acc.wrapping_add(bias) as f32 * scale);
        ((v as i32).saturating_add(zp)).clamp(qmin, qmax) as i8
    }

    #[test]
    fn qdequant_epilogue_dequantizes_with_zero_point_correction() {
        // 2 rows × 3 cols of a 2×4 f32 output (ldc = 4); col0 = 1.
        let mut out = [99.0f32; 8];
        let mut acc = [[0i32; 16]; 4];
        acc[0][..3].copy_from_slice(&[100, -50, 8]);
        acc[1][..3].copy_from_slice(&[0, 7, -3]);
        let w_scales = [0.0, 0.5, 0.25, 2.0];
        let wsum = [0, 10, -4, 6];
        let bias = [0.0, 1.0, -1.0, 0.5];
        let epi = QDequantBiasAct {
            out_addr: out.as_mut_ptr() as usize,
            ldc: 4,
            a_scale: 0.1,
            a_zp: 3,
            w_scales: &w_scales,
            wsum: &wsum,
            bias: Some(&bias),
            act: Activation::None,
        };
        epi.micro_tile_i32(&acc, 0, 1, 2, 3);
        for r in 0..2 {
            for j in 0..3 {
                let c = 1 + j;
                let want = (acc[r][j] - 3 * wsum[c]) as f32 * (0.1 * w_scales[c]) + bias[c];
                assert_eq!(out[r * 4 + c], want, "({r},{c})");
            }
        }
        // ldc padding and untouched columns stay poisoned.
        assert_eq!(out[0], 99.0);
        assert_eq!(out[4], 99.0);
    }

    #[test]
    fn requantize_tile_matches_scalar_reference() {
        let mut out = [i8::MIN; 8];
        let mut acc = [[0i32; 16]; 4];
        acc[0][..4].copy_from_slice(&[1000, -1000, 3, -3]);
        acc[1][..4].copy_from_slice(&[i32::MAX - 5, i32::MIN + 5, 250, -251]);
        let bias = [7, -7, 0, 100_000];
        let scale = [0.05f32, 0.05, 0.5, 0.001];
        let (zp, qmin, qmax) = (-1, -128, 127);
        let epi = Requantize {
            out_addr: out.as_mut_ptr() as usize,
            ldc: 4,
            bias: Some(&bias),
            scale: &scale,
            zero_point: zp,
            qmin,
            qmax,
        };
        epi.micro_tile_i32(&acc, 0, 0, 2, 4);
        for r in 0..2 {
            for c in 0..4 {
                let want = requant_ref(acc[r][c], bias[c], scale[c], zp, qmin, qmax);
                assert_eq!(out[r * 4 + c], want, "({r},{c})");
            }
        }
        // Both saturation bounds actually fired.
        assert!(out[..8].contains(&(qmax as i8)));
        assert!(out[..8].contains(&(qmin as i8)));
    }

    #[test]
    fn requantize_folded_activation_bounds() {
        // ReLU folded as qmin = zp: negative accumulators land exactly on
        // the zero point (which dequantizes to 0.0).
        let mut out = [0i8; 4];
        let mut acc = [[0i32; 16]; 4];
        acc[0][..4].copy_from_slice(&[-500, -1, 0, 500]);
        let scale = [0.1f32; 4];
        let epi = Requantize {
            out_addr: out.as_mut_ptr() as usize,
            ldc: 4,
            bias: None,
            scale: &scale,
            zero_point: 10,
            qmin: 10,
            qmax: 127,
        };
        epi.micro_tile_i32(&acc, 0, 0, 1, 4);
        assert_eq!(out, [10, 10, 10, 60]);
    }

    #[test]
    fn relu6_clamps_both_sides() {
        let mut c = vec![-1.0, 2.0, 9.0];
        let bias = [0.5, 0.5, 0.5];
        BiasAct { bias: Some(&bias), act: Activation::Relu6 }.micro_tile(&mut c, 3, 0, 0, 1, 3);
        assert_eq!(c, vec![0.0, 2.5, 6.0]);
        assert_eq!(Activation::Relu6.apply(-3.0), 0.0);
        assert_eq!(Activation::Relu6.apply(3.0), 3.0);
        assert_eq!(Activation::Relu6.apply(7.0), 6.0);
        assert_eq!(Activation::from_relu(true), Activation::Relu);
        assert_eq!(Activation::from_relu(false), Activation::None);
    }
}
