//! The register-tile micro-kernel at the bottom of the GEMM.
//!
//! An `MR×NR = 6×16` tile of C is held in accumulator registers while the
//! packed A panel (column-major within the panel) and packed B panel
//! (row-major within the panel) stream through. Six rows × four [`F32x4`]
//! accumulators per row; LLVM fuses the adjacent 4-lane quads into wider
//! AVX registers on x86, and the identical code maps to NEON `vfmaq_f32` on
//! aarch64 — the instruction the paper's GEMM (BLASFEO-class) is built on.

use crate::simd::F32x4;

/// Rows of C computed per micro-kernel invocation.
///
/// Register budget: the accumulator tile holds `MR × NR/4` `F32x4`s, which
/// LLVM keeps in individual xmm registers (it does not fuse adjacent
/// 4-lane arrays into zmm). AVX-512 exposes 32 xmm: 6×4 acc + 4 B + 1 A
/// broadcast = 29 fits; the earlier 8×16 attempt needed 37 and spilled to
/// a 20× slowdown (EXPERIMENTS.md §Perf step 3).
pub const MR: usize = 6;
/// Columns of C computed per micro-kernel invocation.
pub const NR: usize = 16;

/// Compute `C[MR×NR] (+)= Apanel · Bpanel` over `kc` rank-1 updates
/// (`MR = 6`, `NR = 16`).
///
/// * `a` — packed A panel: `kc` groups of `MR` values (column of the tile).
/// * `b` — packed B panel: `kc` groups of `NR` values (row of the tile).
/// * `c` — row-major C with leading dimension `ldc`; the full `MR×NR` tile
///   must be in-bounds (edge tiles go through a scratch buffer in the driver).
/// * `accumulate` — false ⇒ overwrite C, true ⇒ add into C.
#[inline]
pub fn kernel_mr_nr(kc: usize, a: &[f32], b: &[f32], c: &mut [f32], ldc: usize, accumulate: bool) {
    debug_assert!(a.len() >= kc * MR);
    debug_assert!(b.len() >= kc * NR);
    debug_assert!(ldc >= NR);
    debug_assert!(c.len() >= (MR - 1) * ldc + NR);

    let mut acc = [[F32x4::zero(); NR / 4]; MR];

    // Stream kc rank-1 updates through the accumulators.
    for p in 0..kc {
        let bp = &b[p * NR..p * NR + NR];
        let b0 = F32x4::load(&bp[0..4]);
        let b1 = F32x4::load(&bp[4..8]);
        let b2 = F32x4::load(&bp[8..12]);
        let b3 = F32x4::load(&bp[12..16]);
        let ap = &a[p * MR..p * MR + MR];
        for (r, accr) in acc.iter_mut().enumerate() {
            let ar = F32x4::splat(ap[r]);
            accr[0] = accr[0].fma(ar, b0);
            accr[1] = accr[1].fma(ar, b1);
            accr[2] = accr[2].fma(ar, b2);
            accr[3] = accr[3].fma(ar, b3);
        }
    }

    // Write back.
    for (r, accr) in acc.iter().enumerate() {
        let row = &mut c[r * ldc..r * ldc + NR];
        if accumulate {
            for (j, av) in accr.iter().enumerate() {
                let cv = F32x4::load(&row[j * 4..j * 4 + 4]) + *av;
                cv.store(&mut row[j * 4..j * 4 + 4]);
            }
        } else {
            for (j, av) in accr.iter().enumerate() {
                av.store(&mut row[j * 4..j * 4 + 4]);
            }
        }
    }
}

/// Reference (scalar) version of the micro-kernel used in tests.
#[cfg(test)]
pub fn kernel_ref(kc: usize, a: &[f32], b: &[f32], c: &mut [f32], ldc: usize, accumulate: bool) {
    for r in 0..MR {
        for j in 0..NR {
            let mut s = 0.0f32;
            for p in 0..kc {
                s += a[p * MR + r] * b[p * NR + j];
            }
            if accumulate {
                c[r * ldc + j] += s;
            } else {
                c[r * ldc + j] = s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShiftRng;

    fn random_panels(kc: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = XorShiftRng::new(seed);
        let mut a = vec![0.0; kc * MR];
        let mut b = vec![0.0; kc * NR];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut b);
        (a, b)
    }

    #[test]
    fn matches_reference_overwrite() {
        for kc in [1, 2, 7, 64] {
            let (a, b) = random_panels(kc, kc as u64);
            let mut c1 = vec![9.0; MR * NR];
            let mut c2 = vec![-3.0; MR * NR];
            kernel_mr_nr(kc, &a, &b, &mut c1, NR, false);
            kernel_ref(kc, &a, &b, &mut c2, NR, false);
            for (x, y) in c1.iter().zip(&c2) {
                assert!((x - y).abs() < 1e-4, "kc={kc}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn matches_reference_accumulate() {
        let kc = 33;
        let (a, b) = random_panels(kc, 5);
        let init: Vec<f32> = (0..MR * NR).map(|i| i as f32).collect();
        let mut c1 = init.clone();
        let mut c2 = init;
        kernel_mr_nr(kc, &a, &b, &mut c1, NR, true);
        kernel_ref(kc, &a, &b, &mut c2, NR, true);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn respects_leading_dimension() {
        let kc = 4;
        let ldc = NR + 5;
        let (a, b) = random_panels(kc, 7);
        let mut c = vec![77.0; MR * ldc];
        kernel_mr_nr(kc, &a, &b, &mut c, ldc, false);
        // Padding columns untouched.
        for r in 0..MR {
            for j in NR..ldc {
                assert_eq!(c[r * ldc + j], 77.0);
            }
        }
    }

    #[test]
    fn zero_kc_zeroes_or_keeps() {
        let a = [0.0; 0];
        let b = [0.0; 0];
        let mut c = vec![5.0; MR * NR];
        kernel_mr_nr(0, &a, &b, &mut c, NR, true);
        assert!(c.iter().all(|&x| x == 5.0));
        kernel_mr_nr(0, &a, &b, &mut c, NR, false);
        assert!(c.iter().all(|&x| x == 0.0));
    }
}
