//! Packed, blocked single-precision GEMM (BLIS/BLASFEO-style).
//!
//! This is the shared substrate under **both** convolution schemes — the
//! paper benchmarks its region-wise Winograd GEMMs against im2row GEMMs
//! running on the same GEMM engine (Arm Compute Library); keeping one engine
//! here likewise isolates the algorithmic difference.
//!
//! Structure: the classical five-loop blocking
//! (`NC`→`KC`→`MC`→`NR`→`MR`) around an `MR×NR = 6×16` SIMD micro-kernel,
//! with A/B packed into panel buffers per block. `sgemm_with_pool`
//! parallelises the `MC` loop across the threadpool. Panel buffers come
//! from per-thread scratch reused across calls, so steady-state GEMMs on a
//! warm thread are allocation-free (part of the crate-wide
//! zero-steady-state-allocation property; see [`crate::workspace`]).
//!
//! Both ends of the pipeline can fuse into the GEMM instead of running as
//! separate passes:
//!
//! * **input side** — producers may write A directly in packed panel
//!   layout ([`pack::PackedAWriter`] / [`pack::packed_a_index`]) and run
//!   the batched driver [`BatchedGemm::run_packed_fused`], skipping the
//!   `pack_a` copy entirely (transform-as-pack);
//! * **output side** — every driver takes an [`Epilogue`] fired per
//!   finished micro-tile while C is cache-hot (bias/ReLU, or the Winograd
//!   inverse-transform gather), replacing whole-tensor post passes.

pub mod microkernel;
pub mod pack;
pub mod batched;
pub mod epilogue;

pub use batched::BatchedGemm;
pub use epilogue::{
    Activation, BiasAct, BiasActAdd, Epilogue, EpilogueI32, QDequantBiasAct, Requantize, Store,
};
pub use microkernel::{MR, NR};

#[cfg(test)]
mod prepack_tests {
    use super::*;
    use crate::util::{rel_error, XorShiftRng};

    #[test]
    fn prepacked_matches_blocked_across_block_boundaries() {
        // k and n cross KC/NC boundaries with the small blocking.
        let blk = Blocking { mc: 16, kc: 8, nc: 16 };
        let (m, n, k) = (21, 37, 29);
        let mut rng = XorShiftRng::new(3);
        let mut a = vec![0.0; m * k];
        let mut b = vec![0.0; k * n];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut b);
        let packed = PackedB::pack_with(&b, n, k, n, blk);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        sgemm_blocked(m, n, k, &a, k, &b, n, &mut c1, n, false, blk, None);
        sgemm_prepacked(m, &a, k, &packed, &mut c2, n, false, None);
        assert!(rel_error(&c2, &c1) < 1e-6);
    }

    #[test]
    fn prepacked_accumulate_and_edge_m() {
        let (m, n, k) = (1, 9, 300); // skinny-R case the pack exists for
        let mut rng = XorShiftRng::new(4);
        let mut a = vec![0.0; m * k];
        let mut b = vec![0.0; k * n];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut b);
        let packed = PackedB::pack(&b, n, k, n);
        let init: Vec<f32> = (0..m * n).map(|i| i as f32).collect();
        let mut c = init.clone();
        sgemm_prepacked(m, &a, k, &packed, &mut c, n, true, None);
        let mut prod = vec![0.0; m * n];
        sgemm_ref(m, n, k, &a, &b, &mut prod);
        let want: Vec<f32> = init.iter().zip(&prod).map(|(x, y)| x + y).collect();
        assert!(rel_error(&c, &want) < 1e-4);
        assert!(packed.bytes() >= k * n * 4);
    }
}

use crate::parallel::ThreadPool;
use pack::{pack_a, pack_b};
use std::cell::RefCell;

thread_local! {
    // Per-thread pack scratch reused across GEMM calls. The per-call `vec!`
    // for the A/B panel buffers was the last steady-state allocation on the
    // im2row hot path; with these, repeat GEMMs on a warm thread are
    // allocation-free. Two cells because one `sgemm_blocked` call holds the
    // B scratch across the MC loop while the calling thread also packs A.
    static PACK_A_SCRATCH: RefCell<Vec<f32>> = RefCell::new(Vec::new());
    static PACK_B_SCRATCH: RefCell<Vec<f32>> = RefCell::new(Vec::new());
}

pub(crate) fn with_scratch<R>(
    cell: &'static std::thread::LocalKey<RefCell<Vec<f32>>>,
    elems: usize,
    f: impl FnOnce(&mut [f32]) -> R,
) -> R {
    cell.with(|c| match c.try_borrow_mut() {
        Ok(mut buf) => {
            if buf.len() < elems {
                buf.resize(elems, 0.0);
            }
            f(&mut buf[..elems])
        }
        // Re-entrant GEMM on one thread: not a path the crate takes today,
        // but stay correct with a one-off buffer rather than panicking.
        Err(_) => f(&mut vec![0.0f32; elems]),
    })
}

/// Cache-blocking parameters. Defaults target a ~32 KiB L1 / ~1 MiB L2 core.
#[derive(Debug, Clone, Copy)]
pub struct Blocking {
    /// Rows of A kept in L2 per block.
    pub mc: usize,
    /// Depth kept in L1 per block.
    pub kc: usize,
    /// Columns of B kept in L3/L2 per block.
    pub nc: usize,
}

impl Default for Blocking {
    fn default() -> Self {
        Blocking {
            mc: 128,
            kc: 256,
            nc: 2048,
        }
    }
}

/// `C[m×n] (+)= A[m×k] · B[k×n]`, all row-major with explicit leading
/// dimensions. `accumulate=false` overwrites C.
#[allow(clippy::too_many_arguments)]
pub fn sgemm(
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    accumulate: bool,
) {
    sgemm_blocked(m, n, k, a, lda, b, ldb, c, ldc, accumulate, Blocking::default(), None)
}

/// Convenience wrapper for contiguous row-major operands.
pub fn sgemm_simple(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    sgemm(m, n, k, a, k, b, n, c, n, false)
}

/// [`sgemm`] with the `MC` loop parallelised over `pool`.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_with_pool(
    pool: &ThreadPool,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    accumulate: bool,
) {
    sgemm_blocked(m, n, k, a, lda, b, ldb, c, ldc, accumulate, Blocking::default(), Some(pool))
}

/// Full-control entry point with the no-op [`Store`] epilogue.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_blocked(
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    accumulate: bool,
    blk: Blocking,
    pool: Option<&ThreadPool>,
) {
    sgemm_blocked_fused(m, n, k, a, lda, b, ldb, c, ldc, accumulate, blk, pool, &Store)
}

/// Degenerate `k == 0` GEMM: zero C (or leave it, when accumulating), then
/// fire the epilogue over every micro-tile anyway — fused post-processing
/// (bias/ReLU) must be applied exactly once per element regardless of the
/// inner dimension, or a zero-depth layer would silently drop its bias.
fn handle_k_zero<E: Epilogue>(
    m: usize,
    n: usize,
    c: &mut [f32],
    ldc: usize,
    accumulate: bool,
    epi: &E,
) {
    if !accumulate {
        for r in 0..m {
            for v in c[r * ldc..r * ldc + n].iter_mut() {
                *v = 0.0;
            }
        }
    }
    for r0 in (0..m).step_by(MR) {
        let rows = (m - r0).min(MR);
        for j0 in (0..n).step_by(NR) {
            let cols = (n - j0).min(NR);
            epi.micro_tile(&mut c[r0 * ldc + j0..], ldc, r0, j0, rows, cols);
        }
    }
}

/// Full-control entry point. `epi` fires once per finished micro-tile of C
/// (on the final KC block, while the tile is cache-hot); a degenerate
/// `k == 0` call fires it over the zeroed C.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_blocked_fused<E: Epilogue>(
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    accumulate: bool,
    blk: Blocking,
    pool: Option<&ThreadPool>,
    epi: &E,
) {
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        handle_k_zero(m, n, c, ldc, accumulate, epi);
        return;
    }
    debug_assert!(a.len() >= (m - 1) * lda + k, "A buffer too small");
    debug_assert!(b.len() >= (k - 1) * ldb + n, "B buffer too small");
    debug_assert!(c.len() >= (m - 1) * ldc + n, "C buffer too small");

    // C as raw pointer so MC-disjoint row blocks can be written in parallel.
    let c_addr = c.as_mut_ptr() as usize;

    for jc in (0..n).step_by(blk.nc) {
        let nc = (n - jc).min(blk.nc);
        for pc in (0..k).step_by(blk.kc) {
            let kc = (k - pc).min(blk.kc);
            // First K-block writes/overwrites, later ones accumulate.
            let acc_block = accumulate || pc > 0;
            let last_kc = pc + kc == k;
            with_scratch(&PACK_B_SCRATCH, nc.div_ceil(NR) * NR * kc, |bbuf| {
                pack_b(&b[pc * ldb + jc..], ldb, kc, nc, bbuf);
                let bbuf = &*bbuf;

                let run_mc_block = |ic: usize| {
                    let mc = (m - ic).min(blk.mc);
                    with_scratch(&PACK_A_SCRATCH, mc.div_ceil(MR) * MR * kc, |abuf| {
                        pack_a(&a[ic * lda + pc..], lda, mc, kc, abuf);
                        // SAFETY: each ic block touches rows [ic, ic+mc) of C
                        // only; blocks are disjoint across parallel
                        // invocations.
                        let c_block: &mut [f32] = unsafe {
                            std::slice::from_raw_parts_mut(
                                (c_addr as *mut f32).add(ic * ldc + jc),
                                (mc - 1) * ldc + nc,
                            )
                        };
                        macro_kernel(
                            mc, nc, kc, abuf, bbuf, c_block, ldc, acc_block, ic, jc, last_kc, epi,
                        );
                    });
                };

                let n_blocks = m.div_ceil(blk.mc);
                match pool {
                    Some(pool) if n_blocks > 1 => {
                        pool.parallel_for(n_blocks, |bi| run_mc_block(bi * blk.mc));
                    }
                    _ => {
                        for bi in 0..n_blocks {
                            run_mc_block(bi * blk.mc);
                        }
                    }
                }
            });
        }
    }
}

/// Run the micro-kernel over every `MR×NR` tile of an `mc×nc` block.
///
/// `row_off`/`col_off` locate the block inside the full C matrix; when
/// `last_kc` is set this KC pass completes every tile's inner product, so
/// `epi` fires on each tile right after its write-back.
#[allow(clippy::too_many_arguments)]
fn macro_kernel<E: Epilogue>(
    mc: usize,
    nc: usize,
    kc: usize,
    abuf: &[f32],
    bbuf: &[f32],
    c: &mut [f32],
    ldc: usize,
    accumulate: bool,
    row_off: usize,
    col_off: usize,
    last_kc: bool,
    epi: &E,
) {
    let mut edge = [0.0f32; MR * NR];
    for jp in 0..nc.div_ceil(NR) {
        let j0 = jp * NR;
        let cols = (nc - j0).min(NR);
        let bpanel = &bbuf[jp * NR * kc..(jp + 1) * NR * kc];
        for ip in 0..mc.div_ceil(MR) {
            let r0 = ip * MR;
            let rows = (mc - r0).min(MR);
            let apanel = &abuf[ip * MR * kc..(ip + 1) * MR * kc];
            let off = r0 * ldc + j0;
            if rows == MR && cols == NR {
                microkernel::kernel_mr_nr(kc, apanel, bpanel, &mut c[off..], ldc, accumulate);
            } else {
                // Edge tile: compute into scratch, copy the valid region.
                microkernel::kernel_mr_nr(kc, apanel, bpanel, &mut edge, NR, false);
                for r in 0..rows {
                    let dst = &mut c[(r0 + r) * ldc + j0..(r0 + r) * ldc + j0 + cols];
                    let src = &edge[r * NR..r * NR + cols];
                    if accumulate {
                        for (d, s) in dst.iter_mut().zip(src) {
                            *d += *s;
                        }
                    } else {
                        dst.copy_from_slice(src);
                    }
                }
            }
            if last_kc {
                epi.micro_tile(&mut c[off..], ldc, row_off + r0, col_off + j0, rows, cols);
            }
        }
    }
}

/// B pre-packed into panel layout for repeated GEMMs against a constant
/// right-hand side (transformed conv weights). Packing once at
/// layer-prepare time removes the dominant per-call cost of skinny-R GEMMs
/// (small feature maps) — see EXPERIMENTS.md §Perf step 2.
#[derive(Debug, Clone)]
pub struct PackedB {
    /// Inner dimension.
    pub k: usize,
    /// Columns.
    pub n: usize,
    blk: Blocking,
    /// Blocks in (jc, pc) iteration order, each `ceil(nc/NR)·NR·kc` long.
    data: Vec<f32>,
}

impl PackedB {
    /// Pack row-major `b` (`k×n`, leading dimension `ldb`).
    pub fn pack(b: &[f32], ldb: usize, k: usize, n: usize) -> PackedB {
        Self::pack_with(b, ldb, k, n, Blocking::default())
    }

    /// Pack with explicit blocking (must match the execution blocking).
    pub fn pack_with(b: &[f32], ldb: usize, k: usize, n: usize, blk: Blocking) -> PackedB {
        let mut data = Vec::new();
        for jc in (0..n).step_by(blk.nc) {
            let nc = (n - jc).min(blk.nc);
            for pc in (0..k).step_by(blk.kc) {
                let kc = (k - pc).min(blk.kc);
                let start = data.len();
                data.resize(start + nc.div_ceil(NR) * NR * kc, 0.0);
                pack_b(&b[pc * ldb + jc..], ldb, kc, nc, &mut data[start..]);
            }
        }
        PackedB { k, n, blk, data }
    }

    /// Bytes held by the packed representation.
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// Number of `NR`-column panels covering the matrix.
    pub fn col_panels(&self) -> usize {
        self.n.div_ceil(NR)
    }

    /// Visit every KC block of column-panel `jp` in depth order: `f` is
    /// called with `(pc, kc, panel)` where `panel` is the `kc`-deep ×
    /// `NR`-wide packed slice the micro-kernel streams for depth rows
    /// `[pc, pc + kc)` of columns `[jp·NR, (jp+1)·NR)`.
    ///
    /// This is how fused drivers consume a `PackedB` without materialising
    /// C blocks: one column-panel of one tile at a time, accumulating over
    /// KC blocks in registers. Requires `blk.nc` to be a multiple of `NR`
    /// when the matrix spans several NC blocks (the default blocking is).
    pub fn for_each_kc_panel(&self, jp: usize, mut f: impl FnMut(usize, usize, &[f32])) {
        let col0 = jp * NR;
        debug_assert!(col0 < self.n, "column panel {jp} out of range");
        // Hard assert: an unaligned nc would make jp_local index the wrong
        // panel and return silently wrong data in release builds.
        assert!(
            self.n <= self.blk.nc || self.blk.nc % NR == 0,
            "multi-NC-block PackedB needs NR-aligned nc"
        );
        let mut offset = 0usize;
        for jc in (0..self.n).step_by(self.blk.nc) {
            let nc = (self.n - jc).min(self.blk.nc);
            let panels = nc.div_ceil(NR);
            let in_block = col0 >= jc && col0 < jc + nc;
            let jp_local = (col0 - jc.min(col0)) / NR;
            for pc in (0..self.k).step_by(self.blk.kc) {
                let kc = (self.k - pc).min(self.blk.kc);
                let len = panels * NR * kc;
                if in_block {
                    let p0 = offset + jp_local * NR * kc;
                    f(pc, kc, &self.data[p0..p0 + NR * kc]);
                }
                offset += len;
            }
        }
    }
}

/// `C[m×n] (+)= A[m×k] · B` with `B` pre-packed by [`PackedB::pack`] and
/// the no-op [`Store`] epilogue.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_prepacked(
    m: usize,
    a: &[f32],
    lda: usize,
    b: &PackedB,
    c: &mut [f32],
    ldc: usize,
    accumulate: bool,
    pool: Option<&ThreadPool>,
) {
    sgemm_prepacked_fused(m, a, lda, b, c, ldc, accumulate, pool, &Store)
}

/// [`sgemm_prepacked`] with a fused [`Epilogue`] fired per finished
/// micro-tile of C while it is cache-hot (a degenerate `k == 0` call
/// fires it over the zeroed C).
#[allow(clippy::too_many_arguments)]
pub fn sgemm_prepacked_fused<E: Epilogue>(
    m: usize,
    a: &[f32],
    lda: usize,
    b: &PackedB,
    c: &mut [f32],
    ldc: usize,
    accumulate: bool,
    pool: Option<&ThreadPool>,
    epi: &E,
) {
    let (n, k, blk) = (b.n, b.k, b.blk);
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        handle_k_zero(m, n, c, ldc, accumulate, epi);
        return;
    }
    debug_assert!(a.len() >= (m - 1) * lda + k, "A buffer too small");
    debug_assert!(c.len() >= (m - 1) * ldc + n, "C buffer too small");
    let c_addr = c.as_mut_ptr() as usize;

    let mut offset = 0usize;
    for jc in (0..n).step_by(blk.nc) {
        let nc = (n - jc).min(blk.nc);
        for pc in (0..k).step_by(blk.kc) {
            let kc = (k - pc).min(blk.kc);
            let len = nc.div_ceil(NR) * NR * kc;
            let bbuf = &b.data[offset..offset + len];
            offset += len;
            let acc_block = accumulate || pc > 0;
            let last_kc = pc + kc == k;

            let run_mc_block = |ic: usize| {
                let mc = (m - ic).min(blk.mc);
                with_scratch(&PACK_A_SCRATCH, mc.div_ceil(MR) * MR * kc, |abuf| {
                    pack_a(&a[ic * lda + pc..], lda, mc, kc, abuf);
                    // SAFETY: disjoint row blocks of C (same as sgemm_blocked).
                    let c_block: &mut [f32] = unsafe {
                        std::slice::from_raw_parts_mut(
                            (c_addr as *mut f32).add(ic * ldc + jc),
                            (mc - 1) * ldc + nc,
                        )
                    };
                    macro_kernel(
                        mc, nc, kc, abuf, bbuf, c_block, ldc, acc_block, ic, jc, last_kc, epi,
                    );
                });
            };
            let n_blocks = m.div_ceil(blk.mc);
            match pool {
                Some(pool) if n_blocks > 1 => {
                    pool.parallel_for(n_blocks, |bi| run_mc_block(bi * blk.mc));
                }
                _ => {
                    for bi in 0..n_blocks {
                        run_mc_block(bi * blk.mc);
                    }
                }
            }
        }
    }
}

/// Naive triple-loop reference GEMM (tests and tiny problems).
pub fn sgemm_ref(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for r in 0..m {
        for j in 0..n {
            let mut s = 0.0f32;
            for p in 0..k {
                s += a[r * k + p] * b[p * n + j];
            }
            c[r * n + j] = s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{rel_error, XorShiftRng};

    fn random_matrix(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
        let mut rng = XorShiftRng::new(seed);
        let mut v = vec![0.0; rows * cols];
        rng.fill_normal(&mut v);
        v
    }

    fn check_case(m: usize, n: usize, k: usize) {
        let a = random_matrix(m, k, (m * 31 + k) as u64);
        let b = random_matrix(k, n, (n * 17 + k) as u64 + 1);
        let mut c = vec![0.0; m * n];
        let mut cref = vec![0.0; m * n];
        sgemm_simple(m, n, k, &a, &b, &mut c);
        sgemm_ref(m, n, k, &a, &b, &mut cref);
        assert!(
            rel_error(&c, &cref) < 1e-4,
            "GEMM mismatch at m={m} n={n} k={k}: err={}",
            rel_error(&c, &cref)
        );
    }

    #[test]
    fn matches_reference_exact_tiles() {
        check_case(8, 8, 16);
        check_case(16, 32, 64);
        check_case(64, 64, 256);
    }

    #[test]
    fn matches_reference_ragged_edges() {
        check_case(1, 1, 1);
        check_case(3, 5, 7);
        check_case(9, 17, 33);
        check_case(130, 70, 300); // crosses MC and KC boundaries
        check_case(7, 250, 2);
    }

    #[test]
    fn accumulate_adds_to_existing() {
        let (m, n, k) = (10, 12, 9);
        let a = random_matrix(m, k, 3);
        let b = random_matrix(k, n, 4);
        let init = random_matrix(m, n, 5);
        let mut c = init.clone();
        sgemm(m, n, k, &a, k, &b, n, &mut c, n, true);
        let mut prod = vec![0.0; m * n];
        sgemm_ref(m, n, k, &a, &b, &mut prod);
        let expect: Vec<f32> = init.iter().zip(&prod).map(|(x, y)| x + y).collect();
        assert!(rel_error(&c, &expect) < 1e-4);
    }

    #[test]
    fn strided_operands() {
        // Operate on the top-left m×k / k×n corners of larger buffers.
        let (m, n, k) = (5, 6, 7);
        let (lda, ldb, ldc) = (k + 3, n + 2, n + 4);
        let abig = random_matrix(m, lda, 6);
        let bbig = random_matrix(k, ldb, 7);
        let mut cbig = vec![42.0; m * ldc];
        sgemm(m, n, k, &abig, lda, &bbig, ldb, &mut cbig, ldc, false);

        let a: Vec<f32> = (0..m).flat_map(|r| abig[r * lda..r * lda + k].to_vec()).collect();
        let b: Vec<f32> = (0..k).flat_map(|r| bbig[r * ldb..r * ldb + n].to_vec()).collect();
        let mut cref = vec![0.0; m * n];
        sgemm_ref(m, n, k, &a, &b, &mut cref);
        for r in 0..m {
            for j in 0..n {
                assert!((cbig[r * ldc + j] - cref[r * n + j]).abs() < 1e-3);
            }
            // untouched past n
            for j in n..ldc {
                assert_eq!(cbig[r * ldc + j], 42.0);
            }
        }
    }

    #[test]
    fn k_zero_zeroes_or_keeps_c() {
        let mut c = vec![3.0; 4];
        sgemm(2, 2, 0, &[], 1, &[], 1, &mut c, 2, true);
        assert_eq!(c, vec![3.0; 4]);
        sgemm(2, 2, 0, &[], 1, &[], 1, &mut c, 2, false);
        assert_eq!(c, vec![0.0; 4]);
    }

    #[test]
    fn parallel_matches_serial() {
        let pool = ThreadPool::new(4);
        let (m, n, k) = (300, 120, 96);
        let a = random_matrix(m, k, 8);
        let b = random_matrix(k, n, 9);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        sgemm_simple(m, n, k, &a, &b, &mut c1);
        sgemm_with_pool(&pool, m, n, k, &a, k, &b, n, &mut c2, n, false);
        assert!(rel_error(&c2, &c1) < 1e-5);
    }

    #[test]
    fn small_blocking_params_still_correct() {
        let (m, n, k) = (37, 29, 41);
        let a = random_matrix(m, k, 10);
        let b = random_matrix(k, n, 11);
        let mut c = vec![0.0; m * n];
        let blk = Blocking { mc: 16, kc: 8, nc: 16 };
        sgemm_blocked(m, n, k, &a, k, &b, n, &mut c, n, false, blk, None);
        let mut cref = vec![0.0; m * n];
        sgemm_ref(m, n, k, &a, &b, &mut cref);
        assert!(rel_error(&c, &cref) < 1e-4);
    }

    /// Fused bias+ReLU epilogue == plain GEMM then a separate bias/ReLU
    /// pass, across KC/MC boundaries, edge tiles and pool execution — the
    /// epilogue must fire exactly once per element, only when its inner
    /// product is complete.
    #[test]
    fn fused_bias_relu_matches_post_pass() {
        let pool = ThreadPool::new(3);
        for (m, n, k) in [(1usize, 1usize, 1usize), (7, 19, 40), (37, 29, 300), (140, 33, 260)] {
            let a = random_matrix(m, k, (m + k) as u64);
            let b = random_matrix(k, n, (n + k) as u64);
            let bias: Vec<f32> = (0..n).map(|j| (j as f32) * 0.25 - 1.0).collect();
            let packed = PackedB::pack(&b, n, k, n);
            for use_pool in [false, true] {
                let p = if use_pool { Some(&pool) } else { None };
                let mut fused = vec![0.0; m * n];
                let epi = BiasAct { bias: Some(&bias), act: Activation::Relu };
                sgemm_prepacked_fused(m, &a, k, &packed, &mut fused, n, false, p, &epi);
                let mut plain = vec![0.0; m * n];
                sgemm_ref(m, n, k, &a, &b, &mut plain);
                for r in 0..m {
                    for j in 0..n {
                        plain[r * n + j] = (plain[r * n + j] + bias[j]).max(0.0);
                    }
                }
                assert!(
                    rel_error(&fused, &plain) < 1e-4,
                    "m={m} n={n} k={k} pool={use_pool}: err={}",
                    rel_error(&fused, &plain)
                );
            }
        }
    }

    /// A zero-depth GEMM must still fire the fused epilogue over the zeroed
    /// C — a degenerate 0-channel conv layer's bias would otherwise be
    /// silently dropped (diverging from the direct-conv oracle).
    #[test]
    fn k_zero_still_fires_epilogue() {
        let (m, n) = (7usize, 18usize); // ragged vs MR/NR on purpose
        let bias: Vec<f32> = (0..n).map(|j| j as f32 + 1.0).collect();
        let packed = PackedB::pack(&[], n, 0, n);
        let mut c = vec![5.0; m * n];
        let epi = BiasAct { bias: Some(&bias), act: Activation::None };
        sgemm_prepacked_fused(m, &[], 0, &packed, &mut c, n, false, None, &epi);
        for r in 0..m {
            for j in 0..n {
                assert_eq!(c[r * n + j], bias[j], "({r},{j})");
            }
        }
    }

    /// `for_each_kc_panel` must reproduce the exact panel slices pack_b
    /// produced, covering the full depth in order, including across
    /// KC/NC block boundaries.
    #[test]
    fn kc_panel_walk_reconstructs_b() {
        let blk = Blocking { mc: 16, kc: 8, nc: 32 }; // nc multiple of NR
        let (k, n) = (21usize, 37usize);
        let b = random_matrix(k, n, 12);
        let packed = PackedB::pack_with(&b, n, k, n, blk);
        assert_eq!(packed.col_panels(), n.div_ceil(NR));
        for jp in 0..packed.col_panels() {
            let col0 = jp * NR;
            let cols = (n - col0).min(NR);
            let mut covered = 0usize;
            packed.for_each_kc_panel(jp, |pc, kc, panel| {
                assert_eq!(pc, covered, "KC blocks must arrive in depth order");
                assert_eq!(panel.len(), NR * kc);
                for p in 0..kc {
                    for j in 0..NR {
                        let want = if j < cols { b[(pc + p) * n + col0 + j] } else { 0.0 };
                        assert_eq!(panel[p * NR + j], want, "jp={jp} pc={pc} p={p} j={j}");
                    }
                }
                covered += kc;
            });
            assert_eq!(covered, k, "panels must cover the full depth");
        }
    }
}
