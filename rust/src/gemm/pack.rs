//! Panel packing for the blocked GEMM.
//!
//! A is repacked into `MR`-row panels stored column-major-within-panel, B
//! into `NR`-column panels stored row-major-within-panel, so the micro-kernel
//! streams both with unit stride. Edge panels are zero-padded — the
//! micro-kernel always runs full `MR×NR` tiles and edge results are sliced
//! out by the driver.
//!
//! Two ways of producing packed A coexist:
//!
//! * [`pack_a`] — the classical copy pass: repack an existing row-major
//!   block (the im2row patch matrix path).
//! * [`PackedAWriter`] / [`packed_a_index`] — **transform-as-pack**: a
//!   producer that computes values (the Winograd input transform) writes
//!   them *directly* into panel layout, so the packed image is the first
//!   and only materialisation of A — no row-major staging buffer, no
//!   second memory pass (the BLASFEO-style fusion the paper's §2.2 kernels
//!   rely on).

use super::microkernel::{MR, NR};
use crate::simd::F32x4;

/// Bytes of one packed-B panel (`NR` columns × `kc` depth) — the B-side
/// working-set term the Winograd region-block sizing budgets for: while the
/// micro-kernel streams a tile's GEMM, exactly one such panel is hot.
pub fn packed_b_panel_bytes(kc: usize) -> usize {
    NR * kc * std::mem::size_of::<f32>()
}

/// Pack an `mc × kc` block of row-major `A` (leading dimension `lda`)
/// starting at `a`, into `buf`.
///
/// Layout: panel-major; panel `i` covers rows `i*MR..`, stored as `kc`
/// consecutive columns of `MR` values. `buf` must hold
/// `ceil(mc/MR)*MR * kc` values.
pub fn pack_a(a: &[f32], lda: usize, mc: usize, kc: usize, buf: &mut [f32]) {
    let panels = mc.div_ceil(MR);
    debug_assert!(buf.len() >= panels * MR * kc);
    for ip in 0..panels {
        let r0 = ip * MR;
        let rows = (mc - r0).min(MR);
        let dst = &mut buf[ip * MR * kc..(ip + 1) * MR * kc];
        for p in 0..kc {
            let col = &mut dst[p * MR..p * MR + MR];
            for r in 0..rows {
                col[r] = a[(r0 + r) * lda + p];
            }
            for v in col[rows..].iter_mut() {
                *v = 0.0;
            }
        }
    }
}

/// Pack a `kc × nc` block of row-major `B` (leading dimension `ldb`)
/// starting at `b`, into `buf`.
///
/// Layout: panel-major; panel `j` covers columns `j*NR..`, stored as `kc`
/// consecutive rows of `NR` values. `buf` must hold
/// `ceil(nc/NR)*NR * kc` values.
pub fn pack_b(b: &[f32], ldb: usize, kc: usize, nc: usize, buf: &mut [f32]) {
    let panels = nc.div_ceil(NR);
    debug_assert!(buf.len() >= panels * NR * kc);
    for jp in 0..panels {
        let c0 = jp * NR;
        let cols = (nc - c0).min(NR);
        let dst = &mut buf[jp * NR * kc..(jp + 1) * NR * kc];
        for p in 0..kc {
            let row = &mut dst[p * NR..p * NR + NR];
            let src = &b[p * ldb + c0..p * ldb + c0 + cols];
            row[..cols].copy_from_slice(src);
            for v in row[cols..].iter_mut() {
                *v = 0.0;
            }
        }
    }
}

/// Elements the packed-A image of an `m×k` matrix occupies: whole `MR`-row
/// panels, the short last panel zero-padded to `MR`.
pub fn packed_a_elems(m: usize, k: usize) -> usize {
    m.div_ceil(MR) * MR * k
}

/// Flat index of logical `A[row][col]` inside the whole-matrix packed-A
/// layout (`k` logical columns): panel `row / MR` starts at
/// `(row / MR) * MR * k`, inside which column `col` is a group of `MR`
/// consecutive values, one per panel row.
///
/// Consecutive `col`s for one `row` are therefore `MR` elements apart — the
/// stride a transform-as-pack producer scatters channel lanes with — while
/// a micro-kernel consuming `(panel, col)` groups streams with unit stride.
/// A `kc`-column slice `[pc, pc+kc)` of panel `ip` is the contiguous range
/// `ip*MR*k + pc*MR ..+ kc*MR`, exactly the panel format
/// [`super::microkernel::kernel_mr_nr`] expects, so KC-blocked drivers can
/// feed the kernel straight from this layout without any repack.
#[inline(always)]
pub fn packed_a_index(k: usize, row: usize, col: usize) -> usize {
    (row / MR) * MR * k + col * MR + (row % MR)
}

/// Incremental writer laying a logical row-major `m×k` matrix directly into
/// packed-A panel layout — what [`pack_a`] would produce for a single block
/// spanning the whole matrix, but without the matrix ever existing in
/// row-major form.
///
/// Used by the fused Winograd input transform (`transform_and_pack`): each
/// region's transformed channel values are scattered straight into their
/// packed cells. Call [`zero_pad_rows`](Self::zero_pad_rows) once before
/// (or after) writing so the dead rows of a short last panel multiply as
/// zero in the micro-kernel.
#[derive(Debug)]
pub struct PackedAWriter<'a> {
    buf: &'a mut [f32],
    m: usize,
    k: usize,
}

impl<'a> PackedAWriter<'a> {
    /// Wrap `buf` (at least [`packed_a_elems`]`(m, k)` long) as the packed
    /// image of an `m×k` matrix.
    pub fn new(buf: &'a mut [f32], m: usize, k: usize) -> PackedAWriter<'a> {
        debug_assert!(buf.len() >= packed_a_elems(m, k));
        PackedAWriter { buf, m, k }
    }

    /// Logical rows.
    pub fn rows(&self) -> usize {
        self.m
    }

    /// Logical columns.
    pub fn cols(&self) -> usize {
        self.k
    }

    /// Write logical `A[row][col] = v`.
    #[inline(always)]
    pub fn write(&mut self, row: usize, col: usize, v: f32) {
        debug_assert!(row < self.m && col < self.k);
        self.buf[packed_a_index(self.k, row, col)] = v;
    }

    /// Scatter the first `lanes` values of `v` into columns
    /// `col..col + lanes` of `row` (`MR`-strided stores in packed layout).
    #[inline(always)]
    pub fn write_lanes(&mut self, row: usize, col: usize, v: F32x4, lanes: usize) {
        debug_assert!(row < self.m && col + lanes <= self.k && lanes <= 4);
        let base = packed_a_index(self.k, row, col);
        let vals = v.to_array();
        for (l, &x) in vals[..lanes].iter().enumerate() {
            self.buf[base + l * MR] = x;
        }
    }

    /// Zero the padding rows of a short last panel (`m..ceil(m/MR)*MR`) so
    /// edge panels contribute zeros. A no-op when `m` divides `MR` evenly.
    pub fn zero_pad_rows(&mut self) {
        let padded = self.m.div_ceil(MR) * MR;
        for row in self.m..padded {
            for col in 0..self.k {
                self.buf[packed_a_index(self.k, row, col)] = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panel_bytes_formula() {
        assert_eq!(packed_b_panel_bytes(0), 0);
        assert_eq!(packed_b_panel_bytes(256), NR * 256 * 4);
    }

    #[test]
    fn pack_a_layout() {
        // 3×2 block of a row-major 3×5 matrix, MR=8 ⇒ one zero-padded panel.
        let lda = 5;
        let a: Vec<f32> = (0..15).map(|i| i as f32).collect();
        let (mc, kc) = (3, 2);
        let mut buf = vec![f32::NAN; MR * kc];
        pack_a(&a, lda, mc, kc, &mut buf);
        // Column p=0 holds a[0][0], a[1][0], a[2][0], then zeros.
        assert_eq!(&buf[0..4], &[0.0, 5.0, 10.0, 0.0]);
        // Column p=1 holds a[0][1], a[1][1], a[2][1], then zeros.
        assert_eq!(&buf[MR..MR + 4], &[1.0, 6.0, 11.0, 0.0]);
        assert!(buf.iter().all(|v| !v.is_nan()));
    }

    #[test]
    fn pack_b_layout() {
        // 2×3 block of a row-major 2×5 matrix, NR=8 ⇒ one zero-padded panel.
        let ldb = 5;
        let b: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let (kc, nc) = (2, 3);
        let mut buf = vec![f32::NAN; NR * kc];
        pack_b(&b, ldb, kc, nc, &mut buf);
        // Row p=0 holds b[0][0..3] then zeros.
        assert_eq!(&buf[0..4], &[0.0, 1.0, 2.0, 0.0]);
        // Row p=1 holds b[1][0..3] then zeros.
        assert_eq!(&buf[NR..NR + 4], &[5.0, 6.0, 7.0, 0.0]);
        assert!(buf.iter().all(|v| !v.is_nan()));
    }

    /// The writer's layout must be bit-identical to `pack_a` run over the
    /// whole matrix as one block — the property that lets the fused
    /// transform delete the row-major A staging buffer without touching the
    /// GEMM's consumption side.
    #[test]
    fn writer_matches_pack_a_whole_matrix() {
        for (m, k) in [(1usize, 1usize), (MR, 3), (MR + 2, 7), (3 * MR - 1, 5)] {
            let a: Vec<f32> = (0..m * k).map(|i| (i as f32).sin()).collect();
            let mut want = vec![0.0f32; packed_a_elems(m, k)];
            pack_a(&a, k, m, k, &mut want);
            let mut got = vec![f32::NAN; packed_a_elems(m, k)];
            let mut w = PackedAWriter::new(&mut got, m, k);
            w.zero_pad_rows();
            for row in 0..m {
                for col in 0..k {
                    w.write(row, col, a[row * k + col]);
                }
            }
            assert_eq!(got, want, "m={m} k={k}");
        }
    }

    #[test]
    fn writer_lane_scatter_matches_scalar_writes() {
        let (m, k) = (MR + 1, 10);
        let a: Vec<f32> = (0..m * k).map(|i| i as f32 * 0.5).collect();
        let mut scalar = vec![0.0f32; packed_a_elems(m, k)];
        let mut lanes = vec![0.0f32; packed_a_elems(m, k)];
        let mut ws = PackedAWriter::new(&mut scalar, m, k);
        ws.zero_pad_rows();
        let mut wl = PackedAWriter::new(&mut lanes, m, k);
        wl.zero_pad_rows();
        for row in 0..m {
            for col in (0..k).step_by(4) {
                let n = (k - col).min(4);
                for l in 0..n {
                    ws.write(row, col + l, a[row * k + col + l]);
                }
                wl.write_lanes(row, col, F32x4::load_partial(&a[row * k + col..row * k + col + n]), n);
            }
        }
        assert_eq!(scalar, lanes);
    }

    #[test]
    fn packed_a_index_formula() {
        let k = 5;
        // Row 0, col 0 → start of panel 0; col advances by MR.
        assert_eq!(packed_a_index(k, 0, 0), 0);
        assert_eq!(packed_a_index(k, 0, 1), MR);
        // Row 1 sits one element into each column group.
        assert_eq!(packed_a_index(k, 1, 0), 1);
        // First row of panel 1 starts after MR*k elements.
        assert_eq!(packed_a_index(k, MR, 0), MR * k);
        assert_eq!(packed_a_elems(MR + 1, k), 2 * MR * k);
    }

    #[test]
    fn multi_panel_pack() {
        // Sizes chosen to force ≥2 panels on each side plus padding.
        let (mc, kc, nc): (usize, usize, usize) = (MR + MR / 2, 3, NR + 1);
        let a: Vec<f32> = (0..mc * kc).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..kc * nc).map(|i| i as f32).collect();
        let mut abuf = vec![0.0; mc.div_ceil(MR) * MR * kc];
        let mut bbuf = vec![0.0; nc.div_ceil(NR) * NR * kc];
        pack_a(&a, kc, mc, kc, &mut abuf);
        pack_b(&b, nc, kc, nc, &mut bbuf);
        // Panel 1 of A starts at row MR: a[MR][0] = MR·kc.
        assert_eq!(abuf[MR * kc], (MR * kc) as f32);
        // Panel 1 of B starts at col NR: b[0][NR] = NR.
        assert_eq!(bbuf[NR * kc], NR as f32);
        // Zero padding in A panel 1: rows mc..2·MR pad column p=0.
        assert_eq!(abuf[MR * kc + (mc - MR)], 0.0);
        // Zero padding in B panel 1: cols nc..2·NR pad row p=0.
        assert_eq!(bbuf[NR * kc + (nc - NR)], 0.0);
    }
}
