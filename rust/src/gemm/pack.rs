//! Panel packing for the blocked GEMM.
//!
//! A is repacked into `MR`-row panels stored column-major-within-panel, B
//! into `NR`-column panels stored row-major-within-panel, so the micro-kernel
//! streams both with unit stride. Edge panels are zero-padded — the
//! micro-kernel always runs full `MR×NR` tiles and edge results are sliced
//! out by the driver.

use super::microkernel::{MR, NR};

/// Bytes of one packed-B panel (`NR` columns × `kc` depth) — the B-side
/// working-set term the Winograd region-block sizing budgets for: while the
/// micro-kernel streams a tile's GEMM, exactly one such panel is hot.
pub fn packed_b_panel_bytes(kc: usize) -> usize {
    NR * kc * std::mem::size_of::<f32>()
}

/// Pack an `mc × kc` block of row-major `A` (leading dimension `lda`)
/// starting at `a`, into `buf`.
///
/// Layout: panel-major; panel `i` covers rows `i*MR..`, stored as `kc`
/// consecutive columns of `MR` values. `buf` must hold
/// `ceil(mc/MR)*MR * kc` values.
pub fn pack_a(a: &[f32], lda: usize, mc: usize, kc: usize, buf: &mut [f32]) {
    let panels = mc.div_ceil(MR);
    debug_assert!(buf.len() >= panels * MR * kc);
    for ip in 0..panels {
        let r0 = ip * MR;
        let rows = (mc - r0).min(MR);
        let dst = &mut buf[ip * MR * kc..(ip + 1) * MR * kc];
        for p in 0..kc {
            let col = &mut dst[p * MR..p * MR + MR];
            for r in 0..rows {
                col[r] = a[(r0 + r) * lda + p];
            }
            for v in col[rows..].iter_mut() {
                *v = 0.0;
            }
        }
    }
}

/// Pack a `kc × nc` block of row-major `B` (leading dimension `ldb`)
/// starting at `b`, into `buf`.
///
/// Layout: panel-major; panel `j` covers columns `j*NR..`, stored as `kc`
/// consecutive rows of `NR` values. `buf` must hold
/// `ceil(nc/NR)*NR * kc` values.
pub fn pack_b(b: &[f32], ldb: usize, kc: usize, nc: usize, buf: &mut [f32]) {
    let panels = nc.div_ceil(NR);
    debug_assert!(buf.len() >= panels * NR * kc);
    for jp in 0..panels {
        let c0 = jp * NR;
        let cols = (nc - c0).min(NR);
        let dst = &mut buf[jp * NR * kc..(jp + 1) * NR * kc];
        for p in 0..kc {
            let row = &mut dst[p * NR..p * NR + NR];
            let src = &b[p * ldb + c0..p * ldb + c0 + cols];
            row[..cols].copy_from_slice(src);
            for v in row[cols..].iter_mut() {
                *v = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panel_bytes_formula() {
        assert_eq!(packed_b_panel_bytes(0), 0);
        assert_eq!(packed_b_panel_bytes(256), NR * 256 * 4);
    }

    #[test]
    fn pack_a_layout() {
        // 3×2 block of a row-major 3×5 matrix, MR=8 ⇒ one zero-padded panel.
        let lda = 5;
        let a: Vec<f32> = (0..15).map(|i| i as f32).collect();
        let (mc, kc) = (3, 2);
        let mut buf = vec![f32::NAN; MR * kc];
        pack_a(&a, lda, mc, kc, &mut buf);
        // Column p=0 holds a[0][0], a[1][0], a[2][0], then zeros.
        assert_eq!(&buf[0..4], &[0.0, 5.0, 10.0, 0.0]);
        // Column p=1 holds a[0][1], a[1][1], a[2][1], then zeros.
        assert_eq!(&buf[MR..MR + 4], &[1.0, 6.0, 11.0, 0.0]);
        assert!(buf.iter().all(|v| !v.is_nan()));
    }

    #[test]
    fn pack_b_layout() {
        // 2×3 block of a row-major 2×5 matrix, NR=8 ⇒ one zero-padded panel.
        let ldb = 5;
        let b: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let (kc, nc) = (2, 3);
        let mut buf = vec![f32::NAN; NR * kc];
        pack_b(&b, ldb, kc, nc, &mut buf);
        // Row p=0 holds b[0][0..3] then zeros.
        assert_eq!(&buf[0..4], &[0.0, 1.0, 2.0, 0.0]);
        // Row p=1 holds b[1][0..3] then zeros.
        assert_eq!(&buf[NR..NR + 4], &[5.0, 6.0, 7.0, 0.0]);
        assert!(buf.iter().all(|v| !v.is_nan()));
    }

    #[test]
    fn multi_panel_pack() {
        // Sizes chosen to force ≥2 panels on each side plus padding.
        let (mc, kc, nc): (usize, usize, usize) = (MR + MR / 2, 3, NR + 1);
        let a: Vec<f32> = (0..mc * kc).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..kc * nc).map(|i| i as f32).collect();
        let mut abuf = vec![0.0; mc.div_ceil(MR) * MR * kc];
        let mut bbuf = vec![0.0; nc.div_ceil(NR) * NR * kc];
        pack_a(&a, kc, mc, kc, &mut abuf);
        pack_b(&b, nc, kc, nc, &mut bbuf);
        // Panel 1 of A starts at row MR: a[MR][0] = MR·kc.
        assert_eq!(abuf[MR * kc], (MR * kc) as f32);
        // Panel 1 of B starts at col NR: b[0][NR] = NR.
        assert_eq!(bbuf[NR * kc], NR as f32);
        // Zero padding in A panel 1: rows mc..2·MR pad column p=0.
        assert_eq!(abuf[MR * kc + (mc - MR)], 0.0);
        // Zero padding in B panel 1: cols nc..2·NR pad row p=0.
        assert_eq!(bbuf[NR * kc + (nc - NR)], 0.0);
    }
}
