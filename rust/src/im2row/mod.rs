//! The classical im2row + GEMM baseline (the paper's comparator, as used by
//! the Arm Compute Library).
//!
//! im2row materialises, for every output pixel, the `KH·KW·C` receptive
//! field as one row of a patch matrix; the convolution is then a single
//! GEMM `[N·OH·OW × KH·KW·C] · [KH·KW·C × M]`. Under NHWC each `(kh, kw)`
//! contributes a contiguous `C`-run, so row construction is `KH·KW` memcpys.
//! The GEMM runs on the same engine as the Winograd scheme's batched GEMMs —
//! benchmark deltas therefore isolate the algorithmic difference, exactly as
//! in the paper's evaluation. Per-channel bias and activation (ReLU / ReLU6) ride as a
//! [`BiasAct`] GEMM epilogue ([`Im2RowConvolution::run_fused_into`]):
//! each micro-tile of the output is biased/activated while cache-hot, so
//! conv outputs are written exactly once — the same single-pass guarantee
//! the fused Winograd pipeline makes. The write-into entry point draws the
//! padded-input staging buffer and the patch matrix from the caller's
//! arena and writes the conv output to a caller-provided slice, so a warm
//! steady-state inference allocates nothing; the allocating
//! [`Im2RowConvolution::run_fused_with`] is a thin wrapper kept as the
//! test oracle.

use crate::gemm::{sgemm_prepacked_fused, Activation, BiasAct, PackedB};
use crate::parallel::ThreadPool;
use crate::tensor::{Tensor, TensorView};
use crate::workspace::Workspace;
use crate::{bail_shape, Result};

/// An im2row convolution with a pre-transposed weight matrix, reusable
/// across inputs (mirrors [`crate::winograd::WinogradConvolution`]).
#[derive(Debug, Clone)]
pub struct Im2RowConvolution {
    cin: usize,
    cout: usize,
    kernel: (usize, usize),
    stride: (usize, usize),
    pad: (usize, usize),
    /// Weights reshaped+transposed to `[KH·KW·C, M]` and pre-packed into
    /// GEMM panel layout (packed once per layer — the same prepare-time
    /// treatment the Winograd scheme gets, keeping the baseline fair; see
    /// EXPERIMENTS.md §Perf step 2).
    wt_packed: PackedB,
}

impl Im2RowConvolution {
    /// Prepare from `[M, KH, KW, C]` weights.
    pub fn new(weights: &Tensor, stride: (usize, usize), pad: (usize, usize)) -> Result<Self> {
        if weights.rank() != 4 {
            bail_shape!("weights must be [M, KH, KW, C], got {:?}", weights.shape());
        }
        let (m, kh, kw, c) = (
            weights.shape()[0],
            weights.shape()[1],
            weights.shape()[2],
            weights.shape()[3],
        );
        if stride.0 == 0 || stride.1 == 0 {
            bail_shape!("stride must be positive");
        }
        // W[k][m] with k = (a·KW + b)·C + ch — matches the patch-row order.
        let k_total = kh * kw * c;
        let mut wt = vec![0.0f32; k_total * m];
        for mi in 0..m {
            for a in 0..kh {
                for b in 0..kw {
                    for ch in 0..c {
                        let k = (a * kw + b) * c + ch;
                        wt[k * m + mi] = weights.at4(mi, a, b, ch);
                    }
                }
            }
        }
        Ok(Im2RowConvolution {
            cin: c,
            cout: m,
            kernel: (kh, kw),
            stride,
            pad,
            wt_packed: PackedB::pack(&wt, m, k_total, m),
        })
    }

    /// Output spatial size for an `h×w` input.
    pub fn output_hw(&self, h: usize, w: usize) -> Result<(usize, usize)> {
        let (kh, kw) = self.kernel;
        let (ph, pw) = self.pad;
        let (sh, sw) = self.stride;
        if h + 2 * ph < kh || w + 2 * pw < kw {
            bail_shape!("input {h}x{w} (pad {ph},{pw}) smaller than filter {kh}x{kw}");
        }
        Ok(((h + 2 * ph - kh) / sh + 1, (w + 2 * pw - kw) / sw + 1))
    }

    /// Patch-matrix elements for an `[n, h, w, C]` input: `N·OH·OW·KH·KW·C`.
    fn patch_elems_for(&self, n: usize, h: usize, w: usize) -> Result<usize> {
        let (oh, ow) = self.output_hw(h, w)?;
        Ok(n * oh * ow * self.kernel.0 * self.kernel.1 * self.cin)
    }

    /// Elements of workspace-owned padded-input staging one inference over
    /// an `[n, h, w, C]` input borrows — 0 for unpadded layers.
    pub fn staging_elems_for(&self, n: usize, h: usize, w: usize) -> usize {
        let (ph, pw) = self.pad;
        if ph == 0 && pw == 0 {
            0
        } else {
            n * (h + 2 * ph) * (w + 2 * pw) * self.cin
        }
    }

    /// Workspace elements ([`f32`]s) one inference over an `[n, h, w, C]`
    /// input borrows from the arena — the full patch matrix plus, for
    /// padded layers, the padded-input staging buffer.
    pub fn workspace_elems_for(&self, n: usize, h: usize, w: usize) -> Result<usize> {
        Ok(self.patch_elems_for(n, h, w)? + self.staging_elems_for(n, h, w))
    }

    /// Fill a caller-provided patch matrix `[N·OH·OW, KH·KW·C]` from the
    /// **already padded** source view.
    fn fill_patches(
        &self,
        src: &TensorView,
        n: usize,
        oh: usize,
        ow: usize,
        pool: Option<&ThreadPool>,
        patches: &mut [f32],
    ) {
        let c = self.cin;
        let (kh, kw) = self.kernel;
        let (sh, sw) = self.stride;
        let k_total = kh * kw * c;
        let rows = n * oh * ow;
        debug_assert_eq!(patches.len(), rows * k_total);
        let p_addr = patches.as_mut_ptr() as usize;
        let fill_row = |row: usize| {
            let b = row / (oh * ow);
            let rem = row % (oh * ow);
            let (oy, ox) = (rem / ow, rem % ow);
            let (y0, x0) = (oy * sh, ox * sw);
            // SAFETY: each row writes its own k_total slice.
            let dst: &mut [f32] = unsafe {
                std::slice::from_raw_parts_mut((p_addr as *mut f32).add(row * k_total), k_total)
            };
            for a in 0..kh {
                for bx in 0..kw {
                    let px = src.pixel(b, y0 + a, x0 + bx);
                    let off = (a * kw + bx) * c;
                    dst[off..off + c].copy_from_slice(px);
                }
            }
        };
        match pool {
            Some(pool) => pool.parallel_for(rows, fill_row),
            None => (0..rows).for_each(fill_row),
        }
    }

    /// Build the patch matrix `[N·OH·OW, KH·KW·C]` as a fresh vector.
    pub fn im2row(&self, input: &Tensor, pool: Option<&ThreadPool>) -> Result<Vec<f32>> {
        let (n, h, w, c) = (
            input.shape()[0],
            input.shape()[1],
            input.shape()[2],
            input.shape()[3],
        );
        if c != self.cin {
            bail_shape!("input has {c} channels, weights expect {}", self.cin);
        }
        let (oh, ow) = self.output_hw(h, w)?;
        let (ph, pw) = self.pad;
        let mut patches = vec![0.0f32; self.patch_elems_for(n, h, w)?];
        if ph == 0 && pw == 0 {
            self.fill_patches(&input.view(), n, oh, ow, pool, &mut patches);
        } else {
            let padded = input.pad_spatial(ph, ph, pw, pw);
            self.fill_patches(&padded.view(), n, oh, ow, pool, &mut patches);
        }
        Ok(patches)
    }

    /// Full convolution: im2row + one GEMM.
    ///
    /// Allocates a throwaway [`Workspace`]; hot loops should hold one and
    /// call [`run_with_workspace`](Self::run_with_workspace) so the im2row
    /// baseline stays apples-to-apples with the arena-backed Winograd path.
    pub fn run(&self, input: &Tensor, pool: Option<&ThreadPool>) -> Result<Tensor> {
        let mut ws = Workspace::new();
        self.run_with_workspace(input, pool, &mut ws)
    }

    /// [`run`](Self::run) drawing the patch matrix from a caller-owned
    /// arena — no heap allocation beyond the output tensor (and the padded
    /// input copy, when the layer pads) once the arena is at size.
    pub fn run_with_workspace(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
        ws: &mut Workspace,
    ) -> Result<Tensor> {
        self.run_fused_with(input, pool, None, Activation::None, ws)
    }

    /// [`run_with_workspace`](Self::run_with_workspace) with per-output-
    /// channel bias and optional activation fused into the GEMM's [`BiasAct`]
    /// epilogue. Thin allocating wrapper over
    /// [`run_fused_into`](Self::run_fused_into) — kept as the oracle the
    /// write-into path is property-tested against.
    pub fn run_fused_with(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
    ) -> Result<Tensor> {
        if input.rank() != 4 {
            bail_shape!("input must be [N, H, W, C], got {:?}", input.shape());
        }
        let (n, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
        let (oh, ow) = self.output_hw(h, w)?;
        let mut out = Tensor::zeros(&[n, oh, ow, self.cout]);
        self.run_fused_into(&input.view(), pool, bias, act, ws, out.data_mut())?;
        Ok(out)
    }

    /// The write-into pipeline: the padded input is staged into
    /// workspace-owned memory (no copy for unpadded layers), the patch
    /// matrix is drawn from the same arena, and the single fused GEMM
    /// (bias/activation in its [`BiasAct`] epilogue, every micro-tile
    /// biased/activated while cache-hot) lands the conv output directly in
    /// the caller-provided `out` slice (`N·OH·OW·M` elements, fully
    /// overwritten — dirty arena memory is fine). With a warm arena this
    /// path performs **zero heap allocation**.
    pub fn run_fused_into(
        &self,
        input: &TensorView,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
        out: &mut [f32],
    ) -> Result<()> {
        if input.rank() != 4 {
            bail_shape!("input must be [N, H, W, C], got {:?}", input.shape());
        }
        let (n, h, w, c) = (
            input.shape()[0],
            input.shape()[1],
            input.shape()[2],
            input.shape()[3],
        );
        if c != self.cin {
            bail_shape!("input has {c} channels, weights expect {}", self.cin);
        }
        if let Some(b) = bias {
            if b.len() != self.cout {
                bail_shape!("bias length {} vs {} output channels", b.len(), self.cout);
            }
        }
        let (oh, ow) = self.output_hw(h, w)?;
        if out.len() != n * oh * ow * self.cout {
            bail_shape!(
                "output slice has {} elems, layer writes {}",
                out.len(),
                n * oh * ow * self.cout
            );
        }
        let rows = n * oh * ow;
        let k_total = self.kernel.0 * self.kernel.1 * self.cin;
        let (ph, pw) = self.pad;
        let (staging, patches) =
            ws.split2(self.staging_elems_for(n, h, w), self.patch_elems_for(n, h, w)?);
        let pshape = [n, h + 2 * ph, w + 2 * pw, c];
        let stage_t = crate::trace::begin();
        if staging.is_empty() {
            self.fill_patches(input, n, oh, ow, pool, patches);
        } else {
            input.pad_spatial_into(ph, ph, pw, pw, staging);
            let padded = TensorView::new(&pshape, staging)?;
            self.fill_patches(&padded, n, oh, ow, pool, patches);
        }
        crate::trace::end_stage(stage_t, crate::trace::Stage::Pack, crate::trace::AlgoCode::Im2Row);
        let stage_t = crate::trace::begin();
        sgemm_prepacked_fused(
            rows,
            patches,
            k_total,
            &self.wt_packed,
            out,
            self.cout,
            false,
            pool,
            &BiasAct { bias, act },
        );
        crate::trace::end_stage(stage_t, crate::trace::Stage::Gemm, crate::trace::AlgoCode::Im2Row);
        Ok(())
    }
}

impl Im2RowConvolution {
    /// Allocating twin of
    /// [`run_fused_batched_into`](Self::run_fused_batched_into) — the
    /// oracle its batched-vs-sequential property tests compare against.
    #[allow(clippy::too_many_arguments)]
    pub fn run_fused_batched_with(
        &self,
        batch: &Tensor,
        nb: usize,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
    ) -> Result<Tensor> {
        if batch.rank() != 4 {
            bail_shape!("batch must be [NB, H, W, C], got {:?}", batch.shape());
        }
        let (h, w) = (batch.shape()[1], batch.shape()[2]);
        let (oh, ow) = self.output_hw(h, w)?;
        let mut out = Tensor::zeros(&[batch.shape()[0], oh, ow, self.cout]);
        self.run_fused_batched_into(&batch.view(), nb, pool, bias, act, ws, out.data_mut())?;
        Ok(out)
    }

    /// Batched write-into entry point: `nb` frames gathered contiguously as
    /// one `[nb, H, W, C]` view execute in a single pass. The packed-B
    /// weight panels (built once at prepare time, batch-invariant) are
    /// traversed **once** per layer while the packed-A patch matrix carries
    /// `nb`× the rows — the batched-GEMM amortization lever. Each output
    /// row's k-accumulation is independent of how many rows share the
    /// sweep, so the result is **bit-identical** to running the frames one
    /// at a time. Allocation-free with a warm arena
    /// (statcheck-registered).
    #[allow(clippy::too_many_arguments)]
    pub fn run_fused_batched_into(
        &self,
        batch: &TensorView,
        nb: usize,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
        out: &mut [f32],
    ) -> Result<()> {
        crate::conv::check_batch_dim(batch, nb)?;
        self.run_fused_into(batch, pool, bias, act, ws, out)
    }
}

/// One-shot convenience wrapper.
pub fn im2row_conv2d(
    input: &Tensor,
    weights: &Tensor,
    stride: (usize, usize),
    pad: (usize, usize),
    pool: Option<&ThreadPool>,
) -> Result<Tensor> {
    Im2RowConvolution::new(weights, stride, pad)?.run(input, pool)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::direct::direct_conv2d;

    fn check(n: usize, h: usize, w: usize, c: usize, m: usize, k: (usize, usize), s: (usize, usize), p: (usize, usize)) {
        let input = Tensor::randn(&[n, h, w, c], (h * w) as u64);
        let weights = Tensor::randn(&[m, k.0, k.1, c], (c * m) as u64);
        let got = im2row_conv2d(&input, &weights, s, p, None).unwrap();
        let want = direct_conv2d(&input, &weights, s, p).unwrap();
        assert_eq!(got.shape(), want.shape());
        assert!(
            got.allclose(&want, 1e-4),
            "mismatch k={k:?} s={s:?} p={p:?}: {}",
            crate::util::rel_error(got.data(), want.data())
        );
    }

    #[test]
    fn matches_direct_3x3() {
        check(1, 8, 8, 4, 8, (3, 3), (1, 1), (1, 1));
        check(2, 7, 9, 3, 5, (3, 3), (1, 1), (0, 0));
    }

    #[test]
    fn matches_direct_strided() {
        check(1, 11, 11, 3, 4, (3, 3), (2, 2), (1, 1));
        check(1, 224 / 4, 224 / 4, 3, 8, (7, 7), (2, 2), (3, 3));
    }

    #[test]
    fn matches_direct_1x1_and_1d() {
        check(1, 6, 6, 8, 4, (1, 1), (1, 1), (0, 0));
        check(1, 6, 12, 4, 4, (1, 7), (1, 1), (0, 3));
        check(1, 12, 6, 4, 4, (7, 1), (1, 1), (3, 0));
    }

    #[test]
    fn matches_direct_5x5() {
        check(1, 10, 10, 3, 6, (5, 5), (1, 1), (2, 2));
    }

    /// The batched contract: one `[nb, H, W, C]` gathered walk through
    /// `run_fused_batched_into` is **bit-identical** to `nb` sequential
    /// batch-1 `run_fused_into` walks over the same frames — each output
    /// row's k-accumulation is independent of how many frames ride the
    /// GEMM — across ragged shapes × {none, bias, bias+ReLU} epilogues,
    /// written into NaN-poisoned buffers, and to its allocating twin.
    #[test]
    fn property_batched_matches_sequential_bitwise() {
        use crate::conv::Activation;
        use crate::testkit::{check as prop, Gen};
        prop("im2row batched == nb × batch-1", 32, |g: &mut Gen| {
            let nb = g.usize_in(2, 5);
            let c = g.usize_in(1, 9);
            let m = g.usize_in(1, 13);
            let h = g.usize_in(3, 9);
            let w = g.usize_in(3, 9);
            let input =
                Tensor::from_vec(&[nb, h, w, c], g.normal_vec(nb * h * w * c)).unwrap();
            let weights = Tensor::from_vec(&[m, 3, 3, c], g.normal_vec(m * 9 * c)).unwrap();
            let bias: Vec<f32> = g.normal_vec(m);
            let (bias_opt, act) = match g.usize_in(0, 2) {
                0 => (None, Activation::None),
                1 => (Some(bias.as_slice()), Activation::None),
                _ => (Some(bias.as_slice()), Activation::Relu),
            };
            let conv = Im2RowConvolution::new(&weights, (1, 1), (1, 1)).unwrap();
            let mut ws = Workspace::new();
            let frame = h * w * c;
            let mut want: Vec<f32> = Vec::new();
            for f in 0..nb {
                let ft = Tensor::from_vec(
                    &[1, h, w, c],
                    input.data()[f * frame..(f + 1) * frame].to_vec(),
                )
                .unwrap();
                want.extend_from_slice(
                    conv.run_fused_with(&ft, None, bias_opt, act, &mut ws).unwrap().data(),
                );
            }
            let mut got = vec![f32::NAN; want.len()];
            conv.run_fused_batched_into(&input.view(), nb, None, bias_opt, act, &mut ws, &mut got)
                .unwrap();
            let twin =
                conv.run_fused_batched_with(&input, nb, None, bias_opt, act, &mut ws).unwrap();
            got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits())
                && got == *twin.data()
        });
    }

    /// A batched entry declared for `nb` frames rejects a view carrying a
    /// different leading dimension instead of silently misreading rows.
    #[test]
    fn batched_rejects_frame_count_mismatch() {
        use crate::conv::Activation;
        let weights = Tensor::randn(&[4, 3, 3, 2], 5);
        let conv = Im2RowConvolution::new(&weights, (1, 1), (1, 1)).unwrap();
        let input = Tensor::randn(&[3, 6, 6, 2], 6);
        let mut ws = Workspace::new();
        let mut out = vec![0.0; 2 * 6 * 6 * 4];
        let r = conv.run_fused_batched_into(
            &input.view(),
            2,
            None,
            None,
            Activation::None,
            &mut ws,
            &mut out,
        );
        assert!(r.is_err(), "nb = 2 must reject a 3-frame view");
    }

    #[test]
    fn parallel_matches_serial() {
        let pool = ThreadPool::new(4);
        let input = Tensor::randn(&[1, 16, 16, 8], 1);
        let weights = Tensor::randn(&[16, 3, 3, 8], 2);
        let a = im2row_conv2d(&input, &weights, (1, 1), (1, 1), None).unwrap();
        let b = im2row_conv2d(&input, &weights, (1, 1), (1, 1), Some(&pool)).unwrap();
        assert!(b.allclose(&a, 1e-6));
    }

    #[test]
    fn workspace_reused_across_runs() {
        let weights = Tensor::randn(&[8, 3, 3, 4], 9);
        let conv = Im2RowConvolution::new(&weights, (1, 1), (1, 1)).unwrap();
        let mut ws = Workspace::new();
        let mut outs = Vec::new();
        for seed in 0..3u64 {
            let input = Tensor::randn(&[1, 10, 10, 4], seed + 1);
            outs.push(conv.run_with_workspace(&input, None, &mut ws).unwrap());
        }
        assert_eq!(ws.grow_count(), 1, "patch matrix drawn from one arena");
        assert_eq!(
            ws.high_water_elems(),
            conv.workspace_elems_for(1, 10, 10).unwrap(),
            "sizing formula matches actual borrow"
        );
        // Same numbers as the allocating path.
        let input = Tensor::randn(&[1, 10, 10, 4], 1);
        let plain = conv.run(&input, None).unwrap();
        assert!(outs[0].allclose(&plain, 1e-6));
    }

    /// The fused bias+ReLU epilogue must equal a separate post pass (and
    /// reject a bad bias length).
    #[test]
    fn fused_bias_relu_matches_post_pass() {
        let weights = Tensor::randn(&[6, 3, 3, 4], 11);
        let conv = Im2RowConvolution::new(&weights, (1, 1), (1, 1)).unwrap();
        let input = Tensor::randn(&[1, 9, 9, 4], 12);
        let bias: Vec<f32> = (0..6).map(|i| i as f32 * 0.3 - 0.7).collect();
        let mut ws = Workspace::new();
        let fused = conv
            .run_fused_with(&input, None, Some(&bias), Activation::Relu, &mut ws)
            .unwrap();
        let mut want = conv.run(&input, None).unwrap();
        let chans = want.shape()[3];
        for (i, v) in want.data_mut().iter_mut().enumerate() {
            *v = (*v + bias[i % chans]).max(0.0);
        }
        assert!(fused.allclose(&want, 1e-5));
        assert!(conv
            .run_fused_with(&input, None, Some(&bias[..5]), Activation::None, &mut ws)
            .is_err());
    }

    /// The write-into path into an offset window of a dirty buffer must be
    /// bit-identical to the allocating wrapper, padded and strided alike.
    #[test]
    fn write_into_matches_allocating_bitwise() {
        for (k, s, p) in [((3, 3), (1, 1), (1, 1)), ((3, 3), (2, 2), (0, 0)), ((1, 7), (1, 1), (0, 3))] {
            let weights = Tensor::randn(&[6, k.0, k.1, 4], 21);
            let conv = Im2RowConvolution::new(&weights, s, p).unwrap();
            let input = Tensor::randn(&[2, 11, 13, 4], 22);
            let bias: Vec<f32> = (0..6).map(|i| i as f32 * 0.2 - 0.5).collect();
            let mut ws_a = Workspace::new();
            let mut ws_b = Workspace::new();
            let want = conv
                .run_fused_with(&input, None, Some(&bias), Activation::Relu, &mut ws_a)
                .unwrap();
            let off = 5usize;
            let mut backing = vec![f32::NAN; want.len() + off];
            conv.run_fused_into(
                &input.view(),
                None,
                Some(&bias),
                Activation::Relu,
                &mut ws_b,
                &mut backing[off..],
            )
            .unwrap();
            assert_eq!(&backing[off..], want.data(), "k={k:?} s={s:?} p={p:?}");
            assert!(backing[..off].iter().all(|x| x.is_nan()));
            // Wrong-size output slices are rejected.
            assert!(conv
                .run_fused_into(&input.view(), None, None, Activation::None, &mut ws_b, &mut backing[..3])
                .is_err());
        }
    }

    #[test]
    fn patch_matrix_layout() {
        // 1×1 input region, 1 channel: patch row equals flattened kernel window.
        let input = Tensor::from_vec(&[1, 3, 3, 1], (1..=9).map(|x| x as f32).collect()).unwrap();
        let weights = Tensor::randn(&[1, 3, 3, 1], 1);
        let conv = Im2RowConvolution::new(&weights, (1, 1), (0, 0)).unwrap();
        let patches = conv.im2row(&input, None).unwrap();
        assert_eq!(patches, (1..=9).map(|x| x as f32).collect::<Vec<_>>());
    }

    #[test]
    fn rejects_bad_shapes() {
        let weights = Tensor::randn(&[4, 3, 3, 2], 1);
        let conv = Im2RowConvolution::new(&weights, (1, 1), (0, 0)).unwrap();
        let too_small = Tensor::zeros(&[1, 2, 2, 2]);
        assert!(conv.run(&too_small, None).is_err());
        let wrong_c = Tensor::zeros(&[1, 5, 5, 3]);
        assert!(conv.run(&wrong_c, None).is_err());
        assert!(Im2RowConvolution::new(&weights, (0, 1), (0, 0)).is_err());
    }
}
