//! # winoconv — region-wise multi-channel Winograd / Cook-Toom convolution
//!
//! A reproduction of *"Efficient Winograd or Cook-Toom Convolution Kernel
//! Implementation on Widely Used Mobile CPUs"* (Maji et al., 2019) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! The crate is organised bottom-up:
//!
//! * [`simd`] — a 4-lane `f32` vector mirroring the ARMv8-A NEON op set
//!   used by the paper's hand-coded transforms: real NEON intrinsics on
//!   `aarch64`, a portable array backend elsewhere, one parity-pinned API.
//! * [`tensor`] — NHWC/NCHW 4-D tensors and layout conversion (§2.1 of the
//!   paper studies exactly this choice).
//! * [`gemm`] — a packed, blocked GEMM with a SIMD micro-kernel plus the
//!   fusion hooks both conv schemes build on: packed-A written directly by
//!   producers (transform-as-pack) and per-micro-tile [`gemm::Epilogue`]s
//!   (bias/ReLU, inverse-transform gather) fired while C is cache-hot.
//! * [`trace`] — zero-steady-state-allocation span tracing: a pre-allocated
//!   lock-free slot buffer the planned executor (layer spans), the engines
//!   (pack/transform/GEMM stage spans) and the coordinator dispatcher
//!   (serve spans) record into, with a per-layer roofline profile
//!   ([`trace::roofline`], the `winoconv profile` subcommand) and a
//!   chrome://tracing exporter on top.
//! * [`workspace`] — the reusable per-thread arena type backing both of the
//!   engine's memory pools: conv scratch (packed-A / patch matrix /
//!   padded-input staging, sized to the largest layer) and the planned
//!   activation arena (sized to [`nn::ActivationPlan::peak_elems`]), so a
//!   warm steady-state inference performs zero heap allocation end to end.
//! * [`winograd`] — the paper's contribution: Cook-Toom transform generation,
//!   hard-coded fast transforms for the five variants, and the **region-
//!   blocked, fused** region-wise multi-channel pipeline — transform-as-pack
//!   → x² GEMMs + gather-as-epilogue (blocks of regions sized to an L2
//!   budget, default 512 KiB; Winograd-domain C never materialised).
//! * [`im2row`] — the classical im2row/im2col + GEMM comparator.
//! * [`quant`] — the int8 inference subsystem: dynamic-range activation /
//!   per-channel weight quantization, a u8×i8→i32 GEMM micro-kernel behind
//!   the same [`simd`] parity contract, dequantize/requantize epilogues and
//!   int8 twins of the im2row, depthwise and pointwise engines (Winograd
//!   stays f32-only — its transforms need subtractive headroom int8 lacks).
//! * [`conv`] — the public convolution API, direct-convolution oracle
//!   (dense and grouped), the **direct depthwise engine**
//!   ([`conv::depthwise`]: register-tiled 3×3 stride-1/2 SIMD kernels for
//!   the `groups == cin == cout` regime where Winograd's amortization
//!   argument collapses) and the unified spatial-aware per-layer algorithm
//!   selector.
//! * [`nn`] / [`zoo`] — a small graph executor (with a prepare-time
//!   activation memory planner, a planned write-into walk and per-algorithm
//!   dispatch counters) and definitions of the evaluated CNNs: the paper's
//!   five (VGG-16/19, GoogleNet, Inception-v3, SqueezeNet) plus
//!   MobileNetV1/V2 (depthwise-separable, ReLU6, inverted residuals).
//! * [`coordinator`] — the L3 serving runtime: request queue, batcher,
//!   worker pool and metrics.
//! * [`runtime`] — PJRT loader that executes the JAX/Pallas-lowered HLO
//!   artifacts for cross-validation (behind the `pjrt` cargo feature; a
//!   stub that reports `Error::Runtime` ships for offline builds).
//! * [`bench`] — the statistical benchmarking harness and the table printers
//!   that regenerate the paper's Tables 1–2 and Figure 3.
//! * [`parallel`], [`util`], [`testkit`] — threadpool, RNG/CLI/stats
//!   helpers and a tiny property-testing framework (the crate builds fully
//!   offline, so these substrates are in-repo rather than external deps).
//!
//! ## Quickstart
//!
//! ```no_run
//! use winoconv::conv::{Conv2d, ConvAlgorithm};
//! use winoconv::tensor::Tensor;
//!
//! // A 3×3 convolution over a 32-channel 56×56 NHWC input, 64 filters.
//! let conv = Conv2d::new(32, 64, (3, 3)).with_algorithm(ConvAlgorithm::WINOGRAD_F4X4_3X3);
//! let x = Tensor::randn(&[1, 56, 56, 32], 42);
//! let w = conv.random_weights(7);
//! let y = conv.run(&x, &w).unwrap();
//! assert_eq!(y.shape(), &[1, 54, 54, 64]);
//! ```
//!
//! The structural invariants behind all of this — documented `unsafe`,
//! allocation-free hot paths, SIMD backend and `*_into` entry-point parity,
//! registered build targets — are enforced statically by [`analysis`] via
//! the `statcheck` binary (first fatal step of `ci.sh`).

#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod util;
pub mod simd;
pub mod tensor;
pub mod parallel;
pub mod gemm;
pub mod workspace;
pub mod trace;
pub mod winograd;
pub mod im2row;
pub mod quant;
pub mod conv;
pub mod nn;
pub mod zoo;
pub mod coordinator;
pub mod runtime;
pub mod bench;
pub mod testkit;

/// Crate-wide result type.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error type.
#[derive(Debug)]
pub enum Error {
    /// Shape or layout mismatch between tensors/operands.
    Shape(String),
    /// Unsupported configuration (e.g. Winograd on stride-2).
    Unsupported(String),
    /// Failure in the PJRT runtime layer.
    Runtime(String),
    /// Invalid CLI or config input.
    Config(String),
    /// I/O failure (artifact files, traces).
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Unsupported(m) => write!(f, "unsupported: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[macro_export]
/// `bail_shape!("...")` — early-return a [`Error::Shape`] with formatting.
macro_rules! bail_shape {
    ($($arg:tt)*) => {
        return Err($crate::Error::Shape(format!($($arg)*)))
    };
}

#[macro_export]
/// `bail_unsupported!("...")` — early-return a [`Error::Unsupported`].
macro_rules! bail_unsupported {
    ($($arg:tt)*) => {
        return Err($crate::Error::Unsupported(format!($($arg)*)))
    };
}
