//! `winoconv` CLI — the leader entrypoint.
//!
//! Subcommands:
//! * `layers --model <name>`   — per-layer im2row vs Winograd comparison
//!   (Table 2 rows for one model).
//! * `network --model <name>`  — whole-network runtime under both schemes
//!   (Table 1 row for one model).
//! * `serve --model <name>`    — run the serving coordinator on synthetic
//!   frames and print latency/throughput metrics.
//! * `profile --model <name>`  — traced planned walks reduced to a per-layer
//!   roofline table (FLOPs, bytes, GFLOP/s, arithmetic intensity, % time);
//!   `--chrome <path>` dumps the raw spans as chrome://tracing JSON.
//! * `verify`                  — cross-check the Rust engine against the
//!   AOT JAX/Pallas artifacts via PJRT.
//! * `variants`                — list shipped Winograd variants and their
//!   theoretical speedups.

use std::time::{Duration, Instant};
use winoconv::bench::workloads::unique_fast_layers;
use winoconv::bench::{measure, ms, speedup, BenchConfig, Table};
use winoconv::coordinator::{EngineConfig, InferenceEngine};
use winoconv::im2row::Im2RowConvolution;
use winoconv::nn::{PreparedModel, Scheme};
use winoconv::parallel::ThreadPool;
use winoconv::quant::Dtype;
use winoconv::tensor::{Tensor, TensorView};
use winoconv::trace::{self, roofline};
use winoconv::util::cli::Args;
use winoconv::winograd::{WinogradConvolution, WinogradVariant};
use winoconv::workspace::Workspace;
use winoconv::zoo::ModelKind;
use winoconv::{conv::select::select_variant_spatial, Error, Result};

fn main() {
    let args = match Args::from_env(&["help", "quick"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let sub = match args.subcommand() {
        Some(s) if !args.flag("help") => s,
        _ => {
            print_help();
            return;
        }
    };
    let result = match sub {
        "layers" => cmd_layers(&args),
        "network" => cmd_network(&args),
        "serve" => cmd_serve(&args),
        "profile" => cmd_profile(&args),
        "verify" => cmd_verify(&args),
        "variants" => cmd_variants(),
        other => Err(Error::Config(format!("unknown subcommand {other:?}"))),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "winoconv — region-wise multi-channel Winograd/Cook-Toom convolution engine\n\
         \n\
         USAGE: winoconv <subcommand> [options]\n\
         \n\
         SUBCOMMANDS\n\
         \x20 layers   --model <vgg16|vgg19|googlenet|inception-v3|squeezenet|mobilenet-v1|mobilenet-v2|resnet-18|resnet-50> [--threads N] [--quick]\n\
         \x20 network  --model <name> [--threads N] [--reps N] [--batch N] [--dtype f32|int8] [--quick]\n\
         \x20 serve    --model <name> [--threads N] [--seconds S]\n\
         \x20 profile  --model <name> [--threads N] [--walks N] [--dtype f32|int8] [--chrome FILE] [--quick]\n\
         \x20 verify   [--artifacts DIR]\n\
         \x20 variants"
    );
}

fn parse_model(args: &Args) -> Result<ModelKind> {
    let name = args.get_or("model", "squeezenet");
    ModelKind::parse(&name).ok_or_else(|| Error::Config(format!("unknown model {name:?}")))
}

fn bench_config(args: &Args) -> BenchConfig {
    if args.flag("quick") {
        BenchConfig::quick()
    } else {
        BenchConfig::from_env()
    }
}

/// Per-layer comparison (Table 2 rows for one model).
fn cmd_layers(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let threads: usize = args.get_parse_or("threads", 4)?;
    let pool = ThreadPool::new(threads);
    let cfg = bench_config(args);

    let layers = unique_fast_layers(model, 1)?;
    if layers.is_empty() {
        println!(
            "{model} has no Winograd-suitable (fast) layers — its convs are grouped, \
             strided or 1x1. For depthwise layers see: cargo bench --bench \
             ablation_depthwise -- --model {}",
            model.name()
        );
        return Ok(());
    }
    let mut table = Table::new(
        &format!("{model}: per-layer im2row vs region-wise Winograd ({threads} threads)"),
        &["layer", "type", "shape", "im2row ms", "ours ms", "speedup", "variant"],
    );
    for (spec, count) in layers {
        let input = spec.input(11);
        let weights = spec.weights(12);
        let im2row = Im2RowConvolution::new(&weights, spec.stride, spec.pad)?;
        let oh = spec.input_shape[1] + 2 * spec.pad.0 - spec.kernel.0 + 1;
        let ow = spec.input_shape[2] + 2 * spec.pad.1 - spec.kernel.1 + 1;
        let variant = select_variant_spatial(spec.kernel, oh, ow)
            .ok_or_else(|| Error::Unsupported(format!("no variant for {:?}", spec.kernel)))?;
        let wino = WinogradConvolution::new(variant, &weights, spec.pad)?;

        let base = measure(&cfg, || {
            let _ = im2row.run(&input, Some(&pool)).unwrap();
        });
        let ours = measure(&cfg, || {
            let _ = wino.run(&input, Some(&pool)).unwrap();
        });
        let label = if count > 1 {
            format!("{} (x{count})", spec.name)
        } else {
            spec.name.clone()
        };
        table.row(&[
            label,
            spec.layer_type(),
            format!(
                "{}x{}x{} -> {}",
                spec.input_shape[1], spec.input_shape[2], spec.cin, spec.cout
            ),
            ms(base.median),
            ms(ours.median),
            speedup(base.median, ours.median),
            variant.name().to_string(),
        ]);
    }
    table.print();
    Ok(())
}

/// Whole-network comparison (Table 1 row for one model). With `--batch N`
/// (N > 1) the comparison runs the batched planned path instead: one
/// shared-weight-panel sweep over all N frames per walk, reported per batch
/// and amortised per frame.
fn cmd_network(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let threads: usize = args.get_parse_or("threads", 4)?;
    let reps: usize = args.get_parse_or("reps", if args.flag("quick") { 2 } else { 5 })?;
    let dtype: Dtype = args.get_parse_or("dtype", Dtype::F32)?;
    let batch: usize = args.get_parse_or("batch", 1)?;
    if batch == 0 {
        return Err(Error::Config("--batch must be at least 1".into()));
    }
    let pool = ThreadPool::new(threads);
    let graph = model.build(1)?;
    if batch > 1 {
        return network_batched(model, &graph, dtype, batch, reps, &pool, threads);
    }
    let input = Tensor::randn(&model.input_shape(1), 99);

    let mut table = Table::new(
        &format!(
            "{model}: whole-network runtime, batch 1, {threads} threads, {dtype} (mean of {reps})"
        ),
        &["scheme", "full net ms", "fast layers ms", "other ms"],
    );
    for scheme in [Scheme::Im2RowOnly, Scheme::WinogradWhereSuitable] {
        let prepared =
            PreparedModel::prepare_with_dtype(model.name(), &graph, input.shape(), scheme, dtype)?;
        let _ = prepared.run(&input, Some(&pool))?; // warm-up
        let mut total = 0.0f64;
        let mut fast = 0.0f64;
        for _ in 0..reps {
            let t0 = Instant::now();
            let (_, timings) = prepared.run(&input, Some(&pool))?;
            total += t0.elapsed().as_nanos() as f64;
            fast += timings
                .iter()
                .filter(|t| t.fast_layer)
                .map(|t| t.ns as f64)
                .sum::<f64>();
        }
        total /= reps as f64;
        fast /= reps as f64;
        table.row(&[scheme.to_string(), ms(total), ms(fast), ms(total - fast)]);
    }
    table.print();
    Ok(())
}

/// `network --batch N`: one batched planned walk sweeps all N frames
/// through each layer's shared weight panel; the per-frame column shows the
/// panel-streaming amortisation vs N independent batch-1 walks.
fn network_batched(
    model: ModelKind,
    graph: &winoconv::nn::Graph,
    dtype: Dtype,
    batch: usize,
    reps: usize,
    pool: &ThreadPool,
    threads: usize,
) -> Result<()> {
    let shape = model.input_shape(1);
    let mut table = Table::new(
        &format!(
            "{model}: whole-network runtime, batch {batch}, {threads} threads, {dtype} \
             (mean of {reps})"
        ),
        &["scheme", "batch ms", "per-frame ms"],
    );
    for scheme in [Scheme::Im2RowOnly, Scheme::WinogradWhereSuitable] {
        let prepared =
            PreparedModel::prepare_with_dtype(model.name(), graph, &shape, scheme, dtype)?;
        let plan = prepared.prepare_batched(batch)?;
        let input = Tensor::randn(plan.input_shape(), 99);
        let mut ws = Workspace::with_capacity(plan.workspace_elems());
        let mut acts = Workspace::with_capacity(plan.peak_elems());
        let mut out = vec![f32::NAN; plan.output_shape().iter().product()];
        let view = TensorView::new(plan.input_shape(), input.data())?;
        prepared.run_planned_batched_into(&plan, &view, Some(pool), &mut ws, &mut acts, &mut out)?; // warm-up
        let mut total = 0.0f64;
        for _ in 0..reps {
            let t0 = Instant::now();
            prepared.run_planned_batched_into(
                &plan,
                &view,
                Some(pool),
                &mut ws,
                &mut acts,
                &mut out,
            )?;
            total += t0.elapsed().as_nanos() as f64;
        }
        total /= reps as f64;
        table.row(&[scheme.to_string(), ms(total), ms(total / batch as f64)]);
    }
    table.print();
    Ok(())
}

/// Run the serving coordinator for a while and report metrics.
fn cmd_serve(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let threads: usize = args.get_parse_or("threads", 4)?;
    let seconds: u64 = args.get_parse_or("seconds", 10)?;
    let graph = model.build(1)?;
    let shape = model.input_shape(1);
    let prepared =
        PreparedModel::prepare(model.name(), &graph, &shape, Scheme::WinogradWhereSuitable)?;
    println!("serving {model} on {threads} threads for {seconds}s ...");
    let engine = InferenceEngine::start(
        prepared,
        EngineConfig {
            threads,
            ..EngineConfig::default()
        },
    );
    let deadline = Instant::now() + Duration::from_secs(seconds);
    let mut frame = 0u64;
    while Instant::now() < deadline {
        let input = Tensor::randn(&shape, frame);
        let _ = engine.infer(input)?;
        frame += 1;
    }
    println!("{}", engine.metrics().report());
    engine.shutdown();
    Ok(())
}

/// Traced planned walks over one model, reduced to the per-layer roofline
/// table: FLOPs and bytes from prepare-time geometry, nanoseconds from the
/// layer spans the walk records into the pre-reserved trace ring — the
/// walks themselves stay allocation-free. `--chrome <path>` additionally
/// dumps the raw spans (layer + engine-stage lanes) as chrome://tracing
/// JSON.
fn cmd_profile(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let threads: usize = args.get_parse_or("threads", 4)?;
    let walks: usize = args.get_parse_or("walks", if args.flag("quick") { 2 } else { 8 })?;
    let dtype: Dtype = args.get_parse_or("dtype", Dtype::F32)?;
    if walks == 0 {
        return Err(Error::Config("--walks must be at least 1".into()));
    }
    let pool = ThreadPool::new(threads);
    let graph = model.build(1)?;
    let shape = model.input_shape(1);
    let prepared = PreparedModel::prepare_with_dtype(
        model.name(),
        &graph,
        &shape,
        Scheme::WinogradWhereSuitable,
        dtype,
    )?;
    let input = Tensor::randn(&shape, 7);
    let mut ws = Workspace::with_capacity(prepared.workspace_elems());
    let mut acts = Workspace::with_capacity(prepared.activation_plan().peak_elems());
    let mut out = vec![f32::NAN; prepared.output_shape().iter().product()];
    // Warm-up untraced: page weights in and settle the arenas first so the
    // profile measures steady state.
    prepared.run_planned_into(&input, Some(&pool), &mut ws, &mut acts, &mut out)?;
    trace::reserve(walks * prepared.trace_spans_per_walk() + 64);
    trace::set_enabled(true);
    for _ in 0..walks {
        prepared.run_planned_into(&input, Some(&pool), &mut ws, &mut acts, &mut out)?;
    }
    trace::set_enabled(false);
    let spans = trace::take();
    let infos = prepared.layer_infos();
    let profiles = roofline::build_profiles(&infos, &spans);
    print!(
        "{}",
        roofline::render(
            &format!("{model}: per-layer roofline ({walks} walks, {threads} threads, {dtype})"),
            &profiles,
        )
    );
    if trace::dropped() > 0 {
        eprintln!("warning: {} spans dropped (trace ring full)", trace::dropped());
    }
    if let Some(path) = args.get("chrome") {
        let n_nodes = infos.iter().map(|i| i.node as usize + 1).max().unwrap_or(0);
        let mut names = vec![String::from("op"); n_nodes];
        for i in &infos {
            names[i.node as usize] = i.name.clone();
        }
        std::fs::write(path, trace::export_chrome(&spans, &names))
            .map_err(|e| Error::Config(format!("writing {path}: {e}")))?;
        println!("chrome trace written to {path} ({} spans)", spans.len());
    }
    Ok(())
}

/// Cross-validate against the AOT artifacts (same as examples/pjrt_verify).
fn cmd_verify(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    winoconv::runtime::verify::verify_all(std::path::Path::new(&dir), true)
}

fn cmd_variants() -> Result<()> {
    let mut table = Table::new(
        "Shipped Winograd/Cook-Toom variants",
        &["variant", "kernel", "out tile", "in tile", "GEMMs", "theoretical speedup"],
    );
    for v in WinogradVariant::ALL {
        let (kh, kw) = v.kernel();
        let (mh, mw) = v.out_tile();
        let (th, tw) = v.in_tile();
        table.row(&[
            v.name().to_string(),
            format!("{kh}x{kw}"),
            format!("{mh}x{mw}"),
            format!("{th}x{tw}"),
            v.gemm_count().to_string(),
            format!("{:.2}x", v.theoretical_speedup()),
        ]);
    }
    table.print();
    Ok(())
}
