//! A small inference graph (DAG) with shape inference and a **planned**
//! prepared executor.
//!
//! Models are built once (weights deterministic from a seed), then
//! **prepared** against an execution policy: every conv layer is bound to a
//! concrete algorithm (im2row baseline vs region-wise Winograd where
//! suitable) with its weights pre-transformed — mirroring how the paper's
//! two benchmark configurations are built (§3.2) — and every intermediate
//! activation is assigned an offset in a single activation arena by the
//! prepare-time planner ([`super::plan::ActivationPlan`]). Execution walks
//! the plan with borrowed arena views and the conv stack's write-into
//! entry points, so a warm steady-state inference performs **zero heap
//! allocation**; per-layer wall-clock is still recorded so the bench
//! harness can split "fast layers" from the rest (Table 1 / Figure 3).

use super::ops;
use super::plan::ActivationPlan;
use crate::conv::depthwise::DepthwiseConvolution;
use crate::conv::pointwise::PointwiseConvolution;
use crate::conv::select::is_winograd_suitable;
use crate::conv::{Activation, Conv2d, ConvAlgorithm};
use crate::im2row::Im2RowConvolution;
use crate::parallel::ThreadPool;
use crate::quant::{
    Dtype, QuantDepthwiseConvolution, QuantIm2RowConvolution, QuantPointwiseConvolution,
};
use crate::tensor::{Tensor, TensorView};
use crate::trace;
use crate::winograd::WinogradConvolution;
use crate::workspace::Workspace;
use crate::{bail_shape, bail_unsupported, Result};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Node identifier within a [`Graph`].
pub type NodeId = usize;

/// Graph operation.
#[derive(Debug, Clone)]
pub enum Op {
    /// Graph input placeholder.
    Input,
    /// Convolution (+ bias + optional fused activation).
    Conv {
        /// Layer descriptor (its algorithm field is ignored; the policy decides).
        desc: Conv2d,
        /// `[M, KH, KW, C/groups]` weights.
        weights: Tensor,
        /// Per-output-channel bias.
        bias: Vec<f32>,
        /// Fused activation after the bias (ReLU, or MobileNet's ReLU6).
        act: Activation,
    },
    /// Max pooling.
    MaxPool {
        /// Window.
        kernel: (usize, usize),
        /// Stride.
        stride: (usize, usize),
        /// Padding.
        pad: (usize, usize),
        /// Ceil-mode output size (Caffe legacy nets).
        ceil: bool,
    },
    /// Average pooling.
    AvgPool {
        /// Window.
        kernel: (usize, usize),
        /// Stride.
        stride: (usize, usize),
        /// Padding.
        pad: (usize, usize),
        /// Ceil-mode output size.
        ceil: bool,
    },
    /// Global average pooling to `[N,1,1,C]`.
    GlobalAvgPool,
    /// Channel concat of all inputs.
    Concat,
    /// Fully connected (+ optional ReLU).
    Fc {
        /// `[K, M]` weights.
        weights: Tensor,
        /// Bias of length M.
        bias: Vec<f32>,
        /// Fuse ReLU.
        relu: bool,
    },
    /// Row softmax (rank-2 input).
    Softmax,
    /// Local response normalisation (legacy GoogleNet).
    Lrn {
        /// Window size across channels.
        size: usize,
        /// Alpha.
        alpha: f32,
        /// Beta.
        beta: f32,
        /// K offset.
        k: f32,
    },
    /// Standalone ReLU6 clamp (conv layers fuse it via [`Activation`]
    /// instead; this node exists for graphs that clamp non-conv values).
    Relu6,
    /// Standalone ReLU — the activation a ResNet residual block applies
    /// *after* its skip-connection add (conv layers fuse their own ReLU via
    /// [`Activation`]; this node exists for post-add activations).
    Relu,
    /// Elementwise residual add of exactly two same-shape inputs — the
    /// MobileNetV2 inverted-residual / ResNet skip connection.
    Add,
}

impl Op {
    /// Short kind string for reports.
    pub fn kind(&self) -> &'static str {
        match self {
            Op::Input => "input",
            Op::Conv { .. } => "conv",
            Op::MaxPool { .. } => "maxpool",
            Op::AvgPool { .. } => "avgpool",
            Op::GlobalAvgPool => "gavgpool",
            Op::Concat => "concat",
            Op::Fc { .. } => "fc",
            Op::Softmax => "softmax",
            Op::Lrn { .. } => "lrn",
            Op::Relu6 => "relu6",
            Op::Relu => "relu",
            Op::Add => "add",
        }
    }
}

/// A named node and its input edges.
#[derive(Debug, Clone)]
pub struct Node {
    /// Human-readable layer name (paper-style, e.g. `conv3_2`).
    pub name: String,
    /// Operation.
    pub op: Op,
    /// Producer nodes.
    pub inputs: Vec<NodeId>,
}

/// An inference DAG in topological order (builders append producers before
/// consumers).
#[derive(Debug, Clone, Default)]
pub struct Graph {
    /// Nodes in topological order.
    pub nodes: Vec<Node>,
}

impl Graph {
    /// Empty graph.
    pub fn new() -> Graph {
        Graph::default()
    }

    /// Append a node; `inputs` must already exist.
    pub fn add(&mut self, name: &str, op: Op, inputs: &[NodeId]) -> NodeId {
        for &i in inputs {
            assert!(i < self.nodes.len(), "input {i} of node {name} not yet defined");
        }
        self.nodes.push(Node {
            name: name.to_string(),
            op,
            inputs: inputs.to_vec(),
        });
        self.nodes.len() - 1
    }

    /// Add the input placeholder (must be node 0).
    pub fn input(&mut self) -> NodeId {
        assert!(self.nodes.is_empty(), "input must be the first node");
        self.add("input", Op::Input, &[])
    }

    /// Number of convolution nodes.
    pub fn conv_count(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n.op, Op::Conv { .. })).count()
    }

    /// Infer every node's output shape from the graph-input shape.
    pub fn infer_shapes(&self, input_shape: &[usize]) -> Result<Vec<Vec<usize>>> {
        let mut shapes: Vec<Vec<usize>> = Vec::with_capacity(self.nodes.len());
        for node in &self.nodes {
            let shape = match &node.op {
                Op::Input => input_shape.to_vec(),
                Op::Conv { desc, .. } => desc.output_shape(&shapes[node.inputs[0]])?,
                Op::MaxPool { kernel, stride, pad, ceil }
                | Op::AvgPool { kernel, stride, pad, ceil } => {
                    let s = &shapes[node.inputs[0]];
                    let (h, w) = (s[1], s[2]);
                    if h + 2 * pad.0 < kernel.0 || w + 2 * pad.1 < kernel.1 {
                        bail_shape!("{}: pool window larger than input", node.name);
                    }
                    let span_h = h + 2 * pad.0 - kernel.0;
                    let span_w = w + 2 * pad.1 - kernel.1;
                    let (oh, ow) = if *ceil {
                        (span_h.div_ceil(stride.0) + 1, span_w.div_ceil(stride.1) + 1)
                    } else {
                        (span_h / stride.0 + 1, span_w / stride.1 + 1)
                    };
                    vec![s[0], oh, ow, s[3]]
                }
                Op::GlobalAvgPool => {
                    let s = &shapes[node.inputs[0]];
                    vec![s[0], 1, 1, s[3]]
                }
                Op::Concat => {
                    let first = shapes[node.inputs[0]].clone();
                    let mut c = 0;
                    for &i in &node.inputs {
                        let s = &shapes[i];
                        if s[0] != first[0] || s[1] != first[1] || s[2] != first[2] {
                            bail_shape!("{}: concat mismatch {:?} vs {:?}", node.name, s, first);
                        }
                        c += s[3];
                    }
                    vec![first[0], first[1], first[2], c]
                }
                Op::Fc { weights, .. } => {
                    let s = &shapes[node.inputs[0]];
                    let k: usize = s[1..].iter().product();
                    if weights.shape()[0] != k {
                        bail_shape!("{}: fc expects K={}, got {k}", node.name, weights.shape()[0]);
                    }
                    vec![s[0], weights.shape()[1]]
                }
                Op::Softmax | Op::Lrn { .. } | Op::Relu6 | Op::Relu => {
                    shapes[node.inputs[0]].clone()
                }
                Op::Add => {
                    if node.inputs.len() != 2 {
                        bail_shape!("{}: add expects exactly 2 inputs", node.name);
                    }
                    let a = &shapes[node.inputs[0]];
                    let b = &shapes[node.inputs[1]];
                    if a != b {
                        bail_shape!("{}: add shape mismatch {:?} vs {:?}", node.name, a, b);
                    }
                    a.clone()
                }
            };
            shapes.push(shape);
        }
        Ok(shapes)
    }
}

/// How conv layers are bound at preparation time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// Every conv uses im2row + GEMM (the paper's baseline configuration).
    Im2RowOnly,
    /// Winograd-suitable convs use the region-wise scheme, rest im2row
    /// (the paper's "our scheme" configuration).
    WinogradWhereSuitable,
}

impl std::fmt::Display for Scheme {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Scheme::Im2RowOnly => write!(f, "im2row"),
            Scheme::WinogradWhereSuitable => write!(f, "ours"),
        }
    }
}

/// A conv node bound to a concrete, weight-pre-transformed implementation.
enum PreparedConv {
    Winograd(WinogradConvolution),
    Im2Row(Im2RowConvolution),
    /// Direct register-tiled depthwise engine (bound on *both* schemes —
    /// the scheme split is a Winograd-vs-im2row question, and neither
    /// GEMM-backed path can express grouped layers).
    Depthwise(DepthwiseConvolution),
    /// Zero-copy direct pointwise engine for dense 1×1 layers. Bound on the
    /// "ours" scheme only: im2row *can* express 1×1 (its patch matrix is a
    /// verbatim input copy), so the baseline keeps it — which is exactly
    /// the copy-overhead comparison the ablation measures. Outputs are
    /// bit-identical across the two bindings (identical GEMM operands).
    Pointwise(PointwiseConvolution),
    /// Exotic grouped fallback: the naive grouped oracle with a post-pass
    /// epilogue. Correct, never fast; no evaluated network binds it.
    DirectGrouped {
        weights: Tensor,
        stride: (usize, usize),
        pad: (usize, usize),
        groups: usize,
    },
    /// Int8 im2row + u8×i8→i32 GEMM with the dequantizing epilogue — the
    /// quantized binding for dense spatial layers (bound on *both* schemes:
    /// Winograd stays f32-only, so the dtype question overrides the scheme
    /// split for these layers).
    Im2RowI8(QuantIm2RowConvolution),
    /// Int8 direct 3×3 depthwise engine.
    DepthwiseI8(QuantDepthwiseConvolution),
    /// Int8 direct pointwise (1×1) engine.
    PointwiseI8(QuantPointwiseConvolution),
}

/// One executable step.
enum PreparedOp {
    Passthrough,
    Conv {
        conv: PreparedConv,
        bias: Vec<f32>,
        act: Activation,
    },
    /// A prepare-time-fused `Conv(1×1) → Add → [Relu|Relu6]` residual
    /// chain, executed as **one** pointwise GEMM with the
    /// [`crate::gemm::BiasActAdd`] epilogue at the chain's tail position.
    /// The fused-away conv and add nodes become zero-size no-ops; the
    /// activation plan never materialises the conv output or the add
    /// intermediate. `x` is the conv's input node, `res` the skip-connection
    /// operand — both kept live to the tail by the planner rewrite.
    PointwiseResidual {
        conv: PointwiseConvolution,
        bias: Vec<f32>,
        act: Activation,
        x: NodeId,
        res: NodeId,
    },
    Other(Op),
}

/// Per-layer record of one executed inference.
#[derive(Debug, Clone)]
pub struct LayerTiming {
    /// Layer name.
    pub name: String,
    /// Op kind (`conv`, `maxpool`, …).
    pub kind: &'static str,
    /// Nanoseconds spent.
    pub ns: u64,
    /// For conv nodes: was it bound to the Winograd scheme?
    pub winograd: bool,
    /// For conv nodes: is the layer Winograd-suitable at all (the paper's
    /// "fast layer" predicate — true for 3×3/5×5/1×7/7×1 stride-1)?
    pub fast_layer: bool,
}

/// Static per-node facts resolved at prepare time (so per-inference timing
/// records need no re-derivation).
#[derive(Clone, Copy, Default)]
struct LayerMeta {
    winograd: bool,
    fast_layer: bool,
}

/// Per-algorithm convolution dispatch counts — how many conv-layer
/// executions each execution path has served. The prepare-time binding is
/// static, so each completed inference adds the model's per-walk census to
/// the running totals; the serving engine exports the totals through
/// [`crate::coordinator::metrics`] snapshots so reports show which paths
/// traffic actually exercises.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DispatchCounts {
    /// Region-wise Winograd conv executions.
    pub winograd: u64,
    /// im2row + GEMM conv executions.
    pub im2row: u64,
    /// Direct depthwise engine executions.
    pub depthwise: u64,
    /// Zero-copy direct pointwise (1×1) engine executions — fused-residual
    /// chains count once (they are one pointwise GEMM).
    pub pointwise: u64,
    /// Naive direct (grouped fallback) executions.
    pub direct: u64,
    /// Int8 im2row + quantized-GEMM executions.
    pub im2row_i8: u64,
    /// Int8 direct depthwise engine executions.
    pub depthwise_i8: u64,
    /// Int8 direct pointwise engine executions.
    pub pointwise_i8: u64,
}

impl DispatchCounts {
    /// Sum over all algorithm paths.
    pub fn total(&self) -> u64 {
        self.winograd
            + self.im2row
            + self.depthwise
            + self.pointwise
            + self.direct
            + self.im2row_i8
            + self.depthwise_i8
            + self.pointwise_i8
    }
}

impl std::fmt::Display for DispatchCounts {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "winograd {} / im2row {} / depthwise {} / pointwise {} / direct {} / im2row_i8 {} / depthwise_i8 {} / pointwise_i8 {}",
            self.winograd,
            self.im2row,
            self.depthwise,
            self.pointwise,
            self.direct,
            self.im2row_i8,
            self.depthwise_i8,
            self.pointwise_i8
        )
    }
}

/// The two arenas one executor thread owns: conv scratch (packed-A blocks,
/// patch matrices, padded-input staging) and planned activations.
struct ExecArenas {
    scratch: Workspace,
    acts: Workspace,
}

/// A graph prepared for a fixed input shape and scheme.
pub struct PreparedModel {
    /// Model name.
    pub name: String,
    /// Scheme the convs were bound with.
    pub scheme: Scheme,
    /// Numeric dtype the convs were bound with (f32, or int8 quantized).
    pub dtype: Dtype,
    nodes: Vec<Node>,
    prepared: Vec<PreparedOp>,
    shapes: Vec<Vec<usize>>,
    meta: Vec<LayerMeta>,
    /// Prepare-time activation layout: per-node arena offsets, peak bytes.
    plan: ActivationPlan,
    /// Scratch arena elements the largest conv layer borrows per inference.
    ws_elems: usize,
    /// The built-in arenas [`run`](Self::run) uses, pre-sized at prepare
    /// time so steady-state inference never grows them.
    arenas: Mutex<ExecArenas>,
    /// Times [`run`](Self::run) lost the arena race and executed over
    /// throwaway arenas (allocating) instead — see
    /// [`fallback_count`](Self::fallback_count).
    fallbacks: AtomicUsize,
    /// Conv layers one inference walk dispatches to each algorithm path
    /// (static after prepare).
    census: DispatchCounts,
    /// Running per-algorithm totals: `census` × completed walks — see
    /// [`dispatch_counts`](Self::dispatch_counts).
    dispatches: [AtomicU64; 8],
}

impl std::fmt::Debug for PreparedModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PreparedModel")
            .field("name", &self.name)
            .field("scheme", &self.scheme)
            .field("nodes", &self.nodes.len())
            .finish_non_exhaustive()
    }
}

/// A batch-size specialisation of a [`PreparedModel`], built by
/// [`PreparedModel::prepare_batched`]: the per-node shapes with their
/// leading (batch) dimension scaled by `nb`, plus the arena sizes a batched
/// walk needs. The activation-plan **lifetimes are untouched** — only slot
/// offsets/extents scale by `nb` at execution time, which preserves the
/// plan's prepare-time disjointness and in-bounds proofs exactly (the
/// scaling is a linear map on arena addresses). Building one allocates;
/// executing against one does not.
#[derive(Debug, Clone)]
pub struct PreparedBatch {
    /// Frames per walk.
    nb: usize,
    /// Per-node shapes with dim 0 scaled by `nb` (precomputed here so the
    /// no-alloc batched executor can borrow them as view shapes).
    shapes: Vec<Vec<usize>>,
    /// Scratch arena elements the largest layer borrows at this batch.
    ws_elems: usize,
    /// Activation arena elements a batched walk takes: plan peak × `nb`.
    peak_elems: usize,
}

impl PreparedBatch {
    /// Frames per batched walk.
    pub fn nb(&self) -> usize {
        self.nb
    }

    /// Expected batched input shape (`[nb·N, H, W, C]`).
    pub fn input_shape(&self) -> &[usize] {
        &self.shapes[0]
    }

    /// Batched output shape of the final node.
    pub fn output_shape(&self) -> &[usize] {
        self.shapes.last().unwrap()
    }

    /// Scratch arena elements to pre-size a worker's [`Workspace`] with.
    pub fn workspace_elems(&self) -> usize {
        self.ws_elems
    }

    /// Activation arena elements a batched walk borrows.
    pub fn peak_elems(&self) -> usize {
        self.peak_elems
    }
}

impl PreparedModel {
    /// Bind every conv layer of `graph` per `scheme` for `input_shape`.
    ///
    /// Binding resolves each conv through the shape-aware selector
    /// ([`Conv2d::resolved_algorithm_for`]) so small feature maps get the
    /// 2×2-tile variant, and pre-sizes the model's workspace arena to the
    /// largest layer's scratch requirement.
    pub fn prepare(
        name: &str,
        graph: &Graph,
        input_shape: &[usize],
        scheme: Scheme,
    ) -> Result<PreparedModel> {
        PreparedModel::prepare_with_dtype(name, graph, input_shape, scheme, Dtype::F32)
    }

    /// [`prepare`](Self::prepare) with an explicit numeric dtype. With
    /// [`Dtype::Int8`] every conv layer binds a quantized engine — weights
    /// are quantized per-output-channel at prepare time (scales folded
    /// offline), activations are quantized dynamically per layer at run
    /// time — and Winograd never binds (its subtractive transforms need
    /// headroom int8 lacks). The residual-fusion rewrite is f32-only: the
    /// quantized pointwise epilogue dequantizes, so the fused add would
    /// mix domains.
    pub fn prepare_with_dtype(
        name: &str,
        graph: &Graph,
        input_shape: &[usize],
        scheme: Scheme,
        dtype: Dtype,
    ) -> Result<PreparedModel> {
        let shapes = graph.infer_shapes(input_shape)?;
        let n = graph.nodes.len();

        // Prepare-time residual fusion (ours scheme only): a dense 1×1
        // linear conv whose sole consumer is an Add collapses — with the
        // Add and an optional Relu/Relu6 tail — into one pointwise GEMM
        // with a fused-residual epilogue. The planner sees a rewritten
        // topology in which the conv output and the add intermediate no
        // longer exist, so fused chains shrink the activation arena too.
        let fusions = if scheme == Scheme::WinogradWhereSuitable && dtype == Dtype::F32 {
            find_pointwise_residual_fusions(&graph.nodes, &shapes)
        } else {
            Vec::new()
        };
        let mut fused_away = vec![false; n];
        let mut tail_fusion: Vec<Option<&FusedChain>> = (0..n).map(|_| None).collect();
        for fu in &fusions {
            fused_away[fu.conv] = true;
            if fu.add != fu.tail {
                fused_away[fu.add] = true;
            }
            tail_fusion[fu.tail] = Some(fu);
        }
        // Planner-visible topology: fused-away nodes become zero-element
        // placeholders (Op::Input is the planner's "no arena slot" marker)
        // and the tail inherits the conv-input and residual edges, keeping
        // both live until the fused GEMM reads them.
        let plan = if fusions.is_empty() {
            ActivationPlan::for_graph(&graph.nodes, &shapes)
        } else {
            let mut planned = graph.nodes.clone();
            for fu in &fusions {
                planned[fu.tail].inputs = vec![fu.x, fu.res];
            }
            for (idx, dead) in fused_away.iter().enumerate() {
                if *dead {
                    planned[idx].op = Op::Input;
                    planned[idx].inputs.clear();
                }
            }
            ActivationPlan::for_graph(&planned, &shapes)
        };

        let mut prepared = Vec::with_capacity(n);
        let mut meta = Vec::with_capacity(n);
        let mut ws_elems = 0usize;
        let mut census = DispatchCounts::default();
        for (idx, node) in graph.nodes.iter().enumerate() {
            let mut m = LayerMeta::default();
            if fused_away[idx] {
                // Conv/Add node absorbed into a fused chain: executes as a
                // no-op at its own position; the work happens at the tail.
                prepared.push(PreparedOp::Passthrough);
                meta.push(m);
                continue;
            }
            if let Some(fu) = tail_fusion[idx] {
                let Op::Conv { desc, weights, bias, .. } = &graph.nodes[fu.conv].op else {
                    unreachable!("fusion matcher only selects conv nodes");
                };
                if bias.len() != desc.cout {
                    bail_shape!(
                        "{}: bias length {} vs {} output channels",
                        graph.nodes[fu.conv].name,
                        bias.len(),
                        desc.cout
                    );
                }
                let conv = PointwiseConvolution::new(weights, desc.stride, desc.padding)?;
                let xs = &shapes[fu.x];
                ws_elems = ws_elems.max(conv.workspace_elems_for(xs[0], xs[1], xs[2])?);
                census.pointwise += 1;
                prepared.push(PreparedOp::PointwiseResidual {
                    conv,
                    bias: bias.clone(),
                    act: fu.act,
                    x: fu.x,
                    res: fu.res,
                });
                meta.push(m);
                continue;
            }
            let p = match &node.op {
                Op::Input => PreparedOp::Passthrough,
                Op::Conv { desc, weights, bias, act } => {
                    // Graph nodes carry bias/activation on Op::Conv itself;
                    // a ConvEpilogue on the descriptor would be silently
                    // ignored here, so reject the ambiguity outright.
                    if !desc.epilogue.is_noop() {
                        bail_unsupported!(
                            "{}: set bias/act on Op::Conv, not on the Conv2d descriptor \
                             (desc.epilogue is only consulted by Conv2d::run*)",
                            node.name
                        );
                    }
                    if bias.len() != desc.cout {
                        bail_shape!(
                            "{}: bias length {} vs {} output channels",
                            node.name,
                            bias.len(),
                            desc.cout
                        );
                    }
                    let in_shape = &shapes[node.inputs[0]];
                    let auto = Conv2d {
                        algorithm: ConvAlgorithm::Auto,
                        dtype,
                        ..desc.clone()
                    };
                    // One spatial-aware chooser resolves the algorithm;
                    // the scheme then only decides the Winograd-vs-im2row
                    // question for dense suitable layers. Grouped layers
                    // bind their direct engines on *both* schemes (neither
                    // GEMM-backed path can express them).
                    let resolved = auto.resolved_algorithm_for(in_shape);
                    let conv = match (scheme, resolved) {
                        (_, ConvAlgorithm::DirectDepthwise) => PreparedConv::Depthwise(
                            DepthwiseConvolution::new(weights, desc.stride, desc.padding)?,
                        ),
                        (_, ConvAlgorithm::Direct) => PreparedConv::DirectGrouped {
                            weights: weights.clone(),
                            stride: desc.stride,
                            pad: desc.padding,
                            groups: desc.groups,
                        },
                        (Scheme::WinogradWhereSuitable, ConvAlgorithm::DirectPointwise) => {
                            PreparedConv::Pointwise(PointwiseConvolution::new(
                                weights,
                                desc.stride,
                                desc.padding,
                            )?)
                        }
                        (Scheme::WinogradWhereSuitable, ConvAlgorithm::Winograd(v)) => {
                            PreparedConv::Winograd(WinogradConvolution::new(
                                v,
                                weights,
                                desc.padding,
                            )?)
                        }
                        // Int8 bindings ignore the scheme split: the dtype
                        // question (Winograd needs f32 headroom) already
                        // decided it, so both schemes bind identically.
                        (_, ConvAlgorithm::Im2RowI8) => PreparedConv::Im2RowI8(
                            QuantIm2RowConvolution::new(weights, desc.stride, desc.padding)?,
                        ),
                        (_, ConvAlgorithm::DirectDepthwiseI8) => PreparedConv::DepthwiseI8(
                            QuantDepthwiseConvolution::new(weights, desc.stride, desc.padding)?,
                        ),
                        (_, ConvAlgorithm::DirectPointwiseI8) => PreparedConv::PointwiseI8(
                            QuantPointwiseConvolution::new(weights, desc.stride, desc.padding)?,
                        ),
                        _ => PreparedConv::Im2Row(Im2RowConvolution::new(
                            weights,
                            desc.stride,
                            desc.padding,
                        )?),
                    };
                    let need = match &conv {
                        PreparedConv::Winograd(wc) => {
                            m.winograd = true;
                            m.fast_layer = true;
                            census.winograd += 1;
                            wc.workspace_elems_for(in_shape[0], in_shape[1], in_shape[2])?
                        }
                        PreparedConv::Im2Row(ic) => {
                            m.fast_layer =
                                is_winograd_suitable(desc.kernel, desc.stride, desc.groups);
                            census.im2row += 1;
                            ic.workspace_elems_for(in_shape[0], in_shape[1], in_shape[2])?
                        }
                        PreparedConv::Depthwise(dc) => {
                            census.depthwise += 1;
                            dc.workspace_elems_for(in_shape[0], in_shape[1], in_shape[2])?
                        }
                        PreparedConv::Pointwise(pc) => {
                            // 1×1 is never Winograd-suitable — not a "fast
                            // layer" in the paper's sense; its win is the
                            // dropped im2row copy, not a transform.
                            census.pointwise += 1;
                            pc.workspace_elems_for(in_shape[0], in_shape[1], in_shape[2])?
                        }
                        PreparedConv::DirectGrouped { .. } => {
                            census.direct += 1;
                            0
                        }
                        PreparedConv::Im2RowI8(qc) => {
                            census.im2row_i8 += 1;
                            qc.workspace_elems_for(in_shape[0], in_shape[1], in_shape[2])?
                        }
                        PreparedConv::DepthwiseI8(qc) => {
                            census.depthwise_i8 += 1;
                            qc.workspace_elems_for(in_shape[0], in_shape[1], in_shape[2])?
                        }
                        PreparedConv::PointwiseI8(qc) => {
                            census.pointwise_i8 += 1;
                            qc.workspace_elems_for(in_shape[0], in_shape[1], in_shape[2])?
                        }
                    };
                    ws_elems = ws_elems.max(need);
                    PreparedOp::Conv {
                        conv,
                        bias: bias.clone(),
                        act: *act,
                    }
                }
                other => PreparedOp::Other(other.clone()),
            };
            prepared.push(p);
            meta.push(m);
        }
        Ok(PreparedModel {
            name: name.to_string(),
            scheme,
            dtype,
            nodes: graph.nodes.clone(),
            prepared,
            shapes,
            meta,
            ws_elems,
            arenas: Mutex::new(ExecArenas {
                scratch: Workspace::with_capacity(ws_elems),
                acts: Workspace::with_capacity(plan.peak_elems()),
            }),
            plan,
            fallbacks: AtomicUsize::new(0),
            census,
            dispatches: [
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
            ],
        })
    }

    /// Scratch arena elements the largest layer needs — what a per-worker
    /// scratch [`Workspace`] should be pre-sized to (see
    /// [`crate::coordinator`]). The matching activation arena is pre-sized
    /// from [`activation_plan`](Self::activation_plan)`().peak_elems()`.
    pub fn workspace_elems(&self) -> usize {
        self.ws_elems
    }

    /// The prepare-time activation memory plan: per-node arena offsets,
    /// planned peak bytes and the naive sum-of-all-intermediates it beats.
    pub fn activation_plan(&self) -> &ActivationPlan {
        &self.plan
    }

    /// How many [`run`](Self::run) calls lost the built-in-arena race and
    /// fell back to throwaway (allocating) arenas. Must stay 0 on any
    /// single-consumer path — the engine's per-worker-arena loop never
    /// takes the fallback, which its serving metrics pin.
    pub fn fallback_count(&self) -> usize {
        self.fallbacks.load(Ordering::Relaxed)
    }

    /// Running per-algorithm conv dispatch totals across every completed
    /// inference (any entry point). The engine surfaces these through its
    /// serving-metrics snapshots.
    pub fn dispatch_counts(&self) -> DispatchCounts {
        DispatchCounts {
            winograd: self.dispatches[0].load(Ordering::Relaxed),
            im2row: self.dispatches[1].load(Ordering::Relaxed),
            depthwise: self.dispatches[2].load(Ordering::Relaxed),
            pointwise: self.dispatches[3].load(Ordering::Relaxed),
            direct: self.dispatches[4].load(Ordering::Relaxed),
            im2row_i8: self.dispatches[5].load(Ordering::Relaxed),
            depthwise_i8: self.dispatches[6].load(Ordering::Relaxed),
            pointwise_i8: self.dispatches[7].load(Ordering::Relaxed),
        }
    }

    /// Conv layers one inference dispatches to each algorithm path — the
    /// static per-walk census behind [`dispatch_counts`](Self::dispatch_counts).
    pub fn dispatch_census(&self) -> DispatchCounts {
        self.census
    }

    /// Trace spans one planned walk records with tracing enabled: one
    /// layer span per executed (non-passthrough) node plus each bound
    /// engine's fixed stage-span count (f32 engines 2, int8 engines 3,
    /// the grouped fallback 0). Static after prepare, so callers can size
    /// the sink exactly ([`trace::reserve`]) and CI can pin
    /// `trace::len() == walks × trace_spans_per_walk()`. Batched walks
    /// record the same count — each engine is entered once per walk
    /// regardless of `nb`.
    pub fn trace_spans_per_walk(&self) -> usize {
        self.prepared
            .iter()
            .map(|p| match p {
                PreparedOp::Passthrough => 0,
                PreparedOp::Conv { conv, .. } => {
                    1 + match conv {
                        PreparedConv::Winograd(_)
                        | PreparedConv::Im2Row(_)
                        | PreparedConv::Depthwise(_)
                        | PreparedConv::Pointwise(_) => 2,
                        PreparedConv::DirectGrouped { .. } => 0,
                        PreparedConv::Im2RowI8(_)
                        | PreparedConv::DepthwiseI8(_)
                        | PreparedConv::PointwiseI8(_) => 3,
                    }
                }
                PreparedOp::PointwiseResidual { .. } => 1 + 2,
                PreparedOp::Other(_) => 1,
            })
            .sum()
    }

    /// Prepare-time roofline description of every executed node — name,
    /// kind, bound algorithm lane, output shape and a static FLOP/byte
    /// cost model — keyed by graph-node index for joining with traced
    /// layer spans via [`trace::roofline::build_profiles`]. Multiply–adds
    /// count as 2 FLOPs (the paper's convention); bytes are compulsory
    /// input + weight + output traffic, with int8 lanes streaming their
    /// offline-quantized weights at 1 byte/element.
    pub fn layer_infos(&self) -> Vec<trace::roofline::LayerInfo> {
        use trace::roofline::{LayerCost, LayerInfo};
        let mut infos = Vec::new();
        for (idx, node) in self.nodes.iter().enumerate() {
            let p = &self.prepared[idx];
            if matches!(p, PreparedOp::Passthrough) {
                continue;
            }
            let out_shape = self.shapes[idx].clone();
            let out_elems: u64 = out_shape.iter().product::<usize>() as u64;
            let in_elems =
                |i: usize| -> u64 { self.shapes[i].iter().product::<usize>() as u64 };
            let algo = prepared_algo(p);
            let wbytes: u64 = if algo.dtype_code() == 1 { 1 } else { 4 };
            let (flops, bytes, kind) = match p {
                PreparedOp::Conv { .. } => {
                    let Op::Conv { desc, .. } = &node.op else {
                        unreachable!("conv binding only happens on conv nodes")
                    };
                    let taps = (desc.kernel.0 * desc.kernel.1 * desc.cin / desc.groups) as u64;
                    let w = desc.cout as u64 * taps;
                    (
                        2 * out_elems * taps,
                        (in_elems(node.inputs[0]) + out_elems) * 4 + w * wbytes,
                        "conv",
                    )
                }
                PreparedOp::PointwiseResidual { x, res, .. } => {
                    // The fused 1×1 GEMM + residual add (+ activation) as
                    // one pass: conv MACs plus one add per output element.
                    let c = *self.shapes[*x].last().unwrap() as u64;
                    let m = *out_shape.last().unwrap() as u64;
                    (
                        2 * out_elems * c + out_elems,
                        (in_elems(*x) + in_elems(*res) + out_elems) * 4 + c * m * wbytes,
                        "conv",
                    )
                }
                PreparedOp::Other(op) => {
                    let inputs: u64 = node.inputs.iter().map(|&i| in_elems(i)).sum();
                    let flops = match op {
                        Op::MaxPool { kernel, .. } | Op::AvgPool { kernel, .. } => {
                            out_elems * (kernel.0 * kernel.1) as u64
                        }
                        Op::GlobalAvgPool => inputs,
                        Op::Fc { weights, .. } => {
                            2 * out_shape[0] as u64 * weights.len() as u64
                        }
                        Op::Lrn { size, .. } => out_elems * (2 * *size + 3) as u64,
                        // Single-pass elementwise traffic: concat copies,
                        // softmax's transcendentals, relu clamps, adds.
                        _ => inputs.max(out_elems),
                    };
                    let wb = match op {
                        Op::Fc { weights, .. } => weights.len() as u64 * 4,
                        _ => 0,
                    };
                    (flops, (inputs + out_elems) * 4 + wb, node.op.kind())
                }
                PreparedOp::Passthrough => unreachable!("filtered above"),
            };
            infos.push(LayerInfo {
                node: idx as u32,
                name: node.name.clone(),
                kind: kind.to_string(),
                algo,
                out_shape,
                cost: LayerCost { flops, bytes },
            });
        }
        infos
    }

    /// Built-in arena statistics: `(bytes, grow_count)` summed over the
    /// scratch and activation arenas. `grow_count` must stay 0 across
    /// inferences — both arenas are pre-sized at prepare time.
    pub fn workspace_stats(&self) -> (usize, usize) {
        let a = self.arenas.lock().unwrap();
        (
            a.scratch.bytes() + a.acts.bytes(),
            a.scratch.grow_count() + a.acts.grow_count(),
        )
    }

    /// Expected input shape.
    pub fn input_shape(&self) -> &[usize] {
        &self.shapes[0]
    }

    /// Output shape of the final node.
    pub fn output_shape(&self) -> &[usize] {
        self.shapes.last().unwrap()
    }

    /// Execute one inference, returning the final tensor and per-layer
    /// timings. All scratch and activations come from the model's built-in
    /// pre-sized arenas when they are free; a *concurrent* `run` on the
    /// same model falls back to throwaway arenas rather than serialising
    /// behind the mutex — counted by [`fallback_count`](Self::fallback_count),
    /// since the fallback allocates. Callers that want a dedicated
    /// steady-state arena pair per thread — like the engine's dispatcher —
    /// use [`run_with_workspace`](Self::run_with_workspace) or
    /// [`run_planned_into`](Self::run_planned_into).
    pub fn run(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
    ) -> Result<(Tensor, Vec<LayerTiming>)> {
        match self.arenas.try_lock() {
            Ok(mut guard) => {
                let ExecArenas { scratch, acts } = &mut *guard;
                self.run_with_workspace(input, pool, scratch, acts)
            }
            Err(_) => {
                self.fallbacks.fetch_add(1, Ordering::Relaxed);
                self.run_with_workspace(input, pool, &mut Workspace::new(), &mut Workspace::new())
            }
        }
    }

    /// [`run`](Self::run) with a caller-owned arena pair: `ws` feeds conv
    /// scratch (packed-A / patch matrix / padded-input staging), `acts`
    /// holds the planned activations. Allocates only the returned output
    /// tensor and the timing records; the walk itself is allocation-free.
    pub fn run_with_workspace(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
        ws: &mut Workspace,
        acts: &mut Workspace,
    ) -> Result<(Tensor, Vec<LayerTiming>)> {
        let mut timings = Vec::with_capacity(self.nodes.len());
        if self.nodes.len() == 1 {
            // Degenerate input-only graph: nothing to plan or execute.
            self.check_input(input)?;
            return Ok((input.clone(), timings));
        }
        let mut out = Tensor::zeros(self.output_shape());
        self.execute(input, pool, ws, acts, out.data_mut(), Some(&mut timings))?;
        Ok((out, timings))
    }

    /// Fully planned inference into a caller-provided output slice: with
    /// warm arenas this performs **zero heap allocation** — no intermediate
    /// tensors (activation plan), no conv scratch (workspace arena), no
    /// timing records, no output allocation. The engine's per-worker loop
    /// runs on this.
    pub fn run_planned_into(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
        ws: &mut Workspace,
        acts: &mut Workspace,
        out: &mut [f32],
    ) -> Result<()> {
        let expect: usize = self.output_shape().iter().product();
        if out.len() != expect {
            bail_shape!(
                "{}: output slice has {} elems, model writes {}",
                self.name,
                out.len(),
                expect
            );
        }
        if self.nodes.len() == 1 {
            self.check_input(input)?;
            out.copy_from_slice(input.data());
            return Ok(());
        }
        self.execute(input, pool, ws, acts, out, None)
    }

    /// Specialise this model for `nb`-frame batched walks. The per-node
    /// shapes scale only in their leading (batch) dimension — slot
    /// **lifetimes do not change shape**, so the batch-1 activation plan
    /// stays sound with every offset/extent multiplied by `nb` — and every
    /// bound engine is re-asked for its scratch need at the batched shape
    /// (workspace sizes are monotone but not always linear in N:
    /// Winograd's region blocking snaps to its L2 budget). Allocates; call
    /// once per batch size at setup time, then execute through
    /// [`run_planned_batched_into`](Self::run_planned_batched_into).
    pub fn prepare_batched(&self, nb: usize) -> Result<PreparedBatch> {
        if nb == 0 {
            bail_shape!("{}: batch must be at least 1", self.name);
        }
        let shapes: Vec<Vec<usize>> = self
            .shapes
            .iter()
            .map(|s| {
                let mut b = s.clone();
                b[0] = s[0] * nb;
                b
            })
            .collect();
        let mut ws_elems = 0usize;
        for (idx, p) in self.prepared.iter().enumerate() {
            let need = match p {
                PreparedOp::Conv { conv, .. } => {
                    let s = &shapes[self.nodes[idx].inputs[0]];
                    conv_workspace_elems(conv, s)?
                }
                PreparedOp::PointwiseResidual { conv, x, .. } => {
                    let s = &shapes[*x];
                    conv.workspace_elems_for(s[0], s[1], s[2])?
                }
                _ => 0,
            };
            ws_elems = ws_elems.max(need);
        }
        Ok(PreparedBatch {
            nb,
            shapes,
            ws_elems,
            peak_elems: self.plan.peak_elems() * nb,
        })
    }

    /// Allocating twin of
    /// [`run_planned_batched_into`](Self::run_planned_batched_into) —
    /// sizes a throwaway arena pair from the batch spec and returns the
    /// `[nb·N, …]` output tensor. Kept as the oracle the zero-alloc batched
    /// path is property-tested against.
    pub fn run_planned_batched_with(
        &self,
        batch: &PreparedBatch,
        input: &Tensor,
        pool: Option<&ThreadPool>,
    ) -> Result<Tensor> {
        let mut ws = Workspace::with_capacity(batch.ws_elems);
        let mut acts = Workspace::with_capacity(batch.peak_elems);
        let mut out = Tensor::zeros(batch.output_shape());
        self.run_planned_batched_into(batch, &input.view(), pool, &mut ws, &mut acts, out.data_mut())?;
        Ok(out)
    }

    /// Fully planned **batched** inference: `nb` frames gathered
    /// contiguously as one `[nb·N, H, W, C]` view walk the plan in a single
    /// pass — each layer traverses its packed-B weight panels once while
    /// the packed-A side (patch rows / Winograd regions / NHWC rows)
    /// carries `nb`× the work, and every activation lives in its batch-1
    /// slot scaled by `nb`. Bit-identical to `nb` sequential
    /// [`run_planned_into`](Self::run_planned_into) walks; with arenas
    /// pre-sized from the [`PreparedBatch`] this performs **zero heap
    /// allocation** (statcheck-registered). Dispatch totals advance by the
    /// census × `nb` — one count per frame per conv layer, so per-frame
    /// accounting matches the sequential path.
    #[allow(clippy::too_many_arguments)]
    pub fn run_planned_batched_into(
        &self,
        batch: &PreparedBatch,
        input: &TensorView,
        pool: Option<&ThreadPool>,
        ws: &mut Workspace,
        acts: &mut Workspace,
        out: &mut [f32],
    ) -> Result<()> {
        if batch.shapes.len() != self.nodes.len() {
            bail_shape!(
                "{}: batch spec carries {} node shapes, model has {}",
                self.name,
                batch.shapes.len(),
                self.nodes.len()
            );
        }
        if input.shape() != batch.input_shape() {
            bail_shape!(
                "{}: batched input {:?}, batch prepared for {:?}",
                self.name,
                input.shape(),
                batch.input_shape()
            );
        }
        let expect: usize = batch.output_shape().iter().product();
        if out.len() != expect {
            bail_shape!(
                "{}: output slice has {} elems, batched model writes {}",
                self.name,
                out.len(),
                expect
            );
        }
        if self.nodes.len() == 1 {
            out.copy_from_slice(input.data());
            return Ok(());
        }
        self.execute_scaled(batch.nb, &batch.shapes, input, pool, ws, acts, out, None)
    }

    fn check_input(&self, input: &Tensor) -> Result<()> {
        if input.shape() != self.input_shape() {
            bail_shape!(
                "{}: input {:?}, prepared for {:?}",
                self.name,
                input.shape(),
                self.input_shape()
            );
        }
        Ok(())
    }

    /// Walk the activation plan: every node reads borrowed arena views of
    /// its inputs (the graph input is borrowed from the caller, never
    /// copied) and writes its output through the conv stack's `*_into`
    /// entry points directly into its planned arena window. The final
    /// node's window is copied into `out` while the arena borrow is still
    /// live — [`Workspace::take`] makes no content-preservation promise
    /// across calls, so the readback must not re-borrow.
    fn execute(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
        ws: &mut Workspace,
        acts: &mut Workspace,
        out: &mut [f32],
        per_layer: Option<&mut Vec<LayerTiming>>,
    ) -> Result<()> {
        self.check_input(input)?;
        self.execute_scaled(1, &self.shapes, &input.view(), pool, ws, acts, out, per_layer)
    }

    /// The plan walk behind both the batch-1 and the batched entry points:
    /// every slot offset/extent is multiplied by `nb` (a linear map on
    /// arena addresses, so the plan's disjointness and in-bounds proofs
    /// carry over unchanged) and node views borrow the caller-provided
    /// `nb`-scaled shapes. `nb == 1` with the model's own shapes is the
    /// classic path.
    #[allow(clippy::too_many_arguments)]
    fn execute_scaled(
        &self,
        nb: usize,
        shapes: &[Vec<usize>],
        input: &TensorView,
        pool: Option<&ThreadPool>,
        ws: &mut Workspace,
        acts: &mut Workspace,
        out: &mut [f32],
        mut per_layer: Option<&mut Vec<LayerTiming>>,
    ) -> Result<()> {
        let arena = acts.take(self.plan.peak_elems() * nb);
        let base = arena.as_mut_ptr();
        // One relaxed load per walk decides all span recording — with the
        // sink disabled the executor pays nothing else.
        let tr = trace::enabled();

        for (idx, node) in self.nodes.iter().enumerate() {
            // Clock reads only when the caller asked for timings — the
            // planned serving path pays no per-node clock_gettime.
            let t0 = per_layer.is_some().then(Instant::now);
            let traced = tr && !matches!(self.prepared[idx], PreparedOp::Passthrough);
            let span_t0 = if traced {
                // Publish the node index so the engines' stage spans
                // attribute to this layer without signature changes.
                trace::set_current_layer(idx as u32);
                trace::now_ns()
            } else {
                0
            };
            // Borrowed view of a producer's planned arena window (or of the
            // caller's input tensor for the graph input).
            //
            // SAFETY: the plan asserts at prepare time that every pair of
            // simultaneously-live slots is address-disjoint and in-bounds,
            // so the shared input views and the node's mutable output
            // window below never alias.
            let view = |i: usize| {
                if matches!(self.nodes[i].op, Op::Input) {
                    *input
                } else {
                    let s = self.plan.slot(i);
                    // SAFETY: see the contract above the closure — slot `s`
                    // is in-bounds of the arena and disjoint from the output
                    // window by the plan's prepare-time assertions, and the
                    // nb-scaling multiplies every offset and extent by the
                    // same factor, preserving both properties.
                    let data: &[f32] = unsafe {
                        std::slice::from_raw_parts(base.add(s.offset * nb) as *const f32, s.elems * nb)
                    };
                    TensorView::new(&shapes[i], data)
                        .expect("plan slot sized from the same shape inference")
                }
            };
            let slot = self.plan.slot(idx);
            // SAFETY: see `view` — the output window is disjoint from every
            // live input window, and nodes execute strictly serially.
            let out: &mut [f32] = unsafe {
                std::slice::from_raw_parts_mut(base.add(slot.offset * nb), slot.elems * nb)
            };

            match &self.prepared[idx] {
                // The graph input is borrowed in place — a zero-element
                // slot, no `Tensor::clone` and no staging copy.
                PreparedOp::Passthrough => {}
                PreparedOp::Conv { conv, bias, act } => {
                    let x = view(node.inputs[0]);
                    match conv {
                        PreparedConv::Winograd(wc) => {
                            // Bias + activation fused into the gather
                            // epilogue; staging and packed-A drawn from
                            // the arena.
                            wc.run_fused_into(&x, pool, Some(bias), *act, ws, out)?
                        }
                        PreparedConv::Im2Row(ic) => {
                            // Bias + activation fused into the GEMM
                            // epilogue — conv outputs are written exactly
                            // once on both scheme paths.
                            ic.run_fused_into(&x, pool, Some(bias), *act, ws, out)?
                        }
                        PreparedConv::Depthwise(dc) => {
                            // Bias seeds the register accumulators; the
                            // activation clamps in-register before the
                            // single store. Staging from the same arena.
                            dc.run_fused_into(&x, pool, Some(bias), *act, ws, out)?
                        }
                        PreparedConv::Pointwise(pc) => {
                            // Zero-copy: the producer's arena window *is*
                            // the GEMM A operand (stride-2 layers gather
                            // sampled rows through the scratch arena).
                            pc.run_fused_into(&x, pool, Some(bias), *act, ws, out)?
                        }
                        PreparedConv::DirectGrouped { weights, stride, pad, groups } => {
                            // Naive grouped fallback: direct conv into the
                            // arena window, then a post-pass epilogue (the
                            // one path with nothing to fuse into).
                            crate::conv::direct::direct_conv2d_grouped_into(
                                &x, weights, *stride, *pad, *groups, out,
                            )?;
                            let m_out = weights.shape()[0];
                            for px in out.chunks_mut(m_out) {
                                for (v, b) in px.iter_mut().zip(bias.iter()) {
                                    *v = act.apply(*v + *b);
                                }
                            }
                        }
                        // Quantized engines: dynamic activation quantize +
                        // i32 accumulate + dequantizing epilogue, all from
                        // the same scratch arena (byte-ceiled borrows).
                        PreparedConv::Im2RowI8(qc) => {
                            qc.run_fused_i8_into(&x, pool, Some(bias), *act, ws, out)?
                        }
                        PreparedConv::DepthwiseI8(qc) => {
                            qc.run_fused_i8_into(&x, pool, Some(bias), *act, ws, out)?
                        }
                        PreparedConv::PointwiseI8(qc) => {
                            qc.run_fused_i8_into(&x, pool, Some(bias), *act, ws, out)?
                        }
                    }
                }
                PreparedOp::PointwiseResidual { conv, bias, act, x, res } => {
                    // The whole Conv(1×1) → Add → Act chain as one GEMM:
                    // the residual operand's arena window feeds the
                    // BiasActAdd epilogue per cache-hot micro-tile. The
                    // conv output and the add intermediate never exist.
                    let xin = view(*x);
                    let rin = view(*res);
                    conv.run_residual_fused_into(
                        &xin,
                        pool,
                        Some(bias),
                        *act,
                        rin.data(),
                        ws,
                        out,
                    )?
                }
                PreparedOp::Other(op) => {
                    match op {
                        Op::MaxPool { kernel, stride, pad, ceil } => {
                            ops::max_pool2d_into(&view(node.inputs[0]), *kernel, *stride, *pad, *ceil, out)?
                        }
                        Op::AvgPool { kernel, stride, pad, ceil } => {
                            ops::avg_pool2d_into(&view(node.inputs[0]), *kernel, *stride, *pad, *ceil, out)?
                        }
                        Op::GlobalAvgPool => ops::global_avg_pool_into(&view(node.inputs[0]), out)?,
                        Op::Concat => {
                            let c_total = shapes[idx][3];
                            let mut c_off = 0usize;
                            for &i in &node.inputs {
                                ops::concat_channels_into_part(&view(i), c_off, c_total, out)?;
                                c_off += shapes[i][3];
                            }
                        }
                        Op::Fc { weights, bias, relu } => {
                            let x = view(node.inputs[0]);
                            // The flat arena window *is* the `[N, K]` view.
                            ops::fully_connected_into(
                                x.data(),
                                x.shape()[0],
                                weights,
                                bias,
                                *relu,
                                out,
                            )?
                        }
                        Op::Softmax => {
                            let x = view(node.inputs[0]);
                            if x.rank() != 2 {
                                bail_shape!("softmax expects [N, M], got {:?}", x.shape());
                            }
                            ops::softmax_into(x.data(), x.shape()[1], out)?
                        }
                        Op::Lrn { size, alpha, beta, k } => {
                            ops::lrn_across_channels_into(
                                &view(node.inputs[0]),
                                *size,
                                *alpha,
                                *beta,
                                *k,
                                out,
                            )?
                        }
                        Op::Relu6 => ops::relu6_into(view(node.inputs[0]).data(), out)?,
                        Op::Relu => ops::relu_into(view(node.inputs[0]).data(), out)?,
                        Op::Add => {
                            let a = view(node.inputs[0]);
                            let b = view(node.inputs[1]);
                            ops::add_into(a.data(), b.data(), out)?
                        }
                        Op::Input | Op::Conv { .. } => unreachable!(),
                    }
                }
            };
            if traced {
                let s = &shapes[idx];
                let dim = |i: usize| s.get(i).copied().unwrap_or(1) as u32;
                trace::record_layer(
                    idx as u32,
                    prepared_algo(&self.prepared[idx]),
                    [dim(0), dim(1), dim(2), dim(3)],
                    span_t0,
                    trace::now_ns().saturating_sub(span_t0),
                );
            }
            if let (Some(timings), Some(t0)) = (per_layer.as_deref_mut(), t0) {
                timings.push(LayerTiming {
                    name: node.name.clone(),
                    kind: node.op.kind(),
                    ns: t0.elapsed().as_nanos() as u64,
                    winograd: self.meta[idx].winograd,
                    fast_layer: self.meta[idx].fast_layer,
                });
            }
        }
        let last = self.plan.slot(self.nodes.len() - 1);
        out.copy_from_slice(&arena[last.offset * nb..last.offset * nb + last.elems * nb]);
        // One relaxed add per non-zero path per walk — the census is
        // static, so totals stay exact without per-layer atomics. A batched
        // walk advances each lane by census × nb: one count per frame per
        // conv layer, matching the sequential path's per-frame accounting.
        for (slot, n) in [
            (0usize, self.census.winograd),
            (1, self.census.im2row),
            (2, self.census.depthwise),
            (3, self.census.pointwise),
            (4, self.census.direct),
            (5, self.census.im2row_i8),
            (6, self.census.depthwise_i8),
            (7, self.census.pointwise_i8),
        ] {
            if n > 0 {
                self.dispatches[slot].fetch_add(n * nb as u64, Ordering::Relaxed);
            }
        }
        Ok(())
    }
}

/// The trace-span algorithm lane a prepared op executes on.
fn prepared_algo(p: &PreparedOp) -> trace::AlgoCode {
    match p {
        PreparedOp::Conv { conv, .. } => match conv {
            PreparedConv::Winograd(_) => trace::AlgoCode::Winograd,
            PreparedConv::Im2Row(_) => trace::AlgoCode::Im2Row,
            PreparedConv::Depthwise(_) => trace::AlgoCode::Depthwise,
            PreparedConv::Pointwise(_) => trace::AlgoCode::Pointwise,
            PreparedConv::DirectGrouped { .. } => trace::AlgoCode::Direct,
            PreparedConv::Im2RowI8(_) => trace::AlgoCode::Im2RowI8,
            PreparedConv::DepthwiseI8(_) => trace::AlgoCode::DepthwiseI8,
            PreparedConv::PointwiseI8(_) => trace::AlgoCode::PointwiseI8,
        },
        PreparedOp::PointwiseResidual { .. } => trace::AlgoCode::Pointwise,
        PreparedOp::Passthrough | PreparedOp::Other(_) => trace::AlgoCode::None,
    }
}

/// Scratch elements one inference over `in_shape` borrows for a bound conv
/// — the same per-engine sizing [`PreparedModel::prepare_with_dtype`] runs
/// at batch 1, factored out so [`PreparedModel::prepare_batched`] can
/// re-ask at `nb`-scaled shapes.
fn conv_workspace_elems(conv: &PreparedConv, in_shape: &[usize]) -> Result<usize> {
    let (n, h, w) = (in_shape[0], in_shape[1], in_shape[2]);
    match conv {
        PreparedConv::Winograd(wc) => wc.workspace_elems_for(n, h, w),
        PreparedConv::Im2Row(ic) => ic.workspace_elems_for(n, h, w),
        PreparedConv::Depthwise(dc) => dc.workspace_elems_for(n, h, w),
        PreparedConv::Pointwise(pc) => pc.workspace_elems_for(n, h, w),
        PreparedConv::DirectGrouped { .. } => Ok(0),
        PreparedConv::Im2RowI8(qc) => qc.workspace_elems_for(n, h, w),
        PreparedConv::DepthwiseI8(qc) => qc.workspace_elems_for(n, h, w),
        PreparedConv::PointwiseI8(qc) => qc.workspace_elems_for(n, h, w),
    }
}

/// One matched `Conv(1×1) → Add → [Relu|Relu6]` residual chain (see
/// [`PreparedOp::PointwiseResidual`]).
struct FusedChain {
    /// The dense 1×1 linear conv node (fused away).
    conv: NodeId,
    /// The Add node (fused away unless it is the tail itself).
    add: NodeId,
    /// The node whose position and arena slot the fused GEMM executes at:
    /// the trailing activation when present, else the Add.
    tail: NodeId,
    /// The conv's input node.
    x: NodeId,
    /// The skip-connection operand (the Add's other input).
    res: NodeId,
    /// Activation applied after bias + residual.
    act: Activation,
}

/// Scan for fusable residual chains: an Add with a dense unpadded *linear*
/// (act-less) 1×1-conv operand that has no other consumer, optionally
/// followed by a sole-consumer standalone Relu/Relu6. Order-agnostic in the
/// Add's operands; when both qualify (a ResNet downsample block feeds its
/// add from the main-path 1×1 expand *and* the 1×1/s2 projection) the
/// stride-1 conv wins — fusing it keeps the zero-staging path hot.
fn find_pointwise_residual_fusions(nodes: &[Node], shapes: &[Vec<usize>]) -> Vec<FusedChain> {
    let n = nodes.len();
    let mut consumers = vec![0usize; n];
    for node in nodes {
        for &i in &node.inputs {
            consumers[i] += 1;
        }
    }
    let mut taken = vec![false; n];
    let mut found = Vec::new();
    for (a_idx, node) in nodes.iter().enumerate() {
        if !matches!(node.op, Op::Add) || node.inputs.len() != 2 {
            continue;
        }
        let (p, q) = (node.inputs[0], node.inputs[1]);
        if p == q {
            continue;
        }
        // Returns the conv's stride when operand `j` is fusable, so the
        // both-qualify preference below can see it.
        let qualifies = |j: NodeId| -> Option<(usize, usize)> {
            if consumers[j] != 1 || taken[j] {
                return None;
            }
            let Op::Conv { desc, act, .. } = &nodes[j].op else {
                return None;
            };
            if *act != Activation::None || !desc.epilogue.is_noop() {
                return None;
            }
            let auto = Conv2d { algorithm: ConvAlgorithm::Auto, ..desc.clone() };
            let resolved = auto.resolved_algorithm_for(&shapes[nodes[j].inputs[0]]);
            (resolved == ConvAlgorithm::DirectPointwise).then_some(desc.stride)
        };
        let conv = match (qualifies(p), qualifies(q)) {
            (Some(sp), Some(_)) => {
                if sp == (1, 1) {
                    p
                } else {
                    q
                }
            }
            (Some(_), None) => p,
            (None, Some(_)) => q,
            (None, None) => continue,
        };
        let res = if conv == p { q } else { p };
        // Optional activation tail: the Add's sole consumer is a
        // standalone Relu/Relu6 reading only the Add.
        let mut tail = a_idx;
        let mut act = Activation::None;
        if consumers[a_idx] == 1 {
            if let Some((t_idx, t_node)) = nodes
                .iter()
                .enumerate()
                .skip(a_idx + 1)
                .find(|(_, t)| t.inputs.contains(&a_idx))
            {
                match t_node.op {
                    Op::Relu if t_node.inputs.len() == 1 => {
                        tail = t_idx;
                        act = Activation::Relu;
                    }
                    Op::Relu6 if t_node.inputs.len() == 1 => {
                        tail = t_idx;
                        act = Activation::Relu6;
                    }
                    _ => {}
                }
            }
        }
        taken[conv] = true;
        found.push(FusedChain {
            conv,
            add: a_idx,
            tail,
            x: nodes[conv].inputs[0],
            res,
            act,
        });
    }
    found
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny two-branch model: conv → {conv3x3, maxpool} → concat → fc.
    fn tiny_graph(seed: u64) -> Graph {
        let mut g = Graph::new();
        let input = g.input();
        let c1 = Conv2d::new(3, 8, (3, 3)).with_padding((1, 1));
        let w1 = c1.random_weights(seed);
        let n1 = g.add(
            "conv1",
            Op::Conv { desc: c1, weights: w1, bias: vec![0.1; 8], act: Activation::Relu },
            &[input],
        );
        let c2 = Conv2d::new(8, 16, (3, 3)).with_padding((1, 1));
        let w2 = c2.random_weights(seed + 1);
        let br_a = g.add(
            "conv2",
            Op::Conv { desc: c2, weights: w2, bias: vec![0.0; 16], act: Activation::Relu },
            &[n1],
        );
        let br_b = g.add(
            "pool",
            Op::MaxPool { kernel: (3, 3), stride: (1, 1), pad: (1, 1), ceil: false },
            &[n1],
        );
        let cat = g.add("concat", Op::Concat, &[br_a, br_b]);
        let gap = g.add("gap", Op::GlobalAvgPool, &[cat]);
        let fcw = Tensor::randn(&[24, 10], seed + 2);
        let fc = g.add(
            "fc",
            Op::Fc { weights: fcw, bias: vec![0.0; 10], relu: false },
            &[gap],
        );
        g.add("softmax", Op::Softmax, &[fc]);
        g
    }

    #[test]
    fn shape_inference_through_branches() {
        let g = tiny_graph(1);
        let shapes = g.infer_shapes(&[1, 8, 8, 3]).unwrap();
        assert_eq!(shapes[1], vec![1, 8, 8, 8]); // conv1
        assert_eq!(shapes[2], vec![1, 8, 8, 16]); // conv2
        assert_eq!(shapes[3], vec![1, 8, 8, 8]); // pool
        assert_eq!(shapes[4], vec![1, 8, 8, 24]); // concat
        assert_eq!(shapes[5], vec![1, 1, 1, 24]); // gap
        assert_eq!(shapes[6], vec![1, 10]); // fc
        assert_eq!(shapes[7], vec![1, 10]); // softmax
    }

    #[test]
    fn schemes_agree_numerically() {
        let g = tiny_graph(3);
        let input = Tensor::randn(&[1, 8, 8, 3], 9);
        let base = PreparedModel::prepare("tiny", &g, input.shape(), Scheme::Im2RowOnly).unwrap();
        let ours =
            PreparedModel::prepare("tiny", &g, input.shape(), Scheme::WinogradWhereSuitable)
                .unwrap();
        let (y1, t1) = base.run(&input, None).unwrap();
        let (y2, t2) = ours.run(&input, None).unwrap();
        assert!(y2.allclose(&y1, 1e-3));
        assert_eq!(t1.len(), g.nodes.len());
        // In "ours", conv2 (8·16 = 128 ≥ threshold) must be Winograd-bound.
        assert!(t2.iter().any(|t| t.name == "conv2" && t.winograd));
        // In the baseline nothing is Winograd-bound.
        assert!(t1.iter().all(|t| !t.winograd));
    }

    #[test]
    fn fast_layer_flag_independent_of_scheme() {
        let g = tiny_graph(5);
        let base = PreparedModel::prepare("tiny", &g, &[1, 8, 8, 3], Scheme::Im2RowOnly).unwrap();
        let input = Tensor::randn(&[1, 8, 8, 3], 2);
        let (_, t) = base.run(&input, None).unwrap();
        let conv2 = t.iter().find(|t| t.name == "conv2").unwrap();
        assert!(conv2.fast_layer && !conv2.winograd);
    }

    /// Bias/activation live on Op::Conv for graph nodes; a ConvEpilogue set
    /// on the descriptor would be silently ignored, so prepare() rejects it.
    #[test]
    fn rejects_descriptor_epilogue_on_graph_conv() {
        let mut g = Graph::new();
        let input = g.input();
        let c1 = Conv2d::new(3, 8, (3, 3)).with_padding((1, 1)).with_relu(true);
        let w1 = c1.random_weights(1);
        g.add(
            "conv1",
            Op::Conv { desc: c1, weights: w1, bias: vec![0.0; 8], act: Activation::Relu },
            &[input],
        );
        assert!(PreparedModel::prepare("bad", &g, &[1, 8, 8, 3], Scheme::Im2RowOnly).is_err());
    }

    #[test]
    fn run_rejects_wrong_input_shape() {
        let g = tiny_graph(1);
        let m = PreparedModel::prepare("tiny", &g, &[1, 8, 8, 3], Scheme::Im2RowOnly).unwrap();
        let bad = Tensor::zeros(&[1, 9, 9, 3]);
        assert!(m.run(&bad, None).is_err());
    }

    #[test]
    fn parallel_execution_matches_serial() {
        let g = tiny_graph(7);
        let m =
            PreparedModel::prepare("tiny", &g, &[1, 8, 8, 3], Scheme::WinogradWhereSuitable)
                .unwrap();
        let input = Tensor::randn(&[1, 8, 8, 3], 4);
        let pool = ThreadPool::new(4);
        let (a, _) = m.run(&input, None).unwrap();
        let (b, _) = m.run(&input, Some(&pool)).unwrap();
        assert!(b.allclose(&a, 1e-5));
    }

    /// The arena-reuse guarantee: prepare() pre-sizes both built-in arenas
    /// (conv scratch + planned activations), so repeated inferences never
    /// grow them, and the uncontended `run` path never takes the
    /// allocating fallback.
    #[test]
    fn workspace_not_regrown_across_inferences() {
        let g = tiny_graph(11);
        let m =
            PreparedModel::prepare("tiny", &g, &[1, 8, 8, 3], Scheme::WinogradWhereSuitable)
                .unwrap();
        assert!(m.workspace_elems() > 0, "model has conv layers needing scratch");
        assert!(m.activation_plan().peak_elems() > 0, "model has intermediates to plan");
        let (bytes0, grows0) = m.workspace_stats();
        assert_eq!(
            bytes0,
            (m.workspace_elems() + m.activation_plan().peak_elems()) * 4
        );
        for seed in 0..3 {
            let input = Tensor::randn(&[1, 8, 8, 3], seed);
            let _ = m.run(&input, None).unwrap();
        }
        let (bytes1, grows1) = m.workspace_stats();
        assert_eq!(grows0, 0);
        assert_eq!(grows1, 0, "steady-state inference must not grow the arenas");
        assert_eq!(bytes0, bytes1);
        assert_eq!(m.fallback_count(), 0, "uncontended runs never fall back");
    }

    /// An explicit per-worker arena pair (the coordinator's pattern) sized
    /// from `workspace_elems()` / `activation_plan().peak_elems()` also
    /// never grows.
    #[test]
    fn explicit_worker_arena_never_grows() {
        let g = tiny_graph(13);
        let m = PreparedModel::prepare("tiny", &g, &[1, 8, 8, 3], Scheme::Im2RowOnly).unwrap();
        let mut ws = Workspace::with_capacity(m.workspace_elems());
        let mut acts = Workspace::with_capacity(m.activation_plan().peak_elems());
        for seed in 0..2 {
            let input = Tensor::randn(&[1, 8, 8, 3], seed + 20);
            let _ = m.run_with_workspace(&input, None, &mut ws, &mut acts).unwrap();
        }
        assert_eq!(ws.grow_count(), 0);
        assert_eq!(acts.grow_count(), 0);
        assert!(ws.high_water_elems() <= m.workspace_elems());
        assert_eq!(acts.high_water_elems(), m.activation_plan().peak_elems());
    }

    /// Reference executor: the pre-planner walk over a `Vec<Option<Tensor>>`
    /// of owned tensors, built from the allocating entry points. The
    /// planned executor must match it **bit-for-bit** — the plan changes
    /// where intermediates live, never their values.
    fn run_reference(m: &PreparedModel, input: &Tensor) -> Tensor {
        let n = m.nodes.len();
        let mut ws = Workspace::new();
        let mut values: Vec<Option<Tensor>> = (0..n).map(|_| None).collect();
        for (idx, node) in m.nodes.iter().enumerate() {
            let out = match &m.prepared[idx] {
                PreparedOp::Passthrough => input.clone(),
                PreparedOp::Conv { conv, bias, act } => {
                    let x = values[node.inputs[0]].as_ref().unwrap_or(input);
                    match conv {
                        PreparedConv::Winograd(wc) => {
                            wc.run_fused_with(x, None, Some(bias), *act, &mut ws).unwrap()
                        }
                        PreparedConv::Im2Row(ic) => {
                            ic.run_fused_with(x, None, Some(bias), *act, &mut ws).unwrap()
                        }
                        PreparedConv::Depthwise(dc) => {
                            dc.run_fused_with(x, None, Some(bias), *act, &mut ws).unwrap()
                        }
                        PreparedConv::Pointwise(pc) => {
                            pc.run_fused_with(x, None, Some(bias), *act, &mut ws).unwrap()
                        }
                        PreparedConv::DirectGrouped { weights, stride, pad, groups } => {
                            let mut y = crate::conv::direct::direct_conv2d_grouped(
                                x, weights, *stride, *pad, *groups,
                            )
                            .unwrap();
                            ops::bias_act_inplace(&mut y, bias, *act).unwrap();
                            y
                        }
                        PreparedConv::Im2RowI8(qc) => {
                            qc.run_fused_i8_with(x, None, Some(bias), *act, &mut ws).unwrap()
                        }
                        PreparedConv::DepthwiseI8(qc) => {
                            qc.run_fused_i8_with(x, None, Some(bias), *act, &mut ws).unwrap()
                        }
                        PreparedConv::PointwiseI8(qc) => {
                            qc.run_fused_i8_with(x, None, Some(bias), *act, &mut ws).unwrap()
                        }
                    }
                }
                // The fused chain's *unfused* reference: conv (bias only),
                // then a whole-tensor add, then the activation — the exact
                // separate-pass walk the fusion claims bit-identity with.
                PreparedOp::PointwiseResidual { conv, bias, act, x, res } => {
                    let xv = values[*x].as_ref().unwrap();
                    let rv = values[*res].as_ref().unwrap();
                    let pre = conv
                        .run_fused_with(xv, None, Some(bias), Activation::None, &mut ws)
                        .unwrap();
                    let mut sum = ops::add_elementwise(&pre, rv).unwrap();
                    ops::act_inplace(&mut sum, *act);
                    sum
                }
                PreparedOp::Other(op) => {
                    let x = values[node.inputs[0]].as_ref().unwrap();
                    match op {
                        Op::MaxPool { kernel, stride, pad, ceil } => {
                            ops::max_pool2d(x, *kernel, *stride, *pad, *ceil).unwrap()
                        }
                        Op::AvgPool { kernel, stride, pad, ceil } => {
                            ops::avg_pool2d(x, *kernel, *stride, *pad, *ceil).unwrap()
                        }
                        Op::GlobalAvgPool => ops::global_avg_pool(x).unwrap(),
                        Op::Concat => {
                            let parts: Vec<&Tensor> =
                                node.inputs.iter().map(|&i| values[i].as_ref().unwrap()).collect();
                            ops::concat_channels(&parts).unwrap()
                        }
                        Op::Fc { weights, bias, relu } => {
                            let flat =
                                x.reshape(&[x.shape()[0], x.len() / x.shape()[0]]).unwrap();
                            ops::fully_connected(&flat, weights, bias, *relu).unwrap()
                        }
                        Op::Softmax => ops::softmax(x).unwrap(),
                        Op::Lrn { size, alpha, beta, k } => {
                            ops::lrn_across_channels(x, *size, *alpha, *beta, *k).unwrap()
                        }
                        Op::Relu6 => ops::relu6(x),
                        Op::Relu => ops::relu(x),
                        Op::Add => {
                            let b = values[node.inputs[1]].as_ref().unwrap();
                            ops::add_elementwise(x, b).unwrap()
                        }
                        Op::Input | Op::Conv { .. } => unreachable!(),
                    }
                }
            };
            values[idx] = Some(out);
        }
        values[n - 1].take().unwrap()
    }

    /// The planned executor is bit-identical to the reference allocating
    /// walk, for both schemes, through branches/concat/pool/fc/softmax —
    /// and `run_planned_into` lands the same bits in a dirty caller slice.
    #[test]
    fn planned_executor_matches_reference_bitwise() {
        for scheme in [Scheme::Im2RowOnly, Scheme::WinogradWhereSuitable] {
            let g = tiny_graph(17);
            let m = PreparedModel::prepare("tiny", &g, &[1, 8, 8, 3], scheme).unwrap();
            let input = Tensor::randn(&[1, 8, 8, 3], 23);
            let want = run_reference(&m, &input);
            let (got, timings) = m.run(&input, None).unwrap();
            assert_eq!(got.shape(), want.shape());
            assert_eq!(got.data(), want.data(), "{scheme}: planned != reference");
            assert_eq!(timings.len(), g.nodes.len());
            // Write-into path over deliberately dirty arenas.
            let mut ws = Workspace::new();
            let mut acts = Workspace::new();
            acts.take(m.activation_plan().peak_elems()).fill(f32::NAN);
            let mut out = vec![f32::NAN; want.len()];
            m.run_planned_into(&input, None, &mut ws, &mut acts, &mut out).unwrap();
            assert_eq!(out, want.data(), "{scheme}: run_planned_into != reference");
            assert!(m
                .run_planned_into(&input, None, &mut ws, &mut acts, &mut out[1..])
                .is_err());
        }
    }

    /// The batched planned walk is bit-identical to `nb` sequential batch-1
    /// planned walks over the same frames, for both schemes, through
    /// branches/concat/pool/fc/softmax — with the [`PreparedBatch`]-sized
    /// arena pair never growing (grow = 0 at every tested N > 1), per-frame
    /// dispatch accounting (census × nb per walk), and the allocating twin
    /// landing the same bits.
    #[test]
    fn batched_planned_matches_sequential_bitwise() {
        for scheme in [Scheme::Im2RowOnly, Scheme::WinogradWhereSuitable] {
            let g = tiny_graph(17);
            let m = PreparedModel::prepare("tiny", &g, &[1, 8, 8, 3], scheme).unwrap();
            for nb in [2usize, 4] {
                let batch = m.prepare_batched(nb).unwrap();
                assert_eq!(batch.nb(), nb);
                assert_eq!(batch.input_shape(), &[nb, 8, 8, 3]);
                assert_eq!(
                    batch.peak_elems(),
                    m.activation_plan().peak_elems() * nb,
                    "slot scaling rule: peak × nb"
                );
                let frame: usize = m.input_shape().iter().product();
                let out_frame: usize = m.output_shape().iter().product();
                let input = Tensor::randn(&[nb, 8, 8, 3], 31 + nb as u64);
                // Reference: nb sequential batch-1 planned walks.
                let mut ws = Workspace::new();
                let mut acts = Workspace::new();
                let mut want = vec![0.0f32; nb * out_frame];
                for f in 0..nb {
                    let ft = Tensor::from_vec(
                        &[1, 8, 8, 3],
                        input.data()[f * frame..(f + 1) * frame].to_vec(),
                    )
                    .unwrap();
                    m.run_planned_into(
                        &ft,
                        None,
                        &mut ws,
                        &mut acts,
                        &mut want[f * out_frame..(f + 1) * out_frame],
                    )
                    .unwrap();
                }
                // One batched walk, twice, over PreparedBatch-sized dirty
                // arenas — sizes must be exact, so grow stays 0.
                let mut wsb = Workspace::with_capacity(batch.workspace_elems());
                let mut actsb = Workspace::with_capacity(batch.peak_elems());
                actsb.take(batch.peak_elems()).fill(f32::NAN);
                let mut got = vec![f32::NAN; nb * out_frame];
                let before = m.dispatch_counts().total();
                for _ in 0..2 {
                    m.run_planned_batched_into(
                        &batch,
                        &input.view(),
                        None,
                        &mut wsb,
                        &mut actsb,
                        &mut got,
                    )
                    .unwrap();
                }
                assert_eq!(got, want, "{scheme} nb={nb}: batched != sequential");
                assert_eq!(wsb.grow_count(), 0, "{scheme} nb={nb}: scratch arena grew");
                assert_eq!(actsb.grow_count(), 0, "{scheme} nb={nb}: activation arena grew");
                // Census × nb per batched walk — per-frame accounting.
                assert_eq!(
                    m.dispatch_counts().total() - before,
                    2 * nb as u64 * m.dispatch_census().total(),
                    "{scheme} nb={nb}: dispatch totals"
                );
                // Allocating twin lands the same bits.
                let twin = m.run_planned_batched_with(&batch, &input, None).unwrap();
                assert_eq!(twin.shape(), batch.output_shape());
                assert_eq!(got, *twin.data());
                // Guards: wrong frame count and short output slice reject.
                let bad = Tensor::randn(&[nb + 1, 8, 8, 3], 1);
                assert!(m
                    .run_planned_batched_into(
                        &batch,
                        &bad.view(),
                        None,
                        &mut wsb,
                        &mut actsb,
                        &mut got
                    )
                    .is_err());
                assert!(m
                    .run_planned_batched_into(
                        &batch,
                        &input.view(),
                        None,
                        &mut wsb,
                        &mut actsb,
                        &mut got[1..]
                    )
                    .is_err());
            }
        }
        // nb = 0 is rejected at prepare time.
        let g = tiny_graph(17);
        let m = PreparedModel::prepare("tiny", &g, &[1, 8, 8, 3], Scheme::Im2RowOnly).unwrap();
        assert!(m.prepare_batched(0).is_err());
    }

    /// Planner integration: disjoint-lifetime layers of the prepared model
    /// share arena bytes, so planned peak sits strictly below the naive
    /// sum-of-all-intermediates.
    #[test]
    fn prepared_plan_shares_arena_bytes() {
        let g = tiny_graph(19);
        let m = PreparedModel::prepare("tiny", &g, &[1, 8, 8, 3], Scheme::Im2RowOnly).unwrap();
        let plan = m.activation_plan();
        assert!(plan.peak_elems() < plan.naive_elems());
        assert_eq!(plan.peak_bytes(), plan.peak_elems() * 4);
    }

    /// A MobileNet-flavoured residual block: pw-expand (ReLU6) → depthwise
    /// 3×3 (ReLU6) → pw-linear → residual Add → standalone Relu6. The
    /// depthwise layer binds the direct engine on *both* schemes, the
    /// planned executor matches the allocating reference bit for bit, and
    /// the dispatch census/counters report what actually ran.
    fn residual_block_graph(seed: u64) -> Graph {
        let mut g = Graph::new();
        let input = g.input();
        let c = 8usize;
        let expand = Conv2d::new(c, 2 * c, (1, 1));
        let we = expand.random_weights(seed);
        let n_e = g.add(
            "pw_expand",
            Op::Conv { desc: expand, weights: we, bias: vec![0.05; 2 * c], act: Activation::Relu6 },
            &[input],
        );
        let dw = Conv2d::new(2 * c, 2 * c, (3, 3)).with_groups(2 * c).with_padding((1, 1));
        let wd = dw.random_weights(seed + 1);
        let n_d = g.add(
            "dw3x3",
            Op::Conv { desc: dw, weights: wd, bias: vec![0.1; 2 * c], act: Activation::Relu6 },
            &[n_e],
        );
        let project = Conv2d::new(2 * c, c, (1, 1));
        let wp = project.random_weights(seed + 2);
        let n_p = g.add(
            "pw_linear",
            Op::Conv { desc: project, weights: wp, bias: vec![0.0; c], act: Activation::None },
            &[n_d],
        );
        let n_add = g.add("residual", Op::Add, &[input, n_p]);
        g.add("clamp", Op::Relu6, &[n_add]);
        g
    }

    #[test]
    fn depthwise_residual_block_planned_matches_reference() {
        let mut outputs: Vec<Vec<f32>> = Vec::new();
        for scheme in [Scheme::Im2RowOnly, Scheme::WinogradWhereSuitable] {
            let g = residual_block_graph(29);
            let m = PreparedModel::prepare("mbblock", &g, &[1, 10, 10, 8], scheme).unwrap();
            // Census: the baseline keeps both 1×1 convs on im2row; "ours"
            // binds them to the pointwise engine, one of them as the fused
            // pw_linear → residual → clamp chain (still one pointwise
            // dispatch). The depthwise layer binds its engine on both.
            let census = m.dispatch_census();
            match scheme {
                Scheme::Im2RowOnly => {
                    assert_eq!(census.im2row, 2);
                    assert_eq!(census.pointwise, 0);
                }
                Scheme::WinogradWhereSuitable => {
                    assert_eq!(census.im2row, 0);
                    assert_eq!(census.pointwise, 2);
                    // The fused chain's conv output and add intermediate
                    // are never materialised: zero-size plan slots.
                    let plan = m.activation_plan();
                    assert_eq!(plan.slot(3).elems, 0, "fused pw_linear slot");
                    assert_eq!(plan.slot(4).elems, 0, "fused residual-add slot");
                }
            }
            assert_eq!(census.depthwise, 1, "{scheme}");
            assert_eq!(census.winograd + census.direct, 0, "{scheme}");
            assert_eq!(m.dispatch_counts().total(), 0, "no walks yet");

            let input = Tensor::randn(&[1, 10, 10, 8], 77);
            let want = run_reference(&m, &input);
            // Relu6 tail: outputs clamped to [0, 6], clamps actually fire.
            assert!(want.data().iter().all(|&v| (0.0..=6.0).contains(&v)));
            assert!(want.data().iter().any(|&v| v == 0.0));
            let (got, timings) = m.run(&input, None).unwrap();
            assert_eq!(got.data(), want.data(), "{scheme}: planned != reference");
            assert_eq!(timings.len(), g.nodes.len());
            // Depthwise/grouped conv is never a "fast layer".
            let dwt = timings.iter().find(|t| t.name == "dw3x3").unwrap();
            assert!(!dwt.fast_layer && !dwt.winograd);

            // Write-into path over dirty arenas, twice; grow pins.
            let mut ws = Workspace::with_capacity(m.workspace_elems());
            let mut acts = Workspace::with_capacity(m.activation_plan().peak_elems());
            acts.take(m.activation_plan().peak_elems()).fill(f32::NAN);
            let mut out = vec![f32::NAN; want.len()];
            for _ in 0..2 {
                m.run_planned_into(&input, None, &mut ws, &mut acts, &mut out).unwrap();
                assert_eq!(out, want.data(), "{scheme}: run_planned_into != reference");
            }
            assert_eq!(ws.grow_count(), 0);
            assert_eq!(acts.grow_count(), 0);
            // Dispatch totals: census × 3 completed walks.
            let counts = m.dispatch_counts();
            match scheme {
                Scheme::Im2RowOnly => assert_eq!(counts.im2row, 6),
                Scheme::WinogradWhereSuitable => assert_eq!(counts.pointwise, 6),
            }
            assert_eq!(counts.depthwise, 3, "{scheme}");
            assert_eq!(counts.total(), 9, "{scheme}");
            outputs.push(want.data().to_vec());
        }
        // The pointwise binding and the residual fusion are both
        // bit-identical to the im2row + separate-pass baseline, so the two
        // schemes agree exactly on this (Winograd-free) block.
        assert_eq!(outputs[0], outputs[1], "schemes must agree bitwise");
    }

    /// A ResNet-style bottleneck with identity shortcut: 1×1 reduce (ReLU)
    /// → 3×3 (ReLU) → 1×1 expand (linear) → Add(input, expand) → Relu. On
    /// the "ours" scheme the expand → add → relu tail collapses into one
    /// fused pointwise GEMM whose conv/add intermediates get zero-size plan
    /// slots; the planned walk must match the unfused reference bit for
    /// bit — and, since every GEMM operand is identical, the im2row
    /// baseline scheme too.
    #[test]
    fn resnet_bottleneck_fused_chain_matches_reference_bitwise() {
        let mut g = Graph::new();
        let input = g.input();
        let c = 8usize;
        let reduce = Conv2d::new(c, 4, (1, 1));
        let wr = reduce.random_weights(31);
        let n_r = g.add(
            "reduce",
            Op::Conv { desc: reduce, weights: wr, bias: vec![0.02; 4], act: Activation::Relu },
            &[input],
        );
        let mid = Conv2d::new(4, 4, (3, 3)).with_padding((1, 1));
        let wm = mid.random_weights(32);
        let n_m = g.add(
            "mid3x3",
            Op::Conv { desc: mid, weights: wm, bias: vec![0.01; 4], act: Activation::Relu },
            &[n_r],
        );
        let expand = Conv2d::new(4, c, (1, 1));
        let we = expand.random_weights(33);
        let n_e = g.add(
            "expand",
            Op::Conv { desc: expand, weights: we, bias: vec![0.0; c], act: Activation::None },
            &[n_m],
        );
        let n_a = g.add("shortcut", Op::Add, &[input, n_e]);
        g.add("post_relu", Op::Relu, &[n_a]);

        let input_t = Tensor::randn(&[1, 9, 9, c], 41);
        let mut outs: Vec<Vec<f32>> = Vec::new();
        for scheme in [Scheme::Im2RowOnly, Scheme::WinogradWhereSuitable] {
            let m = PreparedModel::prepare("bottleneck", &g, &[1, 9, 9, c], scheme).unwrap();
            let census = m.dispatch_census();
            if scheme == Scheme::WinogradWhereSuitable {
                // reduce (unfused) + expand (fused chain head) both count
                // as pointwise dispatches; the 3×3 at 4·4 = 16 below the
                // channel-product gate stays im2row on both schemes.
                assert_eq!(census.pointwise, 2);
                assert_eq!(census.im2row, 1);
                let plan = m.activation_plan();
                assert_eq!(plan.slot(n_e).elems, 0, "fused expand slot");
                assert_eq!(plan.slot(n_a).elems, 0, "fused add slot");
                assert!(plan.slot(n_a + 1).elems > 0, "relu tail carries the output");
            } else {
                assert_eq!(census.pointwise, 0);
                assert_eq!(census.im2row, 3);
            }
            let want = run_reference(&m, &input_t);
            // The post-add ReLU actually fires: no negatives survive, and
            // some lanes clamp to exactly zero.
            assert!(want.data().iter().all(|&v| v >= 0.0));
            assert!(want.data().iter().any(|&v| v == 0.0));
            let (got, timings) = m.run(&input_t, None).unwrap();
            assert_eq!(got.data(), want.data(), "{scheme}: planned != reference");
            assert_eq!(timings.len(), g.nodes.len());
            outs.push(got.data().to_vec());
        }
        assert_eq!(outs[0], outs[1], "fused ours == unfused baseline, bitwise");
    }

    /// Int8 preparation of the MobileNet-flavoured residual block: every
    /// conv binds a quantized engine — identically on *both* schemes, since
    /// the dtype question (Winograd needs f32 headroom) overrides the
    /// scheme split — the planned executor matches the allocating reference
    /// bit for bit, the int8 census lanes report the bindings, the arenas
    /// never regrow (the byte-ceiled quantized sizing is exact), and the
    /// quantized output tracks the f32 oracle within the subsystem's drift
    /// budget.
    #[test]
    fn quantized_residual_block_binds_int8_engines() {
        let g = residual_block_graph(43);
        let input = Tensor::randn(&[1, 10, 10, 8], 91);
        let f32_m =
            PreparedModel::prepare("mbblock", &g, &[1, 10, 10, 8], Scheme::Im2RowOnly).unwrap();
        let (oracle, _) = f32_m.run(&input, None).unwrap();
        let mut outs: Vec<Vec<f32>> = Vec::new();
        for scheme in [Scheme::Im2RowOnly, Scheme::WinogradWhereSuitable] {
            let m = PreparedModel::prepare_with_dtype(
                "mbblock",
                &g,
                &[1, 10, 10, 8],
                scheme,
                Dtype::Int8,
            )
            .unwrap();
            assert_eq!(m.dtype, Dtype::Int8);
            // Census: both 1×1 convs bind the int8 pointwise engine, the
            // 3×3 the int8 depthwise engine; no residual fusion at int8
            // (the fused add would mix quantized and f32 domains), and no
            // f32 lane sees any traffic.
            let census = m.dispatch_census();
            assert_eq!(census.pointwise_i8, 2, "{scheme}");
            assert_eq!(census.depthwise_i8, 1, "{scheme}");
            assert_eq!(census.total(), 3, "{scheme}: f32 lanes must stay empty");

            let want = run_reference(&m, &input);
            let (got, timings) = m.run(&input, None).unwrap();
            assert_eq!(got.data(), want.data(), "{scheme}: planned != reference");
            assert_eq!(timings.len(), g.nodes.len());

            // Write-into path over dirty arenas, twice; grow pins.
            let mut ws = Workspace::with_capacity(m.workspace_elems());
            let mut acts = Workspace::with_capacity(m.activation_plan().peak_elems());
            acts.take(m.activation_plan().peak_elems()).fill(f32::NAN);
            let mut out = vec![f32::NAN; want.len()];
            for _ in 0..2 {
                m.run_planned_into(&input, None, &mut ws, &mut acts, &mut out).unwrap();
                assert_eq!(out, want.data(), "{scheme}: run_planned_into != reference");
            }
            assert_eq!(ws.grow_count(), 0, "{scheme}");
            assert_eq!(acts.grow_count(), 0, "{scheme}");
            // Running totals: census × 3 completed walks, all int8 lanes.
            let counts = m.dispatch_counts();
            assert_eq!(counts.pointwise_i8, 6, "{scheme}");
            assert_eq!(counts.depthwise_i8, 3, "{scheme}");
            assert_eq!(counts.total(), 9, "{scheme}");

            // Drift vs the f32 oracle: finite everywhere and inside the
            // budget the whole-network gate pins (rel 0.25 of peak |y|).
            assert!(got.data().iter().all(|v| v.is_finite()), "{scheme}");
            let max_abs = oracle.data().iter().fold(0f32, |a, &v| a.max(v.abs()));
            let drift = got
                .data()
                .iter()
                .zip(oracle.data())
                .fold(0f32, |a, (&x, &y)| a.max((x - y).abs()));
            assert!(
                drift <= 0.25 * max_abs,
                "{scheme}: int8 drift {drift} vs f32 peak {max_abs}"
            );
            outs.push(got.data().to_vec());
        }
        assert_eq!(outs[0], outs[1], "int8 binds identically on both schemes");
    }

    /// Dense 3×3 layers at int8 route to the quantized im2row GEMM — never
    /// Winograd, even on the "ours" scheme where their f32 twins would be
    /// Winograd-bound.
    #[test]
    fn quantized_dense_graph_routes_im2row_i8() {
        let g = tiny_graph(23);
        let m = PreparedModel::prepare_with_dtype(
            "tiny",
            &g,
            &[1, 8, 8, 3],
            Scheme::WinogradWhereSuitable,
            Dtype::Int8,
        )
        .unwrap();
        let census = m.dispatch_census();
        assert_eq!(census.im2row_i8, 2, "both 3×3 convs quantize");
        assert_eq!(census.winograd, 0, "winograd never binds at int8");
        assert_eq!(census.total(), 2);
        let input = Tensor::randn(&[1, 8, 8, 3], 5);
        let want = run_reference(&m, &input);
        let (got, timings) = m.run(&input, None).unwrap();
        assert_eq!(got.data(), want.data(), "planned != reference");
        assert_eq!(timings.len(), g.nodes.len());
        // Softmax tail: a valid distribution, near the f32 oracle's.
        let f32_m = PreparedModel::prepare("tiny", &g, &[1, 8, 8, 3], Scheme::Im2RowOnly).unwrap();
        let (oracle, _) = f32_m.run(&input, None).unwrap();
        assert!(got.data().iter().all(|v| v.is_finite() && *v >= 0.0));
        let drift = got
            .data()
            .iter()
            .zip(oracle.data())
            .fold(0f32, |a, (&x, &y)| a.max((x - y).abs()));
        assert!(drift <= 0.25, "softmax drift {drift} vs f32 oracle");
    }

    /// The trace-span census and the roofline cost model are static
    /// prepare-time facts — hand-counted here against the engine stage
    /// model (f32 engines 2 stage spans, int8 engines 3, one layer span
    /// per executed node).
    #[test]
    fn trace_census_and_layer_costs_are_static() {
        let g = tiny_graph(47);
        let m =
            PreparedModel::prepare("tiny", &g, &[1, 8, 8, 3], Scheme::WinogradWhereSuitable)
                .unwrap();
        // 7 executed nodes (input is passthrough) + 2 stage spans each for
        // conv1 (im2row — 3·8 channels below the Winograd gate) and conv2
        // (Winograd-bound).
        assert_eq!(m.trace_spans_per_walk(), 11);
        let infos = m.layer_infos();
        assert_eq!(infos.len(), 7);
        assert!(infos.iter().all(|i| i.cost.flops > 0 && i.cost.bytes > 0));
        let conv2 = infos.iter().find(|i| i.name == "conv2").unwrap();
        assert_eq!(conv2.algo, trace::AlgoCode::Winograd);
        assert_eq!(conv2.kind, "conv");
        assert_eq!(conv2.out_shape, vec![1, 8, 8, 16]);
        // 2 FLOPs per MAC × out elems × taps (3·3·8), f32 traffic on
        // input (8·8·8), output (8·8·16) and weights (16·3·3·8).
        assert_eq!(conv2.cost.flops, 2 * (8 * 8 * 16) * (3 * 3 * 8));
        assert_eq!(conv2.cost.bytes, (512 + 1024) * 4 + 1152 * 4);
        let fc = infos.iter().find(|i| i.name == "fc").unwrap();
        assert_eq!(fc.algo, trace::AlgoCode::None);
        assert_eq!(fc.cost.flops, 2 * 240, "fc: 2·N·K·M with [24,10] weights");

        // Int8 binding: every quantized engine records 3 stage spans, and
        // its offline-quantized weights stream at 1 byte/element.
        let g8 = residual_block_graph(49);
        let m8 = PreparedModel::prepare_with_dtype(
            "mbblock",
            &g8,
            &[1, 10, 10, 8],
            Scheme::Im2RowOnly,
            Dtype::Int8,
        )
        .unwrap();
        // input 0 + three quantized convs (1+3 each) + add 1 + clamp 1.
        assert_eq!(m8.trace_spans_per_walk(), 14);
        let pw = m8.layer_infos().into_iter().find(|i| i.name == "pw_expand").unwrap();
        assert_eq!(pw.algo, trace::AlgoCode::PointwiseI8);
        assert_eq!(pw.cost.bytes, (800 + 1600) * 4 + 16 * 8);

        // The f32 "ours" residual block fuses pw_linear → add → clamp into
        // one PointwiseResidual at the clamp's position: 3 executed nodes,
        // each 1 layer + 2 stage spans.
        let gf = residual_block_graph(49);
        let mf = PreparedModel::prepare(
            "mbblock",
            &gf,
            &[1, 10, 10, 8],
            Scheme::WinogradWhereSuitable,
        )
        .unwrap();
        assert_eq!(mf.trace_spans_per_walk(), 9);
        let infos = mf.layer_infos();
        assert_eq!(infos.len(), 3);
        let fused = infos.iter().find(|i| i.name == "clamp").unwrap();
        assert_eq!(fused.algo, trace::AlgoCode::Pointwise);
        assert_eq!(fused.kind, "conv", "the fused chain profiles as its conv");
    }

    /// Tracing integration: with the sink enabled, planned walks record a
    /// layer span per executed node carrying the algo/shape `layer_infos`
    /// describes, the engines add their stage spans, the roofline join
    /// profiles every node — and the arenas still never grow. Lower-bound
    /// assertions only: other tests may record into the global sink during
    /// our enabled window (exact counts are pinned by the `ablation_trace`
    /// bench in its own process).
    #[test]
    fn traced_walk_records_layer_and_stage_spans() {
        let _guard = trace::TEST_LOCK.lock().unwrap();
        let g = residual_block_graph(53);
        let m = PreparedModel::prepare(
            "mbblock",
            &g,
            &[1, 10, 10, 8],
            Scheme::WinogradWhereSuitable,
        )
        .unwrap();
        let walks = 2usize;
        trace::reserve(4096.max(walks * m.trace_spans_per_walk() + 256));
        let input = Tensor::randn(&[1, 10, 10, 8], 3);
        let mut ws = Workspace::with_capacity(m.workspace_elems());
        let mut acts = Workspace::with_capacity(m.activation_plan().peak_elems());
        let mut out = vec![0.0f32; m.output_shape().iter().product()];
        trace::set_enabled(true);
        for _ in 0..walks {
            m.run_planned_into(&input, None, &mut ws, &mut acts, &mut out).unwrap();
        }
        trace::set_enabled(false);
        let spans = trace::take();
        assert!(
            spans.len() >= walks * m.trace_spans_per_walk(),
            "{} spans < {} walks × {} per walk",
            spans.len(),
            walks,
            m.trace_spans_per_walk()
        );
        // Tracing must not break the zero-alloc walk.
        assert_eq!(ws.grow_count(), 0, "tracing grew the scratch arena");
        assert_eq!(acts.grow_count(), 0, "tracing grew the activation arena");
        let infos = m.layer_infos();
        for info in &infos {
            let dim = |i: usize| info.out_shape.get(i).copied().unwrap_or(1) as u32;
            let want = [dim(0), dim(1), dim(2), dim(3)];
            let n = spans
                .iter()
                .filter(|s| {
                    s.kind == trace::SpanKind::Layer
                        && s.layer == info.node
                        && s.algo == info.algo
                        && s.shape == want
                })
                .count();
            assert!(n >= walks, "node {} ({}): {n} layer spans", info.node, info.name);
        }
        // Stage spans attribute to our executed conv nodes.
        for node in [1u32, 2] {
            assert!(
                spans
                    .iter()
                    .any(|s| s.kind == trace::SpanKind::Stage && s.layer == node),
                "no stage span for node {node}"
            );
        }
        // Roofline join + render over the real spans.
        let ps = trace::roofline::build_profiles(&infos, &spans);
        assert_eq!(ps.len(), infos.len(), "every executed node profiles");
        assert!(ps.iter().all(|p| p.spans >= walks as u64));
        let table = trace::roofline::render("mbblock roofline", &ps);
        assert!(table.contains("pw_expand") && table.contains("network:"));
    }

    /// Shape inference guards the new ops: Add requires exactly two
    /// same-shape inputs.
    #[test]
    fn add_shape_inference_guards() {
        let mut g = Graph::new();
        let input = g.input();
        let pool = g.add(
            "pool",
            Op::MaxPool { kernel: (2, 2), stride: (2, 2), pad: (0, 0), ceil: false },
            &[input],
        );
        g.add("bad_add", Op::Add, &[input, pool]);
        assert!(g.infer_shapes(&[1, 8, 8, 3]).is_err());
        let mut g = Graph::new();
        let input = g.input();
        g.add("unary_add", Op::Add, &[input]);
        assert!(g.infer_shapes(&[1, 8, 8, 3]).is_err());
    }
}
