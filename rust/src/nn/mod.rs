//! Neural-network graph layer: ops, DAG, shape inference and the prepared
//! executor used by the whole-network benchmarks (Table 1, Figure 3) and
//! the serving coordinator.

pub mod ops;
pub mod graph;

pub use graph::{Graph, LayerTiming, Node, NodeId, Op, PreparedModel, Scheme};
