//! Neural-network graph layer: ops, DAG, shape inference, the prepare-time
//! activation memory planner and the planned executor used by the
//! whole-network benchmarks (Table 1, Figure 3) and the serving
//! coordinator.

pub mod ops;
pub mod graph;
pub mod plan;

pub use graph::{
    DispatchCounts, Graph, LayerTiming, Node, NodeId, Op, PreparedBatch, PreparedModel, Scheme,
};
pub use plan::{ActivationPlan, ActivationSlot};
