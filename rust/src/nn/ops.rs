//! Non-convolution inference ops (pooling, activation, concat, FC, softmax,
//! LRN) over NHWC tensors. These are the supporting cast for whole-network
//! benchmarks — correctness-critical, SIMD where it is free (channel-inner
//! loops autovectorize), but not the paper's hot path.
//!
//! Every op ships in two forms: a `*_into` core that reads a borrowed
//! [`TensorView`] and writes a caller-provided slice (fully overwritten, so
//! dirty arena memory is fine — this is what the planned executor in
//! [`crate::nn::PreparedModel`] calls against activation-arena windows),
//! and the original allocating wrapper kept for tests and one-shot use.

use crate::gemm::{sgemm_simple, Activation};
use crate::tensor::{Tensor, TensorView};
use crate::{bail_shape, Result};

/// Validate an NHWC pooling op's geometry and derive the output spatial
/// extents — the single copy of the guards and the output formula both
/// entry points share.
fn checked_pool_out_hw(
    shape: &[usize],
    k: (usize, usize),
    s: (usize, usize),
    p: (usize, usize),
    ceil_mode: bool,
) -> Result<(usize, usize)> {
    if shape.len() != 4 {
        bail_shape!("pool2d expects NHWC rank-4, got {shape:?}");
    }
    let (h, w) = (shape[1], shape[2]);
    if s.0 == 0 || s.1 == 0 || k.0 == 0 || k.1 == 0 {
        bail_shape!("pool kernel/stride must be positive");
    }
    if h + 2 * p.0 < k.0 || w + 2 * p.1 < k.1 {
        bail_shape!("input {h}x{w} too small for pool {k:?} pad {p:?}");
    }
    let span_h = h + 2 * p.0 - k.0;
    let span_w = w + 2 * p.1 - k.1;
    if ceil_mode {
        Ok((span_h.div_ceil(s.0) + 1, span_w.div_ceil(s.1) + 1))
    } else {
        Ok((span_h / s.0 + 1, span_w / s.1 + 1))
    }
}

/// Max pooling with window `k`, stride `s`, symmetric padding `p`
/// (padding contributes −∞, i.e. is ignored).
pub fn max_pool2d(
    input: &Tensor,
    k: (usize, usize),
    s: (usize, usize),
    p: (usize, usize),
    ceil_mode: bool,
) -> Result<Tensor> {
    pool2d_alloc(input, k, s, p, ceil_mode, PoolKind::Max)
}

/// Average pooling (padding excluded from the divisor, as in Caffe/ACL).
pub fn avg_pool2d(
    input: &Tensor,
    k: (usize, usize),
    s: (usize, usize),
    p: (usize, usize),
    ceil_mode: bool,
) -> Result<Tensor> {
    pool2d_alloc(input, k, s, p, ceil_mode, PoolKind::Avg)
}

/// [`max_pool2d`] writing into a caller-provided `N·OH·OW·C` slice.
pub fn max_pool2d_into(
    input: &TensorView,
    k: (usize, usize),
    s: (usize, usize),
    p: (usize, usize),
    ceil_mode: bool,
    out: &mut [f32],
) -> Result<()> {
    pool2d_into(input, k, s, p, ceil_mode, PoolKind::Max, out)
}

/// [`avg_pool2d`] writing into a caller-provided `N·OH·OW·C` slice.
pub fn avg_pool2d_into(
    input: &TensorView,
    k: (usize, usize),
    s: (usize, usize),
    p: (usize, usize),
    ceil_mode: bool,
    out: &mut [f32],
) -> Result<()> {
    pool2d_into(input, k, s, p, ceil_mode, PoolKind::Avg, out)
}

#[derive(Clone, Copy)]
enum PoolKind {
    Max,
    Avg,
}

fn pool2d_alloc(
    input: &Tensor,
    k: (usize, usize),
    s: (usize, usize),
    p: (usize, usize),
    ceil_mode: bool,
    kind: PoolKind,
) -> Result<Tensor> {
    let (oh, ow) = checked_pool_out_hw(input.shape(), k, s, p, ceil_mode)?;
    let (n, c) = (input.shape()[0], input.shape()[3]);
    let mut out = Tensor::zeros(&[n, oh, ow, c]);
    pool2d_into(&input.view(), k, s, p, ceil_mode, kind, out.data_mut())?;
    Ok(out)
}

fn pool2d_into(
    input: &TensorView,
    k: (usize, usize),
    s: (usize, usize),
    p: (usize, usize),
    ceil_mode: bool,
    kind: PoolKind,
    out: &mut [f32],
) -> Result<()> {
    let (oh, ow) = checked_pool_out_hw(input.shape(), k, s, p, ceil_mode)?;
    let (n, h, w, c) = (
        input.shape()[0],
        input.shape()[1],
        input.shape()[2],
        input.shape()[3],
    );
    if out.len() != n * oh * ow * c {
        bail_shape!("pool output slice has {} elems, op writes {}", out.len(), n * oh * ow * c);
    }
    let src = input.data();
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let y0 = (oy * s.0) as isize - p.0 as isize;
                let x0 = (ox * s.1) as isize - p.1 as isize;
                let y_lo = y0.max(0) as usize;
                let x_lo = x0.max(0) as usize;
                let y_hi = ((y0 + k.0 as isize) as usize).min(h);
                let x_hi = ((x0 + k.1 as isize) as usize).min(w);
                let count = ((y_hi - y_lo) * (x_hi - x_lo)).max(1) as f32;
                let dst_base = ((b * oh + oy) * ow + ox) * c;
                // Initialise — the destination may be dirty arena memory.
                let init = match kind {
                    PoolKind::Max => f32::NEG_INFINITY,
                    PoolKind::Avg => 0.0,
                };
                out[dst_base..dst_base + c].fill(init);
                for iy in y_lo..y_hi {
                    for ix in x_lo..x_hi {
                        let s0 = input.idx4(b, iy, ix, 0);
                        match kind {
                            PoolKind::Max => {
                                for ch in 0..c {
                                    let v = src[s0 + ch];
                                    let d = &mut out[dst_base + ch];
                                    if v > *d {
                                        *d = v;
                                    }
                                }
                            }
                            PoolKind::Avg => {
                                for ch in 0..c {
                                    out[dst_base + ch] += src[s0 + ch];
                                }
                            }
                        }
                    }
                }
                if let PoolKind::Avg = kind {
                    for ch in 0..c {
                        out[dst_base + ch] /= count;
                    }
                }
            }
        }
    }
    Ok(())
}

/// Global average pooling: `[N, H, W, C] → [N, 1, 1, C]`.
pub fn global_avg_pool(input: &Tensor) -> Result<Tensor> {
    if input.rank() != 4 {
        bail_shape!("global_avg_pool expects rank-4, got {:?}", input.shape());
    }
    let (n, c) = (input.shape()[0], input.shape()[3]);
    let mut out = Tensor::zeros(&[n, 1, 1, c]);
    global_avg_pool_into(&input.view(), out.data_mut())?;
    Ok(out)
}

/// [`global_avg_pool`] writing into a caller-provided `N·C` slice.
pub fn global_avg_pool_into(input: &TensorView, out: &mut [f32]) -> Result<()> {
    if input.rank() != 4 {
        bail_shape!("global_avg_pool expects rank-4, got {:?}", input.shape());
    }
    let (n, h, w, c) = (
        input.shape()[0],
        input.shape()[1],
        input.shape()[2],
        input.shape()[3],
    );
    if out.len() != n * c {
        bail_shape!("gap output slice has {} elems, op writes {}", out.len(), n * c);
    }
    let scale = 1.0 / (h * w) as f32;
    out.fill(0.0);
    for b in 0..n {
        for y in 0..h {
            for x in 0..w {
                let px = input.pixel(b, y, x);
                for ch in 0..c {
                    out[b * c + ch] += px[ch] * scale;
                }
            }
        }
    }
    Ok(())
}

/// In-place ReLU.
pub fn relu_inplace(t: &mut Tensor) {
    act_inplace(t, Activation::Relu)
}

/// In-place activation (no-op for [`Activation::None`]).
pub fn act_inplace(t: &mut Tensor, act: Activation) {
    if act.is_none() {
        return;
    }
    for v in t.data_mut() {
        *v = act.apply(*v);
    }
}

/// ReLU6 (`min(max(x, 0), 6)` — the MobileNet clamp) over a flat input
/// slice, writing into a caller-provided slice of the same length (fully
/// overwritten). The standalone-op form; conv layers fuse it through their
/// epilogues instead.
pub fn relu6_into(input: &[f32], out: &mut [f32]) -> Result<()> {
    if out.len() != input.len() {
        bail_shape!("relu6 output slice has {} elems, input {}", out.len(), input.len());
    }
    for (o, &x) in out.iter_mut().zip(input) {
        *o = Activation::Relu6.apply(x);
    }
    Ok(())
}

/// Allocating wrapper over [`relu6_into`].
pub fn relu6(input: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(input.shape());
    relu6_into(input.data(), out.data_mut()).expect("same-size output");
    out
}

/// Standalone ReLU over a flat input slice, writing into a caller-provided
/// slice of the same length (fully overwritten) — the post-add activation
/// of a ResNet residual block. Conv layers fuse ReLU through their
/// epilogues instead, and fused `Conv(1×1) → Add → Relu` chains apply it
/// inside the pointwise GEMM's residual epilogue.
pub fn relu_into(input: &[f32], out: &mut [f32]) -> Result<()> {
    if out.len() != input.len() {
        bail_shape!("relu output slice has {} elems, input {}", out.len(), input.len());
    }
    for (o, &x) in out.iter_mut().zip(input) {
        *o = Activation::Relu.apply(x);
    }
    Ok(())
}

/// Allocating wrapper over [`relu_into`].
pub fn relu(input: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(input.shape());
    relu_into(input.data(), out.data_mut()).expect("same-size output");
    out
}

/// Elementwise residual add (`out = a + b`) over two same-length flat
/// slices, writing into a caller-provided slice (fully overwritten) — the
/// MobileNetV2 inverted-residual skip connection. The channel-inner loop
/// autovectorizes.
pub fn add_into(a: &[f32], b: &[f32], out: &mut [f32]) -> Result<()> {
    if a.len() != b.len() {
        bail_shape!("add operands differ: {} vs {} elems", a.len(), b.len());
    }
    if out.len() != a.len() {
        bail_shape!("add output slice has {} elems, op writes {}", out.len(), a.len());
    }
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = x + y;
    }
    Ok(())
}

/// Allocating wrapper over [`add_into`]; shapes must match exactly.
pub fn add_elementwise(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.shape() != b.shape() {
        bail_shape!("add shape mismatch: {:?} vs {:?}", a.shape(), b.shape());
    }
    let mut out = Tensor::zeros(a.shape());
    add_into(a.data(), b.data(), out.data_mut())?;
    Ok(out)
}

/// Add a per-channel bias (length C) in place, optionally fused with ReLU.
/// Back-compat shorthand for [`bias_act_inplace`].
pub fn bias_relu_inplace(t: &mut Tensor, bias: &[f32], relu: bool) -> Result<()> {
    bias_act_inplace(t, bias, Activation::from_relu(relu))
}

/// Add a per-channel bias (length C) in place, fused with an activation.
///
/// No longer on the fused conv execution paths: every conv engine fuses
/// bias/activation into its epilogue ([`crate::gemm::Epilogue`], the
/// depthwise register epilogue), so conv outputs are written exactly once.
/// Kept as the oracle the `Direct` conv path (and tests) apply as a post
/// pass.
pub fn bias_act_inplace(t: &mut Tensor, bias: &[f32], act: Activation) -> Result<()> {
    if t.rank() != 4 || t.shape()[3] != bias.len() {
        bail_shape!("bias length {} vs channels {:?}", bias.len(), t.shape());
    }
    let c = bias.len();
    for px in t.data_mut().chunks_mut(c) {
        for (v, b) in px.iter_mut().zip(bias) {
            *v = act.apply(*v + *b);
        }
    }
    Ok(())
}

/// Copy one NHWC part into its channel stripe `[c_off, c_off+part_c)` of a
/// concat output with `c_total` channels. The planned executor calls this
/// once per concat input against the output's arena window, so no
/// per-inference list of parts is ever built; [`concat_channels`] wraps it.
pub fn concat_channels_into_part(
    part: &TensorView,
    c_off: usize,
    c_total: usize,
    out: &mut [f32],
) -> Result<()> {
    if part.rank() != 4 {
        bail_shape!("concat expects rank-4 parts, got {:?}", part.shape());
    }
    let (n, h, w, pc) = (
        part.shape()[0],
        part.shape()[1],
        part.shape()[2],
        part.shape()[3],
    );
    if c_off + pc > c_total || out.len() != n * h * w * c_total {
        bail_shape!(
            "concat stripe [{c_off}, {}) of {c_total} channels vs out len {}",
            c_off + pc,
            out.len()
        );
    }
    for b in 0..n {
        for y in 0..h {
            for x in 0..w {
                let src = part.pixel(b, y, x);
                let dst = ((b * h + y) * w + x) * c_total + c_off;
                out[dst..dst + pc].copy_from_slice(src);
            }
        }
    }
    Ok(())
}

/// Concatenate NHWC tensors along the channel axis.
pub fn concat_channels(parts: &[&Tensor]) -> Result<Tensor> {
    if parts.is_empty() {
        bail_shape!("concat of zero tensors");
    }
    let (n, h, w) = (parts[0].shape()[0], parts[0].shape()[1], parts[0].shape()[2]);
    let mut c_total = 0;
    for p in parts {
        if p.rank() != 4 || p.shape()[0] != n || p.shape()[1] != h || p.shape()[2] != w {
            bail_shape!("concat spatial mismatch: {:?} vs [{n},{h},{w},_]", p.shape());
        }
        c_total += p.shape()[3];
    }
    let mut out = Tensor::zeros(&[n, h, w, c_total]);
    let mut c_off = 0;
    for p in parts {
        concat_channels_into_part(&p.view(), c_off, c_total, out.data_mut())?;
        c_off += p.shape()[3];
    }
    Ok(out)
}

/// Fully-connected layer: flatten to `[N, K]`, multiply `[K, M]`, add bias.
pub fn fully_connected(
    input: &Tensor,
    weights: &Tensor,
    bias: &[f32],
    relu: bool,
) -> Result<Tensor> {
    if weights.rank() != 2 {
        bail_shape!("fc weights must be [K, M], got {:?}", weights.shape());
    }
    let n = input.shape()[0];
    let mut out = Tensor::zeros(&[n, weights.shape()[1]]);
    fully_connected_into(input.data(), n, weights, bias, relu, out.data_mut())?;
    Ok(out)
}

/// [`fully_connected`] over an already-flattened `[N, K]` input slice,
/// writing into a caller-provided `N·M` slice (fully overwritten).
pub fn fully_connected_into(
    input: &[f32],
    n: usize,
    weights: &Tensor,
    bias: &[f32],
    relu: bool,
    out: &mut [f32],
) -> Result<()> {
    if n == 0 || input.len() % n != 0 {
        bail_shape!("fc input of {} elems does not split into {n} rows", input.len());
    }
    let k = input.len() / n;
    if weights.rank() != 2 || weights.shape()[0] != k || weights.shape()[1] != bias.len() {
        bail_shape!(
            "fc weights {:?} incompatible with input K={k}, bias {}",
            weights.shape(),
            bias.len()
        );
    }
    let m = weights.shape()[1];
    if out.len() != n * m {
        bail_shape!("fc output slice has {} elems, op writes {}", out.len(), n * m);
    }
    sgemm_simple(n, m, k, input, weights.data(), out);
    for row in out.chunks_mut(m) {
        for (v, b) in row.iter_mut().zip(bias) {
            *v += *b;
            if relu && *v < 0.0 {
                *v = 0.0;
            }
        }
    }
    Ok(())
}

/// Row-wise softmax over the last axis of a rank-2 tensor.
pub fn softmax(input: &Tensor) -> Result<Tensor> {
    if input.rank() != 2 {
        bail_shape!("softmax expects [N, M], got {:?}", input.shape());
    }
    let mut out = Tensor::zeros(input.shape());
    softmax_into(input.data(), input.shape()[1], out.data_mut())?;
    Ok(out)
}

/// Row-wise softmax over `cols`-wide rows of a flat input slice, writing
/// into a caller-provided slice of the same length (fully overwritten).
pub fn softmax_into(input: &[f32], cols: usize, out: &mut [f32]) -> Result<()> {
    if cols == 0 || input.len() % cols != 0 {
        bail_shape!("softmax input of {} elems does not split into {cols}-wide rows", input.len());
    }
    if out.len() != input.len() {
        bail_shape!("softmax output slice has {} elems, input {}", out.len(), input.len());
    }
    for (src, row) in input.chunks(cols).zip(out.chunks_mut(cols)) {
        let max = src.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for (v, &s) in row.iter_mut().zip(src) {
            *v = (s - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    Ok(())
}

/// Local response normalisation across channels (GoogleNet/AlexNet style):
/// `out = in / (k + α/n · Σ_{window} in²)^β`.
pub fn lrn_across_channels(
    input: &Tensor,
    size: usize,
    alpha: f32,
    beta: f32,
    k: f32,
) -> Result<Tensor> {
    let mut out = Tensor::zeros(input.shape());
    lrn_across_channels_into(&input.view(), size, alpha, beta, k, out.data_mut())?;
    Ok(out)
}

/// [`lrn_across_channels`] writing into a caller-provided slice of the
/// input's length (fully overwritten).
pub fn lrn_across_channels_into(
    input: &TensorView,
    size: usize,
    alpha: f32,
    beta: f32,
    k: f32,
    out: &mut [f32],
) -> Result<()> {
    if input.rank() != 4 {
        bail_shape!("lrn expects rank-4, got {:?}", input.shape());
    }
    if out.len() != input.len() {
        bail_shape!("lrn output slice has {} elems, input {}", out.len(), input.len());
    }
    let c = input.shape()[3];
    let half = size / 2;
    let src = input.data();
    for (pix_idx, px) in out.chunks_mut(c).enumerate() {
        let base = pix_idx * c;
        for ch in 0..c {
            let lo = ch.saturating_sub(half);
            let hi = (ch + half + 1).min(c);
            let mut ss = 0.0;
            for j in lo..hi {
                let v = src[base + j];
                ss += v * v;
            }
            px[ch] = src[base + ch] / (k + alpha / size as f32 * ss).powf(beta);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_pool_basic() {
        // 4×4 single-channel ramp, 2×2/2 pool: max of each quadrant.
        let t = Tensor::from_vec(&[1, 4, 4, 1], (0..16).map(|x| x as f32).collect()).unwrap();
        let p = max_pool2d(&t, (2, 2), (2, 2), (0, 0), false).unwrap();
        assert_eq!(p.shape(), &[1, 2, 2, 1]);
        assert_eq!(p.data(), &[5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn max_pool_ceil_mode() {
        // 6×6, 3×3/2: span 3 ⇒ floor 2×2, ceil 3×3 (SqueezeNet/GoogleNet use ceil).
        let t = Tensor::randn(&[1, 6, 6, 2], 1);
        assert_eq!(max_pool2d(&t, (3, 3), (2, 2), (0, 0), false).unwrap().shape(), &[1, 2, 2, 2]);
        assert_eq!(max_pool2d(&t, (3, 3), (2, 2), (0, 0), true).unwrap().shape(), &[1, 3, 3, 2]);
    }

    #[test]
    fn avg_pool_excludes_padding() {
        let t = Tensor::full(&[1, 2, 2, 1], 4.0);
        let p = avg_pool2d(&t, (3, 3), (1, 1), (1, 1), false).unwrap();
        assert_eq!(p.shape(), &[1, 2, 2, 1]);
        // Each window sees the same four 4.0s (padding excluded) ⇒ avg 4.0.
        assert!(p.data().iter().all(|&v| (v - 4.0).abs() < 1e-6));
    }

    #[test]
    fn global_avg_pool_means() {
        let t = Tensor::from_vec(&[1, 2, 2, 2], vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0])
            .unwrap();
        let g = global_avg_pool(&t).unwrap();
        assert_eq!(g.shape(), &[1, 1, 1, 2]);
        assert_eq!(g.data(), &[2.5, 25.0]);
    }

    #[test]
    fn relu_and_bias() {
        let mut t = Tensor::from_vec(&[1, 1, 1, 3], vec![-1.0, 0.5, 2.0]).unwrap();
        bias_relu_inplace(&mut t, &[0.2, -1.0, 0.0], true).unwrap();
        assert_eq!(t.data(), &[0.0, 0.0, 2.0]);
        let mut t = Tensor::from_vec(&[1, 1], vec![-3.0]).unwrap();
        relu_inplace(&mut t);
        assert_eq!(t.data(), &[0.0]);
    }

    #[test]
    fn relu6_clamps_both_sides() {
        let t = Tensor::from_vec(&[1, 1, 1, 4], vec![-2.0, 0.5, 6.0, 9.0]).unwrap();
        let r = relu6(&t);
        assert_eq!(r.data(), &[0.0, 0.5, 6.0, 6.0]);
        let mut t = Tensor::from_vec(&[1, 1, 1, 2], vec![5.0, -4.0]).unwrap();
        bias_act_inplace(&mut t, &[2.0, 2.0], Activation::Relu6).unwrap();
        assert_eq!(t.data(), &[6.0, 0.0]);
        act_inplace(&mut t, Activation::None); // no-op
        assert_eq!(t.data(), &[6.0, 0.0]);
    }

    #[test]
    fn add_elementwise_sums_and_checks_shapes() {
        let a = Tensor::from_vec(&[1, 2, 1, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::from_vec(&[1, 2, 1, 2], vec![10.0, 20.0, 30.0, 40.0]).unwrap();
        let c = add_elementwise(&a, &b).unwrap();
        assert_eq!(c.shape(), a.shape());
        assert_eq!(c.data(), &[11.0, 22.0, 33.0, 44.0]);
        let bad = Tensor::zeros(&[1, 2, 2, 1]);
        assert!(add_elementwise(&a, &bad).is_err());
        assert!(add_into(a.data(), &b.data()[..3], &mut [0.0; 4]).is_err());
        assert!(add_into(a.data(), b.data(), &mut [0.0; 3]).is_err());
    }

    #[test]
    fn concat_interleaves_channels() {
        let a = Tensor::full(&[1, 1, 2, 1], 1.0);
        let b = Tensor::full(&[1, 1, 2, 2], 2.0);
        let c = concat_channels(&[&a, &b]).unwrap();
        assert_eq!(c.shape(), &[1, 1, 2, 3]);
        assert_eq!(c.data(), &[1.0, 2.0, 2.0, 1.0, 2.0, 2.0]);
        let bad = Tensor::zeros(&[1, 2, 2, 1]);
        assert!(concat_channels(&[&a, &bad]).is_err());
    }

    #[test]
    fn fc_matches_manual() {
        let x = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]).unwrap();
        let w = Tensor::from_vec(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]).unwrap();
        let y = fully_connected(&x, &w, &[10.0, -100.0], false).unwrap();
        assert_eq!(y.data(), &[14.0, -95.0]);
        let y = fully_connected(&x, &w, &[10.0, -100.0], true).unwrap();
        assert_eq!(y.data(), &[14.0, 0.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]).unwrap();
        let s = softmax(&x).unwrap();
        for row in s.data().chunks(3) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(row.windows(2).all(|w| w[0] < w[1])); // monotone inputs
        }
    }

    #[test]
    fn lrn_unit_norm_case() {
        // k=1, alpha=0 ⇒ identity.
        let t = Tensor::randn(&[1, 2, 2, 4], 1);
        let l = lrn_across_channels(&t, 5, 0.0, 0.75, 1.0).unwrap();
        assert!(l.allclose(&t, 1e-6));
    }

    /// Every `_into` variant fully overwrites a dirty destination and is
    /// bit-identical to its allocating wrapper.
    #[test]
    fn into_variants_match_allocating_on_dirty_buffers() {
        let t = Tensor::randn(&[2, 5, 6, 3], 9);
        let dirty = |len: usize| vec![f32::NAN; len];

        let want = max_pool2d(&t, (3, 3), (2, 2), (1, 1), true).unwrap();
        let mut out = dirty(want.len());
        max_pool2d_into(&t.view(), (3, 3), (2, 2), (1, 1), true, &mut out).unwrap();
        assert_eq!(out, want.data());

        let want = avg_pool2d(&t, (2, 2), (2, 2), (0, 0), false).unwrap();
        let mut out = dirty(want.len());
        avg_pool2d_into(&t.view(), (2, 2), (2, 2), (0, 0), false, &mut out).unwrap();
        assert_eq!(out, want.data());

        let want = global_avg_pool(&t).unwrap();
        let mut out = dirty(want.len());
        global_avg_pool_into(&t.view(), &mut out).unwrap();
        assert_eq!(out, want.data());

        let u = Tensor::randn(&[2, 5, 6, 2], 10);
        let want = concat_channels(&[&t, &u]).unwrap();
        let mut out = dirty(want.len());
        concat_channels_into_part(&t.view(), 0, 5, &mut out).unwrap();
        concat_channels_into_part(&u.view(), 3, 5, &mut out).unwrap();
        assert_eq!(out, want.data());

        let x = Tensor::randn(&[3, 7], 11);
        let w = Tensor::randn(&[7, 4], 12);
        let bias = [0.5, -0.25, 0.0, 1.0];
        let want = fully_connected(&x, &w, &bias, true).unwrap();
        let mut out = dirty(want.len());
        fully_connected_into(x.data(), 3, &w, &bias, true, &mut out).unwrap();
        assert_eq!(out, want.data());

        let want = softmax(&x).unwrap();
        let mut out = dirty(want.len());
        softmax_into(x.data(), 7, &mut out).unwrap();
        assert_eq!(out, want.data());

        let want = lrn_across_channels(&t, 5, 1e-4, 0.75, 2.0).unwrap();
        let mut out = dirty(want.len());
        lrn_across_channels_into(&t.view(), 5, 1e-4, 0.75, 2.0, &mut out).unwrap();
        assert_eq!(out, want.data());

        let want = relu6(&t);
        let mut out = dirty(want.len());
        relu6_into(t.data(), &mut out).unwrap();
        assert_eq!(out, want.data());

        let u2 = Tensor::randn(&[2, 5, 6, 3], 13);
        let want = add_elementwise(&t, &u2).unwrap();
        let mut out = dirty(want.len());
        add_into(t.data(), u2.data(), &mut out).unwrap();
        assert_eq!(out, want.data());

        // Size mismatches are rejected, not written out of bounds.
        assert!(global_avg_pool_into(&t.view(), &mut dirty(1)).is_err());
        assert!(softmax_into(x.data(), 7, &mut dirty(2)).is_err());
    }
}
