//! Non-convolution inference ops (pooling, activation, concat, FC, softmax,
//! LRN) over NHWC tensors. These are the supporting cast for whole-network
//! benchmarks — correctness-critical, SIMD where it is free (channel-inner
//! loops autovectorize), but not the paper's hot path.

use crate::gemm::sgemm_simple;
use crate::tensor::Tensor;
use crate::{bail_shape, Result};

/// Max pooling with window `k`, stride `s`, symmetric padding `p`
/// (padding contributes −∞, i.e. is ignored).
pub fn max_pool2d(
    input: &Tensor,
    k: (usize, usize),
    s: (usize, usize),
    p: (usize, usize),
    ceil_mode: bool,
) -> Result<Tensor> {
    pool2d(input, k, s, p, ceil_mode, PoolKind::Max)
}

/// Average pooling (padding excluded from the divisor, as in Caffe/ACL).
pub fn avg_pool2d(
    input: &Tensor,
    k: (usize, usize),
    s: (usize, usize),
    p: (usize, usize),
    ceil_mode: bool,
) -> Result<Tensor> {
    pool2d(input, k, s, p, ceil_mode, PoolKind::Avg)
}

#[derive(Clone, Copy)]
enum PoolKind {
    Max,
    Avg,
}

fn pool2d(
    input: &Tensor,
    k: (usize, usize),
    s: (usize, usize),
    p: (usize, usize),
    ceil_mode: bool,
    kind: PoolKind,
) -> Result<Tensor> {
    if input.rank() != 4 {
        bail_shape!("pool2d expects NHWC rank-4, got {:?}", input.shape());
    }
    let (n, h, w, c) = (
        input.shape()[0],
        input.shape()[1],
        input.shape()[2],
        input.shape()[3],
    );
    if s.0 == 0 || s.1 == 0 || k.0 == 0 || k.1 == 0 {
        bail_shape!("pool kernel/stride must be positive");
    }
    if h + 2 * p.0 < k.0 || w + 2 * p.1 < k.1 {
        bail_shape!("input {h}x{w} too small for pool {k:?} pad {p:?}");
    }
    let span_h = h + 2 * p.0 - k.0;
    let span_w = w + 2 * p.1 - k.1;
    let (oh, ow) = if ceil_mode {
        (span_h.div_ceil(s.0) + 1, span_w.div_ceil(s.1) + 1)
    } else {
        (span_h / s.0 + 1, span_w / s.1 + 1)
    };
    let mut out = Tensor::zeros(&[n, oh, ow, c]);
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let y0 = (oy * s.0) as isize - p.0 as isize;
                let x0 = (ox * s.1) as isize - p.1 as isize;
                let y_lo = y0.max(0) as usize;
                let x_lo = x0.max(0) as usize;
                let y_hi = ((y0 + k.0 as isize) as usize).min(h);
                let x_hi = ((x0 + k.1 as isize) as usize).min(w);
                let count = ((y_hi - y_lo) * (x_hi - x_lo)).max(1) as f32;
                let dst_base = out.idx4(b, oy, ox, 0);
                // Initialise.
                match kind {
                    PoolKind::Max => {
                        for ch in 0..c {
                            out.data_mut()[dst_base + ch] = f32::NEG_INFINITY;
                        }
                    }
                    PoolKind::Avg => {}
                }
                for iy in y_lo..y_hi {
                    for ix in x_lo..x_hi {
                        let src = input.idx4(b, iy, ix, 0);
                        match kind {
                            PoolKind::Max => {
                                for ch in 0..c {
                                    let v = input.data()[src + ch];
                                    let d = &mut out.data_mut()[dst_base + ch];
                                    if v > *d {
                                        *d = v;
                                    }
                                }
                            }
                            PoolKind::Avg => {
                                for ch in 0..c {
                                    out.data_mut()[dst_base + ch] += input.data()[src + ch];
                                }
                            }
                        }
                    }
                }
                if let PoolKind::Avg = kind {
                    for ch in 0..c {
                        out.data_mut()[dst_base + ch] /= count;
                    }
                }
            }
        }
    }
    Ok(out)
}

/// Global average pooling: `[N, H, W, C] → [N, 1, 1, C]`.
pub fn global_avg_pool(input: &Tensor) -> Result<Tensor> {
    if input.rank() != 4 {
        bail_shape!("global_avg_pool expects rank-4, got {:?}", input.shape());
    }
    let (n, h, w, c) = (
        input.shape()[0],
        input.shape()[1],
        input.shape()[2],
        input.shape()[3],
    );
    let mut out = Tensor::zeros(&[n, 1, 1, c]);
    let scale = 1.0 / (h * w) as f32;
    for b in 0..n {
        for y in 0..h {
            for x in 0..w {
                let px = input.pixel(b, y, x);
                let dst = out.idx4(b, 0, 0, 0);
                for ch in 0..c {
                    out.data_mut()[dst + ch] += px[ch] * scale;
                }
            }
        }
    }
    Ok(out)
}

/// In-place ReLU.
pub fn relu_inplace(t: &mut Tensor) {
    for v in t.data_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Add a per-channel bias (length C) in place, optionally fused with ReLU.
///
/// No longer on the GEMM-backed conv execution paths: both conv schemes
/// fuse bias/ReLU into their GEMM epilogues ([`crate::gemm::Epilogue`]),
/// so conv outputs are written exactly once. Kept as the oracle the
/// `Direct` conv path (and tests) apply as a post pass.
pub fn bias_relu_inplace(t: &mut Tensor, bias: &[f32], relu: bool) -> Result<()> {
    if t.rank() != 4 || t.shape()[3] != bias.len() {
        bail_shape!("bias length {} vs channels {:?}", bias.len(), t.shape());
    }
    let c = bias.len();
    for px in t.data_mut().chunks_mut(c) {
        for (v, b) in px.iter_mut().zip(bias) {
            *v += *b;
            if relu && *v < 0.0 {
                *v = 0.0;
            }
        }
    }
    Ok(())
}

/// Concatenate NHWC tensors along the channel axis.
pub fn concat_channels(parts: &[&Tensor]) -> Result<Tensor> {
    if parts.is_empty() {
        bail_shape!("concat of zero tensors");
    }
    let (n, h, w) = (parts[0].shape()[0], parts[0].shape()[1], parts[0].shape()[2]);
    let mut c_total = 0;
    for p in parts {
        if p.rank() != 4 || p.shape()[0] != n || p.shape()[1] != h || p.shape()[2] != w {
            bail_shape!("concat spatial mismatch: {:?} vs [{n},{h},{w},_]", p.shape());
        }
        c_total += p.shape()[3];
    }
    let mut out = Tensor::zeros(&[n, h, w, c_total]);
    for b in 0..n {
        for y in 0..h {
            for x in 0..w {
                let mut off = out.idx4(b, y, x, 0);
                for p in parts {
                    let src = p.pixel(b, y, x);
                    out.data_mut()[off..off + src.len()].copy_from_slice(src);
                    off += src.len();
                }
            }
        }
    }
    Ok(out)
}

/// Fully-connected layer: flatten to `[N, K]`, multiply `[K, M]`, add bias.
pub fn fully_connected(
    input: &Tensor,
    weights: &Tensor,
    bias: &[f32],
    relu: bool,
) -> Result<Tensor> {
    let n = input.shape()[0];
    let k: usize = input.shape()[1..].iter().product();
    if weights.rank() != 2 || weights.shape()[0] != k || weights.shape()[1] != bias.len() {
        bail_shape!(
            "fc weights {:?} incompatible with input K={k}, bias {}",
            weights.shape(),
            bias.len()
        );
    }
    let m = weights.shape()[1];
    let mut out = Tensor::zeros(&[n, m]);
    sgemm_simple(n, m, k, input.data(), weights.data(), out.data_mut());
    for row in out.data_mut().chunks_mut(m) {
        for (v, b) in row.iter_mut().zip(bias) {
            *v += *b;
            if relu && *v < 0.0 {
                *v = 0.0;
            }
        }
    }
    Ok(out)
}

/// Row-wise softmax over the last axis of a rank-2 tensor.
pub fn softmax(input: &Tensor) -> Result<Tensor> {
    if input.rank() != 2 {
        bail_shape!("softmax expects [N, M], got {:?}", input.shape());
    }
    let m = input.shape()[1];
    let mut out = input.clone();
    for row in out.data_mut().chunks_mut(m) {
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    Ok(out)
}

/// Local response normalisation across channels (GoogleNet/AlexNet style):
/// `out = in / (k + α/n · Σ_{window} in²)^β`.
pub fn lrn_across_channels(
    input: &Tensor,
    size: usize,
    alpha: f32,
    beta: f32,
    k: f32,
) -> Result<Tensor> {
    if input.rank() != 4 {
        bail_shape!("lrn expects rank-4, got {:?}", input.shape());
    }
    let c = input.shape()[3];
    let half = size / 2;
    let mut out = input.clone();
    let src = input.data();
    for (pix_idx, px) in out.data_mut().chunks_mut(c).enumerate() {
        let base = pix_idx * c;
        for ch in 0..c {
            let lo = ch.saturating_sub(half);
            let hi = (ch + half + 1).min(c);
            let mut ss = 0.0;
            for j in lo..hi {
                let v = src[base + j];
                ss += v * v;
            }
            px[ch] = src[base + ch] / (k + alpha / size as f32 * ss).powf(beta);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_pool_basic() {
        // 4×4 single-channel ramp, 2×2/2 pool: max of each quadrant.
        let t = Tensor::from_vec(&[1, 4, 4, 1], (0..16).map(|x| x as f32).collect()).unwrap();
        let p = max_pool2d(&t, (2, 2), (2, 2), (0, 0), false).unwrap();
        assert_eq!(p.shape(), &[1, 2, 2, 1]);
        assert_eq!(p.data(), &[5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn max_pool_ceil_mode() {
        // 6×6, 3×3/2: span 3 ⇒ floor 2×2, ceil 3×3 (SqueezeNet/GoogleNet use ceil).
        let t = Tensor::randn(&[1, 6, 6, 2], 1);
        assert_eq!(max_pool2d(&t, (3, 3), (2, 2), (0, 0), false).unwrap().shape(), &[1, 2, 2, 2]);
        assert_eq!(max_pool2d(&t, (3, 3), (2, 2), (0, 0), true).unwrap().shape(), &[1, 3, 3, 2]);
    }

    #[test]
    fn avg_pool_excludes_padding() {
        let t = Tensor::full(&[1, 2, 2, 1], 4.0);
        let p = avg_pool2d(&t, (3, 3), (1, 1), (1, 1), false).unwrap();
        assert_eq!(p.shape(), &[1, 2, 2, 1]);
        // Each window sees the same four 4.0s (padding excluded) ⇒ avg 4.0.
        assert!(p.data().iter().all(|&v| (v - 4.0).abs() < 1e-6));
    }

    #[test]
    fn global_avg_pool_means() {
        let t = Tensor::from_vec(&[1, 2, 2, 2], vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0])
            .unwrap();
        let g = global_avg_pool(&t).unwrap();
        assert_eq!(g.shape(), &[1, 1, 1, 2]);
        assert_eq!(g.data(), &[2.5, 25.0]);
    }

    #[test]
    fn relu_and_bias() {
        let mut t = Tensor::from_vec(&[1, 1, 1, 3], vec![-1.0, 0.5, 2.0]).unwrap();
        bias_relu_inplace(&mut t, &[0.2, -1.0, 0.0], true).unwrap();
        assert_eq!(t.data(), &[0.0, 0.0, 2.0]);
        let mut t = Tensor::from_vec(&[1, 1], vec![-3.0]).unwrap();
        relu_inplace(&mut t);
        assert_eq!(t.data(), &[0.0]);
    }

    #[test]
    fn concat_interleaves_channels() {
        let a = Tensor::full(&[1, 1, 2, 1], 1.0);
        let b = Tensor::full(&[1, 1, 2, 2], 2.0);
        let c = concat_channels(&[&a, &b]).unwrap();
        assert_eq!(c.shape(), &[1, 1, 2, 3]);
        assert_eq!(c.data(), &[1.0, 2.0, 2.0, 1.0, 2.0, 2.0]);
        let bad = Tensor::zeros(&[1, 2, 2, 1]);
        assert!(concat_channels(&[&a, &bad]).is_err());
    }

    #[test]
    fn fc_matches_manual() {
        let x = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]).unwrap();
        let w = Tensor::from_vec(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]).unwrap();
        let y = fully_connected(&x, &w, &[10.0, -100.0], false).unwrap();
        assert_eq!(y.data(), &[14.0, -95.0]);
        let y = fully_connected(&x, &w, &[10.0, -100.0], true).unwrap();
        assert_eq!(y.data(), &[14.0, 0.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]).unwrap();
        let s = softmax(&x).unwrap();
        for row in s.data().chunks(3) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(row.windows(2).all(|w| w[0] < w[1])); // monotone inputs
        }
    }

    #[test]
    fn lrn_unit_norm_case() {
        // k=1, alpha=0 ⇒ identity.
        let t = Tensor::randn(&[1, 2, 2, 4], 1);
        let l = lrn_across_channels(&t, 5, 0.0, 0.75, 1.0).unwrap();
        assert!(l.allclose(&t, 1e-6));
    }
}
