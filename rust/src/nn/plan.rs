//! Prepare-time activation memory planner.
//!
//! The conv stack already draws all *scratch* (packed-A blocks, patch
//! matrices, padded-input staging) from a pre-sized [`crate::workspace`]
//! arena — but without a plan, every inference would still heap-allocate
//! each layer's **output** tensor. On the cache-constrained mobile CPUs the
//! paper targets, working-set footprint decides who wins (Zlateski et al.),
//! and peak memory is a first-class axis of its own (Galvez et al.), so
//! intermediate activations are planned here once at
//! [`PreparedModel::prepare`](crate::nn::PreparedModel::prepare) time:
//!
//! 1. **Lifetimes** — each node's output is live from the step that
//!    produces it to the last step that consumes it (the same refcounts the
//!    executor used to free tensors eagerly, turned into intervals).
//! 2. **Greedy interval packing** — nodes are placed largest-first at the
//!    lowest arena offset that does not collide with any already-placed
//!    slot whose lifetime overlaps. Layers with disjoint lifetimes share
//!    bytes, so the arena's [`peak_elems`](ActivationPlan::peak_elems) is
//!    typically far below the naive sum-of-all-intermediates
//!    ([`naive_elems`](ActivationPlan::naive_elems)).
//!
//! The graph input is *borrowed* by the executor (slot of zero elements),
//! never copied into the arena. Execution then walks the plan with
//! borrowed arena views instead of a `Vec<Option<Tensor>>` of owned
//! tensors: steady-state inference performs **zero heap allocation**, end
//! to end.

use super::graph::{Node, Op};

/// One node's placement in the activation arena.
#[derive(Debug, Clone)]
pub struct ActivationSlot {
    /// Arena offset in `f32` elements.
    pub offset: usize,
    /// Output size in `f32` elements (0 for the borrowed graph input).
    pub elems: usize,
    /// Node index producing this value.
    pub first_use: usize,
    /// Last node index reading this value (`== first_use` when unused).
    pub last_use: usize,
}

impl ActivationSlot {
    /// Arena element range `[offset, offset + elems)`.
    pub fn range(&self) -> std::ops::Range<usize> {
        self.offset..self.offset + self.elems
    }

    /// Do two slots overlap in time (both values live at once)?
    fn lifetime_overlaps(&self, other: &ActivationSlot) -> bool {
        self.first_use <= other.last_use && other.first_use <= self.last_use
    }

    /// Do two slots overlap in arena address space?
    fn range_overlaps(&self, other: &ActivationSlot) -> bool {
        self.elems > 0
            && other.elems > 0
            && self.offset < other.offset + other.elems
            && other.offset < self.offset + self.elems
    }
}

/// A packed layout of every intermediate activation of one prepared graph.
#[derive(Debug, Clone)]
pub struct ActivationPlan {
    slots: Vec<ActivationSlot>,
    peak_elems: usize,
    naive_elems: usize,
}

impl ActivationPlan {
    /// Plan the activation arena for a graph in topological order, given
    /// every node's inferred output shape.
    ///
    /// Panics (at prepare time, never at run time) if the greedy packing
    /// ever produced address overlap between two simultaneously-live slots
    /// — the invariant the executor's disjoint arena views rely on.
    pub fn for_graph(nodes: &[Node], shapes: &[Vec<usize>]) -> ActivationPlan {
        assert_eq!(nodes.len(), shapes.len());
        let n = nodes.len();
        // Lifetime end: the last consumer of each value. The final node is
        // read by the caller after the walk, which `last_use = n-1` covers.
        let mut last_use: Vec<usize> = (0..n).collect();
        for (j, node) in nodes.iter().enumerate() {
            for &i in &node.inputs {
                last_use[i] = last_use[i].max(j);
            }
        }
        let mut slots: Vec<ActivationSlot> = (0..n)
            .map(|i| ActivationSlot {
                offset: 0,
                // The graph input is borrowed from the caller, not staged.
                elems: if matches!(nodes[i].op, Op::Input) {
                    0
                } else {
                    shapes[i].iter().product()
                },
                first_use: i,
                last_use: last_use[i],
            })
            .collect();

        // Greedy placement, largest first (deterministic tie-break by
        // index): first-fit at the lowest offset clear of every
        // already-placed, lifetime-overlapping slot.
        let mut order: Vec<usize> = (0..n).filter(|&i| slots[i].elems > 0).collect();
        order.sort_by(|&a, &b| slots[b].elems.cmp(&slots[a].elems).then(a.cmp(&b)));
        let mut placed: Vec<usize> = Vec::with_capacity(order.len());
        let mut busy: Vec<(usize, usize)> = Vec::with_capacity(order.len());
        for &i in &order {
            busy.clear();
            busy.extend(
                placed
                    .iter()
                    .filter(|&&j| slots[i].lifetime_overlaps(&slots[j]))
                    .map(|&j| (slots[j].offset, slots[j].offset + slots[j].elems)),
            );
            busy.sort_unstable();
            let mut offset = 0usize;
            for &(start, end) in &busy {
                if offset + slots[i].elems <= start {
                    break;
                }
                offset = offset.max(end);
            }
            slots[i].offset = offset;
            placed.push(i);
        }

        let peak_elems = slots.iter().map(|s| s.offset + s.elems).max().unwrap_or(0);
        let naive_elems = slots.iter().map(|s| s.elems).sum();
        let plan = ActivationPlan {
            slots,
            peak_elems,
            naive_elems,
        };
        plan.assert_sound();
        plan
    }

    /// Check the invariant the executor's raw-pointer arena views rely on:
    /// no two simultaneously-live slots share arena bytes, and every slot
    /// sits inside the arena. Cheap (runs once, at prepare time).
    fn assert_sound(&self) {
        for (i, a) in self.slots.iter().enumerate() {
            assert!(a.offset + a.elems <= self.peak_elems);
            for b in &self.slots[i + 1..] {
                assert!(
                    !(a.lifetime_overlaps(b) && a.range_overlaps(b)),
                    "planner bug: slots {:?} and {:?} alias while both live",
                    a,
                    b
                );
            }
        }
    }

    /// Number of planned nodes.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True for an empty graph.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Placement of node `i`'s output.
    pub fn slot(&self, i: usize) -> &ActivationSlot {
        &self.slots[i]
    }

    /// All slots, indexed by node.
    pub fn slots(&self) -> &[ActivationSlot] {
        &self.slots
    }

    /// Arena elements one inference needs for all intermediates.
    pub fn peak_elems(&self) -> usize {
        self.peak_elems
    }

    /// [`peak_elems`](Self::peak_elems) in bytes — what a per-worker
    /// activation arena is pre-sized to.
    pub fn peak_bytes(&self) -> usize {
        self.peak_elems * std::mem::size_of::<f32>()
    }

    /// Sum of all intermediate sizes in elements — what per-layer
    /// allocation (one live tensor per node, no sharing) would cost in the
    /// worst case. The planned-vs-naive headroom the bench reports print.
    pub fn naive_elems(&self) -> usize {
        self.naive_elems
    }

    /// [`naive_elems`](Self::naive_elems) in bytes.
    pub fn naive_bytes(&self) -> usize {
        self.naive_elems * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{Activation, Conv2d};
    use crate::nn::Graph;

    /// A sequential chain of `len` conv layers over `side`×`side` maps —
    /// lifetimes [i, i+1], so the planner should two-colour the arena.
    fn chain(len: usize, side: usize, c: usize) -> (Graph, Vec<Vec<usize>>) {
        let mut g = Graph::new();
        let mut prev = g.input();
        for i in 0..len {
            let desc = Conv2d::new(c, c, (3, 3)).with_padding((1, 1));
            let w = desc.random_weights(i as u64);
            prev = g.add(
                &format!("conv{i}"),
                Op::Conv { desc, weights: w, bias: vec![0.0; c], act: Activation::Relu },
                &[prev],
            );
        }
        let shapes = g.infer_shapes(&[1, side, side, c]).unwrap();
        (g, shapes)
    }

    /// Two disjoint-lifetime layers must actually share an arena interval.
    #[test]
    fn disjoint_lifetimes_share_bytes() {
        let (g, shapes) = chain(4, 8, 4);
        let plan = ActivationPlan::for_graph(&g.nodes, &shapes);
        let per_layer = 8 * 8 * 4;
        // All four conv outputs are the same size; with [i, i+1] lifetimes
        // two offsets suffice — conv1 and conv3 (nodes 1 and 3) are dead by
        // the time conv3 and the tail run, so slots recur.
        assert_eq!(plan.peak_elems(), 2 * per_layer);
        assert_eq!(plan.naive_elems(), 4 * per_layer);
        assert_eq!(plan.slot(1).offset, plan.slot(3).offset, "disjoint slots share an interval");
        assert_eq!(plan.slot(2).offset, plan.slot(4).offset);
        // The borrowed input occupies no arena bytes.
        assert_eq!(plan.slot(0).elems, 0);
    }

    /// On a VGG-16-shaped sequential chain (deep stack of convs + pools),
    /// planned peak must be strictly below the naive sum-of-all-tensors.
    #[test]
    fn vgg16_shaped_chain_peak_below_naive() {
        // VGG-16 topology at 1/8 channel width and 56×56 input: 13 convs in
        // 5 blocks with pooling between — the shape of the memory problem,
        // without the multi-hundred-MB weight tensors.
        let widths = [8usize, 8, 16, 16, 32, 32, 32, 64, 64, 64, 64, 64, 64];
        let pool_after = [1usize, 3, 6, 9, 12];
        let mut g = Graph::new();
        let mut prev = g.input();
        let mut cin = 3usize;
        for (i, &cout) in widths.iter().enumerate() {
            let desc = Conv2d::new(cin, cout, (3, 3)).with_padding((1, 1));
            let w = desc.random_weights(i as u64);
            prev = g.add(
                &format!("conv{i}"),
                Op::Conv { desc, weights: w, bias: vec![0.0; cout], act: Activation::Relu },
                &[prev],
            );
            if pool_after.contains(&i) {
                prev = g.add(
                    &format!("pool{i}"),
                    Op::MaxPool { kernel: (2, 2), stride: (2, 2), pad: (0, 0), ceil: false },
                    &[prev],
                );
            }
            cin = cout;
        }
        let shapes = g.infer_shapes(&[1, 56, 56, 3]).unwrap();
        let plan = ActivationPlan::for_graph(&g.nodes, &shapes);
        assert!(
            plan.peak_elems() < plan.naive_elems(),
            "planned peak {} not below naive {}",
            plan.peak_elems(),
            plan.naive_elems()
        );
        // A sequential chain needs at most the two largest neighbours.
        let mut sizes: Vec<usize> = shapes[1..].iter().map(|s| s.iter().product()).collect();
        sizes.sort_unstable_by(|a, b| b.cmp(a));
        assert!(plan.peak_elems() <= sizes[0] + sizes[1]);
    }

    /// Branching keeps every simultaneously-live value disjoint: a value
    /// consumed by a late node must not be overwritten by intermediate
    /// layers in between (the concat pattern of GoogleNet/SqueezeNet).
    #[test]
    fn branches_keep_live_values_disjoint() {
        let mut g = Graph::new();
        let input = g.input();
        let mk = |cin: usize, cout: usize, seed: u64| {
            let desc = Conv2d::new(cin, cout, (3, 3)).with_padding((1, 1));
            let w = desc.random_weights(seed);
            Op::Conv { desc, weights: w, bias: vec![0.0; cout], act: Activation::None }
        };
        let trunk = g.add("trunk", mk(4, 8, 1), &[input]);
        let a = g.add("a", mk(8, 8, 2), &[trunk]);
        let b = g.add("b", mk(8, 8, 3), &[trunk]);
        let cat = g.add("cat", Op::Concat, &[a, b]);
        let _ = cat;
        let shapes = g.infer_shapes(&[1, 6, 6, 4]).unwrap();
        let plan = ActivationPlan::for_graph(&g.nodes, &shapes);
        // trunk is live until b runs; a is live until cat runs: the pairs
        // (trunk, a), (trunk, b), (a, b) must all be address-disjoint.
        for (x, y) in [(trunk, a), (trunk, b), (a, b)] {
            let (sx, sy) = (plan.slot(x), plan.slot(y));
            assert!(
                sx.range().end <= sy.range().start || sy.range().end <= sx.range().start,
                "slots {x} and {y} overlap"
            );
        }
        assert!(plan.peak_elems() >= 3 * 6 * 6 * 8);
    }
}
