//! A small static threadpool modelling the paper's 4× Cortex-A73 'big'
//! cluster (offline build: no `rayon`).
//!
//! The region-wise pipeline parallelises over output regions / GEMM tiles
//! with [`ThreadPool::parallel_for`], a blocking chunked index-space
//! dispatch. Work is split into contiguous chunks (one per worker by
//! default) because the per-item cost inside a layer is uniform — static
//! chunking beats work-stealing here and mirrors how the paper pins work to
//! the big cluster.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<Vec<Job>>,
    available: Condvar,
    shutdown: Mutex<bool>,
}

/// A fixed-size pool of worker threads.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
    n_threads: usize,
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool").field("n_threads", &self.n_threads).finish_non_exhaustive()
    }
}

impl ThreadPool {
    /// Spawn a pool with `n` worker threads (minimum 1).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            available: Condvar::new(),
            shutdown: Mutex::new(false),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("winoconv-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            shared,
            workers,
            n_threads: n,
        }
    }

    /// Pool with one thread per available core (capped at 16).
    pub fn per_core() -> ThreadPool {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n.min(16))
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.n_threads
    }

    /// Submit a fire-and-forget job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push(Box::new(f));
        }
        self.shared.available.notify_one();
    }

    /// Run `body(i)` for every `i` in `0..n`, blocking until all complete.
    ///
    /// The index space is cut into `threads × chunks_per_thread` contiguous
    /// chunks claimed from an atomic cursor, so mild imbalance self-levels
    /// while cache locality within a chunk is preserved.
    pub fn parallel_for<F>(&self, n: usize, body: F)
    where
        F: Fn(usize) + Sync,
    {
        self.parallel_for_chunked(n, 1, |start, end| {
            for i in start..end {
                body(i);
            }
        });
    }

    /// Run `body(start, end)` over disjoint chunks covering `0..n`.
    ///
    /// `granularity` is the minimum chunk size (e.g. a register-tile height).
    pub fn parallel_for_chunked<F>(&self, n: usize, granularity: usize, body: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let granularity = granularity.max(1);
        // Aim for ~4 chunks per thread for self-levelling, but never below
        // the granularity.
        let target_chunks = self.n_threads * 4;
        let chunk = (n.div_ceil(target_chunks)).max(granularity);
        let k = self.n_threads.min(n.div_ceil(chunk));
        if k <= 1 {
            // Single-chunk dispatch: run inline and skip the scope setup.
            // Region-blocked Winograd stages issue many small dispatches
            // (one per block), so this path is hot.
            let mut start = 0;
            while start < n {
                let end = (start + chunk).min(n);
                body(start, end);
                start = end;
            }
            return;
        }
        // Fork-join over the pool's *persistent* workers. Earlier revisions
        // spawned scoped threads per call; the region-blocked Winograd
        // pipeline issues one dispatch per block per stage, which made the
        // per-call spawn cost a measurable tax. Helpers share an atomic
        // chunk cursor with the calling thread, which participates and then
        // blocks until every helper has signalled completion.
        //
        // SAFETY of lifetimes: `body` is published to the helpers as a raw
        // pointer, and the `CompletionGuard` guarantees (on both the normal
        // and the panicking path) that this call does not return before
        // every helper is done dereferencing it. Do not call
        // `parallel_for*` from inside a pool job on the same pool — nested
        // dispatch could then wait on helpers that have no free worker to
        // run on.
        let body_dyn: &(dyn Fn(usize, usize) + Sync) = &body;
        // SAFETY: pure lifetime erasure of a fat pointer for storage; only
        // dereferenced under the CompletionGuard's liveness guarantee.
        let body_ptr: *const (dyn Fn(usize, usize) + Sync) =
            unsafe { std::mem::transmute(body_dyn) };
        let job = Arc::new(ForkJoin {
            cursor: AtomicUsize::new(0),
            n,
            chunk,
            body: body_ptr,
            pending: Mutex::new(k - 1),
            done: Condvar::new(),
            poisoned: AtomicBool::new(false),
        });
        for _ in 0..k - 1 {
            let helper = Arc::clone(&job);
            self.submit(move || {
                // Decrements `pending` on drop — and records the panic, if
                // any — even if the body unwinds.
                let _signal = HelperGuard(&helper);
                helper.work();
            });
        }
        // The calling thread participates too; the guard then waits for the
        // helpers whether the body returns or unwinds.
        let _wait = CompletionGuard(&job);
        job.work();
        drop(_wait);
        // A helper-side body panic must reach the caller like the old
        // scoped-thread join did, not vanish into a worker thread.
        if job.poisoned.load(Ordering::Relaxed) {
            panic!("parallel_for body panicked in a worker thread");
        }
    }
}

/// Shared state of one `parallel_for_chunked` dispatch.
struct ForkJoin {
    cursor: AtomicUsize,
    n: usize,
    chunk: usize,
    /// The dispatch body, lifetime-erased. Only dereferenced while the
    /// dispatching call frame is alive (enforced by `CompletionGuard`); a
    /// raw pointer rather than a reference so a helper's `Arc<ForkJoin>`
    /// outliving that frame by a beat carries no validity obligation.
    body: *const (dyn Fn(usize, usize) + Sync),
    /// Helpers still running (the caller is not counted).
    pending: Mutex<usize>,
    done: Condvar,
    /// Set when a helper's body panicked; the caller re-raises after the
    /// join so dispatch panics behave like the scoped-thread version did.
    poisoned: AtomicBool,
}

// SAFETY: `body` points at a `Sync` closure and is only dereferenced while
// the dispatching `parallel_for_chunked` frame keeps it alive; all other
// fields are thread-safe primitives.
unsafe impl Send for ForkJoin {}
// SAFETY: same argument as `Send` above — helpers only ever call the `Sync`
// closure through `body` and touch the atomic/Mutex/Condvar fields.
unsafe impl Sync for ForkJoin {}

impl ForkJoin {
    fn work(&self) {
        // SAFETY: the dispatching frame outlives every `work` call (see
        // `CompletionGuard`), so the pointee is valid here.
        let body = unsafe { &*self.body };
        loop {
            let start = self.cursor.fetch_add(self.chunk, Ordering::Relaxed);
            if start >= self.n {
                break;
            }
            body(start, (start + self.chunk).min(self.n));
        }
    }
}

/// Signals helper completion on drop (panic-safe).
struct HelperGuard<'a>(&'a ForkJoin);

impl Drop for HelperGuard<'_> {
    fn drop(&mut self) {
        if thread::panicking() {
            self.0.poisoned.store(true, Ordering::Relaxed);
        }
        let mut pending = self.0.pending.lock().unwrap_or_else(|e| e.into_inner());
        *pending -= 1;
        if *pending == 0 {
            self.0.done.notify_all();
        }
    }
}

/// Blocks until every helper signalled, on drop (panic-safe: the caller's
/// borrow of `body` must not end while helpers still use it).
struct CompletionGuard<'a>(&'a ForkJoin);

impl Drop for CompletionGuard<'_> {
    fn drop(&mut self) {
        let mut pending = self.0.pending.lock().unwrap_or_else(|e| e.into_inner());
        while *pending > 0 {
            pending = self
                .0
                .done
                .wait(pending)
                .unwrap_or_else(|e| e.into_inner());
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop() {
                    break Some(job);
                }
                if *shared.shutdown.lock().unwrap() {
                    break None;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        match job {
            // A panicking job must not kill the (persistent) worker: the
            // fork-join above records and re-raises body panics on the
            // dispatching thread, and the pool keeps its full width.
            Some(job) => {
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
            }
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_every_index_once() {
        let pool = ThreadPool::new(4);
        let n = 10_000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_chunked_partitions_exactly() {
        let pool = ThreadPool::new(3);
        let n = 1001;
        let total = AtomicU64::new(0);
        pool.parallel_for_chunked(n, 8, |s, e| {
            assert!(s < e && e <= n);
            total.fetch_add((e - s) as u64, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), n as u64);
    }

    #[test]
    fn zero_items_is_noop() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(0, |_| panic!("must not run"));
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let sum = AtomicU64::new(0);
        pool.parallel_for(100, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn submit_runs_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..32 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        // Dropping the pool joins all workers after the queue drains.
        drop(pool);
        assert_eq!(counter.load(Ordering::Relaxed), 32);
    }

    #[test]
    #[should_panic]
    fn body_panic_reaches_the_caller() {
        let pool = ThreadPool::new(4);
        // Every chunk panics, so whichever thread claims work panics; a
        // helper-side panic must be re-raised on the calling thread.
        pool.parallel_for(1000, |i| panic!("boom at {i}"));
    }

    #[test]
    fn pool_survives_body_panics() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.parallel_for(100, |_| panic!("transient"));
        }));
        assert!(r.is_err(), "panic must propagate to the dispatching thread");
        // The persistent workers survived and the pool still dispatches.
        let sum = AtomicU64::new(0);
        pool.parallel_for(100, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn results_match_serial_reduction() {
        let pool = ThreadPool::new(4);
        let data: Vec<f64> = (0..5000).map(|i| (i as f64).sqrt()).collect();
        let parallel_sum = Mutex::new(0.0f64);
        pool.parallel_for_chunked(data.len(), 1, |s, e| {
            let partial: f64 = data[s..e].iter().sum();
            *parallel_sum.lock().unwrap() += partial;
        });
        let serial: f64 = data.iter().sum();
        assert!((serial - *parallel_sum.lock().unwrap()).abs() < 1e-6);
    }
}
