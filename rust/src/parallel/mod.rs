//! A small static threadpool modelling the paper's 4× Cortex-A73 'big'
//! cluster (offline build: no `rayon`).
//!
//! The region-wise pipeline parallelises over output regions / GEMM tiles
//! with [`ThreadPool::parallel_for`], a blocking chunked index-space
//! dispatch. Work is split into contiguous chunks (one per worker by
//! default) because the per-item cost inside a layer is uniform — static
//! chunking beats work-stealing here and mirrors how the paper pins work to
//! the big cluster.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<Vec<Job>>,
    available: Condvar,
    shutdown: Mutex<bool>,
}

/// A fixed-size pool of worker threads.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
    n_threads: usize,
}

impl ThreadPool {
    /// Spawn a pool with `n` worker threads (minimum 1).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            available: Condvar::new(),
            shutdown: Mutex::new(false),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("winoconv-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            shared,
            workers,
            n_threads: n,
        }
    }

    /// Pool with one thread per available core (capped at 16).
    pub fn per_core() -> ThreadPool {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n.min(16))
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.n_threads
    }

    /// Submit a fire-and-forget job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push(Box::new(f));
        }
        self.shared.available.notify_one();
    }

    /// Run `body(i)` for every `i` in `0..n`, blocking until all complete.
    ///
    /// The index space is cut into `threads × chunks_per_thread` contiguous
    /// chunks claimed from an atomic cursor, so mild imbalance self-levels
    /// while cache locality within a chunk is preserved.
    pub fn parallel_for<F>(&self, n: usize, body: F)
    where
        F: Fn(usize) + Sync,
    {
        self.parallel_for_chunked(n, 1, |start, end| {
            for i in start..end {
                body(i);
            }
        });
    }

    /// Run `body(start, end)` over disjoint chunks covering `0..n`.
    ///
    /// `granularity` is the minimum chunk size (e.g. a register-tile height).
    pub fn parallel_for_chunked<F>(&self, n: usize, granularity: usize, body: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let granularity = granularity.max(1);
        // Aim for ~4 chunks per thread for self-levelling, but never below
        // the granularity.
        let target_chunks = self.n_threads * 4;
        let chunk = (n.div_ceil(target_chunks)).max(granularity);
        let cursor = AtomicUsize::new(0);
        // SAFETY of lifetimes: achieved with std::thread::scope — workers in
        // the pool cannot borrow `body`, so we run the chunked loop on scoped
        // threads instead of the pool's own queue. The pool still bounds the
        // parallelism degree.
        let k = self.n_threads.min(n.div_ceil(chunk));
        thread::scope(|s| {
            for _ in 0..k.saturating_sub(1) {
                s.spawn(|| {
                    loop {
                        let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                        if start >= n {
                            break;
                        }
                        body(start, (start + chunk).min(n));
                    }
                });
            }
            // The calling thread participates too.
            loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                body(start, (start + chunk).min(n));
            }
        });
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop() {
                    break Some(job);
                }
                if *shared.shutdown.lock().unwrap() {
                    break None;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        match job {
            Some(job) => job(),
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_every_index_once() {
        let pool = ThreadPool::new(4);
        let n = 10_000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_chunked_partitions_exactly() {
        let pool = ThreadPool::new(3);
        let n = 1001;
        let total = AtomicU64::new(0);
        pool.parallel_for_chunked(n, 8, |s, e| {
            assert!(s < e && e <= n);
            total.fetch_add((e - s) as u64, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), n as u64);
    }

    #[test]
    fn zero_items_is_noop() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(0, |_| panic!("must not run"));
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let sum = AtomicU64::new(0);
        pool.parallel_for(100, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn submit_runs_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..32 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        // Dropping the pool joins all workers after the queue drains.
        drop(pool);
        assert_eq!(counter.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn results_match_serial_reduction() {
        let pool = ThreadPool::new(4);
        let data: Vec<f64> = (0..5000).map(|i| (i as f64).sqrt()).collect();
        let parallel_sum = Mutex::new(0.0f64);
        pool.parallel_for_chunked(data.len(), 1, |s, e| {
            let partial: f64 = data[s..e].iter().sum();
            *parallel_sum.lock().unwrap() += partial;
        });
        let serial: f64 = data.iter().sum();
        assert!((serial - *parallel_sum.lock().unwrap()).abs() < 1e-6);
    }
}
