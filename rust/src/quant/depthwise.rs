//! Quantized direct depthwise convolution — the int8 twin of
//! [`crate::conv::depthwise::DepthwiseConvolution`].
//!
//! Same algorithmic stance as the f32 engine (no Winograd, no im2row — a
//! direct 3×3 loop nest; see that module's header for the argument), but
//! the arithmetic profile flips: a depthwise layer is **memory-bound**
//! (9 MACs per loaded pixel), so int8's 4× smaller activations are the
//! whole win. The engine quantizes the input once into a zp-prefilled
//! padded u8 staging buffer (padding bytes dequantize to exactly 0.0, so
//! the hot loop has no bounds checks), accumulates the nine taps in i32
//! per output pixel and channel, and dequantizes inline —
//! `(acc − zp·Σw) · s_in·s_ch + bias`, activation, one f32 store — the
//! same zero-point-folded epilogue math as [`crate::gemm::QDequantBiasAct`]
//! without a GEMM in the middle.
//!
//! Taps are quantized per channel (symmetric i8, as everywhere in
//! [`crate::quant`]) and repacked tap-major `qw[(a·3 + b)·C + ch]`,
//! mirroring the f32 layout so the access pattern carries over.

use crate::gemm::Activation;
use crate::parallel::ThreadPool;
use crate::quant::{as_u8_mut, choose_act_quant, quantize_u8_into, quantize_weight_channel};
use crate::tensor::{Tensor, TensorView};
use crate::workspace::{elems_for_bytes, Workspace};
use crate::{bail_shape, bail_unsupported, Result};

/// A prepared quantized depthwise convolution: per-channel i8 taps plus
/// the per-channel scales and zero-point folding sums.
#[derive(Debug, Clone)]
pub struct QuantDepthwiseConvolution {
    channels: usize,
    stride: (usize, usize),
    pad: (usize, usize),
    /// Quantized taps, tap-major: `qw[(a·3 + b)·C + ch]`.
    qw: Vec<i8>,
    /// Per-channel symmetric weight scale.
    scales: Vec<f32>,
    /// Per-channel `Σ qw` (zero-point folding term).
    wsum: Vec<i32>,
}

impl QuantDepthwiseConvolution {
    /// Prepare from `[C, 3, 3, 1]` weights; 3×3 at stride (1,1) or (2,2)
    /// only — the same envelope the selector enforces for the f32 engine.
    pub fn new(weights: &Tensor, stride: (usize, usize), pad: (usize, usize)) -> Result<Self> {
        if weights.rank() != 4 || weights.shape()[3] != 1 {
            bail_shape!(
                "depthwise weights must be [C, KH, KW, 1], got {:?}",
                weights.shape()
            );
        }
        let (c, kh, kw) = (weights.shape()[0], weights.shape()[1], weights.shape()[2]);
        if (kh, kw) != (3, 3) {
            bail_unsupported!("depthwise engine is 3x3-only, got {kh}x{kw}");
        }
        if stride != (1, 1) && stride != (2, 2) {
            bail_unsupported!("depthwise engine supports stride 1 or 2, got {stride:?}");
        }
        let mut qw = vec![0i8; 9 * c];
        let mut scales = vec![0.0f32; c];
        let mut wsum = vec![0i32; c];
        let mut taps = [0.0f32; 9];
        let mut qtaps = [0i8; 9];
        for ch in 0..c {
            for a in 0..3 {
                for b in 0..3 {
                    taps[a * 3 + b] = weights.at4(ch, a, b, 0);
                }
            }
            let (s, ws) = quantize_weight_channel(&taps, &mut qtaps);
            scales[ch] = s;
            wsum[ch] = ws;
            for (t, &qt) in qtaps.iter().enumerate() {
                qw[t * c + ch] = qt;
            }
        }
        Ok(QuantDepthwiseConvolution {
            channels: c,
            stride,
            pad,
            qw,
            scales,
            wsum,
        })
    }

    /// Channel count (== groups == cin == cout).
    pub fn channels(&self) -> usize {
        self.channels
    }

    /// Output spatial size for an `h×w` input.
    pub fn output_hw(&self, h: usize, w: usize) -> Result<(usize, usize)> {
        let (ph, pw) = self.pad;
        if h + 2 * ph < 3 || w + 2 * pw < 3 {
            bail_shape!("input {h}x{w} (pad {ph},{pw}) smaller than filter 3x3");
        }
        Ok(((h + 2 * ph - 3) / self.stride.0 + 1, (w + 2 * pw - 3) / self.stride.1 + 1))
    }

    /// Workspace elements (**f32**s) one inference over an `[n, h, w, C]`
    /// input borrows: the padded quantized staging (`N·HP·WP·C` bytes,
    /// byte-ceiled into f32 units). Unlike the f32 engine this is nonzero
    /// even for valid layers — quantization always writes a u8 copy.
    pub fn workspace_elems_for(&self, n: usize, h: usize, w: usize) -> Result<usize> {
        let _ = self.output_hw(h, w)?; // geometry must be valid
        let (ph, pw) = self.pad;
        Ok(elems_for_bytes(n * (h + 2 * ph) * (w + 2 * pw) * self.channels))
    }

    /// Allocating twin of [`run_fused_i8_into`](Self::run_fused_i8_into)
    /// (tests / one-shot use).
    pub fn run_fused_i8_with(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
    ) -> Result<Tensor> {
        if input.rank() != 4 {
            bail_shape!("input must be [N, H, W, C], got {:?}", input.shape());
        }
        let (n, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
        let (oh, ow) = self.output_hw(h, w)?;
        let mut out = Tensor::zeros(&[n, oh, ow, self.channels]);
        self.run_fused_i8_into(&input.view(), pool, bias, act, ws, out.data_mut())?;
        Ok(out)
    }

    /// Quantize into padded staging → direct i32 3×3 accumulate →
    /// dequantize/bias/activation inline, writing f32 into `out`. Zero
    /// heap allocations.
    pub fn run_fused_i8_into(
        &self,
        input: &TensorView,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
        out: &mut [f32],
    ) -> Result<()> {
        if input.rank() != 4 {
            bail_shape!("input must be [N, H, W, C], got {:?}", input.shape());
        }
        let (n, h, w, c) = (
            input.shape()[0],
            input.shape()[1],
            input.shape()[2],
            input.shape()[3],
        );
        if c != self.channels {
            bail_shape!("input has {c} channels, depthwise weights expect {}", self.channels);
        }
        if let Some(b) = bias {
            if b.len() != c {
                bail_shape!("bias length {} vs {c} channels", b.len());
            }
        }
        let (oh, ow) = self.output_hw(h, w)?;
        if out.len() != n * oh * ow * c {
            bail_shape!(
                "output slice has {} elems, layer writes {}",
                out.len(),
                n * oh * ow * c
            );
        }
        let (ph, pw) = self.pad;
        let (hp, wp) = (h + 2 * ph, w + 2 * pw);
        let staging_bytes = n * hp * wp * c;

        let stage_t = crate::trace::begin();
        let q = choose_act_quant(input.data());
        let staging = &mut as_u8_mut(ws.take(elems_for_bytes(staging_bytes)))[..staging_bytes];
        if ph != 0 || pw != 0 {
            // zp bytes dequantize to exactly 0.0: zero padding for free.
            staging.fill(q.zp as u8);
        }
        let src = input.data();
        for ni in 0..n {
            for y in 0..h {
                let srow = &src[((ni * h + y) * w) * c..][..w * c];
                let drow = &mut staging[(((ni * hp + y + ph) * wp) + pw) * c..][..w * c];
                quantize_u8_into(srow, q, drow);
            }
        }
        crate::trace::end_stage(
            stage_t,
            crate::trace::Stage::Quantize,
            crate::trace::AlgoCode::DepthwiseI8,
        );
        // Padding is folded into the quantize pass (zp-byte borders), so
        // the Pack span is ~0 ns — recorded anyway to keep the int8 engine
        // stage census fixed at three.
        let stage_t = crate::trace::begin();
        crate::trace::end_stage(
            stage_t,
            crate::trace::Stage::Pack,
            crate::trace::AlgoCode::DepthwiseI8,
        );
        let stage_t = crate::trace::begin();

        let (sh, sw) = self.stride;
        let a_scale = q.scale;
        let a_zp = q.zp;
        let out_addr = out.as_mut_ptr() as usize;
        let qw = &self.qw;
        let scales = &self.scales;
        let wsum = &self.wsum;
        let row_job = |r: usize| {
            let b = r / oh;
            let oy = r % oh;
            let iy0 = oy * sh;
            // SAFETY: each job writes only its own `(b, oy)` output row;
            // jobs are disjoint and `out` outlives the dispatch.
            let out_row: &mut [f32] = unsafe {
                std::slice::from_raw_parts_mut(
                    (out_addr as *mut f32).add((b * oh + oy) * ow * c),
                    ow * c,
                )
            };
            for ox in 0..ow {
                let ix0 = ox * sw;
                for ch in 0..c {
                    let mut acc = 0i32;
                    for a in 0..3 {
                        let base = ((b * hp + iy0 + a) * wp + ix0) * c + ch;
                        for bx in 0..3 {
                            acc += staging[base + bx * c] as i32 * qw[(a * 3 + bx) * c + ch] as i32;
                        }
                    }
                    let centered = acc - a_zp * wsum[ch];
                    let mut v = centered as f32 * (a_scale * scales[ch]);
                    if let Some(bb) = bias {
                        v += bb[ch];
                    }
                    out_row[ox * c + ch] = act.apply(v);
                }
            }
        };
        match pool {
            Some(pool) => pool.parallel_for(n * oh, row_job),
            None => (0..n * oh).for_each(row_job),
        }
        crate::trace::end_stage(
            stage_t,
            crate::trace::Stage::Compute,
            crate::trace::AlgoCode::DepthwiseI8,
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::depthwise::DepthwiseConvolution;
    use crate::util::rel_error;

    #[test]
    fn quantized_tracks_f32_oracle() {
        // Ragged C (C % 4 != 0) included deliberately.
        for (stride, pad, c) in [
            ((1, 1), (1, 1), 7),
            ((2, 2), (1, 1), 8),
            ((1, 1), (0, 0), 5),
            ((2, 2), (0, 0), 4),
        ] {
            let input = Tensor::randn(&[2, 11, 9, c], 91);
            let weights = Tensor::randn(&[c, 3, 3, 1], 92);
            let bias: Vec<f32> = (0..c).map(|i| i as f32 * 0.3 - 0.8).collect();
            let qconv = QuantDepthwiseConvolution::new(&weights, stride, pad).unwrap();
            let fconv = DepthwiseConvolution::new(&weights, stride, pad).unwrap();
            let mut ws = Workspace::new();
            for act in [Activation::None, Activation::Relu, Activation::Relu6] {
                let got = qconv
                    .run_fused_i8_with(&input, None, Some(&bias), act, &mut ws)
                    .unwrap();
                let want = fconv
                    .run_fused_with(&input, None, Some(&bias), act, &mut ws)
                    .unwrap();
                assert_eq!(got.shape(), want.shape());
                let e = rel_error(got.data(), want.data());
                assert!(
                    e < 0.05,
                    "stride {stride:?} pad {pad:?} c {c} act {act}: rel err {e}"
                );
            }
        }
    }

    #[test]
    fn into_matches_with_and_arena_never_grows() {
        let input = Tensor::randn(&[1, 9, 8, 6], 101);
        let weights = Tensor::randn(&[6, 3, 3, 1], 102);
        let conv = QuantDepthwiseConvolution::new(&weights, (1, 1), (1, 1)).unwrap();
        let mut ws = Workspace::new();
        let want = conv
            .run_fused_i8_with(&input, None, None, Activation::Relu, &mut ws)
            .unwrap();
        let elems = conv.workspace_elems_for(1, 9, 8).unwrap();
        let mut ws2 = Workspace::with_capacity(elems);
        for v in ws2.take(elems).iter_mut() {
            *v = f32::from_bits(0x5a5a5a5a);
        }
        let mut out = vec![f32::from_bits(0x3a3a3a3a); want.data().len()];
        conv.run_fused_i8_into(
            &input.view(),
            None,
            None,
            Activation::Relu,
            &mut ws2,
            &mut out,
        )
        .unwrap();
        assert_eq!(ws2.grow_count(), 0, "workspace_elems_for must cover the walk");
        let same = out
            .iter()
            .zip(want.data())
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "into/with must agree bitwise");
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        let pool = ThreadPool::new(4);
        let input = Tensor::randn(&[1, 16, 13, 10], 111);
        let weights = Tensor::randn(&[10, 3, 3, 1], 112);
        for stride in [(1, 1), (2, 2)] {
            let conv = QuantDepthwiseConvolution::new(&weights, stride, (1, 1)).unwrap();
            let mut ws = Workspace::new();
            let a = conv
                .run_fused_i8_with(&input, None, None, Activation::None, &mut ws)
                .unwrap();
            let b = conv
                .run_fused_i8_with(&input, Some(&pool), None, Activation::None, &mut ws)
                .unwrap();
            assert_eq!(a.data(), b.data(), "stride {stride:?}");
        }
    }

    #[test]
    fn rejects_bad_configs() {
        let w33 = Tensor::zeros(&[4, 3, 3, 1]);
        assert!(QuantDepthwiseConvolution::new(&Tensor::zeros(&[4, 5, 5, 1]), (1, 1), (2, 2))
            .is_err());
        assert!(QuantDepthwiseConvolution::new(&Tensor::zeros(&[4, 3, 3, 2]), (1, 1), (1, 1))
            .is_err());
        assert!(QuantDepthwiseConvolution::new(&w33, (1, 2), (0, 0)).is_err());
        let conv = QuantDepthwiseConvolution::new(&w33, (1, 1), (0, 0)).unwrap();
        let mut ws = Workspace::new();
        assert!(conv
            .run_fused_i8_with(&Tensor::zeros(&[1, 8, 8, 5]), None, None, Activation::None, &mut ws)
            .is_err());
        assert!(conv
            .run_fused_i8_with(&Tensor::zeros(&[1, 2, 2, 4]), None, None, Activation::None, &mut ws)
            .is_err());
        let input = Tensor::zeros(&[1, 8, 8, 4]);
        let mut out = vec![0.0; 6 * 6 * 4];
        assert!(conv
            .run_fused_i8_into(
                &input.view(),
                None,
                Some(&[0.0; 3]),
                Activation::None,
                &mut ws,
                &mut out,
            )
            .is_err());
        assert!(conv
            .run_fused_i8_into(&input.view(), None, None, Activation::None, &mut ws, &mut out[1..])
            .is_err());
    }
}
