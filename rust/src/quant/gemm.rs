//! The int8 GEMM path: prepare-time weight quantize-and-pack plus a
//! prepacked, fused driver around the [`crate::simd::qmacc_4x16`]
//! micro-kernel.
//!
//! Structurally simpler than the f32 five-loop engine — and that is the
//! point: at one byte per A element and one per B element the whole
//! working set of a mobile conv layer fits the L1/L2 budget without KC
//! blocking, so the driver accumulates each `4×16` tile over the **full**
//! k extent in registers/stack and fires the epilogue exactly once per
//! tile. The f32 engine by contrast packs A per KC block and re-reads C
//! once per block; skipping both passes is a structural advantage of the
//! int8 path on top of the 2× denser multiplies.

use crate::gemm::EpilogueI32;
use crate::parallel::ThreadPool;
use crate::quant::quantize_weight_channel;
use crate::simd::qmacc_4x16;
use crate::{bail_shape, Result};

/// Micro-kernel rows (A block height).
pub const MR_I8: usize = 4;

/// Micro-kernel columns (B panel width).
pub const NR_I8: usize = 16;

/// B quantized and packed into `NR_I8`-wide column panels:
/// `data[(jp * k + p) * NR_I8 + j]` is element `(p, jp * NR_I8 + j)`.
/// Ragged tail columns are zero-padded (zero weights contribute nothing).
#[derive(Debug, Clone)]
pub struct PackedBI8 {
    k: usize,
    n: usize,
    data: Vec<i8>,
}

impl PackedBI8 {
    /// Inner (reduction) dimension.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Logical column count (before panel padding).
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline(always)]
    fn panel(&self, jp: usize) -> &[i8] {
        &self.data[jp * self.k * NR_I8..(jp + 1) * self.k * NR_I8]
    }
}

/// A prepare-time quantized B operand: packed i8 panels plus the
/// per-column (per-output-channel) scales and folded correction sums the
/// dequantize epilogue needs.
#[derive(Debug, Clone)]
pub struct QuantizedGemmB {
    /// The packed panels.
    pub packed: PackedBI8,
    /// Per-column symmetric scale `s_w[j]`.
    pub scales: Vec<f32>,
    /// Per-column `Σ_p qw[p][j]` (the zero-point folding term).
    pub wsum: Vec<i32>,
}

/// Quantize a row-major `k×n` f32 matrix per **column** (output channel)
/// to symmetric i8 and pack it into [`PackedBI8`] panels.
pub fn quantize_pack_b(src: &[f32], k: usize, n: usize) -> Result<QuantizedGemmB> {
    if src.len() != k * n {
        bail_shape!("quantize_pack_b: {}x{} needs {} elems, got {}", k, n, k * n, src.len());
    }
    let panels = n.div_ceil(NR_I8);
    let mut data = vec![0i8; panels * k * NR_I8];
    let mut scales = vec![0.0f32; n];
    let mut wsum = vec![0i32; n];
    let mut col = vec![0.0f32; k];
    let mut qcol = vec![0i8; k];
    for j in 0..n {
        for p in 0..k {
            col[p] = src[p * n + j];
        }
        let (s, ws) = quantize_weight_channel(&col, &mut qcol);
        scales[j] = s;
        wsum[j] = ws;
        let (jp, jj) = (j / NR_I8, j % NR_I8);
        for p in 0..k {
            data[(jp * k + p) * NR_I8 + jj] = qcol[p];
        }
    }
    Ok(QuantizedGemmB {
        packed: PackedBI8 { k, n, data },
        scales,
        wsum,
    })
}

/// `epilogue(A·B)` with u8 A (`m×k`, row-major, `lda == k`), prepacked i8
/// B, i32 accumulation — parallelised over `MR_I8`-row blocks of A.
///
/// Each worker owns disjoint C rows, accumulates one `MR_I8×NR_I8` tile on
/// its stack over the full k extent, and hands the finished tile to the
/// [`EpilogueI32`] (which writes the actual output — no i32 C matrix is
/// ever materialised). Edge lanes of short row blocks accumulate zeros and
/// are simply not reported to the epilogue.
pub fn qgemm_prepacked_fused<E: EpilogueI32>(
    m: usize,
    a: &[u8],
    b: &PackedBI8,
    pool: Option<&ThreadPool>,
    epi: &E,
) -> Result<()> {
    let (k, n) = (b.k, b.n);
    if a.len() != m * k {
        bail_shape!("qgemm: A is {}x{} ({} elems), got {}", m, k, m * k, a.len());
    }
    let panels = n.div_ceil(NR_I8);
    let row_job = |blk: usize| {
        let r0 = blk * MR_I8;
        let rows = MR_I8.min(m - r0);
        for jp in 0..panels {
            let col0 = jp * NR_I8;
            let cols = NR_I8.min(n - col0);
            let panel = b.panel(jp);
            let mut acc = [[0i32; NR_I8]; MR_I8];
            for p in 0..k {
                let mut a4 = [0u8; MR_I8];
                for (lane, av) in a4.iter_mut().enumerate().take(rows) {
                    *av = a[(r0 + lane) * k + p];
                }
                let bv: &[i8; NR_I8] = panel[p * NR_I8..(p + 1) * NR_I8].try_into().unwrap();
                qmacc_4x16(&mut acc, &a4, bv);
            }
            epi.micro_tile_i32(&acc, r0, col0, rows, cols);
        }
    };
    let blocks = m.div_ceil(MR_I8);
    match pool {
        Some(pool) => pool.parallel_for(blocks, row_job),
        None => (0..blocks).for_each(row_job),
    }
    Ok(())
}

/// Scalar i32 reference GEMM (`C[i][j] = Σ_p a[i][p] · b[p][j]`, b
/// row-major unpacked) — the oracle the driver tests pin against.
pub fn qgemm_ref(m: usize, k: usize, n: usize, a: &[u8], b: &[i8]) -> Vec<i32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p] as i32;
            for j in 0..n {
                c[i * n + j] += av * b[p * n + j] as i32;
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShiftRng;

    /// Epilogue that just stores the raw i32 tile into an m×n matrix.
    struct StoreI32 {
        out_addr: usize,
        ldc: usize,
    }

    impl EpilogueI32 for StoreI32 {
        fn micro_tile_i32(
            &self,
            acc: &[[i32; 16]; 4],
            row0: usize,
            col0: usize,
            rows: usize,
            cols: usize,
        ) {
            let out = self.out_addr as *mut i32;
            for (r, acc_row) in acc.iter().enumerate().take(rows) {
                // SAFETY: test drives disjoint tiles of an m×ldc buffer.
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(out.add((row0 + r) * self.ldc + col0), cols)
                };
                dst.copy_from_slice(&acc_row[..cols]);
            }
        }
    }

    fn random_case(m: usize, k: usize, n: usize, seed: u64, pool: Option<&ThreadPool>) {
        let mut rng = XorShiftRng::new(seed);
        let a: Vec<u8> = (0..m * k).map(|_| (rng.next_u64() % 256) as u8).collect();
        let mut bq: Vec<i8> = (0..k * n)
            .map(|_| ((rng.next_u64() % 255) as i32 - 127) as i8)
            .collect();
        // Pin row 0 to ±127 so every column's max_abs maps to exactly 1.0
        // in f32 — then feeding `bq / 127` through the symmetric quantizer
        // reproduces `bq` bit-for-bit and the driver can be pinned against
        // the pure-integer reference.
        for j in 0..n {
            bq[j] = if j % 2 == 0 { 127 } else { -127 };
        }
        let bf: Vec<f32> = bq.iter().map(|&v| v as f32 / 127.0).collect();
        let packed = quantize_pack_b(&bf, k, n).unwrap();
        for j in 0..n {
            assert!((packed.scales[j] * 127.0 - 1.0).abs() < 1e-5, "scale[{j}]");
        }
        let mut c = vec![0i32; m * n];
        let epi = StoreI32 { out_addr: c.as_mut_ptr() as usize, ldc: n };
        qgemm_prepacked_fused(m, &a, &packed.packed, pool, &epi).unwrap();
        let want = qgemm_ref(m, k, n, &a, &bq);
        assert_eq!(c, want, "m={m} k={k} n={n}");
        // wsum really is the packed column sum.
        for j in 0..n {
            let s: i32 = (0..k).map(|p| bq[p * n + j] as i32).sum();
            assert_eq!(packed.wsum[j], s, "wsum[{j}]");
        }
    }

    #[test]
    fn qgemm_matches_scalar_reference() {
        // Exact multiples of the tile, ragged rows, ragged cols, tiny.
        random_case(8, 32, 32, 1, None);
        random_case(7, 5, 13, 2, None);
        random_case(1, 1, 1, 3, None);
        random_case(4, 64, 17, 4, None);
        random_case(9, 3, 16, 5, None);
    }

    #[test]
    fn qgemm_parallel_matches_serial() {
        let pool = ThreadPool::new(4);
        random_case(33, 27, 29, 6, Some(&pool));
    }

    #[test]
    fn quantize_pack_rejects_bad_shape() {
        assert!(quantize_pack_b(&[0.0; 5], 2, 3).is_err());
        let b = quantize_pack_b(&[0.0; 6], 2, 3).unwrap();
        assert_eq!((b.packed.k(), b.packed.n()), (2, 3));
        assert_eq!(b.packed.data.len(), 2 * NR_I8);
    }

    #[test]
    fn qgemm_rejects_bad_a() {
        let b = quantize_pack_b(&[0.5; 6], 2, 3).unwrap();
        let epi = StoreI32 { out_addr: 0, ldc: 3 };
        assert!(qgemm_prepacked_fused(2, &[0u8; 3], &b.packed, None, &epi).is_err());
    }
}
