//! Quantized im2row: the int8 twin of [`crate::im2row::Im2RowConvolution`]
//! — dense spatial layers under [`super::Dtype::Int8`].
//!
//! Prepare quantizes the `[M, KH, KW, C]` weights per output channel and
//! packs them as the GEMM's B operand ([`super::gemm::quantize_pack_b`]).
//! Per call the f32 input is quantized **once** into a zero-point-filled
//! padded u8 staging buffer (padding bytes are `zp`, which dequantizes to
//! exactly 0.0), the u8 patch matrix is gathered exactly like the f32
//! engine's, and one fused int8 GEMM with the
//! [`crate::gemm::QDequantBiasAct`] epilogue writes the f32 output — bias
//! and activation included — in a single pass.
//!
//! Both scratch buffers are bytes drawn from the shared f32 arena
//! ([`super::as_u8_mut`] over a [`crate::workspace::elems_for_bytes`]-sized
//! borrow), so the zero-alloc steady state survives the dtype change.

use crate::gemm::{Activation, QDequantBiasAct};
use crate::parallel::ThreadPool;
use crate::quant::gemm::{qgemm_prepacked_fused, quantize_pack_b, QuantizedGemmB};
use crate::quant::{as_u8_mut, choose_act_quant, quantize_u8_into};
use crate::tensor::{Tensor, TensorView};
use crate::workspace::{elems_for_bytes, Workspace};
use crate::{bail_shape, Result};

/// Prepared quantized im2row convolution (weights quantized and packed).
#[derive(Debug, Clone)]
pub struct QuantIm2RowConvolution {
    m: usize,
    k: usize,
    cin: usize,
    kernel: (usize, usize),
    stride: (usize, usize),
    pad: (usize, usize),
    b: QuantizedGemmB,
}

impl QuantIm2RowConvolution {
    /// Quantize `[M, KH, KW, C]` weights per output channel and pack them.
    pub fn new(weights: &Tensor, stride: (usize, usize), pad: (usize, usize)) -> Result<Self> {
        let ws = weights.shape();
        if ws.len() != 4 {
            bail_shape!("weights must be [M, KH, KW, C], got {:?}", ws);
        }
        if stride.0 == 0 || stride.1 == 0 {
            bail_shape!("stride must be nonzero, got {:?}", stride);
        }
        let (m, kh, kw, c) = (ws[0], ws[1], ws[2], ws[3]);
        let k = kh * kw * c;
        // Same k×m transpose the f32 engine builds: row (a·kw + b)·c + ch,
        // column = output channel — so B columns are output channels and
        // the per-column symmetric quantizer is per-output-channel.
        let mut wt = vec![0.0f32; k * m];
        let wd = weights.data();
        for mi in 0..m {
            for a in 0..kh {
                for bx in 0..kw {
                    for ch in 0..c {
                        let kk = (a * kw + bx) * c + ch;
                        wt[kk * m + mi] = wd[((mi * kh + a) * kw + bx) * c + ch];
                    }
                }
            }
        }
        let b = quantize_pack_b(&wt, k, m)?;
        Ok(QuantIm2RowConvolution {
            m,
            k,
            cin: c,
            kernel: (kh, kw),
            stride,
            pad,
            b,
        })
    }

    /// Output spatial extent for an `h×w` input.
    pub fn output_hw(&self, h: usize, w: usize) -> Result<(usize, usize)> {
        let (kh, kw) = self.kernel;
        let (ph, pw) = self.pad;
        let (sh, sw) = self.stride;
        if h + 2 * ph < kh || w + 2 * pw < kw {
            bail_shape!("input {h}x{w} (pad {ph},{pw}) smaller than filter {kh}x{kw}");
        }
        Ok(((h + 2 * ph - kh) / sh + 1, (w + 2 * pw - kw) / sw + 1))
    }

    /// Workspace elements (**f32**s) one inference over an `[n, h, w, C]`
    /// input borrows — the u8 staging plus the u8 patch matrix, byte-ceiled
    /// into f32 units (the mixed-dtype sizing rule `workspace_elems()`
    /// aggregates).
    pub fn workspace_elems_for(&self, n: usize, h: usize, w: usize) -> Result<usize> {
        let (oh, ow) = self.output_hw(h, w)?;
        let (ph, pw) = self.pad;
        let staging_bytes = n * (h + 2 * ph) * (w + 2 * pw) * self.cin;
        let patch_bytes = n * oh * ow * self.k;
        Ok(elems_for_bytes(staging_bytes) + elems_for_bytes(patch_bytes))
    }

    /// Allocating twin of [`run_fused_i8_into`](Self::run_fused_i8_into)
    /// (tests / one-shot use).
    pub fn run_fused_i8_with(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
    ) -> Result<Tensor> {
        if input.rank() != 4 {
            bail_shape!("input must be [N, H, W, C], got {:?}", input.shape());
        }
        let (n, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
        let (oh, ow) = self.output_hw(h, w)?;
        let mut out = Tensor::zeros(&[n, oh, ow, self.m]);
        self.run_fused_i8_into(&input.view(), pool, bias, act, ws, out.data_mut())?;
        Ok(out)
    }

    /// Quantize → patch-gather → fused int8 GEMM, writing the f32 output
    /// (bias/activation applied in the dequantize epilogue) into `out`.
    /// All scratch comes from `ws`; zero heap allocations.
    pub fn run_fused_i8_into(
        &self,
        input: &TensorView,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
        out: &mut [f32],
    ) -> Result<()> {
        if input.rank() != 4 {
            bail_shape!("input must be [N, H, W, C], got {:?}", input.shape());
        }
        let (n, h, w, c) = (
            input.shape()[0],
            input.shape()[1],
            input.shape()[2],
            input.shape()[3],
        );
        if c != self.cin {
            bail_shape!("input has {c} channels, weights expect {}", self.cin);
        }
        if let Some(b) = bias {
            if b.len() != self.m {
                bail_shape!("bias length {} vs {} output channels", b.len(), self.m);
            }
        }
        let (oh, ow) = self.output_hw(h, w)?;
        let rows = n * oh * ow;
        if out.len() != rows * self.m {
            bail_shape!("output needs {} elems, got {}", rows * self.m, out.len());
        }
        let (ph, pw) = self.pad;
        let (sph, spw) = (h + 2 * ph, w + 2 * pw);
        let staging_bytes = n * sph * spw * c;
        let patch_bytes = rows * self.k;

        let q = choose_act_quant(input.data());
        let (sf, pf) = ws.split2(elems_for_bytes(staging_bytes), elems_for_bytes(patch_bytes));
        let staging = &mut as_u8_mut(sf)[..staging_bytes];
        let patches = &mut as_u8_mut(pf)[..patch_bytes];

        // Quantize into the padded staging; the border is zp bytes, which
        // dequantize to exactly 0.0 (zero padding for free).
        let stage_t = crate::trace::begin();
        if ph != 0 || pw != 0 {
            staging.fill(q.zp as u8);
        }
        let src = input.data();
        for ni in 0..n {
            for y in 0..h {
                let srow = &src[((ni * h + y) * w) * c..][..w * c];
                let drow = &mut staging[(((ni * sph + y + ph) * spw) + pw) * c..][..w * c];
                quantize_u8_into(srow, q, drow);
            }
        }
        crate::trace::end_stage(
            stage_t,
            crate::trace::Stage::Quantize,
            crate::trace::AlgoCode::Im2RowI8,
        );

        let stage_t = crate::trace::begin();
        self.fill_patches(staging, n, sph, spw, oh, ow, pool, patches);
        crate::trace::end_stage(
            stage_t,
            crate::trace::Stage::Pack,
            crate::trace::AlgoCode::Im2RowI8,
        );

        let stage_t = crate::trace::begin();
        let epi = QDequantBiasAct {
            out_addr: out.as_mut_ptr() as usize,
            ldc: self.m,
            a_scale: q.scale,
            a_zp: q.zp,
            w_scales: &self.b.scales,
            wsum: &self.b.wsum,
            bias,
            act,
        };
        let r = qgemm_prepacked_fused(rows, patches, &self.b.packed, pool, &epi);
        crate::trace::end_stage(
            stage_t,
            crate::trace::Stage::Gemm,
            crate::trace::AlgoCode::Im2RowI8,
        );
        r
    }

    /// Gather the u8 patch matrix `[N·OH·OW, KH·KW·C]` from the padded
    /// staging, parallel over output rows (same shape as the f32 engine's
    /// `fill_patches`, one `KW·C` contiguous copy per kernel row).
    fn fill_patches(
        &self,
        staging: &[u8],
        n: usize,
        sph: usize,
        spw: usize,
        oh: usize,
        ow: usize,
        pool: Option<&ThreadPool>,
        patches: &mut [u8],
    ) {
        let (kh, kw) = self.kernel;
        let (sh, sw) = self.stride;
        let (c, k) = (self.cin, self.k);
        let base = patches.as_mut_ptr() as usize;
        let row_job = |job: usize| {
            let ni = job / oh;
            let oy = job % oh;
            let y0 = oy * sh;
            for ox in 0..ow {
                let x0 = ox * sw;
                let ridx = (ni * oh + oy) * ow + ox;
                // SAFETY: each job owns the `ow` disjoint patch rows of one
                // output row; every write stays inside the `rows·k` patch
                // buffer whose base pointer outlives the parallel section.
                let dst =
                    unsafe { std::slice::from_raw_parts_mut((base as *mut u8).add(ridx * k), k) };
                for a in 0..kh {
                    let srow = &staging[((ni * sph + y0 + a) * spw + x0) * c..][..kw * c];
                    dst[a * kw * c..(a + 1) * kw * c].copy_from_slice(srow);
                }
            }
        };
        match pool {
            Some(pool) => pool.parallel_for(n * oh, row_job),
            None => (0..n * oh).for_each(row_job),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::im2row::Im2RowConvolution;
    use crate::util::rel_error;

    fn oracle(
        input: &Tensor,
        weights: &Tensor,
        stride: (usize, usize),
        pad: (usize, usize),
        bias: Option<&[f32]>,
        act: Activation,
    ) -> Tensor {
        let mut ws = Workspace::new();
        Im2RowConvolution::new(weights, stride, pad)
            .unwrap()
            .run_fused_with(input, None, bias, act, &mut ws)
            .unwrap()
    }

    #[test]
    fn quantized_tracks_f32_oracle() {
        for (stride, pad) in [((1, 1), (1, 1)), ((2, 2), (1, 1)), ((1, 1), (0, 0))] {
            let input = Tensor::randn(&[2, 10, 9, 7], 31);
            let weights = Tensor::randn(&[11, 3, 3, 7], 32);
            let bias: Vec<f32> = (0..11).map(|i| i as f32 * 0.2 - 1.0).collect();
            let conv = QuantIm2RowConvolution::new(&weights, stride, pad).unwrap();
            let mut ws = Workspace::new();
            for act in [Activation::None, Activation::Relu, Activation::Relu6] {
                let got = conv
                    .run_fused_i8_with(&input, None, Some(&bias), act, &mut ws)
                    .unwrap();
                let want = oracle(&input, &weights, stride, pad, Some(&bias), act);
                assert_eq!(got.shape(), want.shape());
                let e = rel_error(got.data(), want.data());
                assert!(
                    e < 0.05,
                    "stride {stride:?} pad {pad:?} act {act}: rel err {e}"
                );
            }
        }
    }

    #[test]
    fn into_matches_with_bitwise_from_poisoned_arena() {
        let input = Tensor::randn(&[1, 8, 8, 5], 41);
        let weights = Tensor::randn(&[6, 3, 3, 5], 42);
        let conv = QuantIm2RowConvolution::new(&weights, (1, 1), (1, 1)).unwrap();
        let mut ws = Workspace::new();
        let want = conv
            .run_fused_i8_with(&input, None, None, Activation::Relu, &mut ws)
            .unwrap();
        // Poison the arena (NaN-free) and the output; the into-path must
        // fully overwrite both of its scratch buffers and the output.
        let elems = conv.workspace_elems_for(1, 8, 8).unwrap();
        let mut ws2 = Workspace::with_capacity(elems);
        for v in ws2.take(elems).iter_mut() {
            *v = f32::from_bits(0x5a5a5a5a);
        }
        let mut out = vec![f32::from_bits(0x3a3a3a3a); want.data().len()];
        conv.run_fused_i8_into(
            &input.view(),
            None,
            None,
            Activation::Relu,
            &mut ws2,
            &mut out,
        )
        .unwrap();
        assert_eq!(ws2.grow_count(), 0, "workspace_elems_for must cover the walk");
        let same = out
            .iter()
            .zip(want.data())
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "into/with must agree bitwise");
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        let pool = ThreadPool::new(4);
        let input = Tensor::randn(&[1, 12, 11, 6], 51);
        let weights = Tensor::randn(&[9, 3, 3, 6], 52);
        let conv = QuantIm2RowConvolution::new(&weights, (1, 1), (1, 1)).unwrap();
        let mut ws = Workspace::new();
        let a = conv
            .run_fused_i8_with(&input, None, None, Activation::None, &mut ws)
            .unwrap();
        let b = conv
            .run_fused_i8_with(&input, Some(&pool), None, Activation::None, &mut ws)
            .unwrap();
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn rejects_bad_shapes() {
        let weights = Tensor::randn(&[4, 3, 3, 5], 1);
        assert!(QuantIm2RowConvolution::new(&weights, (0, 1), (1, 1)).is_err());
        let conv = QuantIm2RowConvolution::new(&weights, (1, 1), (0, 0)).unwrap();
        // Wrong channel count.
        let bad = Tensor::randn(&[1, 8, 8, 4], 2);
        let mut ws = Workspace::new();
        assert!(conv
            .run_fused_i8_with(&bad, None, None, Activation::None, &mut ws)
            .is_err());
        // Wrong bias length.
        let x = Tensor::randn(&[1, 8, 8, 5], 3);
        assert!(conv
            .run_fused_i8_with(&x, None, Some(&[0.0; 3]), Activation::None, &mut ws)
            .is_err());
        // Input smaller than the filter.
        let tiny = Tensor::randn(&[1, 2, 2, 5], 4);
        assert!(conv
            .run_fused_i8_with(&tiny, None, None, Activation::None, &mut ws)
            .is_err());
    }
}
