//! Quantized (dynamic-range int8) inference: the dtype layer, quantization
//! parameter selection, and the int8 convolution engines.
//!
//! The scheme is classic dynamic-range quantization, the variant mobile
//! runtimes deploy when no calibration dataset is available:
//!
//! * **Activations** — per-tensor **u8 affine**, chosen per call from the
//!   live tensor: the range is extended to include 0 (`lo = min(0, min x)`,
//!   `hi = max(0, max x)`) so the zero point is exact —
//!   `quantize(0.0) == zp` bit-for-bit, which is what makes zero padding
//!   free (padding bytes are just `zp`).
//! * **Weights** — per-output-channel **symmetric i8**
//!   (`scale_c = max_abs / 127`, clamp to `[-127, 127]`), quantized once at
//!   prepare time together with the folded per-channel correction term
//!   `wsum[c] = Σ_k qw` (see [`crate::gemm::QDequantBiasAct`]).
//! * **Accumulation** — i32, via the [`crate::simd::qmacc_4x16`]
//!   micro-kernel (u8×i8 products widened through i16).
//! * **Outputs** — dequantized back to f32 in the GEMM epilogue (bias add
//!   and activation clamp fused), so activations flow between layers in
//!   f32 and the activation plan is dtype-agnostic. The i32→i8
//!   [`crate::gemm::Requantize`] epilogue covers fully-quantized chains.
//!
//! All rounding is **round-to-nearest-even**: exact reference
//! [`crate::util::round_half_even`], hot paths use the branch-free
//! [`crate::util::fast_round_half_even`] magic-number form.
//!
//! Engines ([`QuantIm2RowConvolution`], [`QuantDepthwiseConvolution`],
//! [`QuantPointwiseConvolution`]) mirror their f32 twins' API — a
//! zero-alloc `run_fused_i8_into` drawing u8 scratch from the shared f32
//! arena (byte-reinterpreted, sized by [`crate::workspace::elems_for_bytes`])
//! plus an allocating `run_fused_i8_with`. Winograd stays f32-only: its
//! transformed-domain dynamic range makes int8 numerics a known minefield.

pub mod depthwise;
pub mod gemm;
pub mod im2row;
pub mod pointwise;

pub use depthwise::QuantDepthwiseConvolution;
pub use im2row::QuantIm2RowConvolution;
pub use pointwise::QuantPointwiseConvolution;

use crate::util::fast_round_half_even;
use crate::{Error, Result};

/// Element type a layer (or a whole prepared model) computes in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Dtype {
    /// Single-precision float — the paper's pipeline.
    #[default]
    F32,
    /// Dynamic-range quantized int8 (u8 activations × i8 weights, i32
    /// accumulation, f32 layer outputs).
    Int8,
}

impl Dtype {
    /// Parse a CLI-style name; `None` for unknown spellings.
    pub fn parse(s: &str) -> Option<Dtype> {
        match s {
            "f32" | "fp32" | "float32" => Some(Dtype::F32),
            "int8" | "i8" => Some(Dtype::Int8),
            _ => None,
        }
    }

    /// Is this a quantized dtype?
    pub fn is_quantized(self) -> bool {
        matches!(self, Dtype::Int8)
    }
}

impl std::fmt::Display for Dtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Dtype::F32 => write!(f, "f32"),
            Dtype::Int8 => write!(f, "int8"),
        }
    }
}

impl std::str::FromStr for Dtype {
    type Err = Error;

    /// Named `Err` (never a panic) per the CLI convention, so
    /// `Args::get_parse_or("dtype", Dtype::F32)` diagnoses bad values.
    fn from_str(s: &str) -> Result<Dtype> {
        Dtype::parse(s)
            .ok_or_else(|| Error::Config(format!("unknown dtype {s:?} (expected f32 or int8)")))
    }
}

/// Per-tensor affine u8 quantization parameters for one activation tensor.
#[derive(Debug, Clone, Copy)]
pub struct ActQuant {
    /// Step size `s` (always > 0 and finite).
    pub scale: f32,
    /// `1 / s`, precomputed for the hot quantize loop.
    pub inv_scale: f32,
    /// Zero point in `[0, 255]`: `quantize(0.0) == zp` exactly.
    pub zp: i32,
}

/// Choose dynamic-range u8 parameters covering `x` (and always covering
/// 0.0, so the zero point is exact). A constant-zero (or empty) tensor gets
/// the degenerate `scale = 1, zp = 0`.
pub fn choose_act_quant(x: &[f32]) -> ActQuant {
    let mut lo = 0.0f32;
    let mut hi = 0.0f32;
    for &v in x {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let range = hi - lo;
    let scale = if range > 0.0 { range / 255.0 } else { 1.0 };
    let zp = (fast_round_half_even(-lo / scale) as i32).clamp(0, 255);
    ActQuant {
        scale,
        inv_scale: 1.0 / scale,
        zp,
    }
}

/// Quantize `src` to u8 under `q`: `clamp(zp + rhe(x / s), 0, 255)`.
///
/// `dst.len()` must equal `src.len()` (the engines guarantee it). Values
/// inside the chosen range never clamp; the clamp guards rounding at the
/// extremes.
#[inline]
pub fn quantize_u8_into(src: &[f32], q: ActQuant, dst: &mut [u8]) {
    debug_assert_eq!(src.len(), dst.len());
    let zp = q.zp as f32;
    for (d, &x) in dst.iter_mut().zip(src.iter()) {
        // Folding zp before the round keeps the loop at one fma + one
        // round per element (SSE2/NEON-vectorizable). At an exact .5 tie
        // an odd zp flips which even neighbour wins vs `zp + rhe(x/s)` —
        // both are the nearest grid point, which is all the quantizer
        // promises.
        *d = (fast_round_half_even(x * q.inv_scale + zp)).clamp(0.0, 255.0) as u8;
    }
}

/// Dequantize one u8 value under `q` — the test-side inverse.
#[inline]
pub fn dequantize_u8(v: u8, q: ActQuant) -> f32 {
    (v as i32 - q.zp) as f32 * q.scale
}

/// Quantize one weight channel to symmetric i8: `scale = max_abs / 127`
/// (1.0 for an all-zero channel), values clamped to `[-127, 127]`, ties to
/// even. Returns `(scale, Σ qw)` — the per-channel scale and the folded
/// zero-point correction sum.
pub fn quantize_weight_channel(src: &[f32], dst: &mut [i8]) -> (f32, i32) {
    debug_assert_eq!(src.len(), dst.len());
    let max_abs = src.iter().map(|x| x.abs()).fold(0.0f32, f32::max);
    let scale = if max_abs > 0.0 { max_abs / 127.0 } else { 1.0 };
    let inv = 1.0 / scale;
    let mut wsum = 0i32;
    for (d, &x) in dst.iter_mut().zip(src.iter()) {
        let qv = (fast_round_half_even(x * inv) as i32).clamp(-127, 127);
        *d = qv as i8;
        wsum += qv;
    }
    (scale, wsum)
}

/// Reinterpret an f32 arena slice as raw bytes — how the quant engines draw
/// u8 staging/patch scratch from the shared [`crate::workspace::Workspace`]
/// without a second arena type (size it with
/// [`crate::workspace::elems_for_bytes`]).
#[inline]
pub fn as_u8_mut(buf: &mut [f32]) -> &mut [u8] {
    let bytes = std::mem::size_of_val(buf);
    // SAFETY: u8 has alignment 1 and every bit pattern is a valid u8; the
    // byte slice covers exactly the same allocation, and the exclusive
    // `&mut buf` borrow it reborrows guarantees no aliasing for its
    // lifetime.
    unsafe { std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut u8, bytes) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::{EpilogueI32, Requantize};
    use crate::util::{round_half_even, XorShiftRng};

    /// Scalar model of the `Requantize` epilogue, element by element,
    /// built on the exact rounding reference.
    fn requantize_ref(
        acc: i32,
        bias: i32,
        scale: f32,
        zp: i32,
        qmin: i32,
        qmax: i32,
    ) -> i8 {
        let v = round_half_even(acc.wrapping_add(bias) as f32 * scale);
        let q = if v >= i32::MAX as f32 {
            i32::MAX
        } else if v <= i32::MIN as f32 {
            i32::MIN
        } else {
            v as i32
        };
        q.saturating_add(zp).clamp(qmin, qmax) as i8
    }

    /// Drive the `Requantize` epilogue over an m×n accumulator matrix the
    /// way the qgemm driver does (4×16 tiles, ragged edges included) and
    /// compare every element against the scalar reference.
    fn check_requantize_matrix(
        m: usize,
        n: usize,
        acc: &[i32],
        bias: Option<&[i32]>,
        scale: &[f32],
        zp: i32,
        qmin: i32,
        qmax: i32,
    ) {
        // NaN-free poisoned output: a sentinel the epilogue must overwrite.
        let mut out = vec![77i8; m * n];
        let epi = Requantize {
            out_addr: out.as_mut_ptr() as usize,
            ldc: n,
            bias,
            scale,
            zero_point: zp,
            qmin,
            qmax,
        };
        for r0 in (0..m).step_by(4) {
            let rows = 4.min(m - r0);
            for c0 in (0..n).step_by(16) {
                let cols = 16.min(n - c0);
                let mut tile = [[0i32; 16]; 4];
                for r in 0..rows {
                    for j in 0..cols {
                        tile[r][j] = acc[(r0 + r) * n + c0 + j];
                    }
                }
                epi.micro_tile_i32(&tile, r0, c0, rows, cols);
            }
        }
        for r in 0..m {
            for c in 0..n {
                let b = bias.map_or(0, |b| b[c]);
                let want = requantize_ref(acc[r * n + c], b, scale[c], zp, qmin, qmax);
                assert_eq!(out[r * n + c], want, "({r},{c}) acc {}", acc[r * n + c]);
            }
        }
    }

    #[test]
    fn requantize_property_random_ragged_channels() {
        // C % 4 != 0 and % 16 != 0: n = 13 exercises ragged tile columns.
        let (m, n) = (9, 13);
        let mut rng = XorShiftRng::new(42);
        let mut acc = vec![0i32; m * n];
        for v in acc.iter_mut() {
            // Mix of small and large magnitudes, both signs.
            let r = rng.next_u64();
            let small = (r % 20001) as i32 - 10000;
            *v = if r % 7 == 0 { small.wrapping_mul(70001) } else { small };
        }
        let mut scale = vec![0.0f32; n];
        let mut bias = vec![0i32; n];
        for c in 0..n {
            scale[c] = 0.001 + (c as f32) * 0.013;
            bias[c] = (c as i32 - 6) * 37;
        }
        for (zp, qmin, qmax) in [(0, -128, 127), (-1, -128, 127), (10, 10, 127)] {
            check_requantize_matrix(m, n, &acc, Some(&bias), &scale, zp, qmin, qmax);
            check_requantize_matrix(m, n, &acc, None, &scale, zp, qmin, qmax);
        }
    }

    #[test]
    fn requantize_saturates_at_both_bounds() {
        // Accumulators far beyond the i8 grid, both signs, must pin to
        // exactly qmin/qmax — including through the fast-rounding path's
        // out-of-validity range (|v| ≥ 2²²).
        let n = 5;
        let acc: Vec<i32> = vec![i32::MAX, i32::MIN, 100_000_000, -100_000_000, 0];
        let scale = vec![1.0f32; n];
        check_requantize_matrix(1, n, &acc, None, &scale, 3, -128, 127);
        let mut out = vec![0i8; n];
        Requantize {
            out_addr: out.as_mut_ptr() as usize,
            ldc: n,
            bias: None,
            scale: &scale,
            zero_point: 3,
            qmin: -128,
            qmax: 127,
        }
        .micro_tile_i32(
            &{
                let mut t = [[0i32; 16]; 4];
                t[0][..n].copy_from_slice(&acc);
                t
            },
            0,
            0,
            1,
            n,
        );
        assert_eq!(out, vec![127, -128, 127, -128, 3]);
    }

    #[test]
    fn requantize_ties_round_to_even() {
        // scale = 0.5 turns odd accumulators into exact .5 ties.
        let acc: Vec<i32> = vec![1, 3, 5, -1, -3, -5, 2, -2];
        let n = acc.len();
        let scale = vec![0.5f32; n];
        check_requantize_matrix(1, n, &acc, None, &scale, 0, -128, 127);
        let mut out = vec![99i8; n];
        Requantize {
            out_addr: out.as_mut_ptr() as usize,
            ldc: n,
            bias: None,
            scale: &scale,
            zero_point: 0,
            qmin: -128,
            qmax: 127,
        }
        .micro_tile_i32(
            &{
                let mut t = [[0i32; 16]; 4];
                t[0][..n].copy_from_slice(&acc);
                t
            },
            0,
            0,
            1,
            n,
        );
        // 0.5→0, 1.5→2, 2.5→2; negatives mirror; integers untouched.
        assert_eq!(out, vec![0, 2, 2, 0, -2, -2, 1, -1]);
    }

    #[test]
    fn activation_zero_point_is_exact() {
        let mut rng = XorShiftRng::new(7);
        for case in 0..20 {
            let mut x = vec![0.0f32; 97];
            rng.fill_normal(&mut x);
            // Alternate all-positive / all-negative / mixed tensors so the
            // zero point lands at 0, 255 and in between.
            if case % 3 == 1 {
                for v in x.iter_mut() {
                    *v = v.abs();
                }
            } else if case % 3 == 2 {
                for v in x.iter_mut() {
                    *v = -v.abs();
                }
            }
            let q = choose_act_quant(&x);
            assert!(q.scale > 0.0 && q.scale.is_finite());
            assert!((0..=255).contains(&q.zp));
            let mut z = [0u8; 1];
            quantize_u8_into(&[0.0], q, &mut z);
            assert_eq!(z[0] as i32, q.zp, "quantize(0) must hit the zero point");
            assert_eq!(dequantize_u8(z[0], q), 0.0);
            // Round-trip error of every value is within half a step.
            let mut qx = vec![0u8; x.len()];
            quantize_u8_into(&x, q, &mut qx);
            for (&v, &qv) in x.iter().zip(&qx) {
                let back = dequantize_u8(qv, q);
                assert!(
                    (back - v).abs() <= 0.5 * q.scale + 1e-6,
                    "x {v} -> {qv} -> {back} (scale {})",
                    q.scale
                );
            }
        }
        // Degenerate all-zero tensor.
        let q = choose_act_quant(&[0.0; 8]);
        assert_eq!((q.scale, q.zp), (1.0, 0));
    }

    #[test]
    fn weight_channel_quantization_symmetric() {
        let src = [0.5f32, -1.0, 0.25, 0.999, -0.5];
        let mut dst = [0i8; 5];
        let (scale, wsum) = quantize_weight_channel(&src, &mut dst);
        assert_eq!(scale, 1.0 / 127.0);
        // 0.999 / (1/127) = 126.873 → 127; -1.0 → -127.
        assert_eq!(dst, [64, -127, 32, 127, -64]);
        assert_eq!(wsum, 64 - 127 + 32 + 127 - 64);
        // All-zero channel: unit scale, zero sum.
        let mut z = [0i8; 3];
        let (scale, wsum) = quantize_weight_channel(&[0.0; 3], &mut z);
        assert_eq!((scale, wsum), (1.0, 0));
    }

    #[test]
    fn dtype_parse_display_fromstr() {
        assert_eq!(Dtype::parse("f32"), Some(Dtype::F32));
        assert_eq!(Dtype::parse("int8"), Some(Dtype::Int8));
        assert_eq!(Dtype::parse("i8"), Some(Dtype::Int8));
        assert_eq!(Dtype::parse("int4"), None);
        assert_eq!(Dtype::F32.to_string(), "f32");
        assert_eq!(Dtype::Int8.to_string(), "int8");
        assert!(Dtype::Int8.is_quantized() && !Dtype::F32.is_quantized());
        assert_eq!("int8".parse::<Dtype>().unwrap(), Dtype::Int8);
        let err = "bf16".parse::<Dtype>().unwrap_err();
        assert!(matches!(err, Error::Config(_)));
        assert!(err.to_string().contains("bf16"));
    }

    #[test]
    fn as_u8_mut_reinterprets_in_place() {
        let mut buf = [0.0f32; 4];
        {
            let bytes = as_u8_mut(&mut buf);
            assert_eq!(bytes.len(), 16);
            bytes.fill(0x3f);
        }
        // 0x3f3f3f3f as f32 is a normal positive value — the write went
        // through to the same storage.
        assert!(buf.iter().all(|&v| v == f32::from_bits(0x3f3f3f3f)));
    }
}
