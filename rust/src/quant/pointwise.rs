//! Quantized direct pointwise (1×1) convolution — the int8 twin of
//! [`crate::conv::pointwise::PointwiseConvolution`].
//!
//! The f32 engine's zero-copy trick (the NHWC input *is* the GEMM A
//! operand) survives quantization almost intact: the input still needs one
//! quantize pass, but that pass writes a **u8** buffer a quarter the size
//! of the f32 input, and there is no patch gather — at stride 1 the
//! quantized buffer is fed to the int8 GEMM verbatim. Stride 2 (ResNet
//! downsample projections) fuses the strided row gather *into* the
//! quantize pass: each output pixel's `C`-run is quantized straight out of
//! the strided source position, so the gather costs nothing extra.

use crate::gemm::{Activation, QDequantBiasAct};
use crate::parallel::ThreadPool;
use crate::quant::gemm::{qgemm_prepacked_fused, quantize_pack_b, QuantizedGemmB};
use crate::quant::{as_u8_mut, choose_act_quant, quantize_u8_into};
use crate::tensor::{Tensor, TensorView};
use crate::workspace::{elems_for_bytes, Workspace};
use crate::{bail_shape, bail_unsupported, Result};

/// Prepared quantized pointwise convolution: `[M, 1, 1, C]` weights
/// quantized per output channel and packed as the int8 GEMM's B operand.
#[derive(Debug, Clone)]
pub struct QuantPointwiseConvolution {
    cin: usize,
    cout: usize,
    stride: (usize, usize),
    b: QuantizedGemmB,
}

impl QuantPointwiseConvolution {
    /// Prepare from `[M, 1, 1, C]` weights; unpadded, stride (1,1) or
    /// (2,2) only — mirroring the f32 engine's envelope so the dtype-aware
    /// selector can route identically.
    pub fn new(weights: &Tensor, stride: (usize, usize), pad: (usize, usize)) -> Result<Self> {
        if weights.rank() != 4 || weights.shape()[1] != 1 || weights.shape()[2] != 1 {
            bail_shape!("pointwise weights must be [M, 1, 1, C], got {:?}", weights.shape());
        }
        if pad != (0, 0) {
            bail_unsupported!("pointwise engine is unpadded-only, got pad {pad:?}");
        }
        if stride != (1, 1) && stride != (2, 2) {
            bail_unsupported!("pointwise engine supports stride 1 or 2, got {stride:?}");
        }
        let (m, c) = (weights.shape()[0], weights.shape()[3]);
        // Same k = ch row order as the f32 engine's packed matrix; columns
        // are output channels, so per-column quantization is per-channel.
        let mut wt = vec![0.0f32; c * m];
        for mi in 0..m {
            for ch in 0..c {
                wt[ch * m + mi] = weights.at4(mi, 0, 0, ch);
            }
        }
        Ok(QuantPointwiseConvolution {
            cin: c,
            cout: m,
            stride,
            b: quantize_pack_b(&wt, c, m)?,
        })
    }

    /// Output spatial size for an `h×w` input.
    pub fn output_hw(&self, h: usize, w: usize) -> Result<(usize, usize)> {
        if h == 0 || w == 0 {
            bail_shape!("input {h}x{w} smaller than filter 1x1");
        }
        Ok(((h - 1) / self.stride.0 + 1, (w - 1) / self.stride.1 + 1))
    }

    /// Workspace elements (**f32**s) one inference over an `[n, h, w, C]`
    /// input borrows: the quantized u8 A matrix (`N·OH·OW·C` bytes,
    /// byte-ceiled into f32 units) — the engine's only scratch.
    pub fn workspace_elems_for(&self, n: usize, h: usize, w: usize) -> Result<usize> {
        let (oh, ow) = self.output_hw(h, w)?;
        Ok(elems_for_bytes(n * oh * ow * self.cin))
    }

    /// Allocating twin of [`run_fused_i8_into`](Self::run_fused_i8_into)
    /// (tests / one-shot use).
    pub fn run_fused_i8_with(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
    ) -> Result<Tensor> {
        if input.rank() != 4 {
            bail_shape!("input must be [N, H, W, C], got {:?}", input.shape());
        }
        let (n, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
        let (oh, ow) = self.output_hw(h, w)?;
        let mut out = Tensor::zeros(&[n, oh, ow, self.cout]);
        self.run_fused_i8_into(&input.view(), pool, bias, act, ws, out.data_mut())?;
        Ok(out)
    }

    /// Quantize (stride-fused) → int8 GEMM with the dequantize epilogue,
    /// writing the f32 output into `out`. Zero heap allocations.
    pub fn run_fused_i8_into(
        &self,
        input: &TensorView,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
        out: &mut [f32],
    ) -> Result<()> {
        if input.rank() != 4 {
            bail_shape!("input must be [N, H, W, C], got {:?}", input.shape());
        }
        let (n, h, w, c) = (
            input.shape()[0],
            input.shape()[1],
            input.shape()[2],
            input.shape()[3],
        );
        if c != self.cin {
            bail_shape!("input has {c} channels, pointwise weights expect {}", self.cin);
        }
        if let Some(b) = bias {
            if b.len() != self.cout {
                bail_shape!("bias length {} vs {} output channels", b.len(), self.cout);
            }
        }
        let (oh, ow) = self.output_hw(h, w)?;
        let rows = n * oh * ow;
        if out.len() != rows * self.cout {
            bail_shape!(
                "output slice has {} elems, layer writes {}",
                out.len(),
                rows * self.cout
            );
        }

        let stage_t = crate::trace::begin();
        let q = choose_act_quant(input.data());
        let a_bytes = rows * c;
        let qa = &mut as_u8_mut(ws.take(elems_for_bytes(a_bytes)))[..a_bytes];
        let data = input.data();
        if self.stride == (1, 1) {
            quantize_u8_into(data, q, qa);
        } else {
            // Fused strided gather + quantize: each job quantizes the `ow`
            // sampled C-runs of one output row straight out of the source.
            let (sh, sw) = self.stride;
            let base = qa.as_mut_ptr() as usize;
            let gather_row = |r: usize| {
                let bn = r / oh;
                let oy = r % oh;
                // SAFETY: each job owns one disjoint `ow·c`-byte staging
                // row inside the `rows·c` buffer, which outlives the
                // parallel section.
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(
                        (base as *mut u8).add((bn * oh + oy) * ow * c),
                        ow * c,
                    )
                };
                let src_row = ((bn * h + oy * sh) * w) * c;
                for ox in 0..ow {
                    let s0 = src_row + ox * sw * c;
                    quantize_u8_into(&data[s0..s0 + c], q, &mut dst[ox * c..(ox + 1) * c]);
                }
            };
            match pool {
                Some(pool) => pool.parallel_for(n * oh, gather_row),
                None => (0..n * oh).for_each(gather_row),
            }
        }
        crate::trace::end_stage(
            stage_t,
            crate::trace::Stage::Quantize,
            crate::trace::AlgoCode::PointwiseI8,
        );
        // The quantized A buffer *is* the GEMM operand — no separate patch
        // pack, so the Pack span is ~0 ns (kept for the fixed census).
        let stage_t = crate::trace::begin();
        crate::trace::end_stage(
            stage_t,
            crate::trace::Stage::Pack,
            crate::trace::AlgoCode::PointwiseI8,
        );

        let stage_t = crate::trace::begin();
        let epi = QDequantBiasAct {
            out_addr: out.as_mut_ptr() as usize,
            ldc: self.cout,
            a_scale: q.scale,
            a_zp: q.zp,
            w_scales: &self.b.scales,
            wsum: &self.b.wsum,
            bias,
            act,
        };
        let r = qgemm_prepacked_fused(rows, qa, &self.b.packed, pool, &epi);
        crate::trace::end_stage(
            stage_t,
            crate::trace::Stage::Gemm,
            crate::trace::AlgoCode::PointwiseI8,
        );
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::pointwise::PointwiseConvolution;
    use crate::util::rel_error;

    #[test]
    fn quantized_tracks_f32_oracle() {
        for stride in [(1, 1), (2, 2)] {
            let input = Tensor::randn(&[2, 9, 11, 13], 61);
            let weights = Tensor::randn(&[17, 1, 1, 13], 62);
            let bias: Vec<f32> = (0..17).map(|i| i as f32 * 0.15 - 1.2).collect();
            let qconv = QuantPointwiseConvolution::new(&weights, stride, (0, 0)).unwrap();
            let fconv = PointwiseConvolution::new(&weights, stride, (0, 0)).unwrap();
            let mut ws = Workspace::new();
            for act in [Activation::None, Activation::Relu] {
                let got = qconv
                    .run_fused_i8_with(&input, None, Some(&bias), act, &mut ws)
                    .unwrap();
                let want = fconv
                    .run_fused_with(&input, None, Some(&bias), act, &mut ws)
                    .unwrap();
                assert_eq!(got.shape(), want.shape());
                let e = rel_error(got.data(), want.data());
                assert!(e < 0.05, "stride {stride:?} act {act}: rel err {e}");
            }
        }
    }

    #[test]
    fn into_matches_with_and_arena_never_grows() {
        for stride in [(1, 1), (2, 2)] {
            let input = Tensor::randn(&[1, 10, 7, 6], 71);
            let weights = Tensor::randn(&[9, 1, 1, 6], 72);
            let conv = QuantPointwiseConvolution::new(&weights, stride, (0, 0)).unwrap();
            let mut ws = Workspace::new();
            let want = conv
                .run_fused_i8_with(&input, None, None, Activation::Relu6, &mut ws)
                .unwrap();
            let elems = conv.workspace_elems_for(1, 10, 7).unwrap();
            let mut ws2 = Workspace::with_capacity(elems);
            for v in ws2.take(elems).iter_mut() {
                *v = f32::from_bits(0x5a5a5a5a);
            }
            let mut out = vec![f32::from_bits(0x3a3a3a3a); want.data().len()];
            conv.run_fused_i8_into(
                &input.view(),
                None,
                None,
                Activation::Relu6,
                &mut ws2,
                &mut out,
            )
            .unwrap();
            assert_eq!(ws2.grow_count(), 0, "stride {stride:?}: arena grew");
            let same = out
                .iter()
                .zip(want.data())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "stride {stride:?}: into/with must agree bitwise");
        }
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        let pool = ThreadPool::new(4);
        let input = Tensor::randn(&[1, 13, 14, 24], 81);
        let weights = Tensor::randn(&[32, 1, 1, 24], 82);
        for stride in [(1, 1), (2, 2)] {
            let conv = QuantPointwiseConvolution::new(&weights, stride, (0, 0)).unwrap();
            let mut ws = Workspace::new();
            let a = conv
                .run_fused_i8_with(&input, None, None, Activation::Relu, &mut ws)
                .unwrap();
            let b = conv
                .run_fused_i8_with(&input, Some(&pool), None, Activation::Relu, &mut ws)
                .unwrap();
            assert_eq!(a.data(), b.data(), "stride {stride:?}");
        }
    }

    #[test]
    fn rejects_bad_configs() {
        let w11 = Tensor::zeros(&[6, 1, 1, 4]);
        assert!(QuantPointwiseConvolution::new(&Tensor::zeros(&[6, 3, 3, 4]), (1, 1), (0, 0))
            .is_err());
        assert!(QuantPointwiseConvolution::new(&w11, (1, 1), (1, 1)).is_err());
        assert!(QuantPointwiseConvolution::new(&w11, (1, 2), (0, 0)).is_err());
        let conv = QuantPointwiseConvolution::new(&w11, (1, 1), (0, 0)).unwrap();
        let mut ws = Workspace::new();
        // Channel mismatch, bad bias, bad out slice.
        assert!(conv
            .run_fused_i8_with(&Tensor::zeros(&[1, 8, 8, 5]), None, None, Activation::None, &mut ws)
            .is_err());
        let input = Tensor::zeros(&[1, 8, 8, 4]);
        let mut out = vec![0.0; 8 * 8 * 6];
        assert!(conv
            .run_fused_i8_into(
                &input.view(),
                None,
                Some(&[0.0; 3]),
                Activation::None,
                &mut ws,
                &mut out,
            )
            .is_err());
        assert!(conv
            .run_fused_i8_into(&input.view(), None, None, Activation::None, &mut ws, &mut out[1..])
            .is_err());
    }
}
