//! PJRT runtime: load AOT-compiled HLO text artifacts (produced by
//! `python/compile/aot.py` from the JAX/Pallas layers) and execute them on
//! the CPU PJRT client from the Rust hot path.
//!
//! Interchange format is HLO **text**, not serialized `HloModuleProto` —
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see
//! `/opt/xla-example/README.md`). Python never runs at request time: the
//! artifacts directory is compiled once by `make artifacts`.
//!
//! ## Feature gating
//!
//! The real implementation needs the vendored `xla` bindings and is behind
//! the **`pjrt`** cargo feature (add the vendored crate as a path
//! dependency to enable it — see `Cargo.toml`). Offline builds get a stub
//! with the same API whose entry points return [`Error::Runtime`], so the
//! crate, the `winoconv verify` subcommand and `examples/pjrt_verify`
//! always compile; verification simply reports that PJRT is unavailable.

pub mod verify;

use crate::Result;
use std::path::{Path, PathBuf};

/// List `*.hlo.txt` artifacts under a directory (available with or without
/// the `pjrt` feature).
pub fn list_artifacts(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let p = entry?.path();
        if p.to_string_lossy().ends_with(".hlo.txt") {
            out.push(p);
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(feature = "pjrt")]
mod imp {
    use crate::tensor::Tensor;
    use crate::{Error, Result};
    use std::path::{Path, PathBuf};

    /// A compiled HLO executable bound to the CPU PJRT client.
    pub struct HloExecutable {
        exe: xla::PjRtLoadedExecutable,
        /// Path the module was loaded from (for reports).
        pub path: PathBuf,
    }

    impl std::fmt::Debug for HloExecutable {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("HloExecutable").field("path", &self.path).finish_non_exhaustive()
        }
    }

    /// Wrapper that owns the PJRT client and hands out executables.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
    }

    impl std::fmt::Debug for PjrtRuntime {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("PjrtRuntime").finish_non_exhaustive()
        }
    }

    impl PjrtRuntime {
        /// Connect to the CPU PJRT client.
        pub fn cpu() -> Result<PjrtRuntime> {
            let client = xla::PjRtClient::cpu().map_err(wrap)?;
            Ok(PjrtRuntime { client })
        }

        /// Platform string (e.g. `"cpu"`) and device count.
        pub fn describe(&self) -> String {
            format!(
                "platform={} devices={}",
                self.client.platform_name(),
                self.client.device_count()
            )
        }

        /// Load + compile an HLO text file.
        pub fn load_hlo_text(&self, path: &Path) -> Result<HloExecutable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::Runtime(format!("non-utf8 path {path:?}")))?,
            )
            .map_err(wrap)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(wrap)?;
            Ok(HloExecutable {
                exe,
                path: path.to_path_buf(),
            })
        }
    }

    impl HloExecutable {
        /// Execute with NHWC tensors; the module must have been lowered with
        /// `return_tuple=True` (aot.py does), so the single tuple result is
        /// unpacked into its element tensors.
        pub fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|t| {
                    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(t.data())
                        .reshape(&dims)
                        .map_err(wrap)
                })
                .collect::<Result<_>>()?;
            let result = self.exe.execute::<xla::Literal>(&literals).map_err(wrap)?;
            let tuple = result[0][0].to_literal_sync().map_err(wrap)?;
            let elements = tuple.to_tuple().map_err(wrap)?;
            elements
                .into_iter()
                .map(|lit| {
                    let shape = lit.array_shape().map_err(wrap)?;
                    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                    let data = lit.to_vec::<f32>().map_err(wrap)?;
                    Tensor::from_vec(&dims, data)
                })
                .collect()
        }
    }

    fn wrap(e: xla::Error) -> Error {
        Error::Runtime(e.to_string())
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    //! API-compatible stub used when the `pjrt` feature (and with it the
    //! vendored `xla` crate) is not available.

    use crate::tensor::Tensor;
    use crate::{Error, Result};
    use std::path::{Path, PathBuf};

    fn unavailable() -> Error {
        Error::Runtime(
            "PJRT runtime unavailable: rebuild with `--features pjrt` and the vendored `xla` \
             crate (see Cargo.toml)"
                .into(),
        )
    }

    /// Stub for the compiled-executable handle.
    #[derive(Debug)]
    pub struct HloExecutable {
        /// Path the module would have been loaded from.
        pub path: PathBuf,
    }

    /// Stub for the PJRT client wrapper.
    #[derive(Debug)]
    pub struct PjrtRuntime;

    impl PjrtRuntime {
        /// Always fails: the feature is off.
        pub fn cpu() -> Result<PjrtRuntime> {
            Err(unavailable())
        }

        /// Stub description.
        pub fn describe(&self) -> String {
            "platform=stub (pjrt feature disabled) devices=0".into()
        }

        /// Always fails: the feature is off.
        pub fn load_hlo_text(&self, _path: &Path) -> Result<HloExecutable> {
            Err(unavailable())
        }
    }

    impl HloExecutable {
        /// Always fails: the feature is off.
        pub fn run(&self, _inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
            Err(unavailable())
        }
    }
}

pub use imp::{HloExecutable, PjrtRuntime};

#[cfg(test)]
mod tests {
    use super::*;

    // Tests needing a live PJRT client are gated: they need
    // libxla_extension.so at runtime and a generated artifact. The full
    // cross-validation lives in `examples/pjrt_verify.rs`; here we only
    // check client bring-up.
    #[cfg(feature = "pjrt")]
    #[test]
    fn cpu_client_comes_up() {
        let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
        let desc = rt.describe();
        assert!(desc.contains("devices="), "{desc}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_reports_unavailable() {
        let err = PjrtRuntime::cpu().err().expect("stub must error");
        assert!(err.to_string().contains("pjrt"), "{err}");
        let rt = PjrtRuntime;
        assert!(rt.describe().contains("stub"));
        assert!(rt.load_hlo_text(std::path::Path::new("/x.hlo.txt")).is_err());
    }

    #[test]
    fn list_artifacts_filters_and_sorts() {
        let dir = std::env::temp_dir().join(format!("winoconv-art-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("b.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("a.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("ignore.bin"), "x").unwrap();
        let arts = list_artifacts(&dir).unwrap();
        let names: Vec<String> = arts
            .iter()
            .map(|p| p.file_name().unwrap().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["a.hlo.txt", "b.hlo.txt"]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn loading_missing_file_is_error() {
        let rt = PjrtRuntime::cpu().unwrap();
        assert!(rt.load_hlo_text(Path::new("/nonexistent.hlo.txt")).is_err());
    }
}
