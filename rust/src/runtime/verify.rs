//! Cross-validation of the Rust engine against the AOT JAX/Pallas
//! artifacts: the same convolution is computed by (a) the L1/L2 stack
//! lowered to HLO and executed via PJRT, and (b) the native Rust
//! region-wise pipeline — the numbers must agree. This is experiment E9
//! in DESIGN.md and the heart of `examples/pjrt_verify.rs`.
//!
//! Like the loader in [`super`], the real implementation is behind the
//! `pjrt` feature; without it [`verify_all`] returns an explanatory
//! [`Error::Runtime`](crate::Error::Runtime).

#[cfg(not(feature = "pjrt"))]
use crate::{Error, Result};
#[cfg(not(feature = "pjrt"))]
use std::path::Path;

/// Stub: PJRT is not compiled in, so nothing can be verified.
#[cfg(not(feature = "pjrt"))]
pub fn verify_all(_dir: &Path, verbose: bool) -> Result<()> {
    if verbose {
        eprintln!("pjrt feature disabled — skipping artifact cross-validation");
    }
    Err(Error::Runtime(
        "PJRT verification unavailable: rebuild with `--features pjrt` and the vendored `xla` \
         crate (see Cargo.toml)"
            .into(),
    ))
}

#[cfg(feature = "pjrt")]
pub use real::verify_all;

#[cfg(feature = "pjrt")]
mod real {
    use super::super::PjrtRuntime;
    use crate::conv::direct::direct_conv2d;
    use crate::nn::ops;
    use crate::tensor::Tensor;
    use crate::util::rel_error;
    use crate::winograd::{winograd_conv2d, WinogradVariant};
    use crate::{Error, Result};
    use std::path::Path;

    /// One artifact ↔ Rust pairing.
    struct Case {
        /// Artifact file stem.
        name: &'static str,
        /// Input tensor shapes fed to both sides.
        inputs: Vec<Vec<usize>>,
        /// Rust-side computation of the same function.
        rust: fn(&[Tensor]) -> Result<Tensor>,
    }

    fn cases() -> Vec<Case> {
        vec![
            Case {
                name: "conv_f2x2_3x3",
                inputs: vec![vec![1, 16, 16, 8], vec![16, 3, 3, 8]],
                rust: |t| winograd_conv2d(WinogradVariant::F2x2_3x3, &t[0], &t[1], (1, 1), None),
            },
            Case {
                name: "conv_f4x4_3x3",
                inputs: vec![vec![1, 24, 24, 16], vec![32, 3, 3, 16]],
                rust: |t| winograd_conv2d(WinogradVariant::F4x4_3x3, &t[0], &t[1], (1, 1), None),
            },
            Case {
                name: "conv_f2x2_5x5",
                inputs: vec![vec![1, 12, 12, 8], vec![8, 5, 5, 8]],
                rust: |t| winograd_conv2d(WinogradVariant::F2x2_5x5, &t[0], &t[1], (2, 2), None),
            },
            Case {
                name: "conv_f2_1x7",
                inputs: vec![vec![1, 8, 32, 8], vec![16, 1, 7, 8]],
                rust: |t| winograd_conv2d(WinogradVariant::F2_1x7, &t[0], &t[1], (0, 3), None),
            },
            Case {
                name: "mini_cnn",
                inputs: vec![
                    vec![1, 16, 16, 4],
                    vec![8, 3, 3, 4],
                    vec![8, 3, 3, 8],
                    vec![8, 10],
                ],
                rust: |t| {
                    let mut h = direct_conv2d(&t[0], &t[1], (1, 1), (1, 1))?;
                    ops::relu_inplace(&mut h);
                    let mut h = direct_conv2d(&h, &t[2], (1, 1), (1, 1))?;
                    ops::relu_inplace(&mut h);
                    let gap = ops::global_avg_pool(&h)?;
                    let flat = gap.reshape(&[1, 8])?;
                    ops::fully_connected(&flat, &t[3], &[0.0; 10], false)
                },
            },
        ]
    }

    /// Run every artifact found in `dir` against its Rust twin.
    ///
    /// Returns `Err` on the first numeric mismatch (rel err > 1e-3) or load
    /// failure; missing artifacts are skipped with a warning so the test
    /// suite can run before `make artifacts`.
    pub fn verify_all(dir: &Path, verbose: bool) -> Result<()> {
        let rt = PjrtRuntime::cpu()?;
        if verbose {
            println!("PJRT: {}", rt.describe());
        }
        let mut ran = 0usize;
        for case in cases() {
            let path = dir.join(format!("{}.hlo.txt", case.name));
            if !path.exists() {
                eprintln!(
                    "skipping {} (artifact missing — run `make artifacts`)",
                    case.name
                );
                continue;
            }
            let exe = rt.load_hlo_text(&path)?;
            // Deterministic inputs, scaled down so deep products stay tame.
            let tensors: Vec<Tensor> = case
                .inputs
                .iter()
                .enumerate()
                .map(|(i, shape)| {
                    let mut t = Tensor::randn(shape, 0xC0FFEE + i as u64);
                    for v in t.data_mut() {
                        *v *= 0.25;
                    }
                    t
                })
                .collect();
            let refs: Vec<&Tensor> = tensors.iter().collect();
            let xla_out = exe.run(&refs)?;
            let rust_out = (case.rust)(&tensors)?;
            if xla_out.len() != 1 {
                return Err(Error::Runtime(format!(
                    "{}: expected 1 output, got {}",
                    case.name,
                    xla_out.len()
                )));
            }
            let err = rel_error(rust_out.data(), xla_out[0].data());
            if verbose {
                println!(
                    "{:<16} shapes {:?} -> {:?}  rel-err {err:.2e}",
                    case.name,
                    case.inputs,
                    rust_out.shape()
                );
            }
            if rust_out.shape() != xla_out[0].shape() {
                return Err(Error::Runtime(format!(
                    "{}: shape mismatch rust {:?} vs xla {:?}",
                    case.name,
                    rust_out.shape(),
                    xla_out[0].shape()
                )));
            }
            if err > 1e-3 {
                return Err(Error::Runtime(format!(
                    "{}: rel error {err} exceeds 1e-3",
                    case.name
                )));
            }
            ran += 1;
        }
        if verbose {
            println!("verified {ran} artifact(s) against the Rust engine");
        }
        Ok(())
    }
}
