//! A portable 4-lane `f32` vector mirroring the ARMv8-A NEON operations the
//! paper's transform listings use (`vaddq_f32`, `vsubq_f32`, `vmulq_f32`,
//! `vfmaq_f32`, …).
//!
//! The paper hand-codes its input/output transforms over 128-bit NEON
//! registers, holding **four channels of one pixel** under NHWC (§2.1). We
//! keep exactly that granularity: [`F32x4`] is a `#[repr(align(16))]` 4-lane
//! struct whose operations compile to SSE/AVX vector instructions on x86 and
//! would map 1:1 to NEON on aarch64 — LLVM reliably autovectorizes this
//! shape. All transform kernels in [`crate::winograd`] are written against
//! this type so they read like the paper's Listing 2.

use std::ops::{Add, AddAssign, Mul, Neg, Sub};

/// Four `f32` lanes, 16-byte aligned — the NEON `float32x4_t` analog.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
#[repr(C, align(16))]
pub struct F32x4(pub [f32; 4]);

impl F32x4 {
    /// All lanes zero.
    #[inline(always)]
    pub const fn zero() -> Self {
        F32x4([0.0; 4])
    }

    /// All lanes set to `v` (NEON `vdupq_n_f32`).
    #[inline(always)]
    pub const fn splat(v: f32) -> Self {
        F32x4([v; 4])
    }

    /// Load four consecutive values (NEON `vld1q_f32`).
    ///
    /// Panics in debug builds if the slice is short.
    #[inline(always)]
    pub fn load(src: &[f32]) -> Self {
        debug_assert!(src.len() >= 4);
        F32x4([src[0], src[1], src[2], src[3]])
    }

    /// Load up to four values, zero-filling the tail (for channel remainders).
    #[inline(always)]
    pub fn load_partial(src: &[f32]) -> Self {
        let mut out = [0.0f32; 4];
        for (o, s) in out.iter_mut().zip(src.iter()) {
            *o = *s;
        }
        F32x4(out)
    }

    /// Store four values (NEON `vst1q_f32` / A64 `STR q`).
    #[inline(always)]
    pub fn store(self, dst: &mut [f32]) {
        debug_assert!(dst.len() >= 4);
        dst[..4].copy_from_slice(&self.0);
    }

    /// Store the first `n ≤ 4` lanes.
    #[inline(always)]
    pub fn store_partial(self, dst: &mut [f32], n: usize) {
        debug_assert!(n <= 4 && dst.len() >= n);
        dst[..n].copy_from_slice(&self.0[..n]);
    }

    /// Fused multiply–add: `self + a * b` (NEON `vfmaq_f32`).
    #[inline(always)]
    pub fn fma(self, a: F32x4, b: F32x4) -> F32x4 {
        F32x4([
            a.0[0].mul_add(b.0[0], self.0[0]),
            a.0[1].mul_add(b.0[1], self.0[1]),
            a.0[2].mul_add(b.0[2], self.0[2]),
            a.0[3].mul_add(b.0[3], self.0[3]),
        ])
    }

    /// `self + a * scalar` (NEON `vfmaq_n_f32`).
    #[inline(always)]
    pub fn fma_scalar(self, a: F32x4, s: f32) -> F32x4 {
        self.fma(a, F32x4::splat(s))
    }

    /// Multiply by a scalar (NEON `vmulq_n_f32`).
    #[inline(always)]
    pub fn mul_scalar(self, s: f32) -> F32x4 {
        self * F32x4::splat(s)
    }

    /// Lane-wise max (NEON `vmaxq_f32`) — used by ReLU and max-pool.
    #[inline(always)]
    pub fn max(self, o: F32x4) -> F32x4 {
        F32x4([
            self.0[0].max(o.0[0]),
            self.0[1].max(o.0[1]),
            self.0[2].max(o.0[2]),
            self.0[3].max(o.0[3]),
        ])
    }

    /// Horizontal sum of the four lanes (NEON `vaddvq_f32`).
    #[inline(always)]
    pub fn horizontal_sum(self) -> f32 {
        (self.0[0] + self.0[1]) + (self.0[2] + self.0[3])
    }

    /// 4×4 in-register transpose (the NEON `vtrn`/`vzip` idiom the paper uses
    /// to apply a row transform twice for `XᵀxX`).
    #[inline(always)]
    pub fn transpose4(rows: [F32x4; 4]) -> [F32x4; 4] {
        let [a, b, c, d] = rows;
        [
            F32x4([a.0[0], b.0[0], c.0[0], d.0[0]]),
            F32x4([a.0[1], b.0[1], c.0[1], d.0[1]]),
            F32x4([a.0[2], b.0[2], c.0[2], d.0[2]]),
            F32x4([a.0[3], b.0[3], c.0[3], d.0[3]]),
        ]
    }
}

impl Add for F32x4 {
    type Output = F32x4;
    #[inline(always)]
    fn add(self, o: F32x4) -> F32x4 {
        F32x4([
            self.0[0] + o.0[0],
            self.0[1] + o.0[1],
            self.0[2] + o.0[2],
            self.0[3] + o.0[3],
        ])
    }
}

impl Sub for F32x4 {
    type Output = F32x4;
    #[inline(always)]
    fn sub(self, o: F32x4) -> F32x4 {
        F32x4([
            self.0[0] - o.0[0],
            self.0[1] - o.0[1],
            self.0[2] - o.0[2],
            self.0[3] - o.0[3],
        ])
    }
}

impl Mul for F32x4 {
    type Output = F32x4;
    #[inline(always)]
    fn mul(self, o: F32x4) -> F32x4 {
        F32x4([
            self.0[0] * o.0[0],
            self.0[1] * o.0[1],
            self.0[2] * o.0[2],
            self.0[3] * o.0[3],
        ])
    }
}

impl AddAssign for F32x4 {
    #[inline(always)]
    fn add_assign(&mut self, o: F32x4) {
        *self = *self + o;
    }
}

impl Neg for F32x4 {
    type Output = F32x4;
    #[inline(always)]
    fn neg(self) -> F32x4 {
        F32x4([-self.0[0], -self.0[1], -self.0[2], -self.0[3]])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_lanewise() {
        let a = F32x4([1.0, 2.0, 3.0, 4.0]);
        let b = F32x4([10.0, 20.0, 30.0, 40.0]);
        assert_eq!((a + b).0, [11.0, 22.0, 33.0, 44.0]);
        assert_eq!((b - a).0, [9.0, 18.0, 27.0, 36.0]);
        assert_eq!((a * b).0, [10.0, 40.0, 90.0, 160.0]);
        assert_eq!((-a).0, [-1.0, -2.0, -3.0, -4.0]);
    }

    #[test]
    fn fma_matches_scalar() {
        let acc = F32x4::splat(1.0);
        let a = F32x4([1.0, 2.0, 3.0, 4.0]);
        let b = F32x4([5.0, 6.0, 7.0, 8.0]);
        assert_eq!(acc.fma(a, b).0, [6.0, 13.0, 22.0, 33.0]);
        assert_eq!(acc.fma_scalar(a, 2.0).0, [3.0, 5.0, 7.0, 9.0]);
    }

    #[test]
    fn load_store_roundtrip() {
        let src = [1.0, 2.0, 3.0, 4.0, 5.0];
        let v = F32x4::load(&src);
        let mut dst = [0.0; 4];
        v.store(&mut dst);
        assert_eq!(dst, [1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn partial_load_store() {
        let v = F32x4::load_partial(&[7.0, 8.0]);
        assert_eq!(v.0, [7.0, 8.0, 0.0, 0.0]);
        let mut dst = [9.0; 4];
        v.store_partial(&mut dst, 2);
        assert_eq!(dst, [7.0, 8.0, 9.0, 9.0]);
    }

    #[test]
    fn transpose_is_involution() {
        let rows = [
            F32x4([0.0, 1.0, 2.0, 3.0]),
            F32x4([4.0, 5.0, 6.0, 7.0]),
            F32x4([8.0, 9.0, 10.0, 11.0]),
            F32x4([12.0, 13.0, 14.0, 15.0]),
        ];
        let t = F32x4::transpose4(rows);
        assert_eq!(t[0].0, [0.0, 4.0, 8.0, 12.0]);
        assert_eq!(F32x4::transpose4(t), rows);
    }

    #[test]
    fn horizontal_sum_and_max() {
        let a = F32x4([1.0, -2.0, 3.5, 0.5]);
        assert_eq!(a.horizontal_sum(), 3.0);
        assert_eq!(a.max(F32x4::zero()).0, [1.0, 0.0, 3.5, 0.5]);
    }
}
