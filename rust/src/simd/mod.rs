//! A 4-lane `f32` vector mirroring the ARMv8-A NEON operations the paper's
//! transform listings use (`vaddq_f32`, `vsubq_f32`, `vmulq_f32`,
//! `vfmaq_f32`, …).
//!
//! The paper hand-codes its input/output transforms over 128-bit NEON
//! registers, holding **four channels of one pixel** under NHWC (§2.1). We
//! keep exactly that granularity with two interchangeable backends behind
//! one [`F32x4`] type:
//!
//! * [`neon`] (`target_arch = "aarch64"`) — real NEON intrinsics
//!   (`vld1q_f32` loads, `vfmaq_f32` FMAs, `vtrn1q/vtrn2q` transposes), the
//!   instructions the paper's Listing 2 is written in.
//! * [`portable`] (every other target) — a `#[repr(align(16))]` 4-lane
//!   array struct whose operations LLVM compiles to SSE/AVX vector
//!   instructions on x86.
//!
//! Both expose the identical API (the portable constructors are
//! additionally `const`), and the parity suite below pins every operation
//! of whichever backend is active to plain scalar `f32` semantics,
//! lane for lane — so transform kernels written against [`F32x4`] read like
//! the paper's Listing 2 and compute identically on every architecture.

#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "aarch64")]
pub use neon::{qmacc_4x16, F32x4};

#[cfg(not(target_arch = "aarch64"))]
mod portable;
#[cfg(not(target_arch = "aarch64"))]
pub use portable::{qmacc_4x16, F32x4};

#[cfg(test)]
mod tests {
    //! Lane-for-lane parity of the active backend against scalar `f32`
    //! arithmetic. On `aarch64` this validates the NEON intrinsic backend;
    //! elsewhere the portable one — same expectations either way.

    use super::*;

    const A: [f32; 4] = [1.0, 2.0, 3.0, 4.0];
    const B: [f32; 4] = [10.0, 20.0, 30.0, 40.0];

    #[test]
    fn construction_roundtrip() {
        assert_eq!(F32x4::zero().to_array(), [0.0; 4]);
        assert_eq!(F32x4::splat(2.5).to_array(), [2.5; 4]);
        let v = F32x4::from_array(A);
        assert_eq!(v.to_array(), A);
        for (i, &want) in A.iter().enumerate() {
            assert_eq!(v.lane(i), want);
        }
        assert_eq!(F32x4::default(), F32x4::zero());
    }

    #[test]
    fn arithmetic_lanewise() {
        let a = F32x4::from_array(A);
        let b = F32x4::from_array(B);
        for i in 0..4 {
            assert_eq!((a + b).lane(i), A[i] + B[i]);
            assert_eq!((b - a).lane(i), B[i] - A[i]);
            assert_eq!((a * b).lane(i), A[i] * B[i]);
            assert_eq!((-a).lane(i), -A[i]);
        }
        let mut acc = a;
        acc += b;
        assert_eq!(acc, a + b);
    }

    #[test]
    fn fma_matches_scalar() {
        let acc = F32x4::splat(1.0);
        let a = F32x4::from_array(A);
        let b = F32x4::from_array([5.0, 6.0, 7.0, 8.0]);
        let fused = acc.fma(a, b);
        for i in 0..4 {
            assert_eq!(fused.lane(i), A[i].mul_add(b.lane(i), 1.0));
        }
        let scaled = acc.fma_scalar(a, 2.0);
        for i in 0..4 {
            assert_eq!(scaled.lane(i), A[i].mul_add(2.0, 1.0));
        }
        let m = a.mul_scalar(3.0);
        for i in 0..4 {
            assert_eq!(m.lane(i), A[i] * 3.0);
        }
    }

    #[test]
    fn load_store_roundtrip() {
        let src = [1.0, 2.0, 3.0, 4.0, 5.0];
        let v = F32x4::load(&src);
        let mut dst = [0.0; 4];
        v.store(&mut dst);
        assert_eq!(dst, [1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn partial_load_store() {
        let v = F32x4::load_partial(&[7.0, 8.0]);
        assert_eq!(v.to_array(), [7.0, 8.0, 0.0, 0.0]);
        let mut dst = [9.0; 4];
        v.store_partial(&mut dst, 2);
        assert_eq!(dst, [7.0, 8.0, 9.0, 9.0]);
    }

    #[test]
    fn transpose_is_involution() {
        let rows = [
            F32x4::from_array([0.0, 1.0, 2.0, 3.0]),
            F32x4::from_array([4.0, 5.0, 6.0, 7.0]),
            F32x4::from_array([8.0, 9.0, 10.0, 11.0]),
            F32x4::from_array([12.0, 13.0, 14.0, 15.0]),
        ];
        let t = F32x4::transpose4(rows);
        // Column i of the input becomes row i.
        for (i, trow) in t.iter().enumerate() {
            for (j, row) in rows.iter().enumerate() {
                assert_eq!(trow.lane(j), row.lane(i), "t[{i}][{j}]");
            }
        }
        assert_eq!(F32x4::transpose4(t), rows);
    }

    #[test]
    fn horizontal_sum_and_max() {
        let a = F32x4::from_array([1.0, -2.0, 3.5, 0.5]);
        assert_eq!(a.horizontal_sum(), 3.0);
        let m = a.max(F32x4::zero());
        assert_eq!(m.to_array(), [1.0, 0.0, 3.5, 0.5]);
    }

    #[test]
    fn qmacc_matches_scalar_i32() {
        // Whichever backend is active must accumulate u8×i8 into i32
        // exactly like the scalar triple loop — extremes included.
        let a: [u8; 4] = [0, 1, 128, 255];
        let mut b = [0i8; 16];
        for (j, v) in b.iter_mut().enumerate() {
            *v = ((j as i32 * 17) - 127).clamp(-127, 127) as i8;
        }
        b[15] = -127;
        b[14] = 127;
        let mut acc = [[0i32; 16]; 4];
        acc[0][0] = 5;
        acc[3][15] = -9;
        let mut want = acc;
        for r in 0..4 {
            for j in 0..16 {
                want[r][j] += a[r] as i32 * b[j] as i32;
            }
        }
        qmacc_4x16(&mut acc, &a, &b);
        assert_eq!(acc, want);
        // A second step keeps accumulating (no overwrite semantics).
        qmacc_4x16(&mut acc, &a, &b);
        for r in 0..4 {
            for j in 0..16 {
                want[r][j] += a[r] as i32 * b[j] as i32;
            }
        }
        assert_eq!(acc, want);
    }

    #[test]
    fn min_matches_scalar() {
        let a = F32x4::from_array([1.0, -2.0, 7.5, 6.0]);
        let m = a.min(F32x4::splat(6.0));
        for i in 0..4 {
            assert_eq!(m.lane(i), a.lane(i).min(6.0));
        }
        // The ReLU6 idiom: clamp to [0, 6].
        let r6 = a.max(F32x4::zero()).min(F32x4::splat(6.0));
        assert_eq!(r6.to_array(), [1.0, 0.0, 6.0, 6.0]);
    }
}
