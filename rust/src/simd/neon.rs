//! The real `aarch64` NEON backend: every [`F32x4`] operation maps 1:1 to
//! the intrinsic named in the portable backend's doc comments
//! (`vld1q_f32`, `vfmaq_f32`, `vtrn1q/vtrn2q`, …) — the exact instructions
//! the paper's hand-written transform listings (Listing 2) are built from.
//!
//! NEON is a baseline feature of AArch64 (`target_feature = "neon"` is
//! always enabled for `target_arch = "aarch64"`), so the intrinsic calls
//! below are sound; the `unsafe` blocks discharge the `unsafe fn`
//! declarations in `core::arch::aarch64`.
//!
//! The portable array backend is kept for every other target, and the
//! lane-for-lane parity suite in [`super`] pins both backends to the same
//! scalar semantics.

use core::arch::aarch64::{
    float32x4_t, vaddq_f32, vaddvq_f32, vdup_n_s16, vdupq_n_f32, vfmaq_f32, vfmaq_n_f32,
    vget_high_s16, vget_high_s8, vget_low_s16, vget_low_s8, vld1q_f32, vld1q_s32, vld1q_s8,
    vmaxq_f32, vminq_f32, vmlal_s16, vmovl_s8, vmulq_f32, vmulq_n_f32, vnegq_f32,
    vreinterpretq_f32_f64, vreinterpretq_f64_f32,
    vst1q_f32, vst1q_s32, vsubq_f32, vtrn1q_f32, vtrn1q_f64, vtrn2q_f32, vtrn2q_f64,
};
use std::ops::{Add, AddAssign, Mul, Neg, Sub};

/// Four `f32` lanes in a NEON `float32x4_t` register.
#[derive(Clone, Copy)]
#[repr(transparent)]
pub struct F32x4(float32x4_t);

impl F32x4 {
    /// All lanes zero.
    #[inline(always)]
    pub fn zero() -> Self {
        Self::splat(0.0)
    }

    /// All lanes set to `v` (`vdupq_n_f32`).
    #[inline(always)]
    pub fn splat(v: f32) -> Self {
        // SAFETY: NEON is baseline on aarch64 (this file only compiles
        // there); the intrinsic is register-only.
        F32x4(unsafe { vdupq_n_f32(v) })
    }

    /// Build from four lane values.
    #[inline(always)]
    pub fn from_array(a: [f32; 4]) -> Self {
        // SAFETY: `a` is a live `[f32; 4]`, so its pointer is valid for
        // reading exactly the 16 bytes `vld1q_f32` loads.
        F32x4(unsafe { vld1q_f32(a.as_ptr()) })
    }

    /// The four lane values as an array.
    #[inline(always)]
    pub fn to_array(self) -> [f32; 4] {
        let mut out = [0.0f32; 4];
        // SAFETY: `out` is a live `[f32; 4]`, valid for the 16-byte write.
        unsafe { vst1q_f32(out.as_mut_ptr(), self.0) };
        out
    }

    /// One lane value (`i < 4`).
    #[inline(always)]
    pub fn lane(self, i: usize) -> f32 {
        self.to_array()[i]
    }

    /// Load four consecutive values (`vld1q_f32`).
    ///
    /// Panics in debug builds if the slice is short.
    #[inline(always)]
    pub fn load(src: &[f32]) -> Self {
        debug_assert!(src.len() >= 4);
        // SAFETY: callers pass `src.len() >= 4` (debug-asserted above), so
        // the pointer is valid for the 16-byte read.
        F32x4(unsafe { vld1q_f32(src.as_ptr()) })
    }

    /// Load up to four values, zero-filling the tail (for channel remainders).
    #[inline(always)]
    pub fn load_partial(src: &[f32]) -> Self {
        let mut out = [0.0f32; 4];
        for (o, s) in out.iter_mut().zip(src.iter()) {
            *o = *s;
        }
        Self::from_array(out)
    }

    /// Store four values (`vst1q_f32`).
    #[inline(always)]
    pub fn store(self, dst: &mut [f32]) {
        debug_assert!(dst.len() >= 4);
        // SAFETY: callers pass `dst.len() >= 4` (debug-asserted above), so
        // the pointer is valid for the 16-byte write.
        unsafe { vst1q_f32(dst.as_mut_ptr(), self.0) };
    }

    /// Store the first `n ≤ 4` lanes.
    #[inline(always)]
    pub fn store_partial(self, dst: &mut [f32], n: usize) {
        debug_assert!(n <= 4 && dst.len() >= n);
        let a = self.to_array();
        dst[..n].copy_from_slice(&a[..n]);
    }

    /// Fused multiply–add: `self + a * b` (`vfmaq_f32`).
    #[inline(always)]
    pub fn fma(self, a: F32x4, b: F32x4) -> F32x4 {
        // SAFETY: NEON is baseline on aarch64; register-only intrinsic.
        F32x4(unsafe { vfmaq_f32(self.0, a.0, b.0) })
    }

    /// `self + a * scalar` (`vfmaq_n_f32`).
    #[inline(always)]
    pub fn fma_scalar(self, a: F32x4, s: f32) -> F32x4 {
        // SAFETY: NEON is baseline on aarch64; register-only intrinsic.
        F32x4(unsafe { vfmaq_n_f32(self.0, a.0, s) })
    }

    /// Multiply by a scalar (`vmulq_n_f32`).
    #[inline(always)]
    pub fn mul_scalar(self, s: f32) -> F32x4 {
        // SAFETY: NEON is baseline on aarch64; register-only intrinsic.
        F32x4(unsafe { vmulq_n_f32(self.0, s) })
    }

    /// Lane-wise max (`vmaxq_f32`) — used by ReLU and max-pool.
    #[inline(always)]
    pub fn max(self, o: F32x4) -> F32x4 {
        // SAFETY: NEON is baseline on aarch64; register-only intrinsic.
        F32x4(unsafe { vmaxq_f32(self.0, o.0) })
    }

    /// Lane-wise min (`vminq_f32`) — the upper clamp of ReLU6.
    #[inline(always)]
    pub fn min(self, o: F32x4) -> F32x4 {
        // SAFETY: NEON is baseline on aarch64; register-only intrinsic.
        F32x4(unsafe { vminq_f32(self.0, o.0) })
    }

    /// Horizontal sum of the four lanes (`vaddvq_f32`).
    #[inline(always)]
    pub fn horizontal_sum(self) -> f32 {
        // SAFETY: NEON is baseline on aarch64; register-only intrinsic.
        unsafe { vaddvq_f32(self.0) }
    }

    /// 4×4 in-register transpose: the `vtrn1q/vtrn2q` pair on `f32` lanes
    /// followed by the same pair on the reinterpreted `f64` halves — the
    /// classic AArch64 idiom the paper uses to apply a row transform twice
    /// for `XᵀxX`.
    #[inline(always)]
    pub fn transpose4(rows: [F32x4; 4]) -> [F32x4; 4] {
        let [a, b, c, d] = rows;
        // SAFETY: NEON is baseline on aarch64; the trn/reinterpret chain is
        // register-only, and f32x4 <-> f64x2 reinterpretation is a bitcast
        // between two 128-bit vector types.
        unsafe {
            // [a0 b0 a2 b2], [a1 b1 a3 b3], [c0 d0 c2 d2], [c1 d1 c3 d3]
            let ab_lo = vtrn1q_f32(a.0, b.0);
            let ab_hi = vtrn2q_f32(a.0, b.0);
            let cd_lo = vtrn1q_f32(c.0, d.0);
            let cd_hi = vtrn2q_f32(c.0, d.0);
            // Swap the 64-bit halves to interleave the ab/cd pairs.
            let r0 = vreinterpretq_f32_f64(vtrn1q_f64(
                vreinterpretq_f64_f32(ab_lo),
                vreinterpretq_f64_f32(cd_lo),
            ));
            let r1 = vreinterpretq_f32_f64(vtrn1q_f64(
                vreinterpretq_f64_f32(ab_hi),
                vreinterpretq_f64_f32(cd_hi),
            ));
            let r2 = vreinterpretq_f32_f64(vtrn2q_f64(
                vreinterpretq_f64_f32(ab_lo),
                vreinterpretq_f64_f32(cd_lo),
            ));
            let r3 = vreinterpretq_f32_f64(vtrn2q_f64(
                vreinterpretq_f64_f32(ab_hi),
                vreinterpretq_f64_f32(cd_hi),
            ));
            [F32x4(r0), F32x4(r1), F32x4(r2), F32x4(r3)]
        }
    }
}

/// One k-step of the int8 micro-kernel: `acc[r][j] += a[r] * b[j]` with
/// u8 activations, i8 weights and i32 accumulators, via the widening
/// `smlal`-class NEON sequence: `vmovl_s8` widens the 16 weight bytes to
/// two `int16x8_t`, each activation lane is `vdup_n_s16`-broadcast, and
/// four `vmlal_s16` per row multiply-accumulate i16×i16 into the i32
/// accumulator registers — twice the MACs per op of the f32 FMA path.
///
/// Activations fit i16 losslessly (u8 ≤ 255) and products stay ≤ 32385, so
/// the widening multiply is exact.
#[inline(always)]
pub fn qmacc_4x16(acc: &mut [[i32; 16]; 4], a: &[u8; 4], b: &[i8; 16]) {
    // SAFETY: NEON is baseline on aarch64; every pointer load/store below
    // reads or writes exactly the fixed-size arrays passed in (`b` is 16
    // bytes, each `acc` row is 16 i32s accessed as four aligned-by-type
    // quadwords at offsets 0/4/8/12).
    unsafe {
        let bq = vld1q_s8(b.as_ptr());
        let b_lo = vmovl_s8(vget_low_s8(bq)); // weight lanes 0..8 as i16
        let b_hi = vmovl_s8(vget_high_s8(bq)); // weight lanes 8..16 as i16
        for (row, &av) in acc.iter_mut().zip(a.iter()) {
            let a16 = vdup_n_s16(av as i16);
            let p = row.as_mut_ptr();
            let mut c0 = vld1q_s32(p);
            let mut c1 = vld1q_s32(p.add(4));
            let mut c2 = vld1q_s32(p.add(8));
            let mut c3 = vld1q_s32(p.add(12));
            c0 = vmlal_s16(c0, vget_low_s16(b_lo), a16);
            c1 = vmlal_s16(c1, vget_high_s16(b_lo), a16);
            c2 = vmlal_s16(c2, vget_low_s16(b_hi), a16);
            c3 = vmlal_s16(c3, vget_high_s16(b_hi), a16);
            vst1q_s32(p, c0);
            vst1q_s32(p.add(4), c1);
            vst1q_s32(p.add(8), c2);
            vst1q_s32(p.add(12), c3);
        }
    }
}

impl std::fmt::Debug for F32x4 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "F32x4({:?})", self.to_array())
    }
}

impl PartialEq for F32x4 {
    fn eq(&self, o: &F32x4) -> bool {
        self.to_array() == o.to_array()
    }
}

impl Default for F32x4 {
    fn default() -> Self {
        Self::zero()
    }
}

impl Add for F32x4 {
    type Output = F32x4;
    #[inline(always)]
    fn add(self, o: F32x4) -> F32x4 {
        // SAFETY: NEON is baseline on aarch64; register-only intrinsic.
        F32x4(unsafe { vaddq_f32(self.0, o.0) })
    }
}

impl Sub for F32x4 {
    type Output = F32x4;
    #[inline(always)]
    fn sub(self, o: F32x4) -> F32x4 {
        // SAFETY: NEON is baseline on aarch64; register-only intrinsic.
        F32x4(unsafe { vsubq_f32(self.0, o.0) })
    }
}

impl Mul for F32x4 {
    type Output = F32x4;
    #[inline(always)]
    fn mul(self, o: F32x4) -> F32x4 {
        // SAFETY: NEON is baseline on aarch64; register-only intrinsic.
        F32x4(unsafe { vmulq_f32(self.0, o.0) })
    }
}

impl AddAssign for F32x4 {
    #[inline(always)]
    fn add_assign(&mut self, o: F32x4) {
        *self = *self + o;
    }
}

impl Neg for F32x4 {
    type Output = F32x4;
    #[inline(always)]
    fn neg(self) -> F32x4 {
        // SAFETY: NEON is baseline on aarch64; register-only intrinsic.
        F32x4(unsafe { vnegq_f32(self.0) })
    }
}
