//! The portable 4-lane backend: a `#[repr(align(16))]` array struct whose
//! operations LLVM reliably autovectorizes to SSE/AVX on x86 (and to NEON on
//! any other 128-bit SIMD target this crate is built for without the
//! dedicated [`super::neon`] backend).

use std::ops::{Add, AddAssign, Mul, Neg, Sub};

/// Four `f32` lanes, 16-byte aligned — the NEON `float32x4_t` analog.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
#[repr(C, align(16))]
pub struct F32x4([f32; 4]);

impl F32x4 {
    /// All lanes zero.
    #[inline(always)]
    pub const fn zero() -> Self {
        F32x4([0.0; 4])
    }

    /// All lanes set to `v` (NEON `vdupq_n_f32`).
    #[inline(always)]
    pub const fn splat(v: f32) -> Self {
        F32x4([v; 4])
    }

    /// Build from four lane values.
    #[inline(always)]
    pub const fn from_array(a: [f32; 4]) -> Self {
        F32x4(a)
    }

    /// The four lane values as an array.
    #[inline(always)]
    pub const fn to_array(self) -> [f32; 4] {
        self.0
    }

    /// One lane value (`i < 4`).
    #[inline(always)]
    pub fn lane(self, i: usize) -> f32 {
        self.0[i]
    }

    /// Load four consecutive values (NEON `vld1q_f32`).
    ///
    /// Panics in debug builds if the slice is short.
    #[inline(always)]
    pub fn load(src: &[f32]) -> Self {
        debug_assert!(src.len() >= 4);
        F32x4([src[0], src[1], src[2], src[3]])
    }

    /// Load up to four values, zero-filling the tail (for channel remainders).
    #[inline(always)]
    pub fn load_partial(src: &[f32]) -> Self {
        let mut out = [0.0f32; 4];
        for (o, s) in out.iter_mut().zip(src.iter()) {
            *o = *s;
        }
        F32x4(out)
    }

    /// Store four values (NEON `vst1q_f32` / A64 `STR q`).
    #[inline(always)]
    pub fn store(self, dst: &mut [f32]) {
        debug_assert!(dst.len() >= 4);
        dst[..4].copy_from_slice(&self.0);
    }

    /// Store the first `n ≤ 4` lanes.
    #[inline(always)]
    pub fn store_partial(self, dst: &mut [f32], n: usize) {
        debug_assert!(n <= 4 && dst.len() >= n);
        dst[..n].copy_from_slice(&self.0[..n]);
    }

    /// Fused multiply–add: `self + a * b` (NEON `vfmaq_f32`).
    #[inline(always)]
    pub fn fma(self, a: F32x4, b: F32x4) -> F32x4 {
        F32x4([
            a.0[0].mul_add(b.0[0], self.0[0]),
            a.0[1].mul_add(b.0[1], self.0[1]),
            a.0[2].mul_add(b.0[2], self.0[2]),
            a.0[3].mul_add(b.0[3], self.0[3]),
        ])
    }

    /// `self + a * scalar` (NEON `vfmaq_n_f32`).
    #[inline(always)]
    pub fn fma_scalar(self, a: F32x4, s: f32) -> F32x4 {
        self.fma(a, F32x4::splat(s))
    }

    /// Multiply by a scalar (NEON `vmulq_n_f32`).
    #[inline(always)]
    pub fn mul_scalar(self, s: f32) -> F32x4 {
        self * F32x4::splat(s)
    }

    /// Lane-wise max (NEON `vmaxq_f32`) — used by ReLU and max-pool.
    #[inline(always)]
    pub fn max(self, o: F32x4) -> F32x4 {
        F32x4([
            self.0[0].max(o.0[0]),
            self.0[1].max(o.0[1]),
            self.0[2].max(o.0[2]),
            self.0[3].max(o.0[3]),
        ])
    }

    /// Lane-wise min (NEON `vminq_f32`) — the upper clamp of ReLU6.
    #[inline(always)]
    pub fn min(self, o: F32x4) -> F32x4 {
        F32x4([
            self.0[0].min(o.0[0]),
            self.0[1].min(o.0[1]),
            self.0[2].min(o.0[2]),
            self.0[3].min(o.0[3]),
        ])
    }

    /// Horizontal sum of the four lanes (NEON `vaddvq_f32`).
    #[inline(always)]
    pub fn horizontal_sum(self) -> f32 {
        (self.0[0] + self.0[1]) + (self.0[2] + self.0[3])
    }

    /// 4×4 in-register transpose (the NEON `vtrn`/`vzip` idiom the paper uses
    /// to apply a row transform twice for `XᵀxX`).
    #[inline(always)]
    pub fn transpose4(rows: [F32x4; 4]) -> [F32x4; 4] {
        let [a, b, c, d] = rows;
        [
            F32x4([a.0[0], b.0[0], c.0[0], d.0[0]]),
            F32x4([a.0[1], b.0[1], c.0[1], d.0[1]]),
            F32x4([a.0[2], b.0[2], c.0[2], d.0[2]]),
            F32x4([a.0[3], b.0[3], c.0[3], d.0[3]]),
        ]
    }
}

/// One k-step of the int8 micro-kernel: `acc[r][j] += a[r] * b[j]` with
/// u8 activations, i8 weights and i32 accumulators — the portable twin of
/// the NEON `smlal`-class widening multiply-accumulate.
///
/// The products are formed in `i16` (`255 * 127 = 32385` fits with room to
/// spare), which LLVM autovectorizes to `pmullw`/`pmaddwd`-class SSE2
/// instructions — baseline x86-64 has no fast `i32` vector multiply
/// (`pmulld` is SSE4.1), so widening through `i16` is what keeps this
/// kernel competitive with the f32 FMA path on old cores too.
#[inline(always)]
pub fn qmacc_4x16(acc: &mut [[i32; 16]; 4], a: &[u8; 4], b: &[i8; 16]) {
    for (row, &av) in acc.iter_mut().zip(a.iter()) {
        let av = av as i16;
        for (dst, &bv) in row.iter_mut().zip(b.iter()) {
            *dst += (av * bv as i16) as i32;
        }
    }
}

impl Add for F32x4 {
    type Output = F32x4;
    #[inline(always)]
    fn add(self, o: F32x4) -> F32x4 {
        F32x4([
            self.0[0] + o.0[0],
            self.0[1] + o.0[1],
            self.0[2] + o.0[2],
            self.0[3] + o.0[3],
        ])
    }
}

impl Sub for F32x4 {
    type Output = F32x4;
    #[inline(always)]
    fn sub(self, o: F32x4) -> F32x4 {
        F32x4([
            self.0[0] - o.0[0],
            self.0[1] - o.0[1],
            self.0[2] - o.0[2],
            self.0[3] - o.0[3],
        ])
    }
}

impl Mul for F32x4 {
    type Output = F32x4;
    #[inline(always)]
    fn mul(self, o: F32x4) -> F32x4 {
        F32x4([
            self.0[0] * o.0[0],
            self.0[1] * o.0[1],
            self.0[2] * o.0[2],
            self.0[3] * o.0[3],
        ])
    }
}

impl AddAssign for F32x4 {
    #[inline(always)]
    fn add_assign(&mut self, o: F32x4) {
        *self = *self + o;
    }
}

impl Neg for F32x4 {
    type Output = F32x4;
    #[inline(always)]
    fn neg(self) -> F32x4 {
        F32x4([-self.0[0], -self.0[1], -self.0[2], -self.0[3]])
    }
}
