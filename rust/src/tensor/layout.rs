//! NHWC ⇄ NCHW layout conversion (§2.1 of the paper).
//!
//! Under NCHW each channel plane is contiguous; under NHWC all channels of a
//! pixel are contiguous. The paper picks NHWC so a single 128-bit load gives
//! four channels of one pixel, making the transform kernels width-agnostic.
//! Conversion exists for the layout ablation and interop with NCHW frameworks.

use super::Tensor;
use crate::{bail_shape, Result};

/// Memory layout of a rank-4 activation tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Layout {
    /// Batch, Height, Width, Channels — channels innermost (engine default).
    Nhwc,
    /// Batch, Channels, Height, Width — channel planes contiguous.
    Nchw,
}

impl std::fmt::Display for Layout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Layout::Nhwc => write!(f, "NHWC"),
            Layout::Nchw => write!(f, "NCHW"),
        }
    }
}

/// Convert an NHWC `[N, H, W, C]` tensor to NCHW `[N, C, H, W]`.
pub fn nhwc_to_nchw(t: &Tensor) -> Result<Tensor> {
    if t.rank() != 4 {
        bail_shape!("nhwc_to_nchw expects rank-4, got {:?}", t.shape());
    }
    let (n, h, w, c) = (t.shape()[0], t.shape()[1], t.shape()[2], t.shape()[3]);
    let mut out = Tensor::zeros(&[n, c, h, w]);
    let src = t.data();
    let dst = out.data_mut();
    for b in 0..n {
        for y in 0..h {
            for x in 0..w {
                let s = ((b * h + y) * w + x) * c;
                for ch in 0..c {
                    dst[((b * c + ch) * h + y) * w + x] = src[s + ch];
                }
            }
        }
    }
    Ok(out)
}

/// Convert an NCHW `[N, C, H, W]` tensor to NHWC `[N, H, W, C]`.
pub fn nchw_to_nhwc(t: &Tensor) -> Result<Tensor> {
    if t.rank() != 4 {
        bail_shape!("nchw_to_nhwc expects rank-4, got {:?}", t.shape());
    }
    let (n, c, h, w) = (t.shape()[0], t.shape()[1], t.shape()[2], t.shape()[3]);
    let mut out = Tensor::zeros(&[n, h, w, c]);
    let src = t.data();
    let dst = out.data_mut();
    for b in 0..n {
        for ch in 0..c {
            for y in 0..h {
                let s = ((b * c + ch) * h + y) * w;
                for x in 0..w {
                    dst[((b * h + y) * w + x) * c + ch] = src[s + x];
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_preserves_values() {
        let t = Tensor::randn(&[2, 3, 4, 5], 11);
        let nchw = nhwc_to_nchw(&t).unwrap();
        assert_eq!(nchw.shape(), &[2, 5, 3, 4]);
        let back = nchw_to_nhwc(&nchw).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn known_small_case() {
        // NHWC [1,1,2,2]: pixels (c0,c1) = (1,2) then (3,4)
        let t = Tensor::from_vec(&[1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let nchw = nhwc_to_nchw(&t).unwrap();
        // NCHW: plane c0 = [1,3], plane c1 = [2,4]
        assert_eq!(nchw.data(), &[1.0, 3.0, 2.0, 4.0]);
    }

    #[test]
    fn rejects_wrong_rank() {
        let t = Tensor::zeros(&[2, 2]);
        assert!(nhwc_to_nchw(&t).is_err());
        assert!(nchw_to_nhwc(&t).is_err());
    }
}
