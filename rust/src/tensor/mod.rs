//! Dense row-major `f32` tensors plus the NHWC/NCHW layout machinery the
//! paper's §2.1 studies.
//!
//! The engine standardises on **NHWC** activations (channels innermost) —
//! the layout the paper selects so that a 128-bit SIMD load yields four
//! channels of one pixel — and `[M, KH, KW, C]` weights. NCHW support exists
//! for the layout ablation (DESIGN.md E6) and for interop.

mod layout;

pub use layout::{nchw_to_nhwc, nhwc_to_nchw, Layout};

use crate::util::XorShiftRng;
use crate::{bail_shape, Result};

/// A dense row-major tensor of `f32` values.
///
/// Shapes are arbitrary-rank, though the engine mostly uses rank-4
/// `[N, H, W, C]` activations and `[M, KH, KW, C]` weights.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    /// Tensor filled with a constant.
    pub fn full(shape: &[usize], v: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; n],
        }
    }

    /// Tensor with standard-normal entries from a deterministic seed.
    pub fn randn(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        let mut rng = XorShiftRng::new(seed);
        rng.fill_normal(&mut t.data);
        t
    }

    /// Tensor with uniform entries in `[lo, hi)` from a deterministic seed.
    pub fn rand_uniform(shape: &[usize], lo: f32, hi: f32, seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        let mut rng = XorShiftRng::new(seed);
        rng.fill_uniform(&mut t.data, lo, hi);
        t
    }

    /// Wrap an existing buffer. Errors if the element count mismatches.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail_shape!("from_vec: shape {:?} needs {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
        })
    }

    /// Tensor shape.
    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Rank (number of dimensions).
    #[inline]
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Total element count.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor has no elements.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Immutable view of the underlying buffer.
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable view of the underlying buffer.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the underlying buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail_shape!("reshape {:?} -> {:?}: element count mismatch", self.shape, shape);
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data: self.data.clone(),
        })
    }

    // ---- rank-4 NHWC accessors (the engine's canonical activation view) ----

    /// Flat index of `(n, h, w, c)` for an NHWC rank-4 tensor.
    #[inline(always)]
    pub fn idx4(&self, n: usize, h: usize, w: usize, c: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 4);
        ((n * self.shape[1] + h) * self.shape[2] + w) * self.shape[3] + c
    }

    /// Value at `(n, h, w, c)` (NHWC).
    #[inline(always)]
    pub fn at4(&self, n: usize, h: usize, w: usize, c: usize) -> f32 {
        self.data[self.idx4(n, h, w, c)]
    }

    /// Mutable value at `(n, h, w, c)` (NHWC).
    #[inline(always)]
    pub fn at4_mut(&mut self, n: usize, h: usize, w: usize, c: usize) -> &mut f32 {
        let i = self.idx4(n, h, w, c);
        &mut self.data[i]
    }

    /// The contiguous channel slice at pixel `(n, h, w)` (NHWC) — the unit
    /// the paper's SIMD transforms consume four lanes at a time.
    #[inline(always)]
    pub fn pixel(&self, n: usize, h: usize, w: usize) -> &[f32] {
        let c = self.shape[3];
        let base = self.idx4(n, h, w, 0);
        &self.data[base..base + c]
    }

    /// Mutable channel slice at pixel `(n, h, w)` (NHWC).
    #[inline(always)]
    pub fn pixel_mut(&mut self, n: usize, h: usize, w: usize) -> &mut [f32] {
        let c = self.shape[3];
        let base = self.idx4(n, h, w, 0);
        &mut self.data[base..base + c]
    }

    /// Zero-pad a rank-4 NHWC tensor spatially (same N and C).
    ///
    /// Allocates the padded copy. Hot paths stage into workspace-owned
    /// memory instead via [`TensorView::pad_spatial_into`].
    pub fn pad_spatial(&self, pad_top: usize, pad_bottom: usize, pad_left: usize, pad_right: usize) -> Tensor {
        assert_eq!(self.rank(), 4, "pad_spatial expects NHWC rank-4");
        let (n, h, w, c) = (self.shape[0], self.shape[1], self.shape[2], self.shape[3]);
        let (oh, ow) = (h + pad_top + pad_bottom, w + pad_left + pad_right);
        let mut out = Tensor::zeros(&[n, oh, ow, c]);
        self.view().pad_spatial_into(pad_top, pad_bottom, pad_left, pad_right, &mut out.data);
        out
    }

    /// Borrow this tensor as a [`TensorView`] (shape + data, no ownership).
    #[inline]
    pub fn view(&self) -> TensorView<'_> {
        TensorView {
            shape: &self.shape,
            data: &self.data,
        }
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().map(|x| x.abs()).fold(0.0, f32::max)
    }

    /// True when all entries of `self` and `other` are within `tol` of each
    /// other, scaled by the dynamic range of `other`.
    pub fn allclose(&self, other: &Tensor, tol: f32) -> bool {
        self.shape == other.shape && crate::util::rel_error(&self.data, &other.data) <= tol
    }
}

/// A borrowed tensor: an externally owned shape over an externally owned
/// `f32` slice.
///
/// This is what the planned executor hands around — intermediate
/// activations live as offset windows of one arena
/// ([`crate::nn::ActivationPlan`]), and the write-into convolution entry
/// points ([`crate::winograd::WinogradConvolution::run_fused_into`],
/// [`crate::im2row::Im2RowConvolution::run_fused_into`],
/// [`crate::conv::direct::direct_conv2d_into`]) read their input through
/// this view so no owning [`Tensor`] is materialised per layer.
#[derive(Debug, Clone, Copy)]
pub struct TensorView<'a> {
    shape: &'a [usize],
    data: &'a [f32],
}

impl<'a> TensorView<'a> {
    /// View `data` under `shape`. Errors if the element count mismatches.
    pub fn new(shape: &'a [usize], data: &'a [f32]) -> Result<TensorView<'a>> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail_shape!("view: shape {:?} needs {} elems, got {}", shape, n, data.len());
        }
        Ok(TensorView { shape, data })
    }

    /// Tensor shape.
    #[inline]
    pub fn shape(&self) -> &[usize] {
        self.shape
    }

    /// Rank (number of dimensions).
    #[inline]
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Total element count.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the view has no elements.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// The viewed buffer.
    #[inline]
    pub fn data(&self) -> &'a [f32] {
        self.data
    }

    /// Flat index of `(n, h, w, c)` for an NHWC rank-4 view.
    #[inline(always)]
    pub fn idx4(&self, n: usize, h: usize, w: usize, c: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 4);
        ((n * self.shape[1] + h) * self.shape[2] + w) * self.shape[3] + c
    }

    /// Value at `(n, h, w, c)` (NHWC).
    #[inline(always)]
    pub fn at4(&self, n: usize, h: usize, w: usize, c: usize) -> f32 {
        self.data[self.idx4(n, h, w, c)]
    }

    /// The contiguous channel slice at pixel `(n, h, w)` (NHWC).
    #[inline(always)]
    pub fn pixel(&self, n: usize, h: usize, w: usize) -> &'a [f32] {
        let c = self.shape[3];
        let base = self.idx4(n, h, w, 0);
        &self.data[base..base + c]
    }

    /// Copy into an owning [`Tensor`].
    pub fn to_tensor(&self) -> Tensor {
        Tensor {
            shape: self.shape.to_vec(),
            data: self.data.to_vec(),
        }
    }

    /// Zero-pad a rank-4 NHWC view spatially into a caller-provided buffer
    /// of exactly `n·(h+pt+pb)·(w+pl+pr)·c` elements — the staging step the
    /// conv pipelines run against workspace memory instead of a fresh
    /// allocation. `dst` contents are fully overwritten (the border is
    /// zeroed explicitly, so dirty arena memory is fine).
    pub fn pad_spatial_into(
        &self,
        pad_top: usize,
        pad_bottom: usize,
        pad_left: usize,
        pad_right: usize,
        dst: &mut [f32],
    ) {
        assert_eq!(self.rank(), 4, "pad_spatial_into expects NHWC rank-4");
        let (n, h, w, c) = (self.shape[0], self.shape[1], self.shape[2], self.shape[3]);
        let (oh, ow) = (h + pad_top + pad_bottom, w + pad_left + pad_right);
        assert_eq!(dst.len(), n * oh * ow * c, "pad_spatial_into: dst size mismatch");
        let row = ow * c;
        for b in 0..n {
            let img = b * oh * row;
            // Top and bottom border rows.
            dst[img..img + pad_top * row].fill(0.0);
            dst[img + (pad_top + h) * row..img + oh * row].fill(0.0);
            for y in 0..h {
                let d = img + (y + pad_top) * row;
                // Left/right borders, then the payload row in one memcpy.
                dst[d..d + pad_left * c].fill(0.0);
                dst[d + (pad_left + w) * c..d + row].fill(0.0);
                let src = self.idx4(b, y, 0, 0);
                dst[d + pad_left * c..d + (pad_left + w) * c]
                    .copy_from_slice(&self.data[src..src + w * c]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_full() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(t.data().iter().all(|&x| x == 0.0));
        let u = Tensor::full(&[2, 2], 3.5);
        assert!(u.data().iter().all(|&x| x == 3.5));
    }

    #[test]
    fn randn_is_deterministic() {
        let a = Tensor::randn(&[4, 4], 9);
        let b = Tensor::randn(&[4, 4], 9);
        assert_eq!(a, b);
        let c = Tensor::randn(&[4, 4], 10);
        assert_ne!(a, c);
    }

    #[test]
    fn from_vec_checks_len() {
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 4]).is_ok());
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 5]).is_err());
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        let r = t.reshape(&[3, 2]).unwrap();
        assert_eq!(r.shape(), &[3, 2]);
        assert_eq!(r.data(), t.data());
        assert!(t.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn nhwc_indexing() {
        // shape [1, 2, 2, 3]: value = 100h + 10w + c
        let mut t = Tensor::zeros(&[1, 2, 2, 3]);
        for h in 0..2 {
            for w in 0..2 {
                for c in 0..3 {
                    *t.at4_mut(0, h, w, c) = (100 * h + 10 * w + c) as f32;
                }
            }
        }
        assert_eq!(t.at4(0, 1, 0, 2), 102.0);
        assert_eq!(t.pixel(0, 0, 1), &[10.0, 11.0, 12.0]);
        // channels are innermost/contiguous
        assert_eq!(&t.data()[..3], &[0.0, 1.0, 2.0]);
    }

    #[test]
    fn pad_spatial_places_data() {
        let t = Tensor::full(&[1, 1, 1, 2], 5.0);
        let p = t.pad_spatial(1, 2, 0, 1);
        assert_eq!(p.shape(), &[1, 4, 2, 2]);
        assert_eq!(p.at4(0, 1, 0, 0), 5.0);
        assert_eq!(p.at4(0, 1, 0, 1), 5.0);
        assert_eq!(p.at4(0, 0, 0, 0), 0.0);
        assert_eq!(p.at4(0, 1, 1, 0), 0.0);
        let total: f32 = p.data().iter().sum();
        assert_eq!(total, 10.0);
    }

    #[test]
    fn view_mirrors_tensor_accessors() {
        let t = Tensor::randn(&[2, 3, 4, 5], 7);
        let v = t.view();
        assert_eq!(v.shape(), t.shape());
        assert_eq!(v.len(), t.len());
        assert_eq!(v.at4(1, 2, 3, 4), t.at4(1, 2, 3, 4));
        assert_eq!(v.pixel(1, 0, 2), t.pixel(1, 0, 2));
        assert_eq!(v.to_tensor(), t);
        // External shape over an external slice, with a length check.
        let shape = [1usize, 2, 2, 1];
        assert!(TensorView::new(&shape, &[0.0; 4]).is_ok());
        assert!(TensorView::new(&shape, &[0.0; 5]).is_err());
    }

    #[test]
    fn pad_spatial_into_matches_pad_spatial_and_clears_dirt() {
        let t = Tensor::randn(&[2, 3, 4, 3], 11);
        let want = t.pad_spatial(1, 2, 3, 0);
        // Dirty destination: every element must be overwritten.
        let mut dst = vec![f32::NAN; want.len()];
        t.view().pad_spatial_into(1, 2, 3, 0, &mut dst);
        assert_eq!(dst, want.data());
    }

    #[test]
    fn allclose_tolerates_small_error() {
        let a = Tensor::full(&[2, 2], 100.0);
        let mut b = a.clone();
        b.data_mut()[0] = 100.001;
        assert!(a.allclose(&b, 1e-4));
        assert!(!a.allclose(&b, 1e-9));
    }
}
