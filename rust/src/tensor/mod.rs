//! Dense row-major `f32` tensors plus the NHWC/NCHW layout machinery the
//! paper's §2.1 studies.
//!
//! The engine standardises on **NHWC** activations (channels innermost) —
//! the layout the paper selects so that a 128-bit SIMD load yields four
//! channels of one pixel — and `[M, KH, KW, C]` weights. NCHW support exists
//! for the layout ablation (DESIGN.md E6) and for interop.

mod layout;

pub use layout::{nchw_to_nhwc, nhwc_to_nchw, Layout};

use crate::util::XorShiftRng;
use crate::{bail_shape, Result};

/// A dense row-major tensor of `f32` values.
///
/// Shapes are arbitrary-rank, though the engine mostly uses rank-4
/// `[N, H, W, C]` activations and `[M, KH, KW, C]` weights.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    /// Tensor filled with a constant.
    pub fn full(shape: &[usize], v: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; n],
        }
    }

    /// Tensor with standard-normal entries from a deterministic seed.
    pub fn randn(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        let mut rng = XorShiftRng::new(seed);
        rng.fill_normal(&mut t.data);
        t
    }

    /// Tensor with uniform entries in `[lo, hi)` from a deterministic seed.
    pub fn rand_uniform(shape: &[usize], lo: f32, hi: f32, seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        let mut rng = XorShiftRng::new(seed);
        rng.fill_uniform(&mut t.data, lo, hi);
        t
    }

    /// Wrap an existing buffer. Errors if the element count mismatches.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail_shape!("from_vec: shape {:?} needs {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
        })
    }

    /// Tensor shape.
    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Rank (number of dimensions).
    #[inline]
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Total element count.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor has no elements.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Immutable view of the underlying buffer.
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable view of the underlying buffer.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the underlying buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail_shape!("reshape {:?} -> {:?}: element count mismatch", self.shape, shape);
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data: self.data.clone(),
        })
    }

    // ---- rank-4 NHWC accessors (the engine's canonical activation view) ----

    /// Flat index of `(n, h, w, c)` for an NHWC rank-4 tensor.
    #[inline(always)]
    pub fn idx4(&self, n: usize, h: usize, w: usize, c: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 4);
        ((n * self.shape[1] + h) * self.shape[2] + w) * self.shape[3] + c
    }

    /// Value at `(n, h, w, c)` (NHWC).
    #[inline(always)]
    pub fn at4(&self, n: usize, h: usize, w: usize, c: usize) -> f32 {
        self.data[self.idx4(n, h, w, c)]
    }

    /// Mutable value at `(n, h, w, c)` (NHWC).
    #[inline(always)]
    pub fn at4_mut(&mut self, n: usize, h: usize, w: usize, c: usize) -> &mut f32 {
        let i = self.idx4(n, h, w, c);
        &mut self.data[i]
    }

    /// The contiguous channel slice at pixel `(n, h, w)` (NHWC) — the unit
    /// the paper's SIMD transforms consume four lanes at a time.
    #[inline(always)]
    pub fn pixel(&self, n: usize, h: usize, w: usize) -> &[f32] {
        let c = self.shape[3];
        let base = self.idx4(n, h, w, 0);
        &self.data[base..base + c]
    }

    /// Mutable channel slice at pixel `(n, h, w)` (NHWC).
    #[inline(always)]
    pub fn pixel_mut(&mut self, n: usize, h: usize, w: usize) -> &mut [f32] {
        let c = self.shape[3];
        let base = self.idx4(n, h, w, 0);
        &mut self.data[base..base + c]
    }

    /// Zero-pad a rank-4 NHWC tensor spatially (same N and C).
    pub fn pad_spatial(&self, pad_top: usize, pad_bottom: usize, pad_left: usize, pad_right: usize) -> Tensor {
        assert_eq!(self.rank(), 4, "pad_spatial expects NHWC rank-4");
        let (n, h, w, c) = (self.shape[0], self.shape[1], self.shape[2], self.shape[3]);
        let (oh, ow) = (h + pad_top + pad_bottom, w + pad_left + pad_right);
        let mut out = Tensor::zeros(&[n, oh, ow, c]);
        for b in 0..n {
            for y in 0..h {
                for x in 0..w {
                    let src = self.idx4(b, y, x, 0);
                    let dst = out.idx4(b, y + pad_top, x + pad_left, 0);
                    out.data[dst..dst + c].copy_from_slice(&self.data[src..src + c]);
                }
            }
        }
        out
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().map(|x| x.abs()).fold(0.0, f32::max)
    }

    /// True when all entries of `self` and `other` are within `tol` of each
    /// other, scaled by the dynamic range of `other`.
    pub fn allclose(&self, other: &Tensor, tol: f32) -> bool {
        self.shape == other.shape && crate::util::rel_error(&self.data, &other.data) <= tol
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_full() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(t.data().iter().all(|&x| x == 0.0));
        let u = Tensor::full(&[2, 2], 3.5);
        assert!(u.data().iter().all(|&x| x == 3.5));
    }

    #[test]
    fn randn_is_deterministic() {
        let a = Tensor::randn(&[4, 4], 9);
        let b = Tensor::randn(&[4, 4], 9);
        assert_eq!(a, b);
        let c = Tensor::randn(&[4, 4], 10);
        assert_ne!(a, c);
    }

    #[test]
    fn from_vec_checks_len() {
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 4]).is_ok());
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 5]).is_err());
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        let r = t.reshape(&[3, 2]).unwrap();
        assert_eq!(r.shape(), &[3, 2]);
        assert_eq!(r.data(), t.data());
        assert!(t.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn nhwc_indexing() {
        // shape [1, 2, 2, 3]: value = 100h + 10w + c
        let mut t = Tensor::zeros(&[1, 2, 2, 3]);
        for h in 0..2 {
            for w in 0..2 {
                for c in 0..3 {
                    *t.at4_mut(0, h, w, c) = (100 * h + 10 * w + c) as f32;
                }
            }
        }
        assert_eq!(t.at4(0, 1, 0, 2), 102.0);
        assert_eq!(t.pixel(0, 0, 1), &[10.0, 11.0, 12.0]);
        // channels are innermost/contiguous
        assert_eq!(&t.data()[..3], &[0.0, 1.0, 2.0]);
    }

    #[test]
    fn pad_spatial_places_data() {
        let t = Tensor::full(&[1, 1, 1, 2], 5.0);
        let p = t.pad_spatial(1, 2, 0, 1);
        assert_eq!(p.shape(), &[1, 4, 2, 2]);
        assert_eq!(p.at4(0, 1, 0, 0), 5.0);
        assert_eq!(p.at4(0, 1, 0, 1), 5.0);
        assert_eq!(p.at4(0, 0, 0, 0), 0.0);
        assert_eq!(p.at4(0, 1, 1, 0), 0.0);
        let total: f32 = p.data().iter().sum();
        assert_eq!(total, 10.0);
    }

    #[test]
    fn allclose_tolerates_small_error() {
        let a = Tensor::full(&[2, 2], 100.0);
        let mut b = a.clone();
        b.data_mut()[0] = 100.001;
        assert!(a.allclose(&b, 1e-4));
        assert!(!a.allclose(&b, 1e-9));
    }
}
