//! A miniature property-based testing framework (offline build: no
//! `proptest`/`quickcheck`).
//!
//! [`check`] runs a property over many deterministically-seeded random cases
//! and, on failure, performs greedy shrinking over the case's integer
//! parameters before reporting the minimal failing case and the seed that
//! reproduces it.
//!
//! ```
//! use winoconv::testkit::{check, Gen};
//! check("addition commutes", 100, |g: &mut Gen| {
//!     let a = g.usize_in(0, 1000);
//!     let b = g.usize_in(0, 1000);
//!     a + b == b + a
//! });
//! ```

use crate::util::XorShiftRng;

/// Case generator handed to properties; records the integer choices made so
/// the framework can replay and shrink them.
pub struct Gen {
    rng: XorShiftRng,
    /// (lo, hi, chosen) for every `usize_in` call, in order.
    trace: Vec<(usize, usize, usize)>,
    /// When replaying a shrunk trace, choices come from here instead.
    replay: Option<Vec<usize>>,
    cursor: usize,
}

impl std::fmt::Debug for Gen {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Gen")
            .field("trace", &self.trace)
            .field("cursor", &self.cursor)
            .finish_non_exhaustive()
    }
}

impl Gen {
    fn new(seed: u64) -> Gen {
        Gen {
            rng: XorShiftRng::new(seed),
            trace: Vec::new(),
            replay: None,
            cursor: 0,
        }
    }

    fn replaying(seed: u64, choices: Vec<usize>) -> Gen {
        Gen {
            rng: XorShiftRng::new(seed),
            trace: Vec::new(),
            replay: Some(choices),
            cursor: 0,
        }
    }

    /// An integer in `[lo, hi]` inclusive. The fundamental generator; sizes,
    /// channel counts etc. should flow through it so shrinking works.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let v = match &self.replay {
            Some(choices) if self.cursor < choices.len() => {
                choices[self.cursor].clamp(lo, hi)
            }
            _ => self.rng.range(lo, hi),
        };
        self.cursor += 1;
        self.trace.push((lo, hi, v));
        v
    }

    /// A uniform `f32` in `[lo, hi)` (not part of the shrink space).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo, hi)
    }

    /// A vector of `n` standard-normal floats.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.rng.fill_normal(&mut v);
        v
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.usize_in(0, xs.len() - 1);
        &xs[i]
    }
}

/// Run `prop` over `cases` random cases. Panics with the seed and the
/// (shrunk) parameter trace on the first failure.
///
/// Set `WINOCONV_PT_SEED` to reproduce a specific base seed.
pub fn check<F: Fn(&mut Gen) -> bool>(name: &str, cases: usize, prop: F) {
    let base_seed = std::env::var("WINOCONV_PT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::new(seed);
        if !run_case(&prop, &mut g) {
            let shrunk = shrink(&prop, seed, &g.trace);
            panic!(
                "property {name:?} failed (case {case}, seed {seed}).\n\
                 minimal failing choices: {shrunk:?}\n\
                 reproduce with WINOCONV_PT_SEED={base_seed}"
            );
        }
    }
}

fn run_case<F: Fn(&mut Gen) -> bool>(prop: &F, g: &mut Gen) -> bool {
    // A panicking property counts as a failure (assert-style properties).
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(g)));
    matches!(result, Ok(true))
}

/// Greedy shrink: repeatedly try lowering each recorded choice toward its
/// lower bound (binary-search style) while the property still fails.
fn shrink<F: Fn(&mut Gen) -> bool>(
    prop: &F,
    seed: u64,
    trace: &[(usize, usize, usize)],
) -> Vec<usize> {
    let mut current: Vec<usize> = trace.iter().map(|t| t.2).collect();
    let lows: Vec<usize> = trace.iter().map(|t| t.0).collect();
    let mut improved = true;
    let mut budget = 200;
    while improved && budget > 0 {
        improved = false;
        for i in 0..current.len() {
            while current[i] > lows[i] && budget > 0 {
                budget -= 1;
                let mut candidate = current.clone();
                // Try the midpoint toward the lower bound; if that passes,
                // fall back to a single decrement so we land exactly on the
                // failure boundary.
                candidate[i] = lows[i] + (current[i] - lows[i]) / 2;
                let mut g = Gen::replaying(seed, candidate.clone());
                if !run_case(prop, &mut g) {
                    current = candidate;
                    improved = true;
                    continue;
                }
                candidate[i] = current[i] - 1;
                let mut g = Gen::replaying(seed, candidate.clone());
                if !run_case(prop, &mut g) {
                    current = candidate;
                    improved = true;
                } else {
                    break;
                }
            }
        }
    }
    current
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse-reverse is identity", 50, |g| {
            let n = g.usize_in(0, 20);
            let v: Vec<f32> = g.normal_vec(n);
            let mut r = v.clone();
            r.reverse();
            r.reverse();
            r == v
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics() {
        check("all ints are below 5", 100, |g| g.usize_in(0, 100) < 5);
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // Property fails iff n >= 10; the shrunk choice must be exactly 10.
        let result = std::panic::catch_unwind(|| {
            check("n < 10", 100, |g| g.usize_in(0, 1000) < 10)
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().expect("panic payload"),
            Ok(_) => panic!("property should have failed"),
        };
        assert!(msg.contains("[10]"), "expected shrunk [10], got: {msg}");
    }

    #[test]
    fn panicking_property_is_failure() {
        let result = std::panic::catch_unwind(|| {
            check("panics are failures", 5, |g| {
                let _ = g.usize_in(0, 3);
                panic!("inner panic");
            })
        });
        assert!(result.is_err());
    }

    #[test]
    fn choose_picks_from_slice() {
        let mut g = Gen::new(1);
        let options = [2usize, 4, 8];
        for _ in 0..20 {
            assert!(options.contains(g.choose(&options)));
        }
    }
}
