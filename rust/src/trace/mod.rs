//! Zero-steady-state-allocation span tracing for the execution stack.
//!
//! The paper's evidence is per-layer, per-stage accounting (Table 2 rows,
//! Fig. 3 stage bars); this module gives the runtime the same view in situ.
//! A process-global sink records fixed-size **spans** into a pre-allocated
//! slot buffer ([`reserve`]) with an atomic cursor — the record path is
//! lock-free, allocation-free and UB-free (every slot word is an
//! `AtomicU64`), so the statcheck no-alloc pass and the grow-count-0 arena
//! pins survive with tracing ON.
//!
//! Span model (three kinds, one 5-word encoding):
//!
//! * **Layer** spans — one per non-passthrough graph node, recorded by the
//!   planned executor with the op's algorithm, dtype and output shape.
//! * **Stage** spans — the engines subdivide each conv call into its
//!   pipeline stages (pack / transform / GEMM / quantize / compute), a
//!   fixed count per algorithm so a walk's span census is statically
//!   computable (`PreparedModel::trace_spans_per_walk`).
//! * **Serve** spans — the coordinator dispatcher wraps queue-wait /
//!   gather / compute / scatter around every dispatched batch.
//!
//! Disabled tracing costs one relaxed [`AtomicBool`] load per probe; the
//! `ablation_trace` bench gates the *enabled* whole-network overhead at
//! ≤ 3%. When the cursor passes capacity the sink **drops** (and counts)
//! rather than ring-wrapping, so concurrent writers can never alias a slot.
//! Consumers drain with [`take`] (allocates — offline only) and feed
//! [`roofline`] or [`export_chrome`] (a chrome://tracing / Perfetto JSON).

pub mod roofline;

use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Serializes every unit test that enables the process-global sink — tests
/// in *any* module must hold this across their enabled window (and filter
/// what they assert on), since the test harness runs modules concurrently.
#[cfg(test)]
pub(crate) static TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Words per span slot: header, shape lo, shape hi, t0, duration.
const WORDS: usize = 5;

static ENABLED: AtomicBool = AtomicBool::new(false);
/// Next free slot index; may run past capacity (the excess is `DROPPED`).
static CURSOR: AtomicUsize = AtomicUsize::new(0);
static DROPPED: AtomicU64 = AtomicU64::new(0);
/// The slot buffer: word 0 holds the capacity (in spans), the spans follow.
/// Published once per [`reserve`] growth; old buffers are intentionally
/// leaked (reserve happens O(1) times per process) so a racing recorder can
/// never observe a freed allocation.
static SLOTS: AtomicPtr<AtomicU64> = AtomicPtr::new(std::ptr::null_mut());
/// Graph-node index the planned executor is currently inside — stage spans
/// recorded by the engines attribute themselves to this layer.
static CURRENT_LAYER: AtomicU32 = AtomicU32::new(0);
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// What a span describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum SpanKind {
    /// One graph-node execution in a planned walk.
    Layer = 0,
    /// One engine pipeline stage inside a layer.
    Stage = 1,
    /// One coordinator dispatcher phase around a batch.
    Serve = 2,
}

impl SpanKind {
    fn from_u8(v: u8) -> SpanKind {
        match v {
            1 => SpanKind::Stage,
            2 => SpanKind::Serve,
            _ => SpanKind::Layer,
        }
    }

    /// Category name for the chrome exporter.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Layer => "layer",
            SpanKind::Stage => "stage",
            SpanKind::Serve => "serve",
        }
    }
}

/// Engine pipeline stages and dispatcher phases (the `code` of a
/// [`SpanKind::Stage`] / [`SpanKind::Serve`] span).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Stage {
    /// Patch-matrix fill / padded staging / packed-row gather.
    Pack = 0,
    /// Winograd input transform (transform-as-pack).
    Transform = 1,
    /// The GEMM sweep (incl. fused epilogues: bias/act/gather/dequant).
    Gemm = 2,
    /// Activation quantization (int8 engines).
    Quantize = 3,
    /// Direct compute (depthwise register-tiled kernels).
    Compute = 4,
    /// Dispatcher: time the batch head waited in the queue.
    QueueWait = 5,
    /// Dispatcher: gather request frames into the staging batch.
    Gather = 6,
    /// Dispatcher: scatter outputs back to per-request responses.
    Scatter = 7,
}

impl Stage {
    fn from_u8(v: u8) -> Stage {
        match v {
            1 => Stage::Transform,
            2 => Stage::Gemm,
            3 => Stage::Quantize,
            4 => Stage::Compute,
            5 => Stage::QueueWait,
            6 => Stage::Gather,
            7 => Stage::Scatter,
            _ => Stage::Pack,
        }
    }

    /// Human/exporter name.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Pack => "pack",
            Stage::Transform => "transform",
            Stage::Gemm => "gemm",
            Stage::Quantize => "quantize",
            Stage::Compute => "compute",
            Stage::QueueWait => "queue-wait",
            Stage::Gather => "gather",
            Stage::Scatter => "scatter",
        }
    }
}

/// Algorithm lane a span belongs to — a `u8` mirror of
/// [`crate::conv::ConvAlgorithm`] so this module stays a leaf (no `conv`
/// dependency; the `nn` layer maps its prepared bindings onto these codes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum AlgoCode {
    /// Not a conv (pool / fc / elementwise) or unknown.
    None = 0,
    /// Region-wise multi-channel Winograd.
    Winograd = 1,
    /// im2row + GEMM.
    Im2Row = 2,
    /// Direct register-tiled depthwise.
    Depthwise = 3,
    /// Zero-copy direct pointwise (1×1).
    Pointwise = 4,
    /// Naive direct (grouped fallback).
    Direct = 5,
    /// Quantized im2row.
    Im2RowI8 = 6,
    /// Quantized depthwise.
    DepthwiseI8 = 7,
    /// Quantized pointwise.
    PointwiseI8 = 8,
}

impl AlgoCode {
    fn from_u8(v: u8) -> AlgoCode {
        match v {
            1 => AlgoCode::Winograd,
            2 => AlgoCode::Im2Row,
            3 => AlgoCode::Depthwise,
            4 => AlgoCode::Pointwise,
            5 => AlgoCode::Direct,
            6 => AlgoCode::Im2RowI8,
            7 => AlgoCode::DepthwiseI8,
            8 => AlgoCode::PointwiseI8,
            _ => AlgoCode::None,
        }
    }

    /// Human/exporter name, matching the dispatch-census lane names.
    pub fn name(self) -> &'static str {
        match self {
            AlgoCode::None => "-",
            AlgoCode::Winograd => "winograd",
            AlgoCode::Im2Row => "im2row",
            AlgoCode::Depthwise => "depthwise",
            AlgoCode::Pointwise => "pointwise",
            AlgoCode::Direct => "direct",
            AlgoCode::Im2RowI8 => "im2row-i8",
            AlgoCode::DepthwiseI8 => "depthwise-i8",
            AlgoCode::PointwiseI8 => "pointwise-i8",
        }
    }

    /// 1 for the int8 lanes, 0 otherwise (the span `dtype` field).
    pub fn dtype_code(self) -> u8 {
        match self {
            AlgoCode::Im2RowI8 | AlgoCode::DepthwiseI8 | AlgoCode::PointwiseI8 => 1,
            _ => 0,
        }
    }
}

/// A decoded span (offline view of one slot).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// Layer / stage / serve.
    pub kind: SpanKind,
    /// [`Stage`] discriminant for stage/serve spans; 0 for layer spans.
    pub code: u8,
    /// Algorithm lane (conv layer + stage spans; `None` elsewhere).
    pub algo: AlgoCode,
    /// 0 = f32, 1 = int8.
    pub dtype: u8,
    /// Graph-node index (layer + stage spans; 0 for serve spans).
    pub layer: u32,
    /// Output shape `[N, H, W, C]` (layer spans; zeros elsewhere).
    pub shape: [u32; 4],
    /// Start, nanoseconds since the process trace epoch.
    pub t0_ns: u64,
    /// Duration in nanoseconds.
    pub dur_ns: u64,
}

impl Span {
    /// The stage of a stage/serve span.
    pub fn stage(&self) -> Option<Stage> {
        match self.kind {
            SpanKind::Layer => None,
            _ => Some(Stage::from_u8(self.code)),
        }
    }
}

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process trace epoch (monotonic).
#[inline]
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// Is tracing on? One relaxed atomic load — the entire disabled-path cost.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the sink on or off. Also pins the trace epoch so span timestamps
/// stay small.
pub fn set_enabled(on: bool) {
    let _ = epoch();
    ENABLED.store(on, Ordering::Relaxed);
}

/// Ensure capacity for at least `spans` spans, then [`reset`]. Growth
/// allocates a fresh buffer and leaks the old one (never freed — recorders
/// may still hold the pointer); call this at prepare/setup time, sized from
/// `PreparedModel::trace_spans_per_walk`, never on the hot path.
pub fn reserve(spans: usize) {
    let _ = epoch();
    if capacity() < spans {
        let mut buf: Vec<AtomicU64> = Vec::with_capacity(1 + spans * WORDS);
        buf.push(AtomicU64::new(spans as u64));
        buf.resize_with(1 + spans * WORDS, || AtomicU64::new(0));
        let leaked: &'static mut [AtomicU64] = Box::leak(buf.into_boxed_slice());
        // Release-publish: the capacity word and zeroed slots are visible
        // to any recorder that acquires this pointer.
        SLOTS.store(leaked.as_mut_ptr(), Ordering::Release);
    }
    reset();
}

/// Rewind the cursor and clear the dropped counter (slot contents are
/// overwritten by the next records; stale words are never decoded because
/// [`take`] reads only up to the cursor).
pub fn reset() {
    CURSOR.store(0, Ordering::Relaxed);
    DROPPED.store(0, Ordering::Relaxed);
}

/// Reserved capacity in spans (0 before the first [`reserve`]).
pub fn capacity() -> usize {
    let base = SLOTS.load(Ordering::Acquire);
    if base.is_null() {
        return 0;
    }
    // SAFETY: a non-null `base` was Release-published by `reserve` and
    // points at a leaked (never freed) buffer whose word 0 is the capacity.
    unsafe { (*base).load(Ordering::Relaxed) as usize }
}

/// Spans recorded since the last reset (clamped to capacity).
pub fn len() -> usize {
    CURSOR.load(Ordering::Relaxed).min(capacity())
}

/// Spans dropped on overflow (or before any buffer was reserved) since the
/// last reset.
pub fn dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Set the graph-node index stage spans attribute themselves to.
#[inline]
pub fn set_current_layer(layer: u32) {
    CURRENT_LAYER.store(layer, Ordering::Relaxed);
}

/// Span-header word: kind | code | algo | dtype | layer.
#[inline]
fn pack_w0(kind: SpanKind, code: u8, algo: AlgoCode, dtype: u8, layer: u32) -> u64 {
    kind as u64 | (code as u64) << 8 | (algo as u64) << 16 | (dtype as u64) << 24
        | (layer as u64) << 32
}

/// The lock-free hot core: claim a slot, store five words. Drops (and
/// counts) on overflow instead of wrapping so concurrent writers never
/// alias a slot.
#[inline]
fn record(w0: u64, w1: u64, w2: u64, t0_ns: u64, dur_ns: u64) {
    let base = SLOTS.load(Ordering::Acquire);
    if base.is_null() {
        DROPPED.fetch_add(1, Ordering::Relaxed);
        return;
    }
    // SAFETY: non-null `base` points at the leaked buffer published by
    // `reserve`; word 0 is its capacity in spans.
    let cap = unsafe { (*base).load(Ordering::Relaxed) as usize };
    let i = CURSOR.fetch_add(1, Ordering::Relaxed);
    if i >= cap {
        DROPPED.fetch_add(1, Ordering::Relaxed);
        return;
    }
    // SAFETY: `i < cap` and the buffer holds `1 + cap * WORDS` words, so
    // slot words `1 + i*WORDS .. 1 + (i+1)*WORDS` are in bounds; the
    // fetch_add above claimed index `i` uniquely, and every word is an
    // AtomicU64, so concurrent stores are race-free by construction.
    unsafe {
        let s = base.add(1 + i * WORDS);
        (*s).store(w0, Ordering::Relaxed);
        (*s.add(1)).store(w1, Ordering::Relaxed);
        (*s.add(2)).store(w2, Ordering::Relaxed);
        (*s.add(3)).store(t0_ns, Ordering::Relaxed);
        (*s.add(4)).store(dur_ns, Ordering::Relaxed);
    }
}

/// Start a span probe: the current timestamp when tracing is enabled, 0
/// (and nothing else — no clock read) when disabled.
#[inline]
pub fn begin() -> u64 {
    if ENABLED.load(Ordering::Relaxed) {
        now_ns()
    } else {
        0
    }
}

/// Close a stage span opened with [`begin`]; a no-op when disabled.
#[inline]
pub fn end_stage(t0_ns: u64, stage: Stage, algo: AlgoCode) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let now = now_ns();
    record_stage_at(stage, algo, t0_ns, now.saturating_sub(t0_ns));
}

/// Record a stage span from explicit timings (engines that accumulate a
/// stage's nanoseconds across region blocks record one synthetic interval).
#[inline]
pub fn record_stage_at(stage: Stage, algo: AlgoCode, t0_ns: u64, dur_ns: u64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let layer = CURRENT_LAYER.load(Ordering::Relaxed);
    let w0 = pack_w0(SpanKind::Stage, stage as u8, algo, algo.dtype_code(), layer);
    record(w0, 0, 0, t0_ns, dur_ns);
}

/// Record a layer span (the planned executor, once per non-passthrough
/// node); a no-op when disabled.
#[inline]
pub fn record_layer(layer: u32, algo: AlgoCode, shape: [u32; 4], t0_ns: u64, dur_ns: u64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let w0 = pack_w0(SpanKind::Layer, 0, algo, algo.dtype_code(), layer);
    let w1 = shape[0] as u64 | (shape[1] as u64) << 32;
    let w2 = shape[2] as u64 | (shape[3] as u64) << 32;
    record(w0, w1, w2, t0_ns, dur_ns);
}

/// Record a coordinator dispatcher phase span; a no-op when disabled.
#[inline]
pub fn record_serve(phase: Stage, t0_ns: u64, dur_ns: u64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let w0 = pack_w0(SpanKind::Serve, phase as u8, AlgoCode::None, 0, 0);
    record(w0, 0, 0, t0_ns, dur_ns);
}

/// Drain the sink: decode every recorded span (in record order), then
/// reset. Allocates — offline consumers only. Call from a quiescent point
/// (after a walk / at shutdown); spans recorded concurrently with the drain
/// may be missed or half-written (each word is still a valid u64 — no UB,
/// just a torn reading).
pub fn take() -> Vec<Span> {
    let base = SLOTS.load(Ordering::Acquire);
    let mut out = Vec::new();
    if base.is_null() {
        return out;
    }
    // SAFETY: see `record` — non-null `base` is the leaked published
    // buffer; word 0 is the capacity.
    let cap = unsafe { (*base).load(Ordering::Relaxed) as usize };
    let n = CURSOR.load(Ordering::Relaxed).min(cap);
    out.reserve(n);
    for i in 0..n {
        // SAFETY: `i < cap`, so the slot's five words are in bounds of the
        // `1 + cap * WORDS`-word buffer.
        let (w0, w1, w2, t0, dur) = unsafe {
            let s = base.add(1 + i * WORDS);
            (
                (*s).load(Ordering::Relaxed),
                (*s.add(1)).load(Ordering::Relaxed),
                (*s.add(2)).load(Ordering::Relaxed),
                (*s.add(3)).load(Ordering::Relaxed),
                (*s.add(4)).load(Ordering::Relaxed),
            )
        };
        out.push(Span {
            kind: SpanKind::from_u8(w0 as u8),
            code: (w0 >> 8) as u8,
            algo: AlgoCode::from_u8((w0 >> 16) as u8),
            dtype: (w0 >> 24) as u8,
            layer: (w0 >> 32) as u32,
            shape: [w1 as u32, (w1 >> 32) as u32, w2 as u32, (w2 >> 32) as u32],
            t0_ns: t0,
            dur_ns: dur,
        });
    }
    reset();
    out
}

/// Render spans as chrome://tracing "trace event" JSON (open in Perfetto
/// or chrome://tracing). Layer spans sit on tid 0, stage spans on tid 1,
/// serve spans on tid 2, so stages nest visually under their layers.
/// `layer_names[i]` labels the layer/stage spans of graph node `i`.
pub fn export_chrome(spans: &[Span], layer_names: &[String]) -> String {
    use std::fmt::Write as _;
    let mut s = String::from("{\"traceEvents\":[");
    for (i, sp) in spans.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let layer_name = layer_names
            .get(sp.layer as usize)
            .map(|n| n.as_str())
            .unwrap_or("layer");
        let (name, tid) = match sp.kind {
            SpanKind::Layer => (layer_name.to_string(), 0),
            SpanKind::Stage => {
                (format!("{}:{}", sp.algo.name(), Stage::from_u8(sp.code).name()), 1)
            }
            SpanKind::Serve => (Stage::from_u8(sp.code).name().to_string(), 2),
        };
        let _ = write!(
            s,
            "{{\"name\":{:?},\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\
             \"pid\":0,\"tid\":{tid},\"args\":{{\"algo\":\"{}\",\"layer\":{},\
             \"shape\":[{},{},{},{}]}}}}",
            name,
            sp.kind.name(),
            sp.t0_ns as f64 / 1e3,
            sp.dur_ns as f64 / 1e3,
            sp.algo.name(),
            sp.layer,
            sp.shape[0],
            sp.shape[1],
            sp.shape[2],
            sp.shape[3],
        );
    }
    s.push_str("]}");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The sink is process-global; tests serialize on [`TEST_LOCK`] and
    /// filter by magic layer indices so concurrent non-trace tests (which
    /// never enable tracing, but could record during our enabled windows)
    /// cannot flip their assertions.
    use super::TEST_LOCK as LOCK;

    const MAGIC: u32 = 0x00C0_FFEE;

    #[test]
    fn disabled_sink_records_nothing() {
        let _g = LOCK.lock().unwrap();
        reserve(64);
        set_enabled(false);
        record_layer(MAGIC, AlgoCode::Winograd, [1, 2, 3, 4], 10, 20);
        record_stage_at(Stage::Gemm, AlgoCode::Im2Row, 0, 5);
        let spans = take();
        assert!(spans.iter().all(|s| s.layer != MAGIC));
    }

    #[test]
    fn roundtrips_every_field() {
        let _g = LOCK.lock().unwrap();
        reserve(64);
        set_enabled(true);
        record_layer(MAGIC, AlgoCode::PointwiseI8, [2, 56, 28, 192], 1234, 5678);
        set_current_layer(MAGIC);
        record_stage_at(Stage::Quantize, AlgoCode::Im2RowI8, 42, 17);
        set_enabled(false);
        let spans = take();
        set_current_layer(0);
        let lay = spans
            .iter()
            .find(|s| s.kind == SpanKind::Layer && s.layer == MAGIC)
            .expect("layer span");
        assert_eq!(lay.algo, AlgoCode::PointwiseI8);
        assert_eq!(lay.dtype, 1);
        assert_eq!(lay.shape, [2, 56, 28, 192]);
        assert_eq!((lay.t0_ns, lay.dur_ns), (1234, 5678));
        let st = spans
            .iter()
            .find(|s| s.kind == SpanKind::Stage && s.layer == MAGIC)
            .expect("stage span");
        assert_eq!(st.stage(), Some(Stage::Quantize));
        assert_eq!(st.algo, AlgoCode::Im2RowI8);
        assert_eq!((st.t0_ns, st.dur_ns), (42, 17));
    }

    #[test]
    fn overflow_drops_and_counts_instead_of_wrapping() {
        let _g = LOCK.lock().unwrap();
        // A fresh tiny buffer is only installed if no larger one exists, so
        // exercise the drop path by exhausting whatever capacity is there.
        reserve(4);
        let cap = capacity();
        set_enabled(true);
        let extra = 100u64;
        for i in 0..(cap as u64 + extra) {
            record_layer(MAGIC, AlgoCode::Direct, [0; 4], i, 1);
        }
        set_enabled(false);
        assert!(dropped() >= extra, "dropped {} < {extra}", dropped());
        let spans = take();
        assert!(spans.len() <= cap);
        // The sink keeps working after overflow.
        set_enabled(true);
        record_layer(MAGIC, AlgoCode::Winograd, [0; 4], 7, 7);
        set_enabled(false);
        assert!(take().iter().any(|s| s.layer == MAGIC && s.algo == AlgoCode::Winograd));
    }

    #[test]
    fn begin_is_zero_when_disabled_and_monotonic_when_enabled() {
        let _g = LOCK.lock().unwrap();
        set_enabled(false);
        assert_eq!(begin(), 0);
        set_enabled(true);
        let a = begin();
        let b = begin();
        set_enabled(false);
        assert!(b >= a);
    }

    #[test]
    fn chrome_export_shape() {
        let spans = [
            Span {
                kind: SpanKind::Layer,
                code: 0,
                algo: AlgoCode::Winograd,
                dtype: 0,
                layer: 0,
                shape: [1, 56, 56, 64],
                t0_ns: 1000,
                dur_ns: 2000,
            },
            Span {
                kind: SpanKind::Stage,
                code: Stage::Gemm as u8,
                algo: AlgoCode::Winograd,
                dtype: 0,
                layer: 0,
                shape: [0; 4],
                t0_ns: 1500,
                dur_ns: 400,
            },
            Span {
                kind: SpanKind::Serve,
                code: Stage::QueueWait as u8,
                algo: AlgoCode::None,
                dtype: 0,
                layer: 0,
                shape: [0; 4],
                t0_ns: 0,
                dur_ns: 900,
            },
        ];
        let json = export_chrome(&spans, &["conv1_1".to_string()]);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.ends_with("]}"));
        assert!(json.contains("\"conv1_1\""));
        assert!(json.contains("winograd:gemm"));
        assert!(json.contains("queue-wait"));
        assert_eq!(json.matches("\"ph\":\"X\"").count(), 3);
        // Balanced braces — the cheap well-formedness proxy without a JSON
        // parser in the tree.
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }

    #[test]
    fn enum_codes_roundtrip() {
        for algo in [
            AlgoCode::None,
            AlgoCode::Winograd,
            AlgoCode::Im2Row,
            AlgoCode::Depthwise,
            AlgoCode::Pointwise,
            AlgoCode::Direct,
            AlgoCode::Im2RowI8,
            AlgoCode::DepthwiseI8,
            AlgoCode::PointwiseI8,
        ] {
            assert_eq!(AlgoCode::from_u8(algo as u8), algo);
        }
        for st in [
            Stage::Pack,
            Stage::Transform,
            Stage::Gemm,
            Stage::Quantize,
            Stage::Compute,
            Stage::QueueWait,
            Stage::Gather,
            Stage::Scatter,
        ] {
            assert_eq!(Stage::from_u8(st as u8), st);
        }
    }
}
