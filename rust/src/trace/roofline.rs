//! Per-layer roofline profiles on top of the raw trace spans — the
//! runtime's Table 2 view: every layer with its FLOPs, bytes moved,
//! achieved GFLOP/s, arithmetic intensity and share of network time.
//!
//! Costs are derived from conv geometry at prepare time (the `nn` layer
//! builds a [`LayerInfo`] per node via `PreparedModel::layer_infos`);
//! timings come from the layer spans of a traced walk. A layer's
//! arithmetic intensity (FLOPs per byte of input + weights + output) says
//! which side of the roofline it sits on: low-intensity layers (1×1 convs,
//! pools) are bandwidth-bound and gain nothing from a faster kernel, the
//! high-intensity 3×3 mid-network layers are exactly where the paper's
//! Winograd scheme pays.

use super::{AlgoCode, Span, SpanKind};
use crate::bench::Table;

/// Static work/traffic cost of one layer, derived from its geometry.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LayerCost {
    /// Multiply–adds counted as 2 FLOPs each (the paper's convention).
    pub flops: u64,
    /// Input + weights + output traffic in bytes (dtype-aware, compulsory
    /// misses only — the roofline's denominator).
    pub bytes: u64,
}

/// Prepare-time description of one graph node for the profile consumers.
#[derive(Debug, Clone)]
pub struct LayerInfo {
    /// Graph-node index (the span `layer` field).
    pub node: u32,
    /// Layer name as the zoo/table prints it.
    pub name: String,
    /// Op kind ("conv", "maxpool", "fc", ...).
    pub kind: String,
    /// Bound algorithm lane.
    pub algo: AlgoCode,
    /// Output shape `[N, H, W, C]`-ish (as inferred).
    pub out_shape: Vec<usize>,
    /// Static cost model.
    pub cost: LayerCost,
}

/// One profiled layer: static cost + measured nanoseconds.
#[derive(Debug, Clone)]
pub struct LayerProfile {
    /// Prepare-time info.
    pub info: LayerInfo,
    /// Summed layer-span nanoseconds across the profiled walks.
    pub ns: u64,
    /// Layer spans aggregated (== walk count in a clean profile run).
    pub spans: u64,
}

impl LayerProfile {
    /// Achieved GFLOP/s (total FLOPs over total time).
    pub fn gflops(&self) -> f64 {
        if self.ns == 0 {
            return 0.0;
        }
        (self.info.cost.flops * self.spans) as f64 / self.ns as f64
    }

    /// Arithmetic intensity in FLOPs / byte.
    pub fn intensity(&self) -> f64 {
        if self.info.cost.bytes == 0 {
            return 0.0;
        }
        self.info.cost.flops as f64 / self.info.cost.bytes as f64
    }
}

/// Join prepare-time [`LayerInfo`]s with the layer spans of a traced walk:
/// per node, sum span durations and count spans. Nodes that never ran
/// (passthrough) are omitted.
pub fn build_profiles(infos: &[LayerInfo], spans: &[Span]) -> Vec<LayerProfile> {
    infos
        .iter()
        .filter_map(|info| {
            let mut ns = 0u64;
            let mut n = 0u64;
            for s in spans {
                if s.kind == SpanKind::Layer && s.layer == info.node {
                    ns += s.dur_ns;
                    n += 1;
                }
            }
            if n == 0 {
                return None;
            }
            Some(LayerProfile {
                info: info.clone(),
                ns,
                spans: n,
            })
        })
        .collect()
}

/// Render the per-layer roofline table (every layer, network order) plus a
/// whole-network summary line.
pub fn render(title: &str, profiles: &[LayerProfile]) -> String {
    let total_ns: u64 = profiles.iter().map(|p| p.ns).sum();
    let total_flops: u64 = profiles.iter().map(|p| p.info.cost.flops * p.spans).sum();
    let mut table = Table::new(
        title,
        &["layer", "kind", "algo", "out shape", "ms", "% time", "GFLOP/s", "FLOP/byte"],
    );
    for p in profiles {
        let shape = p
            .info
            .out_shape
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("x");
        table.row(&[
            p.info.name.clone(),
            p.info.kind.clone(),
            p.info.algo.name().to_string(),
            shape,
            format!("{:.3}", crate::util::stats::ns_to_ms(p.ns as f64 / p.spans as f64)),
            format!("{:.1}", 100.0 * p.ns as f64 / total_ns.max(1) as f64),
            format!("{:.2}", p.gflops()),
            format!("{:.2}", p.intensity()),
        ]);
    }
    let mut out = table.render();
    out.push_str(&format!(
        "network: {:.2} ms/walk, {:.2} GFLOP/walk, {:.2} GFLOP/s overall\n",
        crate::util::stats::ns_to_ms(total_ns as f64)
            / profiles.iter().map(|p| p.spans).max().unwrap_or(1) as f64,
        total_flops as f64 / 1e9 / profiles.iter().map(|p| p.spans).max().unwrap_or(1) as f64,
        if total_ns == 0 { 0.0 } else { total_flops as f64 / total_ns as f64 },
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn info(node: u32, name: &str, algo: AlgoCode, flops: u64, bytes: u64) -> LayerInfo {
        LayerInfo {
            node,
            name: name.to_string(),
            kind: "conv".to_string(),
            algo,
            out_shape: vec![1, 8, 8, 16],
            cost: LayerCost { flops, bytes },
        }
    }

    fn layer_span(node: u32, dur_ns: u64) -> Span {
        Span {
            kind: SpanKind::Layer,
            code: 0,
            algo: AlgoCode::Winograd,
            dtype: 0,
            layer: node,
            shape: [1, 8, 8, 16],
            t0_ns: 0,
            dur_ns,
        }
    }

    #[test]
    fn profiles_join_costs_with_span_time() {
        let infos = [
            info(0, "conv1", AlgoCode::Winograd, 2_000_000, 100_000),
            info(3, "conv2", AlgoCode::Im2Row, 1_000_000, 500_000),
            info(5, "never-ran", AlgoCode::None, 1, 1),
        ];
        // Two walks: node 0 spans twice, node 3 once.
        let spans = [layer_span(0, 1_000_000), layer_span(0, 3_000_000), layer_span(3, 500_000)];
        let ps = build_profiles(&infos, &spans);
        assert_eq!(ps.len(), 2, "unran nodes are omitted");
        assert_eq!(ps[0].ns, 4_000_000);
        assert_eq!(ps[0].spans, 2);
        // 2 MFLOP x 2 walks over 4 ms = 1 GFLOP/s.
        assert!((ps[0].gflops() - 1.0).abs() < 1e-9, "{}", ps[0].gflops());
        assert!((ps[0].intensity() - 20.0).abs() < 1e-9);
        assert!((ps[1].gflops() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn render_lists_every_layer_and_totals() {
        let infos = [
            info(0, "conv1", AlgoCode::Winograd, 2_000_000, 100_000),
            info(1, "conv2", AlgoCode::Pointwise, 500_000, 400_000),
        ];
        let spans = [layer_span(0, 1_000_000), layer_span(1, 1_000_000)];
        let ps = build_profiles(&infos, &spans);
        let s = render("demo roofline", &ps);
        assert!(s.contains("demo roofline"));
        assert!(s.contains("conv1"));
        assert!(s.contains("conv2"));
        assert!(s.contains("winograd"));
        assert!(s.contains("pointwise"));
        assert!(s.contains("GFLOP/s"));
        assert!(s.contains("network:"));
        // 50/50 time split.
        assert!(s.contains("50.0"));
    }
}
