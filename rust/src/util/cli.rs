//! A minimal command-line argument parser (offline build: no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.
//! Typed getters parse on access and produce [`crate::Error::Config`] with a
//! clear message on malformed values.

use crate::{Error, Result};
use std::collections::HashMap;

/// Parsed command line: options by name plus positionals in order.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: HashMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    ///
    /// `known_flags` lists boolean options that take no value; anything else
    /// beginning with `--` consumes the following token (or its `=` suffix)
    /// as a value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, known_flags: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = raw.into_iter();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: the rest is positional.
                    out.positional.extend(iter);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    // A value-taking option consumes the next token; no
                    // token (or another option) is a diagnosed error, never
                    // a panic — bench/CI wrappers turn it into exit code 2.
                    match iter.next() {
                        Some(v) if !v.starts_with("--") => {
                            out.opts.insert(body.to_string(), v);
                        }
                        Some(v) => {
                            return Err(Error::Config(format!(
                                "option --{body} expects a value, got {v}"
                            )));
                        }
                        None => {
                            return Err(Error::Config(format!("option --{body} expects a value")));
                        }
                    }
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse directly from the process environment.
    pub fn from_env(known_flags: &[&str]) -> Result<Args> {
        Args::parse(std::env::args().skip(1), known_flags)
    }

    /// True if a boolean flag was given.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw string value of `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// String value with a default.
    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Typed value with a default; errors if present but malformed.
    pub fn get_parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse::<T>().map_err(|_| {
                Error::Config(format!("--{name}: cannot parse {s:?}"))
            }),
        }
    }

    /// Positional arguments in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// First positional (the subcommand, by convention).
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str], flags: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn parses_key_value_both_styles() {
        let a = parse(&["--threads", "4", "--model=vgg16"], &[]);
        assert_eq!(a.get("threads"), Some("4"));
        assert_eq!(a.get("model"), Some("vgg16"));
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = parse(&["bench", "--verbose", "layer1"], &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.subcommand(), Some("bench"));
        assert_eq!(a.positional(), &["bench".to_string(), "layer1".to_string()]);
    }

    #[test]
    fn typed_getter_with_default() {
        let a = parse(&["--reps", "30"], &[]);
        assert_eq!(a.get_parse_or("reps", 10usize).unwrap(), 30);
        assert_eq!(a.get_parse_or("threads", 4usize).unwrap(), 4);
    }

    #[test]
    fn typed_getter_rejects_garbage() {
        let a = parse(&["--reps", "abc"], &[]);
        assert!(a.get_parse_or("reps", 1usize).is_err());
    }

    /// The batched-inference entry points take `--batch N`; a malformed
    /// count must be a diagnosed `Config` error naming the option, never a
    /// panic or a silent fallback to the default.
    #[test]
    fn typed_getter_rejects_malformed_batch() {
        let a = parse(&["--batch", "four"], &[]);
        let e = a.get_parse_or("batch", 1usize).unwrap_err();
        assert!(e.to_string().contains("--batch"), "error names the option: {e}");
        let a = parse(&["--batch=-2"], &[]);
        assert!(a.get_parse_or("batch", 1usize).is_err(), "negative counts must not parse");
        let a = parse(&["--batch", "8"], &[]);
        assert_eq!(a.get_parse_or("batch", 1usize).unwrap(), 8);
    }

    /// `get_parse_or` works for any FromStr — including crate enums like
    /// the quantization [`Dtype`](crate::quant::Dtype) behind `--dtype`.
    #[test]
    fn typed_getter_parses_enums() {
        use crate::quant::Dtype;
        let a = parse(&["--dtype", "int8"], &[]);
        assert_eq!(a.get_parse_or("dtype", Dtype::F32).unwrap(), Dtype::Int8);
        let a = parse(&[], &[]);
        assert_eq!(a.get_parse_or("dtype", Dtype::F32).unwrap(), Dtype::F32);
        let a = parse(&["--dtype=int4"], &[]);
        let e = a.get_parse_or("dtype", Dtype::F32).unwrap_err();
        assert!(e.to_string().contains("--dtype"), "error names the option: {e}");
    }

    #[test]
    fn missing_value_is_error() {
        let r = Args::parse(["--threads".to_string()].into_iter(), &[]);
        assert!(r.is_err());
        let r = Args::parse(
            ["--threads".to_string(), "--other".to_string()].into_iter(),
            &[],
        );
        assert!(r.is_err());
    }

    #[test]
    fn errors_name_the_offending_option() {
        let e = Args::parse(["--threads".to_string()].into_iter(), &[]).unwrap_err();
        assert!(e.to_string().contains("--threads"));
        let e = Args::parse(
            ["--threads".to_string(), "--quick".to_string()].into_iter(),
            &[],
        )
        .unwrap_err();
        assert!(e.to_string().contains("--threads") && e.to_string().contains("--quick"));
    }

    #[test]
    fn double_dash_terminates_options() {
        let a = parse(&["--", "--not-an-option"], &[]);
        assert_eq!(a.positional(), &["--not-an-option".to_string()]);
    }
}
