//! Exact rational arithmetic over `i128`.
//!
//! The Cook-Toom transform-matrix construction ([`crate::winograd::cook_toom`])
//! interpolates polynomials at small rational points (0, ±1, ±2, ±1/2, …, ∞).
//! Doing that in floating point loses the exact small-integer structure that
//! the paper's hand-coded transforms rely on, so we derive B, G, A over exact
//! rationals and convert to `f32` at the very end.

use std::cmp::Ordering;
use std::fmt;
use std::ops::{Add, Div, Mul, Neg, Sub};

/// An exact rational number `num/den`, always kept in lowest terms with a
/// positive denominator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Fraction {
    num: i128,
    den: i128,
}

fn gcd(mut a: i128, mut b: i128) -> i128 {
    a = a.abs();
    b = b.abs();
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

impl Fraction {
    /// The rational `num/den`. Panics on a zero denominator.
    pub fn new(num: i128, den: i128) -> Self {
        assert!(den != 0, "Fraction with zero denominator");
        let g = gcd(num, den).max(1);
        let sign = if den < 0 { -1 } else { 1 };
        Self {
            num: sign * num / g,
            den: sign * den / g,
        }
    }

    /// The integer `n` as a rational.
    pub const fn int(n: i128) -> Self {
        Self { num: n, den: 1 }
    }

    /// Zero.
    pub const ZERO: Fraction = Fraction { num: 0, den: 1 };
    /// One.
    pub const ONE: Fraction = Fraction { num: 1, den: 1 };

    /// Numerator (lowest terms).
    pub fn numerator(&self) -> i128 {
        self.num
    }

    /// Denominator (lowest terms, always positive).
    pub fn denominator(&self) -> i128 {
        self.den
    }

    /// True iff the value is zero.
    pub fn is_zero(&self) -> bool {
        self.num == 0
    }

    /// Multiplicative inverse. Panics on zero.
    pub fn recip(&self) -> Self {
        assert!(self.num != 0, "reciprocal of zero");
        Fraction::new(self.den, self.num)
    }

    /// Lossy conversion to `f32` (used once transforms are finalised).
    pub fn to_f32(&self) -> f32 {
        self.num as f32 / self.den as f32
    }

    /// Lossy conversion to `f64`.
    pub fn to_f64(&self) -> f64 {
        self.num as f64 / self.den as f64
    }
}

impl fmt::Display for Fraction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.den == 1 {
            write!(f, "{}", self.num)
        } else {
            write!(f, "{}/{}", self.num, self.den)
        }
    }
}

impl Add for Fraction {
    type Output = Fraction;
    fn add(self, rhs: Fraction) -> Fraction {
        Fraction::new(self.num * rhs.den + rhs.num * self.den, self.den * rhs.den)
    }
}

impl Sub for Fraction {
    type Output = Fraction;
    fn sub(self, rhs: Fraction) -> Fraction {
        Fraction::new(self.num * rhs.den - rhs.num * self.den, self.den * rhs.den)
    }
}

impl Mul for Fraction {
    type Output = Fraction;
    fn mul(self, rhs: Fraction) -> Fraction {
        Fraction::new(self.num * rhs.num, self.den * rhs.den)
    }
}

impl Div for Fraction {
    type Output = Fraction;
    fn div(self, rhs: Fraction) -> Fraction {
        assert!(rhs.num != 0, "division by zero fraction");
        Fraction::new(self.num * rhs.den, self.den * rhs.num)
    }
}

impl Neg for Fraction {
    type Output = Fraction;
    fn neg(self) -> Fraction {
        Fraction {
            num: -self.num,
            den: self.den,
        }
    }
}

impl PartialOrd for Fraction {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Fraction {
    fn cmp(&self, other: &Self) -> Ordering {
        // Denominators are positive, so cross-multiplication preserves order.
        (self.num * other.den).cmp(&(other.num * self.den))
    }
}

impl From<i128> for Fraction {
    fn from(n: i128) -> Self {
        Fraction::int(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduces_to_lowest_terms() {
        let f = Fraction::new(6, 4);
        assert_eq!(f.numerator(), 3);
        assert_eq!(f.denominator(), 2);
    }

    #[test]
    fn denominator_sign_normalised() {
        let f = Fraction::new(1, -2);
        assert_eq!(f.numerator(), -1);
        assert_eq!(f.denominator(), 2);
        assert_eq!(Fraction::new(-3, -6), Fraction::new(1, 2));
    }

    #[test]
    fn arithmetic() {
        let a = Fraction::new(1, 2);
        let b = Fraction::new(1, 3);
        assert_eq!(a + b, Fraction::new(5, 6));
        assert_eq!(a - b, Fraction::new(1, 6));
        assert_eq!(a * b, Fraction::new(1, 6));
        assert_eq!(a / b, Fraction::new(3, 2));
        assert_eq!(-a, Fraction::new(-1, 2));
    }

    #[test]
    fn ordering() {
        assert!(Fraction::new(1, 3) < Fraction::new(1, 2));
        assert!(Fraction::new(-1, 2) < Fraction::ZERO);
        assert_eq!(Fraction::new(2, 4).cmp(&Fraction::new(1, 2)), Ordering::Equal);
    }

    #[test]
    fn recip_and_zero() {
        assert_eq!(Fraction::new(2, 3).recip(), Fraction::new(3, 2));
        assert!(Fraction::ZERO.is_zero());
        assert!(!Fraction::ONE.is_zero());
    }

    #[test]
    fn to_float() {
        assert_eq!(Fraction::new(1, 4).to_f32(), 0.25);
        assert_eq!(Fraction::new(-3, 2).to_f64(), -1.5);
    }

    #[test]
    #[should_panic]
    fn zero_denominator_panics() {
        let _ = Fraction::new(1, 0);
    }
}
