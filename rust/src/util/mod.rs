//! Small self-contained utilities: RNG, exact rational arithmetic, CLI
//! parsing and summary statistics. These live in-repo because the build is
//! fully offline (only `xla` + `anyhow` are vendored).

pub mod rng;
pub mod fraction;
pub mod cli;
pub mod stats;

pub use fraction::Fraction;
pub use rng::XorShiftRng;

/// Round `n` up to the next multiple of `m` (`m > 0`).
#[inline]
pub fn round_up(n: usize, m: usize) -> usize {
    n.div_ceil(m) * m
}

/// Integer ceiling division.
#[inline]
pub fn ceil_div(n: usize, m: usize) -> usize {
    n.div_ceil(m)
}

/// Maximum absolute difference between two slices (∞-norm of the diff).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "max_abs_diff: length mismatch");
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Relative error metric used throughout the test-suite: max |a-b| scaled by
/// the dynamic range of the reference.
pub fn rel_error(actual: &[f32], reference: &[f32]) -> f32 {
    let scale = reference
        .iter()
        .map(|x| x.abs())
        .fold(0.0f32, f32::max)
        .max(1e-6);
    max_abs_diff(actual, reference) / scale
}

/// Round to the nearest integer, ties to even (IEEE 754 `roundTiesToEven`).
///
/// The exact branchy scalar **reference** for the quantized pipeline: the
/// hot requantize paths use [`fast_round_half_even`] and the property tests
/// pin them against this function.
#[inline]
pub fn round_half_even(x: f32) -> f32 {
    if !x.is_finite() || x.abs() >= 8_388_608.0 {
        // Every finite f32 at or beyond 2²³ is already an integer; NaN and
        // the infinities pass through like `f32::round`.
        return x;
    }
    let f = x.floor();
    let d = x - f;
    if d < 0.5 {
        f
    } else if d > 0.5 {
        f + 1.0
    } else if (f as i64) % 2 == 0 {
        f
    } else {
        f + 1.0
    }
}

/// Branch-free round-half-to-even via the classic magic-number trick:
/// adding `1.5 × 2²³` forces the FPU (default rounding mode is
/// round-to-nearest-even) to discard the fraction bits; subtracting it
/// back leaves the rounded value.
///
/// Exact for `|x| < 2²²` — far beyond any value a saturating int8
/// requantize can produce inside its clamp range. Outside that range the
/// result drifts by at most a few ULPs of magnitude, which the clamp in
/// every caller absorbs (the property tests in `quant` rely on exactly
/// this). Unlike `f32::round` this compiles to two adds, not a libm call.
#[inline(always)]
pub fn fast_round_half_even(x: f32) -> f32 {
    const MAGIC: f32 = 12_582_912.0; // 1.5 * 2^23
    (x + MAGIC) - MAGIC
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 4), 0);
        assert_eq!(round_up(1, 4), 4);
        assert_eq!(round_up(4, 4), 4);
        assert_eq!(round_up(5, 4), 8);
        assert_eq!(round_up(17, 8), 24);
    }

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 3), 1);
        assert_eq!(ceil_div(3, 3), 1);
        assert_eq!(ceil_div(4, 3), 2);
    }

    #[test]
    fn rel_error_scales() {
        let a = [100.0, 200.0];
        let b = [100.0, 201.0];
        assert!((rel_error(&a, &b) - 1.0 / 201.0).abs() < 1e-6);
    }

    #[test]
    fn round_half_even_reference() {
        // Ties go to the even neighbour, both signs.
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(-0.5), 0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(-2.5), -2.0);
        // Non-ties round to nearest as usual.
        assert_eq!(round_half_even(1.49), 1.0);
        assert_eq!(round_half_even(1.51), 2.0);
        assert_eq!(round_half_even(-1.49), -1.0);
        assert_eq!(round_half_even(-1.51), -2.0);
        // Large magnitudes are already integral.
        assert_eq!(round_half_even(1.0e9), 1.0e9);
        assert!(round_half_even(f32::NAN).is_nan());
    }

    #[test]
    fn fast_round_matches_reference_in_validity_range() {
        // Dense sweep near zero plus tie points and larger magnitudes.
        for i in -4000i32..=4000 {
            let x = i as f32 * 0.125; // hits every .5 tie exactly
            assert_eq!(
                fast_round_half_even(x),
                round_half_even(x),
                "x = {x}"
            );
        }
        for &x in &[1234.5f32, -1234.5, 65535.5, -65535.5, 1.0e6 + 0.5] {
            assert_eq!(fast_round_half_even(x), round_half_even(x), "x = {x}");
        }
    }
}
