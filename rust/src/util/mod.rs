//! Small self-contained utilities: RNG, exact rational arithmetic, CLI
//! parsing and summary statistics. These live in-repo because the build is
//! fully offline (only `xla` + `anyhow` are vendored).

pub mod rng;
pub mod fraction;
pub mod cli;
pub mod stats;

pub use fraction::Fraction;
pub use rng::XorShiftRng;

/// Round `n` up to the next multiple of `m` (`m > 0`).
#[inline]
pub fn round_up(n: usize, m: usize) -> usize {
    n.div_ceil(m) * m
}

/// Integer ceiling division.
#[inline]
pub fn ceil_div(n: usize, m: usize) -> usize {
    n.div_ceil(m)
}

/// Maximum absolute difference between two slices (∞-norm of the diff).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "max_abs_diff: length mismatch");
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Relative error metric used throughout the test-suite: max |a-b| scaled by
/// the dynamic range of the reference.
pub fn rel_error(actual: &[f32], reference: &[f32]) -> f32 {
    let scale = reference
        .iter()
        .map(|x| x.abs())
        .fold(0.0f32, f32::max)
        .max(1e-6);
    max_abs_diff(actual, reference) / scale
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 4), 0);
        assert_eq!(round_up(1, 4), 4);
        assert_eq!(round_up(4, 4), 4);
        assert_eq!(round_up(5, 4), 8);
        assert_eq!(round_up(17, 8), 24);
    }

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 3), 1);
        assert_eq!(ceil_div(3, 3), 1);
        assert_eq!(ceil_div(4, 3), 2);
    }

    #[test]
    fn rel_error_scales() {
        let a = [100.0, 200.0];
        let b = [100.0, 201.0];
        assert!((rel_error(&a, &b) - 1.0 / 201.0).abs() < 1e-6);
    }
}
