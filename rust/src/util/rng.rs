//! A small, fast, deterministic PRNG (xorshift64*) used for synthetic
//! weights/inputs and by the property-testing framework. Determinism matters:
//! every test failure is reproducible from its seed.

/// xorshift64* generator. Not cryptographic; statistically fine for test
/// data and workload generation.
#[derive(Debug, Clone)]
pub struct XorShiftRng {
    state: u64,
}

impl XorShiftRng {
    /// Create a generator from a seed. A zero seed is remapped (xorshift
    /// cannot leave the all-zero state).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Next 32-bit value.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform `f32` in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller. Two uniforms per call; we discard the
    /// second output for simplicity (generation is not on any hot path).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-9);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Uniform integer in `[0, n)`. `n` must be non-zero.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Fill a slice with standard-normal values.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Fill a slice with uniform values in `[lo, hi)`.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.uniform(lo, hi);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = XorShiftRng::new(123);
        let mut b = XorShiftRng::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = XorShiftRng::new(1);
        let mut b = XorShiftRng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = XorShiftRng::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = XorShiftRng::new(7);
        for _ in 0..1000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v), "{v}");
            let u = r.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&u), "{u}");
        }
    }

    #[test]
    fn uniform_mean_is_roughly_half() {
        let mut r = XorShiftRng::new(99);
        let n = 20_000;
        let mean: f32 = (0..n).map(|_| r.next_f32()).sum::<f32>() / n as f32;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = XorShiftRng::new(1234);
        let n = 40_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_and_range_bounds() {
        let mut r = XorShiftRng::new(5);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
            let v = r.range(3, 9);
            assert!((3..=9).contains(&v));
        }
        assert_eq!(r.range(4, 4), 4);
    }
}
