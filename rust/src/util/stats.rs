//! Summary statistics for the benchmarking harness.
//!
//! The paper reports mean absolute runtimes measured with PMU cycle counters;
//! on a noisy general-purpose host we instead take many wall-clock samples and
//! report robust statistics (median, trimmed mean, MAD) so single-run noise
//! does not move the tables.

/// Summary of a set of timing samples, all in nanoseconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Number of samples.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Median (50th percentile).
    pub median: f64,
    /// Minimum sample.
    pub min: f64,
    /// Maximum sample.
    pub max: f64,
    /// Standard deviation (population).
    pub std_dev: f64,
    /// Median absolute deviation, scaled to be σ-comparable (×1.4826).
    pub mad: f64,
    /// 5%-trimmed mean — the statistic the tables report.
    pub trimmed_mean: f64,
}

impl Summary {
    /// Compute a summary from raw samples. Panics on an empty slice.
    pub fn from_samples(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary of empty sample set");
        let n = samples.len();
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let median = percentile_sorted(&sorted, 50.0);
        let var = sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut devs: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = percentile_sorted(&devs, 50.0) * 1.4826;
        let trim = (n as f64 * 0.05).floor() as usize;
        let kept = &sorted[trim..n - trim];
        let trimmed_mean = kept.iter().sum::<f64>() / kept.len() as f64;
        Summary {
            n,
            mean,
            median,
            min: sorted[0],
            max: sorted[n - 1],
            std_dev: var.sqrt(),
            mad,
            trimmed_mean,
        }
    }

    /// Human-readable single line, in a unit auto-chosen from the median.
    pub fn display_line(&self) -> String {
        format!(
            "median {} (trimmed-mean {}, min {}, n={})",
            fmt_ns(self.median),
            fmt_ns(self.trimmed_mean),
            fmt_ns(self.min),
            self.n
        )
    }
}

/// Percentile (0–100) of an already-sorted slice, with linear interpolation.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Format a duration given in nanoseconds with an auto-selected unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant_samples() {
        let s = Summary::from_samples(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.median, 5.0);
        assert_eq!(s.std_dev, 0.0);
        assert_eq!(s.mad, 0.0);
        assert_eq!(s.trimmed_mean, 5.0);
    }

    #[test]
    fn median_odd_even() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0]);
        assert_eq!(s.median, 2.0);
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn trimmed_mean_ignores_outliers() {
        // 38 well-behaved samples + 2 huge outliers; 5% trim drops exactly
        // one sample from each end.
        let mut xs = vec![10.0; 38];
        xs.push(1e9);
        xs.push(0.0);
        let s = Summary::from_samples(&xs);
        assert_eq!(s.trimmed_mean, 10.0);
        assert!(s.mean > 10.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 50.0), 5.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5_000_000_000.0).contains(" s"));
    }
}
