//! Summary statistics for the benchmarking harness.
//!
//! The paper reports mean absolute runtimes measured with PMU cycle counters;
//! on a noisy general-purpose host we instead take many wall-clock samples and
//! report robust statistics (median, trimmed mean, MAD) so single-run noise
//! does not move the tables.

/// Summary of a set of timing samples, all in nanoseconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Number of samples.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Median (50th percentile).
    pub median: f64,
    /// Minimum sample.
    pub min: f64,
    /// Maximum sample.
    pub max: f64,
    /// Standard deviation (population).
    pub std_dev: f64,
    /// Median absolute deviation, scaled to be σ-comparable (×1.4826).
    pub mad: f64,
    /// 5%-trimmed mean — the statistic the tables report.
    pub trimmed_mean: f64,
}

impl Summary {
    /// Compute a summary from raw samples. Panics on an empty slice.
    pub fn from_samples(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary of empty sample set");
        let n = samples.len();
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let median = percentile_sorted(&sorted, 50.0);
        let var = sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut devs: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = percentile_sorted(&devs, 50.0) * 1.4826;
        let trim = (n as f64 * 0.05).floor() as usize;
        let kept = &sorted[trim..n - trim];
        let trimmed_mean = kept.iter().sum::<f64>() / kept.len() as f64;
        Summary {
            n,
            mean,
            median,
            min: sorted[0],
            max: sorted[n - 1],
            std_dev: var.sqrt(),
            mad,
            trimmed_mean,
        }
    }

    /// Human-readable single line, in a unit auto-chosen from the median.
    pub fn display_line(&self) -> String {
        format!(
            "median {} (trimmed-mean {}, min {}, n={})",
            fmt_ns(self.median),
            fmt_ns(self.trimmed_mean),
            fmt_ns(self.min),
            self.n
        )
    }
}

/// Percentile (0–100) of an already-sorted slice, with linear interpolation.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Nanoseconds → milliseconds. The one conversion every latency report
/// performs; centralized so percentile call sites stop hand-rolling `/ 1e6`.
#[inline]
pub fn ns_to_ms(ns: f64) -> f64 {
    ns / 1e6
}

/// Fixed-capacity uniform reservoir sampler (Vitter's Algorithm R) with a
/// deterministic seedable PRNG — bounded-memory percentile estimation for
/// long-running servers. The first `cap` records are kept verbatim (so
/// short runs stay *exact*); afterwards each new record replaces a kept one
/// with probability `cap / seen`, keeping the sample uniform over the whole
/// stream. Exact running mean / max / count are tracked separately so those
/// stats never degrade to estimates.
#[derive(Debug, Clone)]
pub struct Reservoir {
    sample: Vec<f64>,
    cap: usize,
    seen: u64,
    sum: f64,
    max: f64,
    rng: crate::util::rng::XorShiftRng,
}

impl Reservoir {
    /// New reservoir keeping at most `cap` samples (`cap` > 0).
    pub fn new(cap: usize, seed: u64) -> Reservoir {
        assert!(cap > 0, "reservoir capacity must be positive");
        Reservoir {
            sample: Vec::with_capacity(cap),
            cap,
            seen: 0,
            sum: 0.0,
            max: f64::NEG_INFINITY,
            rng: crate::util::rng::XorShiftRng::new(seed),
        }
    }

    /// Record one observation.
    pub fn record(&mut self, x: f64) {
        self.seen += 1;
        self.sum += x;
        if x > self.max {
            self.max = x;
        }
        if self.sample.len() < self.cap {
            self.sample.push(x);
        } else {
            // Algorithm R: keep with probability cap/seen, evicting a
            // uniformly random kept sample.
            let j = (self.rng.next_u64() % self.seen) as usize;
            if j < self.cap {
                self.sample[j] = x;
            }
        }
    }

    /// Total observations recorded (not capped).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Samples currently kept (≤ capacity).
    pub fn len(&self) -> usize {
        self.sample.len()
    }

    /// True before the first record.
    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    /// Exact running mean over *all* observations (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.seen == 0 {
            0.0
        } else {
            self.sum / self.seen as f64
        }
    }

    /// Exact running maximum over all observations (0 when empty).
    pub fn max(&self) -> f64 {
        if self.seen == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// The kept sample, sorted ascending — feed to [`percentile_sorted`].
    pub fn sorted(&self) -> Vec<f64> {
        let mut v = self.sample.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }
}

/// Format a duration given in nanoseconds with an auto-selected unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns_to_ms(ns))
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant_samples() {
        let s = Summary::from_samples(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.median, 5.0);
        assert_eq!(s.std_dev, 0.0);
        assert_eq!(s.mad, 0.0);
        assert_eq!(s.trimmed_mean, 5.0);
    }

    #[test]
    fn median_odd_even() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0]);
        assert_eq!(s.median, 2.0);
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn trimmed_mean_ignores_outliers() {
        // 38 well-behaved samples + 2 huge outliers; 5% trim drops exactly
        // one sample from each end.
        let mut xs = vec![10.0; 38];
        xs.push(1e9);
        xs.push(0.0);
        let s = Summary::from_samples(&xs);
        assert_eq!(s.trimmed_mean, 10.0);
        assert!(s.mean > 10.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 50.0), 5.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
    }

    #[test]
    #[should_panic]
    fn percentile_of_empty_slice_panics() {
        let _ = percentile_sorted(&[], 50.0);
    }

    #[test]
    fn percentile_single_element_is_that_element() {
        for q in [0.0, 37.5, 50.0, 100.0] {
            assert_eq!(percentile_sorted(&[42.0], q), 42.0);
        }
    }

    #[test]
    fn percentile_extremes_hit_min_and_max() {
        let xs = [1.0, 2.0, 5.0, 9.0, 100.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 100.0);
    }

    #[test]
    fn percentile_duplicate_heavy_distribution() {
        // 99 copies of 10 and one 1000: every percentile below the last
        // rank must sit on the plateau, p100 on the outlier.
        let mut xs = vec![10.0; 99];
        xs.push(1000.0);
        assert_eq!(percentile_sorted(&xs, 50.0), 10.0);
        assert_eq!(percentile_sorted(&xs, 98.0), 10.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 1000.0);
        // p99 interpolates between the plateau and the outlier.
        let p99 = percentile_sorted(&xs, 99.0);
        assert!(p99 > 10.0 && p99 < 1000.0, "{p99}");
    }

    #[test]
    fn ns_to_ms_converts() {
        assert_eq!(ns_to_ms(1_500_000.0), 1.5);
        assert_eq!(ns_to_ms(0.0), 0.0);
    }

    #[test]
    fn reservoir_million_records_stays_at_cap() {
        let mut r = Reservoir::new(1024, 7);
        for i in 0..1_000_000u64 {
            r.record(i as f64);
        }
        assert_eq!(r.len(), 1024);
        assert_eq!(r.seen(), 1_000_000);
        // Exact stats survive the sampling.
        assert_eq!(r.max(), 999_999.0);
        assert!((r.mean() - 499_999.5).abs() < 1e-6, "{}", r.mean());
        // The sampled median of a uniform ramp lands near the true median.
        let sorted = r.sorted();
        let p50 = percentile_sorted(&sorted, 50.0);
        assert!((p50 - 500_000.0).abs() < 100_000.0, "p50={p50}");
    }

    #[test]
    fn reservoir_is_deterministic_for_same_seed() {
        let mut a = Reservoir::new(64, 99);
        let mut b = Reservoir::new(64, 99);
        for i in 0..10_000u64 {
            let x = (i * 2654435761 % 1000) as f64;
            a.record(x);
            b.record(x);
        }
        assert_eq!(a.sorted(), b.sorted());
        let mut c = Reservoir::new(64, 100);
        for i in 0..10_000u64 {
            c.record((i * 2654435761 % 1000) as f64);
        }
        assert_ne!(a.sorted(), c.sorted(), "different seeds keep different samples");
    }

    #[test]
    fn reservoir_below_cap_is_exact() {
        let mut r = Reservoir::new(128, 3);
        for x in [5.0, 1.0, 9.0] {
            r.record(x);
        }
        assert_eq!(r.sorted(), vec![1.0, 5.0, 9.0]);
        assert_eq!(r.mean(), 5.0);
        assert_eq!(r.max(), 9.0);
        assert!(!r.is_empty());
        assert_eq!(Reservoir::new(4, 1).mean(), 0.0);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5_000_000_000.0).contains(" s"));
    }
}
